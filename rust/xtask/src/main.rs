//! Repo automation binary. CI (and developers) run the source lints with
//!
//! ```text
//! cargo run -p xtask -- lint
//! ```
//!
//! Two lints, both zero-dependency text scans over `rust/src`:
//!
//! 1. **Panic hygiene** (ratchet): the runtime and serving layers
//!    (`src/coordinator`, `src/runtime`) must not grow new
//!    `.unwrap()` / `.expect(` / `panic!` sites — worker panics are
//!    supposed to flow through the typed `XgenError` surface, not unwind
//!    the serving loop. The count is pinned at [`PANIC_BASELINE`]; going
//!    above fails the lint (handle the error or, for a checker whose job
//!    is to panic, bump the baseline in the same PR with justification),
//!    and going below prints a reminder to ratchet the baseline down.
//!    This replaces the old grep-based CI step with the same contract.
//!
//! 2. **Unsafe allow-list**: `unsafe` may appear only in the audited
//!    modules ([`UNSAFE_ALLOW`]) that Miri covers in CI. Any new `unsafe`
//!    elsewhere fails the lint; extending the allow-list means extending
//!    the Miri job too.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Pinned line count of `.unwrap()` / `.expect(` / `panic!` matches under
/// [`PANIC_DIRS`]. History: 48 after the PR-6 fault-tolerance work; 49
/// after PR 7 added the `SharedSlice` claim registry, whose overlap check
/// panics by design (it fires only on a soundness bug, in debug builds);
/// 50 after PR 8 added `fault::on_stream_step`, whose `Panic` fault kind
/// panics by design — it exists to drive the stream scheduler's
/// catch-unwind isolation in the chaos tests. The scheduler itself
/// (`src/coordinator/scheduler.rs`) contributes zero sites.
const PANIC_BASELINE: usize = 50;

/// Directories the panic-hygiene ratchet covers, relative to `rust/`.
const PANIC_DIRS: &[&str] = &["src/coordinator", "src/runtime"];

/// The only files allowed to contain `unsafe`, relative to `rust/`. All
/// three are exercised by the Miri CI job.
const UNSAFE_ALLOW: &[&str] = &["src/runtime/pool.rs", "src/tensor/gemm.rs", "src/fkw/mod.rs"];

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => lint(),
        _ => {
            eprintln!("usage: cargo run -p xtask -- lint");
            ExitCode::from(2)
        }
    }
}

/// `rust/` — xtask lives at `rust/xtask`, so the sources are one level up.
fn rust_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..")
}

fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    if let Ok(rd) = std::fs::read_dir(dir) {
        for e in rd.flatten() {
            let p = e.path();
            if p.is_dir() {
                rs_files(&p, out);
            } else if p.extension().is_some_and(|x| x == "rs") {
                out.push(p);
            }
        }
    }
}

fn lint() -> ExitCode {
    let root = rust_root();
    let mut failed = false;

    // --- 1. panic hygiene ratchet -----------------------------------
    let mut total = 0usize;
    let mut per_file: Vec<(PathBuf, usize)> = Vec::new();
    for dir in PANIC_DIRS {
        let mut files = Vec::new();
        rs_files(&root.join(dir), &mut files);
        files.sort();
        for f in files {
            let text = std::fs::read_to_string(&f).unwrap_or_default();
            let n = text
                .lines()
                .filter(|l| l.contains(".unwrap()") || l.contains(".expect(") || l.contains("panic!"))
                .count();
            if n > 0 {
                per_file.push((f, n));
            }
            total += n;
        }
    }
    if total > PANIC_BASELINE {
        failed = true;
        eprintln!(
            "lint(panic-hygiene): FAIL — {total} panic sites in {:?}, baseline {PANIC_BASELINE}",
            PANIC_DIRS
        );
        for (f, n) in &per_file {
            eprintln!("  {:3}  {}", n, f.display());
        }
        eprintln!("  handle the error instead, or bump PANIC_BASELINE in xtask with justification");
    } else {
        println!("lint(panic-hygiene): ok — {total} sites (baseline {PANIC_BASELINE})");
        if total < PANIC_BASELINE {
            println!("  note: below baseline — ratchet PANIC_BASELINE down to {total} in xtask");
        }
    }

    // --- 2. unsafe allow-list ---------------------------------------
    let mut files = Vec::new();
    rs_files(&root.join("src"), &mut files);
    files.sort();
    let mut violations = 0usize;
    for f in files {
        let rel = f
            .strip_prefix(&root)
            .unwrap_or(&f)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        if UNSAFE_ALLOW.contains(&rel.as_str()) {
            continue;
        }
        let text = std::fs::read_to_string(&f).unwrap_or_default();
        for (i, line) in text.lines().enumerate() {
            // Strip line comments so docs may *discuss* unsafety freely.
            let code = line.split("//").next().unwrap_or("");
            if has_word(code, "unsafe") {
                failed = true;
                violations += 1;
                eprintln!("lint(unsafe): FAIL — {rel}:{}: `unsafe` outside the allow-list", i + 1);
            }
        }
    }
    if violations == 0 {
        println!("lint(unsafe): ok — unsafe confined to {UNSAFE_ALLOW:?}");
    } else {
        eprintln!("  allowed files: {UNSAFE_ALLOW:?} (each must be covered by the Miri CI job)");
    }

    if failed {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}

/// Whole-word match: `needle` in `hay` with no identifier char on either side.
fn has_word(hay: &str, needle: &str) -> bool {
    let bytes = hay.as_bytes();
    let mut from = 0;
    while let Some(pos) = hay[from..].find(needle) {
        let start = from + pos;
        let end = start + needle.len();
        let pre_ok = start == 0 || !is_ident(bytes[start - 1]);
        let post_ok = end >= bytes.len() || !is_ident(bytes[end]);
        if pre_ok && post_ok {
            return true;
        }
        from = start + 1;
    }
    false
}

fn is_ident(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}
