//! Repo automation binary. CI (and developers) run the source lints with
//!
//! ```text
//! cargo run -p xtask -- lint
//! ```
//!
//! Three lints, all zero-dependency text scans over `rust/src`:
//!
//! 1. **Panic hygiene** (ratchet): the runtime and serving layers
//!    (`src/coordinator`, `src/runtime`) and the quantizer
//!    (`src/pruning/quant.rs`, ISSUE-10: non-finite and shape faults are
//!    typed `XgenError`s now) must not grow new
//!    `.unwrap()` / `.expect(` / `panic!` sites — worker panics are
//!    supposed to flow through the typed `XgenError` surface, not unwind
//!    the serving loop. The count is pinned by `panic_baseline` in the
//!    checked-in `rust/xtask/lint.toml` (ISSUE-9 moved it out of a
//!    hardcoded constant so bumps are reviewable config diffs); going
//!    above fails the lint (handle the error or, for a checker whose job
//!    is to panic, bump the baseline in the same PR with justification),
//!    and going below prints a reminder to ratchet the baseline down.
//!
//! 2. **Unsafe allow-list**: `unsafe` may appear only in the audited
//!    modules ([`UNSAFE_ALLOW`]) that Miri covers in CI. Any new `unsafe`
//!    elsewhere fails the lint; extending the allow-list means extending
//!    the Miri job too.
//!
//! 3. **SAFETY comments** (ISSUE-9): every `unsafe` site *inside* the
//!    allow-listed modules must carry a `// SAFETY:` comment (or a
//!    `# Safety` doc section for `unsafe fn`) within the
//!    [`SAFETY_WINDOW`] lines above it, stating the invariant that makes
//!    it sound. An unannotated `unsafe` fails the lint.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

/// Directories (or single `.rs` files) the panic-hygiene ratchet covers,
/// relative to `rust/`. `src/pruning/quant.rs` joined in ISSUE-10 when the
/// quantizer's asserts became typed errors — it must stay at zero sites.
const PANIC_DIRS: &[&str] = &["src/coordinator", "src/runtime", "src/pruning/quant.rs"];

/// The only files allowed to contain `unsafe`, relative to `rust/`. All
/// four are exercised by the Miri CI job.
const UNSAFE_ALLOW: &[&str] = &[
    "src/runtime/pool.rs",
    "src/tensor/gemm.rs",
    "src/tensor/qgemm.rs",
    "src/fkw/mod.rs",
];

/// How many lines above an `unsafe` site a `SAFETY:` / `# Safety`
/// annotation may sit (covers attribute + doc-comment stacks between the
/// comment and the `unsafe fn` / block it justifies).
const SAFETY_WINDOW: usize = 8;

/// Read `panic_baseline` from `rust/xtask/lint.toml`. A missing or
/// unparsable file is a hard lint failure — the baseline is part of the
/// reviewed source tree, not an optional default.
fn read_baseline(root: &Path) -> Result<usize, String> {
    let path = root.join("xtask/lint.toml");
    let text = std::fs::read_to_string(&path)
        .map_err(|e| format!("cannot read {}: {e}", path.display()))?;
    for line in text.lines() {
        let line = line.split('#').next().unwrap_or("").trim();
        if let Some((key, val)) = line.split_once('=') {
            if key.trim() == "panic_baseline" {
                return val
                    .trim()
                    .parse::<usize>()
                    .map_err(|e| format!("bad panic_baseline in lint.toml: {e}"));
            }
        }
    }
    Err(format!("panic_baseline missing from {}", path.display()))
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => lint(),
        _ => {
            eprintln!("usage: cargo run -p xtask -- lint");
            ExitCode::from(2)
        }
    }
}

/// `rust/` — xtask lives at `rust/xtask`, so the sources are one level up.
fn rust_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..")
}

fn rs_files(dir: &Path, out: &mut Vec<PathBuf>) {
    // Entries may name a single `.rs` file directly (PANIC_DIRS carries
    // `src/pruning/quant.rs`) — `read_dir` on a file silently yields
    // nothing, so handle that case explicitly.
    if dir.is_file() {
        if dir.extension().is_some_and(|x| x == "rs") {
            out.push(dir.to_path_buf());
        }
        return;
    }
    if let Ok(rd) = std::fs::read_dir(dir) {
        for e in rd.flatten() {
            let p = e.path();
            if p.is_dir() {
                rs_files(&p, out);
            } else if p.extension().is_some_and(|x| x == "rs") {
                out.push(p);
            }
        }
    }
}

fn lint() -> ExitCode {
    let root = rust_root();
    let mut failed = false;

    let baseline = match read_baseline(&root) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("lint(config): FAIL — {e}");
            return ExitCode::FAILURE;
        }
    };

    // --- 1. panic hygiene ratchet -----------------------------------
    let mut total = 0usize;
    let mut per_file: Vec<(PathBuf, usize)> = Vec::new();
    for dir in PANIC_DIRS {
        let mut files = Vec::new();
        rs_files(&root.join(dir), &mut files);
        files.sort();
        for f in files {
            let text = std::fs::read_to_string(&f).unwrap_or_default();
            let n = text
                .lines()
                .filter(|l| l.contains(".unwrap()") || l.contains(".expect(") || l.contains("panic!"))
                .count();
            if n > 0 {
                per_file.push((f, n));
            }
            total += n;
        }
    }
    if total > baseline {
        failed = true;
        eprintln!(
            "lint(panic-hygiene): FAIL — {total} panic sites in {:?}, baseline {baseline}",
            PANIC_DIRS
        );
        for (f, n) in &per_file {
            eprintln!("  {:3}  {}", n, f.display());
        }
        eprintln!(
            "  handle the error instead, or bump panic_baseline in xtask/lint.toml \
             with justification"
        );
    } else {
        println!("lint(panic-hygiene): ok — {total} sites (baseline {baseline})");
        if total < baseline {
            println!(
                "  note: below baseline — ratchet panic_baseline down to {total} in \
                 xtask/lint.toml"
            );
        }
    }

    // --- 2. unsafe allow-list ---------------------------------------
    let mut files = Vec::new();
    rs_files(&root.join("src"), &mut files);
    files.sort();
    let mut violations = 0usize;
    for f in files {
        let rel = f
            .strip_prefix(&root)
            .unwrap_or(&f)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        if UNSAFE_ALLOW.contains(&rel.as_str()) {
            continue;
        }
        let text = std::fs::read_to_string(&f).unwrap_or_default();
        for (i, line) in text.lines().enumerate() {
            // Strip line comments so docs may *discuss* unsafety freely.
            let code = line.split("//").next().unwrap_or("");
            if has_word(code, "unsafe") {
                failed = true;
                violations += 1;
                eprintln!("lint(unsafe): FAIL — {rel}:{}: `unsafe` outside the allow-list", i + 1);
            }
        }
    }
    if violations == 0 {
        println!("lint(unsafe): ok — unsafe confined to {UNSAFE_ALLOW:?}");
    } else {
        eprintln!("  allowed files: {UNSAFE_ALLOW:?} (each must be covered by the Miri CI job)");
    }

    // --- 3. SAFETY comments on allow-listed unsafe ------------------
    let mut unannotated = 0usize;
    let mut sites = 0usize;
    for rel in UNSAFE_ALLOW {
        let path = root.join(rel);
        let text = std::fs::read_to_string(&path).unwrap_or_default();
        let lines: Vec<&str> = text.lines().collect();
        for (i, line) in lines.iter().enumerate() {
            // Comments may *discuss* unsafety; only code counts as a site.
            let code = line.split("//").next().unwrap_or("");
            if !has_word(code, "unsafe") {
                continue;
            }
            sites += 1;
            // Accept `// SAFETY:` (blocks/impls) or a `# Safety` doc
            // section (unsafe fn) on the site line or within the window
            // above it — attributes and doc stacks sit in between.
            let from = i.saturating_sub(SAFETY_WINDOW);
            let ok = lines[from..=i]
                .iter()
                .any(|l| l.contains("SAFETY:") || l.contains("# Safety"));
            if !ok {
                failed = true;
                unannotated += 1;
                eprintln!(
                    "lint(safety-comment): FAIL — {rel}:{}: `unsafe` without a SAFETY: \
                     comment within {SAFETY_WINDOW} lines",
                    i + 1
                );
            }
        }
    }
    if unannotated == 0 {
        println!("lint(safety-comment): ok — {sites} unsafe sites all annotated");
    } else {
        eprintln!("  state the invariant that makes each site sound, above the site");
    }

    if failed {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}

/// Whole-word match: `needle` in `hay` with no identifier char on either side.
fn has_word(hay: &str, needle: &str) -> bool {
    let bytes = hay.as_bytes();
    let mut from = 0;
    while let Some(pos) = hay[from..].find(needle) {
        let start = from + pos;
        let end = start + needle.len();
        let pre_ok = start == 0 || !is_ident(bytes[start - 1]);
        let post_ok = end >= bytes.len() || !is_ident(bytes[end]);
        if pre_ok && post_ok {
            return true;
        }
        from = start + 1;
    }
    false
}

fn is_ident(b: u8) -> bool {
    b == b'_' || b.is_ascii_alphanumeric()
}
