//! E1 — Fig 17: average speedup summary over the other frameworks under
//! the same accuracy (the bar chart derived from Table 3).

use xgen::baselines::{DeviceClass, Framework};
use xgen::cost::{devices, estimate_latency, scheme_density_map, sparse_efficiency};
use xgen::graph::zoo::by_name;
use xgen::pruning::PruneScheme;
use xgen::util::bench::Table;

fn lat(model: &str, fw: Framework, class: DeviceClass) -> Option<f64> {
    let g = by_name(model, 1);
    if !fw.supports(&g, class) {
        return None;
    }
    let dev = match class {
        DeviceClass::MobileCpu => devices::s10_cpu(),
        DeviceClass::MobileGpu => devices::s10_gpu(),
        _ => return None,
    };
    let scheme = fw.deploy_scheme();
    let plan = fw.fusion_plan(&g);
    let prof = fw.profile(class)?;
    let dm = if matches!(scheme, PruneScheme::None) {
        Default::default()
    } else {
        scheme_density_map(&g, &scheme)
    };
    Some(estimate_latency(&g, &plan, &dev, &prof, &dm, sparse_efficiency(&scheme)).total_ms())
}

fn main() {
    let models = [
        "efficientnet-b0",
        "resnet-50",
        "vgg-16",
        "mobilenet-v1-ssd",
        "mobilenet-v3",
        "yolo-v4",
        "u-net",
    ];
    let paper = [("MNN", 6.4), ("TVM", 8.2), ("TFLite", 6.8), ("PyTorch", 16.5)];
    let mut t = Table::new(&["Baseline", "Ours (geomean)", "Ours (mean)", "Paper (mean)"]);
    for (fw, paper_x) in [
        (Framework::Mnn, paper[0].1),
        (Framework::Tvm, paper[1].1),
        (Framework::TfLite, paper[2].1),
        (Framework::PyTorchMobile, paper[3].1),
    ] {
        let mut ratios = Vec::new();
        for m in models {
            for class in [DeviceClass::MobileCpu, DeviceClass::MobileGpu] {
                if let (Some(b), Some(x)) = (lat(m, fw, class), lat(m, Framework::XGenFull, class))
                {
                    ratios.push(b / x);
                }
            }
        }
        if ratios.is_empty() {
            continue;
        }
        t.row(vec![
            fw.name().to_string(),
            format!("{:.1}x", xgen::util::geomean(&ratios)),
            format!("{:.1}x", xgen::util::mean(&ratios)),
            format!("{paper_x:.1}x"),
        ]);
    }
    t.print("Fig 17 — average XGen speedup under the same accuracy");
}
