//! E3 — Table 5: the autonomous-driving runtime ablation — 6 app variants
//! × 5 scheduling regimes on the Jetson-class simulator, reporting
//! mean ± std per module and the worst miss rate, exactly in the paper's
//! layout.

use xgen::util::bench::Table;
use xgen::xengine::adapp::{modules, variants};
use xgen::xengine::sim::simulate;
use xgen::xengine::Policy;

fn main() {
    let shown = ["sensing", "3d_percept", "2d_percept", "localization", "tracking", "prediction", "planning"];
    for (si, policy) in Policy::all().into_iter().enumerate() {
        let mut t = Table::new(&[
            "App", "Sensing", "3D Percept", "2D Percept", "Localize", "Tracking", "Predict",
            "Planning", "Miss",
        ]);
        for v in variants() {
            let mods = modules(v);
            let r = simulate(v.name, &mods, policy, 5000.0, 0xAB00 + si as u64);
            let mut row = vec![v.name.to_string()];
            for name in shown {
                let m = r.module(name);
                if m.timed_out() {
                    row.push("∞".to_string());
                } else {
                    row.push(format!("{:.1}±{:.1}", m.mean(), m.std()));
                }
            }
            row.push(format!("{:.0}%", r.worst_miss_rate() * 100.0));
            t.row(row);
        }
        t.print(&format!("Table 5 segment {} — {}", si + 1, policy.name()));
    }
    println!("\npaper shape: seg1 ∞/100%, seg2–4 ~100% miss (2D percept sluggish), seg5 0%.");
}
