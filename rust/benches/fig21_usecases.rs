//! E8 — Fig 21: the three product use cases — car classification (2–3.3×),
//! home safety monitor / S3D (22.6× vs PyTorch), super-resolution / WDSR
//! (1.9× compiler-only, 7.2× with pruning).

use xgen::baselines::{DeviceClass, Framework};
use xgen::coordinator::compile;
use xgen::cost::devices;
use xgen::graph::zoo::by_name;
use xgen::graph::WeightStore;
use xgen::pruning::PruneScheme;
use xgen::util::bench::Table;
use xgen::util::rng::Rng;

fn main() {
    let gpu = devices::s10_gpu();
    let cpu = devices::s10_cpu();
    let mut rng = Rng::new(21);
    let mut t = Table::new(&["Use case", "Baseline", "Base (ms)", "XGen (ms)", "Speedup", "Paper"]);

    // I: car classification (EfficientNet-B0 class).
    let base = compile(by_name("efficientnet-b0", 1), None, PruneScheme::None)
        .latency_ms(&gpu, Framework::Mnn, DeviceClass::MobileGpu)
        .unwrap();
    let g = by_name("efficientnet-b0", 1);
    let mut ws = WeightStore::init_random(&g, &mut rng);
    let x = compile(g, Some(&mut ws), PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.35 })
        .latency_ms(&gpu, Framework::XGenFull, DeviceClass::MobileGpu)
        .unwrap();
    t.row(vec![
        "car classification".into(),
        "MNN".into(),
        format!("{base:.1}"),
        format!("{x:.1}"),
        format!("{:.1}x", base / x),
        "2-3.3x".into(),
    ]);

    // II: home monitor (S3D), vs PyTorch Mobile (the only baseline that runs it).
    let base = compile(by_name("s3d", 1), None, PruneScheme::None)
        .latency_ms(&cpu, Framework::PyTorchMobile, DeviceClass::MobileCpu)
        .unwrap();
    let g = by_name("s3d", 1);
    let mut ws = WeightStore::init_random(&g, &mut rng);
    let x = compile(g, Some(&mut ws), PruneScheme::Block { block: 8, rate: 0.8 })
        .latency_ms(&gpu, Framework::XGenFull, DeviceClass::MobileGpu)
        .unwrap();
    t.row(vec![
        "home monitor (S3D)".into(),
        "PyTorch".into(),
        format!("{base:.0}"),
        format!("{x:.0}"),
        format!("{:.1}x", base / x),
        "22.6x".into(),
    ]);

    // III: super resolution (WDSR) vs TFLite: compiler-only, then +pruning.
    let base = compile(by_name("wdsr-b", 1), None, PruneScheme::None)
        .latency_ms(&gpu, Framework::TfLite, DeviceClass::MobileGpu)
        .unwrap();
    let comp_only = compile(by_name("wdsr-b", 1), None, PruneScheme::None)
        .latency_ms(&gpu, Framework::XGenFull, DeviceClass::MobileGpu)
        .unwrap();
    let g = by_name("wdsr-b", 1);
    let mut ws = WeightStore::init_random(&g, &mut rng);
    let pruned = compile(g, Some(&mut ws), PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.4 })
        .latency_ms(&gpu, Framework::XGenFull, DeviceClass::MobileGpu)
        .unwrap();
    t.row(vec![
        "super res (compiler)".into(),
        "TFLite".into(),
        format!("{base:.1}"),
        format!("{comp_only:.1}"),
        format!("{:.1}x", base / comp_only),
        "1.9x".into(),
    ]);
    t.row(vec![
        "super res (+pruning)".into(),
        "TFLite".into(),
        format!("{base:.1}"),
        format!("{pruned:.1}"),
        format!("{:.1}x", base / pruned),
        "7.2x".into(),
    ]);
    t.print("Fig 21 — use cases (cost model on Galaxy-S10-class device)");
    println!(
        "\nsuper-res FPS: TFLite {:.1} -> XGen {:.1} (paper: 5 -> 36)",
        1000.0 / base,
        1000.0 / pruned
    );
}
