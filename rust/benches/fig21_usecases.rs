//! E8 — Fig 21: the three product use cases — car classification (2–3.3×),
//! home safety monitor / S3D (22.6× vs PyTorch), super-resolution / WDSR
//! (1.9× compiler-only, 7.2× with pruning). All sessions are built through
//! `xgen::api::Compiler`; baselines estimate from a dense compile, XGen
//! from a pruned one.

use xgen::api::Compiler;
use xgen::baselines::{DeviceClass, Framework};
use xgen::cost::devices;
use xgen::pruning::PruneScheme;
use xgen::util::bench::Table;

fn dense_ms(model: &str, fw: Framework, class: DeviceClass, dev: &xgen::cost::Device) -> f64 {
    Compiler::for_model(model, 1)
        .unwrap()
        .compile()
        .unwrap()
        .estimate(dev, fw, class)
        .unwrap()
}

fn xgen_ms(model: &str, scheme: PruneScheme, class: DeviceClass, dev: &xgen::cost::Device) -> f64 {
    Compiler::for_model(model, 1)
        .unwrap()
        .random_weights(21)
        .scheme(scheme)
        .compile()
        .unwrap()
        .estimate(dev, Framework::XGenFull, class)
        .unwrap()
}

fn main() {
    let gpu = devices::s10_gpu();
    let cpu = devices::s10_cpu();
    let mut t = Table::new(&["Use case", "Baseline", "Base (ms)", "XGen (ms)", "Speedup", "Paper"]);

    // I: car classification (EfficientNet-B0 class).
    let base = dense_ms("efficientnet-b0", Framework::Mnn, DeviceClass::MobileGpu, &gpu);
    let x = xgen_ms(
        "efficientnet-b0",
        PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.35 },
        DeviceClass::MobileGpu,
        &gpu,
    );
    t.row(vec![
        "car classification".into(),
        "MNN".into(),
        format!("{base:.1}"),
        format!("{x:.1}"),
        format!("{:.1}x", base / x),
        "2-3.3x".into(),
    ]);

    // II: home monitor (S3D), vs PyTorch Mobile (the only baseline that runs it).
    let base = dense_ms("s3d", Framework::PyTorchMobile, DeviceClass::MobileCpu, &cpu);
    let x = xgen_ms(
        "s3d",
        PruneScheme::Block { block: 8, rate: 0.8 },
        DeviceClass::MobileGpu,
        &gpu,
    );
    t.row(vec![
        "home monitor (S3D)".into(),
        "PyTorch".into(),
        format!("{base:.0}"),
        format!("{x:.0}"),
        format!("{:.1}x", base / x),
        "22.6x".into(),
    ]);

    // III: super resolution (WDSR) vs TFLite: compiler-only, then +pruning.
    let base = dense_ms("wdsr-b", Framework::TfLite, DeviceClass::MobileGpu, &gpu);
    let comp_only = dense_ms("wdsr-b", Framework::XGenFull, DeviceClass::MobileGpu, &gpu);
    let pruned = xgen_ms(
        "wdsr-b",
        PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.4 },
        DeviceClass::MobileGpu,
        &gpu,
    );
    t.row(vec![
        "super res (compiler)".into(),
        "TFLite".into(),
        format!("{base:.1}"),
        format!("{comp_only:.1}"),
        format!("{:.1}x", base / comp_only),
        "1.9x".into(),
    ]);
    t.row(vec![
        "super res (+pruning)".into(),
        "TFLite".into(),
        format!("{base:.1}"),
        format!("{pruned:.1}"),
        format!("{:.1}x", base / pruned),
        "7.2x".into(),
    ]);
    t.print("Fig 21 — use cases (cost model on Galaxy-S10-class device)");
    println!(
        "\nsuper-res FPS: TFLite {:.1} -> XGen {:.1} (paper: 5 -> 36)",
        1000.0 / base,
        1000.0 / pruned
    );
}
