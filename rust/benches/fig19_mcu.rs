//! E7 — Fig 19: MCU (STM32F469NI) latency — TFLM vs XGen with loop
//! unrolling and optimized (per-channel) quantization. The unrolling gain
//! is *derived* from the codegen register/spill model (not hardcoded):
//! `codegen::tune_unroll` picks the unroll factor for the M4's register
//! file, and the spill delta converts to cycles saved. Paper: 1.2× from
//! unrolling, 1.8× total with optimized quantization.

use xgen::baselines::{DeviceClass, Framework};
use xgen::codegen::{pattern_load_stats, spill_estimate, tune_unroll};
use xgen::cost::{devices, estimate_latency};
use xgen::graph::zoo::by_name;
use xgen::pruning::pattern::PatternSet;
use xgen::pruning::quant::{quant_rms_error, QuantMode};
use xgen::tensor::Tensor;
use xgen::util::bench::Table;
use xgen::util::rng::Rng;

const M4_REGS: usize = 13; // usable GP registers on Cortex-M4

fn main() {
    let g = by_name("mobilenet-v2", 1);

    // TFLM baseline: CMSIS-NN kernels, unroll 1 (spilling inner loop).
    let tflm_prof = Framework::Tflm.profile(DeviceClass::Mcu).unwrap();
    let plan = Framework::Tflm.fusion_plan(&g);
    let tflm_ms =
        estimate_latency(&g, &plan, &devices::stm32_mcu(), &tflm_prof, &Default::default(), 1.0)
            .total_ms();

    // XGen + unrolling: speedup from the register model. An unrolled body
    // amortizes loop overhead (~2 cycles/4 MACs) and removes spills.
    let p = PatternSet::elite8().patterns[0];
    let u = tune_unroll(p, M4_REGS);
    let naive_spills = spill_estimate(p, 8, M4_REGS); // what a fixed unroll-8 kernel would spill
    let loads = pattern_load_stats(p, u);
    // cycles per 4-MAC body: naive = 4 MACs + 4 loads + 2 loop; unrolled =
    // 4 MACs + LRE loads/u + 2/u loop.
    let naive_cycles = 4.0 + 4.0 + 2.0;
    let opt_cycles = 4.0 + loads.lre as f64 / u as f64 + 2.0 / u as f64;
    let unroll_speedup = naive_cycles / opt_cycles;
    let xgen_unroll_ms = tflm_ms / unroll_speedup.min(1.6);

    // + optimized quantization: per-channel int8 keeps the whole net on
    // the int8 SIMD path (no per-layer requant fallbacks to f32).
    let mut rng = Rng::new(19);
    let w = Tensor::randn(&[32, 144], 0.8, &mut rng);
    let e_t = quant_rms_error(&w, QuantMode::PerTensor).expect("finite weights");
    let e_c = quant_rms_error(&w, QuantMode::PerChannel).expect("finite weights");
    // Layers whose per-tensor error exceeds budget fall back to f32 in
    // TFLM (4x slower); per-channel keeps them int8.
    let f32_fallback_frac: f64 = 0.18;
    // Per-tensor int8 forces ~18% of layers back to f32 (4x slower each);
    // per-channel scales keep everything int8: speedup = 1 + 3f ≈ 1.54.
    let quant_speedup: f64 = 1.0 + 3.0 * f32_fallback_frac;
    let xgen_quant_ms = xgen_unroll_ms / quant_speedup.min(1.6);

    let mut t = Table::new(&["Config", "Latency (ms)", "Speedup", "Paper"]);
    t.row(vec!["TFLM (CMSIS-NN)".into(), format!("{tflm_ms:.0}"), "1.0x".into(), "1.0x".into()]);
    t.row(vec![
        format!("XGen + unrolling (u={u}, spills {naive_spills}->0)"),
        format!("{xgen_unroll_ms:.0}"),
        format!("{:.1}x", tflm_ms / xgen_unroll_ms),
        "1.2x".into(),
    ]);
    t.row(vec![
        "XGen + optimized quantization".into(),
        format!("{xgen_quant_ms:.0}"),
        format!("{:.1}x", tflm_ms / xgen_quant_ms),
        "1.8x".into(),
    ]);
    t.print("Fig 19 — MobileNet-V2 on STM32F469NI");
    println!(
        "\nquantization error (per-tensor {e_t:.4} vs per-channel {e_c:.4}) is what keeps \
         XGen's int8 path accurate enough to avoid f32 fallbacks."
    );
}
