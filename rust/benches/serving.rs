//! Multi-stream decode serving bench — the ISSUE-8 acceptance artifact.
//!
//! Drives a many-streams load through the `coordinator::StreamScheduler`
//! (one client thread per stream, all submitted at once) over a bounded
//! session pool on `demo-transformer-causal`, and reports:
//!
//! * **aggregate tokens/sec** across all concurrent streams, and
//! * **per-stream completion latency** (submit → last token) p50/p99.
//!
//! Before timing, the scheduler's output is asserted bit-for-bit equal to
//! single-stream `CompiledModel::generate` — the bench never measures a
//! wrong answer. Writes `BENCH_serving.json` at the repo root (fields
//! documented in EXPERIMENTS.md §Serving). `XGEN_BENCH_QUICK=1` shrinks
//! the load for the CI smoke job.

use std::sync::Arc;
use std::time::Instant;

use xgen::api::{CompiledModel, Compiler};
use xgen::coordinator::{SchedConfig, StreamScheduler};
use xgen::util::bench::Table;
use xgen::util::json::Json;
use xgen::util::stats::{percentile_sorted, Summary};

fn causal() -> CompiledModel {
    Compiler::for_model("demo-transformer-causal", 1)
        .unwrap()
        .random_weights(42)
        .compile()
        .unwrap()
}

/// Distinct valid prompts: rotations of a fixed in-vocab base.
fn prompts(count: usize) -> Vec<Vec<u32>> {
    let base: Vec<u32> = vec![7, 42, 3, 255, 0, 99];
    (0..count)
        .map(|i| {
            let mut p = base.clone();
            p.rotate_left(i % p.len());
            p
        })
        .collect()
}

fn main() {
    let quick = std::env::var("XGEN_BENCH_QUICK").is_ok();
    let (streams, tokens, samples) = if quick { (16, 4, 2) } else { (64, 8, 5) };
    let pool = 4usize;
    let prompt_len = 6usize;
    let max_seq = prompt_len + tokens - 1;
    let ps = prompts(streams);
    let cfg = || SchedConfig { max_streams: pool, ..SchedConfig::default() };

    // ---- correctness guard: scheduler == single-stream decode, bitwise --
    let m = causal();
    let expect: Vec<Vec<u32>> =
        ps.iter().take(4).map(|p| m.generate(p, tokens).unwrap()).collect();
    let sched = StreamScheduler::start_cfg(m, max_seq, cfg()).unwrap();
    for (i, p) in ps.iter().take(4).enumerate() {
        let (toks, err) = sched.submit(p.clone(), tokens).collect();
        assert!(err.is_none(), "warm-up stream {i} failed: {err:?}");
        assert_eq!(toks, expect[i], "scheduler must match single-stream decode bitwise");
    }
    let session_kv_bytes = sched.stats().session_kv_bytes;
    drop(sched);

    // ---- measured load: all streams submitted at once ------------------
    let mut agg_tok_s: Vec<f64> = Vec::new();
    let mut lat_ms: Vec<f64> = Vec::new();
    for _ in 0..samples {
        let sched = Arc::new(StreamScheduler::start_cfg(causal(), max_seq, cfg()).unwrap());
        let t0 = Instant::now();
        let clients: Vec<_> = ps
            .iter()
            .map(|p| {
                let sched = sched.clone();
                let p = p.clone();
                std::thread::spawn(move || {
                    let t = Instant::now();
                    let (toks, err) = sched.submit(p, tokens).collect();
                    assert!(err.is_none(), "stream failed under load: {err:?}");
                    (toks.len(), t.elapsed().as_secs_f64() * 1e3)
                })
            })
            .collect();
        let mut total = 0usize;
        for c in clients {
            let (n, ms) = c.join().unwrap();
            total += n;
            lat_ms.push(ms);
        }
        let wall = t0.elapsed().as_secs_f64();
        assert_eq!(total, streams * tokens, "every stream must deliver all its tokens");
        agg_tok_s.push(total as f64 / wall.max(1e-9));
    }
    lat_ms.sort_by(f64::total_cmp);
    let s = Summary::of(&agg_tok_s);
    let p50 = percentile_sorted(&lat_ms, 0.50);
    let p99 = percentile_sorted(&lat_ms, 0.99);

    let mut t = Table::new(&["metric", "value"]);
    t.row(vec!["aggregate tok/s (mean)".into(), format!("{:.0}", s.mean)]);
    t.row(vec!["aggregate tok/s (min..max)".into(), format!("{:.0}..{:.0}", s.min, s.max)]);
    t.row(vec!["stream latency p50".into(), format!("{p50:.2} ms")]);
    t.row(vec!["stream latency p99".into(), format!("{p99:.2} ms")]);
    t.print(&format!(
        "multi-stream decode serving (demo-transformer-causal, {streams} streams × {tokens} \
         tokens, pool {pool}, kv/session {:.1} KB)",
        session_kv_bytes as f64 / 1024.0
    ));

    let json = Json::obj(vec![
        ("bench", Json::str("serving")),
        ("model", Json::str("demo-transformer-causal")),
        ("streams", Json::num(streams as f64)),
        ("tokens_per_stream", Json::num(tokens as f64)),
        ("pool_sessions", Json::num(pool as f64)),
        ("session_kv_bytes", Json::num(session_kv_bytes as f64)),
        ("aggregate_tok_per_s_mean", Json::num(s.mean)),
        ("aggregate_tok_per_s_std", Json::num(s.std)),
        ("stream_latency_p50_ms", Json::num(p50)),
        ("stream_latency_p99_ms", Json::num(p99)),
        ("samples", Json::num(samples as f64)),
    ]);
    let path = if std::path::Path::new("../ROADMAP.md").exists() {
        "../BENCH_serving.json"
    } else {
        "BENCH_serving.json"
    };
    match std::fs::write(path, json.to_string() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}
