//! E9 — §2.2.1 claim: "with graph rewriting, there are 18% fewer fused
//! layers left after fusion on GPT-2." Fuses the frontend-dump GPT-2 with
//! and without the rewriting pass and reports the reduction.

use xgen::fusion::{fuse, FusionConfig};
use xgen::graph::zoo::nlp;
use xgen::rewrite::{rewrite, RewriteConfig};
use xgen::util::bench::Table;

fn main() {
    let mut t = Table::new(&["Config", "Ops", "Fused layers", "Reduction"]);
    let g0 = nlp::gpt2_frontend(1);
    let plan0 = fuse(&g0, &FusionConfig::default());

    let mut g1 = nlp::gpt2_frontend(1);
    let stats = rewrite(&mut g1, None, &RewriteConfig::default());
    let plan1 = fuse(&g1, &FusionConfig::default());

    let red = 1.0 - plan1.fused_layer_count() as f64 / plan0.fused_layer_count() as f64;
    t.row(vec![
        "fusion only".into(),
        g0.operator_count().to_string(),
        plan0.fused_layer_count().to_string(),
        "-".into(),
    ]);
    t.row(vec![
        "rewriting + fusion".into(),
        g1.operator_count().to_string(),
        plan1.fused_layer_count().to_string(),
        format!("{:.0}%", red * 100.0),
    ]);
    t.print("GPT-2 (12-layer frontend dump): fused layers with/without graph rewriting");
    println!("\npaper: 18% fewer fused layers; ours: {:.0}%", red * 100.0);
    println!("rewrite rule hits: {:?}", stats.hits);

    // Per-rule ablation: knock out one rule family at a time.
    let mut t = Table::new(&["Ablation", "Fused layers"]);
    for (name, cfg) in [
        ("full", RewriteConfig::default()),
        ("no constant folding", RewriteConfig { fold_constants: false, ..Default::default() }),
        ("no linear folding (assoc)", RewriteConfig { fold_linear: false, ..Default::default() }),
        ("no movement collapse", RewriteConfig { collapse_movement: false, ..Default::default() }),
        ("no commutation", RewriteConfig { commute_movement: false, ..Default::default() }),
        ("no distribution", RewriteConfig { distribute: false, ..Default::default() }),
    ] {
        let mut g = nlp::gpt2_frontend(1);
        rewrite(&mut g, None, &cfg);
        let plan = fuse(&g, &FusionConfig::default());
        t.row(vec![name.to_string(), plan.fused_layer_count().to_string()]);
    }
    t.print("rewrite-rule ablation (GPT-2)");
}
