//! Steady-state inference bench — the ISSUE-3 acceptance artifact.
//!
//! Sweeps the `xengine::knobs::steady_knobs()` toggle matrix
//! ({weight pre-packing, workspace arena, worker pool}) over end-to-end
//! `CompiledModel::infer()` on the demo CNN, verifying every configuration
//! against the all-off baseline, and writes `BENCH_steady.json` at the
//! repo root (fields documented in EXPERIMENTS.md §Steady-state).
//!
//! `XGEN_BENCH_QUICK=1` shrinks iteration counts for the CI smoke job;
//! `XGEN_THREADS` sizes the worker pool.

use xgen::api::Compiler;
use xgen::tensor::gemm::GemmConfig;
use xgen::tensor::Tensor;
use xgen::util::bench::{sink, time_ms, Table};
use xgen::util::json::Json;
use xgen::util::rng::Rng;
use xgen::xengine::knobs::steady_knobs;

fn main() {
    let quick = std::env::var("XGEN_BENCH_QUICK").is_ok();
    let (warm, samples, iters) = if quick { (1, 2, 3) } else { (2, 5, 20) };
    let mut rng = Rng::new(0x57EA);
    let x = Tensor::randn(&[1, 3, 24, 24], 1.0, &mut rng);

    let mut t = Table::new(&[
        "config",
        "prepack",
        "workspace",
        "pool",
        "ms/infer",
        "p95",
        "speedup",
        "packed KB",
        "arena KB",
    ]);
    let mut results = Vec::new();
    let mut baseline_ms = 0.0f64;
    let mut reference: Option<Tensor> = None;
    for k in steady_knobs() {
        let m = Compiler::for_model("demo-cnn", 1)
            .unwrap()
            .random_weights(42)
            .prepack(k.prepack)
            .workspace(k.workspace)
            .gemm_config(GemmConfig {
                threads: if k.pool { 0 } else { 1 },
                ..Default::default()
            })
            .compile()
            .unwrap();
        // Correctness guard: every knob config must agree with the first
        // (all-off) configuration.
        let y = m.infer(&[x.clone()]).unwrap();
        match &reference {
            None => reference = Some(y[0].clone()),
            Some(r) => {
                let d = r.max_abs_diff(&y[0]);
                assert!(d < 1e-4, "knob '{}' diverges from baseline by {d}", k.name);
            }
        }
        let s = time_ms(warm, samples, || {
            for _ in 0..iters {
                sink(m.infer(&[x.clone()]).unwrap());
            }
        });
        let per = s.mean / iters as f64;
        let p95 = s.p95 / iters as f64;
        if k.name == "legacy" {
            baseline_ms = per;
        }
        let speedup = if per > 0.0 { baseline_ms / per } else { 0.0 };
        let r = m.report();
        t.row(vec![
            k.name.to_string(),
            k.prepack.to_string(),
            k.workspace.to_string(),
            k.pool.to_string(),
            format!("{per:.3}"),
            format!("{p95:.3}"),
            format!("{speedup:.2}x"),
            format!("{:.1}", r.prepacked_bytes as f64 / 1024.0),
            format!("{:.1}", r.workspace_bytes as f64 / 1024.0),
        ]);
        results.push(Json::obj(vec![
            ("config", Json::str(k.name)),
            ("prepack", Json::num(k.prepack as u8 as f64)),
            ("workspace", Json::num(k.workspace as u8 as f64)),
            ("pool", Json::num(k.pool as u8 as f64)),
            ("ms_per_infer", Json::num(per)),
            ("p95_ms_per_infer", Json::num(p95)),
            ("speedup_vs_legacy", Json::num(speedup)),
            ("prepacked_operands", Json::num(r.prepacked_operands as f64)),
            ("prepacked_bytes", Json::num(r.prepacked_bytes as f64)),
            ("workspace_bytes", Json::num(r.workspace_bytes as f64)),
            ("pool_threads", Json::num(r.pool_threads as f64)),
        ]));
    }
    t.print("steady-state infer: {prepack, workspace, pool} toggle matrix (demo-cnn)");

    let json = Json::obj(vec![
        ("bench", Json::str("steady_state")),
        ("model", Json::str("demo-cnn")),
        ("iters_per_sample", Json::num(iters as f64)),
        ("results", Json::Arr(results)),
    ]);
    let path = if std::path::Path::new("../ROADMAP.md").exists() {
        "../BENCH_steady.json"
    } else {
        "BENCH_steady.json"
    };
    match std::fs::write(path, json.to_string() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}
