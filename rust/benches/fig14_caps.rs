//! E5 — Fig 14: the NPAS/CAPS accuracy-vs-latency frontier on the mobile
//! device (paper points: 6.7 ms @ 78.2%, 5.9 ms @ 75%, 3.9 ms @ 71%),
//! plus the composability (Sequitur) training-cost saving.

use xgen::caps::composability;
use xgen::caps::{search, CapsConfig};
use xgen::cost::devices;
use xgen::util::bench::Table;

fn main() {
    let cfg = CapsConfig { latency_budget_ms: None, iterations: 16, population: 10, seed: 0xF14 };
    let t0 = std::time::Instant::now();
    let r = search(&cfg, &devices::s10_cpu());
    let secs = t0.elapsed().as_secs_f64();

    let mut t = Table::new(&["Latency (ms)", "Top-1 (%)", "MACs", "Scheme", "Width", "Depth"]);
    for e in &r.frontier {
        t.row(vec![
            format!("{:.2}", e.latency_ms),
            format!("{:.2}", e.accuracy),
            format!("{:.2}G", e.macs as f64 / 1e9),
            e.cand.scheme.name().to_string(),
            format!("{:.2}", e.cand.width),
            e.cand.depth.to_string(),
        ]);
    }
    t.print("Fig 14 — CAPS/NPAS Pareto frontier (accuracy vs latency, mobile CPU)");
    println!(
        "\n{} candidates evaluated through the full compiler loop in {:.1}s.",
        r.evaluated, secs
    );
    println!("paper reference points: 6.7 ms @ 78.2% | 5.9 ms @ 75% | 3.9 ms @ 71%");

    // Composability: training-cost saving over the searched population.
    let seqs: Vec<Vec<u32>> = r.frontier.iter().map(|e| e.cand.layer_symbols()).collect();
    if seqs.len() >= 2 {
        let plan = composability::plan(&seqs);
        println!(
            "composability (Sequitur blocks): {} reusable blocks, {:.0}% training-cost saving",
            plan.blocks.len(),
            plan.savings() * 100.0
        );
    }
}
