//! E1 — Table 3: mobile CPU/GPU latency across the model zoo and
//! frameworks, at the paper's "same accuracy" operating point (baselines
//! dense, XGen pattern-pruned + universally fused). Prints the table the
//! paper prints; "-" cells come from op-coverage gaps. Paper averages:
//! XGen 6.8×/8.2×/6.4×/16.5× over TFLite/TVM/MNN/PyTorch.

use xgen::baselines::{DeviceClass, Framework};
use xgen::cost::devices;
use xgen::graph::zoo::by_name;
use xgen::pruning::PruneScheme;
use xgen::util::bench::Table;
use xgen::util::fmt_ms;

const MODELS: &[&str] = &[
    "efficientnet-b0",
    "resnet-50",
    "vgg-16",
    "mobilenet-v1-ssd",
    "mobilenet-v3",
    "yolo-v4",
    "c3d",
    "r2plus1d",
    "s3d",
    "pointpillar",
    "u-net",
    "faster-rcnn",
    "mask-rcnn",
    "tinybert",
    "distilbert",
    "bert-base",
    "mobilebert",
    "gpt-2",
];

fn latency(model: &str, fw: Framework, class: DeviceClass) -> Option<f64> {
    let g = by_name(model, 1);
    if !fw.supports(&g, class) {
        return None;
    }
    let dev = match class {
        DeviceClass::MobileCpu => devices::s10_cpu(),
        DeviceClass::MobileGpu => devices::s10_gpu(),
        _ => unreachable!(),
    };
    let scheme = fw.deploy_scheme();
    // Baselines use their own fusion strategy; plan comes from the fw.
    let plan = fw.fusion_plan(&g);
    let prof = fw.profile(class)?;
    let dm = if matches!(scheme, PruneScheme::None) {
        Default::default()
    } else {
        xgen::cost::scheme_density_map(&g, &scheme)
    };
    Some(
        xgen::cost::estimate_latency(
            &g,
            &plan,
            &dev,
            &prof,
            &dm,
            xgen::cost::sparse_efficiency(&scheme),
        )
        .total_ms(),
    )
}

fn main() {
    let fws = [Framework::Mnn, Framework::Tvm, Framework::TfLite, Framework::PyTorchMobile, Framework::XGenFull];
    let mut t = Table::new(&[
        "Model", "#Params", "#MACs", "MNN cpu", "MNN gpu", "TVM cpu", "TVM gpu", "TFL cpu",
        "TFL gpu", "PT cpu", "XGen cpu", "XGen gpu",
    ]);
    let mut speedups: Vec<(&str, Vec<f64>)> = fws[..4].iter().map(|f| (f.name(), vec![])).collect();
    for m in MODELS {
        let g = by_name(m, 1);
        let mut row = vec![
            m.to_string(),
            format!("{:.1}M", g.total_params() as f64 / 1e6),
            format!("{:.1}G", g.total_macs() as f64 / 1e9),
        ];
        let xgen_cpu = latency(m, Framework::XGenFull, DeviceClass::MobileCpu);
        for fw in &fws {
            for class in [DeviceClass::MobileCpu, DeviceClass::MobileGpu] {
                if *fw == Framework::PyTorchMobile && class == DeviceClass::MobileGpu {
                    continue; // PyTorch has no GPU column in Table 3
                }
                match latency(m, *fw, class) {
                    Some(ms) => {
                        row.push(fmt_ms(ms));
                        if class == DeviceClass::MobileCpu && *fw != Framework::XGenFull {
                            if let (Some(x), Some(su)) =
                                (xgen_cpu, speedups.iter_mut().find(|(n, _)| *n == fw.name()))
                            {
                                su.1.push(ms / x);
                            }
                        }
                    }
                    None => row.push("-".to_string()),
                }
            }
        }
        t.row(row);
    }
    t.print("Table 3 — mobile latency (ms), same-accuracy operating points");
    println!("\naverage XGen CPU speedups (paper: MNN 6.4x, TVM 8.2x, TFLite 6.8x, PyTorch 16.5x):");
    for (name, xs) in &speedups {
        if !xs.is_empty() {
            println!("  over {:>8}: {:.1}x (n={})", name, xgen::util::mean(xs), xs.len());
        }
    }
}
