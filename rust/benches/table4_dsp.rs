//! E2 — Table 4: mobile-DSP (Hexagon 698-class) comparison vs TFLite and
//! SNPE over 10 models, including the transformer-support gap (XGen runs
//! TinyBERT/Conformer on the DSP "for the first time"). Paper geomeans:
//! 2.8× over TFLite, 2.1× over SNPE.

use xgen::baselines::{DeviceClass, Framework};
use xgen::cost::{devices, estimate_latency, scheme_density_map, sparse_efficiency};
use xgen::graph::zoo::by_name;
use xgen::pruning::PruneScheme;
use xgen::util::bench::Table;
use xgen::util::fmt_ms;

const MODELS: &[&str] = &[
    "mobilenet-v3",
    "efficientnet-b0",
    "resnet-50",
    "fst",
    "cyclegan",
    "wdsr-b",
    "efficientdet-d0",
    "pixor",
    "tinybert",
    "conformer",
];

fn lat(model: &str, fw: Framework) -> Option<f64> {
    let g = by_name(model, 1);
    if !fw.supports(&g, DeviceClass::MobileDsp) {
        return None;
    }
    let dev = devices::s20_dsp();
    let scheme = fw.deploy_scheme();
    let plan = fw.fusion_plan(&g);
    let prof = fw.profile(DeviceClass::MobileDsp)?;
    let dm = if matches!(scheme, PruneScheme::None) {
        Default::default()
    } else {
        scheme_density_map(&g, &scheme)
    };
    Some(estimate_latency(&g, &plan, &dev, &prof, &dm, sparse_efficiency(&scheme)).total_ms())
}

fn main() {
    let mut t = Table::new(&["Model", "#MACs", "#Ops", "TFLite", "SNPE", "XGen", "OverT", "OverS"]);
    let (mut rt, mut rs) = (Vec::new(), Vec::new());
    for m in MODELS {
        let g = by_name(m, 1);
        let tf = lat(m, Framework::TfLite);
        let sn = lat(m, Framework::Snpe);
        let xg = lat(m, Framework::XGenFull).expect("xgen runs everything");
        let cell = |v: Option<f64>| v.map(fmt_ms).unwrap_or_else(|| "-".into());
        let ratio = |v: Option<f64>| {
            v.map(|b| {
                format!("{:.1}", b / xg)
            })
            .unwrap_or_else(|| "-".into())
        };
        if let Some(b) = tf {
            rt.push(b / xg);
        }
        if let Some(b) = sn {
            rs.push(b / xg);
        }
        t.row(vec![
            m.to_string(),
            format!("{:.1}G", g.total_macs() as f64 / 1e9),
            g.operator_count().to_string(),
            cell(tf),
            cell(sn),
            fmt_ms(xg),
            ratio(tf),
            ratio(sn),
        ]);
    }
    t.print("Table 4 — mobile DSP latency (ms)");
    println!(
        "\ngeomean speedup: over TFLite {:.1}x (paper 2.8x), over SNPE {:.1}x (paper 2.1x)",
        xgen::util::geomean(&rt),
        xgen::util::geomean(&rs)
    );
    println!("transformers on DSP: TFLite/SNPE '-' (unsupported), XGen runs them — as in the paper.");
}
