//! E14 — hot-path microbenchmarks feeding EXPERIMENTS.md §Perf:
//! 1. dense reference conv vs the FKW pattern-specialized sparse kernel
//!    (with and without filter-kernel reorder) — §2.3.1's generated-code
//!    story on the Rust substrate;
//! 2. straight-line executor vs a compiled session (`xgen::api`) on the
//!    demo CNN — the fused executor with the memory planner behind
//!    `CompiledModel::infer`;
//! 3. serving throughput, single vs dynamically batched, over compiled
//!    sessions (plus the PJRT artifact loop when artifacts are present).

use std::time::Duration;

use xgen::api::Compiler;
use xgen::coordinator::Server;
use xgen::exec::Executor;
use xgen::fkw::FkwLayer;
use xgen::graph::zoo::NetBuilder;
use xgen::graph::{Act, WeightStore};
use xgen::pruning::pattern::{apply_assignment, assign_patterns, connectivity_prune, PatternSet};
use xgen::tensor::Tensor;
use xgen::util::bench::{sink, time_ms, Table};
use xgen::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(0xBEEF);

    // 1. FKW sparse conv vs dense conv (pattern sparsity 5/9 + 40% conn).
    let mut t = Table::new(&["Kernel", "ms/run", "vs dense"]);
    let (c, o, hw) = (32usize, 64usize, 32usize);
    let x = Tensor::randn(&[1, c, hw, hw], 1.0, &mut rng);
    let w = Tensor::randn(&[o, c, 3, 3], 0.5, &mut rng);
    let mut asg = assign_patterns(&w, &PatternSet::elite8());
    connectivity_prune(&w, &mut asg, 0.4);
    let wp = apply_assignment(&w, &asg);
    let dense = time_ms(2, 8, || {
        sink(x.conv2d(&wp, 1, 1));
    });
    let fkw_plain = FkwLayer::encode(&wp, &asg, 1, 1, false);
    let fkw_reord = FkwLayer::encode(&wp, &asg, 1, 1, true);
    let plain = time_ms(2, 8, || {
        sink(fkw_plain.conv2d(&x));
    });
    let reord = time_ms(2, 8, || {
        sink(fkw_reord.conv2d(&x));
    });
    t.row(vec!["dense conv (masked weights)".into(), format!("{:.2}", dense.mean), "1.00x".into()]);
    t.row(vec![
        "FKW sparse conv".into(),
        format!("{:.2}", plain.mean),
        format!("{:.2}x", dense.mean / plain.mean),
    ]);
    t.row(vec![
        "FKW + filter-kernel reorder".into(),
        format!("{:.2}", reord.mean),
        format!("{:.2}x", dense.mean / reord.mean),
    ]);
    t.print(&format!(
        "pattern-sparse conv {c}->{o} @{hw}x{hw} (sparsity {:.0}%, switches {} -> {})",
        wp.zero_fraction() * 100.0,
        fkw_plain.pattern_switches(),
        fkw_reord.pattern_switches()
    ));

    // 2. straight-line executor vs the compiled session on the demo CNN.
    let mut b = NetBuilder::new("demo", &[1, 3, 32, 32]);
    b.conv_bn_act(16, 3, 1, 1, Act::Relu);
    b.conv_bn_act(16, 3, 1, 1, Act::Relu);
    b.conv_bn_act(32, 3, 2, 1, Act::Relu);
    b.gap();
    b.dense(10);
    let g = b.finish();
    let ws = WeightStore::init_random(&g, &mut rng);
    let xin = Tensor::randn(&[1, 3, 32, 32], 1.0, &mut rng);
    // One compiled session; the straight-line oracle runs the *same*
    // rewritten graph + weights, so the gap is purely the execution
    // engine (fusion + in-place elementwise + buffer pooling).
    let cm = Compiler::new(g).weights(ws).compile().unwrap();
    let straight = time_ms(2, 10, || {
        sink(
            Executor::new(cm.graph(), cm.weights().unwrap())
                .run(std::slice::from_ref(&xin))
                .unwrap(),
        );
    });
    let fused = time_ms(2, 10, || {
        sink(cm.infer(std::slice::from_ref(&xin)).unwrap());
    });
    let mut t = Table::new(&["Executor", "ms/run", "speedup"]);
    t.row(vec!["straight-line".into(), format!("{:.2}", straight.mean), "1.00x".into()]);
    t.row(vec![
        "compiled session (fused + planner)".into(),
        format!("{:.2}", fused.mean),
        format!("{:.2}x", straight.mean / fused.mean),
    ]);
    t.print("executor hot path (demo CNN)");

    // 3. Serving loop over compiled sessions, single vs batched.
    let build = |batch: usize| {
        Compiler::for_model("demo-cnn", batch)
            .unwrap()
            .random_weights(0xBEEF)
            .compile()
            .unwrap()
    };
    let per: usize = build(1).input_shapes()[0].iter().product();
    let mut results = Vec::new();
    for (label, wait_ms) in [("single (no batching)", 0u64), ("dynamic batch (<=4)", 2u64)] {
        let server =
            Server::start_compiled(build(1), build(4), Duration::from_millis(wait_ms)).unwrap();
        let n = 128;
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = (0..n)
            .map(|_| server.submit((0..per).map(|_| rng.f32()).collect()))
            .collect();
        for rx in rxs {
            rx.recv().unwrap().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        results.push((label, n as f64 / wall, server.stats().mean_batch()));
    }
    let mut t = Table::new(&["Serving mode", "req/s", "mean batch"]);
    for (label, rps, mb) in results {
        t.row(vec![label.into(), format!("{rps:.0}"), format!("{mb:.2}")]);
    }
    t.print("serving loop (compiled sessions, real execution)");

    // 3b. PJRT artifact serving, when artifacts are present.
    if xgen::runtime::artifacts_present() {
        let per = 3 * 24 * 24;
        let mut results = Vec::new();
        for (label, wait_ms) in [("single (no batching)", 0u64), ("dynamic batch (<=4)", 2u64)] {
            let server = Server::start(
                xgen::runtime::default_artifact_dir(),
                "cnn_dense_b1",
                "cnn_dense_b4",
                Duration::from_millis(wait_ms),
            )
            .unwrap();
            let n = 128;
            let t0 = std::time::Instant::now();
            let rxs: Vec<_> = (0..n)
                .map(|_| server.submit((0..per).map(|_| rng.f32()).collect()))
                .collect();
            for rx in rxs {
                rx.recv().unwrap().unwrap();
            }
            let wall = t0.elapsed().as_secs_f64();
            results.push((label, n as f64 / wall, server.stats().mean_batch()));
        }
        let mut t = Table::new(&["Serving mode", "req/s", "mean batch"]);
        for (label, rps, mb) in results {
            t.row(vec![label.into(), format!("{rps:.0}"), format!("{mb:.2}")]);
        }
        t.print("PJRT serving loop (AOT artifacts)");
    } else {
        println!("\n(PJRT serving bench skipped: run `make artifacts`)");
    }
}
