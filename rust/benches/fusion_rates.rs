//! E10 — §2.2.2 claims: DNNFusion finds "up to 8.8× higher fusion
//! opportunities" than fixed-pattern fusers and yields large end-to-end
//! reductions, especially on deep transformers.

use xgen::baselines::{fixed_pattern_fusion, no_fusion};
use xgen::fusion::{fuse, fusion_opportunities, FusionConfig};
use xgen::graph::zoo::by_name;
use xgen::util::bench::Table;

fn main() {
    let mut t = Table::new(&[
        "Model", "Ops", "Legal pairs", "Fixed accepts", "Opp ratio", "Fixed groups",
        "DNNF groups", "Bytes saved",
    ]);
    let mut max_ratio: f64 = 0.0;
    for m in [
        "mobilenet-v2",
        "efficientnet-b0",
        "resnet-50",
        "u-net",
        "wdsr-b",
        "tinybert",
        "bert-base",
        "gpt-2",
        "conformer",
        "mobilebert",
    ] {
        let g = by_name(m, 1);
        let legal = fusion_opportunities(&g);
        let fixed = fixed_pattern_fusion(&g);
        let univ = fuse(&g, &FusionConfig::default());
        let ratio = legal as f64 / fixed.accepted.max(1) as f64;
        max_ratio = max_ratio.max(ratio);
        t.row(vec![
            m.to_string(),
            g.operator_count().to_string(),
            legal.to_string(),
            fixed.accepted.to_string(),
            format!("{ratio:.1}x"),
            fixed.fused_layer_count().to_string(),
            univ.fused_layer_count().to_string(),
            format!("{:.1}MB", univ.bytes_saved(&g) as f64 / 1e6),
        ]);
    }
    t.print("DNNFusion vs fixed-pattern fusion");
    println!("\nmax opportunity ratio: {max_ratio:.1}x (paper: up to 8.8x)");

    // End-to-end effect of fusion alone (no pruning): PyTorch-style
    // unfused vs DNNFusion on the cost model.
    use xgen::baselines::{DeviceClass, Framework};
    use xgen::cost::{devices, estimate_latency};
    let mut t = Table::new(&["Model", "Unfused (ms)", "Fixed (ms)", "DNNFusion (ms)", "vs unfused"]);
    let dev = devices::s10_cpu();
    let prof = Framework::XGenFull.profile(DeviceClass::MobileCpu).unwrap();
    for m in ["gpt-2", "bert-base", "mobilenet-v2"] {
        let g = by_name(m, 1);
        let lat = |plan: &xgen::fusion::FusionPlan| {
            estimate_latency(&g, plan, &dev, &prof, &Default::default(), 1.0).total_ms()
        };
        let (u, f, d) = (lat(&no_fusion(&g)), lat(&fixed_pattern_fusion(&g)), lat(&fuse(&g, &FusionConfig::default())));
        t.row(vec![
            m.to_string(),
            format!("{u:.1}"),
            format!("{f:.1}"),
            format!("{d:.1}"),
            format!("{:.1}x", u / d),
        ]);
    }
    t.print("end-to-end fusion effect (same engine, fusion strategy varied)");
}
