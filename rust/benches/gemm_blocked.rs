//! E15 — the generated-code hot path (§2.3): naive triple-loop GEMM vs the
//! cache-blocked micro-kernel engine vs blocked+parallel, with the memory
//! planner's pooling stats on the demo CNN. Acceptance: blocked+parallel
//! ≥ 3x over naive at M=N=K=512 with max abs error ≤ 1e-3 vs the naive
//! oracle. Writes machine-local numbers to `BENCH_gemm.json` at the repo
//! root (the checked-in file is a placeholder until this bench runs).

use xgen::api::Compiler;
use xgen::graph::zoo::NetBuilder;
use xgen::graph::{Act, WeightStore};
use xgen::tensor::gemm::{gemm, gemm_naive, GemmConfig};
use xgen::util::bench::{sink, time_ms, Table};
use xgen::util::json::Json;
use xgen::util::rng::Rng;

fn max_abs_diff(x: &[f32], y: &[f32]) -> f32 {
    x.iter().zip(y).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max)
}

fn main() {
    let mut rng = Rng::new(0x6E44);
    let mut t = Table::new(&[
        "M=N=K",
        "naive (ms)",
        "blocked (ms)",
        "+parallel (ms)",
        "blk x",
        "par x",
        "GFLOP/s",
        "max err",
    ]);
    let single = GemmConfig { threads: 1, ..Default::default() };
    let parallel = GemmConfig { threads: 0, ..Default::default() };
    let mut results = Vec::new();
    // CI smoke mode: small sizes only, so the job produces a real (if
    // noisy) BENCH_gemm.json in seconds.
    let sizes: &[usize] = if std::env::var("XGEN_BENCH_QUICK").is_ok() {
        &[128, 256]
    } else {
        &[128, 256, 512]
    };
    for &d in sizes {
        let a = rng.normal_vec(d * d, 0.0, 1.0);
        let b = rng.normal_vec(d * d, 0.0, 1.0);
        let mut want = vec![0.0f32; d * d];
        gemm_naive(d, d, d, &a, &b, &mut want);
        let (warm, samples) = if d >= 512 { (1, 3) } else { (1, 5) };
        let naive_t = time_ms(warm, samples, || {
            let mut c = vec![0.0f32; d * d];
            gemm_naive(d, d, d, &a, &b, &mut c);
            sink(c);
        });
        let mut got_blocked = vec![0.0f32; d * d];
        let blocked_t = time_ms(warm, samples, || {
            gemm(d, d, d, &a, &b, &mut got_blocked, &single);
        });
        let mut got_par = vec![0.0f32; d * d];
        let par_t = time_ms(warm, samples, || {
            gemm(d, d, d, &a, &b, &mut got_par, &parallel);
        });
        let err = max_abs_diff(&want, &got_blocked).max(max_abs_diff(&want, &got_par));
        let gflops = 2.0 * (d as f64).powi(3) / (par_t.mean * 1e-3) / 1e9;
        t.row(vec![
            d.to_string(),
            format!("{:.2}", naive_t.mean),
            format!("{:.2}", blocked_t.mean),
            format!("{:.2}", par_t.mean),
            format!("{:.2}x", naive_t.mean / blocked_t.mean),
            format!("{:.2}x", naive_t.mean / par_t.mean),
            format!("{gflops:.1}"),
            format!("{err:.1e}"),
        ]);
        results.push(Json::obj(vec![
            ("size", Json::num(d as f64)),
            ("naive_ms", Json::num(naive_t.mean)),
            ("blocked_ms", Json::num(blocked_t.mean)),
            ("parallel_ms", Json::num(par_t.mean)),
            ("speedup_blocked", Json::num(naive_t.mean / blocked_t.mean)),
            ("speedup_parallel", Json::num(naive_t.mean / par_t.mean)),
            ("gflops_parallel", Json::num(gflops)),
            ("max_abs_err", Json::num(err as f64)),
        ]));
    }
    t.print("blocked+parallel GEMM vs naive triple loop (f32, square)");

    // Memory planner: peak live allocations on the demo CNN, fused path.
    let mut b = NetBuilder::new("demo", &[1, 3, 32, 32]);
    b.conv_bn_act(16, 3, 1, 1, Act::Relu);
    b.conv_bn_act(16, 3, 1, 1, Act::Relu);
    b.conv_bn_act(32, 3, 2, 1, Act::Relu);
    b.gap();
    b.dense(10);
    let g = b.finish();
    let ws = WeightStore::init_random(&g, &mut rng);
    let x = xgen::tensor::Tensor::randn(&[1, 3, 32, 32], 1.0, &mut rng);
    let cm = Compiler::new(g).weights(ws).compile().unwrap();
    let (_, stats) = cm.infer_with_stats(&[x]).unwrap();
    println!(
        "\nmemory planner (demo CNN): {} materialized values -> {} pooled slots \
         (peak live {}), buffer bytes {} -> {} ({:.0}% saved)",
        stats.planned_values,
        stats.slots,
        stats.peak_live,
        stats.bytes_one_per_node,
        stats.bytes_pooled,
        stats.bytes_saved_frac() * 100.0
    );

    // Dump machine-local numbers next to the repo root for EXPERIMENTS.md.
    let json = Json::obj(vec![
        ("bench", Json::str("gemm_blocked")),
        ("results", Json::Arr(results)),
        (
            "planner",
            Json::obj(vec![
                ("planned_values", Json::num(stats.planned_values as f64)),
                ("slots", Json::num(stats.slots as f64)),
                ("peak_live", Json::num(stats.peak_live as f64)),
                ("bytes_one_per_node", Json::num(stats.bytes_one_per_node as f64)),
                ("bytes_pooled", Json::num(stats.bytes_pooled as f64)),
            ]),
        ),
    ]);
    let path = if std::path::Path::new("../ROADMAP.md").exists() {
        "../BENCH_gemm.json"
    } else {
        "BENCH_gemm.json"
    };
    match std::fs::write(path, json.to_string() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}
