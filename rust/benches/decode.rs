//! Autoregressive decoding bench — the ISSUE-5 acceptance artifact.
//!
//! Measures tokens/sec on `demo-transformer-causal` two ways:
//!
//! 1. **Incremental** (`DecodeSession`): prefill the prompt once, then one
//!    `step()` per token against the K/V caches — `O(L)` work per token.
//! 2. **Naive full recompute**: for every new token, re-run the whole
//!    fixed-length graph through `CompiledModel::infer` and read the
//!    newest row — the `O(L²)`-per-sequence baseline a framework without
//!    KV-cache serving pays.
//!
//! Both paths produce identical logits (causal masking guarantees padding
//! cannot leak backwards; asserted here before timing). Writes
//! `BENCH_decode.json` at the repo root (fields documented in
//! EXPERIMENTS.md §Decoding). `XGEN_BENCH_QUICK=1` shrinks iteration
//! counts for the CI smoke job; `XGEN_THREADS` sizes the worker pool.

use xgen::api::Compiler;
use xgen::tensor::Tensor;
use xgen::util::bench::{sink, time_ms, Table};
use xgen::util::json::Json;

fn main() {
    let quick = std::env::var("XGEN_BENCH_QUICK").is_ok();
    let (warm, samples) = if quick { (1, 2) } else { (2, 5) };

    let m = Compiler::for_model("demo-transformer-causal", 1)
        .unwrap()
        .random_weights(42)
        .compile()
        .unwrap();
    let seq = m.input_shapes()[0][1];
    let prompt: Vec<u32> = (0..8u32).map(|i| (i * 37) % 256).collect();
    let steps: Vec<u32> = (0..(seq - prompt.len()) as u32).map(|i| (i * 97 + 13) % 256).collect();
    let vocab = 256usize;

    // ---- correctness guard: both paths agree at every position --------
    let mut ids = vec![0.0f32; seq];
    let all: Vec<u32> = prompt.iter().chain(&steps).copied().collect();
    for (i, &t) in all.iter().enumerate() {
        ids[i] = t as f32;
    }
    let full = m.infer(&[Tensor::from_vec(&[1, seq], ids.clone())]).unwrap();
    let mut sess = m.decode_session(seq).unwrap();
    for (i, &t) in all.iter().enumerate() {
        let logits = sess.step(t).unwrap();
        let want = &full[0].data()[i * vocab..(i + 1) * vocab];
        let d = logits
            .iter()
            .zip(want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(d < 1e-4, "incremental diverges from full forward at {i} by {d}");
    }

    // ---- incremental: prefill once, then per-token steps --------------
    let s_prefill = time_ms(warm, samples, || {
        sess.reset();
        sink(sess.prefill(&prompt).unwrap()[0]);
    });
    let s_step = time_ms(warm, samples, || {
        sess.reset();
        sess.prefill(&prompt).unwrap();
        for &t in &steps {
            sink(sess.step(t).unwrap()[0]);
        }
    });
    let inc_ms_per_tok = (s_step.mean - s_prefill.mean).max(1e-9) / steps.len() as f64;

    // ---- naive: full recompute per generated token ---------------------
    let naive_iters = if quick { 4 } else { steps.len() };
    let s_naive = time_ms(warm, samples, || {
        // Each new token re-runs the whole fixed-length graph.
        for k in 0..naive_iters {
            let mut ids = vec![0.0f32; seq];
            for (i, &t) in all[..prompt.len() + k].iter().enumerate() {
                ids[i] = t as f32;
            }
            let y = m.infer(&[Tensor::from_vec(&[1, seq], ids)]).unwrap();
            sink(y[0].data()[(prompt.len() + k) * vocab]);
        }
    });
    let naive_ms_per_tok = s_naive.mean / naive_iters as f64;

    let speedup = naive_ms_per_tok / inc_ms_per_tok.max(1e-9);
    let inc_tok_s = 1e3 / inc_ms_per_tok.max(1e-9);
    let naive_tok_s = 1e3 / naive_ms_per_tok.max(1e-9);
    let kv_bytes = sess.kv_cache_elems() as f64 * 4.0;

    let mut t = Table::new(&["path", "ms/token", "tok/s", "speedup"]);
    t.row(vec![
        "full-recompute".into(),
        format!("{naive_ms_per_tok:.3}"),
        format!("{naive_tok_s:.0}"),
        "1.00x".into(),
    ]);
    t.row(vec![
        "prefill+step (KV cache)".into(),
        format!("{inc_ms_per_tok:.3}"),
        format!("{inc_tok_s:.0}"),
        format!("{speedup:.2}x"),
    ]);
    t.print(&format!(
        "autoregressive decode (demo-transformer-causal, prompt {}, {} generated, kv cache {:.1} KB)",
        prompt.len(),
        steps.len(),
        kv_bytes / 1024.0
    ));

    let json = Json::obj(vec![
        ("bench", Json::str("decode")),
        ("model", Json::str("demo-transformer-causal")),
        ("prompt_len", Json::num(prompt.len() as f64)),
        ("generated", Json::num(steps.len() as f64)),
        ("prefill_ms", Json::num(s_prefill.mean)),
        ("incremental_ms_per_token", Json::num(inc_ms_per_tok)),
        ("full_recompute_ms_per_token", Json::num(naive_ms_per_tok)),
        ("incremental_tok_per_s", Json::num(inc_tok_s)),
        ("full_recompute_tok_per_s", Json::num(naive_tok_s)),
        ("speedup_incremental_vs_full", Json::num(speedup)),
        ("kv_cache_bytes", Json::num(kv_bytes)),
    ]);
    let path = if std::path::Path::new("../ROADMAP.md").exists() {
        "../BENCH_decode.json"
    } else {
        "BENCH_decode.json"
    };
    match std::fs::write(path, json.to_string() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}
