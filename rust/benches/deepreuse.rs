//! E11 — §2.3.2: deep reuse "halves the inference time … while causing
//! virtually no accuracy loss". Real wall-clock on the Rust executor:
//! dense conv vs deep-reuse conv on correlated (image-like) inputs, with
//! the MAC savings and output error reported.

use xgen::deepreuse::{reuse_conv2d, ReuseConfig};
use xgen::tensor::Tensor;
use xgen::util::bench::{sink, time_ms, Table};
use xgen::util::rng::Rng;

fn smooth_image(rng: &mut Rng, c: usize, hw: usize) -> Tensor {
    let mut x = Tensor::zeros(&[1, c, hw, hw]);
    for ci in 0..c {
        let fx = 0.15 + 0.1 * ci as f32;
        for y in 0..hw {
            for xx in 0..hw {
                let v = (fx * xx as f32).sin() + (fx * 0.8 * y as f32).cos()
                    + rng.normal_f32(0.0, 0.02);
                x.set(&[0, ci, y, xx], v);
            }
        }
    }
    x
}

fn main() {
    let mut rng = Rng::new(0xDEE9);
    let mut t = Table::new(&[
        "Layer", "Dense (ms)", "Reuse (ms)", "Speedup", "MACs saved", "Reuse ratio", "Rel err",
    ]);
    for (c, o, hw) in [(8usize, 64usize, 40usize), (16, 64, 28), (32, 128, 20)] {
        let x = smooth_image(&mut rng, c, hw);
        let w = Tensor::randn(&[o, c, 3, 3], 0.4, &mut rng);
        let dense_t = time_ms(1, 5, || {
            sink(x.conv2d(&w, 1, 1));
        });
        let cfg = ReuseConfig { hash_bits: 12, max_rel_dev: 0.35, ..Default::default() };
        let mut stats = Default::default();
        let mut out = Tensor::zeros(&[1]);
        let reuse_t = time_ms(1, 5, || {
            let (y, s) = reuse_conv2d(&x, &w, 1, 1, &cfg);
            stats = s;
            out = y;
        });
        let dense = x.conv2d(&w, 1, 1);
        let scale = dense.data().iter().map(|v| v.abs()).sum::<f32>() / dense.len() as f32;
        let rel = out.mad(&dense) / scale.max(1e-9);
        t.row(vec![
            format!("{c}->{o} @{hw}x{hw}"),
            format!("{:.2}", dense_t.mean),
            format!("{:.2}", reuse_t.mean),
            format!("{:.2}x", dense_t.mean / reuse_t.mean),
            format!("{:.0}%", stats.savings() * 100.0),
            format!("{:.1}", stats.reuse_ratio()),
            format!("{rel:.4}"),
        ]);
    }
    t.print("deep reuse on correlated inputs (real executor wall-clock)");
    println!("\npaper: ~2x inference-time reduction at <5e-4 accuracy loss on CNNs;");
    println!("our relative output error is bounded by the adaptive-outlier knob (max_rel_dev).");
}
