//! Transformer inference bench — the ISSUE-4 acceptance artifact.
//!
//! Two sections, both through `CompiledModel::infer()`:
//!
//! 1. The `"demo-transformer"` zoo model (2 layers, d=64, seq=32) swept
//!    over the `xengine::knobs::steady_knobs()` toggle matrix
//!    ({weight pre-packing, workspace arena, worker pool}), every
//!    configuration verified against the all-off baseline.
//! 2. One `gpt2_frontend_layers(1, 2)` row — the exporter-style dump with
//!    per-head Transposes, rank-4 QK^T and Sqrt/Div scaling — timed at
//!    the default configuration, with its rewrite/fusion statistics.
//!
//! Writes `BENCH_transformer.json` at the repo root (fields documented in
//! EXPERIMENTS.md §Transformers). `XGEN_BENCH_QUICK=1` shrinks iteration
//! counts for the CI smoke job; `XGEN_THREADS` sizes the worker pool.

use xgen::api::Compiler;
use xgen::graph::zoo::nlp;
use xgen::tensor::gemm::GemmConfig;
use xgen::tensor::Tensor;
use xgen::util::bench::{sink, time_ms, Table};
use xgen::util::json::Json;
use xgen::xengine::knobs::steady_knobs;

fn main() {
    let quick = std::env::var("XGEN_BENCH_QUICK").is_ok();
    let (warm, samples, iters) = if quick { (1, 2, 3) } else { (2, 5, 20) };

    // ---- demo-transformer: steady-knob sweep --------------------------
    let mut t = Table::new(&[
        "config",
        "prepack",
        "workspace",
        "pool",
        "ms/infer",
        "p95",
        "speedup",
        "packed KB",
        "arena KB",
    ]);
    let mut results = Vec::new();
    let mut baseline_ms = 0.0f64;
    let mut reference: Option<Tensor> = None;
    for k in steady_knobs() {
        let m = Compiler::for_model("demo-transformer", 1)
            .unwrap()
            .random_weights(42)
            .prepack(k.prepack)
            .workspace(k.workspace)
            .gemm_config(GemmConfig {
                threads: if k.pool { 0 } else { 1 },
                ..Default::default()
            })
            .compile()
            .unwrap();
        let xs = m.sample_inputs(0x7A);
        // Correctness guard: every knob config agrees with the first
        // (all-off) configuration and stays finite.
        let y = m.infer(&xs).unwrap();
        assert!(y[0].data().iter().all(|v| v.is_finite()), "knob '{}' non-finite", k.name);
        match &reference {
            None => reference = Some(y[0].clone()),
            Some(r) => {
                let d = r.max_abs_diff(&y[0]);
                assert!(d < 1e-3, "knob '{}' diverges from baseline by {d}", k.name);
            }
        }
        let s = time_ms(warm, samples, || {
            for _ in 0..iters {
                sink(m.infer(&xs).unwrap());
            }
        });
        let per = s.mean / iters as f64;
        let p95 = s.p95 / iters as f64;
        if k.name == "legacy" {
            baseline_ms = per;
        }
        let speedup = if per > 0.0 { baseline_ms / per } else { 0.0 };
        let r = m.report();
        t.row(vec![
            k.name.to_string(),
            k.prepack.to_string(),
            k.workspace.to_string(),
            k.pool.to_string(),
            format!("{per:.3}"),
            format!("{p95:.3}"),
            format!("{speedup:.2}x"),
            format!("{:.1}", r.prepacked_bytes as f64 / 1024.0),
            format!("{:.1}", r.workspace_bytes as f64 / 1024.0),
        ]);
        results.push(Json::obj(vec![
            ("config", Json::str(k.name)),
            ("prepack", Json::num(k.prepack as u8 as f64)),
            ("workspace", Json::num(k.workspace as u8 as f64)),
            ("pool", Json::num(k.pool as u8 as f64)),
            ("ms_per_infer", Json::num(per)),
            ("p95_ms_per_infer", Json::num(p95)),
            ("speedup_vs_legacy", Json::num(speedup)),
            ("prepacked_operands", Json::num(r.prepacked_operands as f64)),
            ("prepacked_bytes", Json::num(r.prepacked_bytes as f64)),
            ("workspace_bytes", Json::num(r.workspace_bytes as f64)),
            ("pool_threads", Json::num(r.pool_threads as f64)),
        ]));
    }
    t.print("transformer infer: {prepack, workspace, pool} toggle matrix (demo-transformer)");

    // ---- gpt2-frontend (2 layers): one end-to-end row -----------------
    let gpt_iters = if quick { 1 } else { 3 };
    let g = nlp::gpt2_frontend_layers(1, 2);
    let ops_before = g.operator_count();
    let m = Compiler::new(g).random_weights(7).compile().unwrap();
    let xs = m.sample_inputs(0x67);
    let y = m.infer(&xs).unwrap();
    assert!(y[0].data().iter().all(|v| v.is_finite()), "gpt2-frontend non-finite");
    let s = time_ms(if quick { 0 } else { 1 }, if quick { 1 } else { 3 }, || {
        for _ in 0..gpt_iters {
            sink(m.infer(&xs).unwrap());
        }
    });
    let per = s.mean / gpt_iters as f64;
    let r = m.report();
    let mut t = Table::new(&["model", "ops in", "ops out", "fused layers", "ms/infer"]);
    t.row(vec![
        "gpt2-frontend-2L".into(),
        ops_before.to_string(),
        r.rewrite.ops_after.to_string(),
        r.fusion_groups.to_string(),
        format!("{per:.1}"),
    ]);
    t.print("gpt2 frontend dump (2 layers, seq 384): rewrite + fusion + real inference");

    let json = Json::obj(vec![
        ("bench", Json::str("transformer")),
        ("model", Json::str("demo-transformer")),
        ("iters_per_sample", Json::num(iters as f64)),
        ("results", Json::Arr(results)),
        (
            "gpt2_frontend_2l",
            Json::obj(vec![
                ("ops_before_rewrite", Json::num(ops_before as f64)),
                ("ops_after_rewrite", Json::num(r.rewrite.ops_after as f64)),
                ("fused_layers", Json::num(r.fusion_groups as f64)),
                ("ms_per_infer", Json::num(per)),
            ]),
        ),
    ]);
    let path = if std::path::Path::new("../ROADMAP.md").exists() {
        "../BENCH_transformer.json"
    } else {
        "BENCH_transformer.json"
    };
    match std::fs::write(path, json.to_string() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}
