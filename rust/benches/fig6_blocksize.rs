//! E4 — Fig 6: accuracy vs latency across block sizes on ResNet-50 under
//! a uniform 6× pruning rate. Latency from the cost model on the mobile
//! GPU (as in the paper's figure), accuracy from the calibrated
//! [`AccuracyModel`]; the measured accuracy ordering on the real
//! (trainable) demo CNN is in artifacts/accuracy.json (see EXPERIMENTS.md).

use xgen::baselines::{DeviceClass, Framework};
use xgen::cost::{
    devices, estimate_latency, gemm_blocked_traffic_bytes, gemm_naive_traffic_bytes,
    scheme_density_map, sparse_efficiency,
};
use xgen::fusion::{fuse, FusionConfig};
use xgen::graph::zoo::by_name;
use xgen::pruning::{AccuracyModel, PruneScheme};
use xgen::tensor::gemm::gemm;
use xgen::util::bench::{sink, time_ms, Table};
use xgen::util::rng::Rng;
use xgen::xengine::knobs::gemm_ladder;

fn main() {
    let g = by_name("resnet-50", 1);
    let plan = fuse(&g, &FusionConfig::default());
    let dev = devices::s10_gpu();
    let prof = Framework::XGenFull.profile(DeviceClass::MobileGpu).unwrap();
    let rate = 1.0 - 1.0 / 6.0;
    let am = AccuracyModel::default();
    let base = 76.5; // ResNet-50 ImageNet top-1

    let mut t = Table::new(&["Scheme", "Block", "Latency (ms)", "Top-1 (%)"]);
    let mut points = Vec::new();
    let schemes: Vec<(String, PruneScheme)> = vec![
        ("non-structured".into(), PruneScheme::NonStructured { rate }),
        ("block 4x4".into(), PruneScheme::Block { block: 4, rate }),
        ("block 8x8".into(), PruneScheme::Block { block: 8, rate }),
        ("block 16x16".into(), PruneScheme::Block { block: 16, rate }),
        ("block 32x32".into(), PruneScheme::Block { block: 32, rate }),
        ("block 64x64".into(), PruneScheme::Block { block: 64, rate }),
        ("block 256x256".into(), PruneScheme::Block { block: 256, rate }),
        ("structured (whole)".into(), PruneScheme::Structured { rate }),
    ];
    for (name, scheme) in schemes {
        let dm = scheme_density_map(&g, &scheme);
        let lat =
            estimate_latency(&g, &plan, &dev, &prof, &dm, sparse_efficiency(&scheme)).total_ms();
        let acc = am.estimate(base, &scheme);
        points.push((lat, acc));
        let block = match &scheme {
            PruneScheme::Block { block, .. } => block.to_string(),
            PruneScheme::Structured { .. } => "matrix".into(),
            _ => "1".into(),
        };
        t.row(vec![name, block, format!("{lat:.1}"), format!("{acc:.2}")]);
    }
    t.print("Fig 6 — ResNet-50 @ uniform 6x rate: accuracy vs latency by block size");
    // Shape checks mirrored from the paper's figure.
    let ns = points[0];
    let st = *points.last().unwrap();
    println!(
        "\nshape: non-structured = best accuracy ({:.2}) worst latency ({:.1} ms); \
         structured = worst accuracy ({:.2}) best latency ({:.1} ms); \
         mid-size blocks get both (e.g. 8x8: {:.1} ms @ {:.2}%).",
        ns.1, ns.0, st.1, st.0, points[2].0, points[2].1
    );

    // The codegen half of the block-size story: the same knob sweep on the
    // REAL blocked-GEMM engine, ranked by the cost model's DRAM-traffic
    // prediction and checked against wall-clock.
    let d = 256usize;
    let mut rng = Rng::new(0xF16);
    let a = rng.normal_vec(d * d, 0.0, 1.0);
    let b = rng.normal_vec(d * d, 0.0, 1.0);
    let mut t = Table::new(&["Knob", "mc/kc/nc", "Pred. traffic (MB)", "Measured (ms)", "GFLOP/s"]);
    for knob in gemm_ladder() {
        let cfg = knob.cfg;
        let mut c = vec![0.0f32; d * d];
        let ms = time_ms(1, 3, || {
            gemm(d, d, d, &a, &b, &mut c, &cfg);
        });
        sink(&c);
        // The traffic model is per worker band; quote it only for
        // single-thread knobs where the implementation matches it.
        let pred = if cfg.threads == 1 {
            let traffic = gemm_blocked_traffic_bytes(d, d, d, cfg.mc, cfg.kc, cfg.nc);
            format!("{:.1}", traffic as f64 / 1e6)
        } else {
            "- (per-band)".to_string()
        };
        t.row(vec![
            knob.name.to_string(),
            format!("{}/{}/{}", cfg.mc, cfg.kc, cfg.nc),
            pred,
            format!("{:.2}", ms.mean),
            format!("{:.1}", 2.0 * (d as f64).powi(3) / (ms.mean * 1e-3) / 1e9),
        ]);
    }
    t.print(&format!(
        "blocked-GEMM tile-size knob sweep @ {d}^3 (naive-loop traffic model: {:.0} MB)",
        gemm_naive_traffic_bytes(d, d, d) as f64 / 1e6
    ));
}
