//! E6/E12 — Fig 18 + §3.2.1 energy claims: energy efficiency of the XGen
//! mobile solution vs cloud TPU-V2 (batch-1 serving) and the NeuroMagic
//! desktop-CPU comparison (paper: 8.0× less energy than TVM; 64.6× and
//! 17.3× efficiency vs NeuroMagic).

use xgen::baselines::{DeviceClass, Framework};
use xgen::cost::{
    devices, energy_mj, estimate_latency, scheme_density_map, sparse_efficiency, Device,
};
use xgen::graph::zoo::by_name;
use xgen::pruning::PruneScheme;
use xgen::util::bench::Table;

fn lat(model: &str, fw: Framework, class: DeviceClass, dev: &Device) -> Option<f64> {
    let g = by_name(model, 1);
    let scheme = fw.deploy_scheme();
    let plan = fw.fusion_plan(&g);
    let prof = fw.profile(class)?;
    let dm = if matches!(scheme, PruneScheme::None) {
        Default::default()
    } else {
        scheme_density_map(&g, &scheme)
    };
    Some(estimate_latency(&g, &plan, dev, &prof, &dm, sparse_efficiency(&scheme)).total_ms())
}

fn main() {
    // Fig 18: XGen on the phone GPU vs TPU-V2 batch-1 serving.
    let mut t = Table::new(&["Model", "XGen-mobile mJ", "TPU-V2 mJ", "Mobile advantage"]);
    let tpu = devices::tpu_v2();
    let tpu_prof = xgen::cost::ExecProfile {
        name: "tpu-serving",
        eff: 0.05, // batch-1 serving: systolic array mostly idle
        per_group_overhead_ms: 0.01,
        sparse_capable: false,
    };
    for m in ["resnet-50", "vgg-16", "efficientnet-b0", "mobilenet-v3"] {
        let mob = lat(m, Framework::XGenFull, DeviceClass::MobileGpu, &devices::s10_gpu()).unwrap();
        let g = by_name(m, 1);
        let plan = xgen::fusion::fuse(&g, &xgen::fusion::FusionConfig::default());
        let tpu_ms =
            estimate_latency(&g, &plan, &tpu, &tpu_prof, &Default::default(), 1.0).total_ms();
        let e_m = energy_mj(&devices::s10_gpu(), mob);
        let e_t = energy_mj(&tpu, tpu_ms);
        t.row(vec![
            m.to_string(),
            format!("{e_m:.1}"),
            format!("{e_t:.1}"),
            format!("{:.1}x", e_t / e_m),
        ]);
    }
    t.print("Fig 18 — energy per inference: XGen mobile vs cloud TPU-V2 (batch 1)");

    // TVM energy comparison (paper: 8.0x less energy, same ~3.8 W device).
    let tvm = lat("resnet-50", Framework::Tvm, DeviceClass::MobileCpu, &devices::s10_cpu()).unwrap();
    let xg = lat("resnet-50", Framework::XGenFull, DeviceClass::MobileCpu, &devices::s10_cpu()).unwrap();
    println!(
        "\nenergy vs TVM (same 3.8 W device, ResNet-50): {:.1}x less (paper: 8.0x)",
        tvm / xg
    );

    // NeuroMagic: desktop CPU with non-structured sparsity vs XGen mobile.
    let nm_dev = devices::intel_4core();
    let nm = lat("mobilenet-v2", Framework::NeuroMagic, DeviceClass::DesktopCpu, &nm_dev).unwrap();
    let xg = lat("mobilenet-v2", Framework::XGenFull, DeviceClass::MobileGpu, &devices::s10_gpu()).unwrap();
    let gain = energy_mj(&nm_dev, nm) / energy_mj(&devices::s10_gpu(), xg);
    println!(
        "energy efficiency vs NeuroMagic (MobileNet-V2, 4-core Intel vs 3.8 W phone): {gain:.0}x (paper: 64.6x)"
    );
    let nm_dev = devices::intel_24core();
    let nm = lat("yolo-v4", Framework::NeuroMagic, DeviceClass::DesktopCpu, &nm_dev).unwrap();
    let xg = lat("yolo-v4", Framework::XGenFull, DeviceClass::MobileGpu, &devices::s10_gpu()).unwrap();
    let gain = energy_mj(&nm_dev, nm) / energy_mj(&devices::s10_gpu(), xg);
    println!(
        "energy efficiency vs NeuroMagic (YOLO, 24-core Intel vs 3.8 W phone): {gain:.0}x (paper: 17.3x)"
    );
}
