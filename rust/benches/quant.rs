//! ISSUE-10 — int8 quantized inference end-to-end: the i32-accumulate
//! GEMM vs the f32 blocked kernel on transformer contraction shapes, and
//! the session-level `quantize(force)` arm vs the f32 baseline on the
//! demo transformer. Correctness is asserted before anything is timed
//! (int8 agrees with f32 within the quantization error bound); timing
//! numbers are machine-local and go to `BENCH_quant.json` at the repo
//! root (the checked-in file is a placeholder until this bench runs).

use xgen::api::{Compiler, QuantPolicy};
use xgen::pruning::quant::quantize_gemm_weight;
use xgen::pruning::PruneScheme;
use xgen::tensor::gemm::{gemm, GemmConfig};
use xgen::tensor::qgemm::{qgemm_prepacked, qgemm_scratch_elems, PackedQB};
use xgen::tensor::Tensor;
use xgen::util::bench::{time_ms, Table};
use xgen::util::json::Json;
use xgen::util::rng::Rng;

fn max_abs(x: &[f32]) -> f32 {
    x.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
}

fn max_abs_diff(x: &[f32], y: &[f32]) -> f32 {
    x.iter().zip(y).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max)
}

fn main() {
    let mut rng = Rng::new(0x1A78);
    let quick = std::env::var("XGEN_BENCH_QUICK").is_ok();
    let cfg = GemmConfig::default();

    // --- kernel-level: prepacked int8 vs prepacked-equivalent f32 ----
    // Transformer contraction shapes (tokens × d_model × d_ff etc.).
    let shapes: &[(usize, usize, usize)] = if quick {
        &[(64, 64, 256), (64, 256, 64)]
    } else {
        &[(64, 64, 256), (64, 256, 64), (128, 128, 512), (256, 256, 1024)]
    };
    let mut t = Table::new(&["m x k x n", "f32 (ms)", "int8 (ms)", "int8 x", "rel err"]);
    let mut results = Vec::new();
    for &(m, k, n) in shapes {
        let a = rng.normal_vec(m * k, 0.0, 1.0);
        // Weight in the Dense layout [in=k, out=n]; packed once like
        // ExecState::prepack does it.
        let w = Tensor::from_vec(&[k, n], rng.normal_vec(k * n, 0.0, 0.1));
        let pqb = PackedQB::from_weight(&w, &cfg).expect("finite weights");
        let q = quantize_gemm_weight(&w).expect("finite weights");
        assert_eq!(q.scales.len(), n, "one dequant scale per output column");

        let mut want = vec![0.0f32; m * n];
        gemm(m, k, n, &a, w.data(), &mut want, &cfg);
        let mut got = vec![0.0f32; m * n];
        let mut scratch = vec![0i8; qgemm_scratch_elems(&cfg) * cfg.resolved_threads()];
        qgemm_prepacked(m, &a, &pqb, &mut got, &cfg, &mut scratch);
        // Matched-accuracy gate before timing: int8 must sit inside the
        // symmetric-quantization error envelope of the f32 result.
        let rel = max_abs_diff(&want, &got) / max_abs(&want).max(1e-6);
        assert!(rel < 0.05, "int8 GEMM off the f32 oracle: rel err {rel} at {m}x{k}x{n}");

        let (warm, samples) = if quick { (1, 3) } else { (1, 5) };
        let f32_t = time_ms(warm, samples, || {
            gemm(m, k, n, &a, w.data(), &mut want, &cfg);
        });
        let int8_t = time_ms(warm, samples, || {
            qgemm_prepacked(m, &a, &pqb, &mut got, &cfg, &mut scratch);
        });
        t.row(vec![
            format!("{m}x{k}x{n}"),
            format!("{:.3}", f32_t.mean),
            format!("{:.3}", int8_t.mean),
            format!("{:.2}x", f32_t.mean / int8_t.mean),
            format!("{rel:.1e}"),
        ]);
        results.push(Json::obj(vec![
            ("m", Json::num(m as f64)),
            ("k", Json::num(k as f64)),
            ("n", Json::num(n as f64)),
            ("f32_ms", Json::num(f32_t.mean)),
            ("int8_ms", Json::num(int8_t.mean)),
            ("speedup", Json::num(f32_t.mean / int8_t.mean)),
            ("rel_err", Json::num(rel as f64)),
        ]));
    }
    t.print("prepacked int8 GEMM vs f32 blocked kernel");

    // --- session-level: quantize(force) vs f32 on the demo transformer --
    let compile = |policy: QuantPolicy| {
        Compiler::for_model("demo-transformer", 1)
            .expect("zoo model")
            .random_weights(11)
            .scheme(PruneScheme::None)
            .quantize(policy)
            .compile()
            .expect("compile")
    };
    let f32_m = compile(QuantPolicy::Off);
    let int8_m = compile(QuantPolicy::Force);
    let xs = f32_m.sample_inputs(11);
    let y_f32 = f32_m.infer(&xs).expect("f32 infer");
    let y_int8 = int8_m.infer(&xs).expect("int8 infer");
    let rel = max_abs_diff(y_f32[0].data(), y_int8[0].data()) / max_abs(y_f32[0].data()).max(1e-6);
    assert!(rel < 0.25, "quantized transformer diverged from f32: rel err {rel}");
    let int8_layers = int8_m.report().int8_layer_count();
    assert!(int8_layers > 0, "force policy quantized no layers");

    let (warm, samples) = if quick { (1, 3) } else { (2, 8) };
    let e2e_f32 = time_ms(warm, samples, || {
        let _ = f32_m.infer(&xs).expect("f32 infer");
    });
    let e2e_int8 = time_ms(warm, samples, || {
        let _ = int8_m.infer(&xs).expect("int8 infer");
    });
    println!(
        "\ndemo-transformer e2e: f32 {:.2} ms, int8[force] {:.2} ms ({:.2}x), \
         {int8_layers}/{} contraction layers int8, rel err {rel:.1e}",
        e2e_f32.mean,
        e2e_int8.mean,
        e2e_f32.mean / e2e_int8.mean,
        int8_m.report().precision.len(),
    );

    let json = Json::obj(vec![
        ("bench", Json::str("quant")),
        ("results", Json::Arr(results)),
        (
            "e2e",
            Json::obj(vec![
                ("model", Json::str("demo-transformer")),
                ("f32_ms", Json::num(e2e_f32.mean)),
                ("int8_ms", Json::num(e2e_int8.mean)),
                ("speedup", Json::num(e2e_f32.mean / e2e_int8.mean)),
                ("int8_layers", Json::num(int8_layers as f64)),
                ("rel_err", Json::num(rel as f64)),
            ]),
        ),
    ]);
    let path = if std::path::Path::new("../ROADMAP.md").exists() {
        "../BENCH_quant.json"
    } else {
        "BENCH_quant.json"
    };
    match std::fs::write(path, json.to_string() + "\n") {
        Ok(()) => println!("wrote {path}"),
        Err(e) => println!("could not write {path}: {e}"),
    }
}
