//! ISSUE-9 acceptance: the semantic dataflow analyses (`xgen::analyze`)
//! through the session API.
//!
//! * Positive sweep: every zoo model compiles with the analyses forced on
//!   at every opt level and produces **zero** warnings — the range
//!   domain's "guaranteed non-finite" trigger must never fire on a sane
//!   model, whatever the fusion level.
//! * Mutation negatives: a guaranteed-NaN path (Sqrt over a proven
//!   strictly-negative range), an int8-infeasible dynamic range, an
//!   accumulator-width overflow and a stateful op in the decode closure
//!   each produce a *typed* diagnostic pinned to code + blamed node.
//! * The demo models surface a QuantPlan with feasible int8 layers,
//!   per-channel scales, and a purity class for every fused group.

use xgen::analyze::Effect;
use xgen::api::{Compiler, OptLevel};
use xgen::error::XgenError;
use xgen::exec::DecodeSession;
use xgen::graph::zoo::{all_models, by_name};
use xgen::graph::{Act, Graph, OpKind, WeightStore};
use xgen::util::json::Json;
use xgen::util::rng::Rng;

const OPTS: [OptLevel; 4] = [OptLevel::O0, OptLevel::O1, OptLevel::O2, OptLevel::O3];

/// Every registry model × every opt level analyzes clean: no guaranteed
/// non-finite paths, a range for every node, an effect for every fused
/// group. Weightless — the statistical weight envelope must be wide
/// enough to cover anything `init_random` would produce, yet never so
/// wide it proves a blow-up that cannot happen.
#[test]
fn zoo_analyzes_clean_at_every_opt_level() {
    for name in all_models() {
        for opt in OPTS {
            let cm = Compiler::for_model(name, 1)
                .expect("registry name")
                .opt_level(opt)
                .analyze(true)
                .compile()
                .unwrap_or_else(|e| panic!("{name} at {opt:?}: {e}"));
            let a = cm.report().analysis.as_ref().expect("analysis forced on");
            assert_eq!(a.nodes, cm.graph().nodes.len(), "{name} at {opt:?}");
            assert_eq!(a.ranges.len(), a.nodes, "{name} at {opt:?}");
            assert!(
                a.warnings.is_empty(),
                "{name} at {opt:?}: spurious diagnostics {:?}",
                a.warnings.iter().map(|w| w.to_string()).collect::<Vec<_>>()
            );
            for gp in &a.purity.groups {
                assert!(!gp.nodes.is_empty(), "{name} at {opt:?}: empty purity group");
            }
        }
    }
}

/// A path that is NaN for *every* input in the declared ranges is blamed
/// on its origin node — the Sqrt — with a typed compile warning, not on
/// the downstream nodes the poison flows into.
#[test]
fn guaranteed_nan_path_is_diagnosed_and_blamed() {
    let mut g = Graph::new("nan-trap");
    let x = g.input("x", &[1, 8]);
    let r = g.add("relu", OpKind::Activation(Act::Relu), vec![x], vec![1, 8]);
    // relu ⊆ [0, 6]; -x - 1 ⊆ [-7, -1]: strictly negative, so IEEE sqrt
    // is NaN over the whole reachable set.
    let s = g.add("flip", OpKind::Scale { mul: -1.0, add: -1.0 }, vec![r], vec![1, 8]);
    let q = g.add("sqrt_bad", OpKind::Sqrt, vec![s], vec![1, 8]);
    let t = g.add("after", OpKind::Activation(Act::Relu6), vec![q], vec![1, 8]);
    g.outputs = vec![t];

    let cm = Compiler::new(g)
        .opt_level(OptLevel::O0) // no rewrites: node ids stay as built
        .analyze(true)
        .compile()
        .expect("diagnostics are warnings, not compile aborts");
    let a = cm.report().analysis.as_ref().unwrap();
    assert_eq!(a.warnings.len(), 1, "origin-only blame: downstream Relu6 is not re-reported");
    let XgenError::AnalysisDiagnostic { code, node, name, detail } = &a.warnings[0] else {
        panic!("expected AnalysisDiagnostic, got {}", a.warnings[0]);
    };
    assert_eq!(code, "guaranteed-nan");
    assert_eq!(*node, q);
    assert_eq!(name, "sqrt_bad");
    assert!(detail.contains("sqrt"), "detail names the op: {detail}");
    assert!(a.ranges[q].guaranteed_non_finite());
    assert!(cm.report().summary().contains("warning: analysis[guaranteed-nan]"));
}

/// Int8 infeasibility is a reason code on the QuantPlan — never a
/// warning: the model compiles clean, the plan records why the layer
/// must stay fp32.
#[test]
fn int8_infeasible_layers_carry_reason_codes() {
    // (a) dynamic range: a 1e7 pre-scale puts the dense input far past
    // any useful 8-bit resolution.
    let mut g = Graph::new("wide");
    let x = g.input("x", &[1, 8]);
    let s = g.add("blow", OpKind::Scale { mul: 1e7, add: 0.0 }, vec![x], vec![1, 8]);
    let w = g.weight("w", &[8, 4]);
    let d = g.add("fc", OpKind::Dense, vec![s, w], vec![1, 4]);
    g.outputs = vec![d];
    let cm = Compiler::new(g).opt_level(OptLevel::O0).analyze(true).compile().unwrap();
    let a = cm.report().analysis.as_ref().unwrap();
    assert!(a.warnings.is_empty(), "infeasibility is not a diagnostic");
    let layer = a.quant.layers.iter().find(|l| l.name == "fc").expect("dense layer planned");
    assert!(!layer.feasible);
    assert_eq!(layer.reason, Some("dynamic-range"));
    assert!(layer.in_amax > 1e4);

    // (b) accumulator width: K = 200_000 needs 15 + ⌈log2 K⌉ = 33 bits,
    // one more than the i32 accumulator has.
    let mut g = Graph::new("deep");
    let x = g.input("x", &[1, 200_000]);
    let w = g.weight("w", &[200_000, 4]);
    let d = g.add("fc", OpKind::Dense, vec![x, w], vec![1, 4]);
    g.outputs = vec![d];
    let cm = Compiler::new(g).opt_level(OptLevel::O0).analyze(true).compile().unwrap();
    let a = cm.report().analysis.as_ref().unwrap();
    let layer = a.quant.layers.iter().find(|l| l.name == "fc").unwrap();
    assert!(!layer.feasible);
    assert_eq!(layer.reason, Some("accumulator-width"));
    assert_eq!(layer.acc_bits, 33);
    assert_eq!(layer.k, 200_000);
}

/// A stateful op inside the decode closure is rejected by the purity
/// gate at session construction — typed, with the blamed node — instead
/// of corrupting generation mid-stream.
#[test]
fn decode_rejects_stateful_op_with_typed_diagnostic() {
    let mut g = by_name("demo-transformer-causal", 1);
    let out = g.outputs[0];
    let shape = g.node(out).shape.clone();
    let pp = g.add("nms", OpKind::PostProcess, vec![out], shape);
    g.outputs = vec![pp];
    let ws = WeightStore::init_random(&g, &mut Rng::new(7));

    let err = DecodeSession::new(&g, &ws, 8).expect_err("stateful op in the trace");
    let xe = XgenError::of(&err).expect("typed error surfaces through anyhow");
    let XgenError::AnalysisDiagnostic { code, node, name, .. } = xe else {
        panic!("expected AnalysisDiagnostic, got {xe}");
    };
    assert_eq!(code, "trace-unsafe");
    assert_eq!(*node, pp);
    assert_eq!(name, "nms");
}

/// The baseline stays usable: the unmodified causal demo passes the
/// purity gate and builds a session (release builds included — the gate
/// and the `.verify(true)` pre-check no longer hide behind
/// `debug_assertions`).
#[test]
fn causal_decoder_passes_the_purity_gate() {
    let cm = Compiler::for_model("demo-transformer-causal", 1)
        .unwrap()
        .random_weights(7)
        .verify(true)
        .compile()
        .unwrap();
    assert!(cm.decode_session(8).is_ok());
    let a = cm.report().analysis.as_ref().expect("O2 default runs the analyses");
    assert!(a.purity.trace_safe(), "every fused group of the causal demo is traceable");
}

/// The demo CNN's report carries the full artifact set: a QuantPlan with
/// at least one feasible int8 layer (with per-channel scales), a purity
/// class for every fused group, and a serializable JSON form.
#[test]
fn demo_model_reports_quant_plan_and_purity() {
    let cm = Compiler::for_model("demo-cnn", 1)
        .unwrap()
        .random_weights(7)
        .opt_level(OptLevel::O2)
        .compile()
        .unwrap();
    let a = cm.report().analysis.as_ref().expect("analysis defaults on at O2");
    assert!(a.warnings.is_empty());
    assert!(a.finite_nodes > 0);

    assert!(!a.quant.layers.is_empty(), "demo-cnn has contraction layers");
    assert!(a.quant.feasible_count() >= 1, "at least one layer is int8-feasible");
    let feas = a.quant.layers.iter().find(|l| l.feasible).unwrap();
    assert!(!feas.channel_scales.is_empty(), "weighted compile yields per-channel scales");
    assert!(feas.in_scale > 0.0 && feas.weight_scale > 0.0);
    for l in &a.quant.layers {
        assert_eq!(l.feasible, l.reason.is_none(), "{}: reason iff infeasible", l.name);
    }

    assert!(!a.purity.groups.is_empty());
    assert!(a.purity.count(Effect::GemmEpilogueFusable) >= 1, "conv groups anchor on a GEMM");
    assert_eq!(a.purity.count(Effect::Stateful), 0);
    assert_eq!(a.purity.count(Effect::FallbackOnly), 0);

    let summary = cm.report().summary();
    assert!(summary.contains("analysis:"), "report surfaces the analysis line:\n{summary}");

    let back = Json::parse(&a.quant.to_json().to_string()).expect("QuantPlan serializes");
    let n = back.get("layers").and_then(Json::as_arr).map(<[Json]>::len);
    assert_eq!(n, Some(a.quant.layers.len()));
}

/// `.analyze(bool)` overrides the opt-level default (on at O2+).
#[test]
fn analyze_defaults_follow_opt_level_and_override() {
    let at = |opt, force: Option<bool>| {
        let mut c = Compiler::for_model("demo-cnn", 1).unwrap().opt_level(opt);
        if let Some(on) = force {
            c = c.analyze(on);
        }
        c.compile().unwrap().report().analysis.is_some()
    };
    assert!(!at(OptLevel::O1, None), "below O2 the analyses default off");
    assert!(at(OptLevel::O2, None), "O2 default on");
    assert!(at(OptLevel::O0, Some(true)), "forced on at O0");
    assert!(!at(OptLevel::O3, Some(false)), "forced off at O3");
}
