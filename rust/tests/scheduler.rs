//! Integration test: the full Table 5 sweep — six application variants ×
//! five runtime regimes — checking the paper's qualitative results:
//! deadlock under ROSCH, 100% miss under the intermediate regimes, 0%
//! miss with the full XEngine stack.

use xgen::xengine::adapp::{modules, variants};
use xgen::xengine::sim::simulate;
use xgen::xengine::Policy;

#[test]
fn table5_full_sweep() {
    for v in variants() {
        let mods = modules(v);
        // Segment 1: ROSCH — perception deadlocks (∞), app misses 100%.
        let r1 = simulate(v.name, &mods, Policy::Rosch, 3000.0, 0xAD01);
        assert!(r1.module("2d_percept").timed_out(), "{}: no deadlock", v.name);
        assert!(r1.worst_miss_rate() > 0.99, "{}: rosch miss {}", v.name, r1.worst_miss_rate());

        // Segments 2–4: progress but the most sluggish module still misses.
        for (policy, seed) in [
            (Policy::LinuxTs, 0xAD02u64),
            (Policy::JitPriority, 0xAD03),
            (Policy::JitMigration, 0xAD04),
        ] {
            let r = simulate(v.name, &mods, policy, 3000.0, seed);
            assert!(
                !r.module("2d_percept").timed_out(),
                "{} {:?}: still deadlocked",
                v.name,
                policy
            );
            assert!(
                r.module("2d_percept").miss_rate() > 0.9,
                "{} {:?}: 2d miss only {}",
                v.name,
                policy,
                r.module("2d_percept").miss_rate()
            );
        }

        // Segment 5: co-optimization meets the deadlines (0% in the paper;
        // allow a little simulator noise).
        let r5 = simulate(v.name, &mods, Policy::CoOpt, 3000.0, 0xAD05);
        assert!(
            r5.worst_miss_rate() < 0.05,
            "{}: co-opt misses {:?}",
            v.name,
            r5.modules
                .iter()
                .map(|m| (m.name, m.miss_rate()))
                .collect::<Vec<_>>()
        );
    }
}

#[test]
fn jit_fixes_localization_starvation_in_every_variant() {
    for v in variants() {
        let mods = modules(v);
        let ts = simulate(v.name, &mods, Policy::LinuxTs, 3000.0, 0xBD01);
        let jit = simulate(v.name, &mods, Policy::JitPriority, 3000.0, 0xBD02);
        let loc_ts = ts.module("localization").mean();
        let loc_jit = jit.module("localization").mean();
        assert!(
            loc_jit < loc_ts * 0.7,
            "{}: localization {} -> {} (no JIT win)",
            v.name,
            loc_ts,
            loc_jit
        );
        assert!(loc_jit < 60.0, "{}: jit localization {}", v.name, loc_jit);
    }
}

#[test]
fn planning_meets_10ms_deadline_under_all_policies() {
    // Planning is tiny and runs on its own core — it must never miss
    // (Table 5 shows ~1.1ms under every segment).
    let v = variants()[2];
    let mods = modules(v);
    for p in Policy::all() {
        let r = simulate(v.name, &mods, p, 2000.0, 0xCD01);
        assert!(
            r.module("planning").miss_rate() < 0.02,
            "{:?}: planning miss {}",
            p,
            r.module("planning").miss_rate()
        );
        assert!(r.module("planning").mean() < 3.0);
    }
}

#[test]
fn deterministic_given_seed() {
    let v = variants()[0];
    let mods = modules(v);
    let a = simulate(v.name, &mods, Policy::LinuxTs, 1500.0, 42);
    let b = simulate(v.name, &mods, Policy::LinuxTs, 1500.0, 42);
    for (ma, mb) in a.modules.iter().zip(&b.modules) {
        assert_eq!(ma.latencies, mb.latencies);
    }
}
