//! ISSUE-3 acceptance: zero-allocation steady-state inference.
//!
//! This integration binary installs a counting global allocator (its own
//! binary, so the lib/test builds are unaffected) and pins the tentpole
//! property: with {prepack, workspace, pool} all on, repeated
//! `CompiledModel::infer_into` performs **zero heap allocations on the
//! calling thread** after warm-up, dispatches its GEMM row bands on the
//! persistent worker pool (no per-call `thread::scope` — the spawn sites
//! were removed from `tensor/gemm.rs` entirely), and is bitwise
//! deterministic across calls.
//!
//! The counter is thread-local so concurrently running tests in this
//! binary cannot pollute the measurement.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::atomic::Ordering;

use xgen::api::{Compiler, QuantPolicy};
use xgen::pruning::PruneScheme;
use xgen::runtime::pool;
use xgen::tensor::Tensor;
use xgen::util::rng::Rng;

thread_local! {
    static TRACK: Cell<bool> = const { Cell::new(false) };
    static COUNT: Cell<u64> = const { Cell::new(0) };
}

struct CountingAlloc;

fn note() {
    // try_with: the allocator must never panic, even during TLS teardown.
    let _ = TRACK.try_with(|t| {
        if t.get() {
            let _ = COUNT.try_with(|c| c.set(c.get() + 1));
        }
    });
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        note();
        System.alloc(l)
    }

    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        note();
        System.alloc_zeroed(l)
    }

    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        note();
        System.realloc(p, l, new_size)
    }

    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Count allocations on this thread while running `f`.
fn count_allocs<F: FnMut()>(mut f: F) -> u64 {
    COUNT.with(|c| c.set(0));
    TRACK.with(|t| t.set(true));
    f();
    TRACK.with(|t| t.set(false));
    COUNT.with(|c| c.get())
}

/// The tentpole acceptance test: demo-cnn end-to-end `infer_into` with
/// the full steady-state engine allocates nothing after warm-up.
#[test]
fn steady_state_infer_is_allocation_free() {
    let m = Compiler::for_model("demo-cnn", 1)
        .unwrap()
        .random_weights(42)
        .compile()
        .unwrap();
    assert!(m.report().prepacked_operands > 0, "prepack did not run");
    assert!(m.report().workspace_enabled, "workspace engine off");
    let inputs = vec![Tensor::randn(&[1, 3, 24, 24], 1.0, &mut Rng::new(5))];
    let mut outs: Vec<Tensor> = m.output_shapes().iter().map(|s| Tensor::zeros(s)).collect();
    // Warm-up: pool spawn, lazy env reads, first-touch faults.
    for _ in 0..3 {
        m.infer_into(&inputs, &mut outs).unwrap();
    }
    let want = outs[0].data().to_vec();
    let n = count_allocs(|| {
        for _ in 0..5 {
            m.infer_into(&inputs, &mut outs).unwrap();
        }
    });
    assert_eq!(
        n, 0,
        "steady-state infer_into made {n} heap allocations on the calling thread"
    );
    assert_eq!(outs[0].data(), &want[..], "tracked runs changed the result");
}

/// ISSUE-10 acceptance: the int8 steady path is allocation-free too —
/// activations quantize into the arena's per-thread i8 scratch bands and
/// the weight side tables were packed at compile time, so `quantize(force)`
/// adds no per-call heap traffic over the f32 engine.
#[test]
fn steady_state_int8_infer_is_allocation_free() {
    let m = Compiler::for_model("demo-cnn", 1)
        .unwrap()
        .random_weights(42)
        .quantize(QuantPolicy::Force)
        .compile()
        .unwrap();
    assert!(m.report().int8_layer_count() > 0, "force packed no int8 layers");
    let inputs = vec![Tensor::randn(&[1, 3, 24, 24], 1.0, &mut Rng::new(5))];
    let mut outs: Vec<Tensor> = m.output_shapes().iter().map(|s| Tensor::zeros(s)).collect();
    for _ in 0..3 {
        m.infer_into(&inputs, &mut outs).unwrap();
    }
    let want = outs[0].data().to_vec();
    let n = count_allocs(|| {
        for _ in 0..5 {
            m.infer_into(&inputs, &mut outs).unwrap();
        }
    });
    assert_eq!(
        n, 0,
        "int8 steady-state infer_into made {n} heap allocations on the calling thread"
    );
    assert_eq!(outs[0].data(), &want[..], "tracked int8 runs changed the result");
}

/// The FKW route (pattern-pruned convs) is allocation-free too.
#[test]
fn steady_state_fkw_infer_is_allocation_free() {
    let m = Compiler::for_model("demo-cnn", 1)
        .unwrap()
        .random_weights(42)
        .scheme(PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.3 })
        .compile()
        .unwrap();
    assert!(m.report().fkw_layers > 0, "no FKW kernels attached");
    let inputs = vec![Tensor::randn(&[1, 3, 24, 24], 1.0, &mut Rng::new(6))];
    let mut outs: Vec<Tensor> = m.output_shapes().iter().map(|s| Tensor::zeros(s)).collect();
    for _ in 0..3 {
        m.infer_into(&inputs, &mut outs).unwrap();
    }
    let n = count_allocs(|| {
        for _ in 0..5 {
            m.infer_into(&inputs, &mut outs).unwrap();
        }
    });
    assert_eq!(n, 0, "FKW steady-state infer_into made {n} allocations");
}

/// Satellite acceptance: the same `CompiledModel` produces bitwise-equal
/// outputs across 10 repeated `infer()` calls (pool parallelism included).
#[test]
fn repeated_infer_is_bitwise_deterministic() {
    let m = Compiler::for_model("demo-cnn", 1)
        .unwrap()
        .random_weights(7)
        .compile()
        .unwrap();
    let inputs = vec![Tensor::randn(&[1, 3, 24, 24], 1.0, &mut Rng::new(3))];
    let first = m.infer(&inputs).unwrap();
    for i in 1..10 {
        let y = m.infer(&inputs).unwrap();
        assert_eq!(first[0].data(), y[0].data(), "call {i} diverged bitwise");
    }
}

/// Acceptance: per-call GEMM dispatches row bands on the persistent pool
/// instead of spawning. `PARALLEL_JOBS` counts pool dispatches; it must
/// grow during an infer whenever more than one worker is configured.
#[test]
fn infer_dispatches_gemm_on_the_persistent_pool() {
    if pool::configured_threads() <= 1 {
        // Single-core environment: every GEMM legitimately runs serial.
        return;
    }
    let m = Compiler::for_model("demo-cnn", 1)
        .unwrap()
        .random_weights(11)
        .compile()
        .unwrap();
    let inputs = vec![Tensor::randn(&[1, 3, 24, 24], 1.0, &mut Rng::new(9))];
    m.infer(&inputs).unwrap();
    let before = pool::PARALLEL_JOBS.load(Ordering::Relaxed);
    m.infer(&inputs).unwrap();
    let after = pool::PARALLEL_JOBS.load(Ordering::Relaxed);
    assert!(
        after > before,
        "no GEMM/FKW band jobs hit the pool during infer ({before} -> {after})"
    );
}

/// ISSUE-5 satellite: `DecodeSession::step` performs zero heap
/// allocations on the calling thread after warm-up — the per-token hot
/// loop of autoregressive serving touches only session-owned buffers
/// (per-node scratch + K/V caches appended in place).
#[test]
fn decode_step_is_allocation_free_after_warmup() {
    let m = Compiler::for_model("demo-transformer-causal", 1)
        .unwrap()
        .random_weights(42)
        .compile()
        .unwrap();
    let mut s = m.decode_session(32).unwrap();
    assert!(s.kv_cache_elems() > 0, "no K/V cache slots allocated");
    // Warm-up: prefill + a few steps (pool spawn, first-touch faults).
    s.prefill(&[1, 2, 3]).unwrap();
    for t in 4..7u32 {
        s.step(t).unwrap();
    }
    let mut sink = 0.0f32;
    let n = count_allocs(|| {
        for t in 7..17u32 {
            sink += s.step(t).unwrap()[0];
        }
    });
    assert_eq!(
        n, 0,
        "DecodeSession::step made {n} heap allocations on the calling thread"
    );
    assert!(sink.is_finite());
}

/// ISSUE-5 satellite: two sessions from the same `CompiledModel` are
/// bitwise deterministic across a 10-step decode.
#[test]
fn decode_is_bitwise_deterministic_across_sessions() {
    let m = Compiler::for_model("demo-transformer-causal", 1)
        .unwrap()
        .random_weights(7)
        .compile()
        .unwrap();
    let mut a = m.decode_session(16).unwrap();
    let mut b = m.decode_session(16).unwrap();
    a.prefill(&[9, 8, 7]).unwrap();
    b.prefill(&[9, 8, 7]).unwrap();
    for t in 0..10u32 {
        let la = a.step(t).unwrap().to_vec();
        let lb = b.step(t).unwrap();
        assert_eq!(&la[..], lb, "step {t} diverged bitwise across sessions");
    }
    // And a reset session replays the same stream bitwise.
    a.reset();
    let first = a.prefill(&[9, 8, 7]).unwrap().to_vec();
    b.reset();
    let again = b.prefill(&[9, 8, 7]).unwrap();
    assert_eq!(&first[..], again, "reset session diverged bitwise");
}

/// `infer_into` agrees with the straight-line reference executor.
#[test]
fn infer_into_matches_reference_executor() {
    let steady = Compiler::for_model("demo-cnn", 1)
        .unwrap()
        .random_weights(13)
        .compile()
        .unwrap();
    let oracle = Compiler::for_model("demo-cnn", 1)
        .unwrap()
        .random_weights(13)
        .memory_planner(false)
        .compile()
        .unwrap();
    let inputs = vec![Tensor::randn(&[1, 3, 24, 24], 1.0, &mut Rng::new(17))];
    let mut outs: Vec<Tensor> =
        steady.output_shapes().iter().map(|s| Tensor::zeros(s)).collect();
    steady.infer_into(&inputs, &mut outs).unwrap();
    let want = oracle.infer(&inputs).unwrap();
    let d = outs[0].max_abs_diff(&want[0]);
    assert!(d < 1e-4, "steady infer_into diverges from reference by {d}");
}
