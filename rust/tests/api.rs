//! Integration tests for the `xgen::api` session API (the ISSUE-2
//! acceptance matrix): every `PruneScheme` × {FKW on/off} × {deep reuse
//! on/off} compiled on the small demo-cnn zoo model must match the plain
//! `Executor` oracle running the *same* rewritten graph + pruned weights.

use xgen::api::{Compiler, OptLevel};
use xgen::deepreuse::ReuseConfig;
use xgen::exec::Executor;
use xgen::pruning::PruneScheme;
use xgen::tensor::Tensor;
use xgen::util::rng::Rng;

fn schemes() -> Vec<PruneScheme> {
    vec![
        PruneScheme::None,
        PruneScheme::NonStructured { rate: 0.7 },
        PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.3 },
        PruneScheme::Pattern { set_size: 4, connectivity_rate: 0.0 },
        PruneScheme::Block { block: 4, rate: 0.6 },
        PruneScheme::Structured { rate: 0.5 },
    ]
}

/// The tentpole acceptance test: scheme × fkw × reuse against the oracle.
#[test]
fn compiled_model_matches_executor_oracle_across_matrix() {
    for scheme in schemes() {
        for fkw in [false, true] {
            for reuse in [false, true] {
                let mut c = Compiler::for_model("demo-cnn", 1)
                    .unwrap()
                    .random_weights(1234)
                    .scheme(scheme.clone())
                    .fkw(fkw);
                if reuse {
                    // Tight LSH config so the oracle comparison stays
                    // meaningful: fine buckets + 2% outlier bound.
                    c = c.reuse_config(ReuseConfig {
                        hash_bits: 12,
                        max_rel_dev: 0.02,
                        ..Default::default()
                    });
                }
                let m = c.compile().unwrap();
                if fkw && matches!(scheme, PruneScheme::Pattern { .. }) {
                    assert!(
                        m.report().fkw_layers > 0,
                        "{scheme:?}: pattern scheme attached no FKW kernels"
                    );
                }
                let shape = m.input_shapes()[0].clone();
                let x = Tensor::randn(&shape, 1.0, &mut Rng::new(99));
                let y = m.infer(&[x.clone()]).unwrap();
                // Oracle: same rewritten graph + pruned weights through the
                // straight-line reference executor.
                let oracle = Executor::new(m.graph(), m.weights().unwrap())
                    .run(&[x])
                    .unwrap();
                assert_eq!(y[0].shape(), oracle[0].shape());
                if reuse {
                    let scale = oracle[0].data().iter().map(|v| v.abs()).sum::<f32>()
                        / oracle[0].len() as f32;
                    let rel = y[0].mad(&oracle[0]) / scale.max(1e-6);
                    assert!(
                        rel < 0.05,
                        "{scheme:?} fkw={fkw} reuse=on: rel err {rel}"
                    );
                } else {
                    let d = y[0].max_abs_diff(&oracle[0]);
                    assert!(d < 1e-4, "{scheme:?} fkw={fkw}: max abs diff {d}");
                }
            }
        }
    }
}

/// All four opt levels agree numerically; fusion (O2) actually reduces the
/// kernel count vs the unfused plan (O0).
#[test]
fn opt_levels_preserve_numerics_and_o2_fuses() {
    let mut outs = Vec::new();
    for opt in [OptLevel::O0, OptLevel::O1, OptLevel::O2, OptLevel::O3] {
        let m = Compiler::for_model("demo-cnn", 1)
            .unwrap()
            .random_weights(6)
            .opt_level(opt)
            .compile()
            .unwrap();
        let x = Tensor::randn(&[1, 3, 24, 24], 1.0, &mut Rng::new(8));
        outs.push((opt, m.report().fusion_groups, m.infer(&[x]).unwrap()));
    }
    for w in outs.windows(2) {
        let d = w[0].2[0].max_abs_diff(&w[1].2[0]);
        assert!(d < 1e-4, "{:?} vs {:?}: diff {d}", w[0].0, w[1].0);
    }
    assert!(
        outs[2].1 < outs[0].1,
        "fusion did not reduce kernel count: {} !< {}",
        outs[2].1,
        outs[0].1
    );
}

/// The planner toggle swaps the engine without changing numerics.
#[test]
fn planner_toggle_is_numerically_invisible() {
    let on = Compiler::for_model("demo-cnn", 1)
        .unwrap()
        .random_weights(5)
        .compile()
        .unwrap();
    let off = Compiler::for_model("demo-cnn", 1)
        .unwrap()
        .random_weights(5)
        .memory_planner(false)
        .compile()
        .unwrap();
    let x = Tensor::randn(&[1, 3, 24, 24], 1.0, &mut Rng::new(4));
    let a = on.infer(&[x.clone()]).unwrap();
    let b = off.infer(&[x]).unwrap();
    assert!(a[0].max_abs_diff(&b[0]) < 1e-4);
    // The planner path actually pools buffers.
    let (_, stats) = on.infer_with_stats(&[Tensor::zeros(&[1, 3, 24, 24])]).unwrap();
    assert!(stats.slots > 0 && stats.slots < stats.planned_values);
}

/// `estimate` uses the density map cached at compile time and stays
/// deterministic across calls; batched flat inference round-trips.
#[test]
fn estimate_is_cached_and_flat_batch_round_trips() {
    use xgen::baselines::{DeviceClass, Framework};
    use xgen::cost::devices;
    let m = Compiler::for_model("demo-cnn", 2)
        .unwrap()
        .random_weights(3)
        .scheme(PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.2 })
        .compile()
        .unwrap();
    let dev = devices::s10_cpu();
    let a = m.estimate(&dev, Framework::XGenFull, DeviceClass::MobileCpu).unwrap();
    let b = m.estimate(&dev, Framework::XGenFull, DeviceClass::MobileCpu).unwrap();
    assert!(a > 0.0);
    assert_eq!(a, b);

    assert_eq!(m.batch_size(), 2);
    let per: usize = m.input_shapes()[0][1..].iter().product();
    let mut rng = Rng::new(17);
    let xs: Vec<Vec<f32>> = (0..2)
        .map(|_| (0..per).map(|_| rng.f32() * 2.0 - 1.0).collect())
        .collect();
    let ys = m.infer_flat_batch(&xs).unwrap();
    assert_eq!(ys.len(), 2);
    assert_eq!(ys[0].len(), 8);
    // Wrong batch size is a loud error.
    assert!(m.infer_flat_batch(&xs[..1]).is_err());
}
