//! ISSUE-8 acceptance: resilient multi-stream decode serving.
//!
//! Always-on tests pin the `StreamScheduler` contract: many concurrent
//! streams multiplexed over a bounded session pool decode exactly what a
//! single-stream `CompiledModel::generate` produces; the KV budget sizes
//! the pool (and refuses a budget too small for one session); bounded
//! admission sheds typed `Overloaded` errors carrying a retry-after hint
//! and the retry helper gives up typed; zero deadlines evict queued
//! streams; and dropping the scheduler *drains* — every admitted stream
//! (64 of them over a 4-session pool) completes, with zero leaked
//! sessions at thread exit.
//!
//! The `chaos` module (compiled under `--features fault-injection`) aims
//! `xgen::runtime::fault::StreamFault`s at exact `(stream, step)`
//! ordinals and proves isolation *bitwise*: a failing, panicking, or
//! NaN-corrupted stream gets its typed error while every unaffected
//! stream's output is bit-for-bit the fault-free run; a stall-driven
//! priority preemption checkpoints the victim and its resumed output is
//! bit-for-bit an uninterrupted decode; a stalled stream is evicted at
//! its deadline with its partial output standing.
//!
//! The fault plan is process-global, so every test in this binary runs
//! behind one file-local mutex (same discipline as `tests/robustness.rs`).

use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Duration;

use xgen::api::{CompiledModel, Compiler};
use xgen::coordinator::{RetryPolicy, SchedConfig, StreamScheduler, SubmitOpts};
use xgen::error::XgenError;

/// Serialize every test in this binary (see module docs).
fn serial() -> MutexGuard<'static, ()> {
    static GATE: Mutex<()> = Mutex::new(());
    GATE.lock().unwrap_or_else(PoisonError::into_inner)
}

fn causal() -> CompiledModel {
    Compiler::for_model("demo-transformer-causal", 1)
        .unwrap()
        .random_weights(31)
        .compile()
        .unwrap()
}

/// Distinct valid prompts: rotations of a fixed in-vocab base.
fn prompts(count: usize) -> Vec<Vec<u32>> {
    let base: Vec<u32> = vec![7, 42, 3, 255, 0, 99];
    (0..count)
        .map(|i| {
            let mut p = base.clone();
            p.rotate_left(i % p.len());
            p
        })
        .collect()
}

/// Single-stream references for the same prompts.
fn references(m: &CompiledModel, ps: &[Vec<u32>], n: usize) -> Vec<Vec<u32>> {
    ps.iter().map(|p| m.generate(p, n).unwrap()).collect()
}

#[test]
fn many_streams_match_single_stream_generation_bitwise() {
    let _g = serial();
    let n = 5;
    let ps = prompts(6);
    let expect = references(&causal(), &ps, n);
    let sched = StreamScheduler::start_cfg(
        causal(),
        16,
        SchedConfig { max_streams: 3, ..SchedConfig::default() },
    )
    .unwrap();
    let handles: Vec<_> = ps.iter().map(|p| sched.submit(p.clone(), n)).collect();
    for (i, h) in handles.into_iter().enumerate() {
        let (toks, err) = h.collect();
        assert_eq!(err, None, "stream {i} must finish cleanly");
        assert_eq!(toks, expect[i], "stream {i} must decode exactly its single-stream run");
    }
    let st = sched.shutdown();
    assert_eq!(st.submitted, 6);
    assert_eq!(st.finished, 6);
    assert_eq!(st.tokens, 6 * n);
    assert_eq!(st.failed + st.cancelled + st.deadline_evicted, 0);
    assert!(st.max_active <= 3, "the pool bounds concurrency");
    assert_eq!(st.leaked_sessions, 0);
}

#[test]
fn kv_budget_sizes_the_pool() {
    let _g = serial();
    let m = causal();
    let per = m.kv_cache_bytes(16);
    assert!(per > 0);
    let sched = StreamScheduler::start_cfg(
        m,
        16,
        SchedConfig {
            max_streams: 8,
            kv_budget_bytes: Some(2 * per + per / 2), // room for 2, not 3
            ..SchedConfig::default()
        },
    )
    .unwrap();
    let st = sched.stats();
    assert_eq!(st.pool_sessions, 2, "the budget tightens max_streams");
    assert_eq!(st.session_kv_bytes, per, "pool accounting matches the planner's sizing");
    drop(sched);

    // A budget that cannot hold even one session is refused eagerly.
    let e = StreamScheduler::start_cfg(
        causal(),
        16,
        SchedConfig { kv_budget_bytes: Some(per - 1), ..SchedConfig::default() },
    )
    .err()
    .expect("sub-session budget must fail start");
    assert!(e.to_string().contains("holds no session"), "got: {e}");
}

#[test]
fn overload_sheds_typed_and_retry_gives_up_typed() {
    let _g = serial();
    let sched = StreamScheduler::start_cfg(
        causal(),
        16,
        SchedConfig { queue_cap: 0, ..SchedConfig::default() },
    )
    .unwrap();
    // Typed admission: immediate Overloaded with depth + hint.
    let e = sched.try_submit(vec![5, 6, 7], 2, SubmitOpts::default()).unwrap_err();
    match e {
        XgenError::Overloaded { capacity, retry_after_ms, .. } => {
            assert_eq!(capacity, 0);
            assert!(retry_after_ms >= 1);
        }
        other => panic!("expected Overloaded, got {other}"),
    }
    // Infallible surface: the shed is the stream's only item.
    let (toks, err) = sched.submit(vec![5, 6, 7], 2).collect();
    assert!(toks.is_empty());
    assert_eq!(err.map(|e| e.code()), Some("Overloaded"));
    // Bounded backoff gives up typed.
    let policy = RetryPolicy {
        attempts: 3,
        base: Duration::from_micros(200),
        max: Duration::from_millis(2),
        ..RetryPolicy::default()
    };
    let e = sched
        .submit_with_retry(vec![5, 6, 7], 2, SubmitOpts::default(), &policy)
        .unwrap_err();
    assert!(matches!(e, XgenError::RetryExhausted { attempts: 3, .. }), "got {e}");
    let st = sched.stats();
    assert_eq!(st.shed, 5, "1 typed + 1 stream-embedded + 3 retry attempts");
    assert_eq!(st.submitted, 0, "shed submissions never become streams");
}

#[test]
fn zero_deadline_evicts_queued_streams_typed() {
    let _g = serial();
    let sched = StreamScheduler::start_cfg(
        causal(),
        16,
        SchedConfig { default_deadline: Some(Duration::ZERO), ..SchedConfig::default() },
    )
    .unwrap();
    let (toks, err) = sched.submit(vec![5, 6, 7], 3).collect();
    assert!(toks.is_empty(), "a zero deadline never decodes");
    assert_eq!(err.map(|e| e.code()), Some("DeadlineExceeded"));
    let st = sched.shutdown();
    assert_eq!(st.deadline_evicted, 1);
    assert_eq!(st.finished, 0);
    assert_eq!(st.leaked_sessions, 0);
}

/// Acceptance: drain-on-drop at 64 concurrent streams over a 4-session
/// pool — no deadlock, no stuck client, zero session leak at exit.
#[test]
fn drain_on_drop_serves_all_64_streams_without_leaks() {
    let _g = serial();
    let n = 3;
    let ps = prompts(4);
    let expect = references(&causal(), &ps, n);
    let sched = StreamScheduler::start_cfg(
        causal(),
        16,
        SchedConfig { max_streams: 4, ..SchedConfig::default() },
    )
    .unwrap();
    let handles: Vec<_> =
        (0..64).map(|i| sched.submit(ps[i % ps.len()].clone(), n)).collect();
    // Shut down immediately: the channel closes but every admitted stream
    // must still be served before the thread exits.
    let st = sched.shutdown();
    for (i, h) in handles.into_iter().enumerate() {
        let (toks, err) = h.collect();
        assert_eq!(err, None, "stream {i} must survive the drain");
        assert_eq!(toks, expect[i % expect.len()], "stream {i} bitwise after drain");
    }
    assert_eq!(st.submitted, 64);
    assert_eq!(st.finished, 64);
    assert_eq!(st.pool_sessions, 4);
    assert!(st.max_active <= 4);
    assert_eq!(st.leaked_sessions, 0, "every slot must return to the pool");
}

#[test]
fn dropped_handle_is_cancelled_coherently() {
    let _g = serial();
    let sched = StreamScheduler::start_cfg(
        causal(),
        30,
        SchedConfig::default(),
    )
    .unwrap();
    drop(sched.submit(vec![5, 6, 7], 28)); // hang up immediately
    let st = sched.shutdown();
    assert_eq!(st.submitted, 1);
    assert_eq!(
        st.finished + st.cancelled,
        1,
        "the hung-up stream either finished first or was cancelled — never an error"
    );
    assert_eq!(st.failed, 0);
    assert_eq!(st.leaked_sessions, 0);
}

#[cfg(feature = "fault-injection")]
mod chaos {
    use super::*;
    use xgen::runtime::fault::{self, FaultPlan, StreamFault, StreamFaultKind};

    /// The chaos matrix: three fault kinds aimed at three different
    /// streams of a six-stream run over a three-session pool, in one
    /// plan. Each faulted stream gets its partial output (bitwise) and
    /// its typed error; every unaffected stream is bit-for-bit the
    /// fault-free run.
    #[test]
    fn chaos_matrix_isolates_faulted_streams_bitwise() {
        let _g = serial();
        let n = 5;
        let ps = prompts(6);
        let expect = references(&causal(), &ps, n);
        let sched = StreamScheduler::start_cfg(
            causal(),
            16,
            SchedConfig { max_streams: 3, ..SchedConfig::default() },
        )
        .unwrap();
        let guard = fault::install(FaultPlan {
            stream_faults: vec![
                StreamFault { stream: 1, step: 2, kind: StreamFaultKind::Fail },
                StreamFault { stream: 2, step: 0, kind: StreamFaultKind::Panic },
                StreamFault { stream: 3, step: 1, kind: StreamFaultKind::Nan },
            ],
            ..Default::default()
        });
        let handles: Vec<_> = ps.iter().map(|p| sched.submit(p.clone(), n)).collect();
        let results: Vec<(Vec<u32>, Option<XgenError>)> =
            handles.into_iter().map(|h| h.collect()).collect();
        drop(guard);

        // Stream 1: two clean tokens, then the injected typed failure.
        assert_eq!(results[1].0, expect[1][..2], "stream 1 partial is bitwise");
        let e = results[1].1.as_ref().expect("stream 1 ends in an error");
        assert!(e.to_string().contains("injected fault"), "got: {e}");
        // Stream 2: panicked at prefill — no tokens, typed WorkerPanic.
        assert!(results[2].0.is_empty());
        assert_eq!(results[2].1.as_ref().map(|e| e.code()), Some("WorkerPanic"));
        // Stream 3: one clean token, then the NaN guard fires typed.
        assert_eq!(results[3].0, expect[3][..1], "stream 3 partial is bitwise");
        assert_eq!(results[3].1.as_ref().map(|e| e.code()), Some("NonFinite"));
        // Streams 0, 4, 5: bit-for-bit the fault-free single-stream run.
        for i in [0usize, 4, 5] {
            assert_eq!(results[i].1, None, "stream {i} must be untouched");
            assert_eq!(results[i].0, expect[i], "stream {i} must be bitwise fault-free");
        }
        let st = sched.shutdown();
        assert_eq!(st.finished, 3);
        assert_eq!(st.failed, 3);
        assert_eq!(st.worker_panics, 1);
        assert_eq!(st.session_rebuilds, 1, "only the panic rebuilds a session");
        assert_eq!(st.leaked_sessions, 0);
    }

    /// KV-pressure eviction end to end: a single-session pool, a stalled
    /// low-priority stream, and a high-priority arrival. The victim is
    /// checkpointed (tokens kept, K/V dropped), the high-priority stream
    /// runs to completion, and the victim's resumed output — re-prefilled
    /// from its snapshot — is bit-for-bit an uninterrupted decode.
    #[test]
    fn preempted_stream_resumes_bitwise_after_checkpoint() {
        let _g = serial();
        let ps = prompts(2);
        let m = causal();
        let expect_a = m.generate(&ps[0], 6).unwrap();
        let expect_b = m.generate(&ps[1], 4).unwrap();
        let sched = StreamScheduler::start_cfg(
            m,
            16,
            SchedConfig { max_streams: 1, ..SchedConfig::default() },
        )
        .unwrap();
        // Stall stream 0's second unit long enough that stream 1 is
        // certainly queued by the time the unit completes.
        let guard = fault::install(FaultPlan {
            stream_faults: vec![StreamFault {
                stream: 0,
                step: 1,
                kind: StreamFaultKind::Stall(150),
            }],
            ..Default::default()
        });
        let a = sched.submit_opts(ps[0].clone(), 6, SubmitOpts { priority: 0, deadline: None });
        // Let stream 0 win the only slot before the rival shows up.
        std::thread::sleep(Duration::from_millis(40));
        let b = sched.submit_opts(ps[1].clone(), 4, SubmitOpts { priority: 9, deadline: None });
        let (toks_b, err_b) = b.collect();
        let (toks_a, err_a) = a.collect();
        drop(guard);
        assert_eq!(err_b, None);
        assert_eq!(toks_b, expect_b, "the preemptor decodes bitwise");
        assert_eq!(err_a, None, "the victim survives its eviction");
        assert_eq!(toks_a, expect_a, "checkpoint + re-prefill resume is bitwise");
        let st = sched.shutdown();
        assert_eq!(st.pool_sessions, 1);
        assert!(st.checkpoints >= 1, "the high-priority arrival must preempt");
        assert!(st.resumes >= 1, "the victim must resume from its snapshot");
        assert_eq!(st.finished, 2);
        assert_eq!(st.leaked_sessions, 0);
    }

    /// The watchdog: a stream stalled past its deadline is evicted with
    /// its partial output standing (bitwise) and a typed error, while a
    /// deadline-free stream sharing the pool finishes untouched.
    #[test]
    fn stalled_stream_is_evicted_at_deadline_with_partial_output() {
        let _g = serial();
        let ps = prompts(2);
        let m = causal();
        let expect_a = m.generate(&ps[0], 6).unwrap();
        let expect_b = m.generate(&ps[1], 6).unwrap();
        let sched = StreamScheduler::start_cfg(
            m,
            16,
            SchedConfig { max_streams: 2, ..SchedConfig::default() },
        )
        .unwrap();
        // Stream 0's third unit sleeps well past its 150 ms deadline.
        let guard = fault::install(FaultPlan {
            stream_faults: vec![StreamFault {
                stream: 0,
                step: 2,
                kind: StreamFaultKind::Stall(400),
            }],
            ..Default::default()
        });
        let a = sched.submit_opts(
            ps[0].clone(),
            6,
            SubmitOpts { priority: 0, deadline: Some(Duration::from_millis(150)) },
        );
        let b = sched.submit_opts(ps[1].clone(), 6, SubmitOpts::default());
        let (toks_a, err_a) = a.collect();
        let (toks_b, err_b) = b.collect();
        drop(guard);
        // The stalled unit itself completes (token 3 of 6), then the
        // watchdog evicts before unit 4 — a 3-token partial, bitwise.
        assert_eq!(toks_a, expect_a[..3], "the partial stands, bitwise");
        assert_eq!(err_a.map(|e| e.code()), Some("DeadlineExceeded"));
        // The deadline-free neighbour is untouched.
        assert_eq!(err_b, None);
        assert_eq!(toks_b, expect_b);
        let st = sched.shutdown();
        assert_eq!(st.deadline_evicted, 1);
        assert_eq!(st.finished, 1);
        assert_eq!(st.tokens, 3 + 6, "partial tokens are accounted");
        assert_eq!(st.leaked_sessions, 0);
    }
}
