//! ISSUE-5 acceptance: incremental decoding is *exactly* autoregressive.
//!
//! The oracle is a full causal forward pass: because every attention is
//! causally masked, running the whole fixed-length graph on a sequence
//! whose first `k` positions hold the prompt (and the rest padding)
//! produces, at positions `0..k`, precisely the outputs of the prompt
//! alone — padding can only influence *later* rows. The suite pins that
//! `prefill + N×step` logits match that oracle **at every position**:
//!
//! * `demo-transformer-causal` across the full {fkw, prepack, workspace,
//!   pool} toggle matrix and O0–O3 (tolerance 1e-4);
//! * `gpt2_frontend_layers(1, 2)` — the causal exporter dump with
//!   per-head rank-4 attention, Sqrt/Div scaling and decomposed GELU —
//!   across single-toggle flips and O0/O3 (tolerance 1e-3: d=768 dot
//!   products under two different summation orders);
//! * the straight-line `Executor` as an engine-independent oracle;
//! * loud validation errors (satellite bugfix): out-of-range token ids
//!   and over-long prompts fail in `DecodeSession`, not as executor
//!   bounds panics.

use xgen::api::{CompiledModel, Compiler, OptLevel};
use xgen::exec::Executor;
use xgen::graph::zoo::nlp;
use xgen::tensor::gemm::GemmConfig;
use xgen::tensor::Tensor;

/// Per-position output rows of a full causal forward pass over `tokens`
/// (graph padded to its fixed length with token 0).
fn full_forward_rows(m: &CompiledModel, tokens: &[u32]) -> Vec<Vec<f32>> {
    let shape = m.input_shapes()[0].clone(); // [1, S]
    let s = shape[1];
    assert!(tokens.len() <= s);
    let mut ids = vec![0.0f32; s];
    for (i, &t) in tokens.iter().enumerate() {
        ids[i] = t as f32;
    }
    let y = m.infer(&[Tensor::from_vec(&shape, ids)]).unwrap();
    let row = y[0].len() / s;
    (0..tokens.len())
        .map(|i| y[0].data()[i * row..(i + 1) * row].to_vec())
        .collect()
}

/// Step the prompt token by token and assert the logits match `rows` at
/// every position within `tol`.
fn assert_steps_match(m: &CompiledModel, prompt: &[u32], rows: &[Vec<f32>], tol: f32, label: &str) {
    let mut s = m.decode_session(prompt.len()).unwrap();
    for (i, &t) in prompt.iter().enumerate() {
        let logits = s.step(t).unwrap();
        assert_eq!(logits.len(), rows[i].len(), "{label}: row width at {i}");
        let d = logits
            .iter()
            .zip(&rows[i])
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(
            d < tol,
            "{label}: decode diverges from full causal forward at position {i} by {d}"
        );
        assert!(logits.iter().all(|v| v.is_finite()), "{label}: non-finite at {i}");
    }
}

fn compile_demo(fkw: bool, prepack: bool, workspace: bool, pool: bool, opt: OptLevel) -> CompiledModel {
    Compiler::for_model("demo-transformer-causal", 1)
        .unwrap()
        .random_weights(2026)
        .opt_level(opt)
        .fkw(fkw)
        .prepack(prepack)
        .workspace(workspace)
        .gemm_config(GemmConfig { threads: if pool { 0 } else { 1 }, ..Default::default() })
        .compile()
        .unwrap()
}

const PROMPT: [u32; 6] = [7, 42, 3, 255, 0, 99];

/// Headline: the full toggle matrix on the small causal decoder. The
/// toggles change the *full-forward* engine (the oracle side); the decode
/// interpreter must agree with every one of them.
#[test]
fn demo_decode_matches_full_forward_across_toggle_matrix() {
    for fkw in [false, true] {
        for prepack in [false, true] {
            for workspace in [false, true] {
                for pool in [false, true] {
                    let m = compile_demo(fkw, prepack, workspace, pool, OptLevel::O2);
                    let rows = full_forward_rows(&m, &PROMPT);
                    assert_steps_match(
                        &m,
                        &PROMPT,
                        &rows,
                        1e-4,
                        &format!("demo fkw={fkw} prepack={prepack} ws={workspace} pool={pool}"),
                    );
                }
            }
        }
    }
}

/// O0–O3 change the *graph* the session interprets (raw movement ops vs
/// folded Scale/GELU/transpose chains) — decode must track all of them.
#[test]
fn demo_decode_matches_across_opt_levels() {
    for opt in [OptLevel::O0, OptLevel::O1, OptLevel::O2, OptLevel::O3] {
        let m = compile_demo(true, true, true, true, opt);
        let rows = full_forward_rows(&m, &PROMPT);
        assert_steps_match(&m, &PROMPT, &rows, 1e-4, &format!("demo {}", opt.name()));
    }
}

/// Engine-independent oracle: the straight-line reference `Executor` over
/// the same rewritten graph + weights.
#[test]
fn demo_decode_matches_reference_executor() {
    let m = compile_demo(true, true, true, true, OptLevel::O2);
    let shape = m.input_shapes()[0].clone();
    let s = shape[1];
    let mut ids = vec![0.0f32; s];
    for (i, &t) in PROMPT.iter().enumerate() {
        ids[i] = t as f32;
    }
    let y = Executor::new(m.graph(), m.weights().unwrap())
        .run(&[Tensor::from_vec(&shape, ids)])
        .unwrap();
    let row = y[0].len() / s;
    let rows: Vec<Vec<f32>> = (0..PROMPT.len())
        .map(|i| y[0].data()[i * row..(i + 1) * row].to_vec())
        .collect();
    assert_steps_match(&m, &PROMPT, &rows, 1e-4, "demo vs Executor");
}

/// `prefill(prompt)` is exactly `N×step`: same cache state, same logits.
#[test]
fn prefill_then_step_equals_all_steps() {
    let m = compile_demo(true, true, true, true, OptLevel::O2);
    let mut stepped = m.decode_session(PROMPT.len() + 2).unwrap();
    let mut mixed = m.decode_session(PROMPT.len() + 2).unwrap();
    for &t in &PROMPT {
        stepped.step(t).unwrap();
    }
    let a = mixed.prefill(&PROMPT).unwrap().to_vec();
    let b = stepped.step(11).unwrap(); // advance stepped past the prompt…
    assert!(b.iter().all(|v| v.is_finite()));
    // …but compare the *prompt-end* logits first: re-derive via a fresh
    // all-step session to keep the borrow story simple.
    let mut fresh = m.decode_session(PROMPT.len()).unwrap();
    let mut last = Vec::new();
    for &t in &PROMPT {
        last = fresh.step(t).unwrap().to_vec();
    }
    assert_eq!(a, last, "prefill != N×step (bitwise)");
    // And continuing from a prefill matches continuing from steps.
    let c = mixed.step(11).unwrap();
    assert_eq!(b, c, "post-prefill step != post-steps step (bitwise)");
}

/// The exporter-style causal GPT-2 dump: rank-4 per-head attention,
/// Sqrt/Div scaling, decomposed GELU. Toggle flips at O2 plus O0/O3
/// (each config pays a seq-384 full forward, so the matrix is the
/// single-flip set rather than the full product).
#[test]
fn gpt2_frontend_decode_matches_full_forward() {
    let seed = 424u64;
    let prompt: [u32; 5] = [50256, 318, 2, 7, 1000];
    let mk = |fkw: bool, prepack: bool, workspace: bool, pool: bool, opt: OptLevel| {
        Compiler::new(nlp::gpt2_frontend_layers(1, 2))
            .random_weights(seed)
            .opt_level(opt)
            .fkw(fkw)
            .prepack(prepack)
            .workspace(workspace)
            .gemm_config(GemmConfig { threads: if pool { 0 } else { 1 }, ..Default::default() })
            .compile()
            .unwrap()
    };
    for (fkw, prepack, workspace, pool, opt) in [
        (true, true, true, true, OptLevel::O2),
        (false, true, true, true, OptLevel::O2),
        (true, false, true, true, OptLevel::O2),
        (true, true, false, true, OptLevel::O2),
        (true, true, true, false, OptLevel::O2),
        (true, true, true, true, OptLevel::O0),
        (true, true, true, true, OptLevel::O3),
    ] {
        let m = mk(fkw, prepack, workspace, pool, opt);
        let rows = full_forward_rows(&m, &prompt);
        assert_steps_match(
            &m,
            &prompt,
            &rows,
            1e-3,
            &format!(
                "gpt2-frontend fkw={fkw} prepack={prepack} ws={workspace} pool={pool} {}",
                opt.name()
            ),
        );
    }
}

/// Satellite bugfix: `sample_inputs` produces in-vocab ids, and the decode
/// session rejects out-of-range and too-long inputs with *session-level*
/// errors — never the executor's bounds panic.
#[test]
fn decode_session_validates_inputs_loudly() {
    let m = compile_demo(true, true, true, true, OptLevel::O2);
    // sample_inputs ids are valid prompt material.
    let xs = m.sample_inputs(5);
    let prompt: Vec<u32> = xs[0].data().iter().take(4).map(|&v| v as u32).collect();
    assert!(prompt.iter().all(|&t| (t as usize) < 256));
    let mut s = m.decode_session(8).unwrap();
    s.prefill(&prompt).unwrap();

    // Out-of-range token id: loud session error.
    let err = s.step(1_000_000).unwrap_err().to_string();
    assert!(err.contains("out of range"), "got: {err}");
    // Too-long prompt: loud session error.
    let mut s2 = m.decode_session(4).unwrap();
    let err = s2.prefill(&[1, 2, 3, 4, 5]).unwrap_err().to_string();
    assert!(err.contains("exceeds max_seq"), "got: {err}");
    // max_seq beyond the positional table: refused at construction.
    assert!(m.decode_session(33).is_err());
    assert!(m.decode_session(0).is_err());
    // Non-causal and non-decoder models: refused at construction.
    let enc = Compiler::for_model("demo-transformer", 1)
        .unwrap()
        .random_weights(1)
        .compile()
        .unwrap();
    assert!(enc.decode_session(8).is_err());
    let cnn = Compiler::for_model("demo-cnn", 1)
        .unwrap()
        .random_weights(1)
        .compile()
        .unwrap();
    assert!(cnn.decode_session(8).is_err());
}

/// ISSUE-8 satellite: `snapshot()` at *every* position of a generation,
/// restored into a fresh (or dirty) session, continues bitwise-identically
/// to the uninterrupted run. A snapshot keeps only the token history and
/// restore re-prefills it, so this leans on the pinned "prefill == N×step"
/// identity — and it is the guarantee the stream scheduler's KV-pressure
/// eviction (checkpoint, drop K/V, re-prefill on re-admission) is built
/// on. Full toggle matrix at O2, all-on at O0/O1/O3.
#[test]
fn snapshot_restore_continues_bitwise_at_every_position() {
    let mut configs: Vec<(bool, bool, bool, bool, OptLevel)> = Vec::new();
    for fkw in [false, true] {
        for prepack in [false, true] {
            for workspace in [false, true] {
                for pool in [false, true] {
                    configs.push((fkw, prepack, workspace, pool, OptLevel::O2));
                }
            }
        }
    }
    for opt in [OptLevel::O0, OptLevel::O1, OptLevel::O3] {
        configs.push((true, true, true, true, opt));
    }
    for (fkw, prepack, workspace, pool, opt) in configs {
        let label = format!(
            "demo fkw={fkw} prepack={prepack} ws={workspace} pool={pool} {}",
            opt.name()
        );
        let m = compile_demo(fkw, prepack, workspace, pool, opt);
        let max_seq = PROMPT.len() + 4;

        // Uninterrupted trajectory: the prompt plus greedy continuations,
        // recording the logits row at every position.
        let mut traj = m.decode_session(max_seq).unwrap();
        let mut tokens: Vec<u32> = PROMPT.to_vec();
        let mut rows: Vec<Vec<f32>> = Vec::with_capacity(max_seq);
        for i in 0..max_seq {
            let l = traj.step(tokens[i]).unwrap().to_vec();
            if tokens.len() < max_seq {
                tokens.push(xgen::exec::decode::argmax(&l) as u32);
            }
            rows.push(l);
        }

        for k in 1..max_seq {
            // Snapshot a session holding the first k tokens…
            let mut part = m.decode_session(max_seq).unwrap();
            part.prefill(&tokens[..k]).unwrap();
            let snap = part.snapshot();
            assert_eq!(snap.tokens(), &tokens[..k], "{label}: snapshot holds the history");
            assert_eq!(snap.len(), k);
            // …restore it into a session with unrelated prior state
            // (restore must fully supersede, not merge)…
            let mut fresh = m.decode_session(max_seq).unwrap();
            fresh.prefill(&[9, 1]).unwrap();
            fresh.restore(&snap).unwrap();
            assert_eq!(fresh.len(), k, "{label}: restore re-prefills to the cut");
            // …and the continuation must be bitwise the uninterrupted run.
            for i in k..max_seq {
                let l = fresh.step(tokens[i]).unwrap();
                assert_eq!(
                    l,
                    &rows[i][..],
                    "{label}: cut at {k}, position {i} diverges after restore"
                );
            }
        }
    }
}

/// An empty snapshot is legal and restores to a blank session.
#[test]
fn empty_snapshot_restores_to_blank() {
    let m = compile_demo(true, true, true, true, OptLevel::O2);
    let blank = m.decode_session(4).unwrap();
    let snap = blank.snapshot();
    assert!(snap.is_empty());
    let mut s = m.decode_session(4).unwrap();
    s.prefill(&[5, 6]).unwrap();
    s.restore(&snap).unwrap();
    assert_eq!(s.len(), 0);
    assert_eq!(s.tokens(), &[] as &[u32]);
    // The blanked session decodes normally afterwards.
    assert!(s.prefill(&[5, 6, 7]).is_ok());
    assert_eq!(s.tokens(), &[5, 6, 7]);
}

/// The compact causal registry entry ("gpt-2-decoder") decodes too — a
/// cheap structural smoke at 1 layer scale via the builder, checking the
/// tied-LM-head constant path (MatMul against a transposed weight).
#[test]
fn gpt2_decoder_compact_form_decodes_with_tied_head() {
    let m = Compiler::new(nlp::gpt2_decoder_layers(1, 1))
        .random_weights(9)
        .prepack(false) // don't double the 150 MB embedding in packed form
        .compile()
        .unwrap();
    let mut s = m.decode_session(3).unwrap();
    let prompt: [u32; 2] = [50_000, 17];
    let logits = s.prefill(&prompt).unwrap();
    assert_eq!(logits.len(), 50257);
    assert!(logits.iter().all(|v| v.is_finite()));
    let rows = full_forward_rows(&m, &prompt);
    // Logits are O(√d)-scale pre-softmax values; compare relative to that.
    let mut s2 = m.decode_session(prompt.len()).unwrap();
    for (i, &t) in prompt.iter().enumerate() {
        let got = s2.step(t).unwrap();
        let d = got
            .iter()
            .zip(&rows[i])
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(d < 5e-3, "gpt-2-decoder position {i} diverges by {d}");
    }
}
