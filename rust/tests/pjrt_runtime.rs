//! Integration tests over the real PJRT runtime: load the AOT artifacts,
//! execute, and check numerics against expectations. Skipped (cleanly)
//! when `make artifacts` has not been run.

use std::time::Duration;

use xgen::coordinator::Server;
use xgen::runtime::{artifacts_present, default_artifact_dir, ModelRuntime};
use xgen::util::rng::Rng;

fn skip() -> bool {
    if !artifacts_present() {
        eprintln!("skipping: run `make artifacts` first");
        return true;
    }
    false
}

#[test]
fn load_and_run_cnn_dense() {
    if skip() {
        return;
    }
    let mut rt = ModelRuntime::open(default_artifact_dir()).unwrap();
    assert!(rt.available().contains(&"cnn_dense_b1"));
    let m = rt.load("cnn_dense_b1").unwrap();
    let n: usize = m.input_shape.iter().product();
    let mut rng = Rng::new(301);
    let x: Vec<f32> = (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect();
    let y = m.run(&x).unwrap();
    assert_eq!(y.len(), 8, "8-class logits");
    assert!(y.iter().all(|v| v.is_finite()));
}

#[test]
fn pattern_artifact_close_to_dense_on_logit_ranking() {
    // The pattern artifact was fine-tuned after pruning, so logits differ;
    // but both must be finite and produce a valid argmax.
    if skip() {
        return;
    }
    let mut rt = ModelRuntime::open(default_artifact_dir()).unwrap();
    let mut rng = Rng::new(302);
    let x: Vec<f32> = (0..3 * 24 * 24).map(|_| rng.f32() * 2.0 - 1.0).collect();
    let d = rt.load("cnn_dense_b1").unwrap().run(&x).unwrap();
    let p = rt.load("cnn_pattern_b1").unwrap().run(&x).unwrap();
    assert_eq!(d.len(), p.len());
    assert!(p.iter().all(|v| v.is_finite()));
}

#[test]
fn batch_artifact_matches_singles() {
    if skip() {
        return;
    }
    let mut rt = ModelRuntime::open(default_artifact_dir()).unwrap();
    let mut rng = Rng::new(303);
    let per = 3 * 24 * 24;
    let inputs: Vec<Vec<f32>> = (0..4)
        .map(|_| (0..per).map(|_| rng.f32() * 2.0 - 1.0).collect())
        .collect();
    let batched = rt.load("cnn_dense_b4").unwrap().run_batch(&inputs).unwrap();
    for (i, input) in inputs.iter().enumerate() {
        let single = rt.load("cnn_dense_b1").unwrap().run(input).unwrap();
        for (a, b) in batched[i].iter().zip(&single) {
            assert!((a - b).abs() < 1e-4, "batch/single divergence {a} vs {b}");
        }
    }
}

#[test]
fn wdsr_artifact_upscales() {
    if skip() {
        return;
    }
    let mut rt = ModelRuntime::open(default_artifact_dir()).unwrap();
    let m = rt.load("wdsr_b1").unwrap();
    let n: usize = m.input_shape.iter().product();
    let x = vec![0.5f32; n];
    let y = m.run(&x).unwrap();
    assert_eq!(y.len(), 3 * 64 * 64, "x2 upscale of 3x32x32");
}

#[test]
fn server_batches_and_answers_all() {
    if skip() {
        return;
    }
    let server = Server::start(
        default_artifact_dir(),
        "cnn_dense_b1",
        "cnn_dense_b4",
        Duration::from_millis(4),
    )
    .unwrap();
    let mut rng = Rng::new(304);
    let per = 3 * 24 * 24;
    let mut rxs = Vec::new();
    for _ in 0..13 {
        let x: Vec<f32> = (0..per).map(|_| rng.f32() * 2.0 - 1.0).collect();
        rxs.push(server.submit(x));
    }
    for rx in rxs {
        let out = rx.recv().unwrap().unwrap();
        assert_eq!(out.len(), 8);
    }
    let st = server.stats();
    assert_eq!(st.completed, 13);
    assert!(st.batches < 13, "no batching happened: {} batches", st.batches);
}
