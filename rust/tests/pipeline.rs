//! Integration tests over the full XGen compile pipeline:
//! zoo model → graph rewriting → pruning → DNNFusion → cost model,
//! plus numeric end-to-end checks on the real executor.

use xgen::baselines::{DeviceClass, Framework};
use xgen::cost::{devices, estimate_latency, scheme_density_map, sparse_efficiency, DensityMap};
use xgen::exec::{Executor, FusedExecutor};
use xgen::fusion::{fuse, FusionConfig};
use xgen::graph::zoo::{all_models, by_name, NetBuilder};
use xgen::graph::{Act, WeightStore};
use xgen::pruning::{prune_graph, PruneScheme};
use xgen::rewrite::{rewrite, RewriteConfig};
use xgen::tensor::Tensor;
use xgen::util::rng::Rng;

/// The paper's headline pipeline: every zoo model goes through rewrite +
/// fusion and ends up with strictly fewer kernels than the unfused op
/// count.
#[test]
fn full_pipeline_shrinks_every_zoo_model() {
    for name in all_models() {
        let mut g = by_name(name, 1);
        let ops_before = g.operator_count();
        rewrite(&mut g, None, &RewriteConfig::default());
        assert!(g.validate().is_ok(), "{name}: {:?}", g.validate());
        let plan = fuse(&g, &FusionConfig::default());
        assert!(
            plan.fused_layer_count() < ops_before,
            "{name}: {} groups !< {} ops",
            plan.fused_layer_count(),
            ops_before
        );
    }
}

/// XGen (pattern-pruned + universally fused) must beat every baseline
/// framework on latency for the classic CNNs — the Table 3 ordering.
#[test]
fn xgen_beats_baselines_on_table3_cnns() {
    let dev = devices::s10_cpu();
    // Minimum credible speedup over MNN per model: compact depthwise nets
    // gain less (paper: MobileNetV3 1.8×) than the big CNNs (ResNet 3.4×,
    // VGG 6.5×).
    for (name, min_speedup) in [
        ("resnet-50", 2.0),
        ("vgg-16", 2.5),
        ("mobilenet-v2", 1.4),
        ("efficientnet-b0", 1.5),
    ] {
        let g = by_name(name, 1);
        let mut lat = std::collections::BTreeMap::new();
        for fw in [Framework::Mnn, Framework::Tvm, Framework::TfLite, Framework::XGenFull] {
            if !fw.supports(&g, DeviceClass::MobileCpu) {
                continue;
            }
            let prof = fw.profile(DeviceClass::MobileCpu).unwrap();
            let plan = fw.fusion_plan(&g);
            let scheme = fw.deploy_scheme();
            let dm = if matches!(scheme, PruneScheme::None) {
                DensityMap::new()
            } else {
                scheme_density_map(&g, &scheme)
            };
            let t = estimate_latency(&g, &plan, &dev, &prof, &dm, sparse_efficiency(&scheme))
                .total_ms();
            lat.insert(fw.name(), t);
        }
        let xgen = lat["XGen"];
        for (fw, &t) in &lat {
            if *fw != "XGen" {
                assert!(
                    xgen < t,
                    "{name}: XGen {xgen:.1}ms !< {fw} {t:.1}ms"
                );
            }
        }
        // The paper's speedups are multiples, not percents.
        assert!(
            lat["MNN"] / xgen > min_speedup,
            "{name}: speedup over MNN only {:.2} (need {min_speedup})",
            lat["MNN"] / xgen
        );
    }
}

/// Numeric end-to-end: a small CNN pruned with patterns, rewritten, fused
/// and executed via FKW matches the unoptimized reference on real tensors.
#[test]
fn optimized_execution_matches_reference_numerically() {
    let mut rng = Rng::new(101);
    let mut b = NetBuilder::new("e2e", &[2, 3, 20, 20]);
    b.conv_bn_act(8, 3, 1, 1, Act::Relu);
    b.conv_bn_act(8, 3, 1, 1, Act::Relu);
    b.maxpool(2, 2, 0);
    b.gap();
    b.dense(10);
    let g = b.finish();
    let ws = WeightStore::init_random(&g, &mut rng);
    let x = Tensor::randn(&[2, 3, 20, 20], 1.0, &mut rng);

    // Reference.
    let y_ref = Executor::new(&g, &ws).run(&[x.clone()]).unwrap();
    // Optimized: fused executor (the FKW path is covered in unit tests; an
    // *unpruned* model must be bit-identical through the fused path).
    let plan = fuse(&g, &FusionConfig::default());
    let y_opt = FusedExecutor::new(&g, &ws, &plan).run(&[x]).unwrap();
    assert!(y_ref[0].max_abs_diff(&y_opt[0]) < 1e-4);
}

/// E2E for the memory planner: a residual CNN (zoo-style topology with
/// fan-out, pooling and a dense head) run through the fused executor with
/// buffer pooling must match the straight-line reference exactly, while
/// using far fewer live buffers than one-per-node.
#[test]
fn fused_with_memory_planner_matches_straight_line() {
    let mut rng = Rng::new(104);
    let mut b = NetBuilder::new("planner-e2e", &[2, 3, 24, 24]);
    b.conv_bn_act(12, 3, 1, 1, Act::Relu);
    let skip = b.cur();
    b.conv_bn_act(12, 3, 1, 1, Act::Relu);
    b.conv_bn_act(12, 3, 1, 1, Act::Relu);
    let t = b.cur();
    b.add_residual(skip, t);
    b.maxpool(2, 2, 0);
    b.conv_bn_act(24, 3, 2, 1, Act::Relu);
    b.gap();
    b.dense(10);
    let g = b.finish();
    let ws = WeightStore::init_random(&g, &mut rng);
    let x = Tensor::randn(&[2, 3, 24, 24], 1.0, &mut rng);

    let straight = Executor::new(&g, &ws).run(&[x.clone()]).unwrap();
    let plan = fuse(&g, &FusionConfig::default());
    let (fused, stats) = FusedExecutor::new(&g, &ws, &plan)
        .run_with_stats(&[x])
        .unwrap();
    assert!(
        straight[0].max_abs_diff(&fused[0]) < 1e-4,
        "planner path diverges by {}",
        straight[0].max_abs_diff(&fused[0])
    );
    assert!(
        stats.slots * 2 <= stats.planned_values,
        "peak live allocations not reduced: {} slots for {} materialized values",
        stats.slots,
        stats.planned_values
    );
    assert!(stats.peak_live <= stats.slots);
}

/// Pruning a graph then estimating latency: the Fig 6 frontier — finer
/// blocks cost latency vs coarse, non-structured costs the most.
#[test]
fn fig6_latency_ordering_holds() {
    let g = by_name("resnet-50", 1);
    let plan = fuse(&g, &FusionConfig::default());
    let dev = devices::s10_cpu();
    let prof = Framework::XGenFull.profile(DeviceClass::MobileCpu).unwrap();
    let rate = 1.0 - 1.0 / 6.0;
    let lat = |scheme: &PruneScheme| {
        let dm = scheme_density_map(&g, scheme);
        estimate_latency(&g, &plan, &dev, &prof, &dm, sparse_efficiency(scheme)).total_ms()
    };
    let ns = lat(&PruneScheme::NonStructured { rate });
    let b8 = lat(&PruneScheme::Block { block: 8, rate });
    let b64 = lat(&PruneScheme::Block { block: 64, rate });
    let st = lat(&PruneScheme::Structured { rate });
    assert!(ns > b8 && b8 > b64 && b64 >= st, "{ns} {b8} {b64} {st}");
}

/// The model optimizer actually zeroes weights at the advertised rates on
/// a real store, for every scheme.
#[test]
fn prune_rates_on_real_weight_store() {
    let g = by_name("mobilenet-v1", 1);
    for (scheme, lo, hi) in [
        (PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.0 }, 0.25, 0.60),
        (PruneScheme::Block { block: 8, rate: 0.75 }, 0.45, 0.90),
        (PruneScheme::NonStructured { rate: 0.8 }, 0.55, 0.90),
    ] {
        let mut rng = Rng::new(102);
        let mut ws = WeightStore::init_random(&g, &mut rng);
        let r = prune_graph(&g, &mut ws, &scheme);
        assert!(
            (lo..hi).contains(&r.sparsity),
            "{:?}: sparsity {} outside [{lo},{hi})",
            scheme,
            r.sparsity
        );
    }
}

/// Rewriting + weights preserves numerics through the executor on a graph
/// engineered to trigger several rules at once.
#[test]
fn rewrite_rules_compose_without_changing_numerics() {
    let mut rng = Rng::new(103);
    let mut b = NetBuilder::new("rwmix", &[1, 8]);
    b.dense(16);
    b.dense(16);
    b.act(Act::Relu);
    b.dense(4);
    let mut g = b.finish();
    // identity tail
    let id = g.add(
        "id_scale",
        xgen::graph::OpKind::Scale { mul: 1.0, add: 0.0 },
        vec![g.outputs[0]],
        vec![1, 4],
    );
    g.outputs = vec![id];
    let mut ws = WeightStore::init_random(&g, &mut rng);
    let x = Tensor::randn(&[1, 8], 1.0, &mut rng);
    let before = Executor::new(&g, &ws).run(&[x.clone()]).unwrap();
    let ops_before = g.operator_count();
    rewrite(&mut g, Some(&mut ws), &RewriteConfig::default());
    let after = Executor::new(&g, &ws).run(&[x]).unwrap();
    assert!(g.operator_count() < ops_before);
    assert!(before[0].max_abs_diff(&after[0]) < 1e-4);
}
