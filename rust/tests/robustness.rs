//! ISSUE-6 acceptance: the fault-tolerant serving runtime.
//!
//! Always-on tests pin the typed-error surface (`XgenError` through
//! `infer`/`infer_flat`/`DecodeSession` and both servers), bounded-queue
//! load shedding, zero-deadline rejection, drain-on-drop, and the
//! error-then-continue oracle (a failed `step` leaves the session's K/V
//! caches at their pre-call lengths, so continuing after the error is
//! bitwise-identical to a fresh session that never erred).
//!
//! The `faults` module (compiled under `--features fault-injection`)
//! drives every recovery path deterministically through
//! `xgen::runtime::fault`: pool-task panics, steady-engine failures and
//! panics (reference-path fallback + arena rebuild), decode-node
//! failures/NaN/panics (typed replies + session rebuild), and
//! stall-driven deadline expiry (partial generations).
//!
//! The fault plan is process-global, so every test here — fault-injecting
//! or not — runs behind one file-local mutex: a concurrently running
//! inference would otherwise consume an injected ordinal meant for the
//! test that installed the plan.

use std::sync::{Mutex, MutexGuard, PoisonError};
use std::time::Duration;

use xgen::api::{CompiledModel, Compiler};
use xgen::coordinator::{DecodeConfig, DecodeServer, RetryPolicy, ServeConfig, Server};
use xgen::error::XgenError;
use xgen::tensor::Tensor;

/// Serialize every test in this binary (see module docs).
fn serial() -> MutexGuard<'static, ()> {
    static GATE: Mutex<()> = Mutex::new(());
    GATE.lock().unwrap_or_else(PoisonError::into_inner)
}

fn cnn(batch: usize) -> CompiledModel {
    Compiler::for_model("demo-cnn", batch)
        .unwrap()
        .random_weights(11)
        .compile()
        .unwrap()
}

fn causal() -> CompiledModel {
    Compiler::for_model("demo-transformer-causal", 1)
        .unwrap()
        .random_weights(31)
        .compile()
        .unwrap()
}

/// The typed error inside an anyhow error, asserted present.
fn typed(e: &anyhow::Error) -> &XgenError {
    XgenError::of(e).unwrap_or_else(|| panic!("expected a typed XgenError, got: {e:#}"))
}

#[test]
fn every_variant_has_a_stable_code_and_message() {
    let _g = serial();
    let all = [
        XgenError::ShapeMismatch { expected: "a".into(), got: "b".into() },
        XgenError::VocabOutOfRange { token: 300, vocab: 256 },
        XgenError::SeqOverflow { at: 0, want: 9, max_seq: 4 },
        XgenError::Overloaded { depth: 3, capacity: 2, retry_after_ms: 6 },
        XgenError::DeadlineExceeded { elapsed_ms: 17 },
        XgenError::Cancelled,
        XgenError::WorkerPanic { detail: "boom".into() },
        XgenError::EngineFallback { detail: "both".into() },
        XgenError::NonFinite { at: "logits".into() },
        XgenError::RetryExhausted { attempts: 4, last_depth: 3 },
        XgenError::ServerGone,
        XgenError::Internal { detail: "other".into() },
    ];
    let codes: std::collections::BTreeSet<&str> = all.iter().map(|e| e.code()).collect();
    assert_eq!(codes.len(), all.len(), "codes must be distinct per variant");
    for e in &all {
        assert!(!e.to_string().is_empty());
        // Round-trip through anyhow: the typed value survives intact.
        let any: anyhow::Error = e.clone().into();
        assert_eq!(XgenError::of(&any), Some(e));
        assert_eq!(&XgenError::classify(&any), e);
    }
    // Untyped errors classify as Internal, keeping the full context chain.
    let plain = anyhow::anyhow!("inner").context("outer");
    let c = XgenError::classify(&plain);
    assert_eq!(c.code(), "Internal");
    assert!(c.to_string().contains("outer") && c.to_string().contains("inner"));
}

#[test]
fn infer_validates_inputs_before_executing() {
    let _g = serial();
    let m = cnn(1);
    let good = m.sample_inputs(7);
    assert!(m.infer(&good).is_ok());

    // Wrong shape.
    let e = m.infer(&[Tensor::zeros(&[1, 3, 5, 5])]).unwrap_err();
    assert_eq!(typed(&e).code(), "ShapeMismatch");
    // Missing input.
    let e = m.infer(&[]).unwrap_err();
    assert_eq!(typed(&e).code(), "ShapeMismatch");
    // Extra input.
    let two = [good[0].clone(), good[0].clone()];
    let e = m.infer(&two).unwrap_err();
    assert_eq!(typed(&e).code(), "ShapeMismatch");
    // Flat-input length mismatch (the serving engine's entry point).
    let e = m.infer_flat(&[0.0; 3]).unwrap_err();
    assert_eq!(typed(&e).code(), "ShapeMismatch");
    // A rejected request leaves the engine fully usable.
    assert!(m.infer(&good).is_ok());
    assert_eq!(m.runtime_stats().engine_fallbacks, 0);
}

#[test]
fn decode_session_validates_prompts_and_tokens() {
    let _g = serial();
    let m = causal();
    let mut s = m.decode_session(8).unwrap();
    // Over-long prompt: typed SeqOverflow, nothing consumed.
    let e = s.prefill(&(0..40).collect::<Vec<u32>>()).unwrap_err();
    assert!(matches!(typed(&e), XgenError::SeqOverflow { at: 0, want: 40, max_seq: 8 }));
    assert!(e.to_string().contains("exceeds max_seq"));
    assert_eq!(s.len(), 0);
    // Out-of-vocab token (vocab is 256): typed VocabOutOfRange.
    s.prefill(&[5, 6, 7]).unwrap();
    let e = s.step(999).unwrap_err();
    assert!(matches!(typed(&e), XgenError::VocabOutOfRange { token: 999, vocab: 256 }));
    // Full sequence: the other SeqOverflow spelling, and reset() recovers.
    let mut s = m.decode_session(2).unwrap();
    s.prefill(&[5, 6]).unwrap();
    let e = s.step(1).unwrap_err();
    assert_eq!(typed(&e).code(), "SeqOverflow");
    assert!(e.to_string().contains("full"), "got: {e}");
    s.reset();
    assert!(s.prefill(&[5]).is_ok());
}

/// The error-then-continue oracle: a failed `step` leaves the session at
/// its pre-call state (length AND K/V cache contents), so decoding on
/// after the error is bitwise-identical to a session that never erred.
#[test]
fn decode_session_survives_a_failed_step_bitwise() {
    let _g = serial();
    let m = causal();
    let mut faulted = m.decode_session(8).unwrap();
    faulted.prefill(&[5, 6, 7]).unwrap();
    assert!(faulted.step(9999).is_err()); // out-of-vocab: rejected
    assert_eq!(faulted.len(), 3, "failed step must not advance the session");
    let after_err: Vec<f32> = faulted.step(2).unwrap().to_vec();
    let continued = faulted.generate_continue(3).unwrap();

    let mut clean = m.decode_session(8).unwrap();
    clean.prefill(&[5, 6, 7]).unwrap();
    let clean_logits: Vec<f32> = clean.step(2).unwrap().to_vec();
    let clean_tokens = clean.generate_continue(3).unwrap();

    assert_eq!(after_err, clean_logits, "post-error logits must be bitwise-identical");
    assert_eq!(continued, clean_tokens);
}

#[test]
fn zero_capacity_queues_shed_with_overloaded() {
    let _g = serial();
    // Batch server: cap 0 sheds every submission, typed, via both entry
    // points; stats count the sheds.
    let server = Server::start_compiled_cfg(
        cnn(1),
        cnn(4),
        ServeConfig { queue_cap: 0, ..ServeConfig::default() },
    )
    .unwrap();
    let per = 3 * 24 * 24;
    let e = server.try_submit(vec![0.0; per]).unwrap_err();
    assert_eq!(e.code(), "Overloaded");
    let e = server.infer(vec![0.0; per]).unwrap_err();
    assert_eq!(e.code(), "Overloaded");
    assert_eq!(server.stats().shed, 2);
    drop(server);

    // Decode server: same contract on the streaming path.
    let server = DecodeServer::start_cfg(
        causal(),
        16,
        DecodeConfig { queue_cap: 0, ..DecodeConfig::default() },
    )
    .unwrap();
    let e = server.generate(vec![5, 6, 7], 2).unwrap_err();
    assert_eq!(e.code(), "Overloaded");
    let st = server.stats();
    assert_eq!(st.shed, 1);
    assert_eq!(st.requests, 0, "shed requests never reach the session");
}

/// ISSUE-8 satellite: the shed carries the observed depth and a
/// retry-after hint, and the `*_with_retry` helpers back off and give up
/// with a typed error instead of spinning forever.
#[test]
fn overloaded_carries_a_hint_and_retry_gives_up_typed() {
    let _g = serial();
    // Tight bounded backoff so the give-up path runs in milliseconds.
    let policy = RetryPolicy {
        attempts: 3,
        base: Duration::from_micros(200),
        max: Duration::from_millis(2),
        ..RetryPolicy::default()
    };

    // Batch server at cap 0: every attempt sheds.
    let server = Server::start_compiled_cfg(
        cnn(1),
        cnn(4),
        ServeConfig { queue_cap: 0, ..ServeConfig::default() },
    )
    .unwrap();
    let per = 3 * 24 * 24;
    let e = server.try_submit(vec![0.0; per]).unwrap_err();
    match e {
        XgenError::Overloaded { capacity, retry_after_ms, .. } => {
            assert_eq!(capacity, 0);
            assert!(retry_after_ms >= 1, "hint is at least 1 ms");
        }
        other => panic!("expected Overloaded, got {other}"),
    }
    let e = server.submit_with_retry(vec![0.0; per], &policy).unwrap_err();
    assert!(
        matches!(e, XgenError::RetryExhausted { attempts: 3, .. }),
        "expected RetryExhausted after 3 attempts, got {e}"
    );
    assert_eq!(server.stats().shed, 4, "1 direct + 3 retry attempts all shed");
    drop(server);

    // Decode server: same give-up contract on the streaming path, and an
    // uncontended server succeeds on the first attempt.
    let server = DecodeServer::start_cfg(
        causal(),
        16,
        DecodeConfig { queue_cap: 0, ..DecodeConfig::default() },
    )
    .unwrap();
    let e = server.generate_with_retry(vec![5, 6, 7], 2, &policy).unwrap_err();
    assert!(matches!(e, XgenError::RetryExhausted { attempts: 3, .. }), "got {e}");
    drop(server);

    let server = DecodeServer::start(causal(), 16).unwrap();
    let rx = server.generate_with_retry(vec![5, 6, 7], 2, &policy).unwrap();
    let toks: Vec<u32> = rx.into_iter().filter_map(|r| r.ok()).collect();
    assert_eq!(toks.len(), 2, "first attempt succeeds on an idle server");
}

#[test]
fn zero_deadline_rejects_before_execution() {
    let _g = serial();
    let server = Server::start_compiled_cfg(
        cnn(1),
        cnn(4),
        ServeConfig { default_deadline: Some(Duration::ZERO), ..ServeConfig::default() },
    )
    .unwrap();
    let per = 3 * 24 * 24;
    let e = server.infer(vec![0.0; per]).unwrap_err();
    assert_eq!(e.code(), "DeadlineExceeded");
    // A per-request override beats the server default: the same server
    // still serves relaxed requests.
    let rx = server.submit_with_deadline(vec![0.0; per], Some(Duration::from_secs(60)));
    assert!(rx.recv().unwrap().is_ok());
    let st = server.stats();
    assert_eq!(st.deadline_exceeded, 1);
    assert_eq!(st.completed, 1);
    drop(server);

    let server = DecodeServer::start_cfg(
        causal(),
        16,
        DecodeConfig { default_deadline: Some(Duration::ZERO), ..DecodeConfig::default() },
    )
    .unwrap();
    let err = server.generate(vec![5, 6, 7], 2).unwrap_err();
    assert_eq!(err.code(), "DeadlineExceeded");
    // Override: a generous explicit deadline completes normally.
    let (toks, err) = server.generate_with_deadline(vec![5, 6, 7], 2, Duration::from_secs(60));
    assert_eq!(err, None);
    assert_eq!(toks.len(), 2);
    let st = server.stats();
    assert_eq!(st.deadline_exceeded, 1);
    assert_eq!(st.requests, 1, "the pre-prefill shed never reaches the session");
}

/// Dropping a response receiver must neither panic nor kill the server
/// (ISSUE-6 satellite: the reply-channel audit's regression test).
#[test]
fn dropped_receiver_does_not_kill_the_server() {
    let _g = serial();
    let server =
        Server::start_compiled(cnn(1), cnn(4), Duration::from_millis(1)).unwrap();
    let per = 3 * 24 * 24;
    drop(server.submit(vec![0.0; per]));
    // Still serving after the hang-up.
    for _ in 0..3 {
        assert!(server.infer(vec![0.0; per]).is_ok());
    }
    let st = server.stats();
    // The dropped request either completed before the drop landed or was
    // counted as a cancellation at reply time — never an error.
    assert_eq!(st.completed + st.cancelled, 4);
    assert_eq!(st.errors, 0);
}

/// Dropping the server closes the queue but still answers what is queued.
#[test]
fn server_drop_drains_already_submitted_requests() {
    let _g = serial();
    let server =
        Server::start_compiled(cnn(1), cnn(4), Duration::from_millis(1)).unwrap();
    let per = 3 * 24 * 24;
    let rxs: Vec<_> = (0..3).map(|_| server.submit(vec![0.5; per])).collect();
    drop(server); // graceful drain: joins after the queue empties
    for rx in rxs {
        assert!(rx.recv().expect("drained, not dropped").is_ok());
    }
}

#[cfg(feature = "fault-injection")]
mod faults {
    use super::*;
    use xgen::runtime::fault::{self, FaultPlan};
    use xgen::runtime::pool::ThreadPool;

    fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
        a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
    }

    /// A pool-task panic surfaces as a typed WorkerPanic and the pool
    /// survives to run the next job.
    #[test]
    fn injected_pool_task_panic_is_typed_and_pool_survives() {
        let _g = serial();
        let pool = ThreadPool::new(2);
        let _f = fault::install(FaultPlan {
            panic_on_parallel_task: Some(fault::parallel_tasks_so_far() + 3),
            ..Default::default()
        });
        let err = pool.try_parallel_for(8, |_| {}).unwrap_err();
        assert_eq!(err.code(), "WorkerPanic");
        fault::clear();
        // Same pool, next job: all tasks run.
        let hits = std::sync::atomic::AtomicUsize::new(0);
        pool.try_parallel_for(8, |_| {
            hits.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(hits.load(std::sync::atomic::Ordering::Relaxed), 8);
    }

    /// A steady-engine *failure* at serve time degrades to the reference
    /// path: the caller still gets a (near-identical) answer, the
    /// fallback is counted, and the steady engine comes back untouched.
    #[test]
    fn steady_failure_degrades_to_reference_and_recovers() {
        let _g = serial();
        let m = cnn(1);
        let xs = m.sample_inputs(7);
        let warm = m.infer(&xs).unwrap(); // steady path, unfaulted
        let guard = fault::install(FaultPlan {
            fail_steady_run: Some(fault::steady_runs_so_far()),
            ..Default::default()
        });
        let faulted = m.infer(&xs).unwrap(); // served via the eval_op path
        drop(guard);
        assert!(
            max_abs_diff(warm[0].data(), faulted[0].data()) < 1e-4,
            "fallback answer must match the steady answer numerically"
        );
        let st = m.runtime_stats();
        assert_eq!(st.engine_fallbacks, 1);
        assert_eq!(st.worker_panics, 0);
        // Fault cleared: the steady engine serves again, bitwise.
        let after = m.infer(&xs).unwrap();
        assert_eq!(warm[0].data(), after[0].data());
        assert_eq!(m.runtime_stats().engine_fallbacks, 1);
    }

    /// A *panic* inside the steady engine is caught at the api layer, the
    /// torn arena is rebuilt, and the request is served via the fallback.
    #[test]
    fn steady_panic_is_isolated_and_arena_rebuilt() {
        let _g = serial();
        let m = cnn(1);
        let xs = m.sample_inputs(7);
        let warm = m.infer(&xs).unwrap();
        let guard = fault::install(FaultPlan {
            panic_steady_run: Some(fault::steady_runs_so_far()),
            ..Default::default()
        });
        let faulted = m.infer(&xs).unwrap();
        drop(guard);
        assert!(max_abs_diff(warm[0].data(), faulted[0].data()) < 1e-4);
        let st = m.runtime_stats();
        assert_eq!(st.worker_panics, 1, "the caught panic is counted");
        assert_eq!(st.engine_fallbacks, 1);
        // The rebuilt arena serves the steady path again, bitwise.
        let after = m.infer(&xs).unwrap();
        assert_eq!(warm[0].data(), after[0].data());
        assert_eq!(m.runtime_stats().worker_panics, 1);
    }

    /// Name of the logits node of the causal demo model — evaluated once
    /// per decoded position, so fault ordinals aim at exact positions:
    /// a 3-token prompt burns hits 1..=3 in prefill; hit 4 is step one.
    fn logits_node_name() -> String {
        let m = causal();
        let g = m.graph();
        g.node(g.outputs[0]).name.clone()
    }

    /// The full fault matrix for the decode server: request A is faulted
    /// at a chosen step and gets a typed error (after its partial
    /// stream); request B afterwards is bitwise-identical to an unfaulted
    /// run — proof that A's fault did not leak into shared session state.
    #[test]
    fn decode_node_failure_is_typed_and_isolated() {
        let _g = serial();
        let reference = causal().generate(&[5, 6, 7], 4).unwrap();
        let node = logits_node_name();
        let server = DecodeServer::start(causal(), 16).unwrap();
        assert_eq!(server.generate(vec![5, 6, 7], 4).unwrap(), reference);

        // Fault request A at its first step (prefill burns hits 1..=3).
        let guard = fault::install(FaultPlan {
            fail_decode_node: Some((node, 4)),
            ..Default::default()
        });
        let rx = server.generate_stream(vec![5, 6, 7], 4);
        let mut tokens = Vec::new();
        let mut err = None;
        for item in rx {
            match item {
                Ok(t) => tokens.push(t),
                Err(e) => {
                    err = Some(e);
                    break;
                }
            }
        }
        drop(guard);
        assert_eq!(tokens, &reference[..1], "one token streams before the fault");
        let err = err.expect("the fault ends the stream with an error");
        assert!(err.to_string().contains("injected fault"), "got: {err}");

        // Request B: bitwise-identical to the unfaulted reference.
        assert_eq!(server.generate(vec![5, 6, 7], 4).unwrap(), reference);
        let st = server.stats();
        assert_eq!(st.errors, 1);
        assert_eq!(st.worker_panics, 0);
    }

    /// NaN corruption at the logits node is caught by the non-finite
    /// guard — typed NonFinite, never NaN fed back into the argmax.
    #[test]
    fn decode_nan_is_caught_as_nonfinite() {
        let _g = serial();
        let reference = causal().generate(&[5, 6, 7], 4).unwrap();
        let node = logits_node_name();
        let server = DecodeServer::start(causal(), 16).unwrap();
        // Hit 3 = the last prefill position: corrupts the prefill logits.
        let guard = fault::install(FaultPlan {
            nan_decode_node: Some((node, 3)),
            ..Default::default()
        });
        let err = server.generate(vec![5, 6, 7], 4).unwrap_err();
        drop(guard);
        assert_eq!(err.code(), "NonFinite");
        assert!(err.to_string().contains("prefill"), "got: {err}");
        // The next request is clean and bitwise-identical.
        assert_eq!(server.generate(vec![5, 6, 7], 4).unwrap(), reference);
    }

    /// A panic mid-step: request A gets WorkerPanic after its partial
    /// stream, the session is rebuilt, and request B is bitwise-identical
    /// to the unfaulted reference.
    #[test]
    fn decode_step_panic_rebuilds_the_session() {
        let _g = serial();
        let reference = causal().generate(&[5, 6, 7], 4).unwrap();
        let node = logits_node_name();
        let server = DecodeServer::start(causal(), 16).unwrap();
        let guard = fault::install(FaultPlan {
            panic_decode_node: Some((node, 4)), // first step after prefill
            ..Default::default()
        });
        let rx = server.generate_stream(vec![5, 6, 7], 4);
        let mut tokens = Vec::new();
        let mut err = None;
        for item in rx {
            match item {
                Ok(t) => tokens.push(t),
                Err(e) => {
                    err = Some(e);
                    break;
                }
            }
        }
        drop(guard);
        assert_eq!(tokens, &reference[..1]);
        assert_eq!(err.expect("stream ends in an error").code(), "WorkerPanic");
        assert_eq!(server.generate(vec![5, 6, 7], 4).unwrap(), reference);
        let st = server.stats();
        assert_eq!(st.worker_panics, 1);
        assert_eq!(st.errors, 1);
    }

    /// ISSUE-8 satellite: per-request session teardown is exactly-once —
    /// a typed step failure *resets* the session, a panic *rebuilds* it,
    /// and interleaving the two failure kinds back-to-back never
    /// double-resets, skips a teardown, or leaks a torn session into the
    /// next request.
    #[test]
    fn interleaved_failure_kinds_tear_down_exactly_once() {
        let _g = serial();
        let reference = causal().generate(&[5, 6, 7], 4).unwrap();
        let node = logits_node_name();
        let server = DecodeServer::start(causal(), 16).unwrap();

        // Typed fail → panic → typed fail, each at the first step (a
        // 3-token prompt burns hits 1..=3; hit 4 is step one), each
        // followed by a request that must be bitwise-clean.
        for (round, kind) in ["fail", "panic", "fail"].iter().enumerate() {
            let plan = match *kind {
                "fail" => FaultPlan {
                    fail_decode_node: Some((node.clone(), 4)),
                    ..Default::default()
                },
                _ => FaultPlan {
                    panic_decode_node: Some((node.clone(), 4)),
                    ..Default::default()
                },
            };
            let guard = fault::install(plan);
            let rx = server.generate_stream(vec![5, 6, 7], 4);
            let mut tokens = Vec::new();
            let mut err = None;
            for item in rx {
                match item {
                    Ok(t) => tokens.push(t),
                    Err(e) => {
                        err = Some(e);
                        break;
                    }
                }
            }
            drop(guard);
            assert_eq!(tokens, &reference[..1], "round {round}: one token, then the fault");
            let err = err.expect("faulted stream ends in an error");
            if *kind == "panic" {
                assert_eq!(err.code(), "WorkerPanic", "round {round}");
            } else {
                assert!(err.to_string().contains("injected fault"), "round {round}: {err}");
            }
            assert_eq!(
                server.generate(vec![5, 6, 7], 4).unwrap(),
                reference,
                "round {round}: the request after the fault must be bitwise-clean"
            );
        }
        let st = server.stats();
        assert_eq!(st.errors, 3);
        assert_eq!(st.worker_panics, 1);
        assert_eq!(st.session_rebuilds, 1, "only the panic rebuilds; typed failures reset");
        assert_eq!(st.requests, 6, "3 faulted (past prefill) + 3 clean");
    }

    /// Deadline + stall: a 400 ms deadline over 500 ms steps yields
    /// exactly one token, then DeadlineExceeded — the partial stands.
    #[test]
    fn stalled_steps_hit_the_deadline_with_a_partial_generation() {
        let _g = serial();
        let reference = causal().generate(&[5, 6, 7], 4).unwrap();
        let server = DecodeServer::start_cfg(
            causal(),
            16,
            DecodeConfig { default_deadline: Some(Duration::from_millis(400)), ..DecodeConfig::default() },
        )
        .unwrap();
        // Unfaulted: well inside the deadline.
        assert_eq!(server.generate(vec![5, 6, 7], 4).unwrap(), reference);
        let guard = fault::install(FaultPlan {
            stall_step_ms: Some(500),
            ..Default::default()
        });
        let (tokens, err) =
            server.generate_with_deadline(vec![5, 6, 7], 4, Duration::from_millis(400));
        drop(guard);
        assert_eq!(tokens, &reference[..1], "exactly one token beats the deadline");
        assert_eq!(err.expect("deadline ends the stream").code(), "DeadlineExceeded");
        let st = server.stats();
        assert_eq!(st.deadline_exceeded, 1);
        assert_eq!(st.tokens, 4 + 1, "partial tokens are accounted");
        // Stall cleared: full generations resume.
        assert_eq!(server.generate(vec![5, 6, 7], 4).unwrap(), reference);
    }

    /// The error-then-continue oracle under a *mid-graph* failure: the
    /// failed `step` may have staged early nodes (K/V appends for the
    /// failed position happen before the fault node evaluates), yet
    /// continuing on the same session is bitwise-identical to a fresh
    /// session — stale rows are rewritten before they are ever read.
    #[test]
    fn mid_graph_step_failure_keeps_the_session_consistent() {
        let _g = serial();
        let m = causal();
        let node = logits_node_name();
        let mut faulted = m.decode_session(8).unwrap();
        faulted.prefill(&[5, 6, 7]).unwrap();
        // Installed after prefill, so the step below is the node's first
        // hit under this plan.
        let guard = fault::install(FaultPlan {
            fail_decode_node: Some((node, 1)),
            ..Default::default()
        });
        let e = faulted.step(2).unwrap_err();
        drop(guard);
        assert!(e.to_string().contains("injected fault"), "got: {e}");
        assert_eq!(faulted.len(), 3, "a failed step must not advance the session");
        let after_err: Vec<f32> = faulted.step(2).unwrap().to_vec();

        let mut clean = m.decode_session(8).unwrap();
        clean.prefill(&[5, 6, 7]).unwrap();
        let clean_logits: Vec<f32> = clean.step(2).unwrap().to_vec();
        assert_eq!(after_err, clean_logits, "continue-after-error must be bitwise-clean");
    }

    /// A client hanging up mid-stream is counted as a cancellation and
    /// never disturbs the next request.
    #[test]
    fn mid_stream_hangup_counts_as_cancellation() {
        let _g = serial();
        let reference = causal().generate(&[5, 6, 7], 4).unwrap();
        let server = DecodeServer::start(causal(), 16).unwrap();
        // Slow the steps so the hang-up lands before the next send.
        let guard = fault::install(FaultPlan {
            stall_step_ms: Some(150),
            ..Default::default()
        });
        let rx = server.generate_stream(vec![5, 6, 7], 6);
        let first = rx.recv().unwrap().unwrap();
        assert_eq!(first, reference[0]);
        drop(rx); // hang up while the server sleeps inside step()
        drop(guard);
        // The next request is served normally; the hang-up was counted.
        assert_eq!(server.generate(vec![5, 6, 7], 4).unwrap(), reference);
        let st = server.stats();
        assert_eq!(st.cancelled, 1);
        assert_eq!(st.errors, 0);
    }
}
