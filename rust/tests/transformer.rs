//! ISSUE-4 acceptance: the transformer zoo executes end-to-end.
//!
//! * The attention math (QK^T → scale → softmax → AV) is pinned to a
//!   hand-rolled NumPy-style oracle — both the raw op sequence and the
//!   full `NetBuilder::attention` block (LN + QKV dense + output dense +
//!   residual) with arbitrary random weights.
//! * `gpt2_frontend_layers(1, 2)` and the `"demo-transformer"` zoo model
//!   compile and infer finite, oracle-matching outputs through
//!   `CompiledModel::infer()` across the {fkw, reuse, prepack, workspace,
//!   pool} toggle matrix.
//! * A zoo-wide coverage test asserts every op of every `all_models()`
//!   graph is either executable by `eval_op` or on the explicit
//!   estimate-only allow-list, so new executor gaps fail loudly.

use xgen::api::{Compiler, OptLevel};
use xgen::deepreuse::ReuseConfig;
use xgen::exec::{eval_supported, Executor};
use xgen::graph::zoo::{all_models, by_name, nlp, NetBuilder};
use xgen::graph::{Graph, OpKind, WeightStore};
use xgen::pruning::PruneScheme;
use xgen::tensor::gemm::GemmConfig;
use xgen::tensor::Tensor;
use xgen::util::rng::Rng;

/// Row-major [rows, d] helpers for the hand-rolled oracle.
fn layer_norm_rows(x: &[Vec<f32>]) -> Vec<Vec<f32>> {
    x.iter()
        .map(|row| {
            let d = row.len() as f32;
            let mean: f32 = row.iter().sum::<f32>() / d;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / d;
            let inv = 1.0 / (var + 1e-5).sqrt();
            row.iter().map(|v| (v - mean) * inv).collect()
        })
        .collect()
}

fn matmul_rows(x: &[Vec<f32>], w: &Tensor) -> Vec<Vec<f32>> {
    let (in_f, out_f) = (w.shape()[0], w.shape()[1]);
    x.iter()
        .map(|row| {
            assert_eq!(row.len(), in_f);
            (0..out_f)
                .map(|j| (0..in_f).map(|i| row[i] * w.at(&[i, j])).sum())
                .collect()
        })
        .collect()
}

fn softmax_row(row: &[f32]) -> Vec<f32> {
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let e: Vec<f32> = row.iter().map(|v| (v - mx).exp()).collect();
    let s: f32 = e.iter().sum();
    e.into_iter().map(|v| v / s).collect()
}

/// Scaled-dot-product attention over explicit row vectors:
/// `softmax(Q K^T / sqrt(d_h)) V`.
fn sdpa_rows(q: &[Vec<f32>], k: &[Vec<f32>], v: &[Vec<f32>], dh: usize) -> Vec<Vec<f32>> {
    let l = q.len();
    let scale = 1.0 / (dh as f32).sqrt();
    (0..l)
        .map(|i| {
            let scores: Vec<f32> = (0..l)
                .map(|j| {
                    q[i].iter().zip(&k[j]).map(|(a, b)| a * b).sum::<f32>() * scale
                })
                .collect();
            let p = softmax_row(&scores);
            let d = v[0].len();
            (0..d)
                .map(|t| (0..l).map(|j| p[j] * v[j][t]).sum())
                .collect()
        })
        .collect()
}

/// *Causal* scaled-dot-product attention: query row `i` normalizes over
/// keys `0..=i` only — the NumPy-style ground truth for `CausalMask` +
/// `Softmax`.
fn sdpa_causal_rows(q: &[Vec<f32>], k: &[Vec<f32>], v: &[Vec<f32>], dh: usize) -> Vec<Vec<f32>> {
    let l = q.len();
    let scale = 1.0 / (dh as f32).sqrt();
    (0..l)
        .map(|i| {
            let scores: Vec<f32> = (0..=i)
                .map(|j| {
                    q[i].iter().zip(&k[j]).map(|(a, b)| a * b).sum::<f32>() * scale
                })
                .collect();
            let p = softmax_row(&scores);
            let d = v[0].len();
            (0..d)
                .map(|t| (0..=i).map(|j| p[j] * v[j][t]).sum())
                .collect()
        })
        .collect()
}

fn rows_of(t: &Tensor, b: usize, l: usize, d: usize) -> Vec<Vec<f32>> {
    (0..l)
        .map(|i| t.data()[(b * l + i) * d..(b * l + i) * d + d].to_vec())
        .collect()
}

/// The raw attention op sequence (independent Q/K/V inputs, so the QK^T
/// orientation is observable — with tied inputs the score matrix is
/// symmetric and a transposed-vs-untransposed K would be invisible)
/// matches the hand-rolled oracle.
#[test]
fn attention_core_matches_numpy_style_oracle() {
    let (n, l, d, heads) = (2usize, 5usize, 8usize, 2usize);
    let dh = d / heads;
    let mut g = Graph::new("attn-core");
    let q = g.input("q", &[n, l, d]);
    let k = g.input("k", &[n, l, d]);
    let v = g.input("v", &[n, l, d]);
    let kt = g.add("kt", OpKind::Transpose { perm: vec![0, 2, 1] }, vec![k], vec![n, d, l]);
    let scores = g.add("qk", OpKind::MatMul, vec![q, kt], vec![n, l, l]);
    let scaled = g.add(
        "scale",
        OpKind::Scale { mul: 1.0 / (dh as f64).sqrt(), add: 0.0 },
        vec![scores],
        vec![n, l, l],
    );
    let probs = g.add("softmax", OpKind::Softmax, vec![scaled], vec![n, l, l]);
    let ctx = g.add("av", OpKind::MatMul, vec![probs, v], vec![n, l, d]);
    g.outputs = vec![ctx];
    assert!(g.validate().is_ok(), "{:?}", g.validate());

    let mut rng = Rng::new(41);
    let qt = Tensor::randn(&[n, l, d], 1.0, &mut rng);
    let ktn = Tensor::randn(&[n, l, d], 1.0, &mut rng);
    let vt = Tensor::randn(&[n, l, d], 1.0, &mut rng);
    let got = Executor::new(&g, &WeightStore::new())
        .run(&[qt.clone(), ktn.clone(), vt.clone()])
        .unwrap();
    assert_eq!(got[0].shape(), &[n, l, d]);
    for b in 0..n {
        let want = sdpa_rows(
            &rows_of(&qt, b, l, d),
            &rows_of(&ktn, b, l, d),
            &rows_of(&vt, b, l, d),
            dh,
        );
        for i in 0..l {
            for t in 0..d {
                let diff = (got[0].at(&[b, i, t]) - want[i][t]).abs();
                assert!(diff < 1e-4, "attention[{b},{i},{t}] off by {diff}");
            }
        }
    }
}

/// The full `NetBuilder::attention` block — LN, Q/K/V dense, QK^T, scale,
/// softmax, AV, output dense, residual — with *random* weights matches a
/// hand-rolled oracle that reads the same weights out of the store. This
/// is the regression test for the builder emitting `MatMul(q, k)` without
/// transposing K.
#[test]
fn netbuilder_attention_block_matches_oracle() {
    let (n, l, d, heads) = (1usize, 6usize, 8usize, 2usize);
    let mut b = NetBuilder::new("attn-block", &[n, l, d]);
    b.attention(heads, false);
    let g = b.finish();
    assert!(g.validate().is_ok(), "{:?}", g.validate());
    let mut rng = Rng::new(42);
    let ws = WeightStore::init_random(&g, &mut rng);
    let x = Tensor::randn(&[n, l, d], 1.0, &mut rng);
    let got = Executor::new(&g, &ws).run(&[x.clone()]).unwrap();

    // Navigate the block structurally: qk = MatMul(q_dense, Transpose(k_dense)),
    // av = MatMul(softmax, v_dense), out_dense consumes av.
    let matmuls: Vec<_> = g.nodes.iter().filter(|nn| matches!(nn.op, OpKind::MatMul)).collect();
    assert_eq!(matmuls.len(), 2);
    let (qk, av) = (matmuls[0], matmuls[1]);
    let kt = g.node(qk.inputs[1]);
    assert!(
        matches!(kt.op, OpKind::Transpose { ref perm } if perm == &vec![0, 2, 1]),
        "QK^T must consume an explicitly transposed K, got {:?}",
        kt.op
    );
    let weight_of = |id: usize| {
        let wid = g
            .node(id)
            .inputs
            .iter()
            .copied()
            .find(|&i| matches!(g.node(i).op, OpKind::Weight))
            .unwrap();
        ws.get(&g.node(wid).name).unwrap()
    };
    let qd = qk.inputs[0];
    let kd = kt.inputs[0];
    let vd = av.inputs[1];
    let od = g
        .nodes
        .iter()
        .find(|nn| matches!(nn.op, OpKind::Dense) && nn.inputs.contains(&av.id))
        .unwrap()
        .id;
    let ln_id = g.data_input(qd).unwrap();
    let lnw = weight_of(ln_id);

    // Hand-rolled oracle over row vectors.
    let xr = rows_of(&x, 0, l, d);
    let mut h = layer_norm_rows(&xr);
    for row in h.iter_mut() {
        for (i, v) in row.iter_mut().enumerate() {
            *v = *v * lnw.at(&[0, i]) + lnw.at(&[1, i]);
        }
    }
    let qrows = matmul_rows(&h, weight_of(qd));
    let krows = matmul_rows(&h, weight_of(kd));
    let vrows = matmul_rows(&h, weight_of(vd));
    let ctx = sdpa_rows(&qrows, &krows, &vrows, d / heads);
    let orows = matmul_rows(&ctx, weight_of(od));
    for i in 0..l {
        for t in 0..d {
            let want = xr[i][t] + orows[i][t];
            let diff = (got[0].at(&[0, i, t]) - want).abs();
            assert!(diff < 1e-3, "attention block [{i},{t}] off by {diff}");
        }
    }
}

/// Masked-softmax unit oracle (ISSUE-5 satellite): `CausalMask → Softmax`
/// through the reference executor against a hand-rolled loop, including
/// the seq=1 and seq=max_seq edge cases. Pins: unmasked prefixes sum to
/// 1, masked positions contribute *exactly* 0, and the kernel matches
/// per-element NumPy-style math.
#[test]
fn causal_masked_softmax_matches_hand_rolled_oracle() {
    let mut rng = Rng::new(0xCA);
    for l in [1usize, 2, 5, 32] {
        let (n, h) = (2usize, 3usize);
        let mut g = Graph::new("masked-softmax");
        let x = g.input("scores", &[n, h, l, l]);
        let m = g.add("mask", OpKind::CausalMask, vec![x], vec![n, h, l, l]);
        let p = g.add("probs", OpKind::Softmax, vec![m], vec![n, h, l, l]);
        g.outputs = vec![p];
        assert!(g.validate().is_ok(), "{:?}", g.validate());
        let xt = Tensor::randn(&[n, h, l, l], 1.0, &mut rng);
        let y = Executor::new(&g, &WeightStore::new()).run(&[xt.clone()]).unwrap();
        for b in 0..n {
            for hh in 0..h {
                for i in 0..l {
                    // Hand-rolled masked row: softmax over columns 0..=i.
                    let raw: Vec<f32> = (0..=i).map(|j| xt.at(&[b, hh, i, j])).collect();
                    let want = softmax_row(&raw);
                    let mut sum = 0.0f32;
                    for j in 0..l {
                        let got = y[0].at(&[b, hh, i, j]);
                        sum += got;
                        if j > i {
                            assert_eq!(got, 0.0, "masked [{b},{hh},{i},{j}] contributes");
                        } else {
                            let d = (got - want[j]).abs();
                            assert!(d < 1e-6, "probs[{b},{hh},{i},{j}] off by {d}");
                        }
                    }
                    assert!((sum - 1.0).abs() < 1e-5, "row [{b},{hh},{i}] sums to {sum}");
                }
            }
        }
    }
}

/// The causal attention core (mask between scale and softmax) matches the
/// causal NumPy-style oracle — and position 0 (a 1-long prefix) gets
/// probability exactly 1 on itself.
#[test]
fn causal_attention_core_matches_oracle() {
    let (n, l, d, heads) = (2usize, 7usize, 8usize, 2usize);
    let dh = d / heads;
    let mut g = Graph::new("causal-attn-core");
    let q = g.input("q", &[n, l, d]);
    let k = g.input("k", &[n, l, d]);
    let v = g.input("v", &[n, l, d]);
    let kt = g.add("kt", OpKind::Transpose { perm: vec![0, 2, 1] }, vec![k], vec![n, d, l]);
    let scores = g.add("qk", OpKind::MatMul, vec![q, kt], vec![n, l, l]);
    let scaled = g.add(
        "scale",
        OpKind::Scale { mul: 1.0 / (dh as f64).sqrt(), add: 0.0 },
        vec![scores],
        vec![n, l, l],
    );
    let masked = g.add("mask", OpKind::CausalMask, vec![scaled], vec![n, l, l]);
    let probs = g.add("softmax", OpKind::Softmax, vec![masked], vec![n, l, l]);
    let ctx = g.add("av", OpKind::MatMul, vec![probs, v], vec![n, l, d]);
    g.outputs = vec![ctx];
    assert!(g.validate().is_ok(), "{:?}", g.validate());

    let mut rng = Rng::new(43);
    let qt = Tensor::randn(&[n, l, d], 1.0, &mut rng);
    let ktn = Tensor::randn(&[n, l, d], 1.0, &mut rng);
    let vt = Tensor::randn(&[n, l, d], 1.0, &mut rng);
    let got = Executor::new(&g, &WeightStore::new())
        .run(&[qt.clone(), ktn.clone(), vt.clone()])
        .unwrap();
    for b in 0..n {
        let want = sdpa_causal_rows(
            &rows_of(&qt, b, l, d),
            &rows_of(&ktn, b, l, d),
            &rows_of(&vt, b, l, d),
            dh,
        );
        for i in 0..l {
            for t in 0..d {
                let diff = (got[0].at(&[b, i, t]) - want[i][t]).abs();
                assert!(diff < 1e-4, "causal attention[{b},{i},{t}] off by {diff}");
            }
        }
        // Row 0 can only attend to itself: its context row is exactly v[0].
        for t in 0..d {
            let diff = (got[0].at(&[b, 0, t]) - vt.at(&[b, 0, t])).abs();
            assert!(diff < 1e-6, "position 0 must copy v[0], off by {diff}");
        }
    }
}

/// The last row of a masked full-sequence attention equals the
/// single-step cache path: the newest query against *all* cached keys
/// with an unmasked softmax — the identity the KV-cache decoder relies
/// on. Checked at seq=1 (trivial) and seq=max.
#[test]
fn masked_full_seq_last_row_equals_single_step_cache_path() {
    let mut rng = Rng::new(44);
    for l in [1usize, 6, 32] {
        let (d, dh) = (8usize, 4usize);
        let q: Vec<Vec<f32>> =
            (0..l).map(|_| Tensor::randn(&[d], 1.0, &mut rng).into_vec()).collect();
        let k: Vec<Vec<f32>> =
            (0..l).map(|_| Tensor::randn(&[d], 1.0, &mut rng).into_vec()).collect();
        let v: Vec<Vec<f32>> =
            (0..l).map(|_| Tensor::randn(&[d], 1.0, &mut rng).into_vec()).collect();
        let full = sdpa_causal_rows(&q, &k, &v, dh);

        // Cache path: the last query row, every key allowed, no mask.
        let scale = 1.0 / (dh as f32).sqrt();
        let scores: Vec<f32> = (0..l)
            .map(|j| q[l - 1].iter().zip(&k[j]).map(|(a, b)| a * b).sum::<f32>() * scale)
            .collect();
        let p = softmax_row(&scores);
        let step: Vec<f32> = (0..d)
            .map(|t| (0..l).map(|j| p[j] * v[j][t]).sum())
            .collect();
        for t in 0..d {
            let diff = (full[l - 1][t] - step[t]).abs();
            assert!(diff < 1e-5, "l={l}: cache path diverges at {t} by {diff}");
        }
    }
}

/// Every op of every zoo model is either executable by `eval_op` or on
/// the explicit estimate-only allow-list. Growing the zoo with an op the
/// executor cannot run (and that is not consciously allow-listed) fails
/// here, loudly, instead of at some user's runtime.
#[test]
fn zoo_ops_are_executable_or_explicitly_estimate_only() {
    // Cost-model-only ops: 3-D conv (video), transposed conv (U-Net /
    // GAN upsampling), channel shuffle, detection post-processing. The
    // RoI/scatter `Gather` forms some detection models use are accepted
    // at the kind level but error at runtime with a "row-lookup form"
    // message — they ride on the PostProcess allowance conceptually.
    let allow = ["conv3d", "conv_transpose2d", "channel_shuffle", "post_process"];
    for name in all_models() {
        let g = by_name(name, 1);
        for n in &g.nodes {
            if n.op.is_source() {
                continue;
            }
            assert!(
                eval_supported(&n.op) || allow.contains(&n.op.name()),
                "{name}: op '{}' (node {}) has no executor kernel and no \
                 estimate-only allowance",
                n.op.name(),
                n.id
            );
        }
    }
}

/// Shared matrix driver: compile `graph` under one toggle config, infer on
/// `xs`, compare against `oracle` (straight-line Executor on the same
/// rewritten graph + weights).
#[allow(clippy::too_many_arguments)]
fn check_config(
    graph: Graph,
    seed: u64,
    xs: &[Tensor],
    oracle: &Tensor,
    fkw: bool,
    reuse: bool,
    prepack: bool,
    workspace: bool,
    pool: bool,
    label: &str,
) {
    let mut c = Compiler::new(graph)
        .random_weights(seed)
        .fkw(fkw)
        .prepack(prepack)
        .workspace(workspace)
        .gemm_config(GemmConfig { threads: if pool { 0 } else { 1 }, ..Default::default() });
    if reuse {
        c = c.reuse_config(ReuseConfig { hash_bits: 12, max_rel_dev: 0.02, ..Default::default() });
    }
    let m = c.compile().unwrap();
    let y = m.infer(xs).unwrap();
    assert_eq!(y[0].shape(), oracle.shape(), "{label}: shape");
    assert!(
        y[0].data().iter().all(|v| v.is_finite()),
        "{label}: non-finite outputs"
    );
    if reuse {
        // Deep reuse is an approximation by design: bounded relative MAD.
        let scale = oracle.data().iter().map(|v| v.abs()).sum::<f32>() / oracle.len() as f32;
        let rel = y[0].mad(oracle) / scale.max(1e-6);
        assert!(rel < 0.25, "{label}: reuse rel err {rel}");
    } else {
        let d = y[0].max_abs_diff(oracle);
        assert!(d < 1e-3, "{label}: max abs diff {d}");
    }
}

/// ISSUE-4 headline acceptance: the exporter-style 2-layer GPT-2 frontend
/// dump (per-head Reshape/Transpose, rank-4 QK^T, Sqrt/Div scaling,
/// decomposed GELU) compiles and infers finite, oracle-matching outputs
/// across the full steady-state toggle matrix.
#[test]
fn gpt2_frontend_two_layers_infers_across_toggle_matrix() {
    let seed = 2024u64;
    // Oracle once: graph/weights after compile are identical across the
    // toggles (they only change the execution engine, never the graph).
    let base = Compiler::new(nlp::gpt2_frontend_layers(1, 2))
        .random_weights(seed)
        .compile()
        .unwrap();
    let xs = base.sample_inputs(7);
    let oracle = Executor::new(base.graph(), base.weights().unwrap())
        .run(&xs)
        .unwrap()
        .remove(0);
    assert_eq!(oracle.shape(), &[1, 384, 768]);
    assert!(oracle.data().iter().all(|v| v.is_finite()), "oracle non-finite");
    // The 50k-vocab embedding table dominates the session footprint —
    // don't keep the oracle session alive while the matrix runs.
    drop(base);

    // (fkw, reuse, prepack, workspace, pool) — default plus one flip each.
    for (fkw, reuse, prepack, workspace, pool) in [
        (true, false, true, true, true),
        (false, false, true, true, true),
        (true, false, false, true, true),
        (true, false, true, false, true),
        (true, false, true, true, false),
        (true, true, true, true, true),
    ] {
        check_config(
            nlp::gpt2_frontend_layers(1, 2),
            seed,
            &xs,
            &oracle,
            fkw,
            reuse,
            prepack,
            workspace,
            pool,
            &format!("gpt2-frontend fkw={fkw} reuse={reuse} prepack={prepack} ws={workspace} pool={pool}"),
        );
    }
}

/// The demo-transformer zoo model (embedding → 2 encoder layers → [CLS]
/// slice → classifier) across the *full* toggle matrix, plus prune
/// schemes — it is small enough to sweep everything.
#[test]
fn demo_transformer_infers_across_full_toggle_matrix() {
    let seed = 77u64;
    let base = Compiler::for_model("demo-transformer", 1)
        .unwrap()
        .random_weights(seed)
        .compile()
        .unwrap();
    let xs = base.sample_inputs(3);
    let oracle = Executor::new(base.graph(), base.weights().unwrap())
        .run(&xs)
        .unwrap()
        .remove(0);
    assert_eq!(oracle.shape(), &[1, 8]);
    for fkw in [false, true] {
        for reuse in [false, true] {
            for prepack in [false, true] {
                for workspace in [false, true] {
                    for pool in [false, true] {
                        check_config(
                            by_name("demo-transformer", 1),
                            seed,
                            &xs,
                            &oracle,
                            fkw,
                            reuse,
                            prepack,
                            workspace,
                            pool,
                            &format!(
                                "demo-transformer fkw={fkw} reuse={reuse} \
                                 prepack={prepack} ws={workspace} pool={pool}"
                            ),
                        );
                    }
                }
            }
        }
    }
    // Pruned sessions still execute and stay finite (block fallback on
    // dense weights; the embedding table is never pruned).
    for scheme in [
        PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.3 },
        PruneScheme::Block { block: 4, rate: 0.5 },
    ] {
        let m = Compiler::for_model("demo-transformer", 1)
            .unwrap()
            .random_weights(seed)
            .scheme(scheme.clone())
            .compile()
            .unwrap();
        let y = m.infer(&xs).unwrap();
        assert!(
            y[0].data().iter().all(|v| v.is_finite()),
            "{scheme:?}: non-finite"
        );
        let oracle = Executor::new(m.graph(), m.weights().unwrap()).run(&xs).unwrap();
        let d = y[0].max_abs_diff(&oracle[0]);
        assert!(d < 1e-3, "{scheme:?}: diff {d}");
    }
}

/// Opt levels O0–O3 agree numerically on the transformer (O0 executes the
/// raw movement ops; O1+ rewrites Sqrt/Div scaling into Scale, folds the
/// decomposed GELU, collapses transpose chains) and batch 2 works.
#[test]
fn demo_transformer_opt_levels_agree_and_batch_scales() {
    let mut outs = Vec::new();
    for opt in [OptLevel::O0, OptLevel::O1, OptLevel::O2, OptLevel::O3] {
        let m = Compiler::for_model("demo-transformer", 1)
            .unwrap()
            .random_weights(5)
            .opt_level(opt)
            .compile()
            .unwrap();
        let xs = m.sample_inputs(11);
        outs.push((opt, m.infer(&xs).unwrap()));
    }
    for w in outs.windows(2) {
        let d = w[0].1[0].max_abs_diff(&w[1].1[0]);
        assert!(d < 1e-3, "{:?} vs {:?}: diff {d}", w[0].0, w[1].0);
    }

    let m = Compiler::for_model("demo-transformer", 2)
        .unwrap()
        .random_weights(5)
        .compile()
        .unwrap();
    let xs = m.sample_inputs(13);
    assert_eq!(xs[0].shape(), &[2, 32]);
    let y = m.infer(&xs).unwrap();
    assert_eq!(y[0].shape(), &[2, 8]);
    assert!(y[0].data().iter().all(|v| v.is_finite()));
}

/// `sample_inputs` produces valid token ids for embedding-fed inputs and
/// Gaussians elsewhere; invalid ids are a loud executor error (not a
/// clamp), pinning the embedding kernel's bounds checking.
#[test]
fn sample_inputs_are_valid_token_ids_and_bad_ids_error() {
    let m = Compiler::for_model("demo-transformer", 1)
        .unwrap()
        .random_weights(1)
        .compile()
        .unwrap();
    let xs = m.sample_inputs(9);
    assert_eq!(xs.len(), 1);
    assert!(xs[0]
        .data()
        .iter()
        .all(|&v| v >= 0.0 && v < 256.0 && v.fract() == 0.0));
    // Out-of-vocab ids error instead of silently clamping.
    let bad = Tensor::full(&[1, 32], 1e6);
    assert!(m.infer(&[bad]).is_err());

    let cnn = Compiler::for_model("demo-cnn", 1).unwrap().random_weights(1).compile().unwrap();
    let xs = cnn.sample_inputs(9);
    assert_eq!(xs[0].shape(), &[1, 3, 24, 24]);
}
