//! ISSUE-10 acceptance: int8 quantized inference end-to-end.
//!
//! * Toggle matrix: `quantize(auto)` on the demo CNN and both demo
//!   transformers at O0–O3 stays within the quantization error envelope
//!   of the f32 baseline (matched accuracy, relative L2), and `auto`
//!   provably selects its int8 layers from the compile-time `QuantPlan`
//!   (every int8 layer has a `feasible` plan entry).
//! * Scale agreement: the per-channel dequant scales the executor packed
//!   (`CompiledModel::int8_scales`) equal the plan's `channel_scales`
//!   **bitwise** — both sides derive from the same
//!   `quantize_gemm_weight` normalization, by construction.
//! * Decode oracle: a `quantize(auto)` causal decoder still opens an
//!   (f32) `DecodeSession`, and its incremental logits match the
//!   mixed-precision full forward within the quant envelope — decode
//!   works unchanged on mixed-precision plans.
//! * `force` quantizes every packable contraction layer; engine toggles
//!   (workspace off, prepack off, planner off) keep working and the
//!   precision report blames skipped layers truthfully.

use xgen::api::{CompiledModel, Compiler, OptLevel, QuantPolicy};
use xgen::graph::OpKind;
use xgen::pruning::PruneScheme;
use xgen::tensor::Tensor;

/// Relative L2 distance — statistically stable under quantization noise,
/// unlike per-element max error.
fn rel_l2(want: &[f32], got: &[f32]) -> f32 {
    assert_eq!(want.len(), got.len());
    let num: f32 = want.iter().zip(got).map(|(a, b)| (a - b) * (a - b)).sum();
    let den: f32 = want.iter().map(|a| a * a).sum();
    (num / den.max(1e-12)).sqrt()
}

fn compile(model: &str, opt: OptLevel, policy: QuantPolicy) -> CompiledModel {
    Compiler::for_model(model, 1)
        .unwrap()
        .random_weights(17)
        .scheme(PruneScheme::None)
        .opt_level(opt)
        .quantize(policy)
        .compile()
        .unwrap()
}

/// Matched accuracy: int8-under-`auto` against the f32 baseline within
/// the symmetric-quantization error envelope, across the zoo demos and
/// every opt level.
#[test]
fn quantize_auto_matches_f32_across_models_and_opt_levels() {
    for model in ["demo-cnn", "demo-transformer", "demo-transformer-causal"] {
        for opt in [OptLevel::O0, OptLevel::O1, OptLevel::O2, OptLevel::O3] {
            let f32_m = compile(model, opt, QuantPolicy::Off);
            let q_m = compile(model, opt, QuantPolicy::Auto);
            let xs = f32_m.sample_inputs(5);
            let want = f32_m.infer(&xs).unwrap();
            let got = q_m.infer(&xs).unwrap();
            let e = rel_l2(want[0].data(), got[0].data());
            assert!(
                e < 0.25,
                "{model}@{}: quantize(auto) diverged from f32 (rel L2 {e})",
                opt.name()
            );
            assert!(got[0].data().iter().all(|v| v.is_finite()), "{model}: non-finite int8 output");

            // Auto selects *from the plan*: every int8 layer has a
            // feasible QuantPlan entry (Auto forces the analysis on, so
            // the plan exists even at O0/O1).
            let r = q_m.report();
            assert_eq!(r.quant_policy, QuantPolicy::Auto);
            assert!(!r.precision.is_empty(), "{model}: no contraction layers reported");
            let plan = &r.analysis.as_ref().expect("auto forces analysis").quant;
            for l in r.precision.iter().filter(|l| l.int8) {
                let p = plan.layers.iter().find(|p| p.node == l.node);
                assert!(
                    p.is_some_and(|p| p.feasible),
                    "{model}: int8 layer {} not feasible in the QuantPlan",
                    l.name
                );
            }
            for l in r.precision.iter().filter(|l| !l.int8) {
                assert!(l.reason.is_some(), "{model}: f32 layer {} carries no reason", l.name);
            }
        }
    }
}

/// The compile-time plan's per-channel scales and the scales the executor
/// actually packed agree bitwise — one normalization helper feeds both.
#[test]
fn packed_scales_agree_with_quant_plan_bitwise() {
    let m = compile("demo-cnn", OptLevel::O2, QuantPolicy::Auto);
    let plan = &m.report().analysis.as_ref().unwrap().quant;
    let mut checked = 0usize;
    for l in &plan.layers {
        if let Some(scales) = m.int8_scales(l.node) {
            assert_eq!(
                scales,
                l.channel_scales.as_slice(),
                "{}: packed scales != plan scales (must be bitwise)",
                l.name
            );
            checked += 1;
        }
    }
    assert!(checked > 0, "no layer was int8-packed under auto on demo-cnn");
}

/// `force` packs every eligible Dense/conv (scheme none, no reuse): the
/// precision report shows all-contraction int8 and a summary line.
#[test]
fn force_policy_quantizes_every_packable_layer() {
    let m = compile("demo-cnn", OptLevel::O2, QuantPolicy::Force);
    let r = m.report();
    assert_eq!(r.quant_policy, QuantPolicy::Force);
    assert!(!r.precision.is_empty());
    for l in &r.precision {
        assert!(l.int8, "force left {} ({}) in f32: {:?}", l.name, l.op, l.reason);
    }
    assert_eq!(r.int8_layer_count(), r.precision.len());
    assert!(r.summary().contains("quant[force]"), "summary misses the quant line");

    // And the numerics stay in the envelope.
    let f32_m = compile("demo-cnn", OptLevel::O2, QuantPolicy::Off);
    let xs = f32_m.sample_inputs(3);
    let want = f32_m.infer(&xs).unwrap();
    let got = m.infer(&xs).unwrap();
    let e = rel_l2(want[0].data(), got[0].data());
    assert!(e < 0.25, "force diverged from f32 (rel L2 {e})");
}

/// Quantized attention: under `force` the transformer's MatMul layers
/// (QK^T / AV) run the dynamically-quantizing int8 path around the f32
/// masked softmax — the report lists them int8.
#[test]
fn force_quantizes_attention_matmuls() {
    let m = compile("demo-transformer", OptLevel::O2, QuantPolicy::Force);
    let matmuls: Vec<_> = m
        .report()
        .precision
        .iter()
        .filter(|l| matches!(m.graph().node(l.node).op, OpKind::MatMul))
        .collect();
    assert!(!matmuls.is_empty(), "demo-transformer has attention MatMuls");
    for l in &matmuls {
        assert!(l.int8, "attention contraction {} stayed f32", l.name);
        // Dynamic quantization has no packed side table.
        assert!(m.int8_scales(l.node).is_none(), "{}: MatMul must not pack scales", l.name);
    }
}

/// Engine toggles under a quantized session: workspace-off (fused Tensor
/// engine int8 arms) matches the steady arena engine; prepack-off and
/// planner-off degrade to f32 with truthful reasons and unchanged
/// numerics vs the f32 baseline.
#[test]
fn quantized_engine_toggles_agree() {
    let xs = compile("demo-cnn", OptLevel::O2, QuantPolicy::Off).sample_inputs(9);

    let steady = compile("demo-cnn", OptLevel::O2, QuantPolicy::Force);
    let want = steady.infer(&xs).unwrap();

    // Fused Tensor engine (workspace off) runs the same int8 kernels.
    let fused = Compiler::for_model("demo-cnn", 1)
        .unwrap()
        .random_weights(17)
        .scheme(PruneScheme::None)
        .workspace(false)
        .quantize(QuantPolicy::Force)
        .compile()
        .unwrap();
    let got = fused.infer(&xs).unwrap();
    let e = rel_l2(want[0].data(), got[0].data());
    assert!(e < 1e-4, "fused int8 engine != steady int8 engine (rel L2 {e})");

    // Prepack off: no int8 side table can exist; layers degrade to f32
    // and say so.
    let nopack = Compiler::for_model("demo-cnn", 1)
        .unwrap()
        .random_weights(17)
        .scheme(PruneScheme::None)
        .prepack(false)
        .quantize(QuantPolicy::Force)
        .compile()
        .unwrap();
    assert_eq!(nopack.report().int8_layer_count(), 0);
    for l in &nopack.report().precision {
        assert_eq!(l.reason.as_deref(), Some("prepack-off"), "{}", l.name);
    }
    assert!(nopack.infer(&xs).is_ok());

    // Planner off: the reference executor runs pure f32.
    let noplan = Compiler::for_model("demo-cnn", 1)
        .unwrap()
        .random_weights(17)
        .scheme(PruneScheme::None)
        .memory_planner(false)
        .quantize(QuantPolicy::Force)
        .compile()
        .unwrap();
    assert_eq!(noplan.report().int8_layer_count(), 0);
    for l in &noplan.report().precision {
        assert_eq!(l.reason.as_deref(), Some("planner-off"), "{}", l.name);
    }
    let f32_want = compile("demo-cnn", OptLevel::O2, QuantPolicy::Off).infer(&xs).unwrap();
    let y = noplan.infer(&xs).unwrap();
    assert!(rel_l2(f32_want[0].data(), y[0].data()) < 1e-5, "planner-off must stay f32");
}

/// Decode on a mixed-precision plan: the (always-f32) `DecodeSession` of
/// a `quantize(auto)` causal decoder matches the quantized full forward
/// within the quantization envelope at every prompt position — the int8
/// side tables don't disturb the decode path.
#[test]
fn quantized_causal_decode_matches_full_forward_oracle() {
    let m = compile("demo-transformer-causal", OptLevel::O2, QuantPolicy::Auto);
    let prompt: [u32; 6] = [3, 1, 4, 1, 5, 9];

    // Full forward: first `prompt.len()` positions of the fixed-length
    // causal graph over the padded prompt (padding only affects later
    // rows).
    let shape = m.input_shapes()[0].clone(); // [1, S]
    let s = shape[1];
    let mut ids = vec![0.0f32; s];
    for (i, &t) in prompt.iter().enumerate() {
        ids[i] = t as f32;
    }
    let y = m.infer(&[Tensor::from_vec(&shape, ids)]).unwrap();
    let row = y[0].len() / s;

    let mut sess = m.decode_session(prompt.len()).unwrap();
    for (i, &t) in prompt.iter().enumerate() {
        let logits = sess.step(t).unwrap();
        let want = &y[0].data()[i * row..(i + 1) * row];
        let e = rel_l2(want, logits);
        assert!(
            e < 0.25,
            "decode diverges from mixed-precision full forward at {i} (rel L2 {e})"
        );
        assert!(logits.iter().all(|v| v.is_finite()));
    }
    // Greedy generation still runs end-to-end on the quantized session.
    let toks = m.generate(&[3, 1, 4], 4).unwrap();
    assert_eq!(toks.len(), 4);
    assert!(toks.iter().all(|&t| (t as usize) < 256));
}

/// Policy spellings round-trip and `off` is the default (empty report).
#[test]
fn quant_policy_parse_and_default_off() {
    for (s, p) in [
        ("off", QuantPolicy::Off),
        ("force", QuantPolicy::Force),
        ("auto", QuantPolicy::Auto),
    ] {
        assert_eq!(QuantPolicy::parse(s), Some(p));
        assert_eq!(QuantPolicy::parse(p.name()), Some(p));
    }
    assert_eq!(QuantPolicy::parse("int4"), None);

    let m = compile("demo-cnn", OptLevel::O2, QuantPolicy::Off);
    assert_eq!(m.report().quant_policy, QuantPolicy::Off);
    assert!(m.report().precision.is_empty());
    assert!(!m.report().summary().contains("quant["));
    assert!(m.int8_scales(0).is_none());
}
