//! ISSUE-7 acceptance for `xgen::verify` — the static soundness checkers.
//!
//! Positive half: every zoo model, at every fusion level the pipeline can
//! produce (O0/O1 straight-line, O2 default, O3 aggressive), passes the
//! deep graph check, the fusion-order invariant, the liveness replay over
//! the memory plan, and the arena disjointness proof — with and without
//! weights, across the fkw/reuse/prepack/workspace/threads toggle matrix
//! on the demo models (full `Compiler::compile` with `.verify(true)`).
//!
//! Negative half: mutation tests. Each one corrupts a *valid* compiled
//! artifact the way a buggy pass would — reordering the schedule,
//! shrinking a slot, aliasing two live values, overlapping arena regions,
//! breaking the fused topological order — and asserts the checker rejects
//! it with the right typed code (`InvalidGraph` / `InvalidPlan`) and a
//! message naming the pass and the offending node / slot / region.

use xgen::api::{Compiler, OptLevel};
use xgen::baselines::no_fusion;
use xgen::exec::{ExecState, MemoryPlan, WorkspaceSpec};
use xgen::fusion::{fuse, FusionConfig, FusionPlan};
use xgen::graph::zoo::{all_models, by_name};
use xgen::graph::Graph;
use xgen::pruning::PruneScheme;
use xgen::tensor::gemm::GemmConfig;
use xgen::verify::{arena_regions, check_compiled, check_fusion, check_plan, check_regions};

/// The three fusion shapes `Compiler::compile` can produce, labeled by
/// the opt levels that select them.
fn fusion_variants(g: &Graph) -> Vec<(&'static str, FusionPlan)> {
    vec![
        ("O0/O1", no_fusion(g)),
        ("O2", fuse(g, &FusionConfig::default())),
        ("O3", fuse(g, &FusionConfig { profile_threshold_bytes: 4 * 1024, max_group_size: 32 })),
    ]
}

/// Every zoo model × every fusion level verifies clean, structurally
/// (no weights: graph, fusion order, liveness, arena — the parts that
/// exist before `random_weights`).
#[test]
fn zoo_verifies_clean_at_every_opt_level() {
    for name in all_models() {
        let g = by_name(name, 1);
        for (label, plan) in fusion_variants(&g) {
            let st = ExecState::new(&g, &plan);
            let rep = check_compiled(&g, None, &plan, &st, "plan")
                .unwrap_or_else(|e| panic!("{name} at {label}: {e}"));
            assert_eq!(rep.nodes, g.nodes.len(), "{name} at {label}");
            assert!(rep.slots > 0, "{name} at {label}: no slots planned");
        }
    }
}

/// The arena layout stays disjoint whatever the thread count resolves to
/// (the per-thread GEMM scratch bands are the regions that scale).
#[test]
fn zoo_arenas_are_disjoint_across_thread_counts() {
    for name in all_models() {
        let g = by_name(name, 1);
        let plan = fuse(&g, &FusionConfig::default());
        let st = ExecState::new(&g, &plan);
        for threads in [1usize, 4] {
            let cfg = GemmConfig { threads, ..Default::default() };
            let (regions, total) = arena_regions(st.workspace_spec(), &cfg);
            check_regions(&regions, total, "plan")
                .unwrap_or_else(|e| panic!("{name} at {threads} threads: {e}"));
            assert_eq!(total as u64 * 4, st.workspace_spec().bytes(&cfg), "{name}");
        }
    }
}

fn demo_compiler(model: &str, opt: OptLevel) -> Compiler {
    let scheme = if model == "demo-cnn" {
        PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.3 }
    } else {
        PruneScheme::None
    };
    Compiler::for_model(model, 1)
        .expect("demo model exists")
        .random_weights(7)
        .scheme(scheme)
        .opt_level(opt)
        .verify(true)
}

/// Full weighted compiles through the session API with the verifier
/// forced on: every demo model × O0–O3, plus the engine toggle matrix at
/// O2 (fkw off, deep reuse on, prepacking off, shared workspace off,
/// single-thread GEMM). All four pipeline hooks must report clean.
#[test]
fn demo_compile_matrix_verifies_with_toggles() {
    let models = ["demo-cnn", "demo-transformer", "demo-transformer-causal"];
    for model in models {
        for opt in [OptLevel::O0, OptLevel::O1, OptLevel::O2, OptLevel::O3] {
            let cm = demo_compiler(model, opt).compile().unwrap_or_else(|e| {
                panic!("{model} at {opt:?}: {e}");
            });
            let rep = cm.report().verify.as_ref().expect("verify(true) records a report");
            assert_eq!(rep.passes, ["rewrite", "prune", "fuse", "plan"], "{model} at {opt:?}");
            assert!(rep.slots > 0, "{model} at {opt:?}");
            assert!(cm.report().summary().contains("verify:"), "{model} at {opt:?}");
        }
        let toggles: Vec<(&str, Compiler)> = vec![
            ("no-fkw", demo_compiler(model, OptLevel::O2).fkw(false)),
            ("reuse", demo_compiler(model, OptLevel::O2).deep_reuse(true)),
            ("no-prepack", demo_compiler(model, OptLevel::O2).prepack(false)),
            ("no-workspace", demo_compiler(model, OptLevel::O2).workspace(false)),
            (
                "threads-1",
                demo_compiler(model, OptLevel::O2)
                    .gemm_config(GemmConfig { threads: 1, ..Default::default() }),
            ),
        ];
        for (label, c) in toggles {
            let cm = c.compile().unwrap_or_else(|e| panic!("{model} [{label}]: {e}"));
            let rep = cm.report().verify.as_ref().expect("verify report");
            assert_eq!(rep.passes.last().map(String::as_str), Some("plan"), "{model} [{label}]");
        }
    }
}

/// With the memory planner off there is no plan to verify; the report
/// still records the graph-stage passes.
#[test]
fn planner_off_verifies_graph_stages_only() {
    let cm = demo_compiler("demo-cnn", OptLevel::O2)
        .memory_planner(false)
        .compile()
        .expect("planner-off compile");
    let rep = cm.report().verify.as_ref().expect("verify report");
    assert_eq!(rep.passes, ["rewrite", "prune", "fuse"]);
    assert_eq!(rep.slots, 0);
}

// --------------------------------------------------------------------
// Mutation negatives: corrupt a valid artifact, assert the typed
// rejection.
// --------------------------------------------------------------------

/// A valid straight-line schedule + plan over demo-cnn, the substrate the
/// plan mutations corrupt.
fn straight_line() -> (Graph, Vec<usize>, Vec<bool>, MemoryPlan) {
    let g = by_name("demo-cnn", 1);
    let order = g.compute_nodes();
    let materialize = vec![true; g.nodes.len()];
    let plan = MemoryPlan::new(&g, &order, &materialize);
    (g, order, materialize, plan)
}

/// Position of an adjacent (producer, consumer) pair in the schedule.
fn adjacent_dep(g: &Graph, order: &[usize]) -> usize {
    (0..order.len() - 1)
        .find(|&i| g.node(order[i + 1]).inputs.contains(&order[i]))
        .expect("demo-cnn has an adjacent producer/consumer pair")
}

#[test]
fn mutated_order_is_rejected() {
    let (g, mut order, materialize, plan) = straight_line();
    let i = adjacent_dep(&g, &order);
    order.swap(i, i + 1); // consumer now runs before its producer
    let err = check_plan(&g, &order, &materialize, &plan, "plan").expect_err("broken schedule");
    assert_eq!(err.code(), "InvalidPlan");
    assert!(err.to_string().contains("not defined earlier"), "{err}");
    assert!(err.to_string().contains("after pass 'plan'"), "{err}");
}

#[test]
fn shrunken_slot_is_rejected() {
    let (g, order, materialize, mut plan) = straight_line();
    let id = order[0];
    let s = plan.slot_of[id].expect("straight line materializes everything");
    plan.slot_elems[s] = g.node(id).out_elems() as usize - 1;
    let err = check_plan(&g, &order, &materialize, &plan, "plan").expect_err("undersized slot");
    assert_eq!(err.code(), "InvalidPlan");
    assert!(err.to_string().contains(&format!("slot {s}")), "{err}");
}

#[test]
fn aliased_live_values_are_rejected() {
    let (g, order, materialize, mut plan) = straight_line();
    let i = adjacent_dep(&g, &order);
    let (a, b) = (order[i], order[i + 1]);
    let sa = plan.slot_of[a].unwrap();
    // Force the consumer into its live input's slot — sized up so only
    // the aliasing is wrong.
    plan.slot_of[b] = Some(sa);
    plan.slot_elems[sa] = plan.slot_elems[sa].max(g.node(b).out_elems() as usize);
    let err = check_plan(&g, &order, &materialize, &plan, "plan").expect_err("aliased slot");
    assert_eq!(err.code(), "InvalidPlan");
    assert!(err.to_string().contains("aliases two live values"), "{err}");
    assert!(err.to_string().contains(&format!("slot {sa}")), "{err}");
}

#[test]
fn overlapping_arena_regions_are_rejected() {
    let g = by_name("demo-cnn", 1);
    let plan = fuse(&g, &FusionConfig::default());
    let st = ExecState::new(&g, &plan);
    let cfg = GemmConfig::default();
    let (mut regions, total) = arena_regions(st.workspace_spec(), &cfg);
    let nz: Vec<usize> =
        (0..regions.len()).filter(|&i| regions[i].len > 0).take(2).collect();
    let &[i, j] = &nz[..] else { panic!("need two non-empty regions") };
    // Slide the second region back so it overlaps the first by one elem.
    regions[j].start = regions[i].start + regions[i].len - 1;
    let err = check_regions(&regions, total, "plan").expect_err("overlapping regions");
    assert_eq!(err.code(), "InvalidPlan");
    let msg = err.to_string();
    assert!(msg.contains("overlap"), "{msg}");
    assert!(msg.contains(&regions[i].name) && msg.contains(&regions[j].name), "{msg}");
}

#[test]
fn out_of_bounds_region_is_rejected() {
    let g = by_name("demo-cnn", 1);
    let plan = no_fusion(&g);
    let st = ExecState::new(&g, &plan);
    let cfg = GemmConfig::default();
    let (mut regions, total) = arena_regions(st.workspace_spec(), &cfg);
    regions[0].start = total; // pushed past the end of the arena
    let err = check_regions(&regions, total, "plan").expect_err("region out of bounds");
    assert_eq!(err.code(), "InvalidPlan");
    assert!(err.to_string().contains("exceeds the arena"), "{err}");
}

#[test]
fn broken_fusion_order_is_rejected() {
    // Accept every fusion candidate so dependent chains are guaranteed
    // to land in one group, then swap a producer/consumer pair *inside*
    // a group — exactly the flattened-order violation the PR-4 bug
    // produced.
    let cfg = FusionConfig { profile_threshold_bytes: 0, max_group_size: 32 };
    let found = ["demo-transformer", "demo-cnn"].iter().find_map(|name| {
        let g = by_name(name, 1);
        let plan = fuse(&g, &cfg);
        plan.groups
            .iter()
            .enumerate()
            .find_map(|(gi, gr)| {
                (0..gr.nodes.len().saturating_sub(1))
                    .find(|&i| g.node(gr.nodes[i + 1]).inputs.contains(&gr.nodes[i]))
                    .map(|i| (gi, i))
            })
            .map(|(gi, i)| (g, plan, gi, i))
    });
    let (g, mut plan, gi, i) = found.expect("a demo model fuses a dependent chain");
    plan.groups[gi].nodes.swap(i, i + 1);
    let err = check_fusion(&g, &plan, "fuse").expect_err("non-topological fused order");
    assert_eq!(err.code(), "InvalidGraph");
    assert!(err.to_string().contains("not topological"), "{err}");
    assert!(err.to_string().contains("after pass 'fuse'"), "{err}");
}

/// The spec-level arena total must agree with the bytes the real
/// `Workspace` would allocate, for a spec with every scratch class
/// non-empty (demo-cnn has convs, so patches/gemm_out/wt are all live).
#[test]
fn arena_covers_every_scratch_class() {
    let g = by_name("demo-cnn", 1);
    let plan = fuse(&g, &FusionConfig::default());
    let st = ExecState::new(&g, &plan);
    let spec: &WorkspaceSpec = st.workspace_spec();
    assert!(spec.patches_elems > 0 && spec.gemm_out_elems > 0 && spec.wt_elems > 0);
    let cfg = GemmConfig::default();
    let (regions, total) = arena_regions(spec, &cfg);
    // slots + group×2 + patches + gemm_out + wt + one scratch band per thread
    assert_eq!(regions.len(), spec.slot_elems.len() + 5 + cfg.resolved_threads());
    assert_eq!(total as u64 * 4, spec.bytes(&cfg));
}
