//! `xgen::verify` — static soundness checkers for the compile pipeline.
//!
//! Every pass in the pipeline mutates the graph or aliases buffers:
//! rewrite substitutes subgraphs, pruning rewrites weights, fusion
//! flattens groups into an execution order, the memory planner maps many
//! values onto few slots, and the steady-state engine lays every scratch
//! buffer into one arena. PRs 4–6 each found a latent soundness bug in
//! that chain (a missing K-transpose, a fusion-ordering violation, a
//! poisoned-workspace recovery) only through end-to-end numeric oracles.
//! This module proves the structural half of those invariants
//! *mechanically*, after every stage, with failures that name the pass
//! and the offending node / slot / region:
//!
//! * [`check_graph`] — deep IR check: [`crate::graph::Graph::validate`]
//!   plus output presence, const-store sync, and weight-store shape
//!   consistency. Failure: [`XgenError::InvalidGraph`].
//! * [`check_fusion`] — the PR-4 fusion invariant: groups partition the
//!   compute nodes and the flattened group order is topological (every
//!   non-source input of a fused node is produced earlier in the
//!   flattened order — exactly what both executors assume). Failure:
//!   [`XgenError::InvalidGraph`].
//! * [`check_plan`] — symbolic liveness replay over a
//!   [`MemoryPlan`]: no two simultaneously-live values share a slot,
//!   every slot is sized for all its occupants, expire lists agree with
//!   the independently recomputed last-use positions, and outputs never
//!   expire. Failure: [`XgenError::InvalidPlan`].
//! * [`arena_regions`] + [`check_regions`] — the workspace arena laid
//!   out symbolically (slots / ping-pong / im2col / GEMM staging / wt /
//!   per-thread pack scratch), proven pairwise disjoint and in-bounds.
//!   Failure: [`XgenError::InvalidPlan`].
//!
//! [`check_compiled`] runs all four against an [`ExecState`] — this is
//! what [`crate::api::Compiler::compile`] calls after planning, and what
//! the per-pass hooks call after rewrite/prune/fuse. The checkers take
//! plain data (graph, order, mask, plan), so the mutation-based negative
//! tests in `tests/verify.rs` can corrupt a valid artifact and assert
//! the exact typed rejection.
//!
//! The third checker of the ISSUE-7 trio — the `SharedSlice` claim
//! registry that turns the unsafe row-band disjointness contract into a
//! checked invariant — lives where the contract lives, in
//! [`crate::runtime::pool`]; it is active in every `debug_assertions`
//! build and exercised (with the rest of the unsafe surface) by the
//! Miri CI job.

use std::collections::BTreeMap;

use crate::error::XgenError;
use crate::exec::{ExecState, MemoryPlan, WorkspaceSpec};
use crate::fusion::FusionPlan;
use crate::graph::{Graph, NodeId, OpKind, WeightStore};
use crate::tensor::gemm::{prepacked_scratch_elems, GemmConfig};
use crate::tensor::qgemm::qgemm_scratch_band_bytes;

fn bad_graph(pass: &str, detail: String) -> XgenError {
    XgenError::InvalidGraph { pass: pass.to_string(), detail }
}

fn bad_plan(pass: &str, detail: String) -> XgenError {
    XgenError::InvalidPlan { pass: pass.to_string(), detail }
}

/// What one full verification run covered — recorded on
/// [`crate::api::CompileReport`] and printed by its `summary()`.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct VerifyReport {
    /// Pipeline stages that passed the graph checker.
    pub passes: Vec<String>,
    /// Nodes deep-checked in the final graph.
    pub nodes: usize,
    /// Values replayed through the liveness checker.
    pub planned_values: usize,
    /// Slots whose occupancy intervals were proven disjoint.
    pub slots: usize,
    /// Arena regions proven pairwise disjoint and in-bounds.
    pub regions: usize,
}

impl VerifyReport {
    /// One-line summary for the compile report.
    pub fn summary(&self) -> String {
        format!(
            "{} passes clean ({}): {} nodes, {} values in {} slots, {} arena regions",
            self.passes.len(),
            self.passes.join("→"),
            self.nodes,
            self.planned_values,
            self.slots,
            self.regions
        )
    }
}

/// Deep IR check, beyond [`Graph::validate`]'s structural pass: the graph
/// has outputs, every recorded constant still has a scalar weight node,
/// duplicate weight names agree on shape, and (when a store is attached)
/// every weight node's tensor exists with exactly the node's shape.
/// `pass` names the pipeline stage being blamed in the error.
pub fn check_graph(g: &Graph, ws: Option<&WeightStore>, pass: &str) -> Result<(), XgenError> {
    g.validate().map_err(|e| e.with_pass(pass))?;
    if g.outputs.is_empty() {
        return Err(bad_graph(pass, format!("graph '{}' has no outputs", g.name)));
    }
    // Weight nodes by name: duplicates (shared/tied weights) must agree on
    // shape — the store holds one tensor per name.
    let mut weight_shape: BTreeMap<&str, (&[usize], NodeId)> = BTreeMap::new();
    for n in &g.nodes {
        if !matches!(n.op, OpKind::Weight) {
            continue;
        }
        if let Some((shape, first)) = weight_shape.insert(&n.name, (&n.shape, n.id)) {
            if shape != &n.shape[..] {
                return Err(bad_graph(
                    pass,
                    format!(
                        "weight '{}' has conflicting shapes: node {} is {:?}, node {} is {:?}",
                        n.name, first, shape, n.id, n.shape
                    ),
                ));
            }
        }
    }
    // Const-store sync: a recorded constant whose weight node survives
    // must still be a scalar (a rewrite that resized it would make
    // `init_random` bake the constant into the wrong tensor). Stale
    // entries for pruned-away nodes are harmless and allowed.
    for name in g.consts.keys() {
        if let Some(&(shape, id)) = weight_shape.get(name.as_str()) {
            if shape.iter().product::<usize>() != 1 {
                return Err(bad_graph(
                    pass,
                    format!("const '{}' (node {}) must be scalar, has shape {:?}", name, id, shape),
                ));
            }
        }
    }
    // Weight-store sync: every surviving weight node must be backed by a
    // tensor of exactly the node's shape — rewrite/prune must keep the
    // store in lockstep with the graph.
    if let Some(ws) = ws {
        for (&name, &(shape, id)) in &weight_shape {
            match ws.get(name) {
                None => {
                    return Err(bad_graph(
                        pass,
                        format!("weight '{}' (node {}) missing from the weight store", name, id),
                    ));
                }
                Some(t) if t.shape() != shape => {
                    return Err(bad_graph(
                        pass,
                        format!(
                            "weight '{}' (node {}) is {:?} in the graph but {:?} in the store",
                            name,
                            id,
                            shape,
                            t.shape()
                        ),
                    ));
                }
                Some(_) => {}
            }
        }
    }
    Ok(())
}

/// The PR-4 fusion invariant, checked mechanically: groups are non-empty,
/// contain only compute nodes, partition them exactly, and the flattened
/// order (groups sorted by first member — how [`ExecState`] executes)
/// is topological: every non-source input of every member is produced at
/// an earlier flattened position. An input produced *later* is exactly
/// the latent bug PR 4 fixed — a group absorbing a consumer whose other
/// operand lands in a later-sorted group.
pub fn check_fusion(g: &Graph, plan: &FusionPlan, pass: &str) -> Result<(), XgenError> {
    let mut group_of: Vec<Option<usize>> = vec![None; g.nodes.len()];
    for (gi, gr) in plan.groups.iter().enumerate() {
        if gr.nodes.is_empty() {
            return Err(bad_graph(pass, format!("fusion group {gi} is empty")));
        }
        for &id in &gr.nodes {
            if id >= g.nodes.len() {
                return Err(bad_graph(pass, format!("fusion group {gi} names node {id} out of range")));
            }
            if g.node(id).op.is_source() {
                return Err(bad_graph(
                    pass,
                    format!("fusion group {gi} contains source node {id} ('{}')", g.node(id).name),
                ));
            }
            if let Some(prev) = group_of[id] {
                return Err(bad_graph(
                    pass,
                    format!("node {id} ('{}') is in groups {prev} and {gi}", g.node(id).name),
                ));
            }
            group_of[id] = Some(gi);
        }
    }
    for n in &g.nodes {
        if !n.op.is_source() && group_of[n.id].is_none() {
            return Err(bad_graph(
                pass,
                format!("compute node {} ('{}') is in no fusion group", n.id, n.name),
            ));
        }
    }
    // Flattened order exactly as ExecState builds it.
    let mut order_of_group: Vec<usize> = (0..plan.groups.len()).collect();
    order_of_group.sort_by_key(|&gi| plan.groups[gi].nodes[0]);
    let mut flat_pos = vec![usize::MAX; g.nodes.len()];
    let mut p = 0usize;
    for &gi in &order_of_group {
        for &id in &plan.groups[gi].nodes {
            flat_pos[id] = p;
            p += 1;
        }
    }
    for n in &g.nodes {
        if n.op.is_source() {
            continue;
        }
        for &inp in &n.inputs {
            if g.node(inp).op.is_source() {
                continue;
            }
            if flat_pos[inp] >= flat_pos[n.id] {
                return Err(bad_graph(
                    pass,
                    format!(
                        "node {} ('{}') at flattened position {} consumes node {} \
                         ('{}') at position {} — the fused order is not topological",
                        n.id, n.name, flat_pos[n.id], inp, g.node(inp).name, flat_pos[inp]
                    ),
                ));
            }
        }
    }
    Ok(())
}

/// Symbolic liveness replay over a [`MemoryPlan`]: recompute every
/// value's live interval `[def, last-use]` from scratch (outputs live
/// forever) and prove, independently of the planner's own bookkeeping,
/// that
///
/// * `order` is a duplicate-free schedule of compute nodes whose every
///   non-source operand is defined earlier in the schedule,
/// * a value has a slot iff it is materialized,
/// * occupants of the same slot have pairwise-disjoint live intervals
///   (the slot may be rewritten only strictly after its previous
///   occupant's last use — an input is still live *while* its consumer
///   writes, so producer and consumer may never alias),
/// * every slot's capacity covers every occupant,
/// * the expire lists release exactly the non-output values at exactly
///   their recomputed last use.
///
/// Returns `(planned_values, slots)` for the [`VerifyReport`].
pub fn check_plan(
    g: &Graph,
    order: &[NodeId],
    materialize: &[bool],
    plan: &MemoryPlan,
    pass: &str,
) -> Result<(usize, usize), XgenError> {
    let nn = g.nodes.len();
    if materialize.len() != nn || plan.slot_of.len() != nn {
        return Err(bad_plan(
            pass,
            format!(
                "plan tables sized {}/{} for a graph of {} nodes",
                materialize.len(),
                plan.slot_of.len(),
                nn
            ),
        ));
    }
    if plan.slot_elems.len() != plan.num_slots {
        return Err(bad_plan(
            pass,
            format!("{} slot capacities for {} slots", plan.slot_elems.len(), plan.num_slots),
        ));
    }
    // --- schedule sanity + position table ---------------------------------
    let mut pos = vec![usize::MAX; nn];
    for (p, &id) in order.iter().enumerate() {
        if id >= nn {
            return Err(bad_plan(pass, format!("order position {p} names node {id} out of range")));
        }
        if g.node(id).op.is_source() {
            return Err(bad_plan(
                pass,
                format!("order position {p} schedules source node {id} ('{}')", g.node(id).name),
            ));
        }
        if pos[id] != usize::MAX {
            return Err(bad_plan(
                pass,
                format!("node {id} ('{}') scheduled twice (positions {} and {p})", g.node(id).name, pos[id]),
            ));
        }
        pos[id] = p;
    }
    for (p, &id) in order.iter().enumerate() {
        for &inp in &g.node(id).inputs {
            if g.node(inp).op.is_source() {
                continue;
            }
            if pos[inp] == usize::MAX || pos[inp] >= p {
                return Err(bad_plan(
                    pass,
                    format!(
                        "node {id} ('{}') at position {p} reads node {inp} which is not \
                         defined earlier in the schedule",
                        g.node(id).name
                    ),
                ));
            }
        }
    }
    // --- independent liveness: last use per scheduled value ----------------
    let mut last = vec![usize::MAX; nn]; // MAX here = "not scheduled"
    for &id in order {
        last[id] = pos[id];
    }
    for &id in order {
        for &inp in &g.node(id).inputs {
            if pos[inp] != usize::MAX && last[inp] != usize::MAX {
                last[inp] = last[inp].max(pos[id]);
            }
        }
    }
    const FOREVER: usize = usize::MAX - 1;
    for &id in order {
        if g.outputs.contains(&id) {
            last[id] = FOREVER;
        }
    }
    // --- slot assignment consistency ---------------------------------------
    let mut planned_values = 0usize;
    for id in 0..nn {
        let scheduled = pos[id] != usize::MAX;
        let mat = scheduled && materialize[id];
        match plan.slot_of[id] {
            Some(s) => {
                if !mat {
                    return Err(bad_plan(
                        pass,
                        format!("unmaterialized node {id} ('{}') holds slot {s}", g.node(id).name),
                    ));
                }
                if s >= plan.num_slots {
                    return Err(bad_plan(
                        pass,
                        format!("node {id} assigned slot {s} of {}", plan.num_slots),
                    ));
                }
                let elems = g.node(id).out_elems() as usize;
                if plan.slot_elems[s] < elems {
                    return Err(bad_plan(
                        pass,
                        format!(
                            "slot {s} holds {} elems but occupant node {id} ('{}') needs {}",
                            plan.slot_elems[s],
                            g.node(id).name,
                            elems
                        ),
                    ));
                }
                planned_values += 1;
            }
            None => {
                if mat {
                    return Err(bad_plan(
                        pass,
                        format!("materialized node {id} ('{}') has no slot", g.node(id).name),
                    ));
                }
            }
        }
    }
    // --- alias check: per-slot occupancy intervals must be disjoint --------
    let mut by_slot: Vec<Vec<(usize, usize, NodeId)>> = vec![Vec::new(); plan.num_slots];
    for id in 0..nn {
        if let Some(s) = plan.slot_of[id] {
            by_slot[s].push((pos[id], last[id], id));
        }
    }
    for (s, occ) in by_slot.iter_mut().enumerate() {
        occ.sort_unstable();
        for w in occ.windows(2) {
            let (_, prev_last, prev_id) = w[0];
            let (next_pos, _, next_id) = w[1];
            if next_pos <= prev_last {
                return Err(bad_plan(
                    pass,
                    format!(
                        "slot {s} aliases two live values: node {prev_id} ('{}') lives through \
                         position {} but node {next_id} ('{}') overwrites it at position {}",
                        g.node(prev_id).name,
                        prev_last,
                        g.node(next_id).name,
                        next_pos
                    ),
                ));
            }
        }
    }
    // --- expire lists agree with the recomputed liveness --------------------
    if plan.expire.len() != order.len() {
        return Err(bad_plan(
            pass,
            format!("{} expire positions for a schedule of {}", plan.expire.len(), order.len()),
        ));
    }
    let mut expired_at = vec![usize::MAX; nn];
    for (p, evs) in plan.expire.iter().enumerate() {
        for &d in evs {
            if d >= nn || plan.slot_of[d].is_none() {
                return Err(bad_plan(
                    pass,
                    format!("expire[{p}] releases node {d} which holds no slot"),
                ));
            }
            if expired_at[d] != usize::MAX {
                return Err(bad_plan(
                    pass,
                    format!("node {d} expires twice (positions {} and {p})", expired_at[d]),
                ));
            }
            expired_at[d] = p;
        }
    }
    for id in 0..nn {
        if plan.slot_of[id].is_none() {
            continue;
        }
        let want = if last[id] == FOREVER { usize::MAX } else { last[id] };
        if expired_at[id] != want {
            return Err(bad_plan(
                pass,
                if want == usize::MAX {
                    format!(
                        "graph output node {id} ('{}') is expired at position {} — outputs \
                         must keep their slot forever",
                        g.node(id).name, expired_at[id]
                    )
                } else {
                    format!(
                        "node {id} ('{}') last used at position {want} but expires at {}",
                        g.node(id).name,
                        if expired_at[id] == usize::MAX {
                            "never".to_string()
                        } else {
                            expired_at[id].to_string()
                        }
                    )
                },
            ));
        }
    }
    Ok((planned_values, plan.num_slots))
}

/// One named interval of the steady-state workspace arena, in f32
/// elements. Produced by [`arena_regions`], consumed by
/// [`check_regions`]; the mutation tests corrupt these directly.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Region {
    pub name: String,
    pub start: usize,
    pub len: usize,
}

/// Lay the arena out symbolically, in the same order
/// [`crate::exec::Workspace::new`] allocates it: one region per value
/// slot, the two ping-pong group buffers, im2col patches, GEMM staging,
/// the per-call transposed weight buffer, one f32 A-pack scratch band
/// per pool thread (the bands `gemm_prepacked` claims through
/// `SharedSlice`), and one int8 A-pack band per thread (the quantized
/// bands `qgemm_prepacked` claims — sized in whole f32 words, ISSUE-10).
/// Returns `(regions, total_elems)`; `total_elems * 4` equals
/// [`WorkspaceSpec::bytes`].
pub fn arena_regions(spec: &WorkspaceSpec, cfg: &GemmConfig) -> (Vec<Region>, usize) {
    let mut regions = Vec::new();
    let mut cursor = 0usize;
    let mut push = |name: String, len: usize, cursor: &mut usize| {
        regions.push(Region { name, start: *cursor, len });
        *cursor += len;
    };
    for (s, &elems) in spec.slot_elems.iter().enumerate() {
        push(format!("slot[{s}]"), elems, &mut cursor);
    }
    push("group[0]".to_string(), spec.group_elems, &mut cursor);
    push("group[1]".to_string(), spec.group_elems, &mut cursor);
    push("patches".to_string(), spec.patches_elems, &mut cursor);
    push("gemm_out".to_string(), spec.gemm_out_elems, &mut cursor);
    push("wt".to_string(), spec.wt_elems, &mut cursor);
    let per = prepacked_scratch_elems(cfg);
    for t in 0..cfg.resolved_threads() {
        push(format!("gemm_scratch[{t}]"), per, &mut cursor);
    }
    // The int8 kernel's per-thread quantized A-pack bands: i8 elements,
    // band length padded to a multiple of 4 bytes so it converts exactly
    // into the arena's f32 accounting units.
    let qper = qgemm_scratch_band_bytes(cfg) / 4;
    for t in 0..cfg.resolved_threads() {
        push(format!("qgemm_scratch[{t}]"), qper, &mut cursor);
    }
    (regions, cursor)
}

/// Prove a region list pairwise disjoint and in-bounds. Zero-length
/// regions are placeholders (a model without convs has empty conv
/// scratch) and never conflict.
pub fn check_regions(regions: &[Region], total: usize, pass: &str) -> Result<(), XgenError> {
    for r in regions {
        if r.start + r.len > total {
            return Err(bad_plan(
                pass,
                format!(
                    "arena region '{}' [{}, {}) exceeds the arena of {} elems",
                    r.name,
                    r.start,
                    r.start + r.len,
                    total
                ),
            ));
        }
    }
    let mut spans: Vec<&Region> = regions.iter().filter(|r| r.len > 0).collect();
    spans.sort_by_key(|r| r.start);
    for w in spans.windows(2) {
        let (a, b) = (w[0], w[1]);
        if b.start < a.start + a.len {
            return Err(bad_plan(
                pass,
                format!(
                    "arena regions overlap: '{}' [{}, {}) intersects '{}' [{}, {})",
                    a.name,
                    a.start,
                    a.start + a.len,
                    b.name,
                    b.start,
                    b.start + b.len
                ),
            ));
        }
    }
    Ok(())
}

/// Run every static checker against a built [`ExecState`]: the deep graph
/// check, the fusion invariant, the liveness replay over the state's own
/// flattened order/mask/plan, and the arena layout under the state's GEMM
/// config. This is the `pass = "plan"` hook of `Compiler::compile`.
pub fn check_compiled(
    g: &Graph,
    ws: Option<&WeightStore>,
    plan: &FusionPlan,
    st: &ExecState,
    pass: &str,
) -> Result<VerifyReport, XgenError> {
    check_graph(g, ws, pass)?;
    check_fusion(g, plan, pass)?;
    let order = st.execution_order(plan);
    let (planned_values, slots) =
        check_plan(g, &order, st.materialize_mask(), st.memory_plan(), pass)?;
    let (regions, total) = arena_regions(st.workspace_spec(), st.gemm_config());
    check_regions(&regions, total, pass)?;
    Ok(VerifyReport {
        passes: vec![pass.to_string()],
        nodes: g.nodes.len(),
        planned_values,
        slots,
        regions: regions.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::exec::ExecState;
    use crate::fusion::{fuse, FusionConfig};
    use crate::graph::zoo::by_name;

    fn compiled(name: &str) -> (Graph, FusionPlan, ExecState) {
        let g = by_name(name, 1);
        let plan = fuse(&g, &FusionConfig::default());
        let st = ExecState::new(&g, &plan);
        (g, plan, st)
    }

    #[test]
    fn demo_models_verify_clean() {
        for name in ["demo-cnn", "demo-transformer", "demo-transformer-causal"] {
            let (g, plan, st) = compiled(name);
            let rep = check_compiled(&g, None, &plan, &st, "plan")
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert!(rep.nodes > 0);
            assert!(rep.slots > 0);
            assert!(rep.regions >= rep.slots + 2);
            assert!(rep.summary().contains("plan"));
        }
    }

    #[test]
    fn straight_line_plan_verifies() {
        let g = by_name("demo-cnn", 1);
        let order = g.compute_nodes();
        let materialize = vec![true; g.nodes.len()];
        let plan = MemoryPlan::new(&g, &order, &materialize);
        check_plan(&g, &order, &materialize, &plan, "plan").expect("straight line is sound");
    }

    #[test]
    fn arena_total_matches_workspace_bytes() {
        let (g, plan, st) = compiled("demo-cnn");
        let _ = (g, plan);
        let cfg = *st.gemm_config();
        let (regions, total) = arena_regions(st.workspace_spec(), &cfg);
        assert_eq!(total as u64 * 4, st.workspace_spec().bytes(&cfg));
        check_regions(&regions, total, "plan").expect("fresh layout is disjoint");
    }

    #[test]
    fn graph_checker_relabels_the_pass() {
        let mut g = by_name("demo-cnn", 1);
        g.nodes[2].shape = vec![0];
        let err = check_graph(&g, None, "fuse").expect_err("zero dim");
        assert_eq!(err.code(), "InvalidGraph");
        assert!(err.to_string().contains("after pass 'fuse'"), "{err}");
    }

    #[test]
    fn graph_checker_requires_outputs() {
        let mut g = by_name("demo-cnn", 1);
        g.outputs.clear();
        let err = check_graph(&g, None, "rewrite").expect_err("no outputs");
        assert!(err.to_string().contains("no outputs"));
    }
}
