//! Analytic device cost model — the substitute for the paper's physical
//! testbeds (Galaxy S10/S20 CPU/GPU/DSP, STM32 MCU, Jetson, cloud TPU-V2;
//! see DESIGN.md substitution table).
//!
//! Per fused group the model charges
//! `max(compute, memory) + launch-overhead`:
//!
//! * compute = effective MACs / (peak × framework-eff × utilization ×
//!   sparse-eff), where utilization grows with per-output arithmetic
//!   intensity (small 1×1 layers can't saturate the SIMD units) and
//!   sparse-eff is the *irregularity* penalty of the pruning scheme — the
//!   central quantity of the paper's Fig 6: non-structured sparsity wins
//!   FLOPs but loses efficiency, pattern/block sparsity keep both.
//! * memory = group boundary tensors + weights over the bandwidth (fusion
//!   shrinks this term: intermediates inside a group never touch DRAM).
//!
//! Peak numbers are public spec sheets; framework efficiencies are
//! calibrated once against the paper's *dense baseline* rows (Table 3 MNN/
//! TVM/TFLite/PyTorch, Table 4 TFLite/SNPE) and then held fixed — XGen's
//! rows are *derived* from mechanism (pruning density × sparse-eff ×
//! fusion), not fitted.

use std::collections::BTreeMap;

use crate::fusion::FusionPlan;
use crate::graph::{Graph, NodeId, OpKind};
use crate::pruning::PruneScheme;

/// A hardware device (one computing unit).
#[derive(Debug, Clone)]
pub struct Device {
    pub name: &'static str,
    /// Peak multiply-accumulates per second (billions) at the unit's
    /// native precision.
    pub peak_gmacs: f64,
    /// Memory bandwidth, GB/s.
    pub mem_bw_gbps: f64,
    /// Whole-platform power draw under load, watts (energy model).
    pub power_w: f64,
    /// Utilization knee: arithmetic intensity (MACs per output element) at
    /// which the unit reaches half of peak.
    pub util_knee: f64,
}

impl Device {
    /// Utilization for a layer with `macs_per_out` MACs per output element.
    pub fn utilization(&self, macs_per_out: f64) -> f64 {
        macs_per_out / (macs_per_out + self.util_knee)
    }
}

/// Device catalog (public spec-sheet scale numbers).
pub mod devices {
    use super::Device;

    /// Snapdragon 855 Kryo 485 octa-core CPU (Galaxy S10).
    pub fn s10_cpu() -> Device {
        Device { name: "s10-cpu", peak_gmacs: 76.0, mem_bw_gbps: 15.0, power_w: 3.8, util_knee: 28.0 }
    }

    /// Adreno 640 GPU (Galaxy S10).
    pub fn s10_gpu() -> Device {
        Device { name: "s10-gpu", peak_gmacs: 450.0, mem_bw_gbps: 14.0, power_w: 3.8, util_knee: 80.0 }
    }

    /// Hexagon 698 DSP with HVX (Galaxy S20 / Snapdragon 865), int8.
    pub fn s20_dsp() -> Device {
        Device { name: "s20-dsp", peak_gmacs: 1100.0, mem_bw_gbps: 16.0, power_w: 2.5, util_knee: 120.0 }
    }

    /// STM32F469NI Cortex-M4 @180 MHz (Fig 19 MCU), int8 path.
    pub fn stm32_mcu() -> Device {
        Device { name: "stm32-mcu", peak_gmacs: 0.18, mem_bw_gbps: 0.15, power_w: 0.3, util_knee: 4.0 }
    }

    /// Jetson AGX Xavier iGPU (fp16).
    pub fn jetson_gpu() -> Device {
        Device { name: "jetson-gpu", peak_gmacs: 5500.0, mem_bw_gbps: 137.0, power_w: 30.0, util_knee: 90.0 }
    }

    /// Jetson AGX Xavier DLA (one of two).
    pub fn jetson_dla() -> Device {
        Device { name: "jetson-dla", peak_gmacs: 2500.0, mem_bw_gbps: 60.0, power_w: 10.0, util_knee: 150.0 }
    }

    /// Jetson AGX Xavier Carmel CPU complex.
    pub fn jetson_cpu() -> Device {
        Device { name: "jetson-cpu", peak_gmacs: 120.0, mem_bw_gbps: 60.0, power_w: 15.0, util_knee: 28.0 }
    }

    /// Google cloud TPU-V2 (single chip, batch-1 serving — Fig 18).
    pub fn tpu_v2() -> Device {
        Device { name: "tpu-v2", peak_gmacs: 22500.0, mem_bw_gbps: 600.0, power_w: 280.0, util_knee: 4000.0 }
    }

    /// Intel 4-core desktop CPU (NeuroMagic comparison).
    pub fn intel_4core() -> Device {
        Device { name: "intel-4core", peak_gmacs: 120.0, mem_bw_gbps: 35.0, power_w: 35.0, util_knee: 24.0 }
    }

    /// Intel 24-core server CPU (NeuroMagic YOLO comparison).
    pub fn intel_24core() -> Device {
        Device { name: "intel-24core", peak_gmacs: 700.0, mem_bw_gbps: 100.0, power_w: 120.0, util_knee: 24.0 }
    }
}

/// How a framework executes graphs on a device class.
#[derive(Debug, Clone)]
pub struct ExecProfile {
    pub name: &'static str,
    /// Fraction of device peak reached on large dense kernels.
    pub eff: f64,
    /// Fixed cost per executed (fused) kernel, ms.
    pub per_group_overhead_ms: f64,
    /// Can the runtime execute pruned models at all?
    pub sparse_capable: bool,
}

/// Irregularity multiplier of a pruning scheme on `eff` (the Fig 6
/// latency mechanism). 1.0 = sparsity is free to exploit.
pub fn sparse_efficiency(scheme: &PruneScheme) -> f64 {
    match scheme {
        PruneScheme::None => 1.0,
        // Indirect indexing + divergence: most FLOP savings are wasted.
        PruneScheme::NonStructured { .. } => 0.22,
        // Branch-less pattern code + FKW + reorder (§2.3.1).
        PruneScheme::Pattern { .. } => 0.88,
        // Blocks keep SIMD lanes full once the block covers the vector
        // width; small blocks pay some packing cost.
        PruneScheme::Block { block, .. } => match *block {
            usize::MAX => 1.0,
            b if b >= 32 => 0.95,
            b if b >= 8 => 0.85,
            b if b >= 4 => 0.72,
            _ => 0.45,
        },
        PruneScheme::Structured { .. } => 1.0,
    }
}

/// Latency estimate for one graph under one plan/profile/device.
#[derive(Debug, Clone, Default)]
pub struct LatencyBreakdown {
    pub compute_ms: f64,
    pub memory_ms: f64,
    pub overhead_ms: f64,
}

impl LatencyBreakdown {
    pub fn total_ms(&self) -> f64 {
        // compute/memory overlap per group is already folded in; totals add.
        self.compute_ms + self.memory_ms + self.overhead_ms
    }
}

/// Per-node weight density after pruning (1.0 when absent).
pub type DensityMap = BTreeMap<NodeId, f64>;

/// Build the density map a [`PruneScheme`] induces on `g`'s prunable nodes
/// (mirrors `pruning::prune_graph`'s selection logic, without weights).
pub fn scheme_density_map(g: &Graph, scheme: &PruneScheme) -> DensityMap {
    let mut m = DensityMap::new();
    if matches!(scheme, PruneScheme::None) {
        return m;
    }
    let density = 1.0 - scheme.rate();
    for n in &g.nodes {
        let prunable = matches!(
            n.op,
            OpKind::Conv2d { .. } | OpKind::Conv3d { .. } | OpKind::Dense | OpKind::MatMul
        ) && g.node_params(n.id) >= 64;
        if prunable {
            m.insert(n.id, density);
        }
    }
    m
}

/// Estimate the latency of executing `g` under fusion `plan` on `device`
/// with framework `profile`. `densities` carries per-node pruning density;
/// `sparse_eff` the scheme's irregularity multiplier.
pub fn estimate_latency(
    g: &Graph,
    plan: &FusionPlan,
    device: &Device,
    profile: &ExecProfile,
    densities: &DensityMap,
    sparse_eff: f64,
) -> LatencyBreakdown {
    let mut out = LatencyBreakdown::default();
    let members: Vec<Option<usize>> = {
        let mut v = vec![None; g.nodes.len()];
        for (gi, gr) in plan.groups.iter().enumerate() {
            for &id in &gr.nodes {
                v[id] = Some(gi);
            }
        }
        v
    };
    for gr in &plan.groups {
        let mut macs = 0.0f64;
        let mut boundary_bytes = 0.0f64;
        let mut weight_bytes = 0.0f64;
        let mut max_mpo = 0.0f64;
        for &id in &gr.nodes {
            let n = g.node(id);
            let density = densities.get(&id).copied().unwrap_or(1.0);
            let dense_macs = g.node_macs(id) as f64;
            macs += dense_macs * density;
            let out_elems = n.out_elems() as f64;
            if out_elems > 0.0 {
                // Arithmetic intensity from the *dense* layer: FKW/block
                // packing keeps the SIMD lanes as full as the dense kernel,
                // so pruning is not double-penalized through utilization.
                max_mpo = max_mpo.max(dense_macs / out_elems);
            }
            // Inputs crossing the group boundary.
            for &i in &n.inputs {
                let src = g.node(i);
                if matches!(src.op, OpKind::Weight) {
                    weight_bytes += src.out_elems() as f64 * 4.0 * density;
                } else if members[i] != members[id] {
                    boundary_bytes += src.out_elems() as f64 * 4.0;
                }
            }
        }
        // Group output leaves to memory.
        let tail = *gr.nodes.last().unwrap();
        boundary_bytes += g.node(tail).out_elems() as f64 * 4.0;

        // A group was pruned iff any of its members appears in the map.
        let pruned = gr.nodes.iter().any(|id| densities.contains_key(id));
        let eff_applied = if pruned { sparse_eff } else { 1.0 };
        let util = device.utilization(max_mpo.max(1.0));
        let compute_ms =
            macs / (device.peak_gmacs * 1e9 * profile.eff * util * eff_applied) * 1e3;
        let memory_ms = (boundary_bytes + weight_bytes) / (device.mem_bw_gbps * 1e9) * 1e3;
        // compute and memory overlap: the group takes the max; the excess
        // of memory over compute is reported as stall time.
        out.compute_ms += compute_ms;
        out.memory_ms += (memory_ms - compute_ms).max(0.0);
        out.overhead_ms += profile.per_group_overhead_ms;
    }
    out
}

/// Energy (millijoules) for a latency on a device.
pub fn energy_mj(device: &Device, latency_ms: f64) -> f64 {
    device.power_w * latency_ms
}

/// Main-memory traffic (bytes) of a cache-blocked `[m,k] x [k,n]` f32 GEMM
/// at panel sizes `mc/kc/nc` — the analytic model behind the block-size
/// knob ([`crate::xengine::knobs::gemm_ladder`]):
///
/// * each packed B panel (`kc x nc`) is loaded once per K-panel per column
///   block → `k*n` total;
/// * each packed A panel (`mc x kc`) is reloaded for every column block →
///   `m*k*ceil(n/nc)`;
/// * C is read+written once per K panel → `2*m*n*ceil(k/kc)`.
///
/// Bigger panels cut the A and C reload factors until the working set
/// spills the cache — which is exactly the trade `fig6_blocksize`
/// measures against wall-clock.
///
/// The model describes ONE worker band: the engine's row-band parallelism
/// re-packs B per band, so for a `threads = T` run the B term scales by
/// `T` (the knob-sweep bench only quotes predictions for single-thread
/// settings for this reason).
pub fn gemm_blocked_traffic_bytes(
    m: usize,
    k: usize,
    n: usize,
    mc: usize,
    kc: usize,
    nc: usize,
) -> u64 {
    let ceil_div = |a: usize, b: usize| ((a + b - 1) / b.max(1)) as u64;
    let (m64, k64, n64) = (m as u64, k as u64, n as u64);
    let b_loads = k64 * n64;
    let a_loads = m64 * k64 * ceil_div(n, nc);
    let c_moves = 2 * m64 * n64 * ceil_div(k, kc);
    let _ = mc; // row-panel height bounds the packing buffer, not DRAM traffic
    4 * (a_loads + b_loads + c_moves)
}

/// Traffic of the unblocked triple loop for comparison: the whole of B is
/// re-streamed for every output row (no cross-row reuse), A is read once,
/// and each C row is written once.
pub fn gemm_naive_traffic_bytes(m: usize, k: usize, n: usize) -> u64 {
    let (m64, k64, n64) = (m as u64, k as u64, n as u64);
    4 * (m64 * k64 + m64 * k64 * n64 + 2 * m64 * n64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fusion::{fuse, FusionConfig};
    use crate::graph::zoo::by_name;

    fn dense_latency(model: &str, dev: &Device, prof: &ExecProfile) -> f64 {
        let g = by_name(model, 1);
        let plan = fuse(&g, &FusionConfig::default());
        estimate_latency(&g, &plan, dev, prof, &DensityMap::new(), 1.0).total_ms()
    }

    fn mnn_cpu() -> ExecProfile {
        ExecProfile { name: "mnn", eff: 0.52, per_group_overhead_ms: 0.012, sparse_capable: false }
    }

    #[test]
    fn resnet50_dense_cpu_near_paper_mnn() {
        // Paper Table 3: MNN CPU ResNet-50 = 124 ms. Calibration target
        // band: within 2x.
        let t = dense_latency("resnet-50", &devices::s10_cpu(), &mnn_cpu());
        assert!((62.0..250.0).contains(&t), "resnet50 mnn-cpu {t} ms");
    }

    #[test]
    fn utilization_monotonic() {
        let d = devices::s10_cpu();
        assert!(d.utilization(10.0) < d.utilization(100.0));
        assert!(d.utilization(1e6) > 0.99);
    }

    #[test]
    fn pruning_reduces_latency_with_pattern_but_not_nonstructured() {
        let g = by_name("resnet-50", 1);
        let plan = fuse(&g, &FusionConfig::default());
        let dev = devices::s10_cpu();
        let prof = mnn_cpu();
        let dense =
            estimate_latency(&g, &plan, &dev, &prof, &DensityMap::new(), 1.0).total_ms();
        let pat_scheme = PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.4 };
        let dm = scheme_density_map(&g, &pat_scheme);
        let pat = estimate_latency(&g, &plan, &dev, &prof, &dm, sparse_efficiency(&pat_scheme))
            .total_ms();
        let ns_scheme = PruneScheme::NonStructured { rate: pat_scheme.rate() };
        let dm_ns = scheme_density_map(&g, &ns_scheme);
        let ns = estimate_latency(&g, &plan, &dev, &prof, &dm_ns, sparse_efficiency(&ns_scheme))
            .total_ms();
        assert!(pat < dense * 0.6, "pattern {pat} vs dense {dense}");
        assert!(ns > pat * 1.5, "non-structured {ns} should trail pattern {pat}");
    }

    #[test]
    fn fusion_lowers_overhead_and_memory() {
        let g = by_name("mobilenet-v2", 1);
        let fused = fuse(&g, &FusionConfig::default());
        let unfused = fuse(&g, &FusionConfig { max_group_size: 1, ..Default::default() });
        let dev = devices::s10_cpu();
        let prof = mnn_cpu();
        let tf = estimate_latency(&g, &fused, &dev, &prof, &DensityMap::new(), 1.0).total_ms();
        let tu = estimate_latency(&g, &unfused, &dev, &prof, &DensityMap::new(), 1.0).total_ms();
        assert!(tf < tu, "fused {tf} >= unfused {tu}");
    }

    #[test]
    fn gpu_faster_than_cpu_on_big_convs() {
        let prof = ExecProfile { name: "x", eff: 0.25, per_group_overhead_ms: 0.04, sparse_capable: false };
        let tc = dense_latency("vgg-16", &devices::s10_cpu(), &mnn_cpu());
        let tg = dense_latency("vgg-16", &devices::s10_gpu(), &prof);
        assert!(tg < tc, "gpu {tg} vs cpu {tc}");
    }

    #[test]
    fn energy_scales_with_power_and_time() {
        let d = devices::tpu_v2();
        assert!((energy_mj(&d, 10.0) - 2800.0).abs() < 1e-9);
    }

    #[test]
    fn blocked_gemm_traffic_far_below_naive() {
        let (m, k, n) = (512, 512, 512);
        let blocked = gemm_blocked_traffic_bytes(m, k, n, 64, 256, 256);
        let naive = gemm_naive_traffic_bytes(m, k, n);
        assert!(blocked * 10 < naive, "blocked {blocked} vs naive {naive}");
        // Wider column panels cut the A reload factor.
        let narrow = gemm_blocked_traffic_bytes(m, k, n, 64, 256, 64);
        let wide = gemm_blocked_traffic_bytes(m, k, n, 64, 256, 512);
        assert!(wide < narrow);
        // Deeper K panels cut the C read-modify-write factor.
        let shallow = gemm_blocked_traffic_bytes(m, k, n, 64, 64, 256);
        let deep = gemm_blocked_traffic_bytes(m, k, n, 64, 512, 256);
        assert!(deep < shallow);
    }

    #[test]
    fn sparse_efficiency_ordering() {
        let ns = sparse_efficiency(&PruneScheme::NonStructured { rate: 0.8 });
        let pat = sparse_efficiency(&PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.0 });
        let blk = sparse_efficiency(&PruneScheme::Block { block: 32, rate: 0.8 });
        let st = sparse_efficiency(&PruneScheme::Structured { rate: 0.8 });
        assert!(ns < pat && pat <= blk && blk <= st);
    }
}
