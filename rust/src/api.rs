//! The XGen session API: one coherent entry point from model to
//! executable (§2's Fig 2 co-design flow as a *single object*).
//!
//! The paper's core claim is that compression (pattern/block pruning),
//! compilation (rewriting, fusion, FKW storage, code generation) and
//! execution are one cooperative pipeline. [`Compiler`] is that pipeline
//! as a builder: pick a model, a [`PruneScheme`], an [`OptLevel`], a
//! target [`Device`] and the feature toggles (FKW kernels, deep reuse,
//! memory planner), then [`Compiler::compile`] runs
//! rewrite → prune → fuse → plan **once** and hands back a
//! [`CompiledModel`] that owns everything the run needs:
//!
//! * [`CompiledModel::infer`] — real execution through the fused executor
//!   with the buffer-pool memory planner; FKW kernels are auto-attached to
//!   every pattern-pruned 3×3 conv from the prune report's
//!   [`PatternAssignment`](crate::pruning::pattern::PatternAssignment)s,
//!   and deep-reuse GEMM routing is applied when enabled.
//! * [`CompiledModel::estimate`] — the analytical cost model, with the
//!   [`DensityMap`] cached at compile time instead of rebuilt per call.
//! * [`CompiledModel::report`] — per-stage statistics (rewrite, prune,
//!   fusion, planner slots, FKW layer count, compile wall-time).
//!
//! ```no_run
//! # fn main() -> anyhow::Result<()> {
//! use xgen::api::Compiler;
//! use xgen::pruning::PruneScheme;
//!
//! let model = Compiler::for_model("demo-cnn", 1)?
//!     .random_weights(42)
//!     .scheme(PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.3 })
//!     .compile()?;
//! let y = model.infer(&[xgen::tensor::Tensor::zeros(&[1, 3, 24, 24])])?;
//! # let _ = y;
//! # Ok(())
//! # }
//! ```
//!
//! Every example, bench, CLI command and the serving
//! [`Server`](crate::coordinator::Server) goes through this seam; future
//! backends (sharding, multi-device XEngine dispatch) plug in here.

use std::collections::BTreeSet;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::analyze::{self, AnalysisConfig, AnalysisReport};
use crate::baselines::{no_fusion, DeviceClass, Framework};
use crate::error::{panic_detail, XgenError};
use crate::cost::{
    devices, estimate_latency, scheme_density_map, sparse_efficiency, DensityMap, Device,
};
use crate::deepreuse::ReuseConfig;
use crate::exec::{DecodeSession, ExecState, Executor, FusedExecutor, PlanStats, Workspace};
use crate::fusion::{fuse, FusionConfig, FusionPlan};
use crate::tensor::gemm::GemmConfig;
use crate::graph::zoo::{all_models, by_name};
use crate::graph::{Graph, OpKind, WeightStore};
use crate::pruning::{prune_graph, PruneReport, PruneScheme};
use crate::rewrite::{rewrite, RewriteConfig, RewriteStats};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::verify::{self, VerifyReport};

/// How hard the graph-level compiler works.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum OptLevel {
    /// No graph transformations: straight per-op execution order.
    O0,
    /// Graph rewriting only (identity elimination, BN folding, movement
    /// collapse) — no operator fusion.
    O1,
    /// Rewriting + DNNFusion with the default profile thresholds.
    O2,
    /// Rewriting + aggressive fusion (lower profile threshold, larger
    /// fused groups).
    O3,
}

impl OptLevel {
    pub fn name(&self) -> &'static str {
        match self {
            OptLevel::O0 => "O0",
            OptLevel::O1 => "O1",
            OptLevel::O2 => "O2",
            OptLevel::O3 => "O3",
        }
    }

    /// Parse a CLI spelling (`0`..`3`, `O0`..`O3`).
    pub fn parse(s: &str) -> Option<OptLevel> {
        match s {
            "0" | "O0" | "o0" => Some(OptLevel::O0),
            "1" | "O1" | "o1" => Some(OptLevel::O1),
            "2" | "O2" | "o2" => Some(OptLevel::O2),
            "3" | "O3" | "o3" => Some(OptLevel::O3),
            _ => None,
        }
    }
}

/// How the session picks numeric precision for its contraction layers
/// (Dense, groups=1 conv, batched matmul) — ROADMAP item 3's int8 GEMM
/// end-to-end, with the compression–compilation co-design twist: the
/// *compile-time* [`QuantPlan`](crate::analyze::quant::QuantPlan) decides,
/// not a runtime calibration pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum QuantPolicy {
    /// Everything stays f32 (the default).
    #[default]
    Off,
    /// Every eligible contraction layer runs int8, feasible or not —
    /// the accuracy-vs-speed stress arm. Non-finite weights still fail
    /// the compile with a typed error.
    Force,
    /// Consult the analysis pass's `QuantPlan` per layer: int8 where
    /// `feasible`, f32 (with the plan's reason on the report) elsewhere.
    /// Forces the analysis pass on even below O2.
    Auto,
}

impl QuantPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            QuantPolicy::Off => "off",
            QuantPolicy::Force => "force",
            QuantPolicy::Auto => "auto",
        }
    }

    /// Parse a CLI spelling (`off`/`force`/`auto`).
    pub fn parse(s: &str) -> Option<QuantPolicy> {
        match s {
            "off" | "f32" => Some(QuantPolicy::Off),
            "force" | "int8" => Some(QuantPolicy::Force),
            "auto" => Some(QuantPolicy::Auto),
            _ => None,
        }
    }
}

/// Resolved precision of one contraction layer on the compiled session —
/// what will *actually* execute, not what the plan wished for: FKW- and
/// reuse-routed layers report f32 with the routing as the reason.
#[derive(Debug, Clone)]
pub struct LayerPrecision {
    pub node: usize,
    pub name: String,
    pub op: &'static str,
    /// True when the layer executes through the int8 kernel (packed
    /// weights for Dense/conv, dynamic quantization for MatMul).
    pub int8: bool,
    /// Why the layer stayed f32 under a non-`Off` policy.
    pub reason: Option<String>,
}

/// Summary of the pruning stage (the full
/// [`PruneReport`] — including per-layer pattern assignments — is on
/// [`CompiledModel::prune_report`]).
#[derive(Debug, Clone)]
pub struct PruneStats {
    pub sparsity: f64,
    pub layers_pruned: usize,
    pub effective_macs: u64,
}

/// Per-stage statistics of one [`Compiler::compile`] run.
#[derive(Debug, Clone)]
pub struct CompileReport {
    pub model: String,
    pub opt: OptLevel,
    pub scheme: PruneScheme,
    /// Name of the target device the session was compiled for.
    pub target: &'static str,
    pub rewrite: RewriteStats,
    pub prune: Option<PruneStats>,
    pub fusion_groups: usize,
    pub fusion_max_group: usize,
    pub fusion_bytes_saved: u64,
    /// Memory-planner pool statistics (present when weights were attached
    /// and an executor was built).
    pub plan: Option<PlanStats>,
    /// Conv layers auto-attached to FKW kernels from the prune report.
    pub fkw_layers: usize,
    pub reuse_enabled: bool,
    pub planner_enabled: bool,
    /// Constant GEMM operands pre-packed at compile time (0 when
    /// pre-packing is off or no executor was built).
    pub prepack_enabled: bool,
    pub prepacked_operands: usize,
    pub prepacked_bytes: u64,
    /// Steady-state workspace arena (allocated once; `infer` borrows it).
    pub workspace_enabled: bool,
    pub workspace_bytes: u64,
    /// Resolved worker-pool size the steady-state engine runs with
    /// (`XGEN_THREADS`, read once per process).
    pub pool_threads: usize,
    /// What the static verifier proved (ISSUE-7): present when the
    /// session compiled with `.verify(true)` — the default under
    /// `debug_assertions` — and every pass checked out clean. A failed
    /// check aborts `compile()` with a typed
    /// [`XgenError::InvalidGraph`]/[`XgenError::InvalidPlan`] instead.
    pub verify: Option<VerifyReport>,
    /// What the semantic dataflow analyses found (ISSUE-9): present when
    /// the session compiled with `.analyze(true)` — the default at O2+.
    /// Guaranteed-failure findings are *warnings* on the report
    /// (`analysis.warnings`), not compile aborts: the model still
    /// compiles, the broken path is named at build time.
    pub analysis: Option<AnalysisReport>,
    /// Precision policy the session compiled under (ISSUE-10).
    pub quant_policy: QuantPolicy,
    /// Per-contraction-layer resolved precision; empty when the policy
    /// is [`QuantPolicy::Off`].
    pub precision: Vec<LayerPrecision>,
    pub compile_ms: f64,
}

impl CompileReport {
    /// Contraction layers that resolved to int8 (0 under `Off`).
    pub fn int8_layer_count(&self) -> usize {
        self.precision.iter().filter(|l| l.int8).count()
    }

    /// Human-readable multi-line summary (what `xgen compile` prints).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "compiled {} [{}] for {} in {:.1} ms\n",
            self.model,
            self.opt.name(),
            self.target,
            self.compile_ms
        );
        s += &format!(
            "  rewrite: {} -> {} ops ({} rule hits)\n",
            self.rewrite.ops_before,
            self.rewrite.ops_after,
            self.rewrite.total_hits()
        );
        if let Some(p) = &self.prune {
            s += &format!(
                "  prune[{}]: {:.1}% sparsity over {} layers, effective {:.2} GMACs\n",
                self.scheme.name(),
                p.sparsity * 100.0,
                p.layers_pruned,
                p.effective_macs as f64 / 1e9
            );
        }
        s += &format!(
            "  fusion: {} fused layers (max group {}), {:.1} KB intermediate traffic saved\n",
            self.fusion_groups,
            self.fusion_max_group,
            self.fusion_bytes_saved as f64 / 1024.0
        );
        if let Some(pl) = &self.plan {
            s += &format!(
                "  plan: {} buffer slots for {} values ({:.0}% buffer bytes pooled away)\n",
                pl.slots,
                pl.planned_values,
                pl.bytes_saved_frac() * 100.0
            );
        }
        s += &format!(
            "  kernels: {} FKW conv layers, deep reuse {}, memory planner {}\n",
            self.fkw_layers,
            if self.reuse_enabled { "on" } else { "off" },
            if self.planner_enabled { "on" } else { "off" }
        );
        s += &format!(
            "  steady: {} prepacked operands ({:.1} KB), workspace {} ({:.1} KB), pool {} threads\n",
            self.prepacked_operands,
            self.prepacked_bytes as f64 / 1024.0,
            if self.workspace_enabled { "on" } else { "off" },
            self.workspace_bytes as f64 / 1024.0,
            self.pool_threads
        );
        if !matches!(self.quant_policy, QuantPolicy::Off) {
            s += &format!(
                "  quant[{}]: {}/{} contraction layers int8\n",
                self.quant_policy.name(),
                self.int8_layer_count(),
                self.precision.len()
            );
            for l in self.precision.iter().filter(|l| !l.int8) {
                if let Some(r) = &l.reason {
                    s += &format!("    f32 {} ({}): {r}\n", l.name, l.op);
                }
            }
        }
        if let Some(v) = &self.verify {
            s += &format!("  verify: {}\n", v.summary());
        }
        if let Some(a) = &self.analysis {
            s += &format!("  analysis: {}\n", a.summary());
            for w in &a.warnings {
                s += &format!("    warning: {w}\n");
            }
        }
        s
    }
}

/// Builder for one compile session. See the [module docs](self).
pub struct Compiler {
    graph: Graph,
    weights: Option<WeightStore>,
    scheme: PruneScheme,
    opt: OptLevel,
    target: Device,
    fkw: bool,
    reuse: Option<ReuseConfig>,
    planner: bool,
    prepack: bool,
    workspace: bool,
    gemm: GemmConfig,
    verify: bool,
    /// `None` = follow the opt level (on at O2+); `Some` = explicit.
    analyze: Option<bool>,
    quantize: QuantPolicy,
}

impl Compiler {
    /// Start a session from an already-built graph.
    pub fn new(graph: Graph) -> Compiler {
        Compiler {
            graph,
            weights: None,
            scheme: PruneScheme::None,
            opt: OptLevel::O2,
            target: devices::s10_cpu(),
            fkw: true,
            reuse: None,
            planner: true,
            prepack: true,
            workspace: true,
            gemm: GemmConfig::default(),
            // Every debug build verifies every compile; release opts in
            // via `.verify(true)` / `xgen compile --verify`.
            verify: cfg!(debug_assertions),
            analyze: None,
            quantize: QuantPolicy::Off,
        }
    }

    /// Start a session from a model-zoo name at a batch size; errors on an
    /// unknown name instead of panicking.
    pub fn for_model(name: &str, batch: usize) -> Result<Compiler> {
        if !all_models().contains(&name) {
            bail!("unknown zoo model '{name}' (see `xgen models`)");
        }
        Ok(Compiler::new(by_name(name, batch)))
    }

    /// Attach a weight store (required for [`CompiledModel::infer`] and
    /// for pruning to have an effect).
    pub fn weights(mut self, ws: WeightStore) -> Self {
        self.weights = Some(ws);
        self
    }

    /// Attach randomly-initialized weights (deterministic per seed).
    pub fn random_weights(mut self, seed: u64) -> Self {
        let ws = WeightStore::init_random(&self.graph, &mut Rng::new(seed));
        self.weights = Some(ws);
        self
    }

    /// Pruning scheme applied by the model optimizer.
    pub fn scheme(mut self, scheme: PruneScheme) -> Self {
        self.scheme = scheme;
        self
    }

    /// Graph-compiler effort level (default [`OptLevel::O2`]).
    pub fn opt_level(mut self, opt: OptLevel) -> Self {
        self.opt = opt;
        self
    }

    /// Target device recorded in the session and used by
    /// [`CompiledModel::estimate_target`].
    pub fn target(mut self, device: Device) -> Self {
        self.target = device;
        self
    }

    /// Auto-attach FKW kernels to pattern-pruned 3×3 convs (default on;
    /// only takes effect under [`PruneScheme::Pattern`]).
    pub fn fkw(mut self, on: bool) -> Self {
        self.fkw = on;
        self
    }

    /// Route eligible GEMM-backed ops through deep reuse with the default
    /// [`ReuseConfig`] (default off).
    pub fn deep_reuse(mut self, on: bool) -> Self {
        self.reuse = if on { Some(ReuseConfig::default()) } else { None };
        self
    }

    /// Route through deep reuse with an explicit config.
    pub fn reuse_config(mut self, cfg: ReuseConfig) -> Self {
        self.reuse = Some(cfg);
        self
    }

    /// Use the fused executor with the buffer-pool memory planner
    /// (default on). Turning this off executes through the straight-line
    /// reference [`Executor`] — the numeric oracle, useful for debugging;
    /// FKW, deep-reuse, pre-packing and workspace toggles do not apply on
    /// that engine.
    pub fn memory_planner(mut self, on: bool) -> Self {
        self.planner = on;
        self
    }

    /// Pre-pack every constant GEMM operand (Dense weights, transposed
    /// conv weight matrices, deep-reuse weight transposes) at compile time
    /// (default on). Off: weights pack/transpose per call — the PR-1
    /// behavior, kept as a bench baseline.
    pub fn prepack(mut self, on: bool) -> Self {
        self.prepack = on;
        self
    }

    /// Execute through the steady-state workspace engine: a per-model
    /// arena sized by the planner that `infer` borrows mutably, making
    /// steady-state inference allocation-free (default on). Off: the
    /// fused Tensor engine allocates per call — kept as the oracle and
    /// bench baseline.
    pub fn workspace(mut self, on: bool) -> Self {
        self.workspace = on;
        self
    }

    /// GEMM blocking/thread config of the compiled engine (default
    /// [`GemmConfig::default`]; `threads: 1` disables the worker pool for
    /// this session — the bench's pool-off arm).
    pub fn gemm_config(mut self, cfg: GemmConfig) -> Self {
        self.gemm = cfg;
        self
    }

    /// Run the [`crate::verify`] static checkers after every pipeline
    /// stage (rewrite → prune → fuse → plan): deep IR validation, the
    /// fusion ordering invariant, the memory-plan liveness replay, and
    /// the arena-region layout. A violation aborts the compile with a
    /// typed [`XgenError::InvalidGraph`] / [`XgenError::InvalidPlan`]
    /// naming the pass and the offending node/slot/region. Default: on
    /// under `debug_assertions`, off in release builds (the CLI's
    /// `compile --verify` turns it on there).
    pub fn verify(mut self, on: bool) -> Self {
        self.verify = on;
        self
    }

    /// Run the [`crate::analyze`] semantic dataflow analyses after the
    /// pipeline: value-range / NaN propagation (guaranteed-non-finite
    /// paths become typed warnings on the report), int8
    /// quantization-feasibility (`QuantPlan`), and trace-purity effect
    /// classification of every op and fused group. Default: follows the
    /// opt level — on at [`OptLevel::O2`] and above, off below (the
    /// CLI's `compile --analyze` forces it on).
    pub fn analyze(mut self, on: bool) -> Self {
        self.analyze = Some(on);
        self
    }

    /// Int8 precision policy for the session's contraction layers
    /// (default [`QuantPolicy::Off`]). Under [`QuantPolicy::Auto`] the
    /// compile-time [`QuantPlan`](crate::analyze::quant::QuantPlan) picks
    /// precision per layer — the analysis pass is forced on for this.
    /// Dense and groups=1 conv weights quantize per output channel and
    /// pack once at compile time; selected `MatMul` layers (attention
    /// QK^T / AV) quantize dynamically around the f32 masked softmax.
    /// Decode sessions always run f32 and work unchanged on
    /// mixed-precision plans.
    pub fn quantize(mut self, policy: QuantPolicy) -> Self {
        self.quantize = policy;
        self
    }

    /// Run the pipeline: rewrite → prune → fuse → plan (+ FKW encode).
    pub fn compile(mut self) -> Result<CompiledModel> {
        let t0 = Instant::now();
        let ops_before = self.graph.operator_count();
        let rewrite_stats = if self.opt >= OptLevel::O1 {
            rewrite(&mut self.graph, self.weights.as_mut(), &RewriteConfig::default())
        } else {
            RewriteStats {
                hits: Default::default(),
                ops_before,
                ops_after: ops_before,
            }
        };
        // ISSUE-7: the static verifier runs between every pair of passes,
        // so a violation is blamed on the pass that introduced it — not
        // discovered numerically three stages later. Each hook aborts the
        // compile with a typed error naming pass + node/slot/region.
        let mut verified_passes: Vec<String> = Vec::new();
        if self.verify {
            verify::check_graph(&self.graph, self.weights.as_ref(), "rewrite")?;
            verified_passes.push("rewrite".to_string());
        }
        let prune_report = match (&mut self.weights, &self.scheme) {
            (Some(ws), s) if !matches!(s, PruneScheme::None) => {
                Some(prune_graph(&self.graph, ws, s))
            }
            _ => None,
        };
        if self.verify {
            verify::check_graph(&self.graph, self.weights.as_ref(), "prune")?;
            verified_passes.push("prune".to_string());
        }
        let plan = match self.opt {
            OptLevel::O0 | OptLevel::O1 => no_fusion(&self.graph),
            OptLevel::O2 => fuse(&self.graph, &FusionConfig::default()),
            OptLevel::O3 => fuse(
                &self.graph,
                &FusionConfig { profile_threshold_bytes: 4 * 1024, max_group_size: 32 },
            ),
        };
        if self.verify {
            verify::check_graph(&self.graph, self.weights.as_ref(), "fuse")?;
            verify::check_fusion(&self.graph, &plan, "fuse")?;
            verified_passes.push("fuse".to_string());
        }
        // Cached at compile time — estimate() no longer rebuilds the
        // density map on every call.
        let density = scheme_density_map(&self.graph, &self.scheme);
        let sparse_eff = sparse_efficiency(&self.scheme);

        // ISSUE-9: the semantic layer on top of the structural verifier —
        // value ranges / NaN safety, int8 feasibility, trace purity.
        // Runs over the *final* graph + fusion plan so its QuantPlan and
        // purity groups describe what will actually execute. Runs before
        // the executor state is built: under `quantize(Auto)` (which
        // forces it on, ISSUE-10) the plan's per-layer verdicts decide
        // which weights pre-pack to int8.
        let analysis = if self.analyze.unwrap_or(self.opt >= OptLevel::O2)
            || matches!(self.quantize, QuantPolicy::Auto)
        {
            Some(analyze::analyze(
                &self.graph,
                self.weights.as_ref(),
                &plan,
                prune_report.as_ref(),
                &AnalysisConfig::default(),
            )?)
        } else {
            None
        };
        // The int8 node set the policy selects. `Force` takes every
        // eligible contraction; `Auto` takes the QuantPlan's feasible
        // subset. Routing (FKW, deep reuse) still wins at prepack time —
        // the precision report below blames those truthfully.
        let eligible = quant_eligible_nodes(&self.graph);
        let quant_sel: BTreeSet<usize> = match self.quantize {
            QuantPolicy::Off => BTreeSet::new(),
            QuantPolicy::Force => eligible.iter().copied().collect(),
            QuantPolicy::Auto => {
                let qp = analysis
                    .as_ref()
                    .map(|a| &a.quant)
                    .expect("Auto forces the analysis pass on");
                eligible
                    .iter()
                    .copied()
                    .filter(|&id| {
                        qp.layers.iter().any(|l| l.node == id && l.feasible)
                    })
                    .collect()
            }
        };

        // With the planner off, infer() runs the straight-line reference
        // executor — don't build (or report) executor state that would
        // never be used.
        let mut fkw_layers = 0usize;
        let state = if let (Some(ws), true) = (&self.weights, self.planner) {
            let mut st = ExecState::new(&self.graph, &plan);
            if self.fkw {
                if let Some(rep) = &prune_report {
                    for n in &self.graph.nodes {
                        let OpKind::Conv2d { k: 3, groups: 1, .. } = n.op else {
                            continue;
                        };
                        let Some(wid) = n
                            .inputs
                            .iter()
                            .copied()
                            .find(|&i| matches!(self.graph.node(i).op, OpKind::Weight))
                        else {
                            continue;
                        };
                        if let Some(asg) =
                            rep.pattern_assignments.get(&self.graph.node(wid).name)
                        {
                            st.attach_fkw(&self.graph, ws, n.id, asg)?;
                            fkw_layers += 1;
                        }
                    }
                }
            }
            st.set_reuse(self.reuse);
            st.set_gemm_config(self.gemm);
            // Before prepack: the set decides which weights pack to int8.
            st.set_quant(quant_sel.clone());
            if self.prepack {
                // After FKW attachment and reuse routing, so each conv
                // packs for the kernel that will actually run it.
                st.prepack(&self.graph, ws)?;
            }
            Some(st)
        } else {
            None
        };
        // The plan-stage checks need the final ExecState (flattened
        // order, materialization mask, memory plan, arena spec); with
        // the planner off there is no plan to verify, so the report
        // covers the graph-stage passes only.
        let verify_report = if self.verify {
            let mut rep = match &state {
                Some(st) => {
                    verify::check_compiled(&self.graph, self.weights.as_ref(), &plan, st, "plan")?
                }
                None => VerifyReport { nodes: self.graph.nodes.len(), ..Default::default() },
            };
            if state.is_some() {
                verified_passes.push("plan".to_string());
            }
            rep.passes = verified_passes;
            Some(rep)
        } else {
            None
        };
        // Resolved per-layer precision: computed from the sets the
        // executor state *actually* built (packed int8 tables, the
        // dynamic-MatMul membership), so FKW-/reuse-routed and
        // prepack-off layers report f32 with a truthful reason.
        let precision: Vec<LayerPrecision> = if matches!(self.quantize, QuantPolicy::Off) {
            Vec::new()
        } else {
            let plan_reason = |id: usize| -> Option<String> {
                analysis.as_ref().and_then(|a| {
                    a.quant
                        .layers
                        .iter()
                        .find(|l| l.node == id)
                        .and_then(|l| l.reason.map(|r| format!("infeasible: {r}")))
                })
            };
            eligible
                .iter()
                .map(|&id| {
                    let n = self.graph.node(id);
                    let is_matmul = matches!(n.op, OpKind::MatMul);
                    let (int8, reason) = match &state {
                        None => (false, Some("planner-off".to_string())),
                        Some(st) => {
                            if st.int8_scales(id).is_some()
                                || (is_matmul && st.quant_nodes().contains(&id))
                            {
                                (true, None)
                            } else if st.has_fkw(id) {
                                (false, Some("fkw-routed".to_string()))
                            } else if self.reuse.is_some() && !is_matmul {
                                (false, Some("reuse-routed".to_string()))
                            } else if matches!(self.quantize, QuantPolicy::Auto)
                                && !quant_sel.contains(&id)
                            {
                                (false, plan_reason(id).or_else(|| Some("not-in-plan".into())))
                            } else if !self.prepack && !is_matmul {
                                (false, Some("prepack-off".to_string()))
                            } else {
                                (false, Some("f32".to_string()))
                            }
                        }
                    };
                    LayerPrecision {
                        node: id,
                        name: n.name.clone(),
                        op: n.op.name(),
                        int8,
                        reason,
                    }
                })
                .collect()
        };
        // The steady-state arena: allocated once here, borrowed by every
        // infer. Sized by the planner's extended liveness pass.
        let workspace = match (&state, self.workspace) {
            (Some(st), true) => Some(Mutex::new(st.workspace())),
            _ => None,
        };
        let (prepacked_operands, prepacked_bytes) =
            state.as_ref().map(|s| s.packed_stats()).unwrap_or((0, 0));
        let workspace_bytes = workspace
            .as_ref()
            .map(|w| w.lock().unwrap().bytes())
            .unwrap_or(0);

        let report = CompileReport {
            model: self.graph.name.clone(),
            opt: self.opt,
            scheme: self.scheme.clone(),
            target: self.target.name,
            rewrite: rewrite_stats,
            prune: prune_report.as_ref().map(|r| PruneStats {
                sparsity: r.sparsity,
                layers_pruned: r.layers_pruned,
                effective_macs: r.effective_macs,
            }),
            fusion_groups: plan.fused_layer_count(),
            fusion_max_group: plan.max_group(),
            fusion_bytes_saved: plan.bytes_saved(&self.graph),
            plan: state.as_ref().map(|s| s.plan_stats().clone()),
            fkw_layers,
            // Deep reuse only applies on the fused engine; with the
            // planner off the reference executor ignores it.
            reuse_enabled: self.reuse.is_some() && self.planner,
            planner_enabled: self.planner,
            prepack_enabled: self.prepack && state.is_some(),
            prepacked_operands,
            prepacked_bytes,
            workspace_enabled: workspace.is_some(),
            workspace_bytes,
            pool_threads: self.gemm.resolved_threads(),
            verify: verify_report,
            analysis,
            quant_policy: self.quantize,
            precision,
            compile_ms: t0.elapsed().as_secs_f64() * 1e3,
        };
        Ok(CompiledModel {
            graph: self.graph,
            weights: self.weights,
            plan,
            scheme: self.scheme,
            target: self.target,
            density,
            sparse_eff,
            state,
            workspace,
            planner: self.planner,
            prune_report,
            report,
            counters: RuntimeCounters::default(),
        })
    }
}

/// Contraction nodes the int8 kernel can execute: Dense, groups=1 conv
/// (im2col GEMM) and batched MatMul (the attention contractions).
fn quant_eligible_nodes(g: &Graph) -> Vec<usize> {
    g.nodes
        .iter()
        .filter(|n| {
            matches!(n.op, OpKind::Dense | OpKind::MatMul | OpKind::Conv2d { groups: 1, .. })
        })
        .map(|n| n.id)
        .collect()
}

/// Serve-time self-healing counters (internal; read through
/// [`CompiledModel::runtime_stats`]). Atomics so `CompiledModel` stays
/// `Sync` and the hot path pays one relaxed store at most.
#[derive(Default)]
struct RuntimeCounters {
    engine_fallbacks: AtomicUsize,
    workspace_recoveries: AtomicUsize,
    worker_panics: AtomicUsize,
}

/// Snapshot of a session's serve-time recovery events: how many times the
/// steady engine degraded to the reference `eval_op` path, how many
/// poisoned workspace arenas were rebuilt, and how many caught panics this
/// model absorbed. All zero in a healthy process.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct RuntimeStats {
    pub engine_fallbacks: usize,
    pub workspace_recoveries: usize,
    pub worker_panics: usize,
}

/// A compiled session: owns the (rewritten) graph, the (pruned) weights,
/// the fusion plan and the pre-built executor state; answers both real
/// inference and cost-model estimation.
pub struct CompiledModel {
    graph: Graph,
    weights: Option<WeightStore>,
    plan: FusionPlan,
    scheme: PruneScheme,
    target: Device,
    density: DensityMap,
    sparse_eff: f64,
    state: Option<ExecState>,
    /// The steady-state arena, allocated once at compile time; `infer`
    /// borrows it mutably (behind a mutex so `CompiledModel` stays
    /// `Sync` for the serving layer).
    workspace: Option<Mutex<Workspace>>,
    planner: bool,
    prune_report: Option<PruneReport>,
    report: CompileReport,
    counters: RuntimeCounters,
}

impl CompiledModel {
    /// The rewritten graph the session executes.
    pub fn graph(&self) -> &Graph {
        &self.graph
    }

    /// The (pruned) weights, when attached.
    pub fn weights(&self) -> Option<&WeightStore> {
        self.weights.as_ref()
    }

    /// The fusion plan.
    pub fn plan(&self) -> &FusionPlan {
        &self.plan
    }

    /// The pruning scheme the session was compiled under.
    pub fn scheme(&self) -> &PruneScheme {
        &self.scheme
    }

    /// The full prune report (per-layer pattern assignments included).
    pub fn prune_report(&self) -> Option<&PruneReport> {
        self.prune_report.as_ref()
    }

    /// Per-stage compile statistics.
    pub fn report(&self) -> &CompileReport {
        &self.report
    }

    /// Per-output-channel dequant scales of node `node`'s int8-packed
    /// weight, when the session's quant policy packed it (Dense /
    /// groups=1 conv). The bitwise source of truth the scale-agreement
    /// test compares against the compile-time
    /// [`QuantPlan`](crate::analyze::quant::QuantPlan).
    pub fn int8_scales(&self, node: usize) -> Option<&[f32]> {
        self.state.as_ref().and_then(|st| st.int8_scales(node))
    }

    /// Shapes of the graph's Input nodes, in execution order.
    pub fn input_shapes(&self) -> Vec<Vec<usize>> {
        self.graph
            .nodes
            .iter()
            .filter(|n| matches!(n.op, OpKind::Input))
            .map(|n| n.shape.clone())
            .collect()
    }

    /// Shapes of the graph outputs.
    pub fn output_shapes(&self) -> Vec<Vec<usize>> {
        self.graph
            .outputs
            .iter()
            .map(|&o| self.graph.node(o).shape.clone())
            .collect()
    }

    /// Leading dimension of the first input — the compiled batch size.
    pub fn batch_size(&self) -> usize {
        self.input_shapes()
            .first()
            .and_then(|s| s.first().copied())
            .unwrap_or(1)
    }

    /// Deterministic *valid* sample inputs for this session: Gaussian
    /// values for dense inputs, in-range token ids for inputs consumed by
    /// an `Embedding`/`Gather` row lookup. The CLI `--infer` smoke and
    /// `benches/transformer.rs` feed transformer sessions through this —
    /// uniform floats are not valid token ids and would (correctly) make
    /// the embedding kernel error out.
    pub fn sample_inputs(&self, seed: u64) -> Vec<Tensor> {
        let mut rng = Rng::new(seed);
        self.graph
            .nodes
            .iter()
            .filter(|n| matches!(n.op, OpKind::Input))
            .map(|n| {
                // Vocab size of the first row-lookup consumer, if any.
                let vocab = self.graph.nodes.iter().find_map(|c| match c.op {
                    OpKind::Embedding | OpKind::Gather
                        if c.inputs.len() == 2 && c.inputs[0] == n.id =>
                    {
                        Some(self.graph.node(c.inputs[1]).shape[0])
                    }
                    _ => None,
                });
                match vocab {
                    Some(v) => {
                        let elems: usize = n.shape.iter().product();
                        let data: Vec<f32> = (0..elems).map(|_| rng.below(v) as f32).collect();
                        Tensor::from_vec(&n.shape, data)
                    }
                    None => Tensor::randn(&n.shape, 1.0, &mut rng),
                }
            })
            .collect()
    }

    /// Real execution: one tensor per Input node, outputs in graph order.
    pub fn infer(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.infer_with_stats(inputs).map(|(y, _)| y)
    }

    /// Real execution, also returning the memory planner's pool stats.
    /// With the workspace engine on (the default) this runs the
    /// steady-state path: all intermediates live in the compile-time
    /// arena, GEMMs hit pre-packed weights, and only the returned output
    /// tensors are allocated. [`CompiledModel::infer_into`] removes even
    /// that allocation.
    pub fn infer_with_stats(&self, inputs: &[Tensor]) -> Result<(Vec<Tensor>, PlanStats)> {
        let ws = self
            .weights
            .as_ref()
            .ok_or_else(|| anyhow!("model was compiled without weights — cannot infer"))?;
        self.validate_inputs(inputs)?;
        if !self.planner {
            let y = Executor::new(&self.graph, ws).run(inputs)?;
            return Ok((y, PlanStats::default()));
        }
        let state = self
            .state
            .as_ref()
            .expect("executor state exists when weights are attached and the planner is on");
        if let Some(arena) = &self.workspace {
            let mut arena = self.lock_workspace(state, arena);
            if let Err(e) = self.run_steady_guarded(ws, state, inputs, &mut arena) {
                return self
                    .reference_fallback(ws, inputs, e)
                    .map(|y| (y, state.plan_stats().clone()));
            }
            let outs = self.steady_outputs(inputs, &arena)?;
            return Ok((outs, state.plan_stats().clone()));
        }
        FusedExecutor::with_state(&self.graph, ws, &self.plan, state).run_with_stats(inputs)
    }

    /// Allocation-free up-front validation of `inputs` against the graph's
    /// Input nodes (count, then shape per position). Typed
    /// [`XgenError::ShapeMismatch`]; nothing executes on failure, so a
    /// malformed request can never corrupt the arena or write garbage.
    fn validate_inputs(&self, inputs: &[Tensor]) -> Result<()> {
        let mut idx = 0usize;
        for n in self.graph.nodes.iter().filter(|n| matches!(n.op, OpKind::Input)) {
            match inputs.get(idx) {
                Some(t) if t.shape() == &n.shape[..] => {}
                Some(t) => {
                    return Err(XgenError::ShapeMismatch {
                        expected: format!("{:?} for input {idx}", n.shape),
                        got: format!("{:?}", t.shape()),
                    }
                    .into());
                }
                None => {
                    return Err(XgenError::ShapeMismatch {
                        expected: format!("at least {} input tensors", idx + 1),
                        got: format!("{}", inputs.len()),
                    }
                    .into());
                }
            }
            idx += 1;
        }
        if inputs.len() > idx {
            return Err(XgenError::ShapeMismatch {
                expected: format!("{idx} input tensors"),
                got: format!("{}", inputs.len()),
            }
            .into());
        }
        Ok(())
    }

    /// Lock the steady arena, recovering a poisoned mutex by rebuilding
    /// the workspace from the compile-time spec — a panic that unwound
    /// through a previous `infer` must not brick every later request.
    fn lock_workspace<'a>(
        &self,
        state: &ExecState,
        arena: &'a Mutex<Workspace>,
    ) -> MutexGuard<'a, Workspace> {
        match arena.lock() {
            Ok(g) => g,
            Err(poisoned) => {
                let mut g = poisoned.into_inner();
                *g = state.workspace();
                arena.clear_poison();
                self.counters.workspace_recoveries.fetch_add(1, Ordering::Relaxed);
                g
            }
        }
    }

    /// One steady-engine run with panic isolation. On *any* failure the
    /// arena is rebuilt before returning: `run_steady` stages values by
    /// `mem::take`-ing arena slots, so an unwound or errored run may leave
    /// the workspace torn.
    fn run_steady_guarded(
        &self,
        ws: &WeightStore,
        state: &ExecState,
        inputs: &[Tensor],
        arena: &mut Workspace,
    ) -> Result<()> {
        let run = catch_unwind(AssertUnwindSafe(|| {
            FusedExecutor::with_state(&self.graph, ws, &self.plan, state)
                .run_steady(inputs, arena)
        }));
        match run {
            Ok(Ok(())) => Ok(()),
            Ok(Err(e)) => {
                *arena = state.workspace();
                Err(e)
            }
            Err(payload) => {
                *arena = state.workspace();
                self.counters.worker_panics.fetch_add(1, Ordering::Relaxed);
                Err(XgenError::WorkerPanic { detail: panic_detail(payload.as_ref()) }.into())
            }
        }
    }

    /// Graceful degradation: the steady engine failed mid-serve, so run
    /// the same request through the reference `eval_op` executor (numeric
    /// oracle, allocating but engine-independent) and count the fallback.
    /// Only if the reference path *also* fails does the caller see an
    /// error — [`XgenError::EngineFallback`] carrying both causes.
    fn reference_fallback(
        &self,
        ws: &WeightStore,
        inputs: &[Tensor],
        steady_err: anyhow::Error,
    ) -> Result<Vec<Tensor>> {
        match Executor::new(&self.graph, ws).run(inputs) {
            Ok(y) => {
                self.counters.engine_fallbacks.fetch_add(1, Ordering::Relaxed);
                Ok(y)
            }
            Err(ref_err) => Err(XgenError::EngineFallback {
                detail: format!("steady: {steady_err:#}; reference: {ref_err:#}"),
            }
            .into()),
        }
    }

    /// Serve-time recovery counters of this session (see [`RuntimeStats`]).
    pub fn runtime_stats(&self) -> RuntimeStats {
        RuntimeStats {
            engine_fallbacks: self.counters.engine_fallbacks.load(Ordering::Relaxed),
            workspace_recoveries: self.counters.workspace_recoveries.load(Ordering::Relaxed),
            worker_panics: self.counters.worker_panics.load(Ordering::Relaxed),
        }
    }

    /// Zero-allocation steady-state inference: runs the workspace engine
    /// and copies each output into the caller's pre-allocated tensors
    /// (shapes must match [`CompiledModel::output_shapes`]). After the
    /// first (warm-up) call, this path performs **no heap allocation**
    /// on the calling thread and spawns no threads — the acceptance
    /// property `tests/steady.rs` pins with a counting allocator.
    pub fn infer_into(&self, inputs: &[Tensor], outs: &mut [Tensor]) -> Result<()> {
        let ws = self
            .weights
            .as_ref()
            .ok_or_else(|| anyhow!("model was compiled without weights — cannot infer"))?;
        let (Some(state), Some(arena)) = (&self.state, &self.workspace) else {
            bail!("infer_into requires the workspace engine (planner + workspace on)");
        };
        if outs.len() != self.graph.outputs.len() {
            bail!(
                "got {} output tensors for {} graph outputs",
                outs.len(),
                self.graph.outputs.len()
            );
        }
        self.validate_inputs(inputs)?;
        let mut arena = self.lock_workspace(state, arena);
        if let Err(e) = self.run_steady_guarded(ws, state, inputs, &mut arena) {
            // Degrade to the reference path, then copy into the caller's
            // buffers so infer_into keeps its contract under faults too.
            let y = self.reference_fallback(ws, inputs, e)?;
            for (oi, t) in y.iter().enumerate() {
                let n = self.graph.node(self.graph.outputs[oi]);
                if outs[oi].shape() != &n.shape[..] {
                    bail!("output {oi} tensor shape {:?} != {:?}", outs[oi].shape(), n.shape);
                }
                outs[oi].data_mut().copy_from_slice(t.data());
            }
            return Ok(());
        }
        for (oi, &o) in self.graph.outputs.iter().enumerate() {
            let n = self.graph.node(o);
            if outs[oi].shape() != &n.shape[..] {
                bail!("output {oi} tensor shape {:?} != {:?}", outs[oi].shape(), n.shape);
            }
            if matches!(n.op, OpKind::Input | OpKind::Weight) {
                let t = self.steady_output_tensor(inputs, &arena, o)?;
                outs[oi].data_mut().copy_from_slice(t.data());
            } else {
                let elems = n.out_elems() as usize;
                let s = state
                    .planned_slice(&arena, o, elems)
                    .ok_or_else(|| anyhow!("output {o} not planned"))?;
                outs[oi].data_mut().copy_from_slice(s);
            }
        }
        Ok(())
    }

    /// Build output tensors from the arena after a steady run.
    fn steady_outputs(&self, inputs: &[Tensor], arena: &Workspace) -> Result<Vec<Tensor>> {
        let mut outs = Vec::with_capacity(self.graph.outputs.len());
        for &o in &self.graph.outputs {
            outs.push(self.steady_output_tensor(inputs, arena, o)?);
        }
        Ok(outs)
    }

    fn steady_output_tensor(
        &self,
        inputs: &[Tensor],
        arena: &Workspace,
        o: usize,
    ) -> Result<Tensor> {
        let n = self.graph.node(o);
        match &n.op {
            OpKind::Input => {
                let idx = self
                    .state
                    .as_ref()
                    .expect("steady run implies state")
                    .input_position(o)
                    .ok_or_else(|| anyhow!("node {o} is not an input"))?;
                inputs
                    .get(idx)
                    .cloned()
                    .ok_or_else(|| anyhow!("missing input {idx}"))
            }
            OpKind::Weight => self
                .weights
                .as_ref()
                .and_then(|w| w.get(&n.name))
                .cloned()
                .ok_or_else(|| anyhow!("weight '{}' missing", n.name)),
            _ => {
                let elems = n.out_elems() as usize;
                let state = self.state.as_ref().expect("steady run implies state");
                let s = state
                    .planned_slice(arena, o, elems)
                    .ok_or_else(|| anyhow!("output {o} not planned"))?;
                Ok(Tensor::from_vec(&n.shape, s.to_vec()))
            }
        }
    }

    /// Open an autoregressive decoding session over this compiled model:
    /// per-attention K/V caches sized for `max_seq` positions, with
    /// `prefill`/`step` returning per-position logits and `step` being
    /// allocation-free after warm-up. Errors loudly when the model was
    /// compiled without weights, is not a causal decoder (every attention
    /// must carry a `CausalMask`), or `max_seq` exceeds the model's
    /// positional range.
    pub fn decode_session(&self, max_seq: usize) -> Result<DecodeSession<'_>> {
        let ws = self
            .weights
            .as_ref()
            .ok_or_else(|| anyhow!("model was compiled without weights — cannot decode"))?;
        // ISSUE-9 satellite: sessions that compiled with verification on
        // keep the structural check in *release* builds too — the old
        // behavior silently dropped it outside debug_assertions.
        let check = self.report.verify.is_some() || cfg!(debug_assertions);
        DecodeSession::new_checked(&self.graph, ws, max_seq, check)
    }

    /// Bytes one decode session's K/V caches occupy at `max_seq`
    /// positions — the planner's
    /// [`WorkspaceSpec::kv_cache_elems`](crate::exec::WorkspaceSpec::kv_cache_elems)
    /// sizing × 4 bytes/f32. This is the unit
    /// [`SchedConfig::kv_budget_bytes`](crate::coordinator::scheduler::SchedConfig)
    /// is counted in: a stream scheduler over this model can hold
    /// `budget / kv_cache_bytes(max_seq)` resident sessions.
    pub fn kv_cache_bytes(&self, max_seq: usize) -> u64 {
        let elems = match &self.state {
            Some(st) => st.workspace_spec().kv_cache_elems(max_seq),
            None => crate::exec::attention_specs(&self.graph)
                .iter()
                .filter(|a| a.causal)
                .map(|a| 2 * a.row_elems() * max_seq)
                .sum(),
        };
        elems as u64 * 4
    }

    /// Greedy generation convenience: prefill `prompt`, then emit `n`
    /// argmax tokens through a fresh [`DecodeSession`] sized to fit
    /// (the last generated token needs no extra position).
    pub fn generate(&self, prompt: &[u32], n: usize) -> Result<Vec<u32>> {
        let need = (prompt.len() + n.saturating_sub(1)).max(1);
        let mut session = self.decode_session(need)?;
        session.generate(prompt, n)
    }

    /// Single-input convenience over flat `f32` data (the serving path).
    pub fn infer_flat(&self, x: &[f32]) -> Result<Vec<f32>> {
        let shape = self
            .input_shapes()
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("model has no input node"))?;
        let n: usize = shape.iter().product();
        if x.len() != n {
            return Err(XgenError::ShapeMismatch {
                expected: format!("{n} elements for shape {shape:?}"),
                got: format!("{} elements", x.len()),
            }
            .into());
        }
        let mut out = self.infer(&[Tensor::from_vec(&shape, x.to_vec())])?;
        if out.is_empty() {
            bail!("model produced no outputs");
        }
        Ok(out.remove(0).into_vec())
    }

    /// Batched convenience: stack `batch_size()` flat inputs along dim 0,
    /// run once, split the first output back per request.
    pub fn infer_flat_batch(&self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        let shape = self
            .input_shapes()
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("model has no input node"))?;
        let b = *shape.first().unwrap_or(&1);
        if xs.len() != b {
            return Err(XgenError::ShapeMismatch {
                expected: format!("{b} inputs (compiled batch size)"),
                got: format!("{} inputs", xs.len()),
            }
            .into());
        }
        let per: usize = shape[1..].iter().product();
        let mut flat = Vec::with_capacity(b * per);
        for x in xs {
            if x.len() != per {
                return Err(XgenError::ShapeMismatch {
                    expected: format!("{per} elements per request"),
                    got: format!("{} elements", x.len()),
                }
                .into());
            }
            flat.extend_from_slice(x);
        }
        let out = self.infer(&[Tensor::from_vec(&shape, flat)])?;
        let y = &out[0];
        let bper = y.len() / b;
        Ok((0..b)
            .map(|i| y.data()[i * bper..(i + 1) * bper].to_vec())
            .collect())
    }

    /// Cost-model latency on an arbitrary device under a framework
    /// profile, using the density map cached at compile time.
    pub fn estimate(&self, device: &Device, fw: Framework, class: DeviceClass) -> Option<f64> {
        let prof = fw.profile(class)?;
        Some(
            estimate_latency(&self.graph, &self.plan, device, &prof, &self.density, self.sparse_eff)
                .total_ms(),
        )
    }

    /// Cost-model latency on the session's target device.
    pub fn estimate_target(&self, fw: Framework, class: DeviceClass) -> Option<f64> {
        self.estimate(&self.target, fw, class)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_compiles_zoo_model_and_estimates() {
        let m = Compiler::for_model("mobilenet-v2", 1)
            .unwrap()
            .scheme(PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.3 })
            .compile()
            .unwrap();
        // Weightless session: estimate works, infer errors cleanly.
        let ms = m
            .estimate(&devices::s10_cpu(), Framework::XGenFull, DeviceClass::MobileCpu)
            .unwrap();
        assert!(ms > 0.0 && ms < 1000.0, "latency {ms}");
        assert!(m.infer(&[]).is_err());
        assert!(m.report().fusion_groups > 0);
    }

    #[test]
    fn unknown_model_is_an_error_not_a_panic() {
        assert!(Compiler::for_model("no-such-net", 1).is_err());
    }

    #[test]
    fn fkw_layers_auto_attached_under_pattern_scheme() {
        let m = Compiler::for_model("demo-cnn", 1)
            .unwrap()
            .random_weights(7)
            .scheme(PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.3 })
            .compile()
            .unwrap();
        assert!(m.report().fkw_layers > 0, "no FKW kernels attached");
        assert!(m.prune_report().unwrap().pattern_assignments.len() >= m.report().fkw_layers);
        let shape = m.input_shapes()[0].clone();
        let y = m.infer(&[Tensor::zeros(&shape)]).unwrap();
        assert_eq!(y[0].shape(), &m.output_shapes()[0][..]);
    }

    /// The steady-state toggles {prepack, workspace, pool} never change
    /// numerics (vs the default all-on engine), `infer_into` matches
    /// `infer` bitwise, and the report exposes the new steady-state
    /// statistics.
    #[test]
    fn steady_toggles_are_numerically_invisible() {
        use crate::tensor::gemm::GemmConfig;
        use crate::util::rng::Rng;
        let x = Tensor::randn(&[1, 3, 24, 24], 1.0, &mut Rng::new(21));
        let base = Compiler::for_model("demo-cnn", 1)
            .unwrap()
            .random_weights(9)
            .compile()
            .unwrap();
        let want = base.infer(&[x.clone()]).unwrap();
        for (pp, wsp) in [(false, false), (false, true), (true, false)] {
            let m = Compiler::for_model("demo-cnn", 1)
                .unwrap()
                .random_weights(9)
                .prepack(pp)
                .workspace(wsp)
                .compile()
                .unwrap();
            let y = m.infer(&[x.clone()]).unwrap();
            let d = want[0].max_abs_diff(&y[0]);
            assert!(d < 1e-4, "prepack={pp} workspace={wsp}: diff {d}");
        }
        let serial = Compiler::for_model("demo-cnn", 1)
            .unwrap()
            .random_weights(9)
            .gemm_config(GemmConfig { threads: 1, ..Default::default() })
            .compile()
            .unwrap();
        let y = serial.infer(&[x.clone()]).unwrap();
        assert!(want[0].max_abs_diff(&y[0]) < 1e-4, "pool-off diverges");

        let mut outs = vec![Tensor::zeros(&base.output_shapes()[0])];
        base.infer_into(&[x.clone()], &mut outs).unwrap();
        assert_eq!(outs[0].data(), want[0].data(), "infer_into != infer");

        let r = base.report();
        assert!(r.prepack_enabled && r.prepacked_operands > 0);
        assert!(r.workspace_enabled && r.workspace_bytes > 0);
        assert!(r.pool_threads >= 1);
        assert!(r.summary().contains("prepacked operands"));
        // infer_into without the workspace engine is a clean error.
        let off = Compiler::for_model("demo-cnn", 1)
            .unwrap()
            .random_weights(9)
            .workspace(false)
            .compile()
            .unwrap();
        assert!(off.infer_into(&[x], &mut outs).is_err());
    }

    /// `decode_session`/`generate` work on the causal demo decoder and
    /// error cleanly on weightless sessions and encoder models.
    #[test]
    fn decode_session_and_generate_on_the_causal_demo() {
        let m = Compiler::for_model("demo-transformer-causal", 1)
            .unwrap()
            .random_weights(23)
            .compile()
            .unwrap();
        let mut s = m.decode_session(8).unwrap();
        let logits = s.prefill(&[3, 1, 4]).unwrap();
        assert_eq!(logits.len(), 256);
        assert!(logits.iter().all(|v| v.is_finite()));
        let out = m.generate(&[3, 1, 4], 5).unwrap();
        assert_eq!(out.len(), 5);
        assert!(out.iter().all(|&t| (t as usize) < 256));
        // Greedy decoding is deterministic: same prompt, same tokens.
        assert_eq!(out, m.generate(&[3, 1, 4], 5).unwrap());

        let weightless = Compiler::for_model("demo-transformer-causal", 1)
            .unwrap()
            .compile()
            .unwrap();
        assert!(weightless.decode_session(8).is_err());
        let encoder = Compiler::for_model("demo-transformer", 1)
            .unwrap()
            .random_weights(23)
            .compile()
            .unwrap();
        assert!(encoder.decode_session(8).is_err());
    }

    #[test]
    fn opt_level_parse_round_trips() {
        for (s, o) in [("0", OptLevel::O0), ("1", OptLevel::O1), ("2", OptLevel::O2), ("3", OptLevel::O3)] {
            assert_eq!(OptLevel::parse(s), Some(o));
            assert_eq!(OptLevel::parse(o.name()), Some(o));
        }
        assert_eq!(OptLevel::parse("max"), None);
    }
}
