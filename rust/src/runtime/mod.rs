//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the `xla` crate's PJRT CPU
//! client. This is the only place the Rust side touches XLA; everything
//! upstream (compiler, pruning, scheduling) is plain Rust, and Python is
//! never on this path.
//!
//! The `xla` crate is not part of the offline dependency set, so the real
//! client lives behind the `xla` cargo feature. Without it (the default)
//! this module compiles a stub with the same API whose `open` reports PJRT
//! as unavailable — the serving loop in [`crate::coordinator`] and the
//! PJRT integration tests degrade gracefully (tests skip when no
//! artifacts/runtime are present).

use std::path::PathBuf;

#[cfg(any(test, feature = "fault-injection"))]
pub mod fault;
pub mod pool;

#[cfg(feature = "xla")]
mod pjrt {
    use std::collections::BTreeMap;
    use std::path::{Path, PathBuf};

    use anyhow::{anyhow, bail, Context, Result};

    use crate::util::json::Json;

    /// A compiled artifact ready to execute.
    pub struct LoadedModel {
        pub name: String,
        pub input_shape: Vec<usize>,
        exe: xla::PjRtLoadedExecutable,
    }

    impl LoadedModel {
        /// Execute on one input tensor (row-major f32 matching
        /// `input_shape`). Returns the flattened first output.
        pub fn run(&self, input: &[f32]) -> Result<Vec<f32>> {
            let elems: usize = self.input_shape.iter().product();
            if input.len() != elems {
                bail!("input length {} != shape {:?}", input.len(), self.input_shape);
            }
            let dims: Vec<i64> = self.input_shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(input).reshape(&dims)?;
            let result = self.exe.execute::<xla::Literal>(&[lit])?[0][0].to_literal_sync()?;
            // aot.py lowers with return_tuple=True → unwrap the 1-tuple.
            let out = result.to_tuple1()?;
            Ok(out.to_vec::<f32>()?)
        }

        /// Batch of inputs, each `input_shape[1..]`-shaped; the artifact's
        /// leading dim must equal `inputs.len()`.
        pub fn run_batch(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            let batch = self.input_shape[0];
            if inputs.len() != batch {
                bail!("artifact batch {} != {} requests", batch, inputs.len());
            }
            let per: usize = self.input_shape[1..].iter().product();
            let mut flat = Vec::with_capacity(batch * per);
            for i in inputs {
                if i.len() != per {
                    bail!("request length {} != {}", i.len(), per);
                }
                flat.extend_from_slice(i);
            }
            let out = self.run(&flat)?;
            let out_per = out.len() / batch;
            Ok(out.chunks(out_per).map(|c| c.to_vec()).collect())
        }
    }

    /// Registry of compiled artifacts over one PJRT client.
    pub struct ModelRuntime {
        client: xla::PjRtClient,
        dir: PathBuf,
        meta: BTreeMap<String, Vec<usize>>,
        models: BTreeMap<String, LoadedModel>,
    }

    impl ModelRuntime {
        /// Open the artifact directory (reads `meta.json`).
        pub fn open<P: AsRef<Path>>(dir: P) -> Result<ModelRuntime> {
            let dir = dir.as_ref().to_path_buf();
            let meta_path = dir.join("meta.json");
            let text = std::fs::read_to_string(&meta_path)
                .with_context(|| format!("reading {meta_path:?} — run `make artifacts` first"))?;
            let parsed = Json::parse(&text).map_err(|e| anyhow!("bad meta.json: {e}"))?;
            let mut meta = BTreeMap::new();
            for (name, entry) in parsed.as_obj().ok_or_else(|| anyhow!("meta.json not an object"))? {
                let shape: Vec<usize> = entry
                    .get("input")
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| anyhow!("artifact {name} missing input shape"))?
                    .iter()
                    .filter_map(|v| v.as_f64())
                    .map(|v| v as usize)
                    .collect();
                meta.insert(name.clone(), shape);
            }
            let client = xla::PjRtClient::cpu()?;
            Ok(ModelRuntime { client, dir, meta, models: BTreeMap::new() })
        }

        /// Artifact names available in meta.json.
        pub fn available(&self) -> Vec<&str> {
            self.meta.keys().map(|s| s.as_str()).collect()
        }

        /// Compile (or fetch cached) an artifact.
        pub fn load(&mut self, name: &str) -> Result<&LoadedModel> {
            if !self.models.contains_key(name) {
                let shape = self
                    .meta
                    .get(name)
                    .ok_or_else(|| anyhow!("unknown artifact '{name}'"))?
                    .clone();
                let path = self.dir.join(format!("{name}.hlo.txt"));
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| anyhow!("bad path"))?,
                )?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self.client.compile(&comp)?;
                self.models.insert(
                    name.to_string(),
                    LoadedModel { name: name.to_string(), input_shape: shape, exe },
                );
            }
            Ok(&self.models[name])
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }
    }
}

#[cfg(not(feature = "xla"))]
mod pjrt {
    use std::path::Path;

    use anyhow::{bail, Result};

    /// Stub artifact handle — the `xla` feature is off, so nothing can
    /// actually execute; the type exists so callers compile unchanged.
    pub struct LoadedModel {
        pub name: String,
        pub input_shape: Vec<usize>,
    }

    impl LoadedModel {
        pub fn run(&self, _input: &[f32]) -> Result<Vec<f32>> {
            bail!("built without the `xla` feature — PJRT execution unavailable")
        }

        pub fn run_batch(&self, _inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            bail!("built without the `xla` feature — PJRT execution unavailable")
        }
    }

    /// Stub registry: `open` always fails with a clear message, which the
    /// serving loop and integration tests treat as "runtime absent".
    pub struct ModelRuntime {
        _priv: (),
    }

    impl ModelRuntime {
        pub fn open<P: AsRef<Path>>(_dir: P) -> Result<ModelRuntime> {
            bail!("built without the `xla` feature — enable it (with the vendored xla crate) to load PJRT artifacts")
        }

        pub fn available(&self) -> Vec<&str> {
            Vec::new()
        }

        pub fn load(&mut self, name: &str) -> Result<&LoadedModel> {
            bail!("built without the `xla` feature — cannot load '{name}'")
        }

        pub fn platform(&self) -> String {
            "stub (no xla feature)".to_string()
        }
    }
}

pub use pjrt::{LoadedModel, ModelRuntime};

/// Locate the repo's artifact dir relative to CWD (tests/examples run from
/// the workspace root; benches sometimes from target/).
pub fn default_artifact_dir() -> PathBuf {
    for cand in ["artifacts", "../artifacts", "../../artifacts"] {
        let p = PathBuf::from(cand);
        if p.join("meta.json").exists() {
            return p;
        }
    }
    PathBuf::from("artifacts")
}

/// True when AOT artifacts exist AND the runtime can execute them (tests
/// skip gracefully otherwise).
pub fn artifacts_present() -> bool {
    cfg!(feature = "xla") && default_artifact_dir().join("meta.json").exists()
}
