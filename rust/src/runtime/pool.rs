//! Persistent worker pool — the spawn-free substrate of steady-state
//! inference (§2.3: the paper's generated code does *all* expensive setup
//! at compile time; per-call `std::thread::scope` spawn/join is exactly the
//! kind of steady-state overhead XGen compiles away).
//!
//! One process-wide pool is lazily built on first use ([`global`]) with
//! `XGEN_THREADS` workers (default: the machine's available parallelism,
//! resolved **once** through a `OnceLock` — see [`configured_threads`]).
//! [`ThreadPool::parallel_for`] distributes `tasks` closure invocations
//! over the persistent workers; the submitting thread participates, so a
//! 1-thread pool degenerates to a plain serial loop and nothing is ever
//! spawned per call.
//!
//! Design constraints, in order:
//! * **std-only** — no rayon/crossbeam; a `Mutex` + two `Condvar`s.
//! * **allocation-free submission** — a job is a raw fat pointer to the
//!   caller's closure plus three counters written into a pre-existing
//!   slot; nothing is boxed, queued or cloned per call.
//! * **never deadlocks** — nested `parallel_for` calls (from inside a pool
//!   task) and concurrent submissions from other threads fall back to
//!   inline serial execution instead of waiting on the busy pool.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, PoisonError};

use crate::error::XgenError;

/// Worker-thread count resolved once per process: `XGEN_THREADS` if set to
/// a positive integer, else `std::thread::available_parallelism()`. Every
/// thread-count decision in the crate (GEMM band split, FKW filter bands,
/// workspace scratch sizing) goes through this single cached read — the
/// per-call `available_parallelism` lookups of the PR-1 engine are gone.
pub fn configured_threads() -> usize {
    static THREADS: OnceLock<usize> = OnceLock::new();
    *THREADS.get_or_init(|| {
        std::env::var("XGEN_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&t| t >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
            })
    })
}

/// The process-wide pool (size [`configured_threads`]), built on first use.
pub fn global() -> &'static ThreadPool {
    static POOL: OnceLock<ThreadPool> = OnceLock::new();
    POOL.get_or_init(|| ThreadPool::new(configured_threads()))
}

thread_local! {
    /// True while this thread is executing inside a pool task (or is a
    /// pool worker): nested submissions run inline instead of deadlocking
    /// on the single job slot.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

/// Lifetime-erased pointer to the submitted closure. Valid strictly while
/// the owning `parallel_for` frame is blocked waiting for the job to
/// drain, which is the only time workers dereference it.
#[derive(Clone, Copy)]
struct JobFn(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (bound on submission) and the pointer is
// only dereferenced between job installation and the final `pending == 0`
// handshake, during which the submitting stack frame keeps it alive.
unsafe impl Send for JobFn {}
unsafe impl Sync for JobFn {}

#[derive(Clone, Copy)]
struct Job {
    f: JobFn,
    tasks: usize,
    /// Next unclaimed task index.
    next: usize,
    /// Tasks claimed but not yet finished + tasks unclaimed.
    pending: usize,
    /// Set when any task panicked (the panic is caught on the executing
    /// thread so the job still drains); the submitter re-raises it.
    panicked: bool,
}

#[derive(Default)]
struct State {
    job: Option<Job>,
}

struct Shared {
    state: Mutex<State>,
    /// Signaled when a job with unclaimed tasks is installed.
    work: Condvar,
    /// Signaled when a job's last task finishes.
    done: Condvar,
}

/// A persistent worker pool. See the [module docs](self); normally
/// accessed through [`global`] rather than constructed directly.
pub struct ThreadPool {
    shared: Arc<Shared>,
    /// Spawned worker threads (the submitter is the +1th participant).
    workers: usize,
}

impl ThreadPool {
    /// Pool with `size` total participants: `size - 1` persistent workers
    /// plus the submitting thread.
    pub fn new(size: usize) -> ThreadPool {
        let shared = Arc::new(Shared {
            state: Mutex::new(State::default()),
            work: Condvar::new(),
            done: Condvar::new(),
        });
        let workers = size.max(1) - 1;
        for w in 0..workers {
            let sh = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("xgen-pool-{w}"))
                .spawn(move || worker_loop(&sh))
                .expect("spawn pool worker");
        }
        ThreadPool { shared, workers }
    }

    /// Total participants (spawned workers + the submitting thread).
    pub fn size(&self) -> usize {
        self.workers + 1
    }

    /// Run `f(0..tasks)` across the pool; returns when every invocation
    /// has finished. The submitting thread executes tasks too. Falls back
    /// to an inline serial loop when the pool is busy, the call is nested
    /// inside another pool task, or there is nothing to parallelize —
    /// so it is always safe to call, never deadlocks, and performs no
    /// heap allocation.
    ///
    /// A panicking task no longer kills a worker or wedges the job: every
    /// task runs under `catch_unwind`, the job drains fully, and the panic
    /// is re-raised here on the submitting thread. Serving paths that must
    /// survive use [`ThreadPool::try_parallel_for`] instead.
    pub fn parallel_for<F: Fn(usize) + Sync>(&self, tasks: usize, f: F) {
        if self.run(tasks, &f) {
            // Propagate like the `thread::scope` this pool replaced: the
            // caller observes the failure, and the pool stays usable (the
            // worker caught the panic and the job slot is cleared).
            panic!("a pool task panicked (see worker output above)");
        }
    }

    /// [`ThreadPool::parallel_for`] for callers that must outlive a bad
    /// task: a task panic surfaces as [`XgenError::WorkerPanic`] instead
    /// of re-panicking. Every task still runs (panicking ones are caught
    /// individually), the pool stays usable, and the unfaulted path stays
    /// allocation-free (`catch_unwind` costs nothing on success).
    pub fn try_parallel_for<F: Fn(usize) + Sync>(&self, tasks: usize, f: F) -> Result<(), XgenError> {
        if self.run(tasks, &f) {
            Err(XgenError::WorkerPanic {
                detail: "a pool task panicked (caught; pool self-healed)".to_string(),
            })
        } else {
            Ok(())
        }
    }

    /// Shared body of the two entry points. Returns true when any task
    /// panicked (the panic itself was caught on the executing thread).
    fn run(&self, tasks: usize, f: &(dyn Fn(usize) + Sync)) -> bool {
        if tasks == 0 {
            return false;
        }
        if tasks == 1 || self.workers == 0 || IN_POOL.with(|c| c.get()) {
            return run_inline(f, tasks);
        }
        // SAFETY: erases the closure's lifetime (fat-pointer layout is
        // identical); see `JobFn` for the validity argument.
        let fptr = JobFn(unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), *const (dyn Fn(usize) + Sync)>(f)
        });
        {
            let mut st = lock_state(&self.shared);
            if st.job.is_some() {
                // Another thread owns the pool right now: run inline
                // rather than queueing (keeps submission allocation-free
                // and deadlock-free).
                drop(st);
                return run_inline(f, tasks);
            }
            st.job = Some(Job { f: fptr, tasks, next: 0, pending: tasks, panicked: false });
        }
        PARALLEL_JOBS.fetch_add(1, Ordering::Relaxed);
        self.shared.work.notify_all();
        // Participate: claim tasks alongside the workers.
        IN_POOL.with(|c| c.set(true));
        drain(&self.shared);
        IN_POOL.with(|c| c.set(false));
        // Wait for stragglers, then clear the slot for the next job.
        let mut st = lock_state(&self.shared);
        while st.job.map(|j| j.pending > 0).unwrap_or(false) {
            st = self.shared.done.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
        let panicked = st.job.map(|j| j.panicked).unwrap_or(false);
        st.job = None;
        panicked
    }
}

/// The pool's locks are held only around counter bookkeeping, so a poisoned
/// state mutex carries no torn invariants — recover the guard instead of
/// propagating poison into every later submission.
fn lock_state(shared: &Shared) -> MutexGuard<'_, State> {
    shared.state.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Run one task on the current thread, catching its panic. Returns true on
/// success. This is the single execution point for pooled *and* inline
/// tasks, so the fault-injection hook fires identically on both paths.
fn run_task(f: &(dyn Fn(usize) + Sync), i: usize) -> bool {
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        #[cfg(feature = "fault-injection")]
        crate::runtime::fault::on_parallel_task();
        f(i);
    }))
    .is_ok()
}

/// Serial fallback: run every task (a panicking one is caught and the rest
/// still run, matching pooled semantics). Returns true when any panicked.
fn run_inline(f: &(dyn Fn(usize) + Sync), tasks: usize) -> bool {
    let mut panicked = false;
    for i in 0..tasks {
        if !run_task(f, i) {
            panicked = true;
        }
    }
    panicked
}

/// Claim and run tasks from the current job until none are unclaimed.
/// Must be called with the state lock **not** held.
fn drain(shared: &Shared) {
    let mut st = lock_state(shared);
    loop {
        let Some(job) = st.job.as_mut() else { return };
        if job.next >= job.tasks {
            return;
        }
        let i = job.next;
        job.next += 1;
        let f = job.f;
        drop(st);
        // SAFETY: pending > 0 keeps the submitter (and thus the closure)
        // alive until after we decrement below. The catch_unwind inside
        // `run_task` keeps a panicking task from wedging the job (pending
        // would never reach 0) or killing a persistent worker; the
        // submitter re-raises (or returns `WorkerPanic`).
        let ok = run_task(unsafe { &*f.0 }, i);
        st = lock_state(shared);
        let job = st.job.as_mut().expect("job cleared while tasks pending");
        job.pending -= 1;
        if !ok {
            job.panicked = true;
        }
        if job.pending == 0 {
            shared.done.notify_all();
        }
    }
}

fn worker_loop(shared: &Shared) {
    IN_POOL.with(|c| c.set(true));
    loop {
        // Defense in depth: per-task panics are caught in `run_task`, so
        // `worker_body` only unwinds if the pool's own bookkeeping breaks.
        // Recover the worker in place rather than losing a lane for the
        // rest of the process, and count it so tests/ops can observe.
        if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| worker_body(shared))).is_err()
        {
            WORKER_RESPAWNS.fetch_add(1, Ordering::Relaxed);
        }
    }
}

fn worker_body(shared: &Shared) {
    loop {
        {
            let mut st = lock_state(shared);
            while !st.job.map(|j| j.next < j.tasks).unwrap_or(false) {
                st = shared.work.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
        }
        drain(shared);
    }
}

/// A mutable buffer shared across pool tasks that each write a
/// **disjoint** region — the zero-allocation alternative to
/// `chunks_mut`-per-spawn under `thread::scope`. Generic over the element
/// (`f32` C/scratch bands in the f32 GEMM, `i8` pack scratch in the int8
/// one); the default parameter keeps the original `SharedSlice` spelling
/// working unchanged.
#[derive(Clone, Copy)]
pub struct SharedSlice<T = f32> {
    ptr: *mut T,
    len: usize,
}

// SAFETY: disjointness of the regions handed to concurrent tasks is the
// caller's obligation (documented on `slice_mut` — and *checked* by the
// debug-build claim registry below). `T: Copy` rules out drop glue, and
// the pointee is plain data owned by the submitting frame.
unsafe impl<T: Copy + Send> Send for SharedSlice<T> {}
unsafe impl<T: Copy + Send> Sync for SharedSlice<T> {}

/// Debug-only disjointness checker behind [`SharedSlice`] (ISSUE-7):
/// every `slice_mut` records its claimed `[start, start+len)` interval,
/// keyed by the buffer's base address, and panics when a claim overlaps
/// one already live in the same dispatch — turning the documented unsafe
/// contract of the GEMM/FKW row-band parallelism into a checked
/// invariant. A `SharedSlice::new` over the buffer starts a new dispatch
/// and clears the old claims (keeping their allocation, so the
/// steady-state engine stays allocation-free once every buffer's entry
/// has warmed up). Compiled out entirely in release builds; tier-1
/// `cargo test` (dev profile, `debug_assertions` on) runs with it live,
/// and the Miri CI job exercises it alongside the raw-pointer unsafe.
#[cfg(debug_assertions)]
mod claims {
    use std::collections::HashMap;
    use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError};

    static CLAIMS: OnceLock<Mutex<HashMap<usize, Vec<(usize, usize)>>>> = OnceLock::new();

    fn table() -> MutexGuard<'static, HashMap<usize, Vec<(usize, usize)>>> {
        CLAIMS
            .get_or_init(|| Mutex::new(HashMap::new()))
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
    }

    pub(super) fn reset(base: usize) {
        table().entry(base).or_default().clear();
    }

    pub(super) fn claim(base: usize, start: usize, len: usize) {
        if len == 0 {
            return;
        }
        let mut t = table();
        let v = t.entry(base).or_default();
        for &(s, l) in v.iter() {
            if start < s + l && s < start + len {
                // The registry's job is to panic: an overlap means two
                // pool tasks hold `&mut` to the same f32s right now.
                panic!(
                    "SharedSlice overlap at base {base:#x}: claim [{start}, {}) intersects live claim [{s}, {})",
                    start + len,
                    s + l
                );
            }
        }
        v.push((start, len));
    }
}

impl<T: Copy + Send> SharedSlice<T> {
    pub fn new(s: &mut [T]) -> SharedSlice<T> {
        #[cfg(debug_assertions)]
        claims::reset(s.as_mut_ptr() as usize);
        SharedSlice { ptr: s.as_mut_ptr(), len: s.len() }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Reborrow `[start, start+len)` mutably.
    ///
    /// # Safety
    /// Concurrent callers must slice **disjoint** ranges, and the backing
    /// buffer must outlive every use (guaranteed when used inside a
    /// `parallel_for` over a buffer borrowed by the submitting frame).
    /// Debug builds enforce the disjointness half through the claim
    /// registry: an overlapping claim within one dispatch panics.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, start: usize, len: usize) -> &mut [T] {
        debug_assert!(start + len <= self.len, "SharedSlice range out of bounds");
        #[cfg(debug_assertions)]
        claims::claim(self.ptr as usize, start, len);
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

/// Allocation-free observable counter for tests/benches: how many jobs
/// have actually been **installed in the pool's job slot** (incremented
/// inside [`ThreadPool::parallel_for`] only after installation — inline
/// fallbacks, nested calls and busy-pool rejections do not count). The
/// steady-state acceptance tests use it to assert GEMM/FKW bands really
/// dispatch on the pool.
pub static PARALLEL_JOBS: AtomicUsize = AtomicUsize::new(0);

/// How many times a pool worker unwound out of its dispatch loop and was
/// recovered in place (see `worker_loop`). Zero in a healthy process —
/// per-task panics are caught one level down and do **not** count here.
pub static WORKER_RESPAWNS: AtomicUsize = AtomicUsize::new(0);

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = ThreadPool::new(4);
        for tasks in [0usize, 1, 2, 7, 64, 1000] {
            let hits: Vec<AtomicUsize> = (0..tasks).map(|_| AtomicUsize::new(0)).collect();
            pool.parallel_for(tasks, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "task {i} of {tasks}");
            }
        }
    }

    #[test]
    fn single_thread_pool_is_serial_and_correct() {
        let pool = ThreadPool::new(1);
        let sum = AtomicUsize::new(0);
        pool.parallel_for(100, |i| {
            sum.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 99 * 100 / 2);
    }

    #[test]
    fn nested_submission_does_not_deadlock() {
        let pool = ThreadPool::new(4);
        let count = AtomicUsize::new(0);
        pool.parallel_for(8, |_| {
            // Nested call must run inline on whichever thread executes it.
            global().parallel_for(4, |_| {
                count.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(count.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn concurrent_submitters_fall_back_inline() {
        // Many user threads hammering the single global pool: every task
        // must still run exactly once per submission, with losers of the
        // job slot running inline.
        let total = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..16 {
                        global().parallel_for(10, |_| {
                            total.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 8 * 16 * 10);
    }

    #[test]
    fn shared_slice_disjoint_writes() {
        let mut buf = vec![0.0f32; 64];
        let ss = SharedSlice::new(&mut buf);
        let pool = ThreadPool::new(4);
        pool.parallel_for(8, |t| {
            // SAFETY: task indices are distinct, so the bands
            // [t*8, t*8+8) are pairwise disjoint and within the buffer.
            let chunk = unsafe { ss.slice_mut(t * 8, 8) };
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = (t * 8 + j) as f32;
            }
        });
        for (i, v) in buf.iter().enumerate() {
            assert_eq!(*v, i as f32);
        }
    }

    #[test]
    fn try_parallel_for_reports_worker_panic_and_pool_survives() {
        let pool = ThreadPool::new(4);
        let done = AtomicUsize::new(0);
        let err = pool
            .try_parallel_for(16, |i| {
                if i == 7 {
                    panic!("task 7 exploded");
                }
                done.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap_err();
        assert_eq!(err.code(), "WorkerPanic");
        // Every non-panicking task still ran — one bad task does not
        // abort its siblings.
        assert_eq!(done.load(Ordering::Relaxed), 15);
        // The pool is immediately reusable for clean work.
        let sum = AtomicUsize::new(0);
        pool.try_parallel_for(32, |i| {
            sum.fetch_add(i, Ordering::Relaxed);
        })
        .unwrap();
        assert_eq!(sum.load(Ordering::Relaxed), 31 * 32 / 2);
    }

    #[test]
    fn parallel_for_repanics_but_pool_stays_usable() {
        let pool = ThreadPool::new(4);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.parallel_for(8, |i| {
                if i == 3 {
                    panic!("boom");
                }
            });
        }));
        assert!(caught.is_err(), "parallel_for keeps panic semantics");
        let n = AtomicUsize::new(0);
        pool.parallel_for(8, |_| {
            n.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(n.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn inline_fallbacks_report_panics_too() {
        // Single-participant pool (workers == 0) takes the inline path;
        // a nested submission takes it as well. Both must report the
        // panic instead of unwinding through the caller.
        let pool = ThreadPool::new(1);
        assert!(pool.try_parallel_for(4, |i| assert!(i != 2, "inline boom")).is_err());
        let outer = ThreadPool::new(4);
        let nested_err = AtomicUsize::new(0);
        outer
            .try_parallel_for(4, |_| {
                if global().try_parallel_for(2, |j| assert!(j != 1)).is_err() {
                    nested_err.fetch_add(1, Ordering::Relaxed);
                }
            })
            .unwrap();
        assert_eq!(nested_err.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn configured_threads_is_positive_and_stable() {
        let a = configured_threads();
        let b = configured_threads();
        assert!(a >= 1);
        assert_eq!(a, b);
        assert_eq!(global().size().max(1), global().size());
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "SharedSlice overlap")]
    fn overlapping_claims_panic_in_debug() {
        let mut buf = vec![0.0f32; 32];
        let sh = SharedSlice::new(&mut buf);
        // SAFETY: deliberately violates the disjointness contract to
        // prove the debug claim registry catches the overlap (the test
        // expects the panic; the aliased slices are never used).
        unsafe {
            let _a = sh.slice_mut(0, 16);
            let _b = sh.slice_mut(8, 16); // [8, 24) intersects [0, 16)
        }
    }

    #[cfg(debug_assertions)]
    #[test]
    fn new_dispatch_resets_claims() {
        let mut buf = vec![0.0f32; 16];
        let sh = SharedSlice::new(&mut buf);
        // SAFETY: sole claim over the whole buffer — nothing to overlap.
        unsafe {
            sh.slice_mut(0, 16)[0] = 1.0;
        }
        // Re-wrapping the same buffer starts a fresh dispatch: the full
        // range is claimable again, and zero-length claims never conflict.
        let sh2 = SharedSlice::new(&mut buf);
        // SAFETY: the zero-length claim covers no elements, so the full
        // 16-element claim that follows is the only live borrow.
        unsafe {
            let _zero = sh2.slice_mut(4, 0);
            sh2.slice_mut(0, 16)[15] = 2.0;
        }
        assert_eq!(buf[15], 2.0);
    }
}
