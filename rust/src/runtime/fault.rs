//! Deterministic fault injection (ISSUE-6 tentpole, layer 4).
//!
//! Every recovery path of the fault-tolerant serving runtime — pool panic
//! isolation, workspace-arena rebuild, steady-engine fallback, decode
//! deadline shedding — is exercised by *deterministic* tests rather than
//! hope. A [`FaultPlan`] is installed process-globally ([`install`]
//! returns a guard that clears it on drop); cheap hooks compiled into the
//! hot paths under `--features fault-injection` consult it:
//!
//! * [`on_parallel_task`] — panic the worker executing the Nth pool task
//!   (counted process-wide from the counter's current value).
//! * [`on_steady_run`] — fail (or panic) the Nth entry into the steady
//!   in-arena engine (models an injected allocation/setup failure or
//!   crash at serve time; drives the `eval_op`-path fallback and the
//!   arena rebuild).
//! * [`on_decode_node`] — fail, corrupt with NaN, or panic at the named
//!   graph node's output on its Nth evaluation inside a [`DecodeSession`]
//!   (`crate::exec::DecodeSession`) step.
//! * [`on_decode_step`] — stall each `step()` by a fixed duration (drives
//!   deadline-exceeded partial generations).
//! * [`on_stream_step`] — stream-targeted faults for the
//!   `coordinator::StreamScheduler` (ISSUE-8): fail, panic, corrupt with
//!   NaN, or stall at an exact `(stream, step)` ordinal, where `stream`
//!   is the scheduler's admission-ordered stream id and `step` 0 is the
//!   prefill. This is what the chaos matrix in `tests/streams.rs` aims
//!   with.
//!
//! With no plan installed every hook is a single relaxed atomic load —
//! the unfaulted path stays allocation-free, which is how the counting-
//! allocator tests in `tests/steady.rs` can run under
//! `--features fault-injection` too.
//!
//! The plan is process-global, so tests that install one must not run
//! concurrently with each other; `rust/tests/robustness.rs` serializes
//! them behind a file-local mutex (integration-test binaries are their
//! own processes, so other test binaries are unaffected).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

/// What to break, and when. All counters are absolute values of the
/// matching process-wide counter — use the `*_so_far()` getters to aim
/// relative to "now".
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    /// Panic inside the pool task whose global ordinal equals this value
    /// (see [`parallel_tasks_so_far`]).
    pub panic_on_parallel_task: Option<u64>,
    /// Fail the steady engine run whose global ordinal equals this value
    /// (see [`steady_runs_so_far`]).
    pub fail_steady_run: Option<u64>,
    /// Panic inside the steady engine run whose global ordinal equals
    /// this value — drives the api-layer catch-unwind + arena-rebuild
    /// path deterministically (unlike pool-task panics, which require a
    /// matrix large enough to be banded across workers).
    pub panic_steady_run: Option<u64>,
    /// `(node name, k)`: make the named decode node return an error on
    /// the k-th time (1-based) it is evaluated after installation.
    pub fail_decode_node: Option<(String, u64)>,
    /// `(node name, k)`: overwrite the named decode node's output with
    /// NaN on the k-th time (1-based) it is evaluated after installation.
    pub nan_decode_node: Option<(String, u64)>,
    /// `(node name, k)`: panic while the named decode node is evaluated,
    /// on the k-th time (1-based) after installation — drives the decode
    /// server's catch-unwind + session-rebuild path.
    pub panic_decode_node: Option<(String, u64)>,
    /// Sleep this many milliseconds inside every `DecodeSession::step`.
    pub stall_step_ms: Option<u64>,
    /// Stream-targeted faults for the stream scheduler, each aimed at an
    /// exact `(stream, step)` ordinal. Multiple entries may target
    /// different streams in the same plan — that is what makes the chaos
    /// matrix a *matrix*.
    pub stream_faults: Vec<StreamFault>,
}

/// One stream-scheduler fault: break stream `stream` at step `step`.
#[derive(Debug, Clone)]
pub struct StreamFault {
    /// The scheduler's admission-ordered stream ordinal (0-based, in
    /// submission order — stable regardless of interleaving).
    pub stream: u64,
    /// Step ordinal within the stream: 0 is the prefill, `k` the k-th
    /// decode step after it.
    pub step: u64,
    pub kind: StreamFaultKind,
}

/// How a targeted stream step breaks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamFaultKind {
    /// The hook returns `Err` — the stream fails with a typed error while
    /// its session stays structurally sound (reset suffices).
    Fail,
    /// The hook panics — drives the scheduler's per-stream
    /// `catch_unwind` + session-rebuild path.
    Panic,
    /// The scheduler is told to overwrite the step's logits with NaN —
    /// drives the `NonFinite` guard.
    Nan,
    /// Sleep this many milliseconds before the step runs — drives the
    /// deadline watchdog's mid-generation eviction.
    Stall(u64),
}

/// What [`on_stream_step`] asks the scheduler to do after it returns.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StreamFaultEffect {
    /// Proceed normally.
    #[default]
    None,
    /// Overwrite the logits produced by this step with NaN.
    Nan,
}

/// Fast-path gate: hooks return immediately while this is false.
static ACTIVE: AtomicBool = AtomicBool::new(false);
/// Process-wide ordinals (monotone, never reset — plans aim at absolute
/// values read off the getters).
static PARALLEL_TASKS: AtomicU64 = AtomicU64::new(0);
static STEADY_RUNS: AtomicU64 = AtomicU64::new(0);
/// Per-plan decode-node evaluation counter (reset by [`install`]).
static DECODE_NODE_HITS: AtomicU64 = AtomicU64::new(0);

static PLAN: Mutex<Option<FaultPlan>> = Mutex::new(None);

fn plan_lock() -> std::sync::MutexGuard<'static, Option<FaultPlan>> {
    // A panic while holding the plan lock (only possible inside an
    // injected-panic hook) must not wedge every later hook.
    PLAN.lock().unwrap_or_else(|p| p.into_inner())
}

/// Install a plan; faults fire until the returned guard drops (or
/// [`clear`] runs). Installing resets the per-plan decode-node counter.
#[must_use = "faults clear when the guard drops"]
pub fn install(plan: FaultPlan) -> FaultGuard {
    DECODE_NODE_HITS.store(0, Ordering::SeqCst);
    *plan_lock() = Some(plan);
    ACTIVE.store(true, Ordering::SeqCst);
    FaultGuard { _priv: () }
}

/// Remove the active plan (idempotent).
pub fn clear() {
    ACTIVE.store(false, Ordering::SeqCst);
    *plan_lock() = None;
}

/// Clears the installed plan on drop.
pub struct FaultGuard {
    _priv: (),
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        clear();
    }
}

/// Pool tasks executed so far, process-wide — aim
/// [`FaultPlan::panic_on_parallel_task`] at `this + k`.
pub fn parallel_tasks_so_far() -> u64 {
    PARALLEL_TASKS.load(Ordering::SeqCst)
}

/// Steady-engine runs entered so far, process-wide.
pub fn steady_runs_so_far() -> u64 {
    STEADY_RUNS.load(Ordering::SeqCst)
}

/// Hook: called once per claimed pool task, before the task closure runs.
/// Panics (on the executing thread — a worker or the submitting thread)
/// when the task's ordinal matches the plan.
pub fn on_parallel_task() {
    let n = PARALLEL_TASKS.fetch_add(1, Ordering::SeqCst);
    if !ACTIVE.load(Ordering::Relaxed) {
        return;
    }
    let hit = plan_lock()
        .as_ref()
        .and_then(|p| p.panic_on_parallel_task)
        .is_some_and(|at| at == n);
    if hit {
        panic!("injected fault: worker panic at pool task {n}");
    }
}

/// Hook: called once per steady-engine run entry. `Err` models a
/// serve-time setup/allocation failure; the caller degrades to the
/// reference path.
pub fn on_steady_run() -> Result<(), String> {
    let n = STEADY_RUNS.fetch_add(1, Ordering::SeqCst);
    if !ACTIVE.load(Ordering::Relaxed) {
        return Ok(());
    }
    let guard = plan_lock();
    let Some(plan) = guard.as_ref() else { return Ok(()) };
    if plan.fail_steady_run.is_some_and(|at| at == n) {
        return Err(format!("injected fault: steady engine failure at run {n}"));
    }
    if plan.panic_steady_run.is_some_and(|at| at == n) {
        drop(guard);
        panic!("injected fault: steady engine panic at run {n}");
    }
    Ok(())
}

/// Hook: called after a decode node evaluates, with its freshly written
/// output. May fail the node or corrupt the output with NaN, per plan.
pub fn on_decode_node(name: &str, out: &mut [f32]) -> Result<(), String> {
    if !ACTIVE.load(Ordering::Relaxed) {
        return Ok(());
    }
    let guard = plan_lock();
    let Some(plan) = guard.as_ref() else { return Ok(()) };
    if let Some((target, k)) = &plan.fail_decode_node {
        if target == name {
            let n = DECODE_NODE_HITS.fetch_add(1, Ordering::SeqCst) + 1;
            if n == *k {
                return Err(format!("injected fault: decode node '{name}' failed (hit {n})"));
            }
        }
    }
    if let Some((target, k)) = &plan.nan_decode_node {
        if target == name {
            let n = DECODE_NODE_HITS.fetch_add(1, Ordering::SeqCst) + 1;
            if n == *k {
                out.fill(f32::NAN);
            }
        }
    }
    if let Some((target, k)) = &plan.panic_decode_node {
        if target == name {
            let n = DECODE_NODE_HITS.fetch_add(1, Ordering::SeqCst) + 1;
            if n == *k {
                // Release the plan lock before unwinding so later hooks
                // (and the clearing guard) never contend with a poisoned
                // holder.
                drop(guard);
                panic!("injected fault: decode node '{name}' panicked (hit {n})");
            }
        }
    }
    Ok(())
}

/// Hook: called by the stream scheduler once per scheduled unit of work
/// (`step` 0 = the prefill, then one call per decode step) with the
/// stream's admission ordinal. Fails, panics, stalls, or requests NaN
/// corruption when an installed [`StreamFault`] matches exactly.
pub fn on_stream_step(stream: u64, step: u64) -> Result<StreamFaultEffect, String> {
    if !ACTIVE.load(Ordering::Relaxed) {
        return Ok(StreamFaultEffect::None);
    }
    let guard = plan_lock();
    let hit = guard
        .as_ref()
        .and_then(|p| p.stream_faults.iter().find(|f| f.stream == stream && f.step == step))
        .map(|f| f.kind);
    let Some(kind) = hit else { return Ok(StreamFaultEffect::None) };
    // Release the plan lock before sleeping or unwinding so concurrent
    // hooks (and the clearing guard) never contend with the holder.
    drop(guard);
    match kind {
        StreamFaultKind::Fail => {
            Err(format!("injected fault: stream {stream} failed at step {step}"))
        }
        StreamFaultKind::Panic => {
            panic!("injected fault: stream {stream} panicked at step {step}");
        }
        StreamFaultKind::Nan => Ok(StreamFaultEffect::Nan),
        StreamFaultKind::Stall(ms) => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            Ok(StreamFaultEffect::None)
        }
    }
}

/// Hook: called once per `DecodeSession::step` (not per prefill
/// position). Stalls when the plan says so.
pub fn on_decode_step() {
    if !ACTIVE.load(Ordering::Relaxed) {
        return;
    }
    let stall = plan_lock().as_ref().and_then(|p| p.stall_step_ms);
    if let Some(ms) = stall {
        std::thread::sleep(std::time::Duration::from_millis(ms));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The hooks are no-ops (and panic-free) with no plan installed, and
    /// the guard clears the plan on drop. The panic/stall behaviors are
    /// exercised end-to-end in `tests/robustness.rs` (its own process).
    #[test]
    fn hooks_are_inert_without_a_plan_and_guard_clears() {
        clear();
        on_parallel_task();
        assert!(on_steady_run().is_ok());
        let mut buf = [1.0f32; 4];
        assert!(on_decode_node("any", &mut buf).is_ok());
        assert_eq!(buf, [1.0f32; 4]);
        on_decode_step();
        assert_eq!(on_stream_step(0, 0), Ok(StreamFaultEffect::None));
        {
            let _g = install(FaultPlan {
                stream_faults: vec![StreamFault {
                    stream: 3,
                    step: 1,
                    kind: StreamFaultKind::Fail,
                }],
                ..Default::default()
            });
            // Exact-match targeting: neighbours are untouched.
            assert_eq!(on_stream_step(3, 0), Ok(StreamFaultEffect::None));
            assert_eq!(on_stream_step(2, 1), Ok(StreamFaultEffect::None));
            assert!(on_stream_step(3, 1).is_err(), "targeted ordinal fails");
        }
        {
            let _g = install(FaultPlan {
                nan_decode_node: Some(("x".into(), 1)),
                ..Default::default()
            });
            let mut buf = [1.0f32; 2];
            on_decode_node("x", &mut buf).unwrap();
            assert!(buf.iter().all(|v| v.is_nan()), "first hit injects NaN");
        }
        // Guard dropped: inert again.
        let mut buf = [1.0f32; 2];
        on_decode_node("x", &mut buf).unwrap();
        assert_eq!(buf, [1.0f32; 2]);
    }

    #[test]
    fn ordinals_are_monotone() {
        let a = parallel_tasks_so_far();
        on_parallel_task();
        assert!(parallel_tasks_so_far() > a);
        let s = steady_runs_so_far();
        let _ = on_steady_run();
        assert!(steady_runs_so_far() > s);
    }
}
