//! Cache-blocked, multi-threaded f32 GEMM — the generated-code hot path of
//! the reproduction (§2.3 of the paper: loop tiling, unrolling and
//! pack-based data layout are what make XGen's kernels "several times"
//! faster than naive loops; CoCoPIE/PatDNN make the same tiled/packed GEMM
//! micro-kernel the centerpiece of their mobile code generators).
//!
//! Structure is the classic three-level blocking (BLIS/GotoBLAS):
//!
//! ```text
//! for jc in 0..n step NC          // B panel column block   (L3 resident)
//!   for pc in 0..k step KC        // K panel                (packed B: L2)
//!     pack B[pc..pc+KC, jc..jc+NC] into NR-column slivers
//!     for ic in 0..m step MC      // A panel row block      (packed A: L1/L2)
//!       pack A[ic..ic+MC, pc..pc+KC] into MR-row slivers
//!       for jr, ir: micro-kernel on an MR x NR register tile
//! ```
//!
//! The micro-kernel is written over fixed-size array refs (`&[f32; NR]`)
//! with a fully unrolled `MR x NR` accumulator so LLVM auto-vectorizes it —
//! no intrinsics, no dependencies. Parallelism splits the M dimension into
//! disjoint row bands of C dispatched on the **persistent worker pool**
//! ([`crate::runtime::pool`]) — nothing is spawned or joined per call.
//! Constant operands can be packed once at compile time ([`PackedB`]) and
//! multiplied through [`gemm_prepacked`], which with caller-provided A
//! scratch performs no heap allocation at all — the steady-state
//! inference configuration.
//!
//! Unlike the old `Tensor::matmul` triple loop, the dense path has **no
//! per-element sparsity branch** (`if a == 0.0 { continue }`): exploiting
//! zeros belongs to the FKW pattern kernels ([`crate::fkw`]), not the dense
//! micro-kernel, where the branch defeats vectorization (this is exactly
//! the paper's Fig 6 argument about irregular sparsity).

/// Register-tile height of the micro-kernel (rows of C per invocation).
pub const MR: usize = 4;

/// Tunable blocking parameters of the engine. The `xengine` knob layer
/// ([`crate::xengine::knobs::gemm_ladder`]) exposes named settings of this
/// struct, and `benches/fig6_blocksize.rs` sweeps them against the cost
/// model's traffic predictions.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GemmConfig {
    /// Row-panel height of packed A (MC).
    pub mc: usize,
    /// Depth of the K panel shared by packed A and B (KC).
    pub kc: usize,
    /// Column-panel width of packed B (NC).
    pub nc: usize,
    /// Register-tile width NR; supported values are 4 and 8 (anything else
    /// falls back to 8).
    pub nr: usize,
    /// Worker threads over the M dimension; 0 = auto-detect.
    pub threads: usize,
}

impl Default for GemmConfig {
    fn default() -> Self {
        GemmConfig { mc: 64, kc: 256, nc: 256, nr: 8, threads: 0 }
    }
}

impl GemmConfig {
    /// `threads` with 0 resolved to the pool size — a single cached env
    /// read ([`crate::runtime::pool::configured_threads`], `XGEN_THREADS`),
    /// not a per-call `available_parallelism` lookup.
    pub fn resolved_threads(&self) -> usize {
        if self.threads == 0 {
            crate::runtime::pool::configured_threads()
        } else {
            self.threads
        }
    }

    /// [`GemmConfig::resolved_threads`] bounded by the number of MR-row
    /// bands so tiny matrices never over-split. Shared with the int8
    /// kernel ([`super::qgemm`]) so both split work identically.
    pub(crate) fn effective_threads(&self, m: usize, k: usize, n: usize) -> usize {
        // Below ~1 MFLOP the handoff overhead dominates any speedup. Under
        // Miri the cutoff drops so tiny test shapes still exercise the
        // parallel unsafe path (SharedSlice bands) at interpretable cost.
        let cutoff: usize = if cfg!(miri) { 1 << 8 } else { 1 << 19 };
        if (m * k).saturating_mul(n) < cutoff {
            return 1;
        }
        self.resolved_threads().min((m + MR - 1) / MR).max(1)
    }
}

/// `C = A * B` for row-major `A [m, k]`, `B [k, n]`, `C [m, n]`.
/// `c` is overwritten (not accumulated into). Panics on slice-length
/// mismatches.
pub fn gemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32], cfg: &GemmConfig) {
    assert_eq!(a.len(), m * k, "gemm: A length");
    assert_eq!(b.len(), k * n, "gemm: B length");
    assert_eq!(c.len(), m * n, "gemm: C length");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        c.fill(0.0);
        return;
    }
    let threads = cfg.effective_threads(m, k, n);
    if threads <= 1 {
        gemm_band(m, k, n, a, b, c, cfg);
        return;
    }
    // Split C (and the matching rows of A) into contiguous row bands, one
    // per worker. Bands are multiples of MR so no band ends mid-tile.
    // Tradeoff: each band independently re-packs the B panels it visits
    // (B traffic scales with the worker count). That keeps the workers
    // fully unsynchronized — no shared pack buffer, no barrier — at the
    // cost of extra bandwidth; `cost::gemm_blocked_traffic_bytes` models
    // the single-band case, so its B term is per-band here.
    //
    // Bands run on the persistent worker pool — nothing is spawned per
    // call (the PR-1 `thread::scope` spawn/join is gone from the hot path).
    let (rows_per, bands) = band_split(m, threads);
    let c_sh = crate::runtime::pool::SharedSlice::new(c);
    crate::runtime::pool::global().parallel_for(bands, |t| {
        let row0 = t * rows_per;
        let rows = rows_per.min(m - row0);
        let a_band = &a[row0 * k..(row0 + rows) * k];
        // SAFETY: bands are disjoint row ranges of C.
        let c_band = unsafe { c_sh.slice_mut(row0 * n, rows * n) };
        gemm_band(rows, k, n, a_band, b, c_band, cfg);
    });
}

/// Row-band split for `threads` workers: MR-aligned band height and the
/// resulting band count (≤ `threads`).
pub(crate) fn band_split(m: usize, threads: usize) -> (usize, usize) {
    let per = (m + threads - 1) / threads;
    let rows_per = ((per + MR - 1) / MR) * MR;
    (rows_per, (m + rows_per - 1) / rows_per)
}

/// Single-threaded blocked GEMM over one row band of C.
fn gemm_band(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32], cfg: &GemmConfig) {
    let mc = cfg.mc.max(MR);
    let kc = cfg.kc.max(1);
    let nc = cfg.nc.max(1);
    let nr = if cfg.nr == 4 { 4 } else { 8 };

    c.fill(0.0);
    // Pack buffers sized for the largest panel; pack routines rewrite the
    // full used prefix (zero padding included) on every refill.
    let mut a_pack = vec![0.0f32; padded(mc, MR) * kc];
    let mut b_pack = vec![0.0f32; padded(nc.min(n), nr) * kc];

    let mut jc = 0;
    while jc < n {
        let ncb = nc.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kcb = kc.min(k - pc);
            pack_b(b, n, pc, jc, kcb, ncb, nr, &mut b_pack);
            let mut ic = 0;
            while ic < m {
                let mcb = mc.min(m - ic);
                pack_a(a, k, ic, pc, mcb, kcb, &mut a_pack);
                run_panel(c, n, ic, jc, mcb, ncb, kcb, nr, &a_pack, &b_pack);
                ic += mc;
            }
            pc += kc;
        }
        jc += nc;
    }
}

/// Micro loops over one packed (A panel, B panel) pair: accumulate the
/// `mcb x ncb` block of C whose top-left corner is `(ic, jc)`.
#[allow(clippy::too_many_arguments)]
fn run_panel(
    c: &mut [f32],
    n: usize,
    ic: usize,
    jc: usize,
    mcb: usize,
    ncb: usize,
    kcb: usize,
    nr: usize,
    a_pack: &[f32],
    b_pack: &[f32],
) {
    let mut jr = 0;
    while jr < ncb {
        let nrb = nr.min(ncb - jr);
        let b_sliver = &b_pack[(jr / nr) * kcb * nr..(jr / nr + 1) * kcb * nr];
        let mut ir = 0;
        while ir < mcb {
            let mrb = MR.min(mcb - ir);
            let a_sliver = &a_pack[(ir / MR) * kcb * MR..(ir / MR + 1) * kcb * MR];
            if nr == 8 {
                let mut acc = [[0.0f32; 8]; MR];
                microkernel_8(kcb, a_sliver, b_sliver, &mut acc);
                for i in 0..mrb {
                    let crow = (ic + ir + i) * n + jc + jr;
                    for j in 0..nrb {
                        c[crow + j] += acc[i][j];
                    }
                }
            } else {
                let mut acc = [[0.0f32; 4]; MR];
                microkernel_4(kcb, a_sliver, b_sliver, &mut acc);
                for i in 0..mrb {
                    let crow = (ic + ir + i) * n + jc + jr;
                    for j in 0..nrb {
                        c[crow + j] += acc[i][j];
                    }
                }
            }
            ir += MR;
        }
        jr += nr;
    }
}

/// A constant B operand packed **once** (at `Compiler::compile` time) into
/// the NR-column sliver layout the micro-kernel consumes — the per-call
/// `pack_b` traffic of the PR-1 engine disappears from the inference hot
/// path, and with [`gemm_prepacked`] + caller-provided A scratch the whole
/// GEMM is allocation-free (§2.3's "all expensive analysis at compile
/// time" applied to data layout).
///
/// Panels are stored in `(jc, pc)` order, matching the loop nest of
/// [`gemm`], and the pack-time blocking (`kc`, `nc`, `nr`) travels with
/// the data so the consuming call can never mismatch the layout.
#[derive(Debug, Clone)]
pub struct PackedB {
    /// Logical shape of the packed operand: `[k, n]`.
    pub k: usize,
    pub n: usize,
    kc: usize,
    nc: usize,
    nr: usize,
    /// Panel start offsets in `(jc, pc)` order, with a trailing sentinel
    /// equal to `data.len()`.
    panel_off: Vec<usize>,
    data: Vec<f32>,
}

impl PackedB {
    /// Pack row-major `b [k, n]` under `cfg`'s blocking parameters.
    pub fn pack(k: usize, n: usize, b: &[f32], cfg: &GemmConfig) -> PackedB {
        assert_eq!(b.len(), k * n, "PackedB: B length");
        let kc = cfg.kc.max(1);
        let nc = cfg.nc.max(1);
        let nr = if cfg.nr == 4 { 4 } else { 8 };
        let mut data = Vec::new();
        let mut panel_off = Vec::new();
        let mut jc = 0;
        while jc < n {
            let ncb = nc.min(n - jc);
            let mut pc = 0;
            while pc < k {
                let kcb = kc.min(k - pc);
                panel_off.push(data.len());
                let start = data.len();
                data.resize(start + padded(ncb, nr) * kcb, 0.0);
                pack_b(b, n, pc, jc, kcb, ncb, nr, &mut data[start..]);
                pc += kc;
            }
            jc += nc;
        }
        panel_off.push(data.len());
        PackedB { k, n, kc, nc, nr, panel_off, data }
    }

    /// Packed bytes held (the compile-time memory cost of pre-packing).
    pub fn bytes(&self) -> u64 {
        self.data.len() as u64 * 4
    }

    /// The packed panel at column block `jci`, K block `pci`.
    fn panel(&self, jci: usize, pci: usize) -> &[f32] {
        let n_pc = (self.k + self.kc - 1) / self.kc;
        let idx = jci * n_pc + pci;
        &self.data[self.panel_off[idx]..self.panel_off[idx + 1]]
    }
}

/// Per-band A-pack scratch (in f32 elements) that [`gemm_prepacked`]
/// needs under `cfg`; multiply by [`GemmConfig::resolved_threads`] for a
/// buffer that covers every band of a parallel call.
pub fn prepacked_scratch_elems(cfg: &GemmConfig) -> usize {
    padded(cfg.mc.max(MR), MR) * cfg.kc.max(1)
}

/// `C = A * packed_B` — the steady-state GEMM entry point: B was packed at
/// compile time ([`PackedB`]), A panels pack into the caller's `scratch`
/// (≥ `prepacked_scratch_elems(cfg) * resolved_threads` elements), row
/// bands run on the persistent pool. Performs **no** heap allocation and
/// spawns **no** threads. `cfg` must carry the same blocking parameters B
/// was packed with (asserted).
pub fn gemm_prepacked(
    m: usize,
    a: &[f32],
    pb: &PackedB,
    c: &mut [f32],
    cfg: &GemmConfig,
    scratch: &mut [f32],
) {
    let (k, n) = (pb.k, pb.n);
    assert_eq!(a.len(), m * k, "gemm_prepacked: A length");
    assert_eq!(c.len(), m * n, "gemm_prepacked: C length");
    assert_eq!(pb.kc, cfg.kc.max(1), "gemm_prepacked: KC mismatch vs pack time");
    assert_eq!(pb.nc, cfg.nc.max(1), "gemm_prepacked: NC mismatch vs pack time");
    assert_eq!(pb.nr, if cfg.nr == 4 { 4 } else { 8 }, "gemm_prepacked: NR mismatch");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        c.fill(0.0);
        return;
    }
    let per = prepacked_scratch_elems(cfg);
    let threads = cfg.effective_threads(m, k, n);
    if threads <= 1 {
        gemm_band_prepacked(m, a, pb, c, cfg, &mut scratch[..per]);
        return;
    }
    let (rows_per, bands) = band_split(m, threads);
    assert!(
        scratch.len() >= per * bands,
        "gemm_prepacked: scratch {} < {} elems for {} bands",
        scratch.len(),
        per * bands,
        bands
    );
    let c_sh = crate::runtime::pool::SharedSlice::new(c);
    let s_sh = crate::runtime::pool::SharedSlice::new(scratch);
    crate::runtime::pool::global().parallel_for(bands, |t| {
        let row0 = t * rows_per;
        let rows = rows_per.min(m - row0);
        let a_band = &a[row0 * k..(row0 + rows) * k];
        // SAFETY: disjoint row bands of C; disjoint per-band scratch.
        let c_band = unsafe { c_sh.slice_mut(row0 * n, rows * n) };
        let a_pack = unsafe { s_sh.slice_mut(t * per, per) };
        gemm_band_prepacked(rows, a_band, pb, c_band, cfg, a_pack);
    });
}

/// Single-threaded prepacked GEMM over one row band of C.
fn gemm_band_prepacked(
    m: usize,
    a: &[f32],
    pb: &PackedB,
    c: &mut [f32],
    cfg: &GemmConfig,
    a_pack: &mut [f32],
) {
    let (k, n) = (pb.k, pb.n);
    let mc = cfg.mc.max(MR);
    let (kc, nc, nr) = (pb.kc, pb.nc, pb.nr);
    c.fill(0.0);
    let mut jc = 0;
    let mut jci = 0;
    while jc < n {
        let ncb = nc.min(n - jc);
        let mut pc = 0;
        let mut pci = 0;
        while pc < k {
            let kcb = kc.min(k - pc);
            let b_pack = pb.panel(jci, pci);
            let mut ic = 0;
            while ic < m {
                let mcb = mc.min(m - ic);
                pack_a(a, k, ic, pc, mcb, kcb, a_pack);
                run_panel(c, n, ic, jc, mcb, ncb, kcb, nr, a_pack, b_pack);
                ic += mc;
            }
            pc += kc;
            pci += 1;
        }
        jc += nc;
        jci += 1;
    }
}

/// Round `x` up to a multiple of `to`.
pub(crate) fn padded(x: usize, to: usize) -> usize {
    ((x + to - 1) / to) * to
}

/// Pack `A[ic..ic+mcb, pc..pc+kcb]` into MR-row slivers: sliver `s` holds
/// rows `ic+s*MR..` in column-major order (`a_pack[s*kcb*MR + p*MR + i]`),
/// zero-padded to a full MR in the last sliver.
fn pack_a(a: &[f32], k: usize, ic: usize, pc: usize, mcb: usize, kcb: usize, a_pack: &mut [f32]) {
    let slivers = (mcb + MR - 1) / MR;
    for s in 0..slivers {
        let base = s * kcb * MR;
        for p in 0..kcb {
            for i in 0..MR {
                let row = s * MR + i;
                a_pack[base + p * MR + i] = if row < mcb {
                    a[(ic + row) * k + pc + p]
                } else {
                    0.0
                };
            }
        }
    }
}

/// Pack `B[pc..pc+kcb, jc..jc+ncb]` into NR-column slivers: sliver `t`
/// holds columns `jc+t*nr..` row-major within the sliver
/// (`b_pack[t*kcb*nr + p*nr + j]`), zero-padded to a full NR.
#[allow(clippy::too_many_arguments)]
fn pack_b(
    b: &[f32],
    n: usize,
    pc: usize,
    jc: usize,
    kcb: usize,
    ncb: usize,
    nr: usize,
    b_pack: &mut [f32],
) {
    let slivers = (ncb + nr - 1) / nr;
    for t in 0..slivers {
        let base = t * kcb * nr;
        for p in 0..kcb {
            let brow = (pc + p) * n + jc;
            for j in 0..nr {
                let col = t * nr + j;
                b_pack[base + p * nr + j] = if col < ncb { b[brow + col] } else { 0.0 };
            }
        }
    }
}

/// MR x 8 register-tile micro-kernel over a K-depth of `kc`. The fixed-size
/// array refs give LLVM exact trip counts, so the inner two loops unroll
/// and vectorize.
#[inline(always)]
fn microkernel_8(kc: usize, a: &[f32], b: &[f32], acc: &mut [[f32; 8]; MR]) {
    for p in 0..kc {
        let ap: &[f32; MR] = (&a[p * MR..p * MR + MR]).try_into().unwrap();
        let bp: &[f32; 8] = (&b[p * 8..p * 8 + 8]).try_into().unwrap();
        for i in 0..MR {
            let ai = ap[i];
            for j in 0..8 {
                acc[i][j] += ai * bp[j];
            }
        }
    }
}

/// MR x 4 variant for the narrow-register knob setting.
#[inline(always)]
fn microkernel_4(kc: usize, a: &[f32], b: &[f32], acc: &mut [[f32; 4]; MR]) {
    for p in 0..kc {
        let ap: &[f32; MR] = (&a[p * MR..p * MR + MR]).try_into().unwrap();
        let bp: &[f32; 4] = (&b[p * 4..p * 4 + 4]).try_into().unwrap();
        for i in 0..MR {
            let ai = ap[i];
            for j in 0..4 {
                acc[i][j] += ai * bp[j];
            }
        }
    }
}

/// Reference triple-loop GEMM — the oracle every blocked/parallel result is
/// property-tested against (and the "naive" baseline of
/// `benches/gemm_blocked.rs`).
pub fn gemm_naive(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32]) {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    assert_eq!(c.len(), m * n);
    c.fill(0.0);
    for i in 0..m {
        for p in 0..k {
            let av = a[i * k + p];
            let brow = &b[p * n..(p + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::forall;
    use crate::util::rng::Rng;

    fn max_abs_diff(x: &[f32], y: &[f32]) -> f32 {
        x.iter().zip(y).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max)
    }

    /// Satellite acceptance: blocked/parallel results match the naive
    /// oracle within 1e-3 on shapes that are NOT multiples of any tile
    /// size (M/N/K drawn from {1, 7, 33, 129}).
    #[test]
    #[cfg_attr(miri, ignore)] // heavy property sweep; Miri runs the tiny-shape soundness test instead
    fn blocked_matches_naive_on_odd_shapes() {
        let dims = [1usize, 7, 33, 129];
        forall("blocked gemm == naive oracle", 32, |rng| {
            let m = *rng.choose(&dims);
            let k = *rng.choose(&dims);
            let n = *rng.choose(&dims);
            let a = rng.normal_vec(m * k, 0.0, 1.0);
            let b = rng.normal_vec(k * n, 0.0, 1.0);
            let mut want = vec![0.0f32; m * n];
            gemm_naive(m, k, n, &a, &b, &mut want);
            // Deliberately awkward tile sizes so every edge path runs.
            let cfg = GemmConfig {
                mc: 4 + rng.below(3) * 17,
                kc: 1 + rng.below(60),
                nc: 1 + rng.below(60),
                nr: *rng.choose(&[4usize, 8]),
                threads: 1 + rng.below(3),
            };
            let mut got = vec![0.0f32; m * n];
            gemm(m, k, n, &a, &b, &mut got, &cfg);
            let d = max_abs_diff(&want, &got);
            assert!(d <= 1e-3, "diff {d} at m={m} k={k} n={n} cfg={cfg:?}");
        });
    }

    #[test]
    #[cfg_attr(miri, ignore)] // heavy property sweep; Miri runs the tiny-shape soundness test instead
    fn parallel_matches_single_thread() {
        forall("parallel gemm == 1-thread gemm", 8, |rng| {
            // Sizes above the serial cutoff (m*k*n >= 1<<19) so the
            // pool band split actually runs for `threads: 4`.
            let (m, k, n) = (128 + rng.below(64), 64 + rng.below(32), 128 + rng.below(64));
            assert!(m * k * n >= 1 << 19);
            let a = rng.normal_vec(m * k, 0.0, 1.0);
            let b = rng.normal_vec(k * n, 0.0, 1.0);
            let one = GemmConfig { threads: 1, ..Default::default() };
            let many = GemmConfig { threads: 4, ..Default::default() };
            let mut c1 = vec![0.0f32; m * n];
            let mut c4 = vec![0.0f32; m * n];
            gemm(m, k, n, &a, &b, &mut c1, &one);
            gemm(m, k, n, &a, &b, &mut c4, &many);
            // Same band-internal association; only the band split differs,
            // and bands never split a row's accumulation.
            assert!(max_abs_diff(&c1, &c4) <= 1e-5);
        });
    }

    #[test]
    fn identity_is_exact() {
        let mut rng = Rng::new(7);
        let m = 13;
        let a = rng.normal_vec(m * m, 0.0, 1.0);
        let mut eye = vec![0.0f32; m * m];
        for i in 0..m {
            eye[i * m + i] = 1.0;
        }
        let mut c = vec![0.0f32; m * m];
        gemm(m, m, m, &a, &eye, &mut c, &GemmConfig::default());
        assert_eq!(a, c);
    }

    #[test]
    fn degenerate_dims_are_safe() {
        let cfg = GemmConfig::default();
        let mut c = vec![1.0f32; 0];
        gemm(0, 3, 0, &[], &[0.0; 0], &mut c, &cfg);
        // k == 0: C must be zeroed, not left stale.
        let mut c = vec![7.0f32; 4];
        gemm(2, 0, 2, &[], &[], &mut c, &cfg);
        assert_eq!(c, vec![0.0; 4]);
    }

    /// Satellite acceptance: the prepacked entry point matches the naive
    /// oracle on shapes that are NOT multiples of any tile size, across
    /// awkward pack-time blockings and thread counts.
    #[test]
    #[cfg_attr(miri, ignore)] // heavy property sweep; Miri runs the tiny-shape soundness test instead
    fn prepacked_matches_naive_on_odd_shapes() {
        let dims = [1usize, 7, 33, 129];
        forall("prepacked gemm == naive oracle", 32, |rng| {
            let m = *rng.choose(&dims);
            let k = *rng.choose(&dims);
            let n = *rng.choose(&dims);
            let a = rng.normal_vec(m * k, 0.0, 1.0);
            let b = rng.normal_vec(k * n, 0.0, 1.0);
            let mut want = vec![0.0f32; m * n];
            gemm_naive(m, k, n, &a, &b, &mut want);
            let cfg = GemmConfig {
                mc: 4 + rng.below(3) * 17,
                kc: 1 + rng.below(60),
                nc: 1 + rng.below(60),
                nr: *rng.choose(&[4usize, 8]),
                threads: 1 + rng.below(3),
            };
            let pb = PackedB::pack(k, n, &b, &cfg);
            let mut scratch =
                vec![0.0f32; prepacked_scratch_elems(&cfg) * cfg.resolved_threads()];
            let mut got = vec![0.0f32; m * n];
            gemm_prepacked(m, &a, &pb, &mut got, &cfg, &mut scratch);
            let d = max_abs_diff(&want, &got);
            assert!(d <= 1e-3, "diff {d} at m={m} k={k} n={n} cfg={cfg:?}");
        });
    }

    /// Prepacked and pack-on-the-fly paths agree bitwise: identical panel
    /// order, identical micro-kernel, only the time of packing differs.
    #[test]
    #[cfg_attr(miri, ignore)] // heavy property sweep; Miri runs the tiny-shape soundness test instead
    fn prepacked_is_bitwise_equal_to_packing_on_the_fly() {
        let mut rng = Rng::new(0xBB);
        for &(m, k, n) in &[(5usize, 700usize, 6usize), (33, 129, 33), (256, 64, 96)] {
            let a = rng.normal_vec(m * k, 0.0, 0.5);
            let b = rng.normal_vec(k * n, 0.0, 0.5);
            let cfg = GemmConfig { threads: 2, ..Default::default() };
            let mut plain = vec![0.0f32; m * n];
            gemm(m, k, n, &a, &b, &mut plain, &cfg);
            let pb = PackedB::pack(k, n, &b, &cfg);
            let mut scratch =
                vec![0.0f32; prepacked_scratch_elems(&cfg) * cfg.resolved_threads()];
            let mut pre = vec![0.0f32; m * n];
            gemm_prepacked(m, &a, &pb, &mut pre, &cfg, &mut scratch);
            assert_eq!(plain, pre, "[{m},{k}]x[{k},{n}]");
        }
    }

    #[test]
    fn prepacked_degenerate_dims_are_safe() {
        let cfg = GemmConfig::default();
        // k == 0: C zeroed.
        let pb = PackedB::pack(0, 2, &[], &cfg);
        let mut scratch = vec![0.0f32; prepacked_scratch_elems(&cfg)];
        let mut c = vec![7.0f32; 4];
        gemm_prepacked(2, &[], &pb, &mut c, &cfg, &mut scratch);
        assert_eq!(c, vec![0.0; 4]);
        // n == 0: nothing to do.
        let pb = PackedB::pack(3, 0, &[], &cfg);
        let mut c: Vec<f32> = Vec::new();
        gemm_prepacked(2, &[0.0; 6], &pb, &mut c, &cfg, &mut scratch);
    }

    #[test]
    #[should_panic]
    fn prepacked_rejects_blocking_mismatch() {
        let pack_cfg = GemmConfig { kc: 32, ..Default::default() };
        let run_cfg = GemmConfig { kc: 64, ..Default::default() };
        let pb = PackedB::pack(4, 4, &[0.0; 16], &pack_cfg);
        let mut scratch = vec![0.0f32; prepacked_scratch_elems(&run_cfg)];
        let mut c = vec![0.0f32; 16];
        gemm_prepacked(4, &[0.0; 16], &pb, &mut c, &run_cfg, &mut scratch);
    }

    /// Miri target: a shape above the (Miri-lowered) serial cutoff so both
    /// parallel unsafe paths — `gemm`'s C bands and `gemm_prepacked`'s C
    /// bands + per-thread scratch — run under the interpreter, checking the
    /// `SharedSlice` raw-pointer arithmetic and the debug claim registry.
    /// Under a normal build the same shape is below the cutoff and takes
    /// the serial path, which keeps this test cheap everywhere.
    #[test]
    fn parallel_paths_are_sound_on_tiny_shapes() {
        let mut rng = Rng::new(0x51);
        let (m, k, n) = (9usize, 8usize, 8usize); // 576 >= Miri cutoff (1<<8)
        let a = rng.normal_vec(m * k, 0.0, 1.0);
        let b = rng.normal_vec(k * n, 0.0, 1.0);
        let cfg = GemmConfig { threads: 3, ..Default::default() };
        let mut want = vec![0.0f32; m * n];
        gemm_naive(m, k, n, &a, &b, &mut want);
        let mut got = vec![0.0f32; m * n];
        gemm(m, k, n, &a, &b, &mut got, &cfg);
        assert!(max_abs_diff(&want, &got) <= 1e-4);
        let pb = PackedB::pack(k, n, &b, &cfg);
        let mut scratch = vec![0.0f32; prepacked_scratch_elems(&cfg) * 3];
        let mut pre = vec![0.0f32; m * n];
        gemm_prepacked(m, &a, &pb, &mut pre, &cfg, &mut scratch);
        // Prepacked and on-the-fly packing are bitwise equal by construction
        // (same panel order, same micro-kernel).
        assert_eq!(got, pre);
    }

    #[test]
    #[cfg_attr(miri, ignore)] // K=700 sweep is slow under the interpreter
    fn large_k_accumulates_accurately() {
        // K spanning several KC panels: panel-wise accumulation into C must
        // agree with the oracle.
        let mut rng = Rng::new(9);
        let (m, k, n) = (5, 700, 6);
        let a = rng.normal_vec(m * k, 0.0, 0.5);
        let b = rng.normal_vec(k * n, 0.0, 0.5);
        let mut want = vec![0.0f32; m * n];
        gemm_naive(m, k, n, &a, &b, &mut want);
        let mut got = vec![0.0f32; m * n];
        gemm(m, k, n, &a, &b, &mut got, &GemmConfig { kc: 128, threads: 1, ..Default::default() });
        assert!(max_abs_diff(&want, &got) < 1e-3);
    }
}
