//! A minimal dense f32 tensor used by the host-side executors and the
//! pruning transforms. Row-major (C order). This is deliberately not a
//! general NDArray — it implements exactly what the XGen reproduction
//! needs: shape bookkeeping, fills, elementwise maps, matmul, im2col
//! convolution, and pooling, all with straightforward reference semantics
//! so the optimized paths in [`crate::exec`] and [`crate::fkw`] have an
//! oracle to be checked against.

pub mod gemm;
pub mod qgemm;

use crate::util::rng::Rng;

use gemm::GemmConfig;

/// Dense row-major f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// Zero-filled tensor.
    pub fn zeros(shape: &[usize]) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Tensor filled with `v`.
    pub fn full(shape: &[usize], v: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: vec![v; n] }
    }

    /// Build from existing data; length must match the shape product.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    /// Gaussian-initialized tensor (DNN weight init), deterministic per rng.
    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape: shape.to_vec(), data: rng.normal_vec(n, 0.0, std) }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Reinterpret with a new shape of the same element count.
    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len(), "reshape count mismatch");
        Tensor { shape: shape.to_vec(), data: self.data.clone() }
    }

    /// Flat offset of a multi-index.
    pub fn offset(&self, idx: &[usize]) -> usize {
        debug_assert_eq!(idx.len(), self.shape.len());
        let mut off = 0;
        for (d, &i) in idx.iter().enumerate() {
            debug_assert!(i < self.shape[d], "index {i} out of bounds for dim {d}");
            off = off * self.shape[d] + i;
        }
        off
    }

    pub fn at(&self, idx: &[usize]) -> f32 {
        self.data[self.offset(idx)]
    }

    pub fn set(&mut self, idx: &[usize], v: f32) {
        let o = self.offset(idx);
        self.data[o] = v;
    }

    /// Elementwise map (new tensor).
    pub fn map<F: Fn(f32) -> f32>(&self, f: F) -> Tensor {
        Tensor { shape: self.shape.clone(), data: self.data.iter().map(|&x| f(x)).collect() }
    }

    /// Elementwise binary zip; shapes must match exactly.
    pub fn zip<F: Fn(f32, f32) -> f32>(&self, other: &Tensor, f: F) -> Tensor {
        assert_eq!(self.shape, other.shape, "zip shape mismatch");
        let data = self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect();
        Tensor { shape: self.shape.clone(), data }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a - b)
    }

    pub fn mul(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a * b)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    pub fn relu(&self) -> Tensor {
        self.map(|x| x.max(0.0))
    }

    /// Sum of all elements.
    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    /// Mean absolute difference vs another tensor (shape-checked).
    pub fn mad(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        if self.data.is_empty() {
            return 0.0;
        }
        let s: f32 = self.data.iter().zip(&other.data).map(|(a, b)| (a - b).abs()).sum();
        s / self.data.len() as f32
    }

    /// Max absolute difference.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// Fraction of zero entries (sparsity probe used by pruning tests).
    pub fn zero_fraction(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        let z = self.data.iter().filter(|&&x| x == 0.0).count();
        z as f64 / self.data.len() as f64
    }

    /// Matrix multiply: `[m,k] x [k,n] -> [m,n]`, routed through the
    /// cache-blocked, multi-threaded engine in [`gemm`]. The dense path has
    /// no per-element sparsity branch — zero exploitation lives in the FKW
    /// pattern kernels where the structure is known at compile time.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        self.matmul_with(other, &GemmConfig::default())
    }

    /// [`Tensor::matmul`] with explicit blocking parameters (the knob the
    /// `xengine` ladder and `benches/gemm_blocked.rs` turn).
    pub fn matmul_with(&self, other: &Tensor, cfg: &GemmConfig) -> Tensor {
        assert_eq!(self.rank(), 2, "matmul lhs rank");
        assert_eq!(other.rank(), 2, "matmul rhs rank");
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul inner dim mismatch");
        let mut out = vec![0.0f32; m * n];
        gemm::gemm(m, k, n, &self.data, &other.data, &mut out, cfg);
        Tensor { shape: vec![m, n], data: out }
    }

    /// Reference triple-loop matmul — the oracle the blocked engine is
    /// checked against, and the naive baseline of the GEMM benches.
    pub fn matmul_naive(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.rank(), 2, "matmul lhs rank");
        assert_eq!(other.rank(), 2, "matmul rhs rank");
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul inner dim mismatch");
        let mut out = vec![0.0f32; m * n];
        gemm::gemm_naive(m, k, n, &self.data, &other.data, &mut out);
        Tensor { shape: vec![m, n], data: out }
    }

    /// 2-D convolution, NCHW input `[n,c,h,w]`, OIHW weights `[o,i,kh,kw]`,
    /// with stride and symmetric zero padding. Reference (naive) semantics —
    /// the oracle for every optimized conv path in the crate.
    pub fn conv2d(&self, weight: &Tensor, stride: usize, pad: usize) -> Tensor {
        assert_eq!(self.rank(), 4, "conv2d input rank");
        assert_eq!(weight.rank(), 4, "conv2d weight rank");
        let (n, c, h, w) = (self.shape[0], self.shape[1], self.shape[2], self.shape[3]);
        let (o, i, kh, kw) = (weight.shape[0], weight.shape[1], weight.shape[2], weight.shape[3]);
        assert_eq!(c, i, "conv2d channel mismatch");
        let oh = (h + 2 * pad - kh) / stride + 1;
        let ow = (w + 2 * pad - kw) / stride + 1;
        let mut out = Tensor::zeros(&[n, o, oh, ow]);
        for b in 0..n {
            for f in 0..o {
                for y in 0..oh {
                    for x in 0..ow {
                        let mut acc = 0.0f32;
                        for ci in 0..c {
                            for ky in 0..kh {
                                let iy = (y * stride + ky) as isize - pad as isize;
                                if iy < 0 || iy as usize >= h {
                                    continue;
                                }
                                for kx in 0..kw {
                                    let ix = (x * stride + kx) as isize - pad as isize;
                                    if ix < 0 || ix as usize >= w {
                                        continue;
                                    }
                                    acc += self.at(&[b, ci, iy as usize, ix as usize])
                                        * weight.at(&[f, ci, ky, kx]);
                                }
                            }
                        }
                        out.set(&[b, f, y, x], acc);
                    }
                }
            }
        }
        out
    }

    /// im2col: unfold `[n,c,h,w]` into `[n*oh*ow, c*kh*kw]` patches so conv
    /// becomes GEMM (the transformation §2.1.2 relies on: "operations in
    /// CONV layers can be transformed into GEMM"). Thin allocating wrapper
    /// over [`im2col_into`] — the steady-state engine calls the `_into`
    /// form against the workspace arena instead.
    pub fn im2col(&self, kh: usize, kw: usize, stride: usize, pad: usize) -> Tensor {
        assert_eq!(self.rank(), 4);
        let (n, c, h, w) = (self.shape[0], self.shape[1], self.shape[2], self.shape[3]);
        let oh = (h + 2 * pad - kh) / stride + 1;
        let ow = (w + 2 * pad - kw) / stride + 1;
        let cols = c * kh * kw;
        let mut out = vec![0.0f32; n * oh * ow * cols];
        im2col_into(&self.data, n, c, h, w, kh, kw, stride, pad, &mut out);
        Tensor { shape: vec![n * oh * ow, cols], data: out }
    }

    /// 2x2 max pooling with stride 2 over NCHW (sufficient for the zoo).
    pub fn maxpool2(&self) -> Tensor {
        assert_eq!(self.rank(), 4);
        let (n, c, h, w) = (self.shape[0], self.shape[1], self.shape[2], self.shape[3]);
        let (oh, ow) = (h / 2, w / 2);
        let mut out = Tensor::zeros(&[n, c, oh, ow]);
        for b in 0..n {
            for ci in 0..c {
                for y in 0..oh {
                    for x in 0..ow {
                        let mut m = f32::NEG_INFINITY;
                        for dy in 0..2 {
                            for dx in 0..2 {
                                m = m.max(self.at(&[b, ci, 2 * y + dy, 2 * x + dx]));
                            }
                        }
                        out.set(&[b, ci, y, x], m);
                    }
                }
            }
        }
        out
    }

    /// Global average pool `[n,c,h,w] -> [n,c]`.
    pub fn global_avg_pool(&self) -> Tensor {
        assert_eq!(self.rank(), 4);
        let (n, c, h, w) = (self.shape[0], self.shape[1], self.shape[2], self.shape[3]);
        let mut out = Tensor::zeros(&[n, c]);
        let denom = (h * w) as f32;
        for b in 0..n {
            for ci in 0..c {
                let mut s = 0.0;
                for y in 0..h {
                    for x in 0..w {
                        s += self.at(&[b, ci, y, x]);
                    }
                }
                out.set(&[b, ci], s / denom);
            }
        }
        out
    }

    /// Row-wise softmax over a 2-D tensor.
    pub fn softmax_rows(&self) -> Tensor {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = self.clone();
        for i in 0..m {
            let row = &mut out.data[i * n..(i + 1) * n];
            let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut s = 0.0;
            for v in row.iter_mut() {
                *v = (*v - mx).exp();
                s += *v;
            }
            for v in row.iter_mut() {
                *v /= s;
            }
        }
        out
    }

    /// Argmax per row of a 2-D tensor (classification readout). Uses
    /// `f32::total_cmp`, so rows containing NaN pick a deterministic
    /// winner (NaN sorts above +inf in the IEEE total order) instead of
    /// panicking the way `partial_cmp(..).unwrap()` did.
    pub fn argmax_rows(&self) -> Vec<usize> {
        assert_eq!(self.rank(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        (0..m)
            .map(|i| {
                let row = &self.data[i * n..(i + 1) * n];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(j, _)| j)
                    .unwrap()
            })
            .collect()
    }
}

/// [`Tensor::im2col`] into a caller-provided buffer (`out` must hold
/// `n*oh*ow * c*kh*kw` elements for the leading patch matrix). The
/// allocation-free form the steady-state executor runs against the
/// workspace arena.
#[allow(clippy::too_many_arguments)]
pub fn im2col_into(
    x: &[f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    out: &mut [f32],
) {
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (w + 2 * pad - kw) / stride + 1;
    let cols = c * kh * kw;
    debug_assert!(out.len() >= n * oh * ow * cols, "im2col_into: out too small");
    for b in 0..n {
        for y in 0..oh {
            for xx in 0..ow {
                let row = (b * oh + y) * ow + xx;
                let orow = &mut out[row * cols..(row + 1) * cols];
                for ci in 0..c {
                    let in_base = (b * c + ci) * h * w;
                    for ky in 0..kh {
                        let iy = (y * stride + ky) as isize - pad as isize;
                        for kx in 0..kw {
                            let ix = (xx * stride + kx) as isize - pad as isize;
                            let col = (ci * kh + ky) * kw + kx;
                            orow[col] =
                                if iy < 0 || ix < 0 || iy as usize >= h || ix as usize >= w {
                                    0.0
                                } else {
                                    x[in_base + iy as usize * w + ix as usize]
                                };
                        }
                    }
                }
            }
        }
    }
}

/// Transpose a flat OIHW weight (`o` rows of `cols = i*kh*kw`) into the
/// `[cols, o]` GEMM operand, into a caller buffer. This is the transform
/// `Compiler::compile` runs **once** per conv when pre-packing — the
/// per-call re-transpose of the PR-1 `conv2d_gemm` is gone from the hot
/// path.
pub fn conv_weight_matrix_into(w: &[f32], o: usize, cols: usize, out: &mut [f32]) {
    debug_assert!(out.len() >= cols * o);
    for f in 0..o {
        let wrow = &w[f * cols..(f + 1) * cols];
        for (c, &v) in wrow.iter().enumerate() {
            out[c * o + f] = v;
        }
    }
}

/// Allocating wrapper over [`conv_weight_matrix_into`]: OIHW weight →
/// `[i*kh*kw, o]` tensor.
pub fn conv_weight_matrix(weight: &Tensor) -> Tensor {
    assert_eq!(weight.rank(), 4);
    let (o, i, kh, kw) = (
        weight.shape()[0],
        weight.shape()[1],
        weight.shape()[2],
        weight.shape()[3],
    );
    let cols = i * kh * kw;
    let mut wt = vec![0.0f32; cols * o];
    conv_weight_matrix_into(weight.data(), o, cols, &mut wt);
    Tensor { shape: vec![cols, o], data: wt }
}

/// Scatter a GEMM conv result `[n*oh*ow, o]` back to NCHW `[n,o,oh,ow]`.
pub fn scatter_rows_to_nchw(y: &[f32], n: usize, o: usize, oh: usize, ow: usize, out: &mut [f32]) {
    let rows = n * oh * ow;
    debug_assert!(y.len() >= rows * o && out.len() >= n * o * oh * ow);
    for row in 0..rows {
        let b = row / (oh * ow);
        let rem = row % (oh * ow);
        for f in 0..o {
            out[((b * o + f) * oh * ow) + rem] = y[row * o + f];
        }
    }
}

/// conv2d as im2col + GEMM against a **pre-packed** transposed weight
/// (`pb` = `[i*kh*kw, o]` packed at compile time), writing every
/// intermediate into caller-provided workspace buffers — the steady-state
/// conv path: no im2col allocation, no weight re-transpose, no B packing,
/// no output allocation, no thread spawn.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_gemm_prepacked_into(
    x: &[f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    pb: &gemm::PackedB,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    cfg: &GemmConfig,
    patches: &mut [f32],
    gemm_out: &mut [f32],
    scratch: &mut [f32],
    out: &mut [f32],
) {
    assert_eq!(x.len(), n * c * h * w, "conv input length");
    let cols = c * kh * kw;
    let o = pb.n;
    assert_eq!(pb.k, cols, "prepacked conv weight shape mismatch");
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (w + 2 * pad - kw) / stride + 1;
    let rows = n * oh * ow;
    im2col_into(x, n, c, h, w, kh, kw, stride, pad, &mut patches[..rows * cols]);
    gemm::gemm_prepacked(rows, &patches[..rows * cols], pb, &mut gemm_out[..rows * o], cfg, scratch);
    scatter_rows_to_nchw(&gemm_out[..rows * o], n, o, oh, ow, out);
}

/// Int8 variant of [`conv2d_gemm_prepacked_into`]: im2col into the f32
/// patch buffer, then the quantized GEMM against a compile-time
/// [`qgemm::PackedQB`] filter matrix (per-output-channel scales ride in
/// the pack). `qscratch` is the per-band i8 A-panel arena — the int8
/// steady conv path allocates nothing.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_qgemm_prepacked_into(
    x: &[f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    pqb: &qgemm::PackedQB,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    cfg: &GemmConfig,
    patches: &mut [f32],
    gemm_out: &mut [f32],
    qscratch: &mut [i8],
    out: &mut [f32],
) {
    assert_eq!(x.len(), n * c * h * w, "conv input length");
    let cols = c * kh * kw;
    let o = pqb.n;
    assert_eq!(pqb.k, cols, "prepacked int8 conv weight shape mismatch");
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (w + 2 * pad - kw) / stride + 1;
    let rows = n * oh * ow;
    im2col_into(x, n, c, h, w, kh, kw, stride, pad, &mut patches[..rows * cols]);
    qgemm::qgemm_prepacked(rows, &patches[..rows * cols], pqb, &mut gemm_out[..rows * o], cfg, qscratch);
    scatter_rows_to_nchw(&gemm_out[..rows * o], n, o, oh, ow, out);
}

/// conv2d as im2col + GEMM with the transposed weight `wt = [cols, o]`
/// supplied by the caller (the steady engine's fallback when pre-packing
/// is off: B panels repack per call inside [`gemm::gemm`], but im2col and
/// the output still land in workspace buffers).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_gemm_wt_into(
    x: &[f32],
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    wt: &[f32],
    o: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    cfg: &GemmConfig,
    patches: &mut [f32],
    gemm_out: &mut [f32],
    out: &mut [f32],
) {
    assert_eq!(x.len(), n * c * h * w, "conv input length");
    let cols = c * kh * kw;
    assert_eq!(wt.len(), cols * o, "conv weight matrix shape mismatch");
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (w + 2 * pad - kw) / stride + 1;
    let rows = n * oh * ow;
    im2col_into(x, n, c, h, w, kh, kw, stride, pad, &mut patches[..rows * cols]);
    gemm::gemm(rows, cols, o, &patches[..rows * cols], wt, &mut gemm_out[..rows * o], cfg);
    scatter_rows_to_nchw(&gemm_out[..rows * o], n, o, oh, ow, out);
}

/// conv2d via im2col + matmul; must agree with `Tensor::conv2d`. This is
/// the GEMM formulation the pruning/compiler stack operates on — now a
/// thin allocating wrapper over [`conv2d_gemm_wt_into`], kept as the
/// oracle the workspace variants are property-tested against.
pub fn conv2d_gemm(input: &Tensor, weight: &Tensor, stride: usize, pad: usize) -> Tensor {
    let (n, c, h, w) = (
        input.shape()[0],
        input.shape()[1],
        input.shape()[2],
        input.shape()[3],
    );
    let (o, i, kh, kw) = (
        weight.shape()[0],
        weight.shape()[1],
        weight.shape()[2],
        weight.shape()[3],
    );
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (w + 2 * pad - kw) / stride + 1;
    let cols = i * kh * kw;
    let rows = n * oh * ow;
    let wt = conv_weight_matrix(weight);
    let mut patches = vec![0.0f32; rows * cols];
    let mut y = vec![0.0f32; rows * o];
    let mut out = Tensor::zeros(&[n, o, oh, ow]);
    conv2d_gemm_wt_into(
        input.data(),
        n,
        c,
        h,
        w,
        wt.data(),
        o,
        kh,
        kw,
        stride,
        pad,
        &GemmConfig::default(),
        &mut patches,
        &mut y,
        out.data_mut(),
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::forall;

    #[test]
    fn matmul_identity() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let eye = Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(a.matmul(&eye), a);
    }

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::from_vec(&[3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn conv2d_identity_kernel() {
        // 1x1 kernel with weight 1 = identity.
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[1, 1, 4, 4], 1.0, &mut rng);
        let w = Tensor::from_vec(&[1, 1, 1, 1], vec![1.0]);
        let y = x.conv2d(&w, 1, 0);
        assert_eq!(y, x);
    }

    #[test]
    fn conv2d_known_3x3() {
        // All-ones 3x3 input, all-ones 3x3 kernel, pad 1: center = 9, corner = 4.
        let x = Tensor::full(&[1, 1, 3, 3], 1.0);
        let w = Tensor::full(&[1, 1, 3, 3], 1.0);
        let y = x.conv2d(&w, 1, 1);
        assert_eq!(y.shape(), &[1, 1, 3, 3]);
        assert_eq!(y.at(&[0, 0, 1, 1]), 9.0);
        assert_eq!(y.at(&[0, 0, 0, 0]), 4.0);
        assert_eq!(y.at(&[0, 0, 0, 1]), 6.0);
    }

    #[test]
    fn conv2d_gemm_matches_direct() {
        forall("im2col-gemm conv == direct conv", 24, |rng| {
            let n = 1 + rng.below(2);
            let c = 1 + rng.below(3);
            let o = 1 + rng.below(4);
            let hw = 3 + rng.below(5);
            let k = *rng.choose(&[1usize, 3]);
            let stride = 1 + rng.below(2);
            let pad = if k == 3 { rng.below(2) } else { 0 };
            let x = Tensor::randn(&[n, c, hw, hw], 1.0, rng);
            let w = Tensor::randn(&[o, c, k, k], 0.5, rng);
            let a = x.conv2d(&w, stride, pad);
            let b = conv2d_gemm(&x, &w, stride, pad);
            assert!(a.max_abs_diff(&b) < 1e-4, "diff {}", a.max_abs_diff(&b));
        });
    }

    #[test]
    fn maxpool_known() {
        let x = Tensor::from_vec(&[1, 1, 2, 2], vec![1.0, 5.0, 3.0, 2.0]);
        let y = x.maxpool2();
        assert_eq!(y.shape(), &[1, 1, 1, 1]);
        assert_eq!(y.at(&[0, 0, 0, 0]), 5.0);
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        forall("softmax rows sum to 1", 16, |rng| {
            let m = 1 + rng.below(4);
            let n = 1 + rng.below(6);
            let t = Tensor::randn(&[m, n], 3.0, rng);
            let s = t.softmax_rows();
            for i in 0..m {
                let row_sum: f32 = s.data()[i * n..(i + 1) * n].iter().sum();
                assert!((row_sum - 1.0).abs() < 1e-5);
            }
        });
    }

    #[test]
    fn global_avg_pool_of_constant() {
        let x = Tensor::full(&[2, 3, 4, 4], 2.5);
        let y = x.global_avg_pool();
        assert_eq!(y.shape(), &[2, 3]);
        assert!(y.data().iter().all(|&v| (v - 2.5).abs() < 1e-6));
    }

    #[test]
    fn zero_fraction_counts() {
        let t = Tensor::from_vec(&[4], vec![0.0, 1.0, 0.0, 2.0]);
        assert_eq!(t.zero_fraction(), 0.5);
    }

    #[test]
    fn argmax_rows_basic() {
        let t = Tensor::from_vec(&[2, 3], vec![0.1, 0.9, 0.0, 1.0, 0.2, 0.3]);
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    /// Satellite regression: a NaN in a row must not panic (the old
    /// `partial_cmp(..).unwrap()` did) and must pick deterministically —
    /// under `total_cmp`, NaN sorts above every finite value and +inf.
    #[test]
    fn argmax_rows_handles_nan() {
        let t = Tensor::from_vec(
            &[3, 3],
            vec![
                0.1,
                f32::NAN,
                0.3,
                f32::NEG_INFINITY,
                -1.0,
                f32::INFINITY,
                f32::NAN,
                f32::NAN,
                f32::NAN,
            ],
        );
        assert_eq!(t.argmax_rows(), vec![1, 2, 0]);
        // Deterministic across calls.
        assert_eq!(t.argmax_rows(), t.argmax_rows());
    }

    /// Satellite acceptance: the workspace `_into` conv variants are
    /// pinned to the allocating `conv2d_gemm` oracle (bitwise — same GEMM,
    /// only the buffer ownership differs) and to the direct conv within
    /// float tolerance, on shapes drawn from the odd set.
    #[test]
    fn conv_into_variants_match_allocating_oracles() {
        use crate::tensor::gemm::{prepacked_scratch_elems, PackedB};
        forall("conv _into == allocating oracle", 16, |rng| {
            let n = 1 + rng.below(2);
            let c = 1 + rng.below(3);
            let o = 1 + rng.below(4);
            let hw = 4 + rng.below(5);
            let k = *rng.choose(&[1usize, 3]);
            let stride = 1 + rng.below(2);
            let pad = if k == 3 { rng.below(2) } else { 0 };
            let x = Tensor::randn(&[n, c, hw, hw], 1.0, rng);
            let w = Tensor::randn(&[o, c, k, k], 0.5, rng);
            let oracle = conv2d_gemm(&x, &w, stride, pad);
            let direct = x.conv2d(&w, stride, pad);

            let oh = (hw + 2 * pad - k) / stride + 1;
            let rows = n * oh * oh;
            let cols = c * k * k;
            let cfg = GemmConfig::default();
            let wt = conv_weight_matrix(&w);
            let mut patches = vec![0.0f32; rows * cols];
            let mut y = vec![0.0f32; rows * o];
            let mut scratch =
                vec![0.0f32; prepacked_scratch_elems(&cfg) * cfg.resolved_threads()];

            let mut got_wt = Tensor::zeros(&[n, o, oh, oh]);
            conv2d_gemm_wt_into(
                x.data(), n, c, hw, hw, wt.data(), o, k, k, stride, pad, &cfg,
                &mut patches, &mut y, got_wt.data_mut(),
            );
            assert_eq!(got_wt.data(), oracle.data(), "wt_into != oracle");

            let pb = PackedB::pack(cols, o, wt.data(), &cfg);
            let mut got_pre = Tensor::zeros(&[n, o, oh, oh]);
            conv2d_gemm_prepacked_into(
                x.data(), n, c, hw, hw, &pb, k, k, stride, pad, &cfg,
                &mut patches, &mut y, &mut scratch, got_pre.data_mut(),
            );
            assert_eq!(got_pre.data(), oracle.data(), "prepacked_into != oracle");
            assert!(
                direct.max_abs_diff(&got_pre) < 1e-4,
                "prepacked conv diverges from direct conv by {}",
                direct.max_abs_diff(&got_pre)
            );
        });
    }

    #[test]
    fn blocked_matmul_matches_naive_oracle() {
        forall("Tensor::matmul == naive oracle", 24, |rng| {
            let dims = [1usize, 7, 33, 129];
            let m = *rng.choose(&dims);
            let k = *rng.choose(&dims);
            let n = *rng.choose(&dims);
            let a = Tensor::randn(&[m, k], 1.0, rng);
            let b = Tensor::randn(&[k, n], 1.0, rng);
            let fast = a.matmul(&b);
            let slow = a.matmul_naive(&b);
            let d = fast.max_abs_diff(&slow);
            assert!(d <= 1e-3, "diff {d} at [{m},{k}]x[{k},{n}]");
        });
    }

    #[test]
    #[should_panic]
    fn matmul_dim_mismatch_panics() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 2]);
        let _ = a.matmul(&b);
    }
}
