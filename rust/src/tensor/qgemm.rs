//! Cache-blocked, multi-threaded **int8** GEMM — the execution half of the
//! compression–compilation co-design story (§2.1 of the paper: quantization
//! is the "compatible compression technique"; PatDNN/CoCoPIE make the
//! quantized tiled/packed micro-kernel the centerpiece of their mobile code
//! generators). Same three-level MC/KC/NC blocking, MR×NR register tiles
//! and persistent-pool row-band parallelism as the f32 engine
//! ([`super::gemm`]) — the loop nests are deliberately line-for-line
//! parallel so the two kernels stay reviewable side by side.
//!
//! Numerics: symmetric int8 with **dynamic per-tensor activation scales**
//! (one amax pass over A per call) and **static per-output-channel weight
//! scales** carried by [`PackedQB`] (packed once at compile time from
//! [`crate::pruning::quant::quantize_gemm_weight`], so the scales the
//! epilogue multiplies by are bitwise the ones `analyze::QuantPlan`
//! reports). The micro-kernel accumulates in i32 over each KC panel
//! (depth ≤ KC, so a panel's accumulator needs ≤ 15 + ⌈log2 KC⌉ bits —
//! comfortably inside i32 for every supported blocking) and the epilogue
//! dequantizes the panel's contribution into f32 C:
//! `C[i,j] += acc_i32 · (a_scale · col_scale[j])`.
//!
//! Non-finite *activations* saturate deterministically through the
//! rounding cast (NaN → 0) rather than erroring: the compile-time
//! feasibility gate (`Compiler::quantize(Auto)` consulting the range
//! analysis) is what keeps non-finite data off this path; weights are
//! validated with typed errors at pack time. With caller-provided i8 pack
//! scratch, [`qgemm_prepacked`] performs no heap allocation — the
//! steady-state inference configuration. [`qgemm`] (both operands
//! quantized on the fly — the attention QK^T/AV path) packs into its own
//! buffers like the f32 `gemm` and is not part of the zero-allocation
//! guarantee, exactly like f32 batched matmul.

use super::gemm::{band_split, padded, GemmConfig, MR};
use crate::pruning::quant::quantize_gemm_weight;
use crate::tensor::Tensor;
use anyhow::Result;

/// Dynamic symmetric per-tensor activation scale: `amax / 127`, or 1.0
/// for an all-zero (or empty) tensor. NaN elements are ignored by the
/// max, matching the saturating behavior of [`quant1`].
pub fn act_scale(a: &[f32]) -> f32 {
    let amax = a.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    if amax > 0.0 && amax.is_finite() {
        amax / 127.0
    } else {
        1.0
    }
}

/// One value, one scale: round-to-nearest, saturate at ±127 (NaN → 0).
#[inline(always)]
pub fn quant1(v: f32, scale: f32) -> i8 {
    (v / scale).round().clamp(-127.0, 127.0) as i8
}

/// A constant int8 B operand packed **once** (at `Compiler::compile` time)
/// into the NR-column sliver layout the int8 micro-kernel consumes, plus
/// the per-output-column dequant scales. Layout (panel order, sliver
/// addressing, trailing sentinel) is identical to the f32 [`PackedB`]
/// (`super::gemm::PackedB`); only the element type and the scale side
/// table differ.
#[derive(Debug, Clone)]
pub struct PackedQB {
    /// Logical shape of the packed operand: `[k, n]`.
    pub k: usize,
    pub n: usize,
    kc: usize,
    nc: usize,
    nr: usize,
    /// Panel start offsets in `(jc, pc)` order, with a trailing sentinel
    /// equal to `data.len()`.
    panel_off: Vec<usize>,
    data: Vec<i8>,
    /// Per-output-column dequant scales, length `n` — one per output
    /// channel, straight from the symmetric per-channel quantizer.
    pub col_scales: Vec<f32>,
}

impl PackedQB {
    /// Pack row-major int8 `b [k, n]` with its per-column scales under
    /// `cfg`'s blocking parameters.
    pub fn pack(k: usize, n: usize, b: &[i8], col_scales: &[f32], cfg: &GemmConfig) -> PackedQB {
        assert_eq!(b.len(), k * n, "PackedQB: B length");
        assert_eq!(col_scales.len(), n, "PackedQB: one scale per output column");
        let kc = cfg.kc.max(1);
        let nc = cfg.nc.max(1);
        let nr = if cfg.nr == 4 { 4 } else { 8 };
        let mut data = Vec::new();
        let mut panel_off = Vec::new();
        let mut jc = 0;
        while jc < n {
            let ncb = nc.min(n - jc);
            let mut pc = 0;
            while pc < k {
                let kcb = kc.min(k - pc);
                panel_off.push(data.len());
                let start = data.len();
                data.resize(start + padded(ncb, nr) * kcb, 0);
                pack_b_q(b, n, pc, jc, kcb, ncb, nr, &mut data[start..]);
                pc += kc;
            }
            jc += nc;
        }
        panel_off.push(data.len());
        PackedQB { k, n, kc, nc, nr, panel_off, data, col_scales: col_scales.to_vec() }
    }

    /// Quantize-and-pack a contraction weight (rank-2 Dense `[in, out]` or
    /// rank-4 OIHW conv) per output channel. This is the compile-time
    /// entry `ExecState::prepack` uses; it rejects non-finite weights with
    /// the quantizer's typed error.
    pub fn from_weight(t: &Tensor, cfg: &GemmConfig) -> Result<PackedQB> {
        let q = quantize_gemm_weight(t)?;
        let (n, k) = (q.shape[0], q.shape[1]);
        // `quantize_gemm_weight` yields row-major [out, k]; the GEMM wants
        // B as [k, n=out].
        let mut b = vec![0i8; k * n];
        for j in 0..n {
            for p in 0..k {
                b[p * n + j] = q.data[j * k + p];
            }
        }
        Ok(PackedQB::pack(k, n, &b, &q.scales, cfg))
    }

    /// Packed bytes held (payload + scales — the compile-time memory cost
    /// of pre-packing, 4x smaller than the f32 table).
    pub fn bytes(&self) -> u64 {
        self.data.len() as u64 + self.col_scales.len() as u64 * 4
    }

    /// The packed panel at column block `jci`, K block `pci`.
    fn panel(&self, jci: usize, pci: usize) -> &[i8] {
        let n_pc = (self.k + self.kc - 1) / self.kc;
        let idx = jci * n_pc + pci;
        &self.data[self.panel_off[idx]..self.panel_off[idx + 1]]
    }
}

/// Per-band A-pack scratch (in **i8** elements) that [`qgemm_prepacked`]
/// needs under `cfg`; multiply by [`GemmConfig::resolved_threads`] for a
/// buffer that covers every band of a parallel call.
pub fn qgemm_scratch_elems(cfg: &GemmConfig) -> usize {
    padded(cfg.mc.max(MR), MR) * cfg.kc.max(1)
}

/// [`qgemm_scratch_elems`] rounded up to a whole number of f32 words
/// (i8 elems == bytes). The workspace arena accounts in 4-byte units, so
/// sizing the per-band i8 region at this granularity keeps the
/// `total f32 units × 4 == WorkspaceSpec::bytes` invariant exact.
pub fn qgemm_scratch_band_bytes(cfg: &GemmConfig) -> usize {
    padded(qgemm_scratch_elems(cfg), 4)
}

/// `C = dequant(quant(A) * packed_QB)` — the steady-state int8 GEMM entry
/// point: B was quantized and packed at compile time ([`PackedQB`]), A is
/// quantized on the fly with one dynamic per-tensor scale and packed into
/// the caller's i8 `scratch` (≥ `qgemm_scratch_elems(cfg) *
/// resolved_threads` elements), row bands run on the persistent pool.
/// Performs **no** heap allocation and spawns **no** threads. `cfg` must
/// carry the same blocking parameters B was packed with (asserted).
pub fn qgemm_prepacked(
    m: usize,
    a: &[f32],
    pqb: &PackedQB,
    c: &mut [f32],
    cfg: &GemmConfig,
    scratch: &mut [i8],
) {
    let (k, n) = (pqb.k, pqb.n);
    assert_eq!(a.len(), m * k, "qgemm_prepacked: A length");
    assert_eq!(c.len(), m * n, "qgemm_prepacked: C length");
    assert_eq!(pqb.kc, cfg.kc.max(1), "qgemm_prepacked: KC mismatch vs pack time");
    assert_eq!(pqb.nc, cfg.nc.max(1), "qgemm_prepacked: NC mismatch vs pack time");
    assert_eq!(pqb.nr, if cfg.nr == 4 { 4 } else { 8 }, "qgemm_prepacked: NR mismatch");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        c.fill(0.0);
        return;
    }
    // One global activation scale: deterministic across thread counts
    // (bands share it instead of deriving per-band scales).
    let a_scale = act_scale(a);
    let per = qgemm_scratch_elems(cfg);
    let threads = cfg.effective_threads(m, k, n);
    if threads <= 1 {
        qgemm_band_prepacked(m, a, a_scale, pqb, c, cfg, &mut scratch[..per]);
        return;
    }
    let (rows_per, bands) = band_split(m, threads);
    assert!(
        scratch.len() >= per * bands,
        "qgemm_prepacked: scratch {} < {} elems for {} bands",
        scratch.len(),
        per * bands,
        bands
    );
    let c_sh = crate::runtime::pool::SharedSlice::new(c);
    let s_sh = crate::runtime::pool::SharedSlice::new(scratch);
    crate::runtime::pool::global().parallel_for(bands, |t| {
        let row0 = t * rows_per;
        let rows = rows_per.min(m - row0);
        let a_band = &a[row0 * k..(row0 + rows) * k];
        // SAFETY: disjoint row bands of C; disjoint per-band i8 scratch.
        let c_band = unsafe { c_sh.slice_mut(row0 * n, rows * n) };
        let a_pack = unsafe { s_sh.slice_mut(t * per, per) };
        qgemm_band_prepacked(rows, a_band, a_scale, pqb, c_band, cfg, a_pack);
    });
}

/// Single-threaded prepacked int8 GEMM over one row band of C.
fn qgemm_band_prepacked(
    m: usize,
    a: &[f32],
    a_scale: f32,
    pqb: &PackedQB,
    c: &mut [f32],
    cfg: &GemmConfig,
    a_pack: &mut [i8],
) {
    let (k, n) = (pqb.k, pqb.n);
    let mc = cfg.mc.max(MR);
    let (kc, nc, nr) = (pqb.kc, pqb.nc, pqb.nr);
    c.fill(0.0);
    let mut jc = 0;
    let mut jci = 0;
    while jc < n {
        let ncb = nc.min(n - jc);
        let mut pc = 0;
        let mut pci = 0;
        while pc < k {
            let kcb = kc.min(k - pc);
            let b_pack = pqb.panel(jci, pci);
            let mut ic = 0;
            while ic < m {
                let mcb = mc.min(m - ic);
                pack_a_q(a, a_scale, k, ic, pc, mcb, kcb, a_pack);
                run_panel_q(
                    c,
                    n,
                    ic,
                    jc,
                    mcb,
                    ncb,
                    kcb,
                    nr,
                    a_pack,
                    b_pack,
                    a_scale,
                    &pqb.col_scales,
                );
                ic += mc;
            }
            pc += kc;
            pci += 1;
        }
        jc += nc;
        jci += 1;
    }
}

/// `C = dequant(quant(A) * quant(B))` with **both** operands quantized on
/// the fly (dynamic per-tensor scales) — the quantized-attention path
/// (int8 QK^T and int8 AV around the f32 masked softmax), where B is an
/// activation too and nothing can be packed at compile time. Allocates
/// its own quantized-B copy and pack buffers, exactly like the f32
/// [`super::gemm::gemm`] allocates pack buffers — batched matmul is not
/// part of the zero-allocation guarantee in either precision.
pub fn qgemm(m: usize, k: usize, n: usize, a: &[f32], b: &[f32], c: &mut [f32], cfg: &GemmConfig) {
    assert_eq!(a.len(), m * k, "qgemm: A length");
    assert_eq!(b.len(), k * n, "qgemm: B length");
    assert_eq!(c.len(), m * n, "qgemm: C length");
    if m == 0 || n == 0 {
        return;
    }
    if k == 0 {
        c.fill(0.0);
        return;
    }
    let a_scale = act_scale(a);
    let b_scale = act_scale(b);
    let qb: Vec<i8> = b.iter().map(|&v| quant1(v, b_scale)).collect();
    let col_scales = vec![b_scale; n];
    let threads = cfg.effective_threads(m, k, n);
    if threads <= 1 {
        qgemm_band(m, k, n, a, a_scale, &qb, &col_scales, c, cfg);
        return;
    }
    let (rows_per, bands) = band_split(m, threads);
    let c_sh = crate::runtime::pool::SharedSlice::new(c);
    crate::runtime::pool::global().parallel_for(bands, |t| {
        let row0 = t * rows_per;
        let rows = rows_per.min(m - row0);
        let a_band = &a[row0 * k..(row0 + rows) * k];
        // SAFETY: bands are disjoint row ranges of C.
        let c_band = unsafe { c_sh.slice_mut(row0 * n, rows * n) };
        qgemm_band(rows, k, n, a_band, a_scale, &qb, &col_scales, c_band, cfg);
    });
}

/// Single-threaded blocked int8 GEMM over one row band of C, packing both
/// operands on the fly.
#[allow(clippy::too_many_arguments)]
fn qgemm_band(
    m: usize,
    k: usize,
    n: usize,
    a: &[f32],
    a_scale: f32,
    qb: &[i8],
    col_scales: &[f32],
    c: &mut [f32],
    cfg: &GemmConfig,
) {
    let mc = cfg.mc.max(MR);
    let kc = cfg.kc.max(1);
    let nc = cfg.nc.max(1);
    let nr = if cfg.nr == 4 { 4 } else { 8 };
    c.fill(0.0);
    let mut a_pack = vec![0i8; padded(mc, MR) * kc];
    let mut b_pack = vec![0i8; padded(nc.min(n), nr) * kc];
    let mut jc = 0;
    while jc < n {
        let ncb = nc.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kcb = kc.min(k - pc);
            pack_b_q(qb, n, pc, jc, kcb, ncb, nr, &mut b_pack);
            let mut ic = 0;
            while ic < m {
                let mcb = mc.min(m - ic);
                pack_a_q(a, a_scale, k, ic, pc, mcb, kcb, &mut a_pack);
                run_panel_q(c, n, ic, jc, mcb, ncb, kcb, nr, &a_pack, &b_pack, a_scale, col_scales);
                ic += mc;
            }
            pc += kc;
        }
        jc += nc;
    }
}

/// Micro loops over one packed (A panel, B panel) pair: accumulate the
/// `mcb x ncb` block of C whose top-left corner is `(ic, jc)`, i32 inside
/// the register tile, dequantized into f32 C in the epilogue.
#[allow(clippy::too_many_arguments)]
fn run_panel_q(
    c: &mut [f32],
    n: usize,
    ic: usize,
    jc: usize,
    mcb: usize,
    ncb: usize,
    kcb: usize,
    nr: usize,
    a_pack: &[i8],
    b_pack: &[i8],
    a_scale: f32,
    col_scales: &[f32],
) {
    let mut jr = 0;
    while jr < ncb {
        let nrb = nr.min(ncb - jr);
        let b_sliver = &b_pack[(jr / nr) * kcb * nr..(jr / nr + 1) * kcb * nr];
        let mut ir = 0;
        while ir < mcb {
            let mrb = MR.min(mcb - ir);
            let a_sliver = &a_pack[(ir / MR) * kcb * MR..(ir / MR + 1) * kcb * MR];
            if nr == 8 {
                let mut acc = [[0i32; 8]; MR];
                microkernel_q8(kcb, a_sliver, b_sliver, &mut acc);
                for i in 0..mrb {
                    let crow = (ic + ir + i) * n + jc + jr;
                    for j in 0..nrb {
                        c[crow + j] += acc[i][j] as f32 * (a_scale * col_scales[jc + jr + j]);
                    }
                }
            } else {
                let mut acc = [[0i32; 4]; MR];
                microkernel_q4(kcb, a_sliver, b_sliver, &mut acc);
                for i in 0..mrb {
                    let crow = (ic + ir + i) * n + jc + jr;
                    for j in 0..nrb {
                        c[crow + j] += acc[i][j] as f32 * (a_scale * col_scales[jc + jr + j]);
                    }
                }
            }
            ir += MR;
        }
        jr += nr;
    }
}

/// Quantize-and-pack `A[ic..ic+mcb, pc..pc+kcb]` into MR-row i8 slivers —
/// same sliver addressing as the f32 `pack_a`
/// (`a_pack[s*kcb*MR + p*MR + i]`), with the dynamic activation scale
/// applied element-wise during the pack (no separate quantized-A buffer
/// ever exists).
#[allow(clippy::too_many_arguments)]
fn pack_a_q(
    a: &[f32],
    a_scale: f32,
    k: usize,
    ic: usize,
    pc: usize,
    mcb: usize,
    kcb: usize,
    a_pack: &mut [i8],
) {
    let slivers = (mcb + MR - 1) / MR;
    for s in 0..slivers {
        let base = s * kcb * MR;
        for p in 0..kcb {
            for i in 0..MR {
                let row = s * MR + i;
                a_pack[base + p * MR + i] = if row < mcb {
                    quant1(a[(ic + row) * k + pc + p], a_scale)
                } else {
                    0
                };
            }
        }
    }
}

/// Pack int8 `B[pc..pc+kcb, jc..jc+ncb]` into NR-column slivers — same
/// sliver addressing as the f32 `pack_b` (`b_pack[t*kcb*nr + p*nr + j]`),
/// zero-padded to a full NR.
#[allow(clippy::too_many_arguments)]
fn pack_b_q(
    b: &[i8],
    n: usize,
    pc: usize,
    jc: usize,
    kcb: usize,
    ncb: usize,
    nr: usize,
    b_pack: &mut [i8],
) {
    let slivers = (ncb + nr - 1) / nr;
    for t in 0..slivers {
        let base = t * kcb * nr;
        for p in 0..kcb {
            let brow = (pc + p) * n + jc;
            for j in 0..nr {
                let col = t * nr + j;
                b_pack[base + p * nr + j] = if col < ncb { b[brow + col] } else { 0 };
            }
        }
    }
}

/// MR x 8 int8 register-tile micro-kernel over a K-depth of `kc`: i8×i8
/// products widened to i32 before accumulation. Fixed-size array refs
/// give LLVM exact trip counts so the inner loops unroll and vectorize
/// (on targets with dot-product instructions this is the shape the
/// autovectorizer matches).
#[inline(always)]
fn microkernel_q8(kc: usize, a: &[i8], b: &[i8], acc: &mut [[i32; 8]; MR]) {
    for p in 0..kc {
        let ap: &[i8; MR] = (&a[p * MR..p * MR + MR]).try_into().unwrap();
        let bp: &[i8; 8] = (&b[p * 8..p * 8 + 8]).try_into().unwrap();
        for i in 0..MR {
            let ai = ap[i] as i32;
            for j in 0..8 {
                acc[i][j] += ai * bp[j] as i32;
            }
        }
    }
}

/// MR x 4 variant for the narrow-register knob setting.
#[inline(always)]
fn microkernel_q4(kc: usize, a: &[i8], b: &[i8], acc: &mut [[i32; 4]; MR]) {
    for p in 0..kc {
        let ap: &[i8; MR] = (&a[p * MR..p * MR + MR]).try_into().unwrap();
        let bp: &[i8; 4] = (&b[p * 4..p * 4 + 4]).try_into().unwrap();
        for i in 0..MR {
            let ai = ap[i] as i32;
            for j in 0..4 {
                acc[i][j] += ai * bp[j] as i32;
            }
        }
    }
}

/// Reference int8 GEMM — full-depth i32 accumulation, then one dequant —
/// the oracle the blocked kernel's panel-wise f32 accumulation is
/// property-tested against.
#[allow(clippy::too_many_arguments)]
pub fn qgemm_naive(
    m: usize,
    k: usize,
    n: usize,
    qa: &[i8],
    qb: &[i8],
    a_scale: f32,
    col_scales: &[f32],
    c: &mut [f32],
) {
    assert_eq!(qa.len(), m * k);
    assert_eq!(qb.len(), k * n);
    assert_eq!(c.len(), m * n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0i32;
            for p in 0..k {
                acc += qa[i * k + p] as i32 * qb[p * n + j] as i32;
            }
            c[i * n + j] = acc as f32 * (a_scale * col_scales[j]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::gemm::gemm_naive;
    use crate::util::proptest_lite::forall;
    use crate::util::rng::Rng;

    /// Quantize a row-major f32 `b [k, n]` per *column* (the per-output-
    /// channel form `PackedQB` carries), returning the int8 payload and
    /// the column scales.
    fn quantize_columns(k: usize, n: usize, b: &[f32]) -> (Vec<i8>, Vec<f32>) {
        let mut scales = vec![1.0f32; n];
        for j in 0..n {
            let amax = (0..k).fold(0.0f32, |m, p| m.max(b[p * n + j].abs()));
            if amax > 0.0 {
                scales[j] = amax / 127.0;
            }
        }
        let mut qb = vec![0i8; k * n];
        for p in 0..k {
            for j in 0..n {
                qb[p * n + j] = quant1(b[p * n + j], scales[j]);
            }
        }
        (qb, scales)
    }

    /// Satellite acceptance: the int8 kernel matches the f32 oracle on
    /// shapes that are NOT multiples of any tile size (M/N/K drawn from
    /// {1, 7, 33, 129}) within the bound the scales imply: each quantized
    /// factor carries ≤ half a step of error, so
    /// |C_int8 - C_f32| ≤ k·(amax_a·s_bj/2 + amax_bj·s_a/2 + s_a·s_bj/4)
    /// ≈ k·s_a·s_bj·127.25 per column j.
    #[test]
    #[cfg_attr(miri, ignore)] // heavy property sweep; Miri runs the tiny-shape soundness test instead
    fn int8_matches_f32_oracle_within_scale_bound() {
        let dims = [1usize, 7, 33, 129];
        forall("int8 gemm ~= f32 oracle", 32, |rng| {
            let m = *rng.choose(&dims);
            let k = *rng.choose(&dims);
            let n = *rng.choose(&dims);
            let a = rng.normal_vec(m * k, 0.0, 1.0);
            let b = rng.normal_vec(k * n, 0.0, 1.0);
            let mut want = vec![0.0f32; m * n];
            gemm_naive(m, k, n, &a, &b, &mut want);
            // Deliberately awkward tile sizes so every edge path runs.
            let cfg = GemmConfig {
                mc: 4 + rng.below(3) * 17,
                kc: 1 + rng.below(60),
                nc: 1 + rng.below(60),
                nr: *rng.choose(&[4usize, 8]),
                threads: 1 + rng.below(3),
            };
            let (qb, col_scales) = quantize_columns(k, n, &b);
            let pqb = PackedQB::pack(k, n, &qb, &col_scales, &cfg);
            let mut scratch = vec![0i8; qgemm_scratch_elems(&cfg) * cfg.resolved_threads()];
            let mut got = vec![0.0f32; m * n];
            qgemm_prepacked(m, &a, &pqb, &mut got, &cfg, &mut scratch);
            let sa = act_scale(&a);
            for i in 0..m {
                for j in 0..n {
                    let bound = k as f32 * sa * col_scales[j] * 130.0 + 1e-4;
                    let d = (want[i * n + j] - got[i * n + j]).abs();
                    assert!(d <= bound, "diff {d} > bound {bound} at ({i},{j}) m={m} k={k} n={n}");
                }
            }
        });
    }

    /// The blocked kernel agrees with the straight-line int8 oracle to
    /// f32 rounding (identical quantized inputs; only the panel-wise f32
    /// accumulation of dequantized partials differs from the oracle's
    /// full-depth i32 sum).
    #[test]
    #[cfg_attr(miri, ignore)] // heavy property sweep; Miri runs the tiny-shape soundness test instead
    fn blocked_matches_int8_oracle() {
        let dims = [1usize, 7, 33, 129];
        forall("blocked int8 == int8 oracle", 16, |rng| {
            let m = *rng.choose(&dims);
            let k = *rng.choose(&dims);
            let n = *rng.choose(&dims);
            let a = rng.normal_vec(m * k, 0.0, 1.0);
            let b = rng.normal_vec(k * n, 0.0, 1.0);
            let cfg = GemmConfig {
                mc: 4 + rng.below(3) * 17,
                kc: 1 + rng.below(60),
                nc: 1 + rng.below(60),
                nr: *rng.choose(&[4usize, 8]),
                threads: 1 + rng.below(3),
            };
            let sa = act_scale(&a);
            let qa: Vec<i8> = a.iter().map(|&v| quant1(v, sa)).collect();
            let (qb, col_scales) = quantize_columns(k, n, &b);
            let mut want = vec![0.0f32; m * n];
            qgemm_naive(m, k, n, &qa, &qb, sa, &col_scales, &mut want);
            let pqb = PackedQB::pack(k, n, &qb, &col_scales, &cfg);
            let mut scratch = vec![0i8; qgemm_scratch_elems(&cfg) * cfg.resolved_threads()];
            let mut got = vec![0.0f32; m * n];
            qgemm_prepacked(m, &a, &pqb, &mut got, &cfg, &mut scratch);
            for (idx, (w, g)) in want.iter().zip(&got).enumerate() {
                // Worst case: k/kc panels each rounding an i32·scale
                // product into f32.
                let slack = (k as f32).sqrt() * 1e-3 * w.abs().max(1.0);
                assert!((w - g).abs() <= slack, "idx {idx}: {w} vs {g} (m={m} k={k} n={n})");
            }
        });
    }

    /// Parallel band split is numerically invisible: every row's panel
    /// accumulation is identical regardless of which band runs it, and the
    /// activation scale is global, so serial and parallel agree bitwise.
    #[test]
    #[cfg_attr(miri, ignore)] // heavy shapes; Miri runs the tiny-shape soundness test instead
    fn parallel_matches_single_thread_bitwise() {
        let mut rng = Rng::new(0xA8);
        // Above the serial cutoff (m*k*n >= 1<<19) so bands actually split.
        let (m, k, n) = (160usize, 64usize, 96usize);
        assert!(m * k * n >= 1 << 19);
        let a = rng.normal_vec(m * k, 0.0, 1.0);
        let b = rng.normal_vec(k * n, 0.0, 1.0);
        let (qb, col_scales) = quantize_columns(k, n, &b);
        let one = GemmConfig { threads: 1, ..Default::default() };
        let many = GemmConfig { threads: 4, ..Default::default() };
        let pqb1 = PackedQB::pack(k, n, &qb, &col_scales, &one);
        let pqb4 = PackedQB::pack(k, n, &qb, &col_scales, &many);
        let mut c1 = vec![0.0f32; m * n];
        let mut c4 = vec![0.0f32; m * n];
        let mut s1 = vec![0i8; qgemm_scratch_elems(&one)];
        let mut s4 = vec![0i8; qgemm_scratch_elems(&many) * 4];
        qgemm_prepacked(m, &a, &pqb1, &mut c1, &one, &mut s1);
        qgemm_prepacked(m, &a, &pqb4, &mut c4, &many, &mut s4);
        assert_eq!(c1, c4);
    }

    /// Dynamic two-operand quantization (the attention path) stays within
    /// the scale-derived bound of the f32 oracle.
    #[test]
    #[cfg_attr(miri, ignore)] // heavy property sweep; Miri runs the tiny-shape soundness test instead
    fn dynamic_qgemm_matches_f32_oracle_within_scale_bound() {
        let dims = [1usize, 7, 33, 129];
        forall("dynamic int8 gemm ~= f32 oracle", 16, |rng| {
            let m = *rng.choose(&dims);
            let k = *rng.choose(&dims);
            let n = *rng.choose(&dims);
            let a = rng.normal_vec(m * k, 0.0, 1.0);
            let b = rng.normal_vec(k * n, 0.0, 1.0);
            let mut want = vec![0.0f32; m * n];
            gemm_naive(m, k, n, &a, &b, &mut want);
            let cfg = GemmConfig {
                mc: 4 + rng.below(3) * 17,
                kc: 1 + rng.below(60),
                nc: 1 + rng.below(60),
                nr: *rng.choose(&[4usize, 8]),
                threads: 1 + rng.below(3),
            };
            let mut got = vec![0.0f32; m * n];
            qgemm(m, k, n, &a, &b, &mut got, &cfg);
            let (sa, sb) = (act_scale(&a), act_scale(&b));
            let bound = k as f32 * sa * sb * 130.0 + 1e-4;
            for (w, g) in want.iter().zip(&got) {
                assert!((w - g).abs() <= bound, "{w} vs {g} (m={m} k={k} n={n})");
            }
        });
    }

    #[test]
    fn degenerate_dims_are_safe() {
        let cfg = GemmConfig::default();
        // k == 0: C must be zeroed, not left stale.
        let pqb = PackedQB::pack(0, 2, &[], &[1.0, 1.0], &cfg);
        let mut scratch = vec![0i8; qgemm_scratch_elems(&cfg)];
        let mut c = vec![7.0f32; 4];
        qgemm_prepacked(2, &[], &pqb, &mut c, &cfg, &mut scratch);
        assert_eq!(c, vec![0.0; 4]);
        // n == 0: nothing to do.
        let pqb = PackedQB::pack(3, 0, &[], &[], &cfg);
        let mut c: Vec<f32> = Vec::new();
        qgemm_prepacked(2, &[0.0; 6], &pqb, &mut c, &cfg, &mut scratch);
        // Dynamic path, k == 0.
        let mut c = vec![7.0f32; 4];
        qgemm(2, 0, 2, &[], &[], &mut c, &cfg);
        assert_eq!(c, vec![0.0; 4]);
    }

    #[test]
    #[should_panic]
    fn prepacked_rejects_blocking_mismatch() {
        let pack_cfg = GemmConfig { kc: 32, ..Default::default() };
        let run_cfg = GemmConfig { kc: 64, ..Default::default() };
        let pqb = PackedQB::pack(4, 4, &[0; 16], &[1.0; 4], &pack_cfg);
        let mut scratch = vec![0i8; qgemm_scratch_elems(&run_cfg)];
        let mut c = vec![0.0f32; 16];
        qgemm_prepacked(4, &[0.0; 16], &pqb, &mut c, &run_cfg, &mut scratch);
    }

    #[test]
    fn from_weight_scales_ride_along() {
        // Dense [in=3, out=2]: column amax 3 and 6 → scales 3/127, 6/127.
        let t = Tensor::from_vec(&[3, 2], vec![1.0, 2.0, 2.0, 4.0, 3.0, 6.0]);
        let cfg = GemmConfig::default();
        let pqb = match PackedQB::from_weight(&t, &cfg) {
            Ok(p) => p,
            Err(e) => unreachable!("finite weight rejected: {e}"),
        };
        assert_eq!((pqb.k, pqb.n), (3, 2));
        assert_eq!(pqb.col_scales, vec![3.0 / 127.0, 6.0 / 127.0]);
        // Non-finite weights are rejected with the quantizer's typed error.
        let bad = Tensor::from_vec(&[2, 2], vec![1.0, f32::NAN, 2.0, 3.0]);
        assert!(PackedQB::from_weight(&bad, &cfg).is_err());
    }

    /// Miri target: a shape above the (Miri-lowered) serial cutoff so both
    /// parallel unsafe paths — prepacked C bands + per-band i8 scratch,
    /// and the dynamic path's C bands — run under the interpreter,
    /// checking the generic `SharedSlice<i8>` raw-pointer arithmetic and
    /// the debug claim registry. Under a normal build the same shape is
    /// below the cutoff and takes the serial path, keeping this cheap.
    #[test]
    fn parallel_paths_are_sound_on_tiny_shapes() {
        let mut rng = Rng::new(0x52);
        let (m, k, n) = (9usize, 8usize, 8usize); // 576 >= Miri cutoff (1<<8)
        let a = rng.normal_vec(m * k, 0.0, 1.0);
        let b = rng.normal_vec(k * n, 0.0, 1.0);
        let cfg = GemmConfig { threads: 3, ..Default::default() };
        let (qb, col_scales) = quantize_columns(k, n, &b);
        let pqb = PackedQB::pack(k, n, &qb, &col_scales, &cfg);
        let mut scratch = vec![0i8; qgemm_scratch_elems(&cfg) * 3];
        let mut got = vec![0.0f32; m * n];
        qgemm_prepacked(m, &a, &pqb, &mut got, &cfg, &mut scratch);
        let sa = act_scale(&a);
        let qa: Vec<i8> = a.iter().map(|&v| quant1(v, sa)).collect();
        let mut want = vec![0.0f32; m * n];
        qgemm_naive(m, k, n, &qa, &qb, sa, &col_scales, &mut want);
        for (w, g) in want.iter().zip(&got) {
            assert!((w - g).abs() <= 1e-3);
        }
        // Dynamic path under the same tiny shape.
        let mut dynm = vec![0.0f32; m * n];
        qgemm(m, k, n, &a, &b, &mut dynm, &cfg);
        let mut want_f32 = vec![0.0f32; m * n];
        gemm_naive(m, k, n, &a, &b, &mut want_f32);
        let bound = k as f32 * sa * act_scale(&b) * 130.0 + 1e-4;
        for (w, g) in want_f32.iter().zip(&dynm) {
            assert!((w - g).abs() <= bound);
        }
    }
}
