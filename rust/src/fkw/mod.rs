//! FKW — the compact **Filter-Kernel-Weight** storage format plus
//! **filter-kernel reorder** (§2.3.1, Fig 10).
//!
//! After pattern pruning, every surviving kernel is one of ≤8 known
//! patterns, so a layer's weights compress to: a filter permutation
//! (filters with similar pattern mixes grouped for inter-thread load
//! balance), per-filter kernel records `(channel, pattern_id)` with kernels
//! sorted by pattern for intra-thread locality, and a flat array of exactly
//! 4 weights per surviving kernel. Index overhead is one byte-pair per
//! *kernel* — much less than CSR's per-*nonzero* column indices, which is
//! the paper's overhead claim, quantified in [`index_overhead_bytes`] /
//! [`csr_overhead_bytes`] and benchmarked in `benches/hotpath_exec.rs`.
//!
//! [`FkwLayer::conv2d`] executes the layer directly from the compact form
//! with a branch-less pattern-specialized inner loop — the Rust equivalent
//! of the paper's generated mobile code (the load-redundancy-elimination
//! codegen story continues in [`crate::codegen`]).

use crate::pruning::pattern::{Pattern, PatternAssignment};
use crate::tensor::Tensor;

/// One kernel record: input channel + pattern + 4 packed weights.
#[derive(Debug, Clone, Copy)]
pub struct KernelRec {
    pub channel: u16,
    pub pattern: u8,
}

/// One filter: its original index and its kernel records (sorted by
/// pattern id after reorder).
#[derive(Debug, Clone)]
pub struct FilterRec {
    pub original_index: u16,
    pub kernels: Vec<KernelRec>,
}

/// FKW-encoded pattern-pruned 3×3 conv layer.
#[derive(Debug, Clone)]
pub struct FkwLayer {
    pub out_channels: usize,
    pub in_channels: usize,
    /// The pattern vocabulary (≤ 256 entries).
    pub patterns: Vec<Pattern>,
    /// Filters in *execution order* (reordered).
    pub filters: Vec<FilterRec>,
    /// 4 weights per kernel record, flat, in filter-major execution order.
    pub weights: Vec<f32>,
    pub stride: usize,
    pub pad: usize,
    /// Per-pattern `(dy, dx)` position tables, resolved **once at encode
    /// time** — the conv hot loop no longer rebuilds them per call.
    pos_tab: Vec<[(usize, usize); 4]>,
    /// Start offset of each filter's weights (4 per kernel), enabling
    /// independent filter bands on the worker pool.
    filter_off: Vec<usize>,
}

impl FkwLayer {
    /// Encode a pattern-pruned OIHW weight tensor.
    ///
    /// `reorder=true` applies filter-kernel reorder (Fig 10): filters are
    /// sorted by their pattern histogram so similar filters are adjacent
    /// (inter-thread balance), and each filter's kernels are sorted by
    /// pattern id (intra-thread: consecutive kernels share the unrolled
    /// body, removing branches).
    pub fn encode(
        w: &Tensor,
        asg: &PatternAssignment,
        stride: usize,
        pad: usize,
        reorder: bool,
    ) -> FkwLayer {
        assert_eq!(w.rank(), 4);
        let (o, i) = (w.shape()[0], w.shape()[1]);
        assert!(o <= u16::MAX as usize && i <= u16::MAX as usize);
        let mut filters: Vec<FilterRec> = (0..o)
            .map(|f| {
                let mut kernels: Vec<KernelRec> = (0..i)
                    .filter(|&c| !asg.is_kernel_pruned(f, c))
                    .map(|c| KernelRec {
                        channel: c as u16,
                        pattern: asg.assignment[f][c] as u8,
                    })
                    .collect();
                if reorder {
                    kernels.sort_by_key(|k| (k.pattern, k.channel));
                }
                FilterRec { original_index: f as u16, kernels }
            })
            .collect();
        if reorder {
            // Group filters by pattern signature (sorted pattern multiset).
            filters.sort_by_key(|f| {
                let mut sig: Vec<u8> = f.kernels.iter().map(|k| k.pattern).collect();
                sig.sort_unstable();
                (sig, f.original_index)
            });
        }
        // Pack weights in execution order.
        let mut weights = Vec::new();
        for fr in &filters {
            let f = fr.original_index as usize;
            for kr in &fr.kernels {
                let p = asg.set.patterns[kr.pattern as usize];
                for pos in p.positions() {
                    weights.push(w.at(&[f, kr.channel as usize, pos / 3, pos % 3]));
                }
            }
        }
        let pos_tab = asg
            .set
            .patterns
            .iter()
            .map(|p| {
                let pos = p.positions();
                [
                    (pos[0] / 3, pos[0] % 3),
                    (pos[1] / 3, pos[1] % 3),
                    (pos[2] / 3, pos[2] % 3),
                    (pos[3] / 3, pos[3] % 3),
                ]
            })
            .collect();
        let mut filter_off = Vec::with_capacity(filters.len());
        let mut off = 0usize;
        for fr in &filters {
            filter_off.push(off);
            off += 4 * fr.kernels.len();
        }
        FkwLayer {
            out_channels: o,
            in_channels: i,
            patterns: asg.set.patterns.clone(),
            filters,
            weights,
            stride,
            pad,
            pos_tab,
            filter_off,
        }
    }

    /// Decode back to a dense OIHW tensor (testing / interop).
    pub fn decode(&self) -> Tensor {
        let mut out = Tensor::zeros(&[self.out_channels, self.in_channels, 3, 3]);
        let mut wi = 0;
        for fr in &self.filters {
            let f = fr.original_index as usize;
            for kr in &fr.kernels {
                let p = self.patterns[kr.pattern as usize];
                for pos in p.positions() {
                    out.set(&[f, kr.channel as usize, pos / 3, pos % 3], self.weights[wi]);
                    wi += 1;
                }
            }
        }
        out
    }

    /// Surviving kernel count.
    pub fn kernel_count(&self) -> usize {
        self.filters.iter().map(|f| f.kernels.len()).sum()
    }

    /// Index (structure) overhead in bytes: 2B channel + 1B pattern per
    /// kernel, 2B per filter for the permutation.
    pub fn index_overhead_bytes(&self) -> usize {
        self.kernel_count() * 3 + self.filters.len() * 2
    }

    /// Number of pattern-id switches along each filter's kernel list —
    /// the branch-divergence proxy that reorder minimizes (Fig 10).
    pub fn pattern_switches(&self) -> usize {
        self.filters
            .iter()
            .map(|f| {
                f.kernels
                    .windows(2)
                    .filter(|w| w[0].pattern != w[1].pattern)
                    .count()
            })
            .sum()
    }

    /// Execute the layer on an NCHW input, directly from compact form.
    /// Allocating wrapper over [`FkwLayer::conv2d_into`] — the steady-state
    /// engine calls the `_into` form against the workspace arena.
    pub fn conv2d(&self, input: &Tensor) -> Tensor {
        assert_eq!(input.rank(), 4);
        let (n, c, h, w) = (
            input.shape()[0],
            input.shape()[1],
            input.shape()[2],
            input.shape()[3],
        );
        assert_eq!(c, self.in_channels);
        let oh = (h + 2 * self.pad - 3) / self.stride + 1;
        let ow = (w + 2 * self.pad - 3) / self.stride + 1;
        let mut out = Tensor::zeros(&[n, self.out_channels, oh, ow]);
        self.conv2d_into(
            input.data(),
            n,
            h,
            w,
            crate::runtime::pool::configured_threads(),
            out.data_mut(),
        );
        out
    }

    /// Execute the layer on a flat NCHW input, writing the NCHW output
    /// into `out` — allocation-free, with **filter bands** dispatched on
    /// the persistent worker pool (`threads`; pass 1 to force serial).
    ///
    /// The inner loop is branch-less per kernel group: pattern offsets
    /// come from the encode-time table, and the 4 multiply-adds are
    /// unrolled. Filter bands are race-free by construction — every
    /// `(batch, filter)` output plane is owned by exactly one band. This
    /// is the hot path that `benches/hotpath_exec.rs` profiles.
    pub fn conv2d_into(
        &self,
        x: &[f32],
        n: usize,
        h: usize,
        w: usize,
        threads: usize,
        out: &mut [f32],
    ) {
        let c = self.in_channels;
        assert_eq!(x.len(), n * c * h * w, "fkw conv input length");
        let oh = (h + 2 * self.pad - 3) / self.stride + 1;
        let ow = (w + 2 * self.pad - 3) / self.stride + 1;
        let out_len = n * self.out_channels * oh * ow;
        let out = &mut out[..out_len];
        out.fill(0.0);
        let nf = self.filters.len();
        if nf == 0 {
            return;
        }
        let work = n * oh * ow * self.kernel_count();
        let t = if work < (1 << 14) { 1 } else { threads.max(1).min(nf) };
        let out_sh = crate::runtime::pool::SharedSlice::new(out);
        if t <= 1 {
            self.conv_filter_band(x, n, h, w, oh, ow, 0, nf, &out_sh);
            return;
        }
        let per = (nf + t - 1) / t;
        let bands = (nf + per - 1) / per;
        crate::runtime::pool::global().parallel_for(bands, |bi| {
            let f0 = bi * per;
            let f1 = nf.min(f0 + per);
            self.conv_filter_band(x, n, h, w, oh, ow, f0, f1, &out_sh);
        });
    }

    /// Run filters `[f0, f1)` over every batch entry, accumulating into
    /// the shared output. Each `(batch, filter)` plane is touched by
    /// exactly one band, so concurrent bands never alias.
    #[allow(clippy::too_many_arguments)]
    fn conv_filter_band(
        &self,
        x: &[f32],
        n: usize,
        h: usize,
        w: usize,
        oh: usize,
        ow: usize,
        f0: usize,
        f1: usize,
        out: &crate::runtime::pool::SharedSlice,
    ) {
        let c = self.in_channels;
        let (pad, stride) = (self.pad as isize, self.stride);
        for b in 0..n {
            for fi in f0..f1 {
                let fr = &self.filters[fi];
                let f = fr.original_index as usize;
                // SAFETY: plane (b, f) belongs to this band alone.
                let plane = unsafe {
                    out.slice_mut(((b * self.out_channels) + f) * oh * ow, oh * ow)
                };
                let mut wi = self.filter_off[fi];
                for kr in &fr.kernels {
                    let ci = kr.channel as usize;
                    let in_base = ((b * c) + ci) * h * w;
                    let tab = &self.pos_tab[kr.pattern as usize];
                    let wk = [
                        self.weights[wi],
                        self.weights[wi + 1],
                        self.weights[wi + 2],
                        self.weights[wi + 3],
                    ];
                    wi += 4;
                    for y in 0..oh {
                        let row_out = y * ow;
                        for xx in 0..ow {
                            let mut acc = 0.0f32;
                            // Unrolled 4-entry pattern body.
                            for t in 0..4 {
                                let (ky, kx) = tab[t];
                                let iy = (y * stride + ky) as isize - pad;
                                let ix = (xx * stride + kx) as isize - pad;
                                if iy >= 0 && ix >= 0 && (iy as usize) < h && (ix as usize) < w {
                                    acc += wk[t] * x[in_base + iy as usize * w + ix as usize];
                                }
                            }
                            plane[row_out + xx] += acc;
                        }
                    }
                }
            }
        }
    }
}

/// CSR overhead for the same sparse tensor: 4B column index per nonzero +
/// 4B row pointer per row (the comparison the paper's FKW claim makes).
pub fn csr_overhead_bytes(nnz: usize, rows: usize) -> usize {
    nnz * 4 + (rows + 1) * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::pattern::{assign_patterns, apply_assignment, connectivity_prune, PatternSet};
    use crate::util::proptest_lite::forall;
    use crate::util::rng::Rng;

    fn pruned_layer(rng: &mut Rng, o: usize, i: usize, conn: f64) -> (Tensor, PatternAssignment) {
        let w = Tensor::randn(&[o, i, 3, 3], 1.0, rng);
        let mut asg = assign_patterns(&w, &PatternSet::elite8());
        if conn > 0.0 {
            connectivity_prune(&w, &mut asg, conn);
        }
        let wp = apply_assignment(&w, &asg);
        (wp, asg)
    }

    #[test]
    fn encode_decode_roundtrip() {
        forall("fkw roundtrip", 16, |rng| {
            let o = 2 + rng.below(6);
            let i = 1 + rng.below(5);
            let conn = if rng.chance(0.5) { 0.3 } else { 0.0 };
            let (wp, asg) = pruned_layer(rng, o, i, conn);
            for reorder in [false, true] {
                let fkw = FkwLayer::encode(&wp, &asg, 1, 1, reorder);
                assert_eq!(fkw.decode(), wp, "roundtrip failed (reorder={reorder})");
            }
        });
    }

    #[test]
    fn fkw_conv_matches_dense_conv() {
        forall("fkw conv == dense conv on pruned weights", 12, |rng| {
            let o = 2 + rng.below(4);
            let i = 1 + rng.below(4);
            let (wp, asg) = pruned_layer(rng, o, i, 0.2);
            let x = Tensor::randn(&[1, i, 6 + rng.below(5), 6 + rng.below(5)], 1.0, rng);
            let stride = 1 + rng.below(2);
            let fkw = FkwLayer::encode(&wp, &asg, stride, 1, true);
            let dense = x.conv2d(&wp, stride, 1);
            let sparse = fkw.conv2d(&x);
            assert!(
                dense.max_abs_diff(&sparse) < 1e-4,
                "diff {}",
                dense.max_abs_diff(&sparse)
            );
        });
    }

    #[test]
    fn reorder_reduces_pattern_switches() {
        let mut rng = Rng::new(41);
        let (wp, asg) = pruned_layer(&mut rng, 32, 16, 0.0);
        let plain = FkwLayer::encode(&wp, &asg, 1, 1, false);
        let reordered = FkwLayer::encode(&wp, &asg, 1, 1, true);
        assert!(
            reordered.pattern_switches() <= plain.pattern_switches(),
            "reorder increased switches: {} -> {}",
            plain.pattern_switches(),
            reordered.pattern_switches()
        );
        // With 8 patterns over 16 kernels, sorting must strictly help on
        // random assignments.
        assert!(reordered.pattern_switches() < plain.pattern_switches());
    }

    #[test]
    fn fkw_overhead_below_csr() {
        let mut rng = Rng::new(42);
        let (wp, asg) = pruned_layer(&mut rng, 64, 32, 0.3);
        let fkw = FkwLayer::encode(&wp, &asg, 1, 1, true);
        let nnz = wp.data().iter().filter(|&&v| v != 0.0).count();
        let csr = csr_overhead_bytes(nnz, 64 * 32 * 3); // CSR over the GEMM matrix rows
        assert!(
            fkw.index_overhead_bytes() * 2 < csr,
            "fkw {} vs csr {}",
            fkw.index_overhead_bytes(),
            csr
        );
    }

    #[test]
    fn connectivity_pruned_kernels_absent() {
        let mut rng = Rng::new(43);
        let (wp, asg) = pruned_layer(&mut rng, 8, 8, 0.5);
        let fkw = FkwLayer::encode(&wp, &asg, 1, 1, true);
        // ~50% of 64 kernels cut.
        assert!(fkw.kernel_count() <= 36, "kernels {}", fkw.kernel_count());
        assert_eq!(fkw.weights.len(), fkw.kernel_count() * 4);
    }

    /// Pool-dispatched filter bands write disjoint output planes, so the
    /// parallel result is bitwise equal to the serial one (and to the
    /// allocating wrapper).
    #[test]
    fn parallel_filter_bands_match_serial() {
        let mut rng = Rng::new(45);
        let (wp, asg) = pruned_layer(&mut rng, 16, 8, 0.2);
        let fkw = FkwLayer::encode(&wp, &asg, 1, 1, true);
        let x = Tensor::randn(&[2, 8, 16, 16], 1.0, &mut rng);
        let mut serial = Tensor::zeros(&[2, 16, 16, 16]);
        fkw.conv2d_into(x.data(), 2, 16, 16, 1, serial.data_mut());
        let mut par = Tensor::zeros(&[2, 16, 16, 16]);
        fkw.conv2d_into(x.data(), 2, 16, 16, 4, par.data_mut());
        assert_eq!(serial.data(), par.data());
        assert_eq!(serial.data(), fkw.conv2d(&x).data());
    }

    #[test]
    fn strided_output_shape() {
        let mut rng = Rng::new(44);
        let (wp, asg) = pruned_layer(&mut rng, 4, 3, 0.0);
        let fkw = FkwLayer::encode(&wp, &asg, 2, 1, true);
        let x = Tensor::randn(&[2, 3, 8, 8], 1.0, &mut rng);
        let y = fkw.conv2d(&x);
        assert_eq!(y.shape(), &[2, 4, 4, 4]);
    }
}
