//! Pattern-conscious code generation (§2.3.1).
//!
//! Three pieces of the paper's low-level story live here:
//!
//! 1. **Layerwise Representation (LR)** — the per-layer record carrying
//!    sparsity structure (pattern vocabulary, per-filter pattern order,
//!    kernel↔channel connectivity) and the tuning-decided parameters (tile
//!    sizes, unroll factor, loop permutation).
//! 2. **Load-redundancy elimination (LRE)** — the analysis that counts the
//!    register loads a pattern kernel performs with and without the
//!    optimization: since patterns are known at compile time, loads of
//!    input values shared by adjacent unrolled outputs are hoisted, and
//!    all indirect accesses become static offsets.
//! 3. **Kernel source emission** — generates the branch-less, fully
//!    unrolled C-like inner body per pattern (what XGen ships to the
//!    phone; here it is inspectable output, exercised by tests and the
//!    `xgen emit-kernel` CLI).
//!
//! The register/spill model also quantifies Fig 19's MCU claim: loop
//! unrolling "reduces the register spilling" — [`spill_estimate`] computes
//! spills for a given unroll factor and register file size, and the MCU
//! bench derives its speedup from the spill delta rather than a hardcoded
//! factor.

use crate::pruning::pattern::{Pattern, PatternAssignment};

/// Tuning-decided execution parameters of one layer (LR fields).
#[derive(Debug, Clone, PartialEq)]
pub struct TuneParams {
    /// Output tile height/width held in registers/cache.
    pub tile_h: usize,
    pub tile_w: usize,
    /// Horizontal unroll factor of the output loop.
    pub unroll: usize,
    /// Loop order: true = output-channel outermost (weight-stationary).
    pub filter_outer: bool,
}

impl Default for TuneParams {
    fn default() -> Self {
        TuneParams { tile_h: 4, tile_w: 8, unroll: 4, filter_outer: true }
    }
}

/// The Layerwise Representation of one pattern-pruned conv layer.
#[derive(Debug, Clone)]
pub struct LayerRep {
    pub name: String,
    pub patterns: Vec<Pattern>,
    /// Per (execution-order) filter: ordered pattern ids of its kernels.
    pub filter_patterns: Vec<Vec<u8>>,
    pub tune: TuneParams,
}

impl LayerRep {
    /// Build the LR from a pattern assignment (post filter-kernel reorder).
    pub fn from_assignment(name: &str, asg: &PatternAssignment, tune: TuneParams) -> LayerRep {
        let filter_patterns = asg
            .assignment
            .iter()
            .enumerate()
            .map(|(f, row)| {
                let mut ps: Vec<u8> = row
                    .iter()
                    .enumerate()
                    .filter(|(c, _)| !asg.pruned_kernels[f][*c])
                    .map(|(_, &p)| p as u8)
                    .collect();
                ps.sort_unstable();
                ps
            })
            .collect();
        LayerRep {
            name: name.to_string(),
            patterns: asg.set.patterns.clone(),
            filter_patterns,
            tune,
        }
    }

    /// Distinct pattern ids present in the layer (LR field used by the
    /// runtime to pick specialized kernels).
    pub fn patterns_present(&self) -> Vec<u8> {
        let mut seen = vec![false; self.patterns.len()];
        for f in &self.filter_patterns {
            for &p in f {
                seen[p as usize] = true;
            }
        }
        seen.iter()
            .enumerate()
            .filter(|(_, &s)| s)
            .map(|(i, _)| i as u8)
            .collect()
    }
}

/// Register-load counts for one kernel invocation over a tile.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LoadStats {
    /// Loads executed by the naive (per-output, per-tap) code.
    pub naive: u64,
    /// Loads after load-redundancy elimination.
    pub lre: u64,
}

impl LoadStats {
    pub fn reduction(&self) -> f64 {
        1.0 - self.lre as f64 / self.naive.max(1) as f64
    }
}

/// Count input register loads for one pattern kernel across a `tile_w`-wide
/// unrolled row of outputs (stride 1).
///
/// Naive: each of the `u` outputs issues 4 loads → `4u`.
/// LRE: the pattern's taps are known offsets; a tap at column `kx` for
/// output `x` touches input column `x+kx`, so across `u` adjacent outputs
/// the distinct columns touched per tap-row collapse — each distinct
/// (row, col) input element is loaded once.
pub fn pattern_load_stats(p: Pattern, unroll: usize) -> LoadStats {
    let u = unroll.max(1) as u64;
    let naive = 4 * u;
    // Distinct (ky, x+kx) pairs over x in 0..u.
    let mut seen = std::collections::BTreeSet::new();
    for x in 0..unroll.max(1) {
        for pos in p.positions() {
            let (ky, kx) = (pos / 3, pos % 3);
            seen.insert((ky, x + kx));
        }
    }
    LoadStats { naive, lre: seen.len() as u64 }
}

/// Aggregate LRE statistics over a layer.
pub fn layer_load_stats(lr: &LayerRep) -> LoadStats {
    let mut naive = 0u64;
    let mut lre = 0u64;
    for f in &lr.filter_patterns {
        for &p in f {
            let s = pattern_load_stats(lr.patterns[p as usize], lr.tune.unroll);
            naive += s.naive;
            lre += s.lre;
        }
    }
    LoadStats { naive, lre }
}

/// Registers needed by the unrolled pattern body: `unroll` accumulators +
/// 4 weight registers + distinct input values live at once + loop
/// bookkeeping.
pub fn registers_needed(p: Pattern, unroll: usize) -> usize {
    let stats = pattern_load_stats(p, unroll);
    unroll + 4 + stats.lre as usize / 3 + 3
}

/// Estimated register spills per inner-loop iteration for a register file
/// of `regs` (Fig 19 mechanism: unrolling amortizes loop overhead but too
/// much unrolling spills; the MCU tuner picks the knee).
pub fn spill_estimate(p: Pattern, unroll: usize, regs: usize) -> usize {
    registers_needed(p, unroll).saturating_sub(regs)
}

/// Pick the best unroll factor for a register budget: largest unroll with
/// zero spills (falls back to 1).
pub fn tune_unroll(p: Pattern, regs: usize) -> usize {
    let mut best = 1;
    for u in [1usize, 2, 4, 8, 16] {
        if spill_estimate(p, u, regs) == 0 {
            best = u;
        }
    }
    best
}

/// Emit the branch-less C-like inner body for one pattern at a given
/// unroll factor: all offsets static, no indirect access, no conditionals.
pub fn emit_kernel_source(p: Pattern, unroll: usize) -> String {
    let mut src = String::new();
    src.push_str(&format!(
        "// pattern 0x{:03x} — 4-entry kernel, unroll {}\n",
        p.0, unroll
    ));
    src.push_str("// taps: ");
    for pos in p.positions() {
        src.push_str(&format!("({},{}) ", pos / 3, pos % 3));
    }
    src.push('\n');
    src.push_str(&format!(
        "static inline void pat_{:03x}_u{}(const float* in, long ldin, const float* w, float* out) {{\n",
        p.0, unroll
    ));
    // Hoisted distinct loads (LRE).
    let mut loaded = std::collections::BTreeMap::new();
    for x in 0..unroll {
        for pos in p.positions() {
            let (ky, kx) = (pos / 3, pos % 3);
            let key = (ky, x + kx);
            if !loaded.contains_key(&key) {
                let reg = format!("i{}_{}", ky, x + kx);
                src.push_str(&format!(
                    "    const float {reg} = in[{} * ldin + {}];\n",
                    ky,
                    x + kx
                ));
                loaded.insert(key, reg);
            }
        }
    }
    for x in 0..unroll {
        let mut terms = Vec::new();
        for (t, pos) in p.positions().iter().enumerate() {
            let (ky, kx) = (pos / 3, pos % 3);
            terms.push(format!("w[{t}] * {}", loaded[&(ky, x + kx)]));
        }
        src.push_str(&format!("    out[{x}] += {};\n", terms.join(" + ")));
    }
    src.push_str("}\n");
    src
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruning::pattern::{assign_patterns, PatternSet};
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn a_pattern() -> Pattern {
        PatternSet::elite8().patterns[0]
    }

    #[test]
    fn lre_reduces_loads_when_unrolled() {
        let p = a_pattern();
        let s1 = pattern_load_stats(p, 1);
        assert_eq!(s1.naive, 4);
        assert!(s1.lre <= 4);
        let s8 = pattern_load_stats(p, 8);
        assert_eq!(s8.naive, 32);
        assert!(s8.lre < s8.naive, "no LRE benefit at unroll 8");
        assert!(s8.reduction() > 0.25, "reduction {}", s8.reduction());
    }

    #[test]
    fn lre_counts_exact_for_known_pattern() {
        // Pattern with taps in rows {0,1} and cols {0,1}: center+left+top...
        // use positions() to compute expected distinct loads by hand.
        let p = a_pattern();
        let stats = pattern_load_stats(p, 2);
        // Distinct cols per tap row: each tap contributes cols {kx, kx+1}.
        let mut expect = std::collections::BTreeSet::new();
        for x in 0..2 {
            for pos in p.positions() {
                expect.insert((pos / 3, x + pos % 3));
            }
        }
        assert_eq!(stats.lre, expect.len() as u64);
    }

    #[test]
    fn emitted_source_is_branchless_and_unrolled() {
        let src = emit_kernel_source(a_pattern(), 4);
        assert!(!src.contains("if"), "branch in inner body:\n{src}");
        assert!(!src.contains("for"), "loop in inner body:\n{src}");
        assert_eq!(src.matches("out[").count(), 4, "unroll mismatch:\n{src}");
        // All four taps used per output.
        assert!(src.contains("w[0]") && src.contains("w[3]"));
    }

    #[test]
    fn unroll_tuner_finds_knee() {
        let p = a_pattern();
        // Cortex-M4-ish: ~13 allocatable registers → small unroll.
        let mcu = tune_unroll(p, 13);
        // AArch64 NEON: 32 registers → larger unroll.
        let neon = tune_unroll(p, 32);
        assert!(mcu >= 1);
        assert!(neon > mcu, "neon {neon} !> mcu {mcu}");
        assert_eq!(spill_estimate(p, mcu, 13), 0);
    }

    #[test]
    fn layer_rep_tracks_patterns_present() {
        let mut rng = Rng::new(71);
        let w = Tensor::randn(&[8, 4, 3, 3], 1.0, &mut rng);
        let asg = assign_patterns(&w, &PatternSet::elite8());
        let lr = LayerRep::from_assignment("conv1", &asg, TuneParams::default());
        let present = lr.patterns_present();
        assert!(!present.is_empty() && present.len() <= 8);
        let stats = layer_load_stats(&lr);
        assert!(stats.lre < stats.naive);
    }

    #[test]
    fn spills_grow_with_unroll() {
        let p = a_pattern();
        assert!(registers_needed(p, 8) > registers_needed(p, 2));
        assert!(spill_estimate(p, 16, 10) > spill_estimate(p, 2, 10));
    }
}
