//! The Level-4 autonomous-driving application of Fig 16 / Table 5 (the
//! "ADApp" workload): Sensing → {2D, 3D} Perception → Localization →
//! Tracking → Prediction → Planning, in six variants — detector family
//! {ADy = YOLO-based, ADs = SSD-based} × camera input size
//! {288, 416, 608} — deployed on a Jetson-AGX-Xavier-like board.
//!
//! Perception service demands are **derived from the cost model**: the 2-D
//! perceptor is the zoo's YOLO-v4 (or MobileNet-SSD) scaled to the input
//! size across `CAMERAS` camera streams on the Jetson GPU; the 3-D
//! perceptor is PointPillars. CPU-side module demands (sensing,
//! localization, tracking, prediction, planning) are the paper's reported
//! standalone values (they are conventional code, not DNNs).

use crate::cost::{devices, estimate_latency, DensityMap};
use crate::fusion::{fuse, FusionConfig};
use crate::graph::zoo::by_name;

use super::{ModuleSpec, Unit};

/// Camera streams feeding 2-D perception (L4 rigs run 6–8 cameras).
pub const CAMERAS: f64 = 6.0;

/// DLA demand multiplier vs GPU (lower clocks, narrower datapath — and
/// the DLA runs fp16 without the GPU's tensor-core paths).
pub const DLA_FACTOR: f64 = 2.75;

/// Compression factor model-schedule co-optimization achieves on the
/// perception DNNs (block pruning at ~5× FLOP reduction with block-size
/// chosen for the DLA/GPU — consistent with `cost::sparse_efficiency`
/// for 32-wide blocks at rate 0.78: (1-0.78)/0.85 ≈ 0.26).
pub const COOPT_COMPRESSION: f64 = 0.26;

/// Application variants of Table 5.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Variant {
    pub name: &'static str,
    /// "y" = YOLO-based detector, "s" = SSD-based.
    pub yolo: bool,
    pub input: usize,
}

/// The six Table 5 variants.
pub fn variants() -> [Variant; 6] {
    [
        Variant { name: "ADy288", yolo: true, input: 288 },
        Variant { name: "ADy416", yolo: true, input: 416 },
        Variant { name: "ADy608", yolo: true, input: 608 },
        Variant { name: "ADs288", yolo: false, input: 288 },
        Variant { name: "ADs416", yolo: false, input: 416 },
        Variant { name: "ADs608", yolo: false, input: 608 },
    ]
}

/// GPU service demand (ms) of the 2-D perception module for a variant:
/// cost-model latency of the detector graph, scaled by input area and
/// camera count.
pub fn perception2d_demand_ms(v: Variant) -> f64 {
    let (g, native) = if v.yolo {
        (by_name("yolo-v4", 1), 416.0f64)
    } else {
        (by_name("mobilenet-v1-ssd", 1), 300.0f64)
    };
    let plan = fuse(&g, &FusionConfig::default());
    let one = estimate_latency(&g, &plan, &devices::jetson_gpu(), &jetson_profile(), &DensityMap::new(), 1.0)
        .total_ms();
    // Latency grows sub-quadratically with input size (the deep tail of
    // the detector is resolution-independent): use sqrt of the area ratio.
    let area_scale = v.input as f64 / native;
    // SSD is ~6× lighter per frame, but the ADs rig compensates with a
    // higher frame rate per camera to match YOLO's detection coverage —
    // the paper's ADs rows track the ADy rows closely; model that with a
    // flat per-period demand factor.
    let family = if v.yolo { 1.0 } else { 6.3 };
    one * area_scale * family * CAMERAS
}

/// TensorRT-class runtime profile on the Jetson GPU.
fn jetson_profile() -> crate::cost::ExecProfile {
    crate::cost::ExecProfile {
        name: "jetson-trt",
        eff: 0.15,
        per_group_overhead_ms: 0.03,
        sparse_capable: true,
    }
}

/// GPU service demand of 3-D (LiDAR) perception: PointPillars.
pub fn perception3d_demand_ms() -> f64 {
    let g = by_name("pointpillar", 1);
    let plan = fuse(&g, &FusionConfig::default());
    // PointPillars' big regular convs run closer to TensorRT peak than the
    // branchy multi-camera detector pipeline.
    let prof = crate::cost::ExecProfile { eff: 0.27, ..jetson_profile() };
    estimate_latency(&g, &plan, &devices::jetson_gpu(), &prof, &DensityMap::new(), 1.0).total_ms()
}

/// Build the module set for a variant.
pub fn modules(v: Variant) -> Vec<ModuleSpec> {
    let p2d = perception2d_demand_ms(v);
    let p3d = perception3d_demand_ms();
    vec![
        ModuleSpec {
            name: "sensing",
            unit: Unit::Cpu(0),
            demand_ms: 8.5,
            alt: None,
            period_ms: 100.0,
            expected_ms: 100.0,
            priority: 90,
            latency_critical: false,
            jitter: 0.10,
            is_dnn: false,
        },
        // 3-D percept releases first (module index parity drives the ROSCH
        // lock order: 3D takes buffer→GPU, 2D takes GPU→buffer).
        ModuleSpec {
            name: "3d_percept",
            unit: Unit::Gpu,
            demand_ms: p3d,
            alt: Some((Unit::Dla(0), p3d * DLA_FACTOR)),
            period_ms: 100.0,
            expected_ms: 100.0,
            // The LiDAR path outranks the camera path (safety-critical
            // obstacle detection) under the priority schedulers.
            priority: 55,
            latency_critical: false,
            jitter: 0.12,
            is_dnn: true,
        },
        ModuleSpec {
            name: "2d_percept",
            unit: Unit::Gpu,
            demand_ms: p2d,
            alt: None, // stays on GPU; migration moves 3-D off instead
            period_ms: 100.0,
            expected_ms: 100.0,
            priority: 50,
            latency_critical: false,
            jitter: 0.07,
            is_dnn: true,
        },
        // Localization contends on CPU core 1 with the perception pre/post
        // thread below; JIT priority adjustment marks it latency-critical.
        ModuleSpec {
            name: "localization",
            unit: Unit::Cpu(1),
            demand_ms: 43.0,
            alt: None,
            period_ms: 100.0,
            expected_ms: 100.0,
            priority: 10,
            latency_critical: true,
            jitter: 0.22,
            is_dnn: false,
        },
        ModuleSpec {
            name: "percept_postproc",
            unit: Unit::Cpu(1),
            demand_ms: 45.0,
            alt: None,
            period_ms: 100.0,
            // Pipeline-internal thread: its output feeds the *next* frame,
            // so its effective deadline is two periods (not a Table 5 row).
            expected_ms: 200.0,
            priority: 20, // statically above localization: the starvation bug
            latency_critical: false,
            jitter: 0.10,
            is_dnn: false,
        },
        ModuleSpec {
            name: "tracking",
            unit: Unit::Cpu(2),
            demand_ms: 1.0,
            alt: None,
            period_ms: 100.0,
            expected_ms: 100.0,
            priority: 30,
            latency_critical: false,
            jitter: 0.6,
            is_dnn: false,
        },
        ModuleSpec {
            name: "prediction",
            unit: Unit::Cpu(2),
            demand_ms: 0.5,
            alt: None,
            period_ms: 100.0,
            expected_ms: 100.0,
            priority: 29,
            latency_critical: false,
            jitter: 0.8,
            is_dnn: false,
        },
        ModuleSpec {
            name: "planning",
            unit: Unit::Cpu(3),
            demand_ms: 1.1,
            alt: None,
            period_ms: 10.0,
            expected_ms: 10.0,
            priority: 95,
            latency_critical: false,
            jitter: 0.3,
            is_dnn: false,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::xengine::sim::simulate;
    use crate::xengine::Policy;

    #[test]
    fn perception_demands_in_expected_band() {
        // 2-D perception must exceed its 100 ms budget when dense (that is
        // what Table 5 segments 2–4 show) and the 3-D perceptor must be
        // comfortably smaller.
        let p608 = perception2d_demand_ms(Variant { name: "ADy608", yolo: true, input: 608 });
        let p288 = perception2d_demand_ms(Variant { name: "ADy288", yolo: true, input: 288 });
        let p3d = perception3d_demand_ms();
        assert!(p288 < p608, "{p288} !< {p608}");
        assert!((250.0..480.0).contains(&p608), "2d@608 {p608}");
        assert!((110.0..260.0).contains(&p288), "2d@288 {p288}");
        assert!(p3d > 20.0 && p3d < 80.0, "3d {p3d}");
    }

    #[test]
    fn table5_segment_shape() {
        let v = variants()[1]; // ADy416
        let mods = modules(v);
        // Segment 1: ROSCH deadlocks perception.
        let r1 = simulate(v.name, &mods, Policy::Rosch, 3000.0, 11);
        assert!(r1.module("2d_percept").timed_out());
        assert!(r1.module("3d_percept").timed_out());
        assert!(!r1.module("sensing").timed_out());
        assert!(!r1.module("planning").timed_out());
        // Segment 2: Linux TS resolves the deadlock; 2-D percept misses.
        let r2 = simulate(v.name, &mods, Policy::LinuxTs, 3000.0, 12);
        assert!(!r2.module("2d_percept").timed_out());
        assert!(r2.module("2d_percept").miss_rate() > 0.9);
        assert!(r2.module("localization").mean() > 70.0, "{}", r2.module("localization").mean());
        // Segment 3: JIT fixes localization, 2-D percept still misses.
        let r3 = simulate(v.name, &mods, Policy::JitPriority, 3000.0, 13);
        assert!(r3.module("localization").mean() < 60.0, "{}", r3.module("localization").mean());
        assert!(r3.module("2d_percept").miss_rate() > 0.9);
        // Segment 5: co-optimization meets all deadlines.
        let r5 = simulate(v.name, &mods, Policy::CoOpt, 3000.0, 15);
        assert!(
            r5.worst_miss_rate() < 0.05,
            "co-opt misses: {:?}",
            r5.modules.iter().map(|m| (m.name, m.miss_rate())).collect::<Vec<_>>()
        );
    }

    #[test]
    fn migration_offloads_3d_to_dla() {
        let v = variants()[0];
        let mods = modules(v);
        let r4 = simulate(v.name, &mods, Policy::JitMigration, 3000.0, 14);
        // 3-D percept slower than on GPU (DLA factor) but 2-D percept
        // improves relative to fair-shared GPU.
        let r2 = simulate(v.name, &mods, Policy::LinuxTs, 3000.0, 14);
        assert!(
            r4.module("3d_percept").mean() > r2.module("3d_percept").mean(),
            "DLA should be slower for 3D"
        );
        assert!(
            r4.module("2d_percept").mean() < r2.module("2d_percept").mean(),
            "2D should improve with sole GPU ownership"
        );
    }
}
