//! Processor-sharing discrete-event simulator for multi-DNN scheduling.
//!
//! Each [`Unit`] runs the task instances assigned to it under the active
//! policy's sharing discipline:
//! * strict priority (ROSCH, JIT-adjusted): the highest-priority runnable
//!   instance gets the whole unit;
//! * fair share (Linux time-sharing): all runnable instances progress at
//!   `1/n` rate.
//!
//! ROSCH additionally models its two-lock acquisition protocol: DNN
//! modules take (GPU-lock, perception-buffer) in *inconsistent order* —
//! the classic circular wait. Once two DNN instances are mid-acquisition,
//! neither ever completes, reproducing Table 5 segment 1's `∞` rows
//! (Sensing and Planning, which touch neither lock, keep running).
//!
//! Time advances in fixed 0.1 ms quanta — a processor-sharing fluid
//! approximation that is simple and exact enough at Table 5's 100 ms
//! periods (validated against closed-form M/D/1-style cases in tests).

use std::collections::BTreeMap;

use crate::util::rng::Rng;

use super::{AppResult, ModuleResult, ModuleSpec, Policy, Unit};

const QUANTUM_MS: f64 = 0.1;

/// One in-flight instance of a module.
#[derive(Debug, Clone)]
struct Instance {
    module: usize,
    release_ms: f64,
    remaining_ms: f64,
    /// ROSCH lock state: 0 = wants first lock, 1 = holds first, 2 = holds
    /// both (running).
    lock_stage: u8,
}

/// Simulate `modules` for `horizon_ms` under `policy`.
pub fn simulate(
    variant: &'static str,
    modules: &[ModuleSpec],
    policy: Policy,
    horizon_ms: f64,
    seed: u64,
) -> AppResult {
    let mut rng = Rng::new(seed);
    let mut instances: Vec<Instance> = Vec::new();
    let mut results: Vec<ModuleResult> = modules
        .iter()
        .map(|m| ModuleResult {
            name: m.name,
            latencies: Vec::new(),
            released: 0,
            expected_ms: m.expected_ms,
        })
        .collect();
    let mut next_release: Vec<f64> = modules.iter().map(|_| 0.0).collect();

    // ROSCH deadlock bookkeeping: who holds lock A (gpu) and lock B
    // (perception buffer). DNN modules with even index take A-then-B, odd
    // take B-then-A.
    let mut lock_a: Option<usize> = None; // instance index
    let mut lock_b: Option<usize> = None;

    let mut t = 0.0f64;
    while t < horizon_ms {
        // Releases. Perception-style pipelines drop a frame when the
        // previous instance of the same module is still in flight (the
        // drop is recorded as a released-but-never-finished instance,
        // i.e. a deadline miss).
        for (mi, m) in modules.iter().enumerate() {
            if t + 1e-9 >= next_release[mi] {
                results[mi].released += 1;
                next_release[mi] += m.period_ms;
                // DNN (frame-processing) modules drop the new frame when
                // the previous one is still in flight; conventional CPU
                // modules queue and catch up.
                if m.is_dnn && instances.iter().any(|inst| inst.module == mi) {
                    continue; // frame dropped
                }
                let noise = 1.0 + m.jitter * rng.normal();
                let demand = effective_demand(m, policy) * noise.clamp(0.7, 1.4);
                instances.push(Instance {
                    module: mi,
                    release_ms: t,
                    remaining_ms: demand.max(0.05),
                    lock_stage: if matches!(policy, Policy::Rosch) && m.is_dnn { 0 } else { 2 },
                });
            }
        }

        // ROSCH lock acquisition (non-preemptive, inconsistent order).
        if matches!(policy, Policy::Rosch) {
            for idx in 0..instances.len() {
                let mi = instances[idx].module;
                if !modules[mi].is_dnn {
                    continue;
                }
                let a_first = mi % 2 == 0;
                match instances[idx].lock_stage {
                    0 => {
                        let first = if a_first { &mut lock_a } else { &mut lock_b };
                        if first.is_none() {
                            *first = Some(idx);
                            instances[idx].lock_stage = 1;
                        }
                    }
                    1 => {
                        let second = if a_first { &mut lock_b } else { &mut lock_a };
                        if second.is_none() {
                            *second = Some(idx);
                            instances[idx].lock_stage = 2;
                        }
                    }
                    _ => {}
                }
            }
        }

        // Group runnable instances per unit.
        let mut per_unit: BTreeMap<Unit, Vec<usize>> = BTreeMap::new();
        for (idx, inst) in instances.iter().enumerate() {
            if inst.lock_stage != 2 {
                continue; // blocked on a lock
            }
            let unit = placed_unit(&modules[inst.module], policy);
            per_unit.entry(unit).or_default().push(idx);
        }

        // Advance one quantum with the policy's sharing discipline.
        let mut progressed: Vec<(usize, f64)> = Vec::new();
        for (_, idxs) in &per_unit {
            match policy {
                Policy::LinuxTs => {
                    // Fair share.
                    let share = QUANTUM_MS / idxs.len() as f64;
                    for &i in idxs {
                        progressed.push((i, share));
                    }
                }
                _ => {
                    // Strict priority, preemptive; JIT boosts
                    // latency-critical modules above everything else.
                    let top = idxs
                        .iter()
                        .copied()
                        .max_by_key(|&i| {
                            let m = &modules[instances[i].module];
                            let boost = if policy_has_jit(policy) && m.latency_critical {
                                1000
                            } else {
                                0
                            };
                            (m.priority + boost, std::cmp::Reverse(instances[i].release_ms as i64))
                        })
                        .unwrap();
                    progressed.push((top, QUANTUM_MS));
                }
            }
        }
        for (i, d) in progressed {
            instances[i].remaining_ms -= d;
        }

        t += QUANTUM_MS;

        // Completions (release ROSCH locks).
        let mut done: Vec<usize> = instances
            .iter()
            .enumerate()
            .filter(|(_, inst)| inst.remaining_ms <= 1e-9)
            .map(|(i, _)| i)
            .collect();
        done.sort_unstable_by(|a, b| b.cmp(a));
        for i in done {
            let inst = instances.remove(i);
            results[inst.module].latencies.push(t - inst.release_ms);
            let fix = |l: &mut Option<usize>| {
                match *l {
                    Some(h) if h == i => *l = None,
                    Some(h) if h > i => *l = Some(h - 1),
                    _ => {}
                }
            };
            fix(&mut lock_a);
            fix(&mut lock_b);
        }
    }

    // Instances still in flight at the horizon that have not yet exceeded
    // their deadline are censored (neither a completion nor a miss).
    for inst in &instances {
        let m = &modules[inst.module];
        if horizon_ms - inst.release_ms < m.expected_ms * 1.1 {
            results[inst.module].released = results[inst.module].released.saturating_sub(1);
        }
    }

    AppResult { policy, variant, modules: results }
}

fn policy_has_jit(p: Policy) -> bool {
    matches!(p, Policy::JitPriority | Policy::JitMigration | Policy::CoOpt)
}

/// Which unit a module runs on under a policy (migration moves DNNs with
/// an accelerator alternative).
fn placed_unit(m: &ModuleSpec, policy: Policy) -> Unit {
    match policy {
        Policy::JitMigration | Policy::CoOpt => m.alt.map(|(u, _)| u).unwrap_or(m.unit),
        _ => m.unit,
    }
}

/// Service demand under a policy (migration uses the alternative-unit
/// demand; co-opt additionally compresses DNN models).
fn effective_demand(m: &ModuleSpec, policy: Policy) -> f64 {
    let base = match policy {
        Policy::JitMigration | Policy::CoOpt => m.alt.map(|(_, d)| d).unwrap_or(m.demand_ms),
        _ => m.demand_ms,
    };
    match policy {
        // Model-schedule co-optimization: the DNNs are re-optimized (block
        // pruning at a rate chosen to just meet the schedule; factor from
        // the cost model's pattern-pruning speedup — see adapp.rs).
        Policy::CoOpt if m.is_dnn => base * super::adapp::COOPT_COMPRESSION,
        _ => base,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn simple_module(name: &'static str, unit: Unit, demand: f64, period: f64) -> ModuleSpec {
        ModuleSpec {
            name,
            unit,
            demand_ms: demand,
            alt: None,
            period_ms: period,
            expected_ms: period,
            priority: 10,
            latency_critical: false,
            jitter: 0.0,
            is_dnn: false,
        }
    }

    #[test]
    fn single_task_latency_equals_demand() {
        let mods = [simple_module("a", Unit::Cpu(0), 10.0, 100.0)];
        let r = simulate("t", &mods, Policy::LinuxTs, 1000.0, 1);
        let m = r.module("a");
        assert!(m.latencies.len() >= 9);
        assert!((m.mean() - 10.0).abs() < 0.5, "mean {}", m.mean());
        assert_eq!(m.miss_rate(), 0.0);
    }

    #[test]
    fn fair_sharing_doubles_equal_tasks() {
        let mods = [
            simple_module("a", Unit::Gpu, 40.0, 100.0),
            simple_module("b", Unit::Gpu, 40.0, 100.0),
        ];
        let r = simulate("t", &mods, Policy::LinuxTs, 2000.0, 2);
        // Two equal tasks sharing: each sees ~80ms.
        assert!((r.module("a").mean() - 80.0).abs() < 4.0, "{}", r.module("a").mean());
        assert!((r.module("b").mean() - 80.0).abs() < 4.0);
    }

    #[test]
    fn ps_short_task_finishes_then_long_runs_alone() {
        let mods = [
            simple_module("short", Unit::Gpu, 45.0, 1000.0),
            simple_module("long", Unit::Gpu, 95.0, 1000.0),
        ];
        let r = simulate("t", &mods, Policy::LinuxTs, 1000.0, 3);
        // short: shares until done at ~90; long: 90 + (95-45) = ~140.
        assert!((r.module("short").mean() - 90.0).abs() < 5.0, "{}", r.module("short").mean());
        assert!((r.module("long").mean() - 140.0).abs() < 6.0, "{}", r.module("long").mean());
    }

    #[test]
    fn strict_priority_starves_low() {
        let mut high = simple_module("high", Unit::Cpu(0), 60.0, 100.0);
        high.priority = 100;
        let low = simple_module("low", Unit::Cpu(0), 60.0, 100.0);
        let r = simulate("t", &[high, low], Policy::JitPriority, 3000.0, 4);
        // High runs 60/100; low gets the remaining 40/100 → falls behind.
        assert!((r.module("high").mean() - 60.0).abs() < 3.0);
        assert!(r.module("low").miss_rate() > 0.5, "low miss {}", r.module("low").miss_rate());
    }

    #[test]
    fn jit_boost_overrides_static_priority() {
        let mut batch = simple_module("batch", Unit::Cpu(0), 50.0, 100.0);
        batch.priority = 100;
        let mut critical = simple_module("critical", Unit::Cpu(0), 20.0, 100.0);
        critical.priority = 1;
        critical.latency_critical = true;
        let r = simulate("t", &[batch, critical], Policy::JitPriority, 3000.0, 5);
        assert!((r.module("critical").mean() - 20.0).abs() < 2.0, "{}", r.module("critical").mean());
    }

    #[test]
    fn rosch_deadlocks_dnn_pair() {
        let mut a = simple_module("dnn_a", Unit::Gpu, 30.0, 100.0);
        a.is_dnn = true;
        let mut b = simple_module("dnn_b", Unit::Gpu, 30.0, 100.0);
        b.is_dnn = true;
        let cpu = simple_module("cpu_task", Unit::Cpu(0), 5.0, 100.0);
        // Module indices: a=0 (A-then-B), b=1 (B-then-A) → circular wait.
        let r = simulate("t", &[a, b, cpu], Policy::Rosch, 2000.0, 6);
        assert!(r.module("dnn_a").timed_out(), "a latencies: {:?}", r.module("dnn_a").latencies);
        assert!(r.module("dnn_b").timed_out());
        // Non-DNN work unaffected.
        assert_eq!(r.module("cpu_task").miss_rate(), 0.0);
    }

    #[test]
    fn migration_moves_to_alt_unit() {
        let mut dnn = simple_module("dnn", Unit::Gpu, 50.0, 100.0);
        dnn.is_dnn = true;
        dnn.alt = Some((Unit::Dla(0), 70.0));
        let other = {
            let mut m = simple_module("hog", Unit::Gpu, 90.0, 100.0);
            m.priority = 50;
            m
        };
        let r = simulate("t", &[dnn.clone(), other.clone()], Policy::JitMigration, 3000.0, 7);
        // On the DLA it runs alone: latency ≈ its DLA demand.
        assert!((r.module("dnn").mean() - 70.0).abs() < 5.0, "{}", r.module("dnn").mean());
    }
}
