//! XEngine — the AI-conscious co-optimizing runtime (§2.5).
//!
//! A processor-sharing discrete-event simulator over a heterogeneous
//! device set (Jetson-AGX-like: CPU cores, one GPU, DLA accelerators)
//! runs multi-DNN applications under five scheduling regimes, reproducing
//! the Table 5 ablation:
//!
//! 1. [`Policy::Rosch`] — fixed-priority real-time scheduler whose
//!    non-preemptive, inconsistently-ordered resource acquisition
//!    deadlocks the perception DNNs (Table 5 segment 1: ∞);
//! 2. [`Policy::LinuxTs`] — CFS-like fair time-sharing: no deadlock, but
//!    the GPU is oversubscribed and latency-critical CPU modules starve
//!    behind batch work (segment 2);
//! 3. [`Policy::JitPriority`] — XEngine's just-in-time priority
//!    adjustment fixes CPU-side starvation (segment 3);
//! 4. [`Policy::JitMigration`] — + DAG-instantiating scheduling migrates
//!    DNNs to the under-utilized DLA (segment 4);
//! 5. [`Policy::CoOpt`] — + model-schedule co-optimization compresses the
//!    models (via the [`crate::pruning`] machinery) until the whole DAG
//!    meets its deadlines (segment 5: 0% miss).

pub mod adapp;
pub mod knobs;
pub mod sim;

/// Compute units of the simulated board.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Unit {
    /// One of the CPU cores (index).
    Cpu(u8),
    Gpu,
    Dla(u8),
}

/// Scheduling regimes (Table 5 segments).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    Rosch,
    LinuxTs,
    JitPriority,
    JitMigration,
    CoOpt,
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Rosch => "ROSCH (default)",
            Policy::LinuxTs => "Linux time sharing",
            Policy::JitPriority => "JIT priority adjustment",
            Policy::JitMigration => "JIT + migration to accelerators",
            Policy::CoOpt => "JIT + migration + model-schedule co-opt",
        }
    }

    /// All segments in Table 5 order.
    pub fn all() -> [Policy; 5] {
        [
            Policy::Rosch,
            Policy::LinuxTs,
            Policy::JitPriority,
            Policy::JitMigration,
            Policy::CoOpt,
        ]
    }
}

/// A periodic task (one application module).
#[derive(Debug, Clone)]
pub struct ModuleSpec {
    pub name: &'static str,
    /// Preferred unit and service demand there (ms of dedicated time).
    pub unit: Unit,
    pub demand_ms: f64,
    /// Alternative unit (accelerator) and the demand there, if migratable.
    pub alt: Option<(Unit, f64)>,
    pub period_ms: f64,
    /// Expected (deadline) latency; Table 5 brackets.
    pub expected_ms: f64,
    /// Static priority (higher = more important) used by priority policies.
    pub priority: i32,
    /// Is this a latency-critical module for JIT priority adjustment?
    pub latency_critical: bool,
    /// Demand noise (std, fraction of demand).
    pub jitter: f64,
    /// DNN modules participate in the ROSCH lock-order deadlock and are
    /// eligible for migration / model co-optimization.
    pub is_dnn: bool,
}

/// Per-module simulation outcome.
#[derive(Debug, Clone)]
pub struct ModuleResult {
    pub name: &'static str,
    /// Completed-instance latencies (ms). Empty ⇒ no instance ever
    /// finished (deadlock/timeout: the Table 5 "∞").
    pub latencies: Vec<f64>,
    pub released: usize,
    pub expected_ms: f64,
}

impl ModuleResult {
    pub fn timed_out(&self) -> bool {
        self.latencies.is_empty() && self.released > 0
    }

    pub fn mean(&self) -> f64 {
        if self.latencies.is_empty() {
            f64::INFINITY
        } else {
            self.latencies.iter().sum::<f64>() / self.latencies.len() as f64
        }
    }

    pub fn std(&self) -> f64 {
        if self.latencies.len() < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.latencies.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
            / self.latencies.len() as f64)
            .sqrt()
    }

    /// Miss rate vs expected latency (10% slack per Table 5 caption),
    /// counting never-finished releases as misses.
    pub fn miss_rate(&self) -> f64 {
        if self.released == 0 {
            return 0.0;
        }
        let finished_misses = self
            .latencies
            .iter()
            .filter(|&&l| l > self.expected_ms * 1.1)
            .count();
        let unfinished = self.released - self.latencies.len();
        (finished_misses + unfinished) as f64 / self.released as f64
    }
}

/// Whole-application outcome for one policy.
#[derive(Debug, Clone)]
pub struct AppResult {
    pub policy: Policy,
    pub variant: &'static str,
    pub modules: Vec<ModuleResult>,
}

impl AppResult {
    pub fn module(&self, name: &str) -> &ModuleResult {
        self.modules
            .iter()
            .find(|m| m.name == name)
            .unwrap_or_else(|| panic!("no module '{name}'"))
    }

    /// The application's miss rate: that of its worst module (the paper's
    /// "most sluggish module" column).
    pub fn worst_miss_rate(&self) -> f64 {
        self.modules
            .iter()
            .map(|m| m.miss_rate())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn module_result_stats() {
        let r = ModuleResult {
            name: "m",
            latencies: vec![90.0, 110.0, 100.0],
            released: 4,
            expected_ms: 100.0,
        };
        assert!((r.mean() - 100.0).abs() < 1e-9);
        assert!(r.std() > 0.0);
        // 110 <= 110 (within 10% slack) → only the unfinished release misses.
        assert!((r.miss_rate() - 0.25).abs() < 1e-9);
        assert!(!r.timed_out());
    }

    #[test]
    fn timeout_detection() {
        let r = ModuleResult { name: "m", latencies: vec![], released: 10, expected_ms: 100.0 };
        assert!(r.timed_out());
        assert_eq!(r.mean(), f64::INFINITY);
        assert_eq!(r.miss_rate(), 1.0);
    }
}
