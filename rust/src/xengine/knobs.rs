//! Plastic IR + synergistic adaptation (§2.5, Fig 15).
//!
//! XGen injects **knobs** into the DNNs it compiles — points where the
//! runtime can cheaply change the executed computation: early exits
//! (which layer to stop at on a multi-exit model), input resolution, and
//! the sparsity variant to dispatch. XEngine's *synergistic adaptation*
//! couples these knobs with scheduling: when a device is contended, the
//! controller turns knobs down (cheaper variants) instead of letting
//! deadlines slip; when pressure releases, it turns them back up —
//! maximizing accuracy subject to the observed per-frame budget.

use crate::pruning::AccuracyModel;
use crate::pruning::PruneScheme;
use crate::tensor::gemm::GemmConfig;

/// A named GEMM tiling — the code-generation block-size knob (§2.3: tile
/// sizes are tuning-decided per layer/device). The runtime can dispatch a
/// different tiling per deployment target exactly like it dispatches a
/// sparsity variant; `benches/fig6_blocksize.rs` sweeps this ladder against
/// the cost model's traffic predictions and real wall-clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GemmKnob {
    pub name: &'static str,
    pub cfg: GemmConfig,
}

/// The standard tiling ladder, ordered from cache-starved to parallel:
/// panel footprints sized for L1-class, L2-class and L3-class working
/// sets, plus the default multi-threaded setting.
pub fn gemm_ladder() -> Vec<GemmKnob> {
    let knob = |name, mc, kc, nc, nr, threads| GemmKnob {
        name,
        cfg: GemmConfig { mc, kc, nc, nr, threads },
    };
    vec![
        knob("tiny-cache", 16, 64, 64, 4, 1),
        knob("l1-resident", 32, 128, 128, 8, 1),
        knob("l2-resident", 64, 256, 256, 8, 1),
        knob("l3-resident", 128, 512, 512, 8, 1),
        knob("parallel", 64, 256, 256, 8, 0),
    ]
}

/// Look up a ladder entry by name.
pub fn gemm_knob(name: &str) -> Option<GemmKnob> {
    gemm_ladder().into_iter().find(|k| k.name == name)
}

/// One steady-state engine configuration — the {weight pre-packing,
/// workspace arena, worker pool} toggle set. Like the sparsity-variant
/// knobs, these are *compile-time* dispatch decisions the runtime can pick
/// per deployment; `benches/steady_state.rs` sweeps the whole matrix and
/// writes `BENCH_steady.json`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SteadyKnob {
    pub name: &'static str,
    /// Pre-pack constant GEMM operands at compile time.
    pub prepack: bool,
    /// Execute through the per-model workspace arena (allocation-free
    /// steady state).
    pub workspace: bool,
    /// Dispatch row/filter bands on the persistent worker pool (false =
    /// single-threaded kernels).
    pub pool: bool,
}

/// The standard steady-state ladder, from the PR-1 baseline (allocate and
/// pack per call, serial) to the full steady-state engine.
pub fn steady_knobs() -> Vec<SteadyKnob> {
    let knob = |name, prepack, workspace, pool| SteadyKnob { name, prepack, workspace, pool };
    vec![
        knob("legacy", false, false, false),
        knob("pool-only", false, false, true),
        knob("workspace", false, true, true),
        knob("prepack", true, true, false),
        knob("steady", true, true, true),
    ]
}

/// Look up a steady-state knob by name.
pub fn steady_knob(name: &str) -> Option<SteadyKnob> {
    steady_knobs().into_iter().find(|k| k.name == name)
}

/// One selectable operating point of a compiled DNN (a knob setting).
#[derive(Debug, Clone, PartialEq)]
pub struct KnobSetting {
    pub name: &'static str,
    /// Nominal latency at this setting on the target unit, ms.
    pub latency_ms: f64,
    /// Accuracy at this setting (model-quality proxy).
    pub accuracy: f64,
}

/// A DNN with injected knobs (the "plastic IR" runtime view): settings
/// sorted by increasing cost.
#[derive(Debug, Clone)]
pub struct PlasticModel {
    pub name: String,
    pub settings: Vec<KnobSetting>,
}

impl PlasticModel {
    /// Build the standard knob ladder for a model with base latency/
    /// accuracy: early exits at 1/3 and 2/3 depth, plus a pruned variant
    /// per exit (the model-schedule co-optimization products).
    pub fn standard_ladder(name: &str, base_latency_ms: f64, base_acc: f64) -> PlasticModel {
        let am = AccuracyModel::default();
        let pruned_acc = am.estimate(base_acc, &PruneScheme::Block { block: 8, rate: 0.75 });
        let mut settings = vec![
            KnobSetting {
                name: "exit1/3+pruned",
                latency_ms: base_latency_ms * 0.33 * 0.45,
                accuracy: pruned_acc - 6.0,
            },
            KnobSetting {
                name: "exit1/3",
                latency_ms: base_latency_ms * 0.33,
                accuracy: base_acc - 6.0,
            },
            KnobSetting {
                name: "exit2/3+pruned",
                latency_ms: base_latency_ms * 0.66 * 0.45,
                accuracy: pruned_acc - 1.8,
            },
            KnobSetting {
                name: "exit2/3",
                latency_ms: base_latency_ms * 0.66,
                accuracy: base_acc - 1.8,
            },
            KnobSetting {
                name: "full+pruned",
                latency_ms: base_latency_ms * 0.45,
                accuracy: pruned_acc,
            },
            KnobSetting { name: "full", latency_ms: base_latency_ms, accuracy: base_acc },
        ];
        settings.sort_by(|a, b| a.latency_ms.partial_cmp(&b.latency_ms).unwrap());
        PlasticModel { name: name.to_string(), settings }
    }

    /// Best-accuracy setting within a latency budget (None if even the
    /// cheapest setting exceeds it).
    pub fn best_within(&self, budget_ms: f64) -> Option<&KnobSetting> {
        self.settings
            .iter()
            .filter(|s| s.latency_ms <= budget_ms)
            .max_by(|a, b| a.accuracy.partial_cmp(&b.accuracy).unwrap())
    }
}

/// The synergistic-adaptation controller: tracks the *observed* per-frame
/// time (scheduling contention included) and picks knob settings so the
/// deadline keeps being met, with hysteresis to avoid oscillation.
#[derive(Debug)]
pub struct AdaptationController {
    pub deadline_ms: f64,
    /// Exponential moving average of observed slowdown (observed/nominal).
    slowdown_ema: f64,
    alpha: f64,
    /// Current setting index (into the model's ladder).
    current: usize,
}

impl AdaptationController {
    pub fn new(deadline_ms: f64) -> AdaptationController {
        AdaptationController { deadline_ms, slowdown_ema: 1.0, alpha: 0.3, current: 0 }
    }

    pub fn slowdown(&self) -> f64 {
        self.slowdown_ema
    }

    /// Report one observed frame time at the current setting; returns the
    /// setting to use for the next frame.
    pub fn observe<'m>(&mut self, model: &'m PlasticModel, observed_ms: f64) -> &'m KnobSetting {
        let nominal = model.settings[self.current].latency_ms.max(1e-6);
        let inst = observed_ms / nominal;
        self.slowdown_ema = (1.0 - self.alpha) * self.slowdown_ema + self.alpha * inst;
        // Choose the best setting whose *predicted* time (nominal × EMA
        // slowdown) fits in 90% of the deadline (hysteresis margin).
        let budget = self.deadline_ms * 0.9 / self.slowdown_ema.max(0.1);
        let pick = model
            .settings
            .iter()
            .enumerate()
            .filter(|(_, s)| s.latency_ms <= budget)
            .max_by(|a, b| a.1.accuracy.partial_cmp(&b.1.accuracy).unwrap())
            .map(|(i, _)| i)
            .unwrap_or(0); // fall to the cheapest knob under extreme pressure
        self.current = pick;
        &model.settings[pick]
    }

    pub fn current_setting<'m>(&self, model: &'m PlasticModel) -> &'m KnobSetting {
        &model.settings[self.current.min(model.settings.len() - 1)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> PlasticModel {
        PlasticModel::standard_ladder("det", 80.0, 76.0)
    }

    #[test]
    fn ladder_is_monotone_in_cost_and_pareto_sane() {
        let m = model();
        assert_eq!(m.settings.len(), 6);
        for w in m.settings.windows(2) {
            assert!(w[0].latency_ms <= w[1].latency_ms);
        }
        // Full model is the most accurate; the cheapest knob is the least.
        let best = m.settings.iter().map(|s| s.accuracy).fold(f64::MIN, f64::max);
        assert_eq!(m.settings.last().unwrap().accuracy, best);
    }

    #[test]
    fn best_within_budget() {
        let m = model();
        let s = m.best_within(100.0).unwrap();
        assert_eq!(s.name, "full");
        let s = m.best_within(45.0).unwrap();
        assert!(s.latency_ms <= 45.0);
        assert!(m.best_within(1.0).is_none());
    }

    #[test]
    fn controller_downshifts_under_contention_and_recovers() {
        let m = model();
        let mut c = AdaptationController::new(100.0);
        // Uncontended: settles on the full model.
        for _ in 0..10 {
            let s = c.current_setting(&m).latency_ms;
            c.observe(&m, s); // observed == nominal
        }
        assert_eq!(c.current_setting(&m).name, "full");
        // GPU contention triples observed times: controller must shift to a
        // setting that still meets the 100 ms deadline at 3x slowdown.
        for _ in 0..20 {
            let s = c.current_setting(&m).latency_ms;
            c.observe(&m, s * 3.0);
        }
        let s = c.current_setting(&m);
        assert!(
            s.latency_ms * 3.0 <= 100.0,
            "setting '{}' misses under contention",
            s.name
        );
        assert_ne!(s.name, "full");
        // Pressure releases: upshifts back to full.
        for _ in 0..30 {
            let s = c.current_setting(&m).latency_ms;
            c.observe(&m, s);
        }
        assert_eq!(c.current_setting(&m).name, "full");
    }

    #[test]
    fn extreme_pressure_falls_to_cheapest_knob() {
        let m = model();
        let mut c = AdaptationController::new(100.0);
        for _ in 0..30 {
            let s = c.current_setting(&m).latency_ms;
            c.observe(&m, s * 50.0);
        }
        assert_eq!(
            c.current_setting(&m).latency_ms,
            m.settings[0].latency_ms,
            "should degrade to the cheapest setting"
        );
    }

    #[test]
    fn gemm_ladder_settings_are_valid_and_distinct() {
        let ladder = gemm_ladder();
        assert!(ladder.len() >= 4);
        for k in &ladder {
            assert!(k.cfg.mc >= 1 && k.cfg.kc >= 1 && k.cfg.nc >= 1, "{}", k.name);
            assert!(k.cfg.nr == 4 || k.cfg.nr == 8, "{}", k.name);
        }
        // Panel working sets grow monotonically along the cache ladder.
        let foot = |k: &GemmKnob| k.cfg.kc * (k.cfg.mc + k.cfg.nc);
        assert!(foot(&ladder[0]) < foot(&ladder[1]));
        assert!(foot(&ladder[1]) < foot(&ladder[2]));
        assert!(foot(&ladder[2]) < foot(&ladder[3]));
        assert_eq!(gemm_knob("l2-resident").unwrap().cfg.mc, 64);
        assert!(gemm_knob("nope").is_none());
    }

    #[test]
    fn steady_knob_ladder_covers_the_toggle_matrix() {
        let ks = steady_knobs();
        assert!(ks.len() >= 4);
        // Endpoints: the PR-1 baseline and the full steady-state engine.
        assert_eq!(steady_knob("legacy").unwrap(), SteadyKnob {
            name: "legacy",
            prepack: false,
            workspace: false,
            pool: false
        });
        let steady = steady_knob("steady").unwrap();
        assert!(steady.prepack && steady.workspace && steady.pool);
        // Each toggle is isolated somewhere in the ladder so the bench can
        // attribute the win.
        assert!(ks.iter().any(|k| k.pool && !k.workspace && !k.prepack));
        assert!(ks.iter().any(|k| k.workspace && !k.prepack));
        assert!(ks.iter().any(|k| k.prepack && !k.pool));
        assert!(steady_knob("nope").is_none());
        // Every knob config actually compiles and infers on the demo CNN.
        use crate::api::Compiler;
        use crate::tensor::gemm::GemmConfig;
        use crate::tensor::Tensor;
        for k in &ks {
            let m = Compiler::for_model("demo-cnn", 1)
                .unwrap()
                .random_weights(77)
                .prepack(k.prepack)
                .workspace(k.workspace)
                .gemm_config(GemmConfig {
                    threads: if k.pool { 0 } else { 1 },
                    ..Default::default()
                })
                .compile()
                .unwrap();
            let y = m.infer(&[Tensor::zeros(&[1, 3, 24, 24])]).unwrap();
            assert_eq!(y[0].shape(), &[1, 8], "knob '{}'", k.name);
        }
    }

    #[test]
    fn ladder_configs_compute_correct_results() {
        use crate::tensor::Tensor;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(0x6E0B);
        let a = Tensor::randn(&[37, 41], 1.0, &mut rng);
        let b = Tensor::randn(&[41, 29], 1.0, &mut rng);
        let want = a.matmul_naive(&b);
        for k in gemm_ladder() {
            let got = a.matmul_with(&b, &k.cfg);
            assert!(
                want.max_abs_diff(&got) <= 1e-3,
                "knob '{}' diverges by {}",
                k.name,
                want.max_abs_diff(&got)
            );
        }
    }

    #[test]
    fn pruned_variants_dominate_unpruned_at_same_exit() {
        let m = model();
        let full = m.settings.iter().find(|s| s.name == "full").unwrap();
        let full_pruned = m.settings.iter().find(|s| s.name == "full+pruned").unwrap();
        assert!(full_pruned.latency_ms < full.latency_ms);
        assert!(full.accuracy - full_pruned.accuracy < 2.0, "pruning cost too high");
    }
}
