//! Baseline framework models: TFLite, TVM, MNN, PyTorch Mobile, SNPE, TFLM
//! and NeuroMagic, each encoded as (a) a fusion strategy (fixed pattern
//! list vs none), (b) an execution-efficiency profile per device class,
//! and (c) an **operator coverage** table — the source of the "-" cells in
//! Tables 3–4 (e.g. no 3-D conv on mobile GPU, no transformer MatMul/Pow
//! variants on DSP). XGen itself appears in two strengths: compiler-only
//! (no compression; the §3.2.1 "at least 2.5×" comparison) and full
//! (compression-compilation co-design).
//!
//! Efficiency constants are calibrated once against the paper's *baseline*
//! rows and then frozen; see `cost` module docs for the methodology.

use crate::cost::ExecProfile;
use crate::fusion::{FusedGroup, FusionPlan};
use crate::graph::{Graph, OpKind};
use crate::pruning::PruneScheme;

/// Device classes a framework can target.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceClass {
    MobileCpu,
    MobileGpu,
    MobileDsp,
    Mcu,
    DesktopCpu,
}

/// A DNN execution framework (baseline or XGen).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Framework {
    TfLite,
    Tvm,
    Mnn,
    PyTorchMobile,
    Snpe,
    Tflm,
    NeuroMagic,
    /// XGen with compiler optimizations only (no compression/NAS).
    XGenCompilerOnly,
    /// Full XGen: compression-compilation co-design.
    XGenFull,
}

impl Framework {
    pub fn name(&self) -> &'static str {
        match self {
            Framework::TfLite => "TFLite",
            Framework::Tvm => "TVM",
            Framework::Mnn => "MNN",
            Framework::PyTorchMobile => "PyTorch",
            Framework::Snpe => "SNPE",
            Framework::Tflm => "TFLM",
            Framework::NeuroMagic => "NeuroMagic",
            Framework::XGenCompilerOnly => "XGen-compiler",
            Framework::XGenFull => "XGen",
        }
    }

    /// The pruning scheme the framework deploys in the "same accuracy"
    /// comparisons. Baselines run dense; NeuroMagic runs non-structured;
    /// full XGen runs pattern+connectivity.
    pub fn deploy_scheme(&self) -> PruneScheme {
        match self {
            Framework::XGenFull => {
                PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.4 }
            }
            Framework::NeuroMagic => PruneScheme::NonStructured { rate: 0.85 },
            _ => PruneScheme::None,
        }
    }

    /// Execution profile on a device class (None = unsupported pairing,
    /// e.g. PyTorch Mobile has no mobile-GPU backend in the paper's table).
    pub fn profile(&self, class: DeviceClass) -> Option<ExecProfile> {
        use DeviceClass::*;
        use Framework::*;
        let p = |name, eff, ovh, sparse| ExecProfile {
            name,
            eff,
            per_group_overhead_ms: ovh,
            sparse_capable: sparse,
        };
        Some(match (self, class) {
            (TfLite, MobileCpu) => p("tflite-cpu", 0.48, 0.012, false),
            (TfLite, MobileGpu) => p("tflite-gpu", 0.20, 0.050, false),
            (TfLite, MobileDsp) => p("tflite-dsp", 0.30, 0.014, false),
            (Tvm, MobileCpu) => p("tvm-cpu", 0.45, 0.008, false),
            (Tvm, MobileGpu) => p("tvm-gpu", 0.17, 0.060, false),
            (Mnn, MobileCpu) => p("mnn-cpu", 0.52, 0.012, false),
            (Mnn, MobileGpu) => p("mnn-gpu", 0.22, 0.045, false),
            (PyTorchMobile, MobileCpu) => p("pytorch-cpu", 0.36, 0.060, false),
            (PyTorchMobile, MobileGpu) => return None, // "-" column in Table 3
            (Snpe, MobileDsp) => p("snpe-dsp", 0.36, 0.012, false),
            (Tflm, Mcu) => p("tflm-mcu", 0.78, 0.030, false),
            (NeuroMagic, DesktopCpu) => p("neuromagic-cpu", 0.45, 0.010, true),
            (XGenCompilerOnly | XGenFull, MobileCpu) => p("xgen-cpu", 0.68, 0.004, true),
            (XGenCompilerOnly | XGenFull, MobileGpu) => p("xgen-gpu", 0.33, 0.018, true),
            (XGenCompilerOnly | XGenFull, MobileDsp) => p("xgen-dsp", 0.55, 0.006, true),
            (XGenCompilerOnly | XGenFull, Mcu) => p("xgen-mcu", 0.94, 0.010, true),
            _ => return None,
        })
    }

    /// Operator coverage: can this framework run `g` on `class` at all?
    /// Encodes the support gaps behind Table 3/4's "-" entries.
    pub fn supports(&self, g: &Graph, class: DeviceClass) -> bool {
        use Framework::*;
        if self.profile(class).is_none() {
            return false;
        }
        let has = |pred: &dyn Fn(&OpKind) -> bool| g.nodes.iter().any(|n| pred(&n.op));
        let has_conv3d = has(&|o| matches!(o, OpKind::Conv3d { .. }));
        let has_transformer = has(&|o| {
            matches!(o, OpKind::Softmax | OpKind::LayerNorm | OpKind::Embedding)
        }) && has(&|o| matches!(o, OpKind::MatMul));
        let has_custom_heads = has(&|o| matches!(o, OpKind::Gather | OpKind::PostProcess));
        let has_pow = has(&|o| matches!(o, OpKind::Pow { .. }));
        match self {
            XGenCompilerOnly | XGenFull => true, // "supports more operators"
            TfLite => {
                // CPU: transformers run (slowly); no 3-D conv; no RoI/NMS
                // custom heads. GPU/DSP additionally drop transformers.
                if has_conv3d || has_custom_heads {
                    return false;
                }
                if matches!(class, DeviceClass::MobileGpu | DeviceClass::MobileDsp)
                    && (has_transformer || has_pow)
                {
                    return false;
                }
                true
            }
            Tvm => !has_conv3d || matches!(class, DeviceClass::MobileCpu) && !has_custom_heads,
            Mnn => !has_transformer && !has_custom_heads && (!has_conv3d || class == DeviceClass::MobileCpu),
            PyTorchMobile => !has_custom_heads || has_conv3d, // torchscript runs 3-D conv; no detectron heads
            Snpe => !has_conv3d && !has_transformer && !has_pow && !has_custom_heads,
            Tflm => !has_conv3d && !has_transformer && !has_custom_heads,
            NeuroMagic => !has_conv3d && !has_transformer,
        }
    }

    /// Does the framework fuse with the universal (mapping-type) algorithm
    /// or a fixed pattern list?
    pub fn fusion_plan(&self, g: &Graph) -> FusionPlan {
        match self {
            Framework::XGenCompilerOnly | Framework::XGenFull => {
                crate::fusion::fuse(g, &crate::fusion::FusionConfig::default())
            }
            Framework::PyTorchMobile => no_fusion(g),
            _ => fixed_pattern_fusion(g),
        }
    }
}

/// The classic fixed-pattern fuser (TFLite/MNN/TVM-style): only
/// `conv/dense + bn? + activation?` triples fuse; everything else runs as
/// its own kernel. This is the baseline for the paper's "up to 8.8× higher
/// fusion opportunities" claim.
pub fn fixed_pattern_fusion(g: &Graph) -> FusionPlan {
    let users = g.users();
    let mut taken = vec![false; g.nodes.len()];
    let mut groups = Vec::new();
    for id in g.compute_nodes() {
        if taken[id] {
            continue;
        }
        let mut nodes = vec![id];
        taken[id] = true;
        let anchor = matches!(
            g.node(id).op,
            OpKind::Conv2d { .. } | OpKind::Conv3d { .. } | OpKind::Dense
        );
        if anchor {
            // conv (+bn) (+act) chain, single-consumer links only.
            let mut tail = id;
            for _ in 0..2 {
                if users[tail].len() != 1 {
                    break;
                }
                let next = users[tail][0];
                if taken[next] {
                    break;
                }
                let ok = match (&g.node(tail).op, &g.node(next).op) {
                    (_, OpKind::BatchNorm) => true,
                    (_, OpKind::Bias) => true,
                    (_, OpKind::Activation(_)) => true,
                    _ => false,
                };
                if !ok {
                    break;
                }
                taken[next] = true;
                nodes.push(next);
                tail = next;
            }
        }
        let mapping = g.node(id).op.mapping();
        groups.push(FusedGroup { nodes, mapping });
    }
    let candidates = groups.iter().map(|gr| gr.len() - 1).sum();
    FusionPlan { groups, candidates, accepted: candidates, profile_rejected: 0 }
}

/// No fusion at all (PyTorch Mobile eager-ish execution).
pub fn no_fusion(g: &Graph) -> FusionPlan {
    let groups = g
        .compute_nodes()
        .into_iter()
        .map(|id| FusedGroup { nodes: vec![id], mapping: g.node(id).op.mapping() })
        .collect::<Vec<_>>();
    FusionPlan { groups, candidates: 0, accepted: 0, profile_rejected: 0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fusion::fusion_opportunities;
    use crate::graph::zoo::by_name;

    #[test]
    fn pytorch_has_no_mobile_gpu() {
        assert!(Framework::PyTorchMobile.profile(DeviceClass::MobileGpu).is_none());
        assert!(Framework::PyTorchMobile.profile(DeviceClass::MobileCpu).is_some());
    }

    #[test]
    fn table3_dash_cells_reproduced() {
        // C3D: MNN CPU runs it, TFLite doesn't, PyTorch does (Table 3 row).
        let c3d = by_name("c3d", 1);
        assert!(Framework::Mnn.supports(&c3d, DeviceClass::MobileCpu));
        assert!(!Framework::TfLite.supports(&c3d, DeviceClass::MobileCpu));
        assert!(Framework::PyTorchMobile.supports(&c3d, DeviceClass::MobileCpu));
        // BERT: TFLite CPU yes, MNN no, XGen yes (Table 3 bottom block).
        let bert = by_name("bert-base", 1);
        assert!(Framework::TfLite.supports(&bert, DeviceClass::MobileCpu));
        assert!(!Framework::Mnn.supports(&bert, DeviceClass::MobileCpu));
        assert!(Framework::XGenFull.supports(&bert, DeviceClass::MobileCpu));
    }

    #[test]
    fn table4_transformer_gap_on_dsp() {
        // "TFLite and SNPE do not support Transformer-based models" (+ XGen
        // supports TinyBERT and Conformer on DSP for the first time).
        for m in ["tinybert", "conformer"] {
            let g = by_name(m, 1);
            assert!(!Framework::TfLite.supports(&g, DeviceClass::MobileDsp), "{m} tflite");
            assert!(!Framework::Snpe.supports(&g, DeviceClass::MobileDsp), "{m} snpe");
            assert!(Framework::XGenFull.supports(&g, DeviceClass::MobileDsp), "{m} xgen");
        }
    }

    #[test]
    fn universal_fusion_beats_fixed_patterns() {
        for m in ["mobilenet-v2", "gpt-2", "efficientnet-b0"] {
            let g = by_name(m, 1);
            let fixed = fixed_pattern_fusion(&g);
            let univ = Framework::XGenFull.fusion_plan(&g);
            assert!(
                univ.fused_layer_count() < fixed.fused_layer_count(),
                "{m}: universal {} !< fixed {}",
                univ.fused_layer_count(),
                fixed.fused_layer_count()
            );
        }
    }

    #[test]
    fn fusion_opportunity_ratio_large_on_transformers() {
        // §2.2.2: "up to 8.8x higher fusion opportunities". Fixed-pattern
        // opportunity count = accepted pairs; universal = legal pairs.
        let g = by_name("gpt-2", 1);
        let fixed = fixed_pattern_fusion(&g);
        let legal = fusion_opportunities(&g);
        let ratio = legal as f64 / (fixed.accepted.max(1)) as f64;
        assert!(ratio > 3.0, "opportunity ratio only {ratio:.1}");
    }

    #[test]
    fn fixed_pattern_groups_cover_all_nodes_once() {
        let g = by_name("resnet-50", 1);
        let plan = fixed_pattern_fusion(&g);
        let total: usize = plan.groups.iter().map(|gr| gr.len()).sum();
        assert_eq!(total, g.compute_nodes().len());
    }

    #[test]
    fn xgen_deploys_pattern_scheme() {
        assert!(matches!(
            Framework::XGenFull.deploy_scheme(),
            PruneScheme::Pattern { .. }
        ));
        assert!(matches!(Framework::Tvm.deploy_scheme(), PruneScheme::None));
    }
}
