//! # xgen — a reproduction of CoCoPIE XGen
//!
//! XGen (Li, Ren, Shen, Wang, 2022) is a *full-stack* DNN-inference
//! optimizing framework built around **compression–compilation co-design**:
//! model-level pruning (pattern-based / block-based), graph-level rewriting
//! and universal operator fusion (DNNFusion), pattern-conscious code
//! generation (FKW storage, filter-kernel reorder, load-redundancy
//! elimination), deep reuse, a compiler-aware architecture/pruning co-search
//! (CAPS/NPAS), and an AI-conscious heterogeneous runtime (XEngine).
//!
//! This crate is Layer 3 of a three-layer Rust + JAX + Pallas stack:
//! Python/JAX/Pallas author and AOT-lower the demonstration models at build
//! time (`make artifacts`), and everything at inference time — the compiler,
//! the executors, the scheduler simulator, and the PJRT serving loop — is
//! Rust. See `DESIGN.md` for the full system inventory and the
//! per-experiment index mapping every paper table/figure to a bench target.
//!
//! ## Start here: the session API
//!
//! [`api::Compiler`] is the single entry point from model to executable —
//! a builder over the whole Fig 2 pipeline (rewrite → prune → fuse →
//! plan) whose [`api::Compiler::compile`] returns an
//! [`api::CompiledModel`] answering real inference
//! ([`infer`](api::CompiledModel::infer)), cost-model estimation
//! ([`estimate`](api::CompiledModel::estimate)) and per-stage statistics
//! ([`report`](api::CompiledModel::report)). Every example, bench, CLI
//! command and the serving [`coordinator::Server`] goes through it; the
//! modules below are the pipeline's stages.
//!
//! ## Module map
//!
//! | layer | modules |
//! |---|---|
//! | **session API** | [`api`] |
//! | substrates | [`error`], [`util`], [`tensor`] |
//! | graph IR + model zoo | [`graph`] |
//! | high-level opt | [`rewrite`], [`fusion`] |
//! | model opt | [`pruning`], [`fkw`] |
//! | low-level opt | [`codegen`], [`deepreuse`], [`exec`] |
//! | static analysis | [`verify`], [`analyze`] |
//! | device models | [`cost`], [`baselines`] |
//! | co-search | [`caps`] |
//! | runtime | [`xengine`], [`runtime`], [`coordinator`] |

// Lint policy (CI gates `cargo clippy -- -D warnings`): style lints that
// fight the explicit index-based idiom of numeric-kernel code are allowed
// crate-wide; correctness lints stay on.
#![allow(unknown_lints)]
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::new_without_default,
    clippy::type_complexity,
    clippy::len_without_is_empty,
    clippy::collapsible_else_if,
    clippy::collapsible_if,
    clippy::uninlined_format_args,
    clippy::excessive_precision,
    clippy::approx_constant,
    clippy::comparison_chain,
    clippy::manual_flatten,
    clippy::manual_memcpy,
    clippy::derivable_impls,
    clippy::missing_safety_doc,
    clippy::should_implement_trait,
    clippy::large_enum_variant,
    clippy::result_large_err
)]

pub mod api;
pub mod error;
pub mod util;
pub mod tensor;
pub mod graph;
pub mod rewrite;
pub mod fusion;
pub mod pruning;
pub mod fkw;
pub mod codegen;
pub mod deepreuse;
pub mod exec;
pub mod verify;
pub mod analyze;
pub mod cost;
pub mod baselines;
pub mod caps;
pub mod xengine;
pub mod runtime;
pub mod coordinator;

/// Crate version string used by the CLI.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
