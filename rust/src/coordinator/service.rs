//! The XGen product service paths (§4, Fig 20).
//!
//! * **Scenario I** — the customer's requirement is met by a model XGen
//!   already produced: serve it straight from the repository (green path).
//! * **Scenario II** — no stored model fits: pick a promising base model,
//!   run the optimizing pipeline (CAPS-style search over schemes), store
//!   and return the result (red path).
//! * **Scenario III** — customer brings their own model/dataset: same as
//!   II but seeded with the customer graph (red + broken path).
//! * **Scenario IV** — new hardware backend: register a [`cost::Device`]
//!   and profile; the IR and pipeline are device-agnostic.

use std::collections::BTreeMap;

use crate::api::Compiler;
use crate::baselines::{DeviceClass, Framework};
use crate::cost::Device;
use crate::graph::zoo::by_name;
use crate::graph::Graph;
use crate::pruning::{AccuracyModel, PruneScheme};

/// A customer requirement (Fig 20 left).
#[derive(Debug, Clone)]
pub struct Requirement {
    /// Task family, e.g. "classification" (selects base models).
    pub task: String,
    pub max_latency_ms: f64,
    pub min_accuracy: f64,
}

/// A stored, optimized AI capability.
#[derive(Debug, Clone)]
pub struct StoredModel {
    pub base: String,
    pub scheme: PruneScheme,
    pub latency_ms: f64,
    pub accuracy: f64,
}

/// Which Fig 20 path served a request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServicePath {
    /// Scenario I: repository hit.
    Repository,
    /// Scenario II/III: freshly optimized.
    Optimized,
}

/// The XGen service: a repository plus the optimizing pipeline.
pub struct XGenService {
    device: Device,
    repo: BTreeMap<String, Vec<StoredModel>>,
    /// Base models per task family (Scenario II picks from these).
    bases: BTreeMap<String, Vec<&'static str>>,
    base_acc: BTreeMap<&'static str, f64>,
}

impl XGenService {
    pub fn new(device: Device) -> XGenService {
        let mut bases = BTreeMap::new();
        bases.insert(
            "classification".to_string(),
            vec!["mobilenet-v3", "efficientnet-b0", "resnet-50"],
        );
        bases.insert("segmentation".to_string(), vec!["u-net"]);
        bases.insert("super-resolution".to_string(), vec!["wdsr-b"]);
        let mut base_acc = BTreeMap::new();
        base_acc.insert("mobilenet-v3", 75.2);
        base_acc.insert("efficientnet-b0", 77.1);
        base_acc.insert("resnet-50", 76.5);
        base_acc.insert("u-net", 76.0);
        base_acc.insert("wdsr-b", 74.0);
        XGenService { device, repo: BTreeMap::new(), bases, base_acc }
    }

    pub fn repo_size(&self) -> usize {
        self.repo.values().map(|v| v.len()).sum()
    }

    /// Serve a requirement (Scenario I if possible, else II).
    pub fn request(&mut self, req: &Requirement) -> Option<(StoredModel, ServicePath)> {
        if let Some(hit) = self.lookup(req) {
            return Some((hit, ServicePath::Repository));
        }
        let built = self.optimize_for(req)?;
        self.repo.entry(req.task.clone()).or_default().push(built.clone());
        Some((built, ServicePath::Optimized))
    }

    /// Scenario III: customer-supplied graph + base accuracy.
    pub fn request_custom(
        &mut self,
        req: &Requirement,
        graph_builder: impl Fn() -> Graph,
        base_acc: f64,
    ) -> Option<StoredModel> {
        let m = self.optimize_graph(req, "custom", &graph_builder, base_acc)?;
        self.repo.entry(req.task.clone()).or_default().push(m.clone());
        Some(m)
    }

    fn lookup(&self, req: &Requirement) -> Option<StoredModel> {
        self.repo.get(&req.task).and_then(|models| {
            models
                .iter()
                .filter(|m| m.latency_ms <= req.max_latency_ms && m.accuracy >= req.min_accuracy)
                .max_by(|a, b| a.accuracy.partial_cmp(&b.accuracy).unwrap())
                .cloned()
        })
    }

    fn optimize_for(&self, req: &Requirement) -> Option<StoredModel> {
        let bases = self.bases.get(&req.task)?.clone();
        let mut best: Option<StoredModel> = None;
        for base in bases {
            let acc = *self.base_acc.get(base).unwrap_or(&75.0);
            if let Some(m) = self.optimize_graph(req, base, &|| by_name(base, 1), acc) {
                let better = best
                    .as_ref()
                    .map(|b| m.accuracy > b.accuracy)
                    .unwrap_or(true);
                if better {
                    best = Some(m);
                }
            }
        }
        best
    }

    fn optimize_graph(
        &self,
        req: &Requirement,
        base: &str,
        graph_builder: &impl Fn() -> Graph,
        base_acc: f64,
    ) -> Option<StoredModel> {
        let am = AccuracyModel::default();
        let schemes = [
            PruneScheme::None,
            PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.2 },
            PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.4 },
            PruneScheme::Block { block: 8, rate: 0.75 },
            PruneScheme::Block { block: 32, rate: 0.85 },
        ];
        let mut best: Option<StoredModel> = None;
        for scheme in schemes {
            let c = Compiler::new(graph_builder()).scheme(scheme.clone()).compile().ok()?;
            let lat = c.estimate(&self.device, Framework::XGenFull, DeviceClass::MobileCpu)?;
            let acc = am.estimate(base_acc, &scheme);
            if lat <= req.max_latency_ms && acc >= req.min_accuracy {
                let better = best.as_ref().map(|b| acc > b.accuracy).unwrap_or(true);
                if better {
                    best = Some(StoredModel { base: base.to_string(), scheme, latency_ms: lat, accuracy: acc });
                }
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::devices;

    fn svc() -> XGenService {
        XGenService::new(devices::s10_cpu())
    }

    #[test]
    fn scenario_ii_then_i() {
        let mut s = svc();
        let req = Requirement {
            task: "classification".into(),
            max_latency_ms: 30.0,
            min_accuracy: 70.0,
        };
        // First request: no repo → optimized (Scenario II).
        let (m1, path1) = s.request(&req).expect("satisfiable");
        assert_eq!(path1, ServicePath::Optimized);
        assert!(m1.latency_ms <= 30.0 && m1.accuracy >= 70.0);
        assert_eq!(s.repo_size(), 1);
        // Same request again: repository hit (Scenario I).
        let (m2, path2) = s.request(&req).unwrap();
        assert_eq!(path2, ServicePath::Repository);
        assert_eq!(m2.base, m1.base);
        assert_eq!(s.repo_size(), 1);
    }

    #[test]
    fn infeasible_requirement_returns_none() {
        let mut s = svc();
        let req = Requirement {
            task: "classification".into(),
            max_latency_ms: 0.01,
            min_accuracy: 99.0,
        };
        assert!(s.request(&req).is_none());
        assert_eq!(s.repo_size(), 0);
    }

    #[test]
    fn tighter_latency_prefers_lighter_base_or_stronger_pruning() {
        let mut s = svc();
        let loose = Requirement {
            task: "classification".into(),
            max_latency_ms: 200.0,
            min_accuracy: 60.0,
        };
        let tight = Requirement {
            task: "classification".into(),
            max_latency_ms: 6.0,
            min_accuracy: 60.0,
        };
        let (ml, _) = s.request(&loose).unwrap();
        let (mt, _) = s.request(&tight).unwrap();
        assert!(mt.latency_ms <= 6.0);
        assert!(ml.accuracy >= mt.accuracy, "loose budget should buy accuracy");
    }

    #[test]
    fn scenario_iii_custom_model() {
        let mut s = svc();
        let req = Requirement {
            task: "custom-det".into(),
            max_latency_ms: 80.0,
            min_accuracy: 60.0,
        };
        let m = s
            .request_custom(&req, || by_name("u-net", 1), 72.0)
            .expect("custom model optimizable");
        assert!(m.latency_ms <= 80.0);
        assert_eq!(m.base, "custom");
        // Now served from the repository.
        let (_, path) = s.request(&req).unwrap();
        assert_eq!(path, ServicePath::Repository);
    }

    #[test]
    fn unknown_task_unserved() {
        let mut s = svc();
        let req = Requirement { task: "speech".into(), max_latency_ms: 100.0, min_accuracy: 0.0 };
        assert!(s.request(&req).is_none());
    }
}
