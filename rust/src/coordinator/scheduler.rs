//! Multi-stream decode serving: the [`StreamScheduler`] (ISSUE-8
//! tentpole) multiplexes many concurrent greedy generations over a pool
//! of [`DecodeSession`]s for one [`CompiledModel`].
//!
//! ## Model
//!
//! Every submitted generation is a **stream** walking the state machine
//!
//! ```text
//! Queued → Prefilling → Decoding → {Finished, Evicted, Failed, Cancelled}
//! ```
//!
//! * **Queued** — admitted past the [`SchedConfig::queue_cap`] bound
//!   (beyond it submissions are shed with [`XgenError::Overloaded`]
//!   carrying the observed depth and a retry-after hint), waiting for a
//!   resident session.
//! * **Prefilling** — bound to a session slot; the next scheduled unit
//!   runs the prompt (or re-prefills a checkpoint) and emits the first
//!   token.
//! * **Decoding** — one `step()` per scheduling round, strict round-robin
//!   over all resident streams, so no stream starves behind a long one.
//! * **Finished / Evicted / Failed / Cancelled** — terminal; the slot
//!   returns to the pool. *Evicted* means the per-stream deadline expired
//!   mid-generation: the tokens already streamed stand and the stream
//!   ends with a typed [`XgenError::DeadlineExceeded`].
//!
//! ## Fault isolation
//!
//! Each unit of work runs under `catch_unwind`: a panicking stream is
//! answered with [`XgenError::WorkerPanic`] and **its** session is
//! rebuilt from the model, a NaN-producing stream is answered with
//! [`XgenError::NonFinite`], a typed step error flows through
//! [`XgenError::classify`] — and in every case all other in-flight
//! streams continue untouched, producing bitwise-identical output to a
//! fault-free run (pinned by the chaos matrix in `tests/streams.rs`).
//!
//! ## KV-memory pressure
//!
//! The resident-session pool is bounded by
//! [`SchedConfig::kv_budget_bytes`], counted in units of
//! [`CompiledModel::kv_cache_bytes`] (the planner's
//! `WorkspaceSpec::kv_cache_elems` sizing). When a higher-priority
//! submission would exceed the budget, the scheduler **checkpoints** the
//! lowest-priority resident stream — among equals, the one with the
//! least progress, which is the cheapest to re-prefill (no resident
//! stream is ever idle: all of them step every round). A checkpoint
//! keeps the prompt + generated tokens ([`DecodeSession::snapshot`])
//! and drops the K/V memory; on re-admission the session is restored by
//! re-prefilling ([`DecodeSession::restore`]), which is bitwise-identical
//! to never having been evicted because prefill *is* N × `step()`.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::api::CompiledModel;
use crate::error::{panic_detail, XgenError};
use crate::exec::{DecodeSession, SessionSnapshot};

use super::{lock, retry_after_ms, retry_loop, RetryPolicy};

/// Stream-scheduler configuration.
#[derive(Debug, Clone)]
pub struct SchedConfig {
    /// Hard cap on resident sessions (concurrently decoding streams).
    /// [`SchedConfig::kv_budget_bytes`] can only tighten it.
    pub max_streams: usize,
    /// Bound on *live* streams (queued + resident); past it, submissions
    /// are shed with [`XgenError::Overloaded`].
    pub queue_cap: usize,
    /// K/V-memory budget in bytes. The pool holds at most
    /// `budget / CompiledModel::kv_cache_bytes(max_seq)` sessions; a
    /// budget smaller than one session fails `start_cfg` eagerly.
    /// `None` leaves [`SchedConfig::max_streams`] in charge.
    pub kv_budget_bytes: Option<u64>,
    /// Deadline applied to [`StreamScheduler::submit`] streams (none by
    /// default). Checked by the watchdog before every unit of work: an
    /// expired stream keeps its streamed tokens and ends with
    /// [`XgenError::DeadlineExceeded`].
    pub default_deadline: Option<Duration>,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig {
            max_streams: 8,
            queue_cap: 1024,
            kv_budget_bytes: None,
            default_deadline: None,
        }
    }
}

/// Per-submission options.
#[derive(Debug, Clone, Default)]
pub struct SubmitOpts {
    /// Higher wins a resident slot; a strictly higher-priority waiter
    /// preempts (checkpoints) the lowest-priority resident stream. Equal
    /// priorities never preempt each other — FIFO, run-to-completion.
    pub priority: u8,
    /// Per-stream deadline (overrides [`SchedConfig::default_deadline`]).
    pub deadline: Option<Duration>,
}

/// Observable counters of a [`StreamScheduler`]. Terminal states are
/// disjoint: every submitted stream ends in exactly one of `finished`,
/// `failed`, `cancelled`, or `deadline_evicted` (shed submissions were
/// never live).
#[derive(Debug, Clone, Default)]
pub struct SchedStats {
    pub submitted: usize,
    pub finished: usize,
    /// Typed step failures, non-finite logits, and caught panics.
    pub failed: usize,
    /// Streams whose client dropped the receiver mid-generation.
    pub cancelled: usize,
    /// Submissions refused at the admission bound.
    pub shed: usize,
    /// Streams evicted by the deadline watchdog (queued or resident);
    /// their partial output stands.
    pub deadline_evicted: usize,
    /// KV-pressure checkpoints (stream survived, K/V dropped).
    pub checkpoints: usize,
    /// Checkpointed streams re-admitted and restored by re-prefill.
    pub resumes: usize,
    /// Tokens delivered across all streams.
    pub tokens: usize,
    /// Per-stream panics caught by the unit `catch_unwind`.
    pub worker_panics: usize,
    /// Sessions rebuilt from the model after a caught panic.
    pub session_rebuilds: usize,
    /// Resident-session pool size (after applying the KV budget).
    pub pool_sessions: usize,
    /// K/V bytes one session holds at `max_seq` — the budget unit.
    pub session_kv_bytes: u64,
    /// High-water mark of concurrently resident streams.
    pub max_active: usize,
    /// Session slots unaccounted for at drain exit; 0 unless the
    /// scheduler aborted. Pinned by the drain-on-drop test.
    pub leaked_sessions: usize,
    /// Total client-visible stream time (submit → terminal) — feeds the
    /// retry-after hint on sheds.
    pub service_ms: f64,
}

impl SchedStats {
    /// One-line operator-facing summary including the fault counters.
    pub fn report(&self) -> String {
        format!(
            "{} streams ({} finished, {} failed, {} cancelled, {} evicted), {} tokens; \
             shed {}, checkpoints {}, resumes {}, panics {}, rebuilds {}; \
             pool {} × {} KV bytes, max active {}",
            self.submitted,
            self.finished,
            self.failed,
            self.cancelled,
            self.deadline_evicted,
            self.tokens,
            self.shed,
            self.checkpoints,
            self.resumes,
            self.worker_panics,
            self.session_rebuilds,
            self.pool_sessions,
            self.session_kv_bytes,
            self.max_active
        )
    }
}

/// One submitted generation, as it crosses the channel.
struct StreamRequest {
    prompt: Vec<u32>,
    n: usize,
    priority: u8,
    reply: mpsc::Sender<Result<u32, XgenError>>,
    enqueued: Instant,
    deadline: Option<Instant>,
}

/// Scheduler-side stream state. `slot` indexes the session pool while
/// resident; `snapshot` is set while checkpointed under KV pressure.
struct Stream {
    /// Admission ordinal in arrival order — the `stream` coordinate the
    /// fault hooks target.
    id: u64,
    prompt: Vec<u32>,
    n: usize,
    priority: u8,
    reply: mpsc::Sender<Result<u32, XgenError>>,
    enqueued: Instant,
    deadline: Option<Instant>,
    /// Tokens delivered so far; also the step ordinal of the next unit
    /// (0 = prefill), independent of eviction history.
    emitted: usize,
    /// Last delivered token, not yet fed back (valid when `emitted > 0`).
    pending: u32,
    snapshot: Option<SessionSnapshot>,
    slot: usize,
}

/// Client handle to one stream: tokens arrive one by one; an `Err` item
/// ends the stream (a deadline eviction still delivers the tokens decoded
/// before it).
pub struct StreamHandle {
    rx: mpsc::Receiver<Result<u32, XgenError>>,
}

impl StreamHandle {
    /// Next stream item; `None` when the stream is complete.
    pub fn recv(&self) -> Option<Result<u32, XgenError>> {
        self.rx.recv().ok()
    }

    /// Drain the stream: the tokens delivered plus the terminating error,
    /// if any.
    pub fn collect(self) -> (Vec<u32>, Option<XgenError>) {
        let mut out = Vec::new();
        for item in &self.rx {
            match item {
                Ok(t) => out.push(t),
                Err(e) => return (out, Some(e)),
            }
        }
        (out, None)
    }

    /// The raw receiver, for `select`-style consumers.
    pub fn into_receiver(self) -> mpsc::Receiver<Result<u32, XgenError>> {
        self.rx
    }
}

/// How a unit of work left its stream.
enum UnitEnd {
    /// Stream stays resident.
    Continue,
    /// Stream went terminal (already removed, slot already freed).
    Done,
    /// Session rebuild failed — the scheduler cannot continue.
    Fatal,
}

/// Terminal state counters.
enum Terminal {
    Finished,
    Failed,
    Cancelled,
    DeadlineEvicted,
}

/// The scheduler thread's working set. Sessions borrow the model, so the
/// whole engine lives inside the thread that owns the [`CompiledModel`].
struct Engine<'m> {
    model: &'m CompiledModel,
    max_seq: usize,
    pool_cap: usize,
    sessions: Vec<DecodeSession<'m>>,
    /// Free slots (indices into `sessions`). Invariant:
    /// `free.len() + active.len() == sessions.len()`.
    free: Vec<usize>,
    waiting: VecDeque<Stream>,
    active: Vec<Stream>,
    next_id: u64,
    /// Reusable logits buffer (one row — the scheduler is
    /// allocation-free per token after warm-up, like the sessions).
    logits: Vec<f32>,
    depth: Arc<AtomicUsize>,
    stats: Arc<Mutex<SchedStats>>,
}

impl<'m> Engine<'m> {
    fn enroll(&mut self, r: StreamRequest) {
        let s = Stream {
            id: self.next_id,
            prompt: r.prompt,
            n: r.n,
            priority: r.priority,
            reply: r.reply,
            enqueued: r.enqueued,
            deadline: r.deadline,
            emitted: 0,
            pending: 0,
            snapshot: None,
            slot: usize::MAX,
        };
        self.next_id += 1;
        lock(&self.stats).submitted += 1;
        self.waiting.push_back(s);
    }

    /// A stream went terminal: close out its accounting. Dropping the
    /// reply sender is what ends the client's stream.
    fn finish(&mut self, s: Stream, t: Terminal) {
        self.depth.fetch_sub(1, Ordering::SeqCst);
        let mut st = lock(&self.stats);
        st.service_ms += s.enqueued.elapsed().as_secs_f64() * 1e3;
        match t {
            Terminal::Finished => st.finished += 1,
            Terminal::Failed => st.failed += 1,
            Terminal::Cancelled => st.cancelled += 1,
            Terminal::DeadlineEvicted => st.deadline_evicted += 1,
        }
    }

    /// Return a slot to the pool with a clean session.
    fn release_slot(&mut self, slot: usize) {
        self.sessions[slot].reset();
        self.free.push(slot);
    }

    /// Queued streams whose deadline expired never get a slot: deliver
    /// the typed eviction (any checkpointed partial output stands).
    fn shed_expired_waiters(&mut self) {
        let now = Instant::now();
        let mut k = 0;
        while k < self.waiting.len() {
            if self.waiting[k].deadline.is_some_and(|d| now >= d) {
                if let Some(s) = self.waiting.remove(k) {
                    let elapsed_ms = s.enqueued.elapsed().as_millis() as u64;
                    let _ = s.reply.send(Err(XgenError::DeadlineExceeded { elapsed_ms }));
                    self.finish(s, Terminal::DeadlineEvicted);
                }
            } else {
                k += 1;
            }
        }
    }

    /// Index of the best waiter: highest priority, FIFO among equals.
    fn best_waiter(&self) -> Option<usize> {
        let mut best: Option<usize> = None;
        for (i, s) in self.waiting.iter().enumerate() {
            match best {
                Some(b) if s.priority <= self.waiting[b].priority => {}
                _ => best = Some(i),
            }
        }
        best
    }

    /// Bind waiters to free slots (building sessions lazily up to the
    /// pool cap), best-priority first.
    fn admit(&mut self) {
        while self.active.len() < self.pool_cap {
            let Some(w) = self.best_waiter() else { return };
            let slot = if let Some(slot) = self.free.pop() {
                slot
            } else if self.sessions.len() < self.pool_cap {
                match self.model.decode_session(self.max_seq) {
                    Ok(sess) => {
                        self.sessions.push(sess);
                        self.sessions.len() - 1
                    }
                    Err(e) => {
                        // This stream alone fails; the pool is unchanged.
                        if let Some(s) = self.waiting.remove(w) {
                            let _ = s.reply.send(Err(XgenError::classify(&e)));
                            self.finish(s, Terminal::Failed);
                        }
                        continue;
                    }
                }
            } else {
                return; // pool exhausted — preemption may still free a slot
            };
            match self.waiting.remove(w) {
                Some(mut s) => {
                    s.slot = slot;
                    self.active.push(s);
                }
                None => self.free.push(slot), // unreachable: w is in range
            }
        }
    }

    /// KV-pressure preemption: when a waiter outranks the lowest-priority
    /// resident stream, checkpoint that stream (tokens kept, K/V
    /// dropped) and recycle its slot. Strictly-greater priority only, so
    /// equal-priority streams never thrash, and each preemption raises
    /// the resident priority multiset — the admit/preempt loop
    /// terminates. Among equal-priority victims the least-progressed
    /// stream goes (cheapest re-prefill; no resident stream is idle —
    /// they all step every round).
    fn preempt_one(&mut self) -> bool {
        let Some(w) = self.best_waiter() else { return false };
        let Some(v) = self
            .active
            .iter()
            .enumerate()
            .min_by_key(|(_, s)| (s.priority, s.emitted))
            .map(|(i, _)| i)
        else {
            return false;
        };
        if self.waiting[w].priority <= self.active[v].priority {
            return false;
        }
        let mut s = self.active.remove(v);
        if s.emitted > 0 {
            s.snapshot = Some(self.sessions[s.slot].snapshot());
        }
        self.release_slot(s.slot);
        lock(&self.stats).checkpoints += 1;
        self.waiting.push_back(s);
        true
    }

    /// Remove the resident stream at `i`, freeing its slot.
    fn retire(&mut self, i: usize) -> Stream {
        let s = self.active.remove(i);
        self.release_slot(s.slot);
        s
    }

    /// One unit of work for the resident stream at `i`: prefill, restore
    /// + step, or step — under per-stream panic isolation.
    fn run_unit(&mut self, i: usize) -> UnitEnd {
        // Zero-token streams finish without touching their session.
        if self.active[i].emitted >= self.active[i].n {
            let s = self.retire(i);
            self.finish(s, Terminal::Finished);
            return UnitEnd::Done;
        }
        // Watchdog: a stream past its deadline — stalled, preempted too
        // long, or just slow — is evicted mid-generation. The tokens
        // already delivered stand.
        if self.active[i].deadline.is_some_and(|d| Instant::now() >= d) {
            let s = self.retire(i);
            let elapsed_ms = s.enqueued.elapsed().as_millis() as u64;
            let _ = s.reply.send(Err(XgenError::DeadlineExceeded { elapsed_ms }));
            self.finish(s, Terminal::DeadlineEvicted);
            return UnitEnd::Done;
        }
        let slot = self.active[i].slot;
        let run = {
            // Split-borrow: the unit reads the stream and writes the
            // session + logits buffer; the containers are disjoint.
            let Engine { sessions, active, logits, .. } = self;
            let s = &active[i];
            let sess = &mut sessions[slot];
            // The unit returns whether an injected fault demands NaN
            // logits (always false without the fault-injection feature).
            catch_unwind(AssertUnwindSafe(|| -> anyhow::Result<bool> {
                #[cfg(feature = "fault-injection")]
                let nan = {
                    use crate::runtime::fault::{on_stream_step, StreamFaultEffect};
                    match on_stream_step(s.id, s.emitted as u64) {
                        Ok(StreamFaultEffect::Nan) => true,
                        Ok(StreamFaultEffect::None) => false,
                        Err(m) => return Err(anyhow::anyhow!(m)),
                    }
                };
                #[cfg(not(feature = "fault-injection"))]
                let nan = false;
                let l = if s.emitted == 0 {
                    sess.prefill(&s.prompt)?
                } else if let Some(snap) = &s.snapshot {
                    // Re-admission after a KV-pressure checkpoint:
                    // re-prefill the history, then run the pending step —
                    // bitwise-identical to never having been evicted.
                    sess.restore(snap)?;
                    sess.step(s.pending)?
                } else {
                    sess.step(s.pending)?
                };
                logits.clear();
                logits.extend_from_slice(l);
                Ok(nan)
            }))
        };
        match run {
            Err(payload) => {
                // Panic: typed reply, rebuild THIS stream's session from
                // the model; every other resident stream is untouched.
                let s = self.active.remove(i);
                let _ = s.reply.send(Err(XgenError::WorkerPanic {
                    detail: panic_detail(payload.as_ref()),
                }));
                lock(&self.stats).worker_panics += 1;
                match self.model.decode_session(self.max_seq) {
                    Ok(fresh) => {
                        self.sessions[slot] = fresh;
                        self.free.push(slot);
                        lock(&self.stats).session_rebuilds += 1;
                        self.finish(s, Terminal::Failed);
                        UnitEnd::Done
                    }
                    Err(_) => {
                        // The model can no longer build sessions; callers
                        // get typed errors and the scheduler stops.
                        self.finish(s, Terminal::Failed);
                        UnitEnd::Fatal
                    }
                }
            }
            Ok(Err(e)) => {
                // Typed failure: the session did not advance (step errors
                // leave `len` and the K/V lengths untouched) — reset is
                // sufficient.
                let s = self.retire(i);
                let _ = s.reply.send(Err(XgenError::classify(&e)));
                self.finish(s, Terminal::Failed);
                UnitEnd::Done
            }
            Ok(Ok(nan)) => {
                if self.active[i].snapshot.take().is_some() {
                    lock(&self.stats).resumes += 1;
                }
                if nan {
                    // Injected-NaN effect: corrupt the logits row exactly
                    // the way a kernel bug would.
                    for v in self.logits.iter_mut() {
                        *v = f32::NAN;
                    }
                }
                if !self.logits.iter().all(|v| v.is_finite()) {
                    let s = self.retire(i);
                    let _ = s
                        .reply
                        .send(Err(XgenError::NonFinite { at: "stream logits".to_string() }));
                    self.finish(s, Terminal::Failed);
                    return UnitEnd::Done;
                }
                let next = crate::exec::decode::argmax(&self.logits) as u32;
                if self.active[i].reply.send(Ok(next)).is_err() {
                    let s = self.retire(i);
                    self.finish(s, Terminal::Cancelled);
                    return UnitEnd::Done;
                }
                lock(&self.stats).tokens += 1;
                let s = &mut self.active[i];
                s.pending = next;
                s.emitted += 1;
                if s.emitted >= s.n {
                    let s = self.retire(i);
                    self.finish(s, Terminal::Finished);
                    return UnitEnd::Done;
                }
                UnitEnd::Continue
            }
        }
    }

    /// Catastrophic stop: every remaining stream gets a typed error.
    fn fail_all(&mut self) {
        let mut rest: Vec<Stream> = self.active.drain(..).collect();
        rest.extend(self.waiting.drain(..));
        for s in rest {
            let _ = s.reply.send(Err(XgenError::ServerGone));
            self.finish(s, Terminal::Failed);
        }
    }
}

/// The scheduler thread: intake → admission (+ preemption) → one
/// round-robin unit per resident stream, until the channel closes *and*
/// every live stream is terminal (drain-on-drop).
fn scheduler_loop(
    model: CompiledModel,
    max_seq: usize,
    cfg: SchedConfig,
    rx: mpsc::Receiver<StreamRequest>,
    depth: Arc<AtomicUsize>,
    stats: Arc<Mutex<SchedStats>>,
    ready: mpsc::Sender<Result<(), String>>,
) {
    // The probe session validates the model (causal decoder, weights,
    // max_seq in range) and measures the KV budget unit.
    let probe = match model.decode_session(max_seq) {
        Ok(s) => s,
        Err(e) => {
            let _ = ready.send(Err(e.to_string()));
            return;
        }
    };
    let session_bytes = (probe.kv_cache_elems() as u64 * 4).max(1);
    let by_budget = match cfg.kv_budget_bytes {
        Some(b) => {
            let fit = (b / session_bytes) as usize;
            if fit == 0 {
                let _ = ready.send(Err(format!(
                    "kv_budget_bytes {b} holds no session: one session's K/V caches at \
                     max_seq {max_seq} need {session_bytes} bytes"
                )));
                return;
            }
            fit
        }
        None => usize::MAX,
    };
    let pool_cap = cfg.max_streams.max(1).min(by_budget);
    {
        let mut st = lock(&stats);
        st.pool_sessions = pool_cap;
        st.session_kv_bytes = session_bytes;
    }
    let _ = ready.send(Ok(()));

    let mut eng = Engine {
        model: &model,
        max_seq,
        pool_cap,
        sessions: vec![probe],
        free: vec![0],
        waiting: VecDeque::new(),
        active: Vec::new(),
        next_id: 0,
        logits: Vec::new(),
        depth,
        stats,
    };
    loop {
        // Intake: block only when fully idle; the recv error after the
        // last sender drops is the shutdown signal — by then every
        // buffered submission has been drained and served.
        if eng.active.is_empty() && eng.waiting.is_empty() {
            match rx.recv() {
                Ok(r) => eng.enroll(r),
                Err(_) => break,
            }
        }
        while let Ok(r) = rx.try_recv() {
            eng.enroll(r);
        }
        eng.shed_expired_waiters();
        // Admission + KV-pressure preemption to a fixed point.
        loop {
            eng.admit();
            if !eng.preempt_one() {
                break;
            }
        }
        {
            let mut st = lock(&eng.stats);
            st.max_active = st.max_active.max(eng.active.len());
        }
        // One unit per resident stream, strict round-robin.
        let mut i = 0;
        while i < eng.active.len() {
            match eng.run_unit(i) {
                UnitEnd::Continue => i += 1,
                UnitEnd::Done => {} // removed at i; successor shifted in
                UnitEnd::Fatal => {
                    eng.fail_all();
                    return;
                }
            }
        }
    }
    // Clean drain exit: every slot must be back on the free list.
    let leaked = eng.sessions.len() - eng.free.len();
    lock(&eng.stats).leaked_sessions = leaked;
}

/// Multi-stream greedy-decoding scheduler over one compiled causal
/// decoder — see the [module docs](self) for the state machine, the
/// isolation guarantees, and the eviction policy.
pub struct StreamScheduler {
    tx: mpsc::Sender<StreamRequest>,
    handle: Option<std::thread::JoinHandle<()>>,
    stats: Arc<Mutex<SchedStats>>,
    depth: Arc<AtomicUsize>,
    cap: usize,
    default_deadline: Option<Duration>,
}

impl StreamScheduler {
    /// Spawn the scheduler thread with default [`SchedConfig`] bounds.
    /// The model must carry weights and decode incrementally (validated
    /// before the call returns).
    pub fn start(model: CompiledModel, max_seq: usize) -> anyhow::Result<StreamScheduler> {
        StreamScheduler::start_cfg(model, max_seq, SchedConfig::default())
    }

    /// [`StreamScheduler::start`] with explicit pool/queue/budget bounds.
    pub fn start_cfg(
        model: CompiledModel,
        max_seq: usize,
        cfg: SchedConfig,
    ) -> anyhow::Result<StreamScheduler> {
        let (tx, rx) = mpsc::channel::<StreamRequest>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let stats = Arc::new(Mutex::new(SchedStats::default()));
        let depth = Arc::new(AtomicUsize::new(0));
        let cap = cfg.queue_cap;
        let default_deadline = cfg.default_deadline;
        let (stats2, depth2) = (stats.clone(), depth.clone());
        let handle = std::thread::spawn(move || {
            scheduler_loop(model, max_seq, cfg, rx, depth2, stats2, ready_tx);
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("stream scheduler thread died"))?
            .map_err(anyhow::Error::msg)?;
        Ok(StreamScheduler { tx, handle: Some(handle), stats, depth, cap, default_deadline })
    }

    /// Typed admission: bump the live-stream count, shed past the cap
    /// with the observed depth and a retry-after hint.
    fn enqueue(
        &self,
        prompt: Vec<u32>,
        n: usize,
        opts: &SubmitOpts,
    ) -> Result<StreamHandle, XgenError> {
        let d = self.depth.fetch_add(1, Ordering::SeqCst);
        if d >= self.cap {
            self.depth.fetch_sub(1, Ordering::SeqCst);
            let mut st = lock(&self.stats);
            st.shed += 1;
            let done = st.finished + st.failed + st.cancelled + st.deadline_evicted;
            let mean_ms = if done == 0 { 0.0 } else { st.service_ms / done as f64 };
            return Err(XgenError::Overloaded {
                depth: d,
                capacity: self.cap,
                retry_after_ms: retry_after_ms(d, mean_ms),
            });
        }
        let (reply, rx) = mpsc::channel();
        let now = Instant::now();
        let req = StreamRequest {
            prompt,
            n,
            priority: opts.priority,
            reply,
            enqueued: now,
            deadline: opts.deadline.or(self.default_deadline).map(|w| now + w),
        };
        if let Err(mpsc::SendError(req)) = self.tx.send(req) {
            self.depth.fetch_sub(1, Ordering::SeqCst);
            let _ = req.reply.send(Err(XgenError::ServerGone));
        }
        Ok(StreamHandle { rx })
    }

    /// Submit a greedy generation of `n` tokens; tokens stream over the
    /// returned handle. Infallible surface: a shed becomes the first
    /// (and only) item on the stream.
    pub fn submit(&self, prompt: Vec<u32>, n: usize) -> StreamHandle {
        self.submit_opts(prompt, n, SubmitOpts::default())
    }

    /// [`StreamScheduler::submit`] with priority/deadline options.
    pub fn submit_opts(&self, prompt: Vec<u32>, n: usize, opts: SubmitOpts) -> StreamHandle {
        match self.enqueue(prompt, n, &opts) {
            Ok(h) => h,
            Err(e) => {
                let (reply, rx) = mpsc::channel();
                let _ = reply.send(Err(e));
                StreamHandle { rx }
            }
        }
    }

    /// Typed-admission variant: a full queue is an immediate
    /// `Err(Overloaded)` instead of an error on the stream.
    pub fn try_submit(
        &self,
        prompt: Vec<u32>,
        n: usize,
        opts: SubmitOpts,
    ) -> Result<StreamHandle, XgenError> {
        self.enqueue(prompt, n, &opts)
    }

    /// [`StreamScheduler::try_submit`] with client-side backoff: on an
    /// [`XgenError::Overloaded`] shed, sleep per `policy` (seeded by the
    /// server's retry-after hint) and resubmit, up to `policy.attempts`
    /// total attempts; exhausting them yields the typed
    /// [`XgenError::RetryExhausted`].
    pub fn submit_with_retry(
        &self,
        prompt: Vec<u32>,
        n: usize,
        opts: SubmitOpts,
        policy: &RetryPolicy,
    ) -> Result<StreamHandle, XgenError> {
        retry_loop(policy, || self.enqueue(prompt.clone(), n, &opts))
    }

    pub fn stats(&self) -> SchedStats {
        lock(&self.stats).clone()
    }

    /// Drain every live stream, stop the scheduler thread, and return the
    /// final statistics (including the drain-exit leak check).
    pub fn shutdown(mut self) -> SchedStats {
        self.close_and_join();
        let st = lock(&self.stats).clone();
        st
    }

    /// Close the submission channel and join the thread (idempotent).
    /// Buffered submissions survive sender drop, so every admitted
    /// stream is served before the thread exits — drop is a drain, not
    /// an abort.
    fn close_and_join(&mut self) {
        let (dummy_tx, _) = mpsc::channel();
        let tx = std::mem::replace(&mut self.tx, dummy_tx);
        drop(tx);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for StreamScheduler {
    fn drop(&mut self) {
        self.close_and_join();
    }
}
