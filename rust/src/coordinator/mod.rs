//! The XGen coordinator: the serving loop — dynamic batching with Python
//! never on the request path (§2's Fig 20 "Usage II/III" service path).
//! A [`Server`] dispatches onto either backend of the same bucketed
//! scheme: AOT artifacts over the PJRT runtime ([`Server::start`]) or
//! compiled sessions from [`crate::api::Compiler`] executing in-process
//! ([`Server::start_compiled`]).
//!
//! The serving loop uses std threads + mpsc channels (tokio is not in the
//! offline vendor set — see DESIGN.md): one dispatcher thread drains a
//! request queue, forms batches (up to the engine's batch size, bounded
//! wait), executes on a [`BatchEngine`], and completes per-request
//! responses through per-request channels.
//!
//! The old pipeline driver ([`compile`]/[`Compiled`]) is a deprecated
//! shim over [`crate::api::Compiler`]; it stays for one release.

pub mod service;

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::api::CompiledModel;
use crate::baselines::{DeviceClass, Framework};
use crate::cost::{estimate_latency, scheme_density_map, sparse_efficiency, DensityMap, Device};
use crate::fusion::FusionPlan;
use crate::graph::{Graph, WeightStore};
use crate::pruning::{prune_graph, PruneReport, PruneScheme};
use crate::rewrite::{rewrite, RewriteConfig, RewriteStats};
use crate::runtime::ModelRuntime;
use crate::util::stats::Summary;

/// Everything the pipeline produced for one model (legacy shape; the
/// session API's `CompiledModel` supersedes it).
pub struct Compiled {
    pub graph: Graph,
    pub plan: FusionPlan,
    pub rewrite_stats: RewriteStats,
    pub prune_report: Option<PruneReport>,
    pub scheme: PruneScheme,
    /// Density map cached at compile time (used to be rebuilt on every
    /// `latency_ms` call).
    pub density: DensityMap,
}

impl Compiled {
    /// Cost-model latency on a device under a framework profile.
    pub fn latency_ms(&self, device: &Device, fw: Framework, class: DeviceClass) -> Option<f64> {
        let prof = fw.profile(class)?;
        Some(
            estimate_latency(
                &self.graph,
                &self.plan,
                device,
                &prof,
                &self.density,
                sparse_efficiency(&self.scheme),
            )
            .total_ms(),
        )
    }
}

/// Run the full XGen pipeline: rewrite → prune → fuse.
#[deprecated(
    since = "0.2.0",
    note = "use xgen::api::Compiler — the session API that also builds the executor state"
)]
pub fn compile(
    mut graph: Graph,
    mut ws: Option<&mut WeightStore>,
    scheme: PruneScheme,
) -> Compiled {
    let rewrite_stats = rewrite(&mut graph, ws.as_deref_mut(), &RewriteConfig::default());
    let prune_report = ws
        .filter(|_| !matches!(scheme, PruneScheme::None))
        .map(|ws| prune_graph(&graph, ws, &scheme));
    let plan = crate::fusion::fuse(&graph, &crate::fusion::FusionConfig::default());
    let density = scheme_density_map(&graph, &scheme);
    Compiled { graph, plan, rewrite_stats, prune_report, scheme, density }
}

/// A single inference request: input tensor + response channel.
struct Request {
    input: Vec<f32>,
    reply: mpsc::Sender<Result<Vec<f32>, String>>,
    enqueued: Instant,
}

/// Serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub completed: usize,
    pub batches: usize,
    pub latencies_ms: Vec<f64>,
}

impl ServeStats {
    pub fn summary(&self) -> Option<Summary> {
        if self.latencies_ms.is_empty() {
            None
        } else {
            Some(Summary::of(&self.latencies_ms))
        }
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.completed as f64 / self.batches as f64
        }
    }
}

/// An inference engine the [`Server`] dispatcher batches onto: a
/// single-request variant plus a full-batch variant of the same model —
/// the classic bucketed-batching scheme.
trait BatchEngine {
    fn run_single(&mut self, x: &[f32]) -> Result<Vec<f32>>;
    fn run_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>>;
}

/// AOT artifacts executed through the PJRT runtime.
struct PjrtEngine {
    rt: ModelRuntime,
    single: String,
    batched: String,
}

impl BatchEngine for PjrtEngine {
    fn run_single(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        self.rt.load(&self.single)?.run(x)
    }

    fn run_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.rt.load(&self.batched)?.run_batch(xs)
    }
}

/// Compiled sessions from [`crate::api::Compiler`] executing in-process —
/// serving with no AOT artifacts and no Python anywhere.
struct CompiledEngine {
    single: CompiledModel,
    batched: CompiledModel,
}

impl BatchEngine for CompiledEngine {
    fn run_single(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        self.single.infer_flat(x)
    }

    fn run_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.batched.infer_flat_batch(xs)
    }
}

/// Dynamic-batching server over one model family (either PJRT artifacts
/// or compiled sessions).
///
/// The batch variant (e.g. `cnn_dense_b4`) serves full batches; the
/// single variant (`cnn_dense_b1`) serves the remainder.
pub struct Server {
    tx: mpsc::Sender<Request>,
    handle: Option<std::thread::JoinHandle<()>>,
    stats: Arc<Mutex<ServeStats>>,
}

impl Server {
    /// Spawn the dispatcher thread over PJRT artifacts. The PJRT client is
    /// **created inside** the thread (the xla crate's client is not
    /// `Send`); artifacts are compiled there before the call returns.
    pub fn start(
        artifact_dir: std::path::PathBuf,
        single_artifact: &str,
        batch_artifact: &str,
        max_wait: Duration,
    ) -> Result<Server> {
        let single = single_artifact.to_string();
        let batched = batch_artifact.to_string();
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let stats = Arc::new(Mutex::new(ServeStats::default()));
        let stats2 = stats.clone();
        let handle = std::thread::spawn(move || {
            let mut rt = match ModelRuntime::open(&artifact_dir) {
                Ok(rt) => rt,
                Err(e) => {
                    let _ = ready_tx.send(Err(e.to_string()));
                    return;
                }
            };
            // Pre-compile both variants before accepting traffic.
            let batch_size = match (|| -> Result<usize> {
                rt.load(&single)?;
                Ok(rt.load(&batched)?.input_shape[0])
            })() {
                Ok(b) => b,
                Err(e) => {
                    let _ = ready_tx.send(Err(e.to_string()));
                    return;
                }
            };
            let _ = ready_tx.send(Ok(()));
            dispatcher(PjrtEngine { rt, single, batched }, rx, batch_size, max_wait, stats2);
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server thread died"))?
            .map_err(anyhow::Error::msg)?;
        Ok(Server { tx, handle: Some(handle), stats })
    }

    /// Spawn the dispatcher over a pair of compiled sessions (batch-1 and
    /// batch-N variants of the same model, both built via
    /// [`crate::api::Compiler`] with weights attached). Pure-Rust real
    /// execution — no AOT artifacts required.
    pub fn start_compiled(
        single: CompiledModel,
        batched: CompiledModel,
        max_wait: Duration,
    ) -> Result<Server> {
        if single.weights().is_none() || batched.weights().is_none() {
            bail!("serving requires sessions compiled with weights");
        }
        if single.batch_size() != 1 {
            bail!("single-request session must be compiled at batch 1");
        }
        // Both sessions must be variants of the same model: identical
        // per-sample input shape, or the two serving paths would return
        // different results depending on arrival timing.
        let (ss, bs) = (single.input_shapes(), batched.input_shapes());
        match (ss.first(), bs.first()) {
            (Some(s), Some(b)) if !s.is_empty() && !b.is_empty() && s[1..] == b[1..] => {}
            _ => bail!(
                "single/batched sessions disagree on per-sample input shape: {ss:?} vs {bs:?}"
            ),
        }
        let batch_size = batched.batch_size().max(1);
        let (tx, rx) = mpsc::channel::<Request>();
        let stats = Arc::new(Mutex::new(ServeStats::default()));
        let stats2 = stats.clone();
        let handle = std::thread::spawn(move || {
            dispatcher(CompiledEngine { single, batched }, rx, batch_size, max_wait, stats2);
        });
        Ok(Server { tx, handle: Some(handle), stats })
    }

    /// Enqueue a request; returns the response receiver.
    pub fn submit(&self, input: Vec<f32>) -> mpsc::Receiver<Result<Vec<f32>, String>> {
        let (reply, rx) = mpsc::channel();
        let _ = self.tx.send(Request { input, reply, enqueued: Instant::now() });
        rx
    }

    /// Blocking convenience call.
    pub fn infer(&self, input: Vec<f32>) -> Result<Vec<f32>, String> {
        self.submit(input)
            .recv()
            .map_err(|_| "server shut down".to_string())?
    }

    pub fn stats(&self) -> ServeStats {
        self.stats.lock().unwrap().clone()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // Closing the channel stops the dispatcher.
        let (dummy_tx, _) = mpsc::channel();
        let tx = std::mem::replace(&mut self.tx, dummy_tx);
        drop(tx);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// One token-streaming generation request.
struct GenRequest {
    prompt: Vec<u32>,
    n: usize,
    reply: mpsc::Sender<Result<u32, String>>,
}

/// Serving statistics of a [`DecodeServer`].
#[derive(Debug, Clone, Default)]
pub struct DecodeStats {
    pub requests: usize,
    pub tokens: usize,
}

/// Token-streaming generation server: one thread owns a compiled *causal
/// decoder* session ([`CompiledModel::decode_session`]) and serves greedy
/// generation requests, sending each token back over the request's channel
/// **as it is decoded** — the client reads a stream, not a batch. The
/// session's K/V caches are reset and reused across requests, so the
/// serving loop allocates nothing per token after the first request.
pub struct DecodeServer {
    tx: mpsc::Sender<GenRequest>,
    handle: Option<std::thread::JoinHandle<()>>,
    stats: Arc<Mutex<DecodeStats>>,
}

impl DecodeServer {
    /// Spawn the decode thread over a compiled causal decoder. The model
    /// must carry weights and decode incrementally (validated before the
    /// call returns, so misconfiguration fails here, not on request one).
    pub fn start(model: CompiledModel, max_seq: usize) -> Result<DecodeServer> {
        let (tx, rx) = mpsc::channel::<GenRequest>();
        // Session construction (constant-subgraph evaluation, cache
        // allocation) happens once, inside the worker thread; the ready
        // channel reports the validation result before start() returns so
        // misconfiguration still fails eagerly.
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let stats = Arc::new(Mutex::new(DecodeStats::default()));
        let stats2 = stats.clone();
        let handle = std::thread::spawn(move || {
            let mut session = match model.decode_session(max_seq) {
                Ok(s) => {
                    let _ = ready_tx.send(Ok(()));
                    s
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e.to_string()));
                    return;
                }
            };
            let mut logits: Vec<f32> = Vec::new();
            while let Ok(req) = rx.recv() {
                session.reset();
                logits.clear();
                match session.prefill(&req.prompt) {
                    Ok(l) => logits.extend_from_slice(l),
                    Err(e) => {
                        let _ = req.reply.send(Err(e.to_string()));
                        continue;
                    }
                }
                let mut sent = 0usize;
                for i in 0..req.n {
                    let next = crate::exec::decode::argmax(&logits) as u32;
                    if req.reply.send(Ok(next)).is_err() {
                        break; // client hung up mid-stream
                    }
                    sent += 1;
                    if i + 1 < req.n {
                        match session.step(next) {
                            Ok(l) => {
                                logits.clear();
                                logits.extend_from_slice(l);
                            }
                            Err(e) => {
                                let _ = req.reply.send(Err(e.to_string()));
                                break;
                            }
                        }
                    }
                }
                let mut st = stats2.lock().unwrap();
                st.requests += 1;
                st.tokens += sent;
            }
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("decode server thread died"))?
            .map_err(anyhow::Error::msg)?;
        Ok(DecodeServer { tx, handle: Some(handle), stats })
    }

    /// Enqueue a generation request; tokens stream over the returned
    /// receiver one by one (an `Err` item ends the stream).
    pub fn generate_stream(
        &self,
        prompt: Vec<u32>,
        n: usize,
    ) -> mpsc::Receiver<Result<u32, String>> {
        let (reply, rx) = mpsc::channel();
        let _ = self.tx.send(GenRequest { prompt, n, reply });
        rx
    }

    /// Blocking convenience: drain the stream into a vec.
    pub fn generate(&self, prompt: Vec<u32>, n: usize) -> Result<Vec<u32>, String> {
        let rx = self.generate_stream(prompt, n);
        let mut out = Vec::with_capacity(n);
        for tok in rx {
            out.push(tok?);
        }
        Ok(out)
    }

    pub fn stats(&self) -> DecodeStats {
        self.stats.lock().unwrap().clone()
    }
}

impl Drop for DecodeServer {
    fn drop(&mut self) {
        let (dummy_tx, _) = mpsc::channel();
        let tx = std::mem::replace(&mut self.tx, dummy_tx);
        drop(tx);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn dispatcher<E: BatchEngine>(
    mut engine: E,
    rx: mpsc::Receiver<Request>,
    batch_size: usize,
    max_wait: Duration,
    stats: Arc<Mutex<ServeStats>>,
) {
    loop {
        // Block for the first request.
        let Ok(first) = rx.recv() else { return };
        let mut pending = vec![first];
        let deadline = Instant::now() + max_wait;
        // Coalesce until a full batch or the wait bound.
        while pending.len() < batch_size {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => pending.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        // Serve: full batches through the batch variant, remainder 1-by-1.
        while !pending.is_empty() {
            let take = if pending.len() >= batch_size { batch_size } else { 1 };
            let chunk: Vec<Request> = pending.drain(..take).collect();
            let inputs: Vec<Vec<f32>> = chunk.iter().map(|r| r.input.clone()).collect();
            let result = if take == 1 {
                engine.run_single(&inputs[0]).map(|o| vec![o])
            } else {
                engine.run_batch(&inputs)
            };
            let mut st = stats.lock().unwrap();
            st.batches += 1;
            match result {
                Ok(outs) => {
                    for (req, out) in chunk.into_iter().zip(outs) {
                        st.completed += 1;
                        st.latencies_ms
                            .push(req.enqueued.elapsed().as_secs_f64() * 1e3);
                        let _ = req.reply.send(Ok(out));
                    }
                }
                Err(e) => {
                    for req in chunk {
                        let _ = req.reply.send(Err(e.to_string()));
                    }
                }
            }
        }
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::graph::zoo::by_name;
    use crate::util::rng::Rng;

    #[test]
    fn compiled_server_round_trips_requests() {
        use crate::api::Compiler;
        let single = Compiler::for_model("demo-cnn", 1)
            .unwrap()
            .random_weights(11)
            .compile()
            .unwrap();
        let batched = Compiler::for_model("demo-cnn", 4)
            .unwrap()
            .random_weights(11)
            .compile()
            .unwrap();
        let server =
            Server::start_compiled(single, batched, Duration::from_millis(2)).unwrap();
        let per = 3 * 24 * 24;
        let mut rng = Rng::new(1);
        let rxs: Vec<_> = (0..9)
            .map(|_| server.submit((0..per).map(|_| rng.f32()).collect()))
            .collect();
        for rx in rxs {
            let y = rx.recv().unwrap().unwrap();
            assert_eq!(y.len(), 8);
            assert!(y.iter().all(|v| v.is_finite()));
        }
        let st = server.stats();
        assert_eq!(st.completed, 9);
        assert!(st.batches >= 3);
    }

    /// The token-streaming decode server: tokens arrive one by one,
    /// multiple requests reuse the session, and results match the
    /// in-process `CompiledModel::generate` exactly.
    #[test]
    fn decode_server_streams_tokens() {
        use crate::api::Compiler;
        let build = || {
            Compiler::for_model("demo-transformer-causal", 1)
                .unwrap()
                .random_weights(31)
                .compile()
                .unwrap()
        };
        let reference = build().generate(&[5, 6, 7], 4).unwrap();
        let server = DecodeServer::start(build(), 16).unwrap();
        // Streamed tokens match the in-process greedy decode.
        let rx = server.generate_stream(vec![5, 6, 7], 4);
        let mut got = Vec::new();
        for tok in rx {
            got.push(tok.unwrap());
        }
        assert_eq!(got, reference);
        // A second request after the first reuses the reset session.
        let again = server.generate(vec![5, 6, 7], 4).unwrap();
        assert_eq!(again, reference);
        // Errors stream too: an over-long prompt fails loudly.
        let err = server.generate((0..40).collect(), 1).unwrap_err();
        assert!(err.contains("exceeds max_seq"), "got: {err}");
        let st = server.stats();
        assert_eq!(st.requests, 2, "failed prefill must not count");
        assert_eq!(st.tokens, 8);
    }

    #[test]
    fn decode_server_rejects_non_decoders_eagerly() {
        use crate::api::Compiler;
        // Encoder attention: refused at start(), not at request time.
        let enc = Compiler::for_model("demo-transformer", 1)
            .unwrap()
            .random_weights(1)
            .compile()
            .unwrap();
        assert!(DecodeServer::start(enc, 8).is_err());
        // Weightless causal model: refused too.
        let weightless = Compiler::for_model("demo-transformer-causal", 1)
            .unwrap()
            .compile()
            .unwrap();
        assert!(DecodeServer::start(weightless, 8).is_err());
    }

    #[test]
    fn compiled_server_rejects_weightless_sessions() {
        use crate::api::Compiler;
        let single = Compiler::for_model("demo-cnn", 1).unwrap().compile().unwrap();
        let batched = Compiler::for_model("demo-cnn", 4).unwrap().compile().unwrap();
        assert!(Server::start_compiled(single, batched, Duration::from_millis(1)).is_err());
    }

    #[test]
    fn pipeline_compile_produces_report() {
        let g = by_name("mobilenet-v2", 1);
        let mut rng = Rng::new(201);
        let mut ws = WeightStore::init_random(&g, &mut rng);
        let c = compile(g, Some(&mut ws), PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.3 });
        assert!(c.prune_report.is_some());
        assert!(c.plan.fused_layer_count() > 0);
        let lat = c
            .latency_ms(&crate::cost::devices::s10_cpu(), Framework::XGenFull, DeviceClass::MobileCpu)
            .unwrap();
        assert!(lat > 0.0 && lat < 1000.0);
    }

    #[test]
    fn compile_without_weights_is_structural() {
        let g = by_name("wdsr-b", 1);
        let c = compile(g, None, PruneScheme::None);
        assert!(c.prune_report.is_none());
        assert!(c.rewrite_stats.ops_after <= c.rewrite_stats.ops_before);
    }
}
