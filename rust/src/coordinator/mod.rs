//! The XGen coordinator: the serving loop — dynamic batching with Python
//! never on the request path (§2's Fig 20 "Usage II/III" service path).
//! A [`Server`] dispatches onto either backend of the same bucketed
//! scheme: AOT artifacts over the PJRT runtime ([`Server::start`]) or
//! compiled sessions from [`crate::api::Compiler`] executing in-process
//! ([`Server::start_compiled`]).
//!
//! The serving loop uses std threads + mpsc channels (tokio is not in the
//! offline vendor set — see DESIGN.md): one dispatcher thread drains a
//! request queue, forms batches (up to the engine's batch size, bounded
//! wait), executes on a [`BatchEngine`], and completes per-request
//! responses through per-request channels.
//!
//! ## Fault tolerance
//!
//! The request lifecycle is hardened end to end:
//!
//! - **Typed errors** — every per-request channel carries
//!   [`crate::error::XgenError`], so clients can branch on
//!   [`XgenError::code`] instead of string-matching.
//! - **Backpressure** — submission queues are bounded
//!   ([`ServeConfig::queue_cap`] / [`DecodeConfig::queue_cap`]); past the
//!   cap, requests are shed immediately with [`XgenError::Overloaded`]
//!   rather than growing the queue without bound.
//! - **Deadlines** — a per-request deadline is checked before dispatch and
//!   between decode steps; an expired request gets
//!   [`XgenError::DeadlineExceeded`] (decode clients keep any tokens
//!   already streamed — the partial generation stands).
//! - **Panic isolation** — engine execution runs under `catch_unwind`: a
//!   panicking request is answered with [`XgenError::WorkerPanic`] and the
//!   server keeps serving; the decode server rebuilds its session after a
//!   panic so later requests see a clean K/V cache.
//! - **Cancellation** — a dropped receiver never kills the server; failed
//!   reply sends are counted as cancellations and the stream just stops.
//! - **Graceful drain** — dropping a server closes the submission channel;
//!   the dispatcher keeps draining buffered requests (mpsc receivers yield
//!   queued messages after all senders drop) before the thread joins.
//!
//! All of it is observable through [`ServeStats`] / [`DecodeStats`].
//!
//! The old pipeline driver ([`compile`]/[`Compiled`]) is a deprecated
//! shim over [`crate::api::Compiler`]; it stays for one release.
//!
//! Multi-stream decode serving — many concurrent generations interleaved
//! over a pool of sessions with per-stream fault isolation and
//! KV-pressure eviction — lives in [`scheduler`] (ISSUE-8).

pub mod scheduler;
pub mod service;

pub use scheduler::{SchedConfig, SchedStats, StreamHandle, StreamScheduler, SubmitOpts};

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::api::CompiledModel;
use crate::baselines::{DeviceClass, Framework};
use crate::cost::{estimate_latency, scheme_density_map, sparse_efficiency, DensityMap, Device};
use crate::error::{panic_detail, XgenError};
use crate::fusion::FusionPlan;
use crate::graph::{Graph, WeightStore};
use crate::pruning::{prune_graph, PruneReport, PruneScheme};
use crate::rewrite::{rewrite, RewriteConfig, RewriteStats};
use crate::runtime::ModelRuntime;
use crate::util::stats::Summary;

/// Everything the pipeline produced for one model (legacy shape; the
/// session API's `CompiledModel` supersedes it).
pub struct Compiled {
    pub graph: Graph,
    pub plan: FusionPlan,
    pub rewrite_stats: RewriteStats,
    pub prune_report: Option<PruneReport>,
    pub scheme: PruneScheme,
    /// Density map cached at compile time (used to be rebuilt on every
    /// `latency_ms` call).
    pub density: DensityMap,
}

impl Compiled {
    /// Cost-model latency on a device under a framework profile.
    pub fn latency_ms(&self, device: &Device, fw: Framework, class: DeviceClass) -> Option<f64> {
        let prof = fw.profile(class)?;
        Some(
            estimate_latency(
                &self.graph,
                &self.plan,
                device,
                &prof,
                &self.density,
                sparse_efficiency(&self.scheme),
            )
            .total_ms(),
        )
    }
}

/// Run the full XGen pipeline: rewrite → prune → fuse.
#[deprecated(
    since = "0.2.0",
    note = "use xgen::api::Compiler — the session API that also builds the executor state"
)]
pub fn compile(
    mut graph: Graph,
    mut ws: Option<&mut WeightStore>,
    scheme: PruneScheme,
) -> Compiled {
    let rewrite_stats = rewrite(&mut graph, ws.as_deref_mut(), &RewriteConfig::default());
    let prune_report = ws
        .filter(|_| !matches!(scheme, PruneScheme::None))
        .map(|ws| prune_graph(&graph, ws, &scheme));
    let plan = crate::fusion::fuse(&graph, &crate::fusion::FusionConfig::default());
    let density = scheme_density_map(&graph, &scheme);
    Compiled { graph, plan, rewrite_stats, prune_report, scheme, density }
}

/// Lock a stats mutex, recovering from poison: statistics stay readable
/// even if a holder panicked mid-update (counters may then be one off —
/// acceptable for observability data, fatal for nothing).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Retry-after estimate attached to an [`XgenError::Overloaded`] shed:
/// observed queue depth × the recent mean service time, floored at 1 ms
/// (when nothing has completed yet there is no observation to extrapolate
/// from, but "come back immediately" would just shed again).
fn retry_after_ms(depth: usize, mean_service_ms: f64) -> u64 {
    let est = depth.max(1) as f64 * mean_service_ms;
    (est.ceil() as u64).max(1)
}

/// Client-side backoff policy for the `*_with_retry` submission helpers:
/// on every [`XgenError::Overloaded`] shed, sleep
/// `min(max, max(base, server hint) × 2^attempt) × jitter` (jitter
/// uniform in `[0.5, 1.5)`, seeded — deterministic for tests) and try
/// again, up to `attempts` total attempts. Any error other than
/// `Overloaded` aborts the loop immediately; exhausting the budget yields
/// the typed [`XgenError::RetryExhausted`].
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Total submission attempts (including the first). 0 is treated as 1.
    pub attempts: usize,
    /// First backoff, doubled per subsequent attempt (the server's
    /// `retry_after_ms` hint overrides it when larger).
    pub base: Duration,
    /// Upper bound on a single backoff sleep (pre-jitter).
    pub max: Duration,
    /// Jitter seed — fixed default so tests are deterministic; vary per
    /// client in production to decorrelate retry storms.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            attempts: 5,
            base: Duration::from_millis(1),
            max: Duration::from_millis(100),
            seed: 0x5eed,
        }
    }
}

/// Shared engine of the `*_with_retry` helpers: run `attempt` under
/// `policy`, sleeping between [`XgenError::Overloaded`] sheds.
fn retry_loop<T>(
    policy: &RetryPolicy,
    mut attempt: impl FnMut() -> Result<T, XgenError>,
) -> Result<T, XgenError> {
    let attempts = policy.attempts.max(1);
    let mut rng = crate::util::rng::Rng::new(policy.seed);
    let mut last_depth = 0usize;
    for k in 0..attempts {
        match attempt() {
            Ok(v) => return Ok(v),
            Err(XgenError::Overloaded { depth, retry_after_ms, .. }) => {
                last_depth = depth;
                if k + 1 == attempts {
                    break;
                }
                let hint = retry_after_ms.max(policy.base.as_millis() as u64);
                let backoff = hint.saturating_mul(1u64 << k.min(20)).min(policy.max.as_millis() as u64);
                let jitter = 0.5 + rng.f64();
                std::thread::sleep(Duration::from_micros(
                    (backoff as f64 * jitter * 1e3) as u64,
                ));
            }
            Err(other) => return Err(other),
        }
    }
    Err(XgenError::RetryExhausted { attempts, last_depth })
}

/// A single inference request: input tensor + response channel.
struct Request {
    input: Vec<f32>,
    reply: mpsc::Sender<Result<Vec<f32>, XgenError>>,
    enqueued: Instant,
    deadline: Option<Instant>,
}

/// Serving configuration: batching bound, queue bound, default deadline.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// How long the dispatcher waits for a batch to fill before serving a
    /// partial one.
    pub max_wait: Duration,
    /// Bound on queued (admitted, not yet served) requests; past it,
    /// submissions are shed with [`XgenError::Overloaded`].
    pub queue_cap: usize,
    /// Deadline applied to [`Server::submit`] requests (none by default;
    /// [`Server::submit_with_deadline`] overrides per request).
    pub default_deadline: Option<Duration>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { max_wait: Duration::from_millis(2), queue_cap: 1024, default_deadline: None }
    }
}

/// Serving statistics.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    pub completed: usize,
    pub batches: usize,
    pub latencies_ms: Vec<f64>,
    /// Requests answered with an error (engine failure or worker panic).
    pub errors: usize,
    /// Requests refused at submission because the queue was full.
    pub shed: usize,
    /// Requests dropped because their deadline expired before dispatch.
    pub deadline_exceeded: usize,
    /// Replies that found the receiver already dropped.
    pub cancelled: usize,
    /// Engine panics caught and converted into per-request errors.
    pub worker_panics: usize,
    /// Requests served through the reference-executor fallback after the
    /// steady-state engine failed (see [`CompiledModel::runtime_stats`]).
    pub engine_fallbacks: usize,
}

impl ServeStats {
    pub fn summary(&self) -> Option<Summary> {
        if self.latencies_ms.is_empty() {
            None
        } else {
            Some(Summary::of(&self.latencies_ms))
        }
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.completed as f64 / self.batches as f64
        }
    }

    /// One-line operator-facing summary including the fault counters.
    pub fn report(&self) -> String {
        format!(
            "served {} in {} batches (mean {:.2}/batch); errors {}, shed {}, \
             deadline-exceeded {}, cancelled {}, worker panics {}, engine fallbacks {}",
            self.completed,
            self.batches,
            self.mean_batch(),
            self.errors,
            self.shed,
            self.deadline_exceeded,
            self.cancelled,
            self.worker_panics,
            self.engine_fallbacks
        )
    }
}

/// An inference engine the [`Server`] dispatcher batches onto: a
/// single-request variant plus a full-batch variant of the same model —
/// the classic bucketed-batching scheme.
trait BatchEngine {
    fn run_single(&mut self, x: &[f32]) -> Result<Vec<f32>>;
    fn run_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>>;
    /// Cumulative count of requests this engine served through a degraded
    /// fallback path (0 for engines with no fallback).
    fn fallbacks(&self) -> usize {
        0
    }
}

/// AOT artifacts executed through the PJRT runtime.
struct PjrtEngine {
    rt: ModelRuntime,
    single: String,
    batched: String,
}

impl BatchEngine for PjrtEngine {
    fn run_single(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        self.rt.load(&self.single)?.run(x)
    }

    fn run_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.rt.load(&self.batched)?.run_batch(xs)
    }
}

/// Compiled sessions from [`crate::api::Compiler`] executing in-process —
/// serving with no AOT artifacts and no Python anywhere.
struct CompiledEngine {
    single: CompiledModel,
    batched: CompiledModel,
}

impl BatchEngine for CompiledEngine {
    fn run_single(&mut self, x: &[f32]) -> Result<Vec<f32>> {
        self.single.infer_flat(x)
    }

    fn run_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.batched.infer_flat_batch(xs)
    }

    fn fallbacks(&self) -> usize {
        self.single.runtime_stats().engine_fallbacks
            + self.batched.runtime_stats().engine_fallbacks
    }
}

/// Dynamic-batching server over one model family (either PJRT artifacts
/// or compiled sessions).
///
/// The batch variant (e.g. `cnn_dense_b4`) serves full batches; the
/// single variant (`cnn_dense_b1`) serves the remainder.
pub struct Server {
    tx: mpsc::Sender<Request>,
    handle: Option<std::thread::JoinHandle<()>>,
    stats: Arc<Mutex<ServeStats>>,
    depth: Arc<AtomicUsize>,
    cap: usize,
    default_deadline: Option<Duration>,
}

impl Server {
    /// Spawn the dispatcher thread over PJRT artifacts with default
    /// [`ServeConfig`] bounds. The PJRT client is **created inside** the
    /// thread (the xla crate's client is not `Send`); artifacts are
    /// compiled there before the call returns.
    pub fn start(
        artifact_dir: std::path::PathBuf,
        single_artifact: &str,
        batch_artifact: &str,
        max_wait: Duration,
    ) -> Result<Server> {
        Server::start_cfg(
            artifact_dir,
            single_artifact,
            batch_artifact,
            ServeConfig { max_wait, ..ServeConfig::default() },
        )
    }

    /// [`Server::start`] with explicit queue/deadline bounds.
    pub fn start_cfg(
        artifact_dir: std::path::PathBuf,
        single_artifact: &str,
        batch_artifact: &str,
        cfg: ServeConfig,
    ) -> Result<Server> {
        let single = single_artifact.to_string();
        let batched = batch_artifact.to_string();
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let stats = Arc::new(Mutex::new(ServeStats::default()));
        let stats2 = stats.clone();
        let depth = Arc::new(AtomicUsize::new(0));
        let depth2 = depth.clone();
        let max_wait = cfg.max_wait;
        let handle = std::thread::spawn(move || {
            let mut rt = match ModelRuntime::open(&artifact_dir) {
                Ok(rt) => rt,
                Err(e) => {
                    let _ = ready_tx.send(Err(e.to_string()));
                    return;
                }
            };
            // Pre-compile both variants before accepting traffic.
            let batch_size = match (|| -> Result<usize> {
                rt.load(&single)?;
                Ok(rt.load(&batched)?.input_shape[0])
            })() {
                Ok(b) => b,
                Err(e) => {
                    let _ = ready_tx.send(Err(e.to_string()));
                    return;
                }
            };
            let _ = ready_tx.send(Ok(()));
            dispatcher(PjrtEngine { rt, single, batched }, rx, batch_size, max_wait, depth2, stats2);
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server thread died"))?
            .map_err(anyhow::Error::msg)?;
        Ok(Server {
            tx,
            handle: Some(handle),
            stats,
            depth,
            cap: cfg.queue_cap,
            default_deadline: cfg.default_deadline,
        })
    }

    /// Spawn the dispatcher over a pair of compiled sessions (batch-1 and
    /// batch-N variants of the same model, both built via
    /// [`crate::api::Compiler`] with weights attached) with default
    /// [`ServeConfig`] bounds. Pure-Rust real execution — no AOT
    /// artifacts required.
    pub fn start_compiled(
        single: CompiledModel,
        batched: CompiledModel,
        max_wait: Duration,
    ) -> Result<Server> {
        Server::start_compiled_cfg(single, batched, ServeConfig { max_wait, ..ServeConfig::default() })
    }

    /// [`Server::start_compiled`] with explicit queue/deadline bounds.
    pub fn start_compiled_cfg(
        single: CompiledModel,
        batched: CompiledModel,
        cfg: ServeConfig,
    ) -> Result<Server> {
        if single.weights().is_none() || batched.weights().is_none() {
            bail!("serving requires sessions compiled with weights");
        }
        if single.batch_size() != 1 {
            bail!("single-request session must be compiled at batch 1");
        }
        // Both sessions must be variants of the same model: identical
        // per-sample input shape, or the two serving paths would return
        // different results depending on arrival timing.
        let (ss, bs) = (single.input_shapes(), batched.input_shapes());
        match (ss.first(), bs.first()) {
            (Some(s), Some(b)) if !s.is_empty() && !b.is_empty() && s[1..] == b[1..] => {}
            _ => bail!(
                "single/batched sessions disagree on per-sample input shape: {ss:?} vs {bs:?}"
            ),
        }
        let batch_size = batched.batch_size().max(1);
        Ok(Server::spawn_engine(CompiledEngine { single, batched }, batch_size, cfg))
    }

    /// Spawn the dispatcher thread over an arbitrary engine (shared by the
    /// compiled path and the mock engines in tests).
    fn spawn_engine<E: BatchEngine + Send + 'static>(
        engine: E,
        batch_size: usize,
        cfg: ServeConfig,
    ) -> Server {
        let (tx, rx) = mpsc::channel::<Request>();
        let stats = Arc::new(Mutex::new(ServeStats::default()));
        let stats2 = stats.clone();
        let depth = Arc::new(AtomicUsize::new(0));
        let depth2 = depth.clone();
        let max_wait = cfg.max_wait;
        let handle = std::thread::spawn(move || {
            dispatcher(engine, rx, batch_size, max_wait, depth2, stats2);
        });
        Server {
            tx,
            handle: Some(handle),
            stats,
            depth,
            cap: cfg.queue_cap,
            default_deadline: cfg.default_deadline,
        }
    }

    /// Admission control: bump the queue depth, shed if past the cap, then
    /// hand the request to the dispatcher. The depth counter is our own
    /// (std mpsc has no bounded variant); the dispatcher decrements it on
    /// dequeue.
    fn enqueue(
        &self,
        input: Vec<f32>,
        deadline: Option<Duration>,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>, XgenError>>, XgenError> {
        let d = self.depth.fetch_add(1, Ordering::SeqCst);
        if d >= self.cap {
            self.depth.fetch_sub(1, Ordering::SeqCst);
            let mut st = lock(&self.stats);
            st.shed += 1;
            let mean_ms = st.summary().map_or(0.0, |s| s.mean);
            return Err(XgenError::Overloaded {
                depth: d,
                capacity: self.cap,
                retry_after_ms: retry_after_ms(d, mean_ms),
            });
        }
        let (reply, rx) = mpsc::channel();
        let now = Instant::now();
        let req = Request { input, reply, enqueued: now, deadline: deadline.map(|w| now + w) };
        if let Err(mpsc::SendError(req)) = self.tx.send(req) {
            self.depth.fetch_sub(1, Ordering::SeqCst);
            // Recover the reply sender from the failed send so the caller
            // still gets a typed answer through the usual channel.
            let _ = req.reply.send(Err(XgenError::ServerGone));
        }
        Ok(rx)
    }

    /// Enqueue a request; returns the response receiver. Uses the server's
    /// default deadline (if any). If the queue is full the receiver yields
    /// [`XgenError::Overloaded`] immediately — the signature stays
    /// infallible so existing call sites keep working.
    pub fn submit(&self, input: Vec<f32>) -> mpsc::Receiver<Result<Vec<f32>, XgenError>> {
        self.submit_with_deadline(input, self.default_deadline)
    }

    /// [`Server::submit`] with an explicit per-request deadline (None =
    /// no deadline).
    pub fn submit_with_deadline(
        &self,
        input: Vec<f32>,
        deadline: Option<Duration>,
    ) -> mpsc::Receiver<Result<Vec<f32>, XgenError>> {
        match self.enqueue(input, deadline) {
            Ok(rx) => rx,
            Err(e) => {
                let (reply, rx) = mpsc::channel();
                let _ = reply.send(Err(e));
                rx
            }
        }
    }

    /// Typed-admission variant of [`Server::submit`]: a full queue is an
    /// immediate `Err(Overloaded)` instead of an error on the receiver.
    pub fn try_submit(
        &self,
        input: Vec<f32>,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>, XgenError>>, XgenError> {
        self.enqueue(input, self.default_deadline)
    }

    /// [`Server::try_submit`] with client-side backoff: on an
    /// [`XgenError::Overloaded`] shed, sleep per `policy` (starting from
    /// the server's retry-after hint) and resubmit, up to
    /// `policy.attempts` total attempts; exhausting them yields the typed
    /// [`XgenError::RetryExhausted`]. Non-overload errors abort at once.
    pub fn submit_with_retry(
        &self,
        input: Vec<f32>,
        policy: &RetryPolicy,
    ) -> Result<mpsc::Receiver<Result<Vec<f32>, XgenError>>, XgenError> {
        retry_loop(policy, || self.enqueue(input.clone(), self.default_deadline))
    }

    /// Blocking convenience call.
    pub fn infer(&self, input: Vec<f32>) -> Result<Vec<f32>, XgenError> {
        self.submit(input).recv().map_err(|_| XgenError::ServerGone)?
    }

    pub fn stats(&self) -> ServeStats {
        lock(&self.stats).clone()
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        // Closing the channel stops the dispatcher — after it drains what
        // is already queued (mpsc receivers keep yielding buffered
        // messages once all senders are gone), so in-flight requests get
        // answers, not hangups.
        let (dummy_tx, _) = mpsc::channel();
        let tx = std::mem::replace(&mut self.tx, dummy_tx);
        drop(tx);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn dispatcher<E: BatchEngine>(
    mut engine: E,
    rx: mpsc::Receiver<Request>,
    batch_size: usize,
    max_wait: Duration,
    depth: Arc<AtomicUsize>,
    stats: Arc<Mutex<ServeStats>>,
) {
    loop {
        // Block for the first request.
        let Ok(first) = rx.recv() else { return };
        depth.fetch_sub(1, Ordering::SeqCst);
        let mut pending = vec![first];
        let wait_deadline = Instant::now() + max_wait;
        // Coalesce until a full batch or the wait bound.
        while pending.len() < batch_size {
            let now = Instant::now();
            if now >= wait_deadline {
                break;
            }
            match rx.recv_timeout(wait_deadline - now) {
                Ok(r) => {
                    depth.fetch_sub(1, Ordering::SeqCst);
                    pending.push(r);
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        // Shed requests whose deadline expired while they sat in the
        // queue: answering them late helps nobody and starves the rest.
        let now = Instant::now();
        pending.retain(|req| {
            let expired = req.deadline.is_some_and(|d| now >= d);
            if expired {
                let mut st = lock(&stats);
                st.deadline_exceeded += 1;
                let elapsed_ms = req.enqueued.elapsed().as_millis() as u64;
                if req.reply.send(Err(XgenError::DeadlineExceeded { elapsed_ms })).is_err() {
                    st.cancelled += 1;
                }
            }
            !expired
        });
        // Serve: full batches through the batch variant, remainder 1-by-1.
        while !pending.is_empty() {
            let take = if pending.len() >= batch_size { batch_size } else { 1 };
            let chunk: Vec<Request> = pending.drain(..take).collect();
            let inputs: Vec<Vec<f32>> = chunk.iter().map(|r| r.input.clone()).collect();
            // Panic isolation: a panicking engine answers this chunk with
            // WorkerPanic and the dispatcher keeps serving.
            let result = catch_unwind(AssertUnwindSafe(|| {
                if take == 1 {
                    engine.run_single(&inputs[0]).map(|o| vec![o])
                } else {
                    engine.run_batch(&inputs)
                }
            }));
            let mut st = lock(&stats);
            st.batches += 1;
            st.engine_fallbacks = engine.fallbacks();
            match result {
                Ok(Ok(outs)) => {
                    for (req, out) in chunk.into_iter().zip(outs) {
                        if req.reply.send(Ok(out)).is_ok() {
                            st.completed += 1;
                            st.latencies_ms.push(req.enqueued.elapsed().as_secs_f64() * 1e3);
                        } else {
                            st.cancelled += 1;
                        }
                    }
                }
                Ok(Err(e)) => {
                    let typed = XgenError::classify(&e);
                    for req in chunk {
                        st.errors += 1;
                        if req.reply.send(Err(typed.clone())).is_err() {
                            st.cancelled += 1;
                        }
                    }
                }
                Err(payload) => {
                    st.worker_panics += 1;
                    let typed =
                        XgenError::WorkerPanic { detail: panic_detail(payload.as_ref()) };
                    for req in chunk {
                        st.errors += 1;
                        if req.reply.send(Err(typed.clone())).is_err() {
                            st.cancelled += 1;
                        }
                    }
                }
            }
        }
    }
}

/// One token-streaming generation request.
struct GenRequest {
    prompt: Vec<u32>,
    n: usize,
    reply: mpsc::Sender<Result<u32, XgenError>>,
    enqueued: Instant,
    deadline: Option<Instant>,
}

/// Decode-server configuration: queue bound + default per-request deadline.
#[derive(Debug, Clone)]
pub struct DecodeConfig {
    /// Bound on queued generation requests; past it, submissions are shed
    /// with [`XgenError::Overloaded`].
    pub queue_cap: usize,
    /// Deadline applied to [`DecodeServer::generate_stream`] requests
    /// (none by default). Checked between decode steps: an expired request
    /// keeps the tokens already streamed and ends with
    /// [`XgenError::DeadlineExceeded`].
    pub default_deadline: Option<Duration>,
}

impl Default for DecodeConfig {
    fn default() -> Self {
        DecodeConfig { queue_cap: 1024, default_deadline: None }
    }
}

/// Serving statistics of a [`DecodeServer`].
#[derive(Debug, Clone, Default)]
pub struct DecodeStats {
    pub requests: usize,
    pub tokens: usize,
    /// Requests refused at submission because the queue was full.
    pub shed: usize,
    /// Streams whose client dropped the receiver mid-generation.
    pub cancelled: usize,
    /// Requests answered with an error (prefill/step failure or panic).
    pub errors: usize,
    /// Requests cut off mid-generation by their deadline.
    pub deadline_exceeded: usize,
    /// Session panics caught; the session is rebuilt after each.
    pub worker_panics: usize,
    /// Sessions rebuilt from the model after a caught panic. Every served
    /// request ends in **exactly one** recovery action — rebuild (panic)
    /// or reset (everything else) — pinned by the interleaved-failure
    /// test in `tests/robustness.rs`.
    pub session_rebuilds: usize,
    /// Total client-visible time (enqueue → stream end) over counted
    /// requests — `service_ms / requests` feeds the retry-after hint on
    /// [`XgenError::Overloaded`] sheds.
    pub service_ms: f64,
}

impl DecodeStats {
    /// One-line operator-facing summary including the fault counters.
    pub fn report(&self) -> String {
        format!(
            "{} requests, {} tokens; errors {}, shed {}, deadline-exceeded {}, \
             cancelled {}, worker panics {}",
            self.requests,
            self.tokens,
            self.errors,
            self.shed,
            self.deadline_exceeded,
            self.cancelled,
            self.worker_panics
        )
    }
}

/// The single recovery action a served request leaves the decode session
/// owing: panics require a rebuild (buffers may be mid-move), everything
/// else a reset. Unified here so both failure kinds take exactly one
/// recovery step — the old loop reset at the top of *every* request and
/// additionally rebuilt after panics, which made the recovery count
/// depend on the failure kind.
enum Teardown {
    Reset,
    Rebuild,
}

/// Serve one generation request on a clean session: prefill, stream
/// argmax tokens, guard every logits row for finiteness, honor the
/// deadline between steps. Returns the one [`Teardown`] action owed.
fn serve_decode_request(
    session: &mut crate::exec::DecodeSession<'_>,
    logits: &mut Vec<f32>,
    req: &GenRequest,
    stats: &Mutex<DecodeStats>,
) -> Teardown {
    logits.clear();
    // Prefill under panic isolation. A failed prefill ran nothing of the
    // generation, so it is not counted in `requests`.
    let prefill = catch_unwind(AssertUnwindSafe(|| {
        session.prefill(&req.prompt).map(|l| {
            logits.clear();
            logits.extend_from_slice(l);
        })
    }));
    match prefill {
        Ok(Ok(())) => {}
        Ok(Err(e)) => {
            lock(stats).errors += 1;
            let _ = req.reply.send(Err(XgenError::classify(&e)));
            return Teardown::Reset;
        }
        Err(payload) => {
            let mut st = lock(stats);
            st.worker_panics += 1;
            st.errors += 1;
            drop(st);
            let _ = req
                .reply
                .send(Err(XgenError::WorkerPanic { detail: panic_detail(payload.as_ref()) }));
            return Teardown::Rebuild;
        }
    }
    if !logits.iter().all(|v| v.is_finite()) {
        lock(stats).errors += 1;
        let _ = req.reply.send(Err(XgenError::NonFinite { at: "prefill logits".to_string() }));
        return Teardown::Reset;
    }
    let mut sent = 0usize;
    let mut teardown = Teardown::Reset;
    for i in 0..req.n {
        // Deadline between steps: the partial stream stands.
        if let Some(d) = req.deadline {
            if Instant::now() >= d {
                let mut st = lock(stats);
                st.deadline_exceeded += 1;
                let elapsed_ms = req.enqueued.elapsed().as_millis() as u64;
                if req.reply.send(Err(XgenError::DeadlineExceeded { elapsed_ms })).is_err() {
                    st.cancelled += 1;
                }
                break;
            }
        }
        let next = crate::exec::decode::argmax(logits) as u32;
        if req.reply.send(Ok(next)).is_err() {
            lock(stats).cancelled += 1;
            break; // client hung up mid-stream
        }
        sent += 1;
        if i + 1 < req.n {
            let step = catch_unwind(AssertUnwindSafe(|| {
                session.step(next).map(|l| {
                    logits.clear();
                    logits.extend_from_slice(l);
                })
            }));
            match step {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    lock(stats).errors += 1;
                    let _ = req.reply.send(Err(XgenError::classify(&e)));
                    break;
                }
                Err(payload) => {
                    let mut st = lock(stats);
                    st.worker_panics += 1;
                    st.errors += 1;
                    drop(st);
                    let _ = req.reply.send(Err(XgenError::WorkerPanic {
                        detail: panic_detail(payload.as_ref()),
                    }));
                    teardown = Teardown::Rebuild;
                    break;
                }
            }
            if !logits.iter().all(|v| v.is_finite()) {
                lock(stats).errors += 1;
                let _ = req
                    .reply
                    .send(Err(XgenError::NonFinite { at: "step logits".to_string() }));
                break;
            }
        }
    }
    let mut st = lock(stats);
    st.requests += 1;
    st.tokens += sent;
    st.service_ms += req.enqueued.elapsed().as_secs_f64() * 1e3;
    teardown
}

/// Token-streaming generation server: one thread owns a compiled *causal
/// decoder* session ([`CompiledModel::decode_session`]) and serves greedy
/// generation requests, sending each token back over the request's channel
/// **as it is decoded** — the client reads a stream, not a batch. The
/// session's K/V caches are reset and reused across requests, so the
/// serving loop allocates nothing per token after the first request.
///
/// Faults are isolated per request: a panic during prefill or a step is
/// caught, answered with [`XgenError::WorkerPanic`], and the session is
/// **rebuilt** before the next request (a panic can leave session buffers
/// mid-move); non-finite logits abort the stream with
/// [`XgenError::NonFinite`] instead of feeding NaN back into the argmax.
pub struct DecodeServer {
    tx: mpsc::Sender<GenRequest>,
    handle: Option<std::thread::JoinHandle<()>>,
    stats: Arc<Mutex<DecodeStats>>,
    depth: Arc<AtomicUsize>,
    cap: usize,
    default_deadline: Option<Duration>,
}

impl DecodeServer {
    /// Spawn the decode thread over a compiled causal decoder with default
    /// [`DecodeConfig`] bounds. The model must carry weights and decode
    /// incrementally (validated before the call returns, so
    /// misconfiguration fails here, not on request one).
    pub fn start(model: CompiledModel, max_seq: usize) -> Result<DecodeServer> {
        DecodeServer::start_cfg(model, max_seq, DecodeConfig::default())
    }

    /// [`DecodeServer::start`] with explicit queue/deadline bounds.
    pub fn start_cfg(
        model: CompiledModel,
        max_seq: usize,
        cfg: DecodeConfig,
    ) -> Result<DecodeServer> {
        let (tx, rx) = mpsc::channel::<GenRequest>();
        // Session construction (constant-subgraph evaluation, cache
        // allocation) happens once, inside the worker thread; the ready
        // channel reports the validation result before start() returns so
        // misconfiguration still fails eagerly.
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        let stats = Arc::new(Mutex::new(DecodeStats::default()));
        let stats2 = stats.clone();
        let depth = Arc::new(AtomicUsize::new(0));
        let depth2 = depth.clone();
        let handle = std::thread::spawn(move || {
            let mut session = match model.decode_session(max_seq) {
                Ok(s) => {
                    let _ = ready_tx.send(Ok(()));
                    s
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e.to_string()));
                    return;
                }
            };
            let mut logits: Vec<f32> = Vec::new();
            while let Ok(req) = rx.recv() {
                depth2.fetch_sub(1, Ordering::SeqCst);
                // Expired before we even started: shed without touching
                // the session. Not counted in `requests` (nothing ran),
                // and no recovery needed (the session was never dirtied).
                if let Some(d) = req.deadline {
                    if Instant::now() >= d {
                        let mut st = lock(&stats2);
                        st.deadline_exceeded += 1;
                        let elapsed_ms = req.enqueued.elapsed().as_millis() as u64;
                        if req
                            .reply
                            .send(Err(XgenError::DeadlineExceeded { elapsed_ms }))
                            .is_err()
                        {
                            st.cancelled += 1;
                        }
                        continue;
                    }
                }
                // Serve, then recover **exactly once** — rebuild after a
                // caught panic (the session buffers may be mid-move),
                // reset after everything else (success included; a typed
                // step error leaves `len` and the K/V lengths at their
                // pre-call values, so reset is sufficient). The loop
                // invariant is that the session is clean at the top of
                // every request.
                match serve_decode_request(&mut session, &mut logits, &req, &stats2) {
                    Teardown::Reset => session.reset(),
                    Teardown::Rebuild => match model.decode_session(max_seq) {
                        Ok(s) => {
                            lock(&stats2).session_rebuilds += 1;
                            session = s;
                        }
                        Err(_) => return, // cannot recover: stop serving
                    },
                }
            }
        });
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("decode server thread died"))?
            .map_err(anyhow::Error::msg)?;
        Ok(DecodeServer {
            tx,
            handle: Some(handle),
            stats,
            depth,
            cap: cfg.queue_cap,
            default_deadline: cfg.default_deadline,
        })
    }

    /// Typed admission path: shed past the cap with a retry-after hint
    /// (observed depth × recent mean request time), recover the reply
    /// sender on a dead server so the stream still ends with a typed
    /// error.
    fn enqueue(
        &self,
        prompt: Vec<u32>,
        n: usize,
        deadline: Option<Duration>,
    ) -> Result<mpsc::Receiver<Result<u32, XgenError>>, XgenError> {
        let d = self.depth.fetch_add(1, Ordering::SeqCst);
        if d >= self.cap {
            self.depth.fetch_sub(1, Ordering::SeqCst);
            let mut st = lock(&self.stats);
            st.shed += 1;
            let mean_ms =
                if st.requests == 0 { 0.0 } else { st.service_ms / st.requests as f64 };
            return Err(XgenError::Overloaded {
                depth: d,
                capacity: self.cap,
                retry_after_ms: retry_after_ms(d, mean_ms),
            });
        }
        let (reply, rx) = mpsc::channel();
        let now = Instant::now();
        let req = GenRequest {
            prompt,
            n,
            reply,
            enqueued: now,
            deadline: deadline.map(|w| now + w),
        };
        if let Err(mpsc::SendError(req)) = self.tx.send(req) {
            self.depth.fetch_sub(1, Ordering::SeqCst);
            let _ = req.reply.send(Err(XgenError::ServerGone));
        }
        Ok(rx)
    }

    /// Shared admission path of the infallible `generate_*` surface: a
    /// shed becomes the first (and only) item on the stream.
    fn stream_opt(
        &self,
        prompt: Vec<u32>,
        n: usize,
        deadline: Option<Duration>,
    ) -> mpsc::Receiver<Result<u32, XgenError>> {
        match self.enqueue(prompt, n, deadline) {
            Ok(rx) => rx,
            Err(e) => {
                let (reply, rx) = mpsc::channel();
                let _ = reply.send(Err(e));
                rx
            }
        }
    }

    /// Typed-admission variant of [`DecodeServer::generate_stream`]: a
    /// full queue is an immediate `Err(Overloaded)` instead of an error
    /// on the receiver.
    pub fn try_generate_stream(
        &self,
        prompt: Vec<u32>,
        n: usize,
    ) -> Result<mpsc::Receiver<Result<u32, XgenError>>, XgenError> {
        self.enqueue(prompt, n, self.default_deadline)
    }

    /// [`DecodeServer::try_generate_stream`] with client-side backoff: on
    /// an [`XgenError::Overloaded`] shed, sleep per `policy` (seeded by
    /// the server's retry-after hint) and resubmit, up to
    /// `policy.attempts` total attempts; exhausting them yields the typed
    /// [`XgenError::RetryExhausted`]. Non-overload errors abort at once.
    pub fn generate_with_retry(
        &self,
        prompt: Vec<u32>,
        n: usize,
        policy: &RetryPolicy,
    ) -> Result<mpsc::Receiver<Result<u32, XgenError>>, XgenError> {
        retry_loop(policy, || self.enqueue(prompt.clone(), n, self.default_deadline))
    }

    /// Enqueue a generation request; tokens stream over the returned
    /// receiver one by one (an `Err` item ends the stream). Uses the
    /// server's default deadline, if any.
    pub fn generate_stream(
        &self,
        prompt: Vec<u32>,
        n: usize,
    ) -> mpsc::Receiver<Result<u32, XgenError>> {
        self.stream_opt(prompt, n, self.default_deadline)
    }

    /// [`DecodeServer::generate_stream`] with an explicit per-request
    /// deadline.
    pub fn generate_stream_deadline(
        &self,
        prompt: Vec<u32>,
        n: usize,
        deadline: Duration,
    ) -> mpsc::Receiver<Result<u32, XgenError>> {
        self.stream_opt(prompt, n, Some(deadline))
    }

    /// Blocking convenience: drain the stream into a vec.
    pub fn generate(&self, prompt: Vec<u32>, n: usize) -> Result<Vec<u32>, XgenError> {
        let rx = self.generate_stream(prompt, n);
        let mut out = Vec::with_capacity(n);
        for tok in rx {
            out.push(tok?);
        }
        Ok(out)
    }

    /// Deadline-bounded blocking generation: returns the tokens produced
    /// before the stream ended plus the terminating error, if any — a
    /// deadline mid-generation yields the partial prefix and
    /// `Some(DeadlineExceeded)`.
    pub fn generate_with_deadline(
        &self,
        prompt: Vec<u32>,
        n: usize,
        deadline: Duration,
    ) -> (Vec<u32>, Option<XgenError>) {
        let rx = self.generate_stream_deadline(prompt, n, deadline);
        let mut out = Vec::with_capacity(n);
        for tok in rx {
            match tok {
                Ok(t) => out.push(t),
                Err(e) => return (out, Some(e)),
            }
        }
        (out, None)
    }

    pub fn stats(&self) -> DecodeStats {
        lock(&self.stats).clone()
    }
}

impl Drop for DecodeServer {
    fn drop(&mut self) {
        // Closing the channel stops the decode loop after it drains the
        // already-queued requests (buffered mpsc messages survive sender
        // drop), so queued clients get streams, not hangups.
        let (dummy_tx, _) = mpsc::channel();
        let tx = std::mem::replace(&mut self.tx, dummy_tx);
        drop(tx);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
#[allow(deprecated)]
mod tests {
    use super::*;
    use crate::graph::zoo::by_name;
    use crate::util::rng::Rng;
    use std::sync::atomic::AtomicBool;

    #[test]
    fn compiled_server_round_trips_requests() {
        use crate::api::Compiler;
        let single = Compiler::for_model("demo-cnn", 1)
            .unwrap()
            .random_weights(11)
            .compile()
            .unwrap();
        let batched = Compiler::for_model("demo-cnn", 4)
            .unwrap()
            .random_weights(11)
            .compile()
            .unwrap();
        let server =
            Server::start_compiled(single, batched, Duration::from_millis(2)).unwrap();
        let per = 3 * 24 * 24;
        let mut rng = Rng::new(1);
        let rxs: Vec<_> = (0..9)
            .map(|_| server.submit((0..per).map(|_| rng.f32()).collect()))
            .collect();
        for rx in rxs {
            let y = rx.recv().unwrap().unwrap();
            assert_eq!(y.len(), 8);
            assert!(y.iter().all(|v| v.is_finite()));
        }
        let st = server.stats();
        assert_eq!(st.completed, 9);
        assert!(st.batches >= 3);
        assert_eq!(st.errors, 0);
        assert_eq!(st.shed, 0);
    }

    /// The token-streaming decode server: tokens arrive one by one,
    /// multiple requests reuse the session, and results match the
    /// in-process `CompiledModel::generate` exactly.
    #[test]
    fn decode_server_streams_tokens() {
        use crate::api::Compiler;
        let build = || {
            Compiler::for_model("demo-transformer-causal", 1)
                .unwrap()
                .random_weights(31)
                .compile()
                .unwrap()
        };
        let reference = build().generate(&[5, 6, 7], 4).unwrap();
        let server = DecodeServer::start(build(), 16).unwrap();
        // Streamed tokens match the in-process greedy decode.
        let rx = server.generate_stream(vec![5, 6, 7], 4);
        let mut got = Vec::new();
        for tok in rx {
            got.push(tok.unwrap());
        }
        assert_eq!(got, reference);
        // A second request after the first reuses the reset session.
        let again = server.generate(vec![5, 6, 7], 4).unwrap();
        assert_eq!(again, reference);
        // Errors stream too: an over-long prompt fails loudly, and the
        // error is typed.
        let err = server.generate((0..40).collect(), 1).unwrap_err();
        assert_eq!(err.code(), "SeqOverflow");
        assert!(err.to_string().contains("exceeds max_seq"), "got: {err}");
        let st = server.stats();
        assert_eq!(st.requests, 2, "failed prefill must not count");
        assert_eq!(st.tokens, 8);
        assert_eq!(st.errors, 1);
    }

    #[test]
    fn decode_server_rejects_non_decoders_eagerly() {
        use crate::api::Compiler;
        // Encoder attention: refused at start(), not at request time.
        let enc = Compiler::for_model("demo-transformer", 1)
            .unwrap()
            .random_weights(1)
            .compile()
            .unwrap();
        assert!(DecodeServer::start(enc, 8).is_err());
        // Weightless causal model: refused too.
        let weightless = Compiler::for_model("demo-transformer-causal", 1)
            .unwrap()
            .compile()
            .unwrap();
        assert!(DecodeServer::start(weightless, 8).is_err());
    }

    #[test]
    fn compiled_server_rejects_weightless_sessions() {
        use crate::api::Compiler;
        let single = Compiler::for_model("demo-cnn", 1).unwrap().compile().unwrap();
        let batched = Compiler::for_model("demo-cnn", 4).unwrap().compile().unwrap();
        assert!(Server::start_compiled(single, batched, Duration::from_millis(1)).is_err());
    }

    /// An engine that panics on its second call: the dispatcher must
    /// answer that request with `WorkerPanic` and keep serving.
    struct FlakyEngine {
        calls: usize,
    }

    impl BatchEngine for FlakyEngine {
        fn run_single(&mut self, x: &[f32]) -> Result<Vec<f32>> {
            self.calls += 1;
            if self.calls == 2 {
                panic!("injected engine panic (call #2)");
            }
            Ok(x.iter().map(|v| v * 2.0).collect())
        }

        fn run_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            xs.iter().map(|x| self.run_single(x)).collect()
        }
    }

    #[test]
    fn dispatcher_isolates_engine_panics() {
        let server = Server::spawn_engine(
            FlakyEngine { calls: 0 },
            1,
            ServeConfig { max_wait: Duration::ZERO, ..ServeConfig::default() },
        );
        let r1 = server.infer(vec![1.0, 2.0]).unwrap();
        assert_eq!(r1, vec![2.0, 4.0]);
        let e = server.infer(vec![1.0, 2.0]).unwrap_err();
        assert_eq!(e.code(), "WorkerPanic");
        assert!(e.to_string().contains("injected engine panic"), "got: {e}");
        // The server survived the panic: request 3 is bitwise-identical
        // to request 1.
        let r3 = server.infer(vec![1.0, 2.0]).unwrap();
        assert_eq!(r3, r1);
        let st = server.stats();
        assert_eq!(st.worker_panics, 1);
        assert_eq!(st.errors, 1);
        assert_eq!(st.completed, 2);
    }

    /// An engine whose `run_single` blocks on a gate the test holds —
    /// lets the test fill the queue deterministically.
    struct GateEngine {
        gate: Arc<Mutex<()>>,
        entered: Arc<AtomicBool>,
    }

    impl BatchEngine for GateEngine {
        fn run_single(&mut self, x: &[f32]) -> Result<Vec<f32>> {
            self.entered.store(true, Ordering::SeqCst);
            let _g = lock(&self.gate);
            Ok(x.to_vec())
        }

        fn run_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
            xs.iter().map(|x| self.run_single(x)).collect()
        }
    }

    #[test]
    fn full_queue_sheds_with_overloaded() {
        let gate = Arc::new(Mutex::new(()));
        let entered = Arc::new(AtomicBool::new(false));
        let server = Server::spawn_engine(
            GateEngine { gate: gate.clone(), entered: entered.clone() },
            1,
            ServeConfig { max_wait: Duration::ZERO, queue_cap: 2, default_deadline: None },
        );
        let held = gate.lock().unwrap();
        // r1 is dequeued by the dispatcher and blocks inside the engine.
        let r1 = server.submit(vec![1.0]);
        while !entered.load(Ordering::SeqCst) {
            std::thread::yield_now();
        }
        // r2 and r3 fill the queue (cap 2); r4 must shed.
        let r2 = server.submit(vec![2.0]);
        let r3 = server.submit(vec![3.0]);
        let e = server.try_submit(vec![4.0]).unwrap_err();
        assert_eq!(e.code(), "Overloaded");
        // submit() delivers the same typed error through the channel.
        let r5 = server.submit(vec![5.0]);
        assert_eq!(r5.recv().unwrap().unwrap_err().code(), "Overloaded");
        drop(held);
        // Everything admitted completes once the gate opens.
        assert_eq!(r1.recv().unwrap().unwrap(), vec![1.0]);
        assert_eq!(r2.recv().unwrap().unwrap(), vec![2.0]);
        assert_eq!(r3.recv().unwrap().unwrap(), vec![3.0]);
        let st = server.stats();
        assert_eq!(st.shed, 2);
        assert_eq!(st.completed, 3);
    }

    /// Dropping the receiver must not panic or kill the server — the
    /// failed reply send is counted as a cancellation.
    #[test]
    fn dropped_receiver_counts_as_cancellation() {
        struct Echo;
        impl BatchEngine for Echo {
            fn run_single(&mut self, x: &[f32]) -> Result<Vec<f32>> {
                Ok(x.to_vec())
            }
            fn run_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
                Ok(xs.to_vec())
            }
        }
        let server = Server::spawn_engine(
            Echo,
            1,
            ServeConfig { max_wait: Duration::ZERO, ..ServeConfig::default() },
        );
        drop(server.submit(vec![1.0])); // receiver gone before the reply
        // The server is still alive and serving.
        let y = server.infer(vec![2.0]).unwrap();
        assert_eq!(y, vec![2.0]);
        // The dropped request was either cancelled at reply time or (rarely)
        // completed before the drop landed; cancellation is the expected
        // path once the reply send fails.
        let st = server.stats();
        assert_eq!(st.completed + st.cancelled, 2);
        assert!(st.errors == 0);
    }

    /// Dropping the server drains the queue: every already-submitted
    /// request still gets an answer before the dispatcher exits.
    #[test]
    fn drop_drains_queued_requests() {
        struct Echo;
        impl BatchEngine for Echo {
            fn run_single(&mut self, x: &[f32]) -> Result<Vec<f32>> {
                Ok(x.to_vec())
            }
            fn run_batch(&mut self, xs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
                Ok(xs.to_vec())
            }
        }
        let server = Server::spawn_engine(
            Echo,
            4,
            ServeConfig { max_wait: Duration::from_millis(1), ..ServeConfig::default() },
        );
        let rxs: Vec<_> = (0..3).map(|i| server.submit(vec![i as f32])).collect();
        drop(server); // joins the dispatcher after it drains the queue
        for (i, rx) in rxs.into_iter().enumerate() {
            assert_eq!(rx.recv().unwrap().unwrap(), vec![i as f32]);
        }
    }

    #[test]
    fn pipeline_compile_produces_report() {
        let g = by_name("mobilenet-v2", 1);
        let mut rng = Rng::new(201);
        let mut ws = WeightStore::init_random(&g, &mut rng);
        let c = compile(g, Some(&mut ws), PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.3 });
        assert!(c.prune_report.is_some());
        assert!(c.plan.fused_layer_count() > 0);
        let lat = c
            .latency_ms(&crate::cost::devices::s10_cpu(), Framework::XGenFull, DeviceClass::MobileCpu)
            .unwrap();
        assert!(lat > 0.0 && lat < 1000.0);
    }

    #[test]
    fn compile_without_weights_is_structural() {
        let g = by_name("wdsr-b", 1);
        let c = compile(g, None, PruneScheme::None);
        assert!(c.prune_report.is_none());
        assert!(c.rewrite_stats.ops_after <= c.rewrite_stats.ops_before);
    }
}
