//! DNNFusion — universal operator fusion (§2.2.2, Table 1).
//!
//! Instead of matching a fixed pattern list (the TFLite/MNN/TVM approach,
//! modeled in [`crate::baselines`]), fusion legality and profitability are
//! derived from the **mapping-type algebra** in [`crate::graph::ops`]:
//! every operator is classified One-to-One / One-to-Many / Many-to-Many /
//! Reorganize / Shuffle, and any producer→consumer pair whose combination
//! is not a `×` cell of Table 1 is a fusion candidate. Candidates in the
//! *Profile* class are accepted or rejected with a lightweight
//! memory-traffic model (fusing is profitable when it removes more
//! intermediate-tensor traffic than the recompute it might introduce).
//!
//! The output is a [`FusionPlan`]: a partition of the compute nodes into
//! fused groups ("fused layers" in the paper's GPT-2 claim), each with the
//! resulting mapping type of the fused operator.

use std::collections::BTreeSet;

use crate::graph::ops::{fuse_class, fused_mapping, FuseClass, MappingType};
use crate::graph::{Graph, NodeId};

/// One fused group: a set of nodes executed as a single kernel.
#[derive(Debug, Clone)]
pub struct FusedGroup {
    /// Member node ids in topological order. The first Many-to-Many member
    /// (if any) is the group's anchor kernel.
    pub nodes: Vec<NodeId>,
    /// Mapping type of the fused operator (per the Table 1 algebra).
    pub mapping: MappingType,
}

impl FusedGroup {
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }
}

/// A complete fusion plan over a graph.
#[derive(Debug, Clone)]
pub struct FusionPlan {
    pub groups: Vec<FusedGroup>,
    /// Candidate pairs examined / accepted / rejected-by-profile.
    pub candidates: usize,
    pub accepted: usize,
    pub profile_rejected: usize,
}

impl FusionPlan {
    /// Number of fused layers left after fusion (the paper's metric).
    pub fn fused_layer_count(&self) -> usize {
        self.groups.len()
    }

    /// Largest group size.
    pub fn max_group(&self) -> usize {
        self.groups.iter().map(|g| g.len()).max().unwrap_or(0)
    }

    /// Intermediate-tensor bytes eliminated by fusion: every intra-group
    /// producer→consumer edge keeps its tensor in registers/cache.
    pub fn bytes_saved(&self, g: &Graph) -> u64 {
        let mut saved = 0u64;
        for group in &self.groups {
            let members: BTreeSet<NodeId> = group.nodes.iter().copied().collect();
            for &id in &group.nodes {
                for &inp in &g.node(id).inputs {
                    if members.contains(&inp) {
                        saved += g.node(inp).out_elems() * 4;
                    }
                }
            }
        }
        saved
    }
}

/// Fusion configuration.
#[derive(Debug, Clone)]
pub struct FusionConfig {
    /// Accept Profile-class candidates when the saved intermediate bytes
    /// exceed this threshold (bytes). The paper's profiler is replaced by
    /// this traffic model — see DESIGN.md substitutions.
    pub profile_threshold_bytes: u64,
    /// Upper bound on nodes per fused kernel (register pressure guard).
    pub max_group_size: usize,
}

impl Default for FusionConfig {
    fn default() -> Self {
        FusionConfig { profile_threshold_bytes: 16 * 1024, max_group_size: 24 }
    }
}

/// Run DNNFusion over `g`.
///
/// Greedy seed-and-grow, as in the paper: scan compute nodes in topological
/// order; each not-yet-fused node seeds a group, which is grown forward
/// along producer→consumer edges while (a) the Table 1 algebra allows it,
/// (b) the producer's value does not escape the group (no recompute), and
/// (c) every other input of the candidate is already fused into this or
/// an earlier-seeded group (which keeps the flattened group order
/// topological — the property both executors execute by — and implies
/// the group is convex).
pub fn fuse(g: &Graph, cfg: &FusionConfig) -> FusionPlan {
    let users = g.users();
    let mut group_of: Vec<Option<usize>> = vec![None; g.nodes.len()];
    let mut groups: Vec<FusedGroup> = Vec::new();
    let mut candidates = 0usize;
    let mut accepted = 0usize;
    let mut profile_rejected = 0usize;

    for seed in g.compute_nodes() {
        if group_of[seed].is_some() {
            continue;
        }
        let gi = groups.len();
        let mut members = vec![seed];
        let mut mapping = g.node(seed).op.mapping();
        group_of[seed] = Some(gi);

        // Grow forward from the current tail while the tail's single
        // non-weight consumer is fusable.
        let mut tail = seed;
        loop {
            if members.len() >= cfg.max_group_size {
                break;
            }
            // The tail must have exactly one consumer (otherwise its tensor
            // escapes and must be materialized anyway).
            let consumers: Vec<NodeId> = users[tail].clone();
            if consumers.len() != 1 {
                break;
            }
            let next = consumers[0];
            if group_of[next].is_some() || g.node(next).op.is_source() {
                break;
            }
            candidates += 1;
            let next_map = g.node(next).op.mapping();
            let class = fuse_class(mapping, next_map);
            let fusable = match class {
                FuseClass::Never => false,
                FuseClass::Direct => true,
                FuseClass::Profile => {
                    // Traffic model: saved bytes = tail's output tensor.
                    let saved = g.node(tail).out_elems() * 4;
                    let ok = saved >= cfg.profile_threshold_bytes;
                    if !ok {
                        profile_rejected += 1;
                    }
                    ok
                }
            };
            if !fusable {
                break;
            }
            // Order safety (which implies convexity): every non-source
            // input of `next` must already be fused — into this group or
            // into one seeded earlier. Groups execute sorted by first
            // member, and seeds are visited in id order, so an assigned
            // input's group always runs before this one. An *unassigned*
            // input (id > seed, like the position-broadcast feeding a
            // transformer's embedding residual) would land in a
            // later-sorted group and break the flattened topological
            // order the executors require — the old check only rejected
            // cycles, which let those groups form and then fail at run
            // time with "fusion order is not topological".
            let safe = g
                .node(next)
                .inputs
                .iter()
                .all(|&i| group_of[i].is_some() || g.node(i).op.is_source());
            if !safe {
                break;
            }
            mapping = fused_mapping(mapping, next_map).unwrap_or(next_map);
            group_of[next] = Some(gi);
            members.push(next);
            accepted += 1;
            tail = next;
        }
        groups.push(FusedGroup { nodes: members, mapping });
    }

    FusionPlan { groups, candidates, accepted, profile_rejected }
}

/// Fusion-opportunity count: number of producer→consumer pairs of compute
/// nodes whose fusion is *legal* under the Table 1 algebra. The paper's
/// "up to 8.8× higher fusion opportunities" compares this against the
/// fixed-pattern baselines.
pub fn fusion_opportunities(g: &Graph) -> usize {
    let users = g.users();
    let mut count = 0;
    for id in g.compute_nodes() {
        let m = g.node(id).op.mapping();
        for &u in &users[id] {
            if g.node(u).op.is_source() {
                continue;
            }
            if fuse_class(m, g.node(u).op.mapping()) != FuseClass::Never {
                count += 1;
            }
        }
    }
    count
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo::{by_name, nlp, NetBuilder};
    use crate::graph::Act;
    use crate::util::proptest_lite::forall;

    fn plan(g: &Graph) -> FusionPlan {
        fuse(g, &FusionConfig::default())
    }

    #[test]
    fn conv_bn_relu_fuses_into_one_group() {
        let mut b = NetBuilder::new("t", &[1, 3, 16, 16]);
        b.conv_bn_act(8, 3, 1, 1, Act::Relu);
        let g = b.finish();
        let p = plan(&g);
        assert_eq!(p.fused_layer_count(), 1);
        assert_eq!(p.groups[0].len(), 3);
        assert_eq!(p.groups[0].mapping, MappingType::ManyToMany);
    }

    #[test]
    fn two_convs_stay_separate() {
        let mut b = NetBuilder::new("t", &[1, 3, 16, 16]);
        b.conv(8, 3, 1, 1, 1);
        b.conv(8, 3, 1, 1, 1);
        let g = b.finish();
        let p = plan(&g);
        assert_eq!(p.fused_layer_count(), 2, "conv+conv must not fuse (× cell)");
    }

    #[test]
    fn residual_fanout_blocks_greedy_chain() {
        // conv output feeds both a bn chain and a residual add: the conv's
        // tensor escapes, so it cannot be folded into a single consumer.
        let mut b = NetBuilder::new("t", &[1, 4, 8, 8]);
        b.conv(4, 3, 1, 1, 1);
        let c = b.cur();
        b.bn();
        b.act(Act::Relu);
        let t = b.cur();
        b.add_residual(c, t);
        let g = b.finish();
        let p = plan(&g);
        // conv alone; bn+relu+add fused.
        assert_eq!(p.fused_layer_count(), 2);
        let sizes: Vec<usize> = p.groups.iter().map(|gr| gr.len()).collect();
        assert!(sizes.contains(&1) && sizes.contains(&3), "{sizes:?}");
    }

    #[test]
    fn plan_partitions_all_compute_nodes() {
        forall("fusion partitions compute nodes", 8, |rng| {
            let names = ["mobilenet-v2", "wdsr-b", "tinybert", "u-net"];
            let g = by_name(names[rng.below(names.len())], 1);
            let p = plan(&g);
            let mut covered = BTreeSet::new();
            for gr in &p.groups {
                for &n in &gr.nodes {
                    assert!(covered.insert(n), "node {n} in two groups");
                }
            }
            assert_eq!(covered.len(), g.compute_nodes().len());
        });
    }

    #[test]
    fn groups_are_chains_of_existing_edges() {
        let g = by_name("efficientnet-b0", 1);
        let p = plan(&g);
        for gr in &p.groups {
            for w in gr.nodes.windows(2) {
                assert!(
                    g.node(w[1]).inputs.contains(&w[0]),
                    "group member {} not consumer of {}",
                    w[1],
                    w[0]
                );
            }
        }
    }

    /// The flattened group order (groups sorted by first member, members
    /// in chain order) must be topological — the property both executors
    /// run by. The embedding + position-broadcast residual of every
    /// transformer used to break this: the Add joined the embedding's
    /// group while the broadcast (id between seed and Add) landed in a
    /// *later*-sorted group.
    #[test]
    fn flattened_group_order_is_topological() {
        for name in ["demo-transformer", "tinybert", "mobilenet-v2", "u-net"] {
            let g = by_name(name, 1);
            let p = plan(&g);
            let mut order: Vec<usize> = (0..p.groups.len()).collect();
            order.sort_by_key(|&gi| p.groups[gi].nodes[0]);
            let mut done = vec![false; g.nodes.len()];
            for n in &g.nodes {
                if n.op.is_source() {
                    done[n.id] = true;
                }
            }
            for &gi in &order {
                for &id in &p.groups[gi].nodes {
                    for &i in &g.node(id).inputs {
                        assert!(
                            done[i],
                            "{name}: node {id} runs before its input {i} — \
                             flattened fusion order is not topological"
                        );
                    }
                    done[id] = true;
                }
            }
        }
    }

    #[test]
    fn fusion_reduces_layer_count_substantially_on_gpt2() {
        let g = nlp::gpt2_frontend_layers(1, 2);
        let ops = g.operator_count();
        let p = plan(&g);
        assert!(
            p.fused_layer_count() * 2 < ops,
            "expected >2x reduction: {} ops -> {} groups",
            ops,
            p.fused_layer_count()
        );
    }

    #[test]
    fn opportunities_exceed_fixed_pattern_set() {
        // Any conv-bn-act graph has legal pairs beyond {conv+bn, conv+act}.
        let g = by_name("mobilenet-v2", 1);
        assert!(fusion_opportunities(&g) > 100);
    }

    #[test]
    fn bytes_saved_positive_when_fusing() {
        let mut b = NetBuilder::new("t", &[1, 3, 32, 32]);
        b.conv_bn_act(8, 3, 1, 1, Act::Relu);
        let g = b.finish();
        let p = plan(&g);
        assert!(p.bytes_saved(&g) > 0);
    }

    #[test]
    fn max_group_size_respected() {
        let mut b = NetBuilder::new("t", &[1, 8]);
        for _ in 0..40 {
            b.act(Act::Relu);
        }
        let g = b.finish();
        let cfg = FusionConfig { max_group_size: 10, ..Default::default() };
        let p = fuse(&g, &cfg);
        assert!(p.max_group() <= 10);
        assert_eq!(p.fused_layer_count(), 4);
    }
}
