//! Mathematical-property-based computational-graph rewriting (§2.2.1,
//! Fig 9). Strength reduction lifted from scalars to tensor operators:
//! the pass (1) removes unnecessary operations, (2) eliminates redundant
//! intermediate copies, and (3) replaces costly operator combinations with
//! cheaper ones, using associativity / distributivity / commutativity of
//! the underlying linear algebra. Crucially (and unlike TASO-style
//! superoptimizers) the rule set is chosen to *set up the subsequent
//! fusion pass*: movement ops are commuted out of elementwise chains and
//! constant subgraphs are folded so DNNFusion sees longer fusable spans.
//!
//! Rules are applied to fixpoint. When a [`WeightStore`] is supplied the
//! weight-folding rules (BN→conv, dense·dense, conv+conv distributivity)
//! also rewrite the concrete weights so numerics are preserved — the
//! property tests in `rust/tests/pipeline_semantics.rs` check rewritten
//! graphs against the originals on real tensors.

pub mod rules;

use std::collections::BTreeMap;

use crate::graph::{Graph, NodeId, OpKind, WeightStore};

/// Statistics from one rewriting run (per-rule hit counts).
#[derive(Debug, Clone, Default)]
pub struct RewriteStats {
    pub hits: BTreeMap<&'static str, usize>,
    pub ops_before: usize,
    pub ops_after: usize,
}

impl RewriteStats {
    pub fn total_hits(&self) -> usize {
        self.hits.values().sum()
    }
}

/// Configuration: individual rule toggles (ablations flip these).
#[derive(Debug, Clone)]
pub struct RewriteConfig {
    /// Identity elimination (reshape-to-same-shape, upsample ×1, scale-by-1
    /// style no-ops) — "remove unnecessary operations".
    pub eliminate_identity: bool,
    /// Collapse movement-op chains (transpose∘transpose, reshape∘reshape) —
    /// "eliminate redundant intermediate data copies".
    pub collapse_movement: bool,
    /// Associativity: fold adjacent weight-only linear ops (dense·dense,
    /// bn/scale into conv) — "replace costly combinations with cheaper ones".
    pub fold_linear: bool,
    /// Distributivity: conv(x,W1)+conv(x,W2) → conv(x,W1+W2); shared
    /// subexpression discovery on weight-side combos.
    pub distribute: bool,
    /// Commutativity: swap elementwise past movement ops so elementwise
    /// chains stay adjacent to their Many-to-Many producer for fusion.
    pub commute_movement: bool,
    /// Constant subgraph folding (e.g. Sqrt over a weight scalar).
    pub fold_constants: bool,
    /// Maximum fixpoint iterations.
    pub max_passes: usize,
}

impl Default for RewriteConfig {
    fn default() -> Self {
        RewriteConfig {
            eliminate_identity: true,
            collapse_movement: true,
            fold_linear: true,
            distribute: true,
            commute_movement: true,
            fold_constants: true,
            max_passes: 12,
        }
    }
}

impl RewriteConfig {
    /// Everything off — the "no rewriting" baseline.
    pub fn disabled() -> Self {
        RewriteConfig {
            eliminate_identity: false,
            collapse_movement: false,
            fold_linear: false,
            distribute: false,
            commute_movement: false,
            fold_constants: false,
            max_passes: 0,
        }
    }
}

/// Run the rewriting pass over `g` to fixpoint. `ws` (optional) receives
/// the weight-folding updates that keep numerics identical.
pub fn rewrite(g: &mut Graph, mut ws: Option<&mut WeightStore>, cfg: &RewriteConfig) -> RewriteStats {
    let mut stats = RewriteStats {
        ops_before: g.operator_count(),
        ..Default::default()
    };
    for _ in 0..cfg.max_passes {
        let mut changed = 0usize;
        if cfg.fold_constants {
            changed += count(&mut stats, "fold_constants", rules::fold_constants(g, ws.as_deref_mut()));
        }
        if cfg.eliminate_identity {
            changed += count(&mut stats, "eliminate_identity", rules::eliminate_identity(g));
        }
        if cfg.collapse_movement {
            changed += count(&mut stats, "collapse_movement", rules::collapse_movement(g));
        }
        if cfg.commute_movement {
            changed += count(&mut stats, "commute_movement", rules::commute_movement(g));
        }
        if cfg.fold_linear {
            changed += count(&mut stats, "fold_linear", rules::fold_linear(g, ws.as_deref_mut()));
        }
        if cfg.distribute {
            changed += count(&mut stats, "distribute", rules::distribute(g, ws.as_deref_mut()));
        }
        if changed == 0 {
            break;
        }
        g.prune_dead();
    }
    g.prune_dead();
    stats.ops_after = g.operator_count();
    stats
}

fn count(stats: &mut RewriteStats, rule: &'static str, n: usize) -> usize {
    if n > 0 {
        *stats.hits.entry(rule).or_insert(0) += n;
    }
    n
}

/// Redirect every use of `old` to `new` (including graph outputs).
pub(crate) fn replace_uses(g: &mut Graph, old: NodeId, new: NodeId) {
    for n in g.nodes.iter_mut() {
        for i in n.inputs.iter_mut() {
            if *i == old {
                *i = new;
            }
        }
    }
    for o in g.outputs.iter_mut() {
        if *o == old {
            *o = new;
        }
    }
}

/// True if `id` is a weight node.
pub(crate) fn is_weight(g: &Graph, id: NodeId) -> bool {
    matches!(g.node(id).op, OpKind::Weight)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo::{nlp, NetBuilder};
    use crate::graph::Act;

    #[test]
    fn disabled_config_is_identity() {
        let mut g = nlp::gpt2_frontend_layers(1, 1);
        let before = g.operator_count();
        let stats = rewrite(&mut g, None, &RewriteConfig::disabled());
        assert_eq!(g.operator_count(), before);
        assert_eq!(stats.total_hits(), 0);
    }

    #[test]
    fn gpt2_frontend_shrinks_substantially() {
        let mut g = nlp::gpt2_frontend_layers(1, 2);
        let before = g.operator_count();
        let stats = rewrite(&mut g, None, &RewriteConfig::default());
        assert!(g.validate().is_ok(), "{:?}", g.validate());
        assert!(
            g.operator_count() < before,
            "no shrink: {} -> {}",
            before,
            g.operator_count()
        );
        assert!(stats.total_hits() > 0);
        // Output shape must be preserved.
        let out = &g.node(g.outputs[0]).shape;
        assert_eq!(out, &vec![1, 384, 768]);
    }

    #[test]
    fn rewrite_reaches_fixpoint() {
        let mut g = nlp::gpt2_frontend_layers(1, 1);
        rewrite(&mut g, None, &RewriteConfig::default());
        let after1 = g.operator_count();
        let stats2 = rewrite(&mut g, None, &RewriteConfig::default());
        assert_eq!(g.operator_count(), after1, "second run changed the graph");
        assert_eq!(stats2.total_hits(), 0);
    }

    #[test]
    fn plain_cnn_unharmed() {
        // A graph with nothing to rewrite keeps its structure.
        let mut b = NetBuilder::new("cnn", &[1, 3, 16, 16]);
        b.conv(8, 3, 1, 1, 1);
        b.act(Act::Relu);
        b.conv(8, 3, 1, 1, 1);
        let mut g = b.finish();
        let before = g.operator_count();
        rewrite(&mut g, None, &RewriteConfig::default());
        assert_eq!(g.operator_count(), before);
        assert!(g.validate().is_ok());
    }
}
