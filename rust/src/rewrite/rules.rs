//! The individual rewrite rules (Fig 9 of the paper). Each returns the
//! number of sites rewritten in one sweep.
//!
//! All rules mutate nodes *in place* or redirect uses to an earlier node;
//! they never append nodes, which keeps the graph's topological-id
//! invariant intact. Dead nodes are swept by `Graph::prune_dead` between
//! passes.
//!
//! Numerics: rules that fold weights take an optional [`WeightStore`]. With
//! a store, folds update the concrete tensors so the rewritten graph is
//! bit-compatible up to float reassociation; without one (structural mode,
//! used by op-count benches) folds still apply but numeric equivalence is
//! not claimed.

use crate::graph::{Act, Graph, MappingType, NodeId, OpKind, WeightStore};

use super::{is_weight, replace_uses};

/// Rule 1 ("remove unnecessary operations"): bypass structural no-ops.
pub fn eliminate_identity(g: &mut Graph) -> usize {
    let mut hits = 0;
    for id in 0..g.nodes.len() {
        let n = &g.nodes[id];
        let bypass = match &n.op {
            OpKind::Reshape | OpKind::Flatten | OpKind::Pad { .. } | OpKind::Slice { .. } => {
                n.inputs.len() == 1 && g.nodes[n.inputs[0]].shape == n.shape
            }
            OpKind::Transpose { perm } => {
                n.inputs.len() == 1 && perm.iter().enumerate().all(|(i, &p)| i == p)
            }
            OpKind::Upsample { r: 1 } => true,
            OpKind::Scale { mul, add } => {
                n.inputs.len() == 1 && *mul == 1.0 && *add == 0.0
            }
            OpKind::Pow { e } => n.inputs.len() == 1 && *e == 1.0,
            _ => false,
        };
        if bypass {
            let src = g.nodes[id].inputs[0];
            replace_uses(g, id, src);
            hits += 1;
        }
    }
    hits
}

/// Rule 2 ("eliminate redundant intermediate copies"): collapse chains of
/// movement ops. `transpose∘transpose` that restores the original shape is
/// treated as identity; `reshape∘reshape` (and flatten variants) keep only
/// the outer op.
pub fn collapse_movement(g: &mut Graph) -> usize {
    let mut hits = 0;
    let users = g.users();
    for id in 0..g.nodes.len() {
        let n = &g.nodes[id];
        if n.inputs.len() != 1 {
            continue;
        }
        let p = n.inputs[0];
        let parent = &g.nodes[p];
        match (&parent.op, &n.op) {
            // transpose(transpose(x)) == x when the composed permutation is
            // the identity. (The old shape-round-trip test was both too
            // weak — equal dims can round-trip the shape without restoring
            // the layout — and is now unnecessary: perms are explicit.)
            (OpKind::Transpose { perm: p1 }, OpKind::Transpose { perm: p2 })
                if users[p].len() == 1
                    && p1.len() == p2.len()
                    && p2.iter().enumerate().all(|(i, &o)| p1[o] == i) =>
            {
                let src = parent.inputs[0];
                replace_uses(g, id, src);
                hits += 1;
            }
            // Non-inverse transpose chains compose into one transpose of
            // the combined permutation (the GPT-2 frontend's head-split
            // transpose feeding the K^T transpose is the motivating case).
            (OpKind::Transpose { perm: p1 }, OpKind::Transpose { perm: p2 })
                if users[p].len() == 1 && p1.len() == p2.len() =>
            {
                let combined: Vec<usize> = p2.iter().map(|&o| p1[o]).collect();
                let src = parent.inputs[0];
                g.nodes[id].op = OpKind::Transpose { perm: combined };
                g.nodes[id].inputs[0] = src;
                hits += 1;
            }
            // reshape/flatten chains: retarget the outer one.
            (
                OpKind::Reshape | OpKind::Flatten,
                OpKind::Reshape | OpKind::Flatten,
            ) if users[p].len() == 1 => {
                let src = parent.inputs[0];
                g.nodes[id].inputs[0] = src;
                hits += 1;
            }
            _ => {}
        }
    }
    hits
}

/// Rule 3 (commutativity): swap a unary elementwise op below a movement op
/// (`act(reorganize(x))` → `reorganize(act(x))`) so the elementwise op sits
/// next to its Many-to-Many producer for the fusion pass.
pub fn commute_movement(g: &mut Graph) -> usize {
    let mut hits = 0;
    let users = g.users();
    for id in 0..g.nodes.len() {
        // `id` is the elementwise op, its parent the movement op.
        let n = &g.nodes[id];
        let elementwise_unary = matches!(
            n.op,
            OpKind::Activation(_) | OpKind::Scale { .. } | OpKind::Pow { .. } | OpKind::Sqrt
        ) && n.inputs.len() == 1;
        if !elementwise_unary {
            continue;
        }
        let p = n.inputs[0];
        let parent = &g.nodes[p];
        let movement_unary = matches!(
            parent.op,
            OpKind::Reshape | OpKind::Transpose { .. } | OpKind::Flatten
        ) && parent.inputs.len() == 1;
        if !movement_unary || users[p].len() != 1 {
            continue;
        }
        // Only profitable when the movement op's producer is compute
        // (ManyToMany or OneToOne) — then E lands adjacent to it.
        let gp = parent.inputs[0];
        let gp_map = g.nodes[gp].op.mapping();
        if !matches!(gp_map, MappingType::ManyToMany | MappingType::OneToOne) {
            continue;
        }
        // Swap: parent becomes E over gp (gp's shape); node becomes the
        // movement op with the original output shape.
        let e_op = g.nodes[id].op.clone();
        let m_op = g.nodes[p].op.clone();
        let gp_shape = g.nodes[gp].shape.clone();
        g.nodes[p].op = e_op;
        g.nodes[p].shape = gp_shape;
        g.nodes[id].op = m_op;
        hits += 1;
    }
    hits
}

/// Rule 4 (constant folding / strength reduction on constants):
/// * unary math over a weight → folded into the weight;
/// * `Div(x, c)` / `Mul(x, c)` with broadcast-constant `c` → `Scale` —
///   the Fig 9(c) commutative example (division turned into a cheaper
///   multiply whose constant is precomputed).
pub fn fold_constants(g: &mut Graph, mut ws: Option<&mut WeightStore>) -> usize {
    let mut hits = 0;
    let users = g.users();
    for id in 0..g.nodes.len() {
        let n = g.nodes[id].clone();
        match n.op {
            // sqrt/pow/scale over a weight: fold into the weight tensor.
            OpKind::Sqrt | OpKind::Pow { .. } | OpKind::Scale { .. }
                if n.inputs.len() == 1
                    && is_weight(g, n.inputs[0])
                    && users[n.inputs[0]].len() == 1 =>
            {
                let wid = n.inputs[0];
                let f = |x: f32| -> f32 {
                    match n.op {
                        // IEEE semantics: sqrt of a negative is NaN,
                        // matching the executor kernel — the old clamp
                        // silently hid bad constants.
                        OpKind::Sqrt => x.sqrt(),
                        OpKind::Pow { e } => x.powf(e as f32),
                        OpKind::Scale { mul, add } => x * mul as f32 + add as f32,
                        _ => unreachable!(),
                    }
                };
                let wname = g.nodes[wid].name.clone();
                // Keep the graph-constant record in sync with the fold —
                // a later structural rewrite or weight re-init must see
                // the folded value, not the original.
                if let Some(&v) = g.consts.get(&wname) {
                    g.consts.insert(wname.clone(), f(v));
                }
                if let Some(ws) = ws.as_deref_mut() {
                    if let Some(t) = ws.get(&wname).cloned() {
                        ws.set(&wname, t.map(f));
                    }
                }
                replace_uses(g, id, wid);
                hits += 1;
            }
            // Div/Mul by a (broadcast) scalar constant → Scale.
            OpKind::Div | OpKind::Mul if n.inputs.len() == 2 => {
                let (x, c) = (n.inputs[0], n.inputs[1]);
                // Constant side: a weight, or a broadcast of a weight.
                let const_scalar = resolve_scalar_const(g, c, ws.as_deref());
                if let Some(v) = const_scalar {
                    let mul = if matches!(n.op, OpKind::Div) {
                        1.0 / v
                    } else {
                        v
                    };
                    g.nodes[id].op = OpKind::Scale { mul, add: 0.0 };
                    g.nodes[id].inputs = vec![x];
                    hits += 1;
                }
            }
            _ => {}
        }
    }
    hits
}

/// If `id` denotes a scalar constant (a 1-element weight, possibly behind a
/// Broadcast), return its value (1.0 in structural mode without a store).
fn resolve_scalar_const(g: &Graph, id: NodeId, ws: Option<&WeightStore>) -> Option<f64> {
    let base = match &g.nodes[id].op {
        OpKind::Broadcast => g.nodes[id].inputs.first().copied()?,
        _ => id,
    };
    let n = &g.nodes[base];
    if !matches!(n.op, OpKind::Weight) || n.out_elems() != 1 {
        return None;
    }
    match ws {
        Some(ws) => ws.get(&n.name).map(|t| t.data()[0] as f64),
        // Structural mode: graph constants keep their baked value; for
        // anything else the value does not matter for op counting.
        None => Some(g.consts.get(&n.name).copied().unwrap_or(1.0) as f64),
    }
}

/// Rule 5 (associativity / "replace costly combinations with cheaper
/// ones"):
/// * `dense(dense(x, W1), W2)` → `dense(x, W1·W2)`;
/// * `scale(conv/dense(x, W))` → conv/dense with scaled weights;
/// * recognize the decomposed tanh-GELU subgraph and replace it with the
///   fused `Activation(Gelu)` operator.
pub fn fold_linear(g: &mut Graph, mut ws: Option<&mut WeightStore>) -> usize {
    let mut hits = 0;
    let users = g.users();

    for id in 0..g.nodes.len() {
        let n = g.nodes[id].clone();
        match n.op {
            // dense(dense(x)) fold.
            OpKind::Dense => {
                let Some(x) = g.data_input(id) else { continue };
                let inner = &g.nodes[x];
                if !matches!(inner.op, OpKind::Dense) || users[x].len() != 1 {
                    continue;
                }
                let (Some(w2id), Some(w1id)) = (weight_input(g, id), weight_input(g, x)) else {
                    continue;
                };
                if users[w2id].len() != 1 || users[w1id].len() != 1 {
                    continue;
                }
                let Some(src) = g.data_input(x) else { continue };
                let in_f = *g.nodes[src].shape.last().unwrap();
                let out_f = *g.nodes[id].shape.last().unwrap();
                if let Some(ws) = ws.as_deref_mut() {
                    let n1 = g.nodes[w1id].name.clone();
                    let n2 = g.nodes[w2id].name.clone();
                    if let (Some(w1), Some(w2)) = (ws.get(&n1).cloned(), ws.get(&n2).cloned()) {
                        ws.set(&n2, w1.matmul(&w2));
                    }
                }
                g.nodes[w2id].shape = vec![in_f, out_f];
                g.nodes[id].inputs = vec![src, w2id];
                hits += 1;
            }
            // scale(conv|dense) fold into the producer's weights.
            OpKind::Scale { mul, add } => {
                if n.inputs.len() != 1 || add != 0.0 {
                    continue;
                }
                let p = n.inputs[0];
                let parent = &g.nodes[p];
                let foldable = matches!(parent.op, OpKind::Conv2d { .. } | OpKind::Dense);
                if !foldable || users[p].len() != 1 {
                    continue;
                }
                let Some(wid) = weight_input(g, p) else { continue };
                if users[wid].len() != 1 {
                    continue;
                }
                if let Some(ws) = ws.as_deref_mut() {
                    let wname = g.nodes[wid].name.clone();
                    if let Some(t) = ws.get(&wname).cloned() {
                        ws.set(&wname, t.scale(mul as f32));
                    }
                }
                replace_uses(g, id, p);
                hits += 1;
            }
            // GELU recognition: Scale{0.5}(Mul(x, Scale{1,+1}(Tanh(...x...)))).
            OpKind::Mul if n.inputs.len() == 2 => {
                if let Some(x) = match_decomposed_gelu(g, id, &users) {
                    // The Mul's single user is the trailing Scale{0.5}; keep
                    // that node's identity, morph it into Gelu over x...
                    // unless the 0.5 sits elsewhere — we morph the Mul into
                    // Gelu and let identity-elimination clean a trailing
                    // Scale{1,0} if the caller folded 0.5 differently.
                    let trailing = users[id]
                        .iter()
                        .copied()
                        .find(|&u| matches!(g.nodes[u].op, OpKind::Scale { mul, add } if mul == 0.5 && add == 0.0));
                    if let Some(tr) = trailing {
                        let shape = g.nodes[x].shape.clone();
                        g.nodes[tr].op = OpKind::Activation(Act::Gelu);
                        g.nodes[tr].inputs = vec![x];
                        g.nodes[tr].shape = shape;
                        hits += 1;
                    }
                }
            }
            _ => {}
        }
    }
    hits
}

/// Match `Mul(x, Scale{1,+1}(Tanh(Scale{c1}(Add(x, Scale{c2}(Pow{3}(x)))))))`
/// rooted at the Mul node `id`; returns `x` on success.
fn match_decomposed_gelu(g: &Graph, id: NodeId, users: &[Vec<NodeId>]) -> Option<NodeId> {
    let n = &g.nodes[id];
    let (a, bnode) = (n.inputs[0], n.inputs[1]);
    // One side is x, the other the gate chain ending in Scale{1,+1}.
    for (x, gate) in [(a, bnode), (bnode, a)] {
        let gn = &g.nodes[gate];
        if !matches!(gn.op, OpKind::Scale { mul, add } if mul == 1.0 && add == 1.0) {
            continue;
        }
        if users[gate].len() != 1 || gn.inputs.len() != 1 {
            continue;
        }
        let tanh = gn.inputs[0];
        if !matches!(g.nodes[tanh].op, OpKind::Activation(Act::Tanh)) {
            continue;
        }
        let sc1 = g.nodes[tanh].inputs[0];
        if !matches!(g.nodes[sc1].op, OpKind::Scale { .. }) {
            continue;
        }
        let add = g.nodes[sc1].inputs[0];
        if !matches!(g.nodes[add].op, OpKind::Add) {
            continue;
        }
        let (u, v) = (g.nodes[add].inputs[0], g.nodes[add].inputs[1]);
        for (xx, cubic_scaled) in [(u, v), (v, u)] {
            if xx != x {
                continue;
            }
            if !matches!(g.nodes[cubic_scaled].op, OpKind::Scale { .. }) {
                continue;
            }
            let pow = g.nodes[cubic_scaled].inputs[0];
            if matches!(g.nodes[pow].op, OpKind::Pow { e } if e == 3.0)
                && g.nodes[pow].inputs[0] == x
            {
                return Some(x);
            }
        }
    }
    None
}

/// Rule 6 (distributivity, Fig 9(b)): `add(conv(x,W1), conv(x,W2))` with
/// identical hyper-parameters → `conv(x, W1+W2)`; same for Dense.
pub fn distribute(g: &mut Graph, mut ws: Option<&mut WeightStore>) -> usize {
    let mut hits = 0;
    let users = g.users();
    for id in 0..g.nodes.len() {
        let n = g.nodes[id].clone();
        if !matches!(n.op, OpKind::Add) || n.inputs.len() != 2 {
            continue;
        }
        let (l, r) = (n.inputs[0], n.inputs[1]);
        if l == r {
            continue;
        }
        let (ln, rn) = (&g.nodes[l], &g.nodes[r]);
        let same_kind = match (&ln.op, &rn.op) {
            (OpKind::Conv2d { .. }, OpKind::Conv2d { .. }) => ln.op == rn.op,
            (OpKind::Dense, OpKind::Dense) => true,
            _ => false,
        };
        if !same_kind || users[l].len() != 1 || users[r].len() != 1 {
            continue;
        }
        let (Some(xl), Some(xr)) = (g.data_input(l), g.data_input(r)) else {
            continue;
        };
        if xl != xr {
            continue;
        }
        let (Some(w1), Some(w2)) = (weight_input(g, l), weight_input(g, r)) else {
            continue;
        };
        if users[w1].len() != 1 || users[w2].len() != 1 {
            continue;
        }
        if g.nodes[w1].shape != g.nodes[w2].shape {
            continue;
        }
        if let Some(ws) = ws.as_deref_mut() {
            let n1 = g.nodes[w1].name.clone();
            let n2 = g.nodes[w2].name.clone();
            if let (Some(t1), Some(t2)) = (ws.get(&n1).cloned(), ws.get(&n2)) {
                let sum = t1.add(t2);
                ws.set(&n1, sum);
            }
        }
        replace_uses(g, id, l);
        hits += 1;
    }
    hits
}

/// The weight input of node `id`, if any.
fn weight_input(g: &Graph, id: NodeId) -> Option<NodeId> {
    g.nodes[id]
        .inputs
        .iter()
        .copied()
        .find(|&i| matches!(g.nodes[i].op, OpKind::Weight))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo::NetBuilder;
    use crate::tensor::Tensor;
    use crate::graph::Act;
    use crate::util::rng::Rng;

    #[test]
    fn identity_reshape_removed() {
        let mut g = Graph::new("t");
        let x = g.input("x", &[2, 8]);
        let r = g.add("rs", OpKind::Reshape, vec![x], vec![2, 8]);
        let s = g.add("sqrt", OpKind::Sqrt, vec![r], vec![2, 8]);
        g.outputs = vec![s];
        assert_eq!(eliminate_identity(&mut g), 1);
        g.prune_dead();
        assert_eq!(g.operator_count(), 1);
    }

    #[test]
    fn double_transpose_removed() {
        let mut g = Graph::new("t");
        let x = g.input("x", &[2, 3, 4]);
        let t1 = g.add("t1", OpKind::Transpose { perm: vec![2, 1, 0] }, vec![x], vec![4, 3, 2]);
        let t2 = g.add("t2", OpKind::Transpose { perm: vec![2, 1, 0] }, vec![t1], vec![2, 3, 4]);
        let s = g.add("sqrt", OpKind::Sqrt, vec![t2], vec![2, 3, 4]);
        g.outputs = vec![s];
        assert_eq!(collapse_movement(&mut g), 1);
        g.prune_dead();
        assert_eq!(g.operator_count(), 1);
    }

    #[test]
    fn transpose_chain_composes_into_one() {
        // Head-split [0,2,1,3] then K^T [0,1,3,2] → single [0,2,3,1].
        let mut g = Graph::new("t");
        let x = g.input("x", &[2, 3, 4, 5]);
        let t1 = g.add("t1", OpKind::Transpose { perm: vec![0, 2, 1, 3] }, vec![x], vec![2, 4, 3, 5]);
        let t2 = g.add("t2", OpKind::Transpose { perm: vec![0, 1, 3, 2] }, vec![t1], vec![2, 4, 5, 3]);
        g.outputs = vec![t2];
        assert_eq!(collapse_movement(&mut g), 1);
        g.prune_dead();
        assert_eq!(g.operator_count(), 1);
        let out = g.node(g.outputs[0]);
        assert!(
            matches!(out.op, OpKind::Transpose { ref perm } if perm == &vec![0, 2, 3, 1]),
            "composed perm wrong: {:?}",
            out.op
        );
        assert!(g.validate().is_ok(), "{:?}", g.validate());
    }

    #[test]
    fn reshape_chain_collapses() {
        let mut g = Graph::new("t");
        let x = g.input("x", &[2, 12]);
        let r1 = g.add("r1", OpKind::Reshape, vec![x], vec![2, 3, 4]);
        let r2 = g.add("r2", OpKind::Reshape, vec![r1], vec![6, 4]);
        g.outputs = vec![r2];
        assert_eq!(collapse_movement(&mut g), 1);
        g.prune_dead();
        assert_eq!(g.operator_count(), 1);
        assert_eq!(g.node(g.outputs[0]).shape, vec![6, 4]);
    }

    #[test]
    fn commute_act_past_reshape() {
        let mut b = NetBuilder::new("t", &[1, 4, 4, 4]);
        b.conv(4, 3, 1, 1, 1);
        b.flatten();
        b.act(Act::Relu);
        let mut g = b.finish();
        assert_eq!(commute_movement(&mut g), 1);
        // Now conv -> relu -> flatten.
        let out = g.outputs[0];
        assert!(matches!(g.node(out).op, OpKind::Flatten));
        let relu = g.node(out).inputs[0];
        assert!(matches!(g.node(relu).op, OpKind::Activation(Act::Relu)));
        assert_eq!(g.node(relu).shape, vec![1, 4, 4, 4]);
        assert!(g.validate().is_ok());
    }

    #[test]
    fn div_by_broadcast_const_becomes_scale() {
        let mut g = Graph::new("t");
        let x = g.input("x", &[2, 4]);
        let c = g.weight("c", &[1]);
        let bc = g.add("bc", OpKind::Broadcast, vec![c], vec![2, 4]);
        let d = g.add("div", OpKind::Div, vec![x, bc], vec![2, 4]);
        g.outputs = vec![d];
        let mut ws = WeightStore::new();
        ws.set("c", Tensor::from_vec(&[1], vec![4.0]));
        assert_eq!(fold_constants(&mut g, Some(&mut ws)), 1);
        g.prune_dead();
        let out = g.node(g.outputs[0]);
        match out.op {
            OpKind::Scale { mul, add } => {
                assert!((mul - 0.25).abs() < 1e-12);
                assert_eq!(add, 0.0);
            }
            ref other => panic!("expected scale, got {other:?}"),
        }
    }

    #[test]
    fn dense_dense_folds_with_weights() {
        let mut b = NetBuilder::new("t", &[1, 8]);
        b.dense(16);
        b.dense(4);
        let mut g = b.finish();
        let mut rng = Rng::new(5);
        let mut ws = WeightStore::init_random(&g, &mut rng);
        assert_eq!(fold_linear(&mut g, Some(&mut ws)), 1);
        g.prune_dead();
        assert_eq!(g.operator_count(), 1);
        // Folded weight has shape [8, 4].
        let wnode = g.nodes.iter().find(|n| matches!(n.op, OpKind::Weight)).unwrap();
        assert_eq!(wnode.shape, vec![8, 4]);
        assert_eq!(ws.expect(&wnode.name).shape(), &[8, 4]);
    }

    #[test]
    fn gelu_chain_recognized() {
        use crate::graph::zoo::nlp;
        let mut g = nlp::gpt2_frontend_layers(1, 1);
        let before_gelu = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, OpKind::Activation(Act::Gelu)))
            .count();
        assert_eq!(before_gelu, 0);
        fold_linear(&mut g, None);
        g.prune_dead();
        let after_gelu = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, OpKind::Activation(Act::Gelu)))
            .count();
        assert_eq!(after_gelu, 1, "decomposed GELU not recognized");
        assert!(g.validate().is_ok());
    }

    #[test]
    fn distribute_merges_sibling_convs() {
        let mut g = Graph::new("t");
        let x = g.input("x", &[1, 3, 8, 8]);
        let w1 = g.weight("w1", &[4, 3, 3, 3]);
        let w2 = g.weight("w2", &[4, 3, 3, 3]);
        let op = OpKind::Conv2d { k: 3, stride: 1, pad: 1, groups: 1 };
        let c1 = g.add("c1", op.clone(), vec![x, w1], vec![1, 4, 8, 8]);
        let c2 = g.add("c2", op, vec![x, w2], vec![1, 4, 8, 8]);
        let a = g.add("add", OpKind::Add, vec![c1, c2], vec![1, 4, 8, 8]);
        g.outputs = vec![a];
        let mut ws = WeightStore::new();
        ws.set("w1", Tensor::full(&[4, 3, 3, 3], 1.0));
        ws.set("w2", Tensor::full(&[4, 3, 3, 3], 2.0));
        assert_eq!(distribute(&mut g, Some(&mut ws)), 1);
        g.prune_dead();
        assert_eq!(g.operator_count(), 1);
        assert_eq!(ws.expect("w1").data()[0], 3.0);
    }
}
