//! Deep reuse (§2.3.2, Figs 11–12): exploit similarity among **neuron
//! vectors** — consecutive segments of the input/activation map — so one
//! dot product's result is reused for every similar vector in its cluster.
//!
//! Implementation follows the cited papers (Ning & Shen, ICS'19/ICDE'19):
//! the im2col patch matrix `X [rows, cols]` is split column-wise into
//! sub-vectors of length `l`; each sub-vector is hashed with `h` random
//! hyperplanes (LSH); rows falling in the same bucket share a centroid,
//! and the GEMM `X·W` is computed on centroids only, then scattered back:
//!
//! ```text
//! X·W  ≈  G · (C·W)      G = cluster membership, C = centroids
//! ```
//!
//! Cost drops from `rows·cols·n` to `clusters·cols·n (+ hashing)`; the
//! reuse ratio `rows/clusters` is the paper's knob: ~2× savings at <5e-4
//! accuracy loss (benchmarked in `benches/deepreuse.rs`).

use crate::tensor::gemm::{gemm, GemmConfig};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Deep-reuse configuration.
#[derive(Debug, Clone, Copy)]
pub struct ReuseConfig {
    /// Neuron-vector (sub-vector) length; columns are processed in chunks
    /// of this size.
    pub vec_len: usize,
    /// LSH hyperplanes per chunk (bucket id bits).
    pub hash_bits: usize,
    /// Seed for the hyperplanes (deterministic).
    pub seed: u64,
    /// Outlier control (the *adaptive* deep-reuse knob, Ning & Shen
    /// ICDE'19): a member whose L2 distance from its cluster centroid
    /// exceeds `max_rel_dev × ‖segment‖` is computed exactly instead of
    /// reusing the centroid result. Bounds the approximation error at the
    /// cost of some savings; set to `f32::INFINITY` to disable.
    pub max_rel_dev: f32,
}

impl Default for ReuseConfig {
    fn default() -> Self {
        ReuseConfig { vec_len: 8, hash_bits: 6, seed: 0xDEE9_0001, max_rel_dev: 0.25 }
    }
}

/// Statistics of one deep-reuse GEMM.
#[derive(Debug, Clone, Default)]
pub struct ReuseStats {
    pub rows: usize,
    pub chunks: usize,
    /// Total clusters across chunks (Σ per-chunk cluster count).
    pub clusters: usize,
    /// MACs actually executed (centroid GEMM).
    pub macs_done: u64,
    /// MACs a dense GEMM would execute.
    pub macs_dense: u64,
}

impl ReuseStats {
    /// rows·chunks / clusters — how many vectors share one computation.
    pub fn reuse_ratio(&self) -> f64 {
        if self.clusters == 0 {
            return 1.0;
        }
        (self.rows * self.chunks) as f64 / self.clusters as f64
    }

    /// Fraction of dense MACs avoided.
    pub fn savings(&self) -> f64 {
        if self.macs_dense == 0 {
            return 0.0;
        }
        1.0 - self.macs_done as f64 / self.macs_dense as f64
    }
}

/// Compute `x · w` (`[rows, cols] x [cols, n]`) with LSH-clustered reuse.
pub fn reuse_gemm(x: &Tensor, w: &Tensor, cfg: &ReuseConfig) -> (Tensor, ReuseStats) {
    assert_eq!(x.rank(), 2);
    assert_eq!(w.rank(), 2);
    let (rows, cols) = (x.shape()[0], x.shape()[1]);
    let (cols2, n) = (w.shape()[0], w.shape()[1]);
    assert_eq!(cols, cols2);
    let l = cfg.vec_len.min(cols).max(1);
    let mut out = Tensor::zeros(&[rows, n]);
    let mut stats = ReuseStats {
        rows,
        macs_dense: (rows * cols * n) as u64,
        ..Default::default()
    };
    let mut rng = Rng::new(cfg.seed);

    let mut c0 = 0usize;
    while c0 < cols {
        let cw = l.min(cols - c0);
        stats.chunks += 1;
        // Random hyperplanes for this chunk.
        let planes: Vec<Vec<f32>> = (0..cfg.hash_bits)
            .map(|_| rng.normal_vec(cw, 0.0, 1.0))
            .collect();
        // Bucket rows by LSH signature.
        let mut buckets: std::collections::HashMap<u64, Vec<usize>> =
            std::collections::HashMap::new();
        for r in 0..rows {
            let seg = &x.data()[r * cols + c0..r * cols + c0 + cw];
            let mut sig = 0u64;
            for (bi, p) in planes.iter().enumerate() {
                let dot: f32 = seg.iter().zip(p).map(|(a, b)| a * b).sum();
                if dot >= 0.0 {
                    sig |= 1 << bi;
                }
            }
            buckets.entry(sig).or_default().push(r);
        }
        stats.clusters += buckets.len();
        // Centroid per bucket, then ONE blocked GEMM over all centroids of
        // the chunk — [clusters, cw] x [cw, n] through the tiled engine —
        // instead of a scalar loop per centroid. The weight panel
        // w[c0..c0+cw, :] is already contiguous in the row-major store.
        let nb = buckets.len();
        let mut centroids = vec![0.0f32; nb * cw];
        let mut member_lists: Vec<Vec<usize>> = Vec::with_capacity(nb);
        for (bi, (_, members)) in buckets.into_iter().enumerate() {
            let cent = &mut centroids[bi * cw..(bi + 1) * cw];
            for &r in &members {
                let seg = &x.data()[r * cols + c0..r * cols + c0 + cw];
                for (c, &v) in cent.iter_mut().zip(seg) {
                    *c += v;
                }
            }
            let inv = 1.0 / members.len() as f32;
            for c in cent.iter_mut() {
                *c *= inv;
            }
            member_lists.push(members);
        }
        let wpanel = &w.data()[c0 * n..(c0 + cw) * n];
        let mut partials = vec![0.0f32; nb * n];
        gemm(nb, cw, n, &centroids, wpanel, &mut partials, &GemmConfig::default());
        stats.macs_done += (nb * cw * n) as u64;
        // Scatter centroid results to members (outliers computed exactly).
        for (bi, members) in member_lists.iter().enumerate() {
            let centroid = &centroids[bi * cw..(bi + 1) * cw];
            let partial = &partials[bi * n..(bi + 1) * n];
            for &r in members {
                let seg = &x.data()[r * cols + c0..r * cols + c0 + cw];
                // Adaptive outlier check: exact compute for far members.
                let (mut d2, mut s2) = (0.0f32, 0.0f32);
                for (&v, &c) in seg.iter().zip(centroid) {
                    d2 += (v - c) * (v - c);
                    s2 += v * v;
                }
                let orow = &mut out.data_mut()[r * n..(r + 1) * n];
                if members.len() > 1 && d2 > (cfg.max_rel_dev * cfg.max_rel_dev) * s2.max(1e-12)
                {
                    // Exact path for this member.
                    for (i, &v) in seg.iter().enumerate() {
                        if v == 0.0 {
                            continue;
                        }
                        let wrow = &w.data()[(c0 + i) * n..(c0 + i + 1) * n];
                        for (o, &wv) in orow.iter_mut().zip(wrow) {
                            *o += v * wv;
                        }
                    }
                    stats.macs_done += (cw * n) as u64;
                } else {
                    for (o, &p) in orow.iter_mut().zip(partial) {
                        *o += p;
                    }
                }
            }
        }
        c0 += cw;
    }
    // Hashing cost, charged as MACs.
    stats.macs_done += (rows * cols * cfg.hash_bits) as u64 / 1;
    (out, stats)
}

/// Deep-reuse convolution: im2col + [`reuse_gemm`] (the paper's CNN use).
/// Thin wrapper over [`reuse_conv2d_pre`] that transposes the OIHW weight
/// per call; the compiled path caches the transpose at compile time.
pub fn reuse_conv2d(
    input: &Tensor,
    weight: &Tensor,
    stride: usize,
    pad: usize,
    cfg: &ReuseConfig,
) -> (Tensor, ReuseStats) {
    let (kh, kw) = (weight.shape()[2], weight.shape()[3]);
    let wt = crate::tensor::conv_weight_matrix(weight); // [i*kh*kw, o]
    reuse_conv2d_pre(input, &wt, kh, kw, stride, pad, cfg)
}

/// [`reuse_conv2d`] with the transposed weight matrix `wt = [i*kh*kw, o]`
/// supplied by the caller — the `PackedWeights` side table builds it once
/// at `Compiler::compile` time, removing the per-call OIHW re-transpose
/// from the deep-reuse inference path.
pub fn reuse_conv2d_pre(
    input: &Tensor,
    wt: &Tensor,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    cfg: &ReuseConfig,
) -> (Tensor, ReuseStats) {
    let (n, c, h, w) = (
        input.shape()[0],
        input.shape()[1],
        input.shape()[2],
        input.shape()[3],
    );
    assert_eq!(wt.shape()[0], c * kh * kw, "reuse conv weight matrix mismatch");
    let o = wt.shape()[1];
    let oh = (h + 2 * pad - kh) / stride + 1;
    let ow = (w + 2 * pad - kw) / stride + 1;
    let patches = input.im2col(kh, kw, stride, pad); // [n*oh*ow, i*kh*kw]
    let (y, stats) = reuse_gemm(&patches, wt, cfg);
    // [n*oh*ow, o] -> [n, o, oh, ow]
    let mut out = Tensor::zeros(&[n, o, oh, ow]);
    for b in 0..n {
        for f in 0..o {
            for yy in 0..oh {
                for xx in 0..ow {
                    let row = (b * oh + yy) * ow + xx;
                    out.set(&[b, f, yy, xx], y.at(&[row, f]));
                }
            }
        }
    }
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::forall;

    /// Inputs with repeated rows (the similarity deep reuse exploits).
    fn clustered_input(rng: &mut Rng, rows: usize, cols: usize, protos: usize) -> Tensor {
        let base: Vec<Vec<f32>> = (0..protos)
            .map(|_| rng.normal_vec(cols, 0.0, 1.0))
            .collect();
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows {
            let p = &base[rng.below(protos)];
            // Small jitter around the prototype.
            data.extend(p.iter().map(|&v| v + rng.normal_f32(0.0, 0.01)));
        }
        Tensor::from_vec(&[rows, cols], data)
    }

    #[test]
    fn exact_on_identical_rows() {
        let mut rng = Rng::new(61);
        let row = rng.normal_vec(16, 0.0, 1.0);
        let mut data = Vec::new();
        for _ in 0..8 {
            data.extend(&row);
        }
        let x = Tensor::from_vec(&[8, 16], data);
        let w = Tensor::randn(&[16, 4], 1.0, &mut rng);
        let (y, stats) = reuse_gemm(&x, &w, &ReuseConfig::default());
        let dense = x.matmul(&w);
        assert!(y.max_abs_diff(&dense) < 1e-4);
        assert!(stats.reuse_ratio() > 7.9, "ratio {}", stats.reuse_ratio());
    }

    #[test]
    fn approximate_on_clustered_rows_with_high_savings() {
        // Wide output (n >> hash bits) so hashing cost amortizes — the
        // regime of real conv layers.
        forall("deep reuse accurate on clustered inputs", 8, |rng| {
            let x = clustered_input(rng, 64, 32, 4);
            let w = Tensor::randn(&[32, 64], 0.5, rng);
            let (y, stats) = reuse_gemm(&x, &w, &ReuseConfig::default());
            let dense = x.matmul(&w);
            let scale = dense.data().iter().map(|v| v.abs()).sum::<f32>()
                / dense.len() as f32;
            let rel = y.mad(&dense) / scale.max(1e-6);
            assert!(rel < 0.10, "relative error {rel}");
            assert!(stats.savings() > 0.4, "savings {}", stats.savings());
        });
    }

    #[test]
    fn reuse_conv_close_to_dense_on_smooth_input() {
        let mut rng = Rng::new(63);
        // Smooth input (natural-image-like): neighbouring patches similar.
        let mut x = Tensor::zeros(&[1, 3, 16, 16]);
        for c in 0..3 {
            for y in 0..16 {
                for xx in 0..16 {
                    let v = ((y as f32) / 8.0).sin() + ((xx as f32) / 8.0).cos() + c as f32 * 0.1;
                    x.set(&[0, c, y, xx], v);
                }
            }
        }
        let w = Tensor::randn(&[16, 3, 3, 3], 0.5, &mut rng);
        let cfg = ReuseConfig { hash_bits: 8, ..Default::default() };
        let (y, stats) = reuse_conv2d(&x, &w, 1, 1, &cfg);
        let dense = x.conv2d(&w, 1, 1);
        let scale =
            dense.data().iter().map(|v| v.abs()).sum::<f32>() / dense.len() as f32;
        let rel = y.mad(&dense) / scale.max(1e-6);
        assert!(rel < 0.25, "relative error {rel}");
        assert!(stats.reuse_ratio() > 1.5, "ratio {}", stats.reuse_ratio());
    }

    #[test]
    fn more_hash_bits_monotonically_reduce_error() {
        // The paper's accuracy knob: finer LSH buckets → smaller clusters →
        // less approximation (and less reuse).
        let mut rng = Rng::new(64);
        let x = Tensor::randn(&[48, 16], 1.0, &mut rng);
        let w = Tensor::randn(&[16, 32], 1.0, &mut rng);
        let dense = x.matmul(&w);
        let err = |bits: usize| {
            // Disable the adaptive fallback to observe the raw LSH error.
            let cfg = ReuseConfig {
                hash_bits: bits,
                max_rel_dev: f32::INFINITY,
                ..Default::default()
            };
            reuse_gemm(&x, &w, &cfg).0.mad(&dense)
        };
        let (e2, e6, e14) = (err(2), err(6), err(14));
        assert!(e14 <= e6 && e6 <= e2, "not monotone: {e2} {e6} {e14}");
        assert!(e14 < e2 * 0.5, "insufficient improvement: {e2} -> {e14}");
        // And the adaptive fallback bounds the error tightly even at few bits.
        let bounded = reuse_gemm(&x, &w, &ReuseConfig { hash_bits: 2, ..Default::default() })
            .0
            .mad(&dense);
        assert!(bounded < e2 * 0.5, "adaptive fallback ineffective: {bounded} vs {e2}");
    }

    #[test]
    fn stats_accounting_consistent() {
        let mut rng = Rng::new(65);
        let x = clustered_input(&mut rng, 40, 24, 3);
        let w = Tensor::randn(&[24, 32], 1.0, &mut rng);
        let (_y, stats) = reuse_gemm(&x, &w, &ReuseConfig::default());
        assert_eq!(stats.rows, 40);
        assert_eq!(stats.chunks, 3);
        assert!(stats.clusters >= stats.chunks);
        assert!(stats.macs_done < stats.macs_dense);
    }
}
