//! Int8 quantization-feasibility analysis: per-contraction-layer dynamic
//! range, per-channel scales and accumulator width, derived from the
//! range analysis plus the prune report.
//!
//! The output, [`QuantPlan`], is the compile-time artifact ROADMAP item 3
//! (int8 GEMM end-to-end) consumes: the future quantized executor picks
//! precision per layer by reading `feasible` here, instead of re-deriving
//! calibration from scratch. Infeasibility is a *reason code* on the
//! plan, never a compile diagnostic — an fp32 model with a wide dynamic
//! range is a perfectly valid model.

use crate::graph::{Graph, OpKind, WeightStore};
use crate::pruning::quant::quantize_gemm_weight;
use crate::pruning::PruneReport;
use crate::util::json::Json;

use super::{range::AbsVal, AnalysisConfig};

/// Int8 feasibility verdict for one contraction layer.
#[derive(Debug, Clone)]
pub struct QuantLayerPlan {
    /// Blamed IR node and its display identity.
    pub node: usize,
    pub name: String,
    pub op: &'static str,
    pub feasible: bool,
    /// Why not, when infeasible: "non-finite-input", "non-finite-weight",
    /// "dynamic-range" or "accumulator-width".
    pub reason: Option<&'static str>,
    /// Largest finite input magnitude the range analysis allows.
    pub in_amax: f64,
    /// Symmetric input scale (`in_amax / 127`).
    pub in_scale: f64,
    /// Largest per-channel weight scale (0 when no store is attached).
    pub weight_scale: f64,
    /// Per-output-channel weight scales from the symmetric int8
    /// quantizer; empty when no store is attached.
    pub channel_scales: Vec<f32>,
    /// Bits an exact i8×i8 accumulation over depth `k` needs.
    pub acc_bits: u32,
    /// Reduction depth (products per output element).
    pub k: usize,
    /// Weight sparsity: exact zero fraction with a store, else the prune
    /// report's global sparsity.
    pub sparsity: f64,
}

/// The per-layer int8 plan attached to `CompileReport`.
#[derive(Debug, Clone, Default)]
pub struct QuantPlan {
    pub layers: Vec<QuantLayerPlan>,
}

impl QuantPlan {
    pub fn feasible_count(&self) -> usize {
        self.layers.iter().filter(|l| l.feasible).count()
    }

    pub fn summary(&self) -> String {
        format!("{}/{} layers int8-feasible", self.feasible_count(), self.layers.len())
    }

    /// Serializable form (the artifact contract with the int8 GEMM PR).
    pub fn to_json(&self) -> Json {
        let layers = self.layers.iter().map(|l| {
            let mut pairs = vec![
                ("node", Json::num(l.node as f64)),
                ("name", Json::str(&l.name)),
                ("op", Json::str(l.op)),
                ("feasible", Json::Bool(l.feasible)),
                ("in_amax", Json::num(l.in_amax)),
                ("in_scale", Json::num(l.in_scale)),
                ("weight_scale", Json::num(l.weight_scale)),
                ("acc_bits", Json::num(l.acc_bits as f64)),
                ("k", Json::num(l.k as f64)),
                ("sparsity", Json::num(l.sparsity)),
                (
                    "channel_scales",
                    Json::arr(l.channel_scales.iter().map(|&s| Json::num(s as f64))),
                ),
            ];
            if let Some(r) = l.reason {
                pairs.push(("reason", Json::str(r)));
            }
            Json::obj(pairs)
        });
        Json::obj(vec![
            ("feasible_layers", Json::num(self.feasible_count() as f64)),
            ("layers", Json::arr(layers)),
        ])
    }
}

/// Bits needed to index `k` values: ⌈log2 k⌉ (0 for k ≤ 1).
fn ceil_log2(k: usize) -> u32 {
    if k <= 1 {
        0
    } else {
        64 - ((k - 1) as u64).leading_zeros()
    }
}

/// Build the int8 plan: one entry per contraction node
/// (`reduction_depth` = Some), in node order.
pub fn plan(
    g: &Graph,
    ws: Option<&WeightStore>,
    ranges: &[AbsVal],
    prune: Option<&PruneReport>,
    cfg: &AnalysisConfig,
) -> QuantPlan {
    let fallback_sparsity = prune.map(|p| p.sparsity).unwrap_or(0.0);
    let mut layers = Vec::new();
    for n in &g.nodes {
        let Some(k) = super::reduction_depth(g, n.id) else { continue };
        let xin = ranges.get(n.inputs[0]).copied().unwrap_or_else(AbsVal::top);
        let acc_bits = 15 + ceil_log2(k);

        // Per-channel weight statistics, exact when a store is attached.
        // `quantize_gemm_weight` is the same helper `ExecState::prepack`
        // packs from, so the plan's scales agree bitwise with the scales
        // the int8 epilogue actually multiplies by.
        let wnode = n.inputs.iter().copied().find(|&i| matches!(g.node(i).op, OpKind::Weight));
        let mut weight_scale = 0.0f64;
        let mut channel_scales = Vec::new();
        let mut sparsity = fallback_sparsity;
        let mut weight_nonfinite = false;
        if let Some(t) = wnode.and_then(|wid| ws.and_then(|ws| ws.get(&g.node(wid).name))) {
            match quantize_gemm_weight(t) {
                Ok(q) => {
                    weight_scale = q.scales.iter().fold(0.0f32, |m, &s| m.max(s)) as f64;
                    channel_scales = q.scales;
                }
                Err(_) => weight_nonfinite = true,
            }
            let zeros = t.data().iter().filter(|&&v| v == 0.0).count();
            sparsity = zeros as f64 / t.len().max(1) as f64;
        }

        let in_amax = xin.amax();
        let reason = if !xin.is_finite() {
            Some("non-finite-input")
        } else if weight_nonfinite {
            Some("non-finite-weight")
        } else if in_amax > cfg.int8_max_amax {
            Some("dynamic-range")
        } else if acc_bits > cfg.int8_acc_bits {
            Some("accumulator-width")
        } else {
            None
        };
        layers.push(QuantLayerPlan {
            node: n.id,
            name: n.name.clone(),
            op: n.op.name(),
            feasible: reason.is_none(),
            reason,
            in_amax,
            in_scale: in_amax / 127.0,
            weight_scale,
            channel_scales,
            acc_bits,
            k,
            sparsity,
        });
    }
    QuantPlan { layers }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulator_width_grows_with_depth() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(9), 4);
        assert_eq!(ceil_log2(1 << 17), 17);
        assert_eq!(ceil_log2((1 << 17) + 1), 18);
    }

    #[test]
    fn json_round_trips() {
        let p = QuantPlan {
            layers: vec![QuantLayerPlan {
                node: 3,
                name: "fc".into(),
                op: "dense",
                feasible: false,
                reason: Some("dynamic-range"),
                in_amax: 2e4,
                in_scale: 2e4 / 127.0,
                weight_scale: 0.01,
                channel_scales: vec![0.01, 0.008],
                acc_bits: 18,
                k: 512,
                sparsity: 0.5,
            }],
        };
        let text = p.to_json().to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("feasible_layers").and_then(Json::as_f64), Some(0.0));
        let layers = back.get("layers").and_then(Json::as_arr).unwrap();
        assert_eq!(layers.len(), 1);
        assert_eq!(layers[0].get("reason").and_then(Json::as_str), Some("dynamic-range"));
        assert_eq!(layers[0].get("channel_scales").and_then(Json::as_arr).unwrap().len(), 2);
    }
}
