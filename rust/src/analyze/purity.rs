//! Trace-purity / effect analysis: which ops (and fused groups) are pure
//! enough to trace-compile, which must stay on the interpreter, and which
//! can only run through the estimate-only fallback.
//!
//! The trace compiler (ROADMAP item 4) flattens a fused group into a
//! straight-line execution trace — legal only when every member is a
//! pure function of its inputs with a statically known access pattern.
//! The classification is cross-checked against
//! [`exec::eval_supported`](crate::exec::eval_supported): an op without a
//! kernel can never be traced, whatever its algebraic shape.

use crate::exec::eval_supported;
use crate::fusion::FusionPlan;
use crate::graph::{Graph, MappingType, NodeId, OpKind};

/// Effect class of an op or fused group. Declaration order is severity
/// order (derived `Ord`): a group is as impure as its worst member.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Effect {
    /// Pure elementwise / movement op: fusable anywhere, traceable, and
    /// eligible for a GEMM epilogue slot.
    PureElementwise,
    /// Pure contraction/reduction (ManyToMany): traceable as the *anchor*
    /// of a group, with elementwise followers fused into its epilogue.
    GemmEpilogueFusable,
    /// Observable effects or data-dependent control (detection
    /// post-processing): never traceable, breaks an incremental decode.
    Stateful,
    /// No executable kernel at all — estimate-only fallback path.
    FallbackOnly,
}

impl Effect {
    pub fn name(&self) -> &'static str {
        match self {
            Effect::PureElementwise => "pure-elementwise",
            Effect::GemmEpilogueFusable => "gemm-epilogue-fusable",
            Effect::Stateful => "stateful",
            Effect::FallbackOnly => "fallback-only",
        }
    }

    /// Can an incremental decode trace replay this effect every step?
    pub fn trace_safe(&self) -> bool {
        matches!(self, Effect::PureElementwise | Effect::GemmEpilogueFusable)
    }
}

/// Effect of a single op.
///
/// `PostProcess` is checked *before* the `eval_supported` cross-check:
/// it is stateful by nature (data-dependent NMS on the CPU side), and
/// "stateful" is the stronger claim — adding a kernel for it would not
/// make it traceable.
pub fn op_effect(op: &OpKind) -> Effect {
    if matches!(op, OpKind::PostProcess) {
        return Effect::Stateful;
    }
    if !eval_supported(op) {
        return Effect::FallbackOnly;
    }
    match op.mapping() {
        MappingType::ManyToMany => Effect::GemmEpilogueFusable,
        _ => Effect::PureElementwise,
    }
}

/// Effect classification of one fused group.
#[derive(Debug, Clone)]
pub struct GroupPurity {
    pub nodes: Vec<NodeId>,
    pub effect: Effect,
}

/// Per-node and per-group effect classification of a compiled graph.
#[derive(Debug, Clone)]
pub struct PurityReport {
    /// Effect of every node, indexed by `NodeId` (sources are pure).
    pub per_node: Vec<Effect>,
    /// One entry per fused group of the [`FusionPlan`], in plan order.
    pub groups: Vec<GroupPurity>,
}

impl PurityReport {
    pub fn count(&self, e: Effect) -> usize {
        self.groups.iter().filter(|gp| gp.effect == e).count()
    }

    /// True when every fused group can be trace-compiled.
    pub fn trace_safe(&self) -> bool {
        self.groups.iter().all(|gp| gp.effect.trace_safe())
    }

    pub fn summary(&self) -> String {
        format!(
            "{} gemm / {} pure / {} stateful / {} fallback groups",
            self.count(Effect::GemmEpilogueFusable),
            self.count(Effect::PureElementwise),
            self.count(Effect::Stateful),
            self.count(Effect::FallbackOnly)
        )
    }
}

/// Classify every node and every fused group of `plan`. Group effect is
/// the maximum (worst) member effect — one stateful op poisons the group.
pub fn classify(g: &Graph, plan: &FusionPlan) -> PurityReport {
    let per_node: Vec<Effect> = g
        .nodes
        .iter()
        .map(|n| if n.op.is_source() { Effect::PureElementwise } else { op_effect(&n.op) })
        .collect();
    let groups = plan
        .groups
        .iter()
        .map(|grp| GroupPurity {
            nodes: grp.nodes.clone(),
            effect: grp
                .nodes
                .iter()
                .map(|&id| per_node[id])
                .max()
                .unwrap_or(Effect::PureElementwise),
        })
        .collect();
    PurityReport { per_node, groups }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Act;

    #[test]
    fn effects_cross_check_eval_supported() {
        let cases = [
            OpKind::Activation(Act::Relu),
            OpKind::Add,
            OpKind::Reshape,
            OpKind::CausalMask,
            OpKind::Dense,
            OpKind::Softmax,
            OpKind::Conv2d { k: 3, stride: 1, pad: 1, groups: 1 },
            OpKind::Conv3d { kt: 3, k: 3, stride: 1, pad: 1 },
            OpKind::ConvTranspose2d { k: 4, stride: 2, pad: 1 },
            OpKind::ChannelShuffle { groups: 2 },
            OpKind::PostProcess,
        ];
        for op in cases {
            let e = op_effect(&op);
            // Every op with no kernel is fallback-only or stateful, and
            // every traceable op has a kernel — no misclassification can
            // promise the trace compiler an op it cannot execute.
            assert_eq!(e.trace_safe(), eval_supported(&op) && !matches!(op, OpKind::PostProcess));
        }
        assert_eq!(op_effect(&OpKind::Dense), Effect::GemmEpilogueFusable);
        assert_eq!(op_effect(&OpKind::Add), Effect::PureElementwise);
        assert_eq!(op_effect(&OpKind::PostProcess), Effect::Stateful);
        assert_eq!(
            op_effect(&OpKind::Conv3d { kt: 3, k: 3, stride: 1, pad: 1 }),
            Effect::FallbackOnly
        );
    }

    #[test]
    fn severity_order_backs_group_max() {
        assert!(Effect::PureElementwise < Effect::GemmEpilogueFusable);
        assert!(Effect::GemmEpilogueFusable < Effect::Stateful);
        assert!(Effect::Stateful < Effect::FallbackOnly);
    }
}
