//! Semantic dataflow analyses over the IR (ISSUE-9 tentpole).
//!
//! `xgen::verify` (PR 7) proves *structural* invariants — topology,
//! payload consistency, memory-plan aliasing. This module adds the
//! *semantic* half: a forward abstract-interpretation framework
//! ([`run_forward`]) over [`Graph`]'s validated topological order, with
//! pluggable lattice domains ([`Lattice`]) and per-node transfer
//! functions ([`Transfer`]), plus the three client analyses the roadmap
//! items consume:
//!
//! * **[`range`]** — value-range / NaN-propagation over the
//!   interval-with-flags domain [`AbsVal`]: proves per-node finiteness
//!   from weight statistics and declared input ranges, and flags
//!   *guaranteed* non-finite paths as typed
//!   [`XgenError::AnalysisDiagnostic`](crate::error::XgenError) warnings
//!   at compile time (blamed on the origin node, not downstream victims).
//! * **[`quant`]** — int8 quantization feasibility: per-layer dynamic
//!   range and per-channel scales derived from the range analysis plus
//!   [`PruneReport`] sparsity, emitted as a serializable [`QuantPlan`]
//!   on the `CompileReport` — the artifact the int8 GEMM work (ROADMAP
//!   item 3) consumes.
//! * **[`purity`]** — trace-purity / effect classification of every op
//!   and fused group ({pure-elementwise, GEMM-epilogue-fusable,
//!   stateful, fallback-only}), cross-checked against
//!   [`exec::eval_supported`](crate::exec::eval_supported) — the
//!   trace-safety report the trace-compiler work (ROADMAP item 4) needs,
//!   and what `DecodeSession::new` uses to reject untraceable graphs
//!   with a typed error instead of a mid-generate failure.
//!
//! Wired into `Compiler::compile` behind `.analyze(bool)` (default on at
//! O2+) and the CLI's `compile --analyze`; results surface in
//! `report()` next to the verify section.

pub mod purity;
pub mod quant;
pub mod range;

use std::collections::VecDeque;

use anyhow::Result;

use crate::fusion::FusionPlan;
use crate::graph::{Graph, Node, NodeId, OpKind, WeightStore};
use crate::pruning::PruneReport;

pub use purity::{classify, op_effect, Effect, GroupPurity, PurityReport};
pub use quant::{QuantLayerPlan, QuantPlan};
pub use range::{AbsVal, RangeAnalysis};

/// Tunables of the built-in analyses. The defaults are deliberately wide:
/// the range analysis must never call a reachable value impossible.
#[derive(Debug, Clone)]
pub struct AnalysisConfig {
    /// Declared magnitude bound of dense (non-token) graph inputs:
    /// `[-input_bound, input_bound]`. Matches `CompiledModel`'s
    /// normalized-input convention (sample inputs are N(0,1); ±6 covers
    /// the 6σ tail).
    pub input_bound: f64,
    /// Sigma multiplier for the statistical weight envelope used when no
    /// weight store is attached (`init_random` draws N(0,1)/√fan_in).
    pub weight_sigma: f64,
    /// A layer whose input amplitude exceeds this is int8-infeasible
    /// ("dynamic-range"): 8-bit resolution at that scale is coarser than
    /// any useful signal.
    pub int8_max_amax: f64,
    /// Accumulator budget in bits; i8×i8 products need `15 + ⌈log2 K⌉`
    /// bits over a depth-K reduction ("accumulator-width" when exceeded).
    pub int8_acc_bits: u32,
    /// Worklist budget per node before the analysis gives up soundly
    /// (returns ⊤ everywhere). A DAG converges in one pass; this only
    /// bounds pathological inputs.
    pub max_steps_per_node: usize,
}

impl Default for AnalysisConfig {
    fn default() -> Self {
        AnalysisConfig {
            input_bound: 6.0,
            weight_sigma: 4.0,
            int8_max_amax: 1e4,
            int8_acc_bits: 32,
            max_steps_per_node: 8,
        }
    }
}

/// A join-semilattice of abstract values.
pub trait Lattice: Clone + PartialEq {
    /// Least element (unreached / no information yet).
    fn bottom() -> Self;
    /// Greatest element (no property proven).
    fn top() -> Self;
    /// Least upper bound; must be monotone in both arguments.
    fn join(&self, other: &Self) -> Self;
}

/// One forward analysis: how sources are seeded and how each compute op
/// transforms the abstract values of its inputs.
pub trait Transfer {
    type Value: Lattice;
    /// Abstract value of a source node (`Input` / `Weight`).
    fn seed(&self, g: &Graph, node: &Node) -> Self::Value;
    /// Abstract value of a compute node from its inputs' values
    /// (`args[i]` is the value of `node.inputs[i]`).
    fn transfer(&self, g: &Graph, node: &Node, args: &[Self::Value]) -> Self::Value;
}

/// Run a forward dataflow analysis to fixpoint.
///
/// The graph's builder invariant (inputs precede users) makes the node
/// order a topological order, so on a DAG one sweep converges and the
/// worklist only re-queues on genuine lattice movement. The step budget
/// is a backstop for non-monotone transfers: when exhausted the analysis
/// *gives up soundly* by returning ⊤ for every node — no property is
/// claimed, no diagnostic can fire spuriously.
pub fn run_forward<T: Transfer>(
    g: &Graph,
    t: &T,
    max_steps_per_node: usize,
) -> Result<Vec<T::Value>> {
    g.validate()?;
    let nn = g.nodes.len();
    let users = g.users();
    let mut vals: Vec<T::Value> = vec![T::Value::bottom(); nn];
    let mut queued = vec![true; nn];
    let mut work: VecDeque<NodeId> = (0..nn).collect();
    let mut budget = nn.saturating_mul(max_steps_per_node.max(1)) + 64;
    while let Some(id) = work.pop_front() {
        queued[id] = false;
        if budget == 0 {
            return Ok((0..nn).map(|_| T::Value::top()).collect());
        }
        budget -= 1;
        let n = g.node(id);
        let out = if n.op.is_source() {
            t.seed(g, n)
        } else {
            let args: Vec<T::Value> = n.inputs.iter().map(|&i| vals[i].clone()).collect();
            t.transfer(g, n, &args)
        };
        let joined = vals[id].join(&out);
        if joined != vals[id] {
            vals[id] = joined;
            for &u in &users[id] {
                if !queued[u] {
                    queued[u] = true;
                    work.push_back(u);
                }
            }
        }
    }
    Ok(vals)
}

/// Reduction depth K of a contraction node (products accumulated per
/// output element), or `None` for non-contraction ops. This is both the
/// range analysis's accumulation factor and the int8 accumulator-width
/// driver.
pub fn reduction_depth(g: &Graph, id: NodeId) -> Option<usize> {
    let n = g.node(id);
    if n.inputs.is_empty() {
        return None;
    }
    let in_shape = &g.node(n.inputs[0]).shape;
    match &n.op {
        OpKind::Conv2d { k, groups, .. } => {
            let in_c = in_shape.get(1).copied().unwrap_or(1);
            Some((in_c / (*groups).max(1)).max(1) * k * k)
        }
        OpKind::Conv3d { kt, k, .. } => {
            let in_c = in_shape.get(1).copied().unwrap_or(1);
            Some(in_c.max(1) * kt * k * k)
        }
        OpKind::ConvTranspose2d { k, .. } => {
            let in_c = in_shape.get(1).copied().unwrap_or(1);
            Some(in_c.max(1) * k * k)
        }
        OpKind::Dense | OpKind::MatMul => Some(in_shape.last().copied().unwrap_or(1)),
        _ => None,
    }
}

/// Everything one `analyze()` run proved, in report form.
#[derive(Debug, Clone)]
pub struct AnalysisReport {
    /// Total IR nodes analyzed.
    pub nodes: usize,
    /// Nodes proven finite for all inputs in the declared ranges.
    pub finite_nodes: usize,
    /// Guaranteed-failure diagnostics (compile *warnings*: the model
    /// still compiles, but these paths are provably broken).
    pub warnings: Vec<crate::error::XgenError>,
    /// Per-node abstract values, indexed by `NodeId`.
    pub ranges: Vec<AbsVal>,
    /// Int8 feasibility per contraction layer.
    pub quant: QuantPlan,
    /// Effect classification per node and per fused group.
    pub purity: PurityReport,
}

impl AnalysisReport {
    /// One-line form for `CompileReport::summary()`.
    pub fn summary(&self) -> String {
        format!(
            "{}/{} nodes proven finite, {} warning(s); int8: {}; purity: {}",
            self.finite_nodes,
            self.nodes,
            self.warnings.len(),
            self.quant.summary(),
            self.purity.summary()
        )
    }
}

/// Run all three client analyses over a compiled graph.
pub fn analyze(
    g: &Graph,
    ws: Option<&WeightStore>,
    plan: &FusionPlan,
    prune: Option<&PruneReport>,
    cfg: &AnalysisConfig,
) -> Result<AnalysisReport> {
    let ra = RangeAnalysis::new(g, ws, cfg);
    let ranges = run_forward(g, &ra, cfg.max_steps_per_node)?;
    let warnings = range::diagnostics(g, &ranges);
    let quant = quant::plan(g, ws, &ranges, prune, cfg);
    let purity = purity::classify(g, plan);
    let finite_nodes = ranges.iter().filter(|v| v.is_finite()).count();
    Ok(AnalysisReport { nodes: g.nodes.len(), finite_nodes, warnings, ranges, quant, purity })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Act;

    fn chain() -> Graph {
        let mut g = Graph::new("t");
        let x = g.input("x", &[1, 8]);
        let r = g.add("relu", OpKind::Activation(Act::Relu), vec![x], vec![1, 8]);
        let s = g.add("sig", OpKind::Activation(Act::Sigmoid), vec![r], vec![1, 8]);
        g.outputs = vec![s];
        g
    }

    #[test]
    fn forward_pass_reaches_fixpoint_on_a_dag() {
        let g = chain();
        let cfg = AnalysisConfig::default();
        let ra = RangeAnalysis::new(&g, None, &cfg);
        let vals = run_forward(&g, &ra, cfg.max_steps_per_node).unwrap();
        assert_eq!(vals.len(), 3);
        // input ±6 → relu [0,6] → sigmoid [σ(0), σ(6)] ⊂ (0,1).
        assert!(vals.iter().all(|v| v.is_finite()));
        assert_eq!((vals[1].lo, vals[1].hi), (0.0, 6.0));
        assert!(vals[2].lo >= 0.49 && vals[2].hi <= 1.0);
    }

    #[test]
    fn exhausted_budget_gives_up_soundly_with_top() {
        let g = chain();
        let cfg = AnalysisConfig::default();
        let ra = RangeAnalysis::new(&g, None, &cfg);
        // Budget 64 + 3 ≥ 3 nodes, so force exhaustion via a fake huge
        // graph is impractical here; instead check the ⊤ contract
        // directly: ⊤ proves nothing and fires nothing.
        let top = AbsVal::top();
        assert!(!top.is_finite() && !top.guaranteed_non_finite());
        let _ = run_forward(&g, &ra, 0).unwrap(); // min-clamped, still fine
    }

    #[test]
    fn reduction_depth_follows_contraction_shapes() {
        let mut g = Graph::new("k");
        let x = g.input("x", &[1, 16, 8, 8]);
        let w = g.weight("w", &[32, 16, 3, 3]);
        let c = g.add(
            "conv",
            OpKind::Conv2d { k: 3, stride: 1, pad: 1, groups: 1 },
            vec![x, w],
            vec![1, 32, 8, 8],
        );
        let f = g.add("flat", OpKind::Flatten, vec![c], vec![1, 32 * 64]);
        let dw = g.weight("dw", &[32 * 64, 10]);
        let d = g.add("fc", OpKind::Dense, vec![f, dw], vec![1, 10]);
        g.outputs = vec![d];
        assert_eq!(reduction_depth(&g, c), Some(16 * 9));
        assert_eq!(reduction_depth(&g, d), Some(32 * 64));
        assert_eq!(reduction_depth(&g, f), None);
        assert_eq!(reduction_depth(&g, x), None);
    }
}
