//! Value-range / NaN-propagation analysis: the interval-with-flags
//! abstract domain ([`AbsVal`]) and its per-op transfer functions.
//!
//! The domain over-approximates the set of f32 values a tensor may hold:
//!
//! * `[lo, hi]` bounds the **finite** values (clamped to ±`f32::MAX`);
//! * `nan` / `pinf` / `ninf` say the tensor **may** contain that
//!   non-finite value.
//!
//! The one non-obvious encoding: an *empty* real interval (`lo > hi`,
//! canonically `(+∞, -∞)`) with at least one flag set means **every**
//! element is non-finite — that is what lets the analysis report
//! *guaranteed* failures (`analysis[guaranteed-nan]`) instead of noisy
//! "might be NaN" warnings. An empty interval with no flags is ⊥
//! (unreached). [`AbsVal::fix`] maintains the canonical form, folding
//! overflow past `f32::MAX` into the inf flags: when a whole interval
//! lands above the representable range, every runtime f32 is `+inf` and
//! the value becomes empty+`pinf` — a proof, not a heuristic.
//!
//! Every transfer mirrors the *exact* kernel semantics in `exec`:
//! `Relu` is `x.max(0.0)`, which maps NaN to 0 (Rust `max` drops NaN),
//! so it **clears** the nan flag; `Relu6` is `clamp(0.0, 6.0)`, which
//! keeps NaN; `Sqrt` is IEEE (negative input → NaN — the PR-4 fix);
//! a `Softmax` fed by `CausalMask` runs the fused masked kernel that
//! never touches the masked `-inf` entries, so the mask's own `ninf`
//! flag is forgiven there and only there.

use std::collections::BTreeMap;

use crate::error::XgenError;
use crate::graph::{Act, Graph, Node, NodeId, OpKind, WeightStore};

use super::{AnalysisConfig, Lattice, Transfer};

/// Largest finite f32, as the f64 the domain computes in.
pub const MAXF: f64 = f32::MAX as f64;

/// One abstract tensor value: finite-value interval + may-flags.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AbsVal {
    pub lo: f64,
    pub hi: f64,
    /// May contain NaN.
    pub nan: bool,
    /// May contain +inf.
    pub pinf: bool,
    /// May contain -inf.
    pub ninf: bool,
}

impl AbsVal {
    /// ⊥ — no values at all (unreached node).
    pub fn bottom() -> AbsVal {
        AbsVal { lo: f64::INFINITY, hi: f64::NEG_INFINITY, nan: false, pinf: false, ninf: false }
    }

    /// ⊤ — any f32 whatsoever.
    pub fn top() -> AbsVal {
        AbsVal { lo: -MAXF, hi: MAXF, nan: true, pinf: true, ninf: true }
    }

    /// Empty real interval carrying only non-finite possibilities.
    pub fn empty_with(nan: bool, pinf: bool, ninf: bool) -> AbsVal {
        AbsVal { lo: f64::INFINITY, hi: f64::NEG_INFINITY, nan, pinf, ninf }
    }

    /// The single finite value `v`.
    pub fn exact(v: f64) -> AbsVal {
        AbsVal::range(v, v)
    }

    /// Finite interval `[lo, hi]` (normalized through [`AbsVal::fix`]).
    pub fn range(lo: f64, hi: f64) -> AbsVal {
        let mut r = AbsVal { lo, hi, nan: false, pinf: false, ninf: false };
        r.fix();
        r
    }

    pub fn is_empty(&self) -> bool {
        self.lo > self.hi
    }

    pub fn any_flag(&self) -> bool {
        self.nan || self.pinf || self.ninf
    }

    /// Provably finite: some finite values, no non-finite possibility.
    pub fn is_finite(&self) -> bool {
        !self.is_empty() && !self.any_flag()
    }

    /// Provably non-finite: *every* concrete element is NaN/±inf.
    pub fn guaranteed_non_finite(&self) -> bool {
        self.is_empty() && self.any_flag()
    }

    /// Largest finite magnitude the value may reach (0 when empty).
    pub fn amax(&self) -> f64 {
        if self.is_empty() {
            0.0
        } else {
            self.lo.abs().max(self.hi.abs())
        }
    }

    /// Restore the canonical form: NaN bounds become the nan flag over the
    /// full finite range, an interval entirely outside ±`f32::MAX` becomes
    /// empty + the matching inf flag (a *guarantee* — every f32 overflows),
    /// and bounds poking past ±`f32::MAX` are clamped with the flag set.
    pub fn fix(&mut self) {
        if self.lo.is_nan() || self.hi.is_nan() {
            self.nan = true;
            self.lo = -MAXF;
            self.hi = MAXF;
            return;
        }
        if self.lo > self.hi {
            self.lo = f64::INFINITY;
            self.hi = f64::NEG_INFINITY;
            return;
        }
        if self.lo > MAXF {
            self.pinf = true;
            self.lo = f64::INFINITY;
            self.hi = f64::NEG_INFINITY;
            return;
        }
        if self.hi < -MAXF {
            self.ninf = true;
            self.lo = f64::INFINITY;
            self.hi = f64::NEG_INFINITY;
            return;
        }
        if self.hi > MAXF {
            self.pinf = true;
            self.hi = MAXF;
        }
        if self.lo < -MAXF {
            self.ninf = true;
            self.lo = -MAXF;
        }
    }

    /// Least upper bound: interval hull + flag union.
    pub fn join(&self, o: &AbsVal) -> AbsVal {
        let mut r = AbsVal {
            lo: self.lo.min(o.lo),
            hi: self.hi.max(o.hi),
            nan: self.nan || o.nan,
            pinf: self.pinf || o.pinf,
            ninf: self.ninf || o.ninf,
        };
        r.fix();
        r
    }

    pub fn join_point(&self, v: f64) -> AbsVal {
        self.join(&AbsVal::exact(v))
    }

    pub fn add(&self, o: &AbsVal) -> AbsVal {
        let (lo, hi) = if self.is_empty() || o.is_empty() {
            (f64::INFINITY, f64::NEG_INFINITY)
        } else {
            (self.lo + o.lo, self.hi + o.hi)
        };
        let mut r = AbsVal {
            lo,
            hi,
            // (+inf) + (-inf) = NaN.
            nan: self.nan || o.nan || (self.pinf && o.ninf) || (self.ninf && o.pinf),
            pinf: self.pinf || o.pinf,
            ninf: self.ninf || o.ninf,
        };
        r.fix();
        r
    }

    pub fn neg(&self) -> AbsVal {
        let mut r = AbsVal {
            lo: -self.hi,
            hi: -self.lo,
            nan: self.nan,
            pinf: self.ninf,
            ninf: self.pinf,
        };
        r.fix();
        r
    }

    pub fn sub(&self, o: &AbsVal) -> AbsVal {
        self.add(&o.neg())
    }

    pub fn mul(&self, o: &AbsVal) -> AbsVal {
        let (lo, hi) = if self.is_empty() || o.is_empty() {
            (f64::INFINITY, f64::NEG_INFINITY)
        } else {
            let c = [self.lo * o.lo, self.lo * o.hi, self.hi * o.lo, self.hi * o.hi];
            (c.iter().copied().fold(f64::INFINITY, f64::min),
             c.iter().copied().fold(f64::NEG_INFINITY, f64::max))
        };
        let mut r = AbsVal { lo, hi, nan: self.nan || o.nan, pinf: false, ninf: false };
        if self.pinf || self.ninf || o.pinf || o.ninf {
            // inf × (unknown sign, possibly 0) can be ±inf or NaN.
            r.nan = true;
            r.pinf = true;
            r.ninf = true;
        }
        r.fix();
        r
    }

    pub fn div(&self, o: &AbsVal) -> AbsVal {
        // Denominator may be zero or non-finite: anything can come out.
        if o.is_empty() || o.any_flag() || (o.lo..=o.hi).contains(&0.0) {
            return AbsVal::top();
        }
        if self.is_empty() {
            // Guaranteed non-finite numerator over a finite nonzero
            // denominator stays non-finite; the infinity's sign follows
            // the denominator's, so keep both inf flags to stay sound.
            let inf = self.pinf || self.ninf;
            return AbsVal::empty_with(self.nan, inf, inf);
        }
        let c = [self.lo / o.lo, self.lo / o.hi, self.hi / o.lo, self.hi / o.hi];
        let mut r = AbsVal {
            lo: c.iter().copied().fold(f64::INFINITY, f64::min),
            hi: c.iter().copied().fold(f64::NEG_INFINITY, f64::max),
            nan: self.nan,
            pinf: self.pinf || self.ninf,
            ninf: self.pinf || self.ninf,
        };
        r.fix();
        r
    }

    /// `x*m + a` with constant `m`, `a` (the `Scale` op's payload form).
    pub fn scale_affine(&self, m: f64, a: f64) -> AbsVal {
        let mut r = *self;
        if m == 0.0 {
            // 0·x is 0 for finite x, NaN for ±inf.
            r.nan = self.nan || self.pinf || self.ninf;
            r.pinf = false;
            r.ninf = false;
            if !self.is_empty() {
                r.lo = a;
                r.hi = a;
            }
        } else {
            if !self.is_empty() {
                let (x, y) = (self.lo * m + a, self.hi * m + a);
                r.lo = x.min(y);
                r.hi = x.max(y);
            }
            if m < 0.0 {
                r.pinf = self.ninf;
                r.ninf = self.pinf;
            }
        }
        r.fix();
        r
    }

    /// Exact abstraction of a concrete tensor (weight-store seeding).
    pub fn from_data(data: &[f32]) -> AbsVal {
        if data.is_empty() {
            return AbsVal::exact(0.0);
        }
        let mut r = AbsVal::bottom();
        for &v in data {
            if v.is_nan() {
                r.nan = true;
            } else if v == f32::INFINITY {
                r.pinf = true;
            } else if v == f32::NEG_INFINITY {
                r.ninf = true;
            } else {
                let v = v as f64;
                r.lo = r.lo.min(v);
                r.hi = r.hi.max(v);
            }
        }
        r
    }
}

impl Lattice for AbsVal {
    fn bottom() -> Self {
        AbsVal::bottom()
    }
    fn top() -> Self {
        AbsVal::top()
    }
    fn join(&self, other: &Self) -> Self {
        AbsVal::join(self, other)
    }
}

/// The range analysis: seeds from declared input bounds / embedding
/// vocabularies / weight statistics, transfers per [`OpKind`].
pub struct RangeAnalysis<'a> {
    ws: Option<&'a WeightStore>,
    input_bound: f64,
    weight_sigma: f64,
    /// Input nodes that feed an `Embedding`/`Gather` index slot, mapped to
    /// the lookup table's row count: their declared range is `[0, vocab)`.
    token_vocab: BTreeMap<NodeId, usize>,
}

impl<'a> RangeAnalysis<'a> {
    pub fn new(g: &Graph, ws: Option<&'a WeightStore>, cfg: &AnalysisConfig) -> RangeAnalysis<'a> {
        let mut token_vocab = BTreeMap::new();
        for n in &g.nodes {
            if matches!(n.op, OpKind::Embedding | OpKind::Gather) && n.inputs.len() == 2 {
                let (idx, table) = (n.inputs[0], n.inputs[1]);
                if matches!(g.node(idx).op, OpKind::Input) {
                    if let Some(&rows) = g.node(table).shape.first() {
                        token_vocab.insert(idx, rows);
                    }
                }
            }
        }
        RangeAnalysis { ws, input_bound: cfg.input_bound, weight_sigma: cfg.weight_sigma, token_vocab }
    }

    fn weight_range(&self, g: &Graph, n: &Node) -> AbsVal {
        // Exact when a store is attached; statistical envelope otherwise
        // (matches `WeightStore::init_random`: N(0,1)/√fan_in tensors, and
        // `[2, C]` affine tables with scale 1+0.1·N, shift 0.1·N).
        if let Some(t) = self.ws.and_then(|ws| ws.get(&n.name)) {
            return AbsVal::from_data(t.data());
        }
        if let Some(&v) = g.consts.get(&n.name) {
            return AbsVal::exact(v as f64);
        }
        let s = self.weight_sigma;
        if n.shape.len() == 2 && n.shape[0] == 2 {
            return AbsVal::range((1.0 - 0.1 * s).min(-0.1 * s), (1.0 + 0.1 * s).max(0.1 * s));
        }
        let fan_in: usize = n.shape.iter().skip(1).product::<usize>().max(1);
        let b = s / (fan_in as f64).sqrt();
        AbsVal::range(-b, b)
    }
}

impl Transfer for RangeAnalysis<'_> {
    type Value = AbsVal;

    fn seed(&self, g: &Graph, n: &Node) -> AbsVal {
        match n.op {
            OpKind::Input => match self.token_vocab.get(&n.id) {
                Some(&vocab) => AbsVal::range(0.0, vocab.saturating_sub(1) as f64),
                None => AbsVal::range(-self.input_bound, self.input_bound),
            },
            OpKind::Weight => self.weight_range(g, n),
            // Non-source ops never reach seed() (run_forward dispatches on
            // `is_source`); ⊥ keeps a misuse visible instead of masking it.
            _ => AbsVal::bottom(),
        }
    }

    fn transfer(&self, g: &Graph, n: &Node, args: &[AbsVal]) -> AbsVal {
        transfer_op(g, n, args)
    }
}

/// Second operand, or ⊤ for malformed arity (sound, never unsound).
fn arg1(args: &[AbsVal]) -> AbsVal {
    args.get(1).copied().unwrap_or_else(AbsVal::top)
}

/// Empty input stays empty: the op maps non-finite to non-finite, and we
/// conservatively collapse which kind to "may be NaN".
fn carry_empty(x: &AbsVal) -> AbsVal {
    AbsVal::empty_with(x.any_flag(), false, false)
}

/// GEMM-family contraction over depth `k`: `k` products accumulated.
fn gemm_like(x: &AbsVal, w: &AbsVal, k: usize) -> AbsVal {
    x.mul(w).scale_affine(k.max(1) as f64, 0.0)
}

/// Per-channel `x*w + w` where `w` is a `[2, C]` scale/shift table
/// (BatchNorm, weighted Scale).
fn affine_by_table(x: &AbsVal, w: &AbsVal) -> AbsVal {
    x.mul(w).add(w)
}

/// Monotone activation saturating at `sat_lo`/`sat_hi` (sigmoid, tanh):
/// `f(-inf) = sat_lo`, `f(+inf) = sat_hi`, NaN stays NaN.
fn bounded_monotone(x: &AbsVal, f: impl Fn(f64) -> f64, sat_lo: f64, sat_hi: f64) -> AbsVal {
    let mut r = AbsVal::empty_with(x.nan, false, false);
    if !x.is_empty() {
        r = r.join(&AbsVal::range(f(x.lo), f(x.hi)));
    }
    if x.ninf {
        r = r.join_point(sat_lo);
    }
    if x.pinf {
        r = r.join_point(sat_hi);
    }
    r
}

/// The x·σ(x) family (gelu/swish/hard-swish/mish): bounded below by a
/// small negative constant, `f(x) ≤ max(x, 0)` above, `f(x) ≥ 0` once
/// `x ≥ 0`. `f(-inf) = -inf·0 = NaN`, `f(+inf) = +inf`.
fn xish(x: &AbsVal, min_bound: f64) -> AbsVal {
    let mut r = AbsVal::empty_with(x.nan || x.ninf, x.pinf, false);
    if !x.is_empty() {
        let lo = if x.lo >= 0.0 { 0.0 } else { min_bound };
        r = r.join(&AbsVal::range(lo, x.hi.max(0.0)));
    }
    r
}

fn act_range(a: Act, x: &AbsVal) -> AbsVal {
    match a {
        Act::Relu => {
            // `x.max(0.0)`: Rust max drops NaN, so NaN (and -inf) land on 0.
            let mut r = AbsVal::empty_with(false, x.pinf, false);
            if !x.is_empty() {
                r = r.join(&AbsVal::range(x.lo.max(0.0), x.hi.max(0.0)));
            }
            if x.nan || x.ninf {
                r = r.join_point(0.0);
            }
            r
        }
        Act::Relu6 => {
            // `clamp(0,6)` keeps NaN; ±inf clamp to the endpoints.
            let mut r = AbsVal::empty_with(x.nan, false, false);
            if !x.is_empty() {
                r = r.join(&AbsVal::range(x.lo.clamp(0.0, 6.0), x.hi.clamp(0.0, 6.0)));
            }
            if x.ninf {
                r = r.join_point(0.0);
            }
            if x.pinf {
                r = r.join_point(6.0);
            }
            r
        }
        Act::Sigmoid => bounded_monotone(x, |v| 1.0 / (1.0 + (-v).exp()), 0.0, 1.0),
        Act::Tanh => bounded_monotone(x, f64::tanh, -1.0, 1.0),
        Act::LeakyRelu => {
            let f = |v: f64| if v >= 0.0 { v } else { 0.1 * v };
            let mut r = *x;
            if !x.is_empty() {
                r.lo = f(x.lo);
                r.hi = f(x.hi);
            }
            r.fix();
            r
        }
        Act::Gelu => xish(x, -0.2),
        Act::Swish => xish(x, -0.3),
        Act::HardSwish => xish(x, -0.4),
        Act::Mish => xish(x, -0.32),
    }
}

/// The per-op transfer function: abstract semantics of [`OpKind`] over
/// [`AbsVal`], mirroring `exec::eval_op` exactly.
pub fn transfer_op(g: &Graph, n: &Node, args: &[AbsVal]) -> AbsVal {
    let x = args.first().copied().unwrap_or_else(AbsVal::top);
    match &n.op {
        OpKind::Input | OpKind::Weight => x, // sources; handled by seed()
        OpKind::Conv2d { .. }
        | OpKind::Conv3d { .. }
        | OpKind::ConvTranspose2d { .. }
        | OpKind::Dense
        | OpKind::MatMul => {
            let k = super::reduction_depth(g, n.id).unwrap_or(1);
            gemm_like(&x, &arg1(args), k)
        }
        OpKind::BatchNorm => affine_by_table(&x, &arg1(args)),
        OpKind::Bias => x.add(&arg1(args)),
        OpKind::LayerNorm => {
            if x.is_empty() {
                carry_empty(&x)
            } else {
                // Normalized rows are bounded by ±√d; then per-channel
                // gain/shift from the [2, C] table. Any non-finite input
                // poisons the row mean → may-NaN.
                let d = *n.shape.last().unwrap_or(&1) as f64;
                let z = AbsVal::range(-d.sqrt(), d.sqrt());
                let w = arg1(args);
                let mut out = z.mul(&w).add(&w);
                out.nan = out.nan || x.any_flag();
                out
            }
        }
        OpKind::Activation(a) => act_range(*a, &x),
        OpKind::Add => x.add(&arg1(args)),
        OpKind::Sub => x.sub(&arg1(args)),
        OpKind::Mul => x.mul(&arg1(args)),
        OpKind::Div => x.div(&arg1(args)),
        OpKind::Pow { e } => pow_range(&x, *e),
        OpKind::Sqrt => sqrt_range(&x),
        OpKind::Scale { mul, add } => {
            if args.len() > 1 {
                affine_by_table(&x, &args[1]) // per-channel weight override
            } else {
                x.scale_affine(*mul, *add)
            }
        }
        OpKind::CausalMask => {
            // Masked positions become -inf; the kept ones pass through.
            let mut r = x;
            if !x.is_empty() || x.any_flag() {
                r.ninf = true;
            }
            r
        }
        OpKind::Softmax => {
            let mut x = x;
            if matches!(g.node(n.inputs[0]).op, OpKind::CausalMask) {
                // The fused masked kernel normalizes each row over its
                // allowed prefix and never reads the masked entries — the
                // mask's own -inf is structurally harmless.
                x.ninf = false;
            }
            if x.is_empty() {
                carry_empty(&x)
            } else {
                AbsVal { lo: 0.0, hi: 1.0, nan: x.any_flag(), pinf: false, ninf: false }
            }
        }
        OpKind::MaxPool { pad, .. } => {
            if x.is_empty() {
                carry_empty(&x)
            } else if *pad > 0 {
                x.join_point(0.0) // zero padding enters the windows
            } else {
                x
            }
        }
        OpKind::AvgPool { pad, .. } => {
            if x.is_empty() {
                carry_empty(&x)
            } else {
                let mut r = if *pad > 0 { x.join_point(0.0) } else { x };
                r.nan = r.nan || (r.pinf && r.ninf); // inf + -inf in one window
                r
            }
        }
        OpKind::GlobalAvgPool => {
            let mut r = x;
            r.nan = r.nan || (r.pinf && r.ninf);
            r
        }
        OpKind::Pad { .. } => x.join_point(0.0),
        OpKind::Reshape
        | OpKind::Flatten
        | OpKind::Transpose { .. }
        | OpKind::Slice { .. }
        | OpKind::ChannelShuffle { .. }
        | OpKind::PixelShuffle { .. }
        | OpKind::Upsample { .. }
        | OpKind::Broadcast => x,
        OpKind::Concat => args.iter().fold(AbsVal::bottom(), |acc, v| acc.join(v)),
        OpKind::Embedding | OpKind::Gather => {
            // Row lookup: output values come from the table operand.
            if args.len() >= 2 {
                args[1]
            } else {
                x
            }
        }
        // Opaque CPU-side op (NMS etc.) — no useful abstraction.
        OpKind::PostProcess => AbsVal::top(),
    }
}

fn pow_range(x: &AbsVal, e: f64) -> AbsVal {
    if x.is_empty() {
        // inf^e / nan^e: conservatively any non-finite outcome.
        return AbsVal::empty_with(true, x.pinf, x.ninf);
    }
    if x.any_flag() {
        return AbsVal::top();
    }
    if x.lo < 0.0 && x.hi > 0.0 && e < 0.0 {
        return AbsVal::top(); // pole at 0 inside the interval
    }
    let mut c = vec![x.lo.powf(e), x.hi.powf(e)];
    if x.lo < 0.0 && x.hi > 0.0 {
        c.push(0.0f64.powf(e));
    }
    // f64 min/max folds *drop* NaN operands, so detect them explicitly:
    // all-NaN candidates leave an empty hull → guaranteed-NaN, which is
    // exactly right for e.g. [-8,-2]^0.5.
    let has_nan = c.iter().any(|v| v.is_nan());
    let mut r = AbsVal {
        lo: c.iter().copied().fold(f64::INFINITY, f64::min),
        hi: c.iter().copied().fold(f64::NEG_INFINITY, f64::max),
        nan: has_nan || (x.lo < 0.0 && e.fract() != 0.0),
        pinf: false,
        ninf: false,
    };
    r.fix();
    r
}

fn sqrt_range(x: &AbsVal) -> AbsVal {
    if x.is_empty() {
        // sqrt(NaN) = NaN, sqrt(-inf) = NaN, sqrt(+inf) = +inf.
        return AbsVal::empty_with(x.nan || x.ninf, x.pinf, false);
    }
    if x.hi < 0.0 {
        // Every finite value is strictly negative: IEEE sqrt yields NaN
        // for all of them. This is the guaranteed-NaN origin case.
        return AbsVal::empty_with(true, x.pinf, false);
    }
    let mut r = AbsVal::range(x.lo.max(0.0).sqrt(), x.hi.sqrt());
    r.nan = x.nan || x.ninf || x.lo < 0.0;
    r.pinf = x.pinf;
    r.fix();
    r
}

/// Compile-time warnings: one typed diagnostic per *origin* node whose
/// value is guaranteed non-finite. Downstream nodes the poison merely
/// propagates to are skipped — blame lands where the problem starts.
pub fn diagnostics(g: &Graph, vals: &[AbsVal]) -> Vec<XgenError> {
    let mut out = Vec::new();
    for n in &g.nodes {
        if n.op.is_source() {
            continue;
        }
        let v = &vals[n.id];
        if !v.guaranteed_non_finite() {
            continue;
        }
        if n.inputs.iter().any(|&i| vals[i].guaranteed_non_finite()) {
            continue;
        }
        let code = if v.nan { "guaranteed-nan" } else { "guaranteed-inf" };
        out.push(XgenError::AnalysisDiagnostic {
            code: code.to_string(),
            node: n.id,
            name: n.name.clone(),
            detail: format!(
                "every element of '{}' ({}) is non-finite for all inputs in the declared ranges",
                n.name,
                n.op.name()
            ),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interval_arithmetic_is_sound() {
        let a = AbsVal::range(-2.0, 3.0);
        let b = AbsVal::range(1.0, 4.0);
        let s = a.add(&b);
        assert_eq!((s.lo, s.hi), (-1.0, 7.0));
        let m = a.mul(&b);
        assert_eq!((m.lo, m.hi), (-8.0, 12.0));
        let d = a.div(&b);
        assert_eq!((d.lo, d.hi), (-2.0, 3.0));
        assert!(a.div(&AbsVal::range(-1.0, 1.0)).nan); // zero in denominator
        let n = a.neg();
        assert_eq!((n.lo, n.hi), (-3.0, 2.0));
    }

    #[test]
    fn overflow_becomes_a_guaranteed_inf() {
        let big = AbsVal::range(1e30, 1e30);
        let sq = big.mul(&big); // 1e60 > f32::MAX everywhere
        assert!(sq.guaranteed_non_finite());
        assert!(sq.pinf && !sq.ninf && !sq.nan);
        // A symmetric blow-up is clamped, flagged, but NOT guaranteed.
        let sym = AbsVal::range(-1e30, 1e30).mul(&AbsVal::range(-1e30, 1e30));
        assert!(!sym.guaranteed_non_finite());
        assert!(sym.pinf && sym.ninf);
    }

    #[test]
    fn sqrt_of_negative_range_is_guaranteed_nan() {
        let v = sqrt_range(&AbsVal::range(-9.0, -1.0));
        assert!(v.guaranteed_non_finite() && v.nan);
        // Straddling zero: may-NaN but not guaranteed.
        let v = sqrt_range(&AbsVal::range(-1.0, 4.0));
        assert!(!v.guaranteed_non_finite() && v.nan);
        assert_eq!((v.lo, v.hi), (0.0, 2.0));
    }

    #[test]
    fn relu_launders_nan_relu6_keeps_it() {
        let poison = AbsVal { lo: 1.0, hi: 2.0, nan: true, pinf: false, ninf: true };
        let r = act_range(Act::Relu, &poison);
        assert!(!r.nan && !r.ninf);
        assert_eq!((r.lo, r.hi), (0.0, 2.0)); // NaN/-inf land on 0
        let r6 = act_range(Act::Relu6, &poison);
        assert!(r6.nan && !r6.ninf);
        let g = act_range(Act::Gelu, &poison);
        assert!(g.nan); // gelu(-inf) = NaN
    }

    #[test]
    fn saturating_activations_absorb_infinities() {
        let wild = AbsVal { lo: -5.0, hi: 5.0, nan: false, pinf: true, ninf: true };
        let s = act_range(Act::Sigmoid, &wild);
        assert!(s.is_finite());
        assert!(s.lo >= 0.0 && s.hi <= 1.0);
        let t = act_range(Act::Tanh, &wild);
        assert!(t.is_finite() && t.lo >= -1.0 && t.hi <= 1.0);
    }

    #[test]
    fn pow_of_strictly_negative_base_with_half_exponent_is_nan() {
        let v = pow_range(&AbsVal::range(-8.0, -2.0), 0.5);
        assert!(v.guaranteed_non_finite() && v.nan);
        let v = pow_range(&AbsVal::range(2.0, 3.0), 2.0);
        assert_eq!((v.lo, v.hi), (4.0, 9.0));
        assert!(v.is_finite());
    }

    #[test]
    fn from_data_scans_flags_and_bounds() {
        let v = AbsVal::from_data(&[1.0, -3.5, f32::NAN, 2.0]);
        assert!(v.nan && !v.pinf && !v.ninf);
        assert_eq!((v.lo, v.hi), (-3.5, 2.0));
        let v = AbsVal::from_data(&[f32::INFINITY; 4]);
        assert!(v.guaranteed_non_finite() && v.pinf);
    }

    #[test]
    fn join_is_hull_plus_flag_union() {
        let a = AbsVal::range(0.0, 1.0);
        let b = AbsVal { lo: 5.0, hi: 6.0, nan: true, pinf: false, ninf: false };
        let j = a.join(&b);
        assert_eq!((j.lo, j.hi), (0.0, 6.0));
        assert!(j.nan);
        assert_eq!(AbsVal::bottom().join(&a), a);
    }
}
