//! Pattern-based pruning (§2.1.1, Fig 4): every 3×3 kernel keeps exactly 4
//! weights, and the surviving positions must form one of a small set of
//! pre-defined *patterns*. The fixed pattern vocabulary is what makes the
//! sparsity compiler-friendly: the code generator emits one branch-less
//! unrolled body per pattern (see [`crate::fkw`] and [`crate::codegen`]).

use crate::tensor::Tensor;

/// A 4-entry pattern over a 3×3 kernel: a 9-bit mask with popcount 4.
/// Bit i corresponds to kernel position (i/3, i%3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Pattern(pub u16);

impl Pattern {
    pub const ENTRIES: usize = 4;

    pub fn new(mask: u16) -> Pattern {
        assert_eq!(mask & !0x1FF, 0, "mask beyond 9 bits");
        assert_eq!(mask.count_ones(), Self::ENTRIES as u32, "pattern must keep 4 entries");
        Pattern(mask)
    }

    pub fn keeps(&self, pos: usize) -> bool {
        debug_assert!(pos < 9);
        self.0 >> pos & 1 == 1
    }

    /// Kept positions in ascending order (always 4 of them).
    pub fn positions(&self) -> [usize; 4] {
        let mut out = [0usize; 4];
        let mut j = 0;
        for pos in 0..9 {
            if self.keeps(pos) {
                out[j] = pos;
                j += 1;
            }
        }
        debug_assert_eq!(j, 4);
        out
    }
}

/// The pattern vocabulary used by the compiler. PatDNN-style *elite*
/// sets: all patterns keep the central weight (position 4) — consistent
/// with the paper's observation that good patterns resemble Gaussian
/// filters around the kernel center — plus 3 of the 8 surrounding
/// positions.
#[derive(Debug, Clone)]
pub struct PatternSet {
    pub patterns: Vec<Pattern>,
}

impl PatternSet {
    /// The canonical 8-pattern elite set (center + 3 neighbours forming an
    /// L/T around the center, one per orientation).
    pub fn elite8() -> PatternSet {
        // Positions: 0 1 2 / 3 4 5 / 6 7 8, center = 4.
        let masks: [[usize; 4]; 8] = [
            [1, 3, 4, 0], // top-left elbow
            [1, 5, 4, 2], // top-right elbow
            [3, 7, 4, 6], // bottom-left elbow
            [5, 7, 4, 8], // bottom-right elbow
            [1, 3, 4, 5], // T up
            [3, 7, 4, 5], // T down... (orientations of a 3-neighbour tee)
            [1, 4, 7, 3], // T left
            [1, 4, 7, 5], // T right
        ];
        let patterns = masks
            .iter()
            .map(|ps| {
                let mut m = 0u16;
                for &p in ps {
                    m |= 1 << p;
                }
                Pattern::new(m)
            })
            .collect();
        PatternSet { patterns }
    }

    /// Smaller 4-pattern set (tighter vocabulary = more reorder benefit,
    /// slightly worse accuracy; CAPS searches over this knob).
    pub fn elite4() -> PatternSet {
        PatternSet { patterns: PatternSet::elite8().patterns[..4].to_vec() }
    }

    /// Select the `n` most valuable patterns for a concrete weight tensor:
    /// rank all 126 4-of-9 masks by total preserved magnitude over every
    /// kernel, greedily keep the top `n` (the "extended ADMM-based
    /// framework" searches this space; magnitude ranking is its first
    /// phase).
    pub fn select_for(weights: &Tensor, n: usize) -> PatternSet {
        assert_eq!(weights.rank(), 4);
        assert_eq!(weights.shape()[2], 3);
        assert_eq!(weights.shape()[3], 3);
        let mut scores: Vec<(f64, u16)> = all_4of9()
            .into_iter()
            .map(|m| (0.0f64, m))
            .collect();
        let (o, i) = (weights.shape()[0], weights.shape()[1]);
        for f in 0..o {
            for c in 0..i {
                let k = kernel9(weights, f, c);
                for (score, mask) in scores.iter_mut() {
                    let mut s = 0.0;
                    for pos in 0..9 {
                        if *mask >> pos & 1 == 1 {
                            s += (k[pos] * k[pos]) as f64;
                        }
                    }
                    *score += s;
                }
            }
        }
        scores.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        PatternSet {
            patterns: scores.into_iter().take(n).map(|(_, m)| Pattern::new(m)).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.patterns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.patterns.is_empty()
    }
}

/// All C(9,4)=126 4-entry masks.
pub fn all_4of9() -> Vec<u16> {
    let mut v = Vec::with_capacity(126);
    for m in 0u16..512 {
        if m.count_ones() == 4 {
            v.push(m);
        }
    }
    v
}

/// Per-kernel pattern assignment for an OIHW conv weight (3×3 kernels):
/// `assignment[f][c]` = index into the pattern set.
#[derive(Debug, Clone)]
pub struct PatternAssignment {
    pub set: PatternSet,
    pub assignment: Vec<Vec<usize>>,
    /// Kernels removed entirely by connectivity pruning (f, c).
    pub pruned_kernels: Vec<Vec<bool>>,
}

impl PatternAssignment {
    /// Pattern of kernel (f, c).
    pub fn pattern(&self, f: usize, c: usize) -> Pattern {
        self.set.patterns[self.assignment[f][c]]
    }

    pub fn is_kernel_pruned(&self, f: usize, c: usize) -> bool {
        self.pruned_kernels[f][c]
    }

    /// Overall weight sparsity achieved (fraction zeroed).
    pub fn sparsity(&self) -> f64 {
        let total: usize = self.assignment.iter().map(|r| r.len() * 9).sum();
        let mut kept = 0usize;
        for (f, row) in self.assignment.iter().enumerate() {
            for (c, _) in row.iter().enumerate() {
                if !self.pruned_kernels[f][c] {
                    kept += 4;
                }
            }
        }
        1.0 - kept as f64 / total as f64
    }
}

fn kernel9(w: &Tensor, f: usize, c: usize) -> [f32; 9] {
    let mut k = [0.0f32; 9];
    for y in 0..3 {
        for x in 0..3 {
            k[y * 3 + x] = w.at(&[f, c, y, x]);
        }
    }
    k
}

/// Assign each kernel the pattern preserving the most energy (squared
/// magnitude) — the projection step of the ADMM framework.
pub fn assign_patterns(weights: &Tensor, set: &PatternSet) -> PatternAssignment {
    assert_eq!(weights.rank(), 4, "OIHW expected");
    assert_eq!(weights.shape()[2], 3, "pattern pruning needs 3x3 kernels");
    assert_eq!(weights.shape()[3], 3);
    let (o, i) = (weights.shape()[0], weights.shape()[1]);
    let mut assignment = vec![vec![0usize; i]; o];
    for f in 0..o {
        for c in 0..i {
            let k = kernel9(weights, f, c);
            let mut best = (f64::NEG_INFINITY, 0usize);
            for (pi, p) in set.patterns.iter().enumerate() {
                let s: f64 = p
                    .positions()
                    .iter()
                    .map(|&pos| (k[pos] * k[pos]) as f64)
                    .sum();
                if s > best.0 {
                    best = (s, pi);
                }
            }
            assignment[f][c] = best.1;
        }
    }
    PatternAssignment {
        set: set.clone(),
        assignment,
        pruned_kernels: vec![vec![false; i]; o],
    }
}

/// Connectivity pruning (Fig 4b): additionally remove whole kernels with
/// the smallest post-pattern energy until `rate` of kernels are cut.
pub fn connectivity_prune(weights: &Tensor, asg: &mut PatternAssignment, rate: f64) {
    assert!((0.0..1.0).contains(&rate));
    let (o, i) = (weights.shape()[0], weights.shape()[1]);
    let mut energies: Vec<(f64, usize, usize)> = Vec::with_capacity(o * i);
    for f in 0..o {
        for c in 0..i {
            let k = kernel9(weights, f, c);
            let p = asg.pattern(f, c);
            let e: f64 = p.positions().iter().map(|&pos| (k[pos] * k[pos]) as f64).sum();
            energies.push((e, f, c));
        }
    }
    energies.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let cut = (energies.len() as f64 * rate).round() as usize;
    for &(_, f, c) in energies.iter().take(cut) {
        asg.pruned_kernels[f][c] = true;
    }
}

/// Materialize the assignment: zero all weights outside their kernel's
/// pattern (and whole kernels cut by connectivity pruning).
pub fn apply_assignment(weights: &Tensor, asg: &PatternAssignment) -> Tensor {
    let mut out = weights.clone();
    let (o, i) = (weights.shape()[0], weights.shape()[1]);
    for f in 0..o {
        for c in 0..i {
            let p = asg.pattern(f, c);
            for pos in 0..9 {
                let zero = asg.is_kernel_pruned(f, c) || !p.keeps(pos);
                if zero {
                    out.set(&[f, c, pos / 3, pos % 3], 0.0);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::forall;
    use crate::util::rng::Rng;

    #[test]
    fn all_4of9_has_126_masks() {
        assert_eq!(all_4of9().len(), 126);
    }

    #[test]
    fn elite_sets_keep_center() {
        for p in PatternSet::elite8().patterns {
            assert!(p.keeps(4), "pattern {:#b} drops the center", p.0);
        }
        assert_eq!(PatternSet::elite4().len(), 4);
    }

    #[test]
    fn assignment_preserves_best_energy() {
        forall("pattern choice maximizes preserved energy", 24, |rng| {
            let w = Tensor::randn(&[2, 3, 3, 3], 1.0, rng);
            let set = PatternSet::elite8();
            let asg = assign_patterns(&w, &set);
            let pruned = apply_assignment(&w, &asg);
            // Chosen pattern's preserved energy >= any other pattern's.
            for f in 0..2 {
                for c in 0..3 {
                    let kept: f64 = (0..9)
                        .map(|pos| {
                            let v = pruned.at(&[f, c, pos / 3, pos % 3]);
                            (v * v) as f64
                        })
                        .sum();
                    for p in &set.patterns {
                        let alt: f64 = p
                            .positions()
                            .iter()
                            .map(|&pos| {
                                let v = w.at(&[f, c, pos / 3, pos % 3]);
                                (v * v) as f64
                            })
                            .sum();
                        assert!(kept >= alt - 1e-9, "suboptimal pattern chosen");
                    }
                }
            }
        });
    }

    #[test]
    fn pattern_sparsity_is_5_of_9() {
        let mut rng = Rng::new(3);
        let w = Tensor::randn(&[8, 4, 3, 3], 1.0, &mut rng);
        let asg = assign_patterns(&w, &PatternSet::elite8());
        let pruned = apply_assignment(&w, &asg);
        let zf = pruned.zero_fraction();
        assert!((zf - 5.0 / 9.0).abs() < 1e-6, "zero fraction {zf}");
        assert!((asg.sparsity() - 5.0 / 9.0).abs() < 1e-6);
    }

    #[test]
    fn connectivity_adds_sparsity() {
        let mut rng = Rng::new(4);
        let w = Tensor::randn(&[8, 8, 3, 3], 1.0, &mut rng);
        let mut asg = assign_patterns(&w, &PatternSet::elite8());
        connectivity_prune(&w, &mut asg, 0.5);
        let pruned = apply_assignment(&w, &asg);
        // 50% kernels fully cut: sparsity = 5/9 + 0.5*4/9 = 7/9.
        assert!((pruned.zero_fraction() - 7.0 / 9.0).abs() < 0.01);
    }

    #[test]
    fn select_for_prefers_high_energy_positions() {
        // Construct weights whose energy is concentrated in positions
        // {0,1,3,4}; the top selected pattern must be exactly that mask.
        let mut w = Tensor::zeros(&[4, 4, 3, 3]);
        for f in 0..4 {
            for c in 0..4 {
                for &pos in &[0usize, 1, 3, 4] {
                    w.set(&[f, c, pos / 3, pos % 3], 1.0);
                }
            }
        }
        let set = PatternSet::select_for(&w, 1);
        let expect = Pattern::new(1 << 0 | 1 << 1 | 1 << 3 | 1 << 4);
        assert_eq!(set.patterns[0], expect);
    }

    #[test]
    #[should_panic]
    fn pattern_rejects_wrong_popcount() {
        Pattern::new(0b111);
    }
}
