//! ADMM-based pruning search (§2.1: "The selection of appropriate patterns
//! … can be achieved via search through an extended ADMM-based framework";
//! §2.1.2: "We have extended the ADMM-based pruning algorithm to
//! automatically determine the block-based sparsity").
//!
//! The pruning problem is `min_W L(W)  s.t.  W ∈ C` where `C` is the
//! (non-convex) constraint set of a sparsity scheme. ADMM splits it into a
//! proximal update on `W` and a Euclidean projection onto `C`:
//!
//! ```text
//! W^{k+1} = argmin_W  L(W) + ρ/2 ||W − Z^k + U^k||²
//! Z^{k+1} = Π_C(W^{k+1} + U^k)
//! U^{k+1} = U^k + W^{k+1} − Z^{k+1}
//! ```
//!
//! Without the original training set (see DESIGN.md substitutions) we use
//! the quadratic surrogate `L(W) = ½||W − W₀||²_H` with a diagonal
//! curvature estimate `H` (per-weight saliency), which makes the W-step
//! closed-form while preserving the algorithm's structure, its convergence
//! diagnostics, and the role of ρ.

use crate::tensor::Tensor;

use super::block::{block_prune, magnitude_prune, BlockPruneConfig};
use super::pattern::{apply_assignment, assign_patterns, PatternSet};

/// A Euclidean projector onto a sparsity constraint set.
pub trait Projector {
    /// Project `w` onto the constraint set (zero the disallowed entries,
    /// re-deciding the support for the *current* w).
    fn project(&self, w: &Tensor) -> Tensor;
    /// Human-readable name for logs.
    fn name(&self) -> &'static str;
}

/// Pattern-based constraint: every 3×3 kernel keeps 4 entries forming a
/// pattern from the set.
pub struct PatternProjector {
    pub set: PatternSet,
}

impl Projector for PatternProjector {
    fn project(&self, w: &Tensor) -> Tensor {
        let asg = assign_patterns(w, &self.set);
        apply_assignment(w, &asg)
    }
    fn name(&self) -> &'static str {
        "pattern"
    }
}

/// Block row/column constraint at a given rate.
pub struct BlockProjector {
    pub cfg: BlockPruneConfig,
}

impl Projector for BlockProjector {
    fn project(&self, w: &Tensor) -> Tensor {
        let m = super::block::conv_weight_as_matrix(w);
        let mask = block_prune(&m, &self.cfg);
        mask.apply(&m).reshape(w.shape())
    }
    fn name(&self) -> &'static str {
        "block"
    }
}

/// Unstructured magnitude constraint at a given rate.
pub struct MagnitudeProjector {
    pub rate: f64,
}

impl Projector for MagnitudeProjector {
    fn project(&self, w: &Tensor) -> Tensor {
        let m = super::block::conv_weight_as_matrix(w);
        let mask = magnitude_prune(&m, self.rate);
        mask.apply(&m).reshape(w.shape())
    }
    fn name(&self) -> &'static str {
        "magnitude"
    }
}

/// ADMM hyper-parameters.
#[derive(Debug, Clone, Copy)]
pub struct AdmmConfig {
    pub rho: f32,
    pub iters: usize,
    /// Stop early when the primal residual ‖W−Z‖ drops below this.
    pub tol: f32,
}

impl Default for AdmmConfig {
    fn default() -> Self {
        AdmmConfig { rho: 1.5, iters: 30, tol: 1e-5 }
    }
}

/// Result of an ADMM run.
pub struct AdmmResult {
    /// Final constrained weights (exactly feasible: last Z).
    pub weights: Tensor,
    /// Primal residual per iteration (‖W−Z‖₂ / √n).
    pub residuals: Vec<f32>,
    pub iterations: usize,
}

/// Run ADMM pruning on `w0` with per-weight saliency `h` (pass `None` for
/// uniform curvature).
pub fn admm_prune(
    w0: &Tensor,
    h: Option<&Tensor>,
    proj: &dyn Projector,
    cfg: &AdmmConfig,
) -> AdmmResult {
    let n = w0.len().max(1);
    let ones;
    let h = match h {
        Some(t) => {
            assert_eq!(t.shape(), w0.shape());
            t
        }
        None => {
            ones = Tensor::full(w0.shape(), 1.0);
            &ones
        }
    };
    let mut w = w0.clone();
    let mut z = proj.project(&w);
    let mut u = Tensor::zeros(w0.shape());
    let mut residuals = Vec::new();
    let rho = cfg.rho;
    let mut iters = 0;
    for _ in 0..cfg.iters {
        iters += 1;
        // W-step (closed form for the quadratic surrogate):
        // w = (h .* w0 + rho (z - u)) ./ (h + rho)
        {
            let wd = w.data_mut();
            for i in 0..n {
                let hi = h.data()[i].max(1e-6);
                wd[i] = (hi * w0.data()[i] + rho * (z.data()[i] - u.data()[i])) / (hi + rho);
            }
        }
        // Z-step: projection.
        let wu = w.add(&u);
        z = proj.project(&wu);
        // Dual update + residual.
        let mut res = 0.0f64;
        {
            let ud = u.data_mut();
            for i in 0..n {
                let d = w.data()[i] - z.data()[i];
                ud[i] += d;
                res += (d * d) as f64;
            }
        }
        let res = (res / n as f64).sqrt() as f32;
        residuals.push(res);
        if res < cfg.tol {
            break;
        }
    }
    AdmmResult { weights: z, residuals, iterations: iters }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn admm_pattern_result_is_feasible() {
        let mut rng = Rng::new(11);
        let w0 = Tensor::randn(&[8, 4, 3, 3], 1.0, &mut rng);
        let proj = PatternProjector { set: PatternSet::elite8() };
        let r = admm_prune(&w0, None, &proj, &AdmmConfig::default());
        // Feasible: exactly 4 of 9 nonzero per kernel.
        for f in 0..8 {
            for c in 0..4 {
                let nz = (0..9)
                    .filter(|&p| r.weights.at(&[f, c, p / 3, p % 3]) != 0.0)
                    .count();
                assert!(nz <= 4, "kernel ({f},{c}) has {nz} nonzeros");
            }
        }
    }

    #[test]
    fn residuals_decrease() {
        let mut rng = Rng::new(12);
        let w0 = Tensor::randn(&[16, 8, 3, 3], 1.0, &mut rng);
        let proj = MagnitudeProjector { rate: 0.8 };
        let r = admm_prune(&w0, None, &proj, &AdmmConfig { rho: 1.0, iters: 25, tol: 0.0 });
        assert!(r.residuals.len() >= 10);
        let first = r.residuals[0];
        let last = *r.residuals.last().unwrap();
        assert!(last < first * 0.5, "residuals did not shrink: {first} -> {last}");
    }

    #[test]
    fn admm_beats_naive_projection_under_saliency() {
        // With non-uniform curvature, ADMM should retain more *salient*
        // energy than one-shot projection of w0.
        let mut rng = Rng::new(13);
        let w0 = Tensor::randn(&[12, 6, 3, 3], 1.0, &mut rng);
        // Saliency: huge on a random 20%, small elsewhere.
        let mut h = Tensor::full(w0.shape(), 0.05);
        for i in 0..h.len() {
            if rng.chance(0.2) {
                h.data_mut()[i] = 50.0;
            }
        }
        let proj = MagnitudeProjector { rate: 0.75 };
        let admm = admm_prune(&w0, Some(&h), &proj, &AdmmConfig { rho: 0.5, iters: 40, tol: 0.0 });
        let naive = proj.project(&w0);
        let weighted = |t: &Tensor| -> f64 {
            t.data()
                .iter()
                .zip(h.data())
                .map(|(&v, &s)| (s * v * v) as f64)
                .sum()
        };
        assert!(
            weighted(&admm.weights) >= weighted(&naive) * 0.999,
            "admm {} < naive {}",
            weighted(&admm.weights),
            weighted(&naive)
        );
    }

    #[test]
    fn block_projector_feasible_rate() {
        let mut rng = Rng::new(14);
        let w0 = Tensor::randn(&[16, 8, 3, 3], 1.0, &mut rng);
        let proj = BlockProjector { cfg: BlockPruneConfig::six_x(8) };
        let r = admm_prune(&w0, None, &proj, &AdmmConfig::default());
        let zf = r.weights.zero_fraction();
        assert!(zf > 0.7, "block admm sparsity {zf}");
    }
}
