//! Block-based pruning (§2.1.2, Figs 5–7): partition a weight matrix (the
//! GEMM form of any CONV/FC/attention layer) into `br×bc` blocks and apply
//! *independent* column pruning and row pruning inside each block. Whole-
//! matrix blocks degenerate to coarse structured pruning; tiny blocks
//! approach non-structured pruning — Fig 6 sweeps exactly this knob.
//! 3-D convolutions reduce to the same GEMM matrix (Fig 7), so this module
//! covers them too.

use crate::tensor::Tensor;

/// Block pruning configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BlockPruneConfig {
    /// Block height (rows per block); `usize::MAX` = whole matrix.
    pub block_rows: usize,
    /// Block width (columns per block); `usize::MAX` = whole matrix.
    pub block_cols: usize,
    /// Target fraction of weights removed (e.g. 6× pruning → 1 - 1/6).
    pub prune_rate: f64,
}

impl BlockPruneConfig {
    /// The paper's "uniform 6× pruning rate".
    pub fn six_x(block: usize) -> BlockPruneConfig {
        BlockPruneConfig { block_rows: block, block_cols: block, prune_rate: 1.0 - 1.0 / 6.0 }
    }
}

/// The row/column keep-masks per block, from which both the pruned matrix
/// and the compact execution format are derived.
#[derive(Debug, Clone)]
pub struct BlockMask {
    pub rows: usize,
    pub cols: usize,
    pub block_rows: usize,
    pub block_cols: usize,
    /// keep[r][c] for the full matrix (expanded form).
    keep: Vec<bool>,
}

impl BlockMask {
    pub fn keeps(&self, r: usize, c: usize) -> bool {
        self.keep[r * self.cols + c]
    }

    pub fn sparsity(&self) -> f64 {
        let kept = self.keep.iter().filter(|&&k| k).count();
        1.0 - kept as f64 / self.keep.len() as f64
    }

    /// Apply to a matrix tensor `[rows, cols]`.
    pub fn apply(&self, m: &Tensor) -> Tensor {
        assert_eq!(m.shape(), &[self.rows, self.cols]);
        let mut out = m.clone();
        for r in 0..self.rows {
            for c in 0..self.cols {
                if !self.keeps(r, c) {
                    out.set(&[r, c], 0.0);
                }
            }
        }
        out
    }
}

/// Compute the block row/column pruning mask for matrix `m` ([rows, cols]):
/// within each block, rows and columns are ranked by L2 norm and the
/// weakest are dropped; the split between row- and column-pruning is chosen
/// per block to maximize retained energy at the target rate.
pub fn block_prune(m: &Tensor, cfg: &BlockPruneConfig) -> BlockMask {
    assert_eq!(m.rank(), 2, "block pruning works on the GEMM matrix form");
    let (rows, cols) = (m.shape()[0], m.shape()[1]);
    let br = cfg.block_rows.min(rows).max(1);
    let bc = cfg.block_cols.min(cols).max(1);
    let mut keep = vec![true; rows * cols];

    for r0 in (0..rows).step_by(br) {
        for c0 in (0..cols).step_by(bc) {
            let rh = br.min(rows - r0);
            let cw = bc.min(cols - c0);
            // Row and column energies within the block.
            let mut row_e = vec![0.0f64; rh];
            let mut col_e = vec![0.0f64; cw];
            for r in 0..rh {
                for c in 0..cw {
                    let v = m.at(&[r0 + r, c0 + c]) as f64;
                    row_e[r] += v * v;
                    col_e[c] += v * v;
                }
            }
            // Choose (#rows cut, #cols cut) maximizing retained energy under
            // the rate constraint: kept_fraction = (1-ra)(1-ca) where ra,ca
            // are the cut fractions. Enumerate row cuts; derive column cuts.
            let target_keep = 1.0 - cfg.prune_rate;
            let mut ranked_rows: Vec<usize> = (0..rh).collect();
            ranked_rows.sort_by(|&a, &b| row_e[a].partial_cmp(&row_e[b]).unwrap());
            let mut ranked_cols: Vec<usize> = (0..cw).collect();
            ranked_cols.sort_by(|&a, &b| col_e[a].partial_cmp(&col_e[b]).unwrap());
            let total_e: f64 = row_e.iter().sum();

            let mut best = (f64::NEG_INFINITY, 0usize, 0usize);
            for rcut in 0..rh {
                let rows_kept = rh - rcut;
                // Columns to cut so that kept fraction <= target.
                let need_cols_kept =
                    ((target_keep * (rh * cw) as f64) / rows_kept as f64).floor() as usize;
                let cols_kept = need_cols_kept.min(cw);
                if cols_kept == 0 {
                    continue;
                }
                let ccut = cw - cols_kept;
                // Retained energy estimate: energy of kept rows × fraction
                // of kept column energy.
                let kept_row_e: f64 = ranked_rows[rcut..].iter().map(|&r| row_e[r]).sum();
                let kept_col_e: f64 = ranked_cols[ccut..].iter().map(|&c| col_e[c]).sum();
                let score = if total_e > 0.0 {
                    kept_row_e / total_e.max(1e-12) * (kept_col_e / total_e.max(1e-12))
                } else {
                    0.0
                };
                if score > best.0 {
                    best = (score, rcut, ccut);
                }
            }
            let (_, rcut, ccut) = best;
            for &r in ranked_rows.iter().take(rcut) {
                for c in 0..cw {
                    keep[(r0 + r) * cols + (c0 + c)] = false;
                }
            }
            for &c in ranked_cols.iter().take(ccut) {
                for r in 0..rh {
                    keep[(r0 + r) * cols + (c0 + c)] = false;
                }
            }
        }
    }
    BlockMask { rows, cols, block_rows: br, block_cols: bc, keep }
}

/// Non-structured magnitude pruning baseline (Fig 6 leftmost point): keep
/// the largest-magnitude `1-rate` fraction of individual weights.
pub fn magnitude_prune(m: &Tensor, rate: f64) -> BlockMask {
    assert_eq!(m.rank(), 2);
    let (rows, cols) = (m.shape()[0], m.shape()[1]);
    let mut idx: Vec<usize> = (0..rows * cols).collect();
    idx.sort_by(|&a, &b| {
        m.data()[a]
            .abs()
            .partial_cmp(&m.data()[b].abs())
            .unwrap()
    });
    let cut = (idx.len() as f64 * rate).round() as usize;
    let mut keep = vec![true; rows * cols];
    for &i in idx.iter().take(cut) {
        keep[i] = false;
    }
    BlockMask { rows, cols, block_rows: 1, block_cols: 1, keep }
}

/// Reshape an OIHW (or OIDHW) conv weight to its GEMM matrix [O, I*K...].
pub fn conv_weight_as_matrix(w: &Tensor) -> Tensor {
    assert!(w.rank() >= 2);
    let o = w.shape()[0];
    let rest: usize = w.shape()[1..].iter().product();
    w.reshape(&[o, rest])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::forall;
    use crate::util::rng::Rng;

    #[test]
    fn rate_respected_within_tolerance() {
        forall("block prune hits target rate", 20, |rng| {
            let rows = 8 + rng.below(32);
            let cols = 8 + rng.below(32);
            let m = Tensor::randn(&[rows, cols], 1.0, rng);
            let block = *rng.choose(&[4usize, 8, 16]);
            let cfg = BlockPruneConfig { block_rows: block, block_cols: block, prune_rate: 0.75 };
            let mask = block_prune(&m, &cfg);
            let s = mask.sparsity();
            assert!(s >= 0.70 && s <= 0.95, "sparsity {s} for target 0.75");
        });
    }

    #[test]
    fn whole_matrix_block_prunes_full_rows_or_cols() {
        let mut rng = Rng::new(7);
        let m = Tensor::randn(&[16, 16], 1.0, &mut rng);
        let cfg = BlockPruneConfig {
            block_rows: usize::MAX,
            block_cols: usize::MAX,
            prune_rate: 0.5,
        };
        let mask = block_prune(&m, &cfg);
        // The survivor set must be rectangular: keep = row_keep ⊗ col_keep.
        let row_keep: Vec<bool> =
            (0..16).map(|r| (0..16).any(|c| mask.keeps(r, c))).collect();
        let col_keep: Vec<bool> =
            (0..16).map(|c| (0..16).any(|r| mask.keeps(r, c))).collect();
        for r in 0..16 {
            for c in 0..16 {
                assert_eq!(
                    mask.keeps(r, c),
                    row_keep[r] && col_keep[c],
                    "non-rectangular survivors at ({r},{c})"
                );
            }
        }
    }

    #[test]
    fn magnitude_prune_keeps_largest() {
        let m = Tensor::from_vec(&[2, 2], vec![0.1, -5.0, 3.0, 0.2]);
        let mask = magnitude_prune(&m, 0.5);
        assert!(!mask.keeps(0, 0));
        assert!(mask.keeps(0, 1));
        assert!(mask.keeps(1, 0));
        assert!(!mask.keeps(1, 1));
    }

    #[test]
    fn block_prune_retains_more_energy_than_structured() {
        // Key Fig 6 mechanism: at equal rate, smaller blocks retain >= the
        // energy of whole-matrix (structured) pruning.
        forall("blocks retain >= structured energy", 12, |rng| {
            let m = Tensor::randn(&[32, 32], 1.0, rng);
            let rate = 1.0 - 1.0 / 6.0;
            let fine = block_prune(&m, &BlockPruneConfig { block_rows: 4, block_cols: 4, prune_rate: rate });
            let coarse = block_prune(
                &m,
                &BlockPruneConfig { block_rows: usize::MAX, block_cols: usize::MAX, prune_rate: rate },
            );
            let e = |mask: &BlockMask| -> f64 {
                let t = mask.apply(&m);
                t.data().iter().map(|&v| (v * v) as f64).sum()
            };
            assert!(
                e(&fine) >= e(&coarse) * 0.98,
                "fine {} < coarse {}",
                e(&fine),
                e(&coarse)
            );
        });
    }

    #[test]
    fn conv_weight_matrix_shape() {
        let w = Tensor::zeros(&[8, 4, 3, 3]);
        let m = conv_weight_as_matrix(&w);
        assert_eq!(m.shape(), &[8, 36]);
        // 3-D conv weight reduces the same way (Fig 7).
        let w3 = Tensor::zeros(&[8, 4, 3, 3, 3]);
        assert_eq!(conv_weight_as_matrix(&w3).shape(), &[8, 108]);
    }

    #[test]
    fn apply_zeroes_only_pruned() {
        let mut rng = Rng::new(9);
        let m = Tensor::randn(&[8, 8], 1.0, &mut rng);
        let mask = block_prune(&m, &BlockPruneConfig::six_x(4));
        let pruned = mask.apply(&m);
        for r in 0..8 {
            for c in 0..8 {
                if mask.keeps(r, c) {
                    assert_eq!(pruned.at(&[r, c]), m.at(&[r, c]));
                } else {
                    assert_eq!(pruned.at(&[r, c]), 0.0);
                }
            }
        }
    }
}
