//! Quantization — the "compatible model compression technique" of §2.1 and
//! the "optimized quantization" bar of Fig 19 (MCU experiment). Symmetric
//! int8 with either one scale per tensor (baseline, what TFLM's CMSIS-NN
//! path uses) or one scale per output channel (XGen's optimized variant —
//! better accuracy at the same bit width, and the form the MCU codegen
//! exploits).

use crate::tensor::Tensor;

/// Quantization granularity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantMode {
    PerTensor,
    PerChannel,
}

/// A quantized tensor: int8 payload + scale(s).
#[derive(Debug, Clone)]
pub struct QuantTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i8>,
    /// One scale (per-tensor) or `shape[0]` scales (per-channel).
    pub scales: Vec<f32>,
    pub mode: QuantMode,
}

impl QuantTensor {
    /// Bytes of storage (payload + scales).
    pub fn bytes(&self) -> usize {
        self.data.len() + 4 * self.scales.len()
    }
}

/// Quantize symmetric int8.
pub fn quantize(t: &Tensor, mode: QuantMode) -> QuantTensor {
    match mode {
        QuantMode::PerTensor => {
            let amax = t.data().iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
            let data = t.data().iter().map(|&v| quant1(v, scale)).collect();
            QuantTensor { shape: t.shape().to_vec(), data, scales: vec![scale], mode }
        }
        QuantMode::PerChannel => {
            assert!(t.rank() >= 2, "per-channel wants >=2-d weights");
            let ch = t.shape()[0];
            let per = t.len() / ch;
            let mut scales = Vec::with_capacity(ch);
            let mut data = Vec::with_capacity(t.len());
            for c in 0..ch {
                let row = &t.data()[c * per..(c + 1) * per];
                let amax = row.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
                scales.push(scale);
                data.extend(row.iter().map(|&v| quant1(v, scale)));
            }
            QuantTensor { shape: t.shape().to_vec(), data, scales, mode }
        }
    }
}

fn quant1(v: f32, scale: f32) -> i8 {
    (v / scale).round().clamp(-127.0, 127.0) as i8
}

/// Dequantize back to f32.
pub fn dequantize(q: &QuantTensor) -> Tensor {
    let n = q.data.len();
    let mut out = Vec::with_capacity(n);
    match q.mode {
        QuantMode::PerTensor => {
            let s = q.scales[0];
            out.extend(q.data.iter().map(|&v| v as f32 * s));
        }
        QuantMode::PerChannel => {
            let ch = q.scales.len();
            let per = n / ch;
            for c in 0..ch {
                let s = q.scales[c];
                out.extend(q.data[c * per..(c + 1) * per].iter().map(|&v| v as f32 * s));
            }
        }
    }
    Tensor::from_vec(&q.shape, out)
}

/// RMS quantization error of a round trip.
pub fn quant_rms_error(t: &Tensor, mode: QuantMode) -> f64 {
    let back = dequantize(&quantize(t, mode));
    let n = t.len().max(1);
    let s: f64 = t
        .data()
        .iter()
        .zip(back.data())
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum();
    (s / n as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::forall;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        forall("quant roundtrip bounded", 24, |rng| {
            let t = Tensor::randn(&[4, 16], 2.0, rng);
            let q = quantize(&t, QuantMode::PerTensor);
            let back = dequantize(&q);
            let step = q.scales[0];
            for (a, b) in t.data().iter().zip(back.data()) {
                assert!((a - b).abs() <= step * 0.5 + 1e-6);
            }
        });
    }

    #[test]
    fn per_channel_beats_per_tensor_on_mixed_ranges() {
        // Channel 0 tiny values, channel 1 huge: per-tensor wastes range.
        let mut rng = Rng::new(21);
        let mut data = Vec::new();
        data.extend(rng.normal_vec(64, 0.0, 0.01));
        data.extend(rng.normal_vec(64, 0.0, 10.0));
        let t = Tensor::from_vec(&[2, 64], data);
        // Overall RMS is dominated by the huge channel; the per-channel win
        // shows on the *small* channel's slice.
        let small_err = |mode| {
            let back = dequantize(&quantize(&t, mode));
            let s: f64 = t.data()[..64]
                .iter()
                .zip(&back.data()[..64])
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum();
            (s / 64.0).sqrt()
        };
        let e_t = small_err(QuantMode::PerTensor);
        let e_c = small_err(QuantMode::PerChannel);
        assert!(e_c < e_t * 0.1, "per-channel {e_c} vs per-tensor {e_t}");
    }

    #[test]
    fn storage_is_4x_smaller_than_f32() {
        let t = Tensor::zeros(&[8, 32]);
        let q = quantize(&t, QuantMode::PerChannel);
        assert!(q.bytes() * 3 < 8 * 32 * 4);
    }

    #[test]
    fn zeros_stay_zero() {
        let t = Tensor::zeros(&[3, 3]);
        let q = quantize(&t, QuantMode::PerTensor);
        assert!(q.data.iter().all(|&v| v == 0));
        assert_eq!(dequantize(&q), t);
    }
}
