//! Quantization — the "compatible model compression technique" of §2.1 and
//! the "optimized quantization" bar of Fig 19 (MCU experiment). Symmetric
//! int8 with either one scale per tensor (baseline, what TFLM's CMSIS-NN
//! path uses) or one scale per output channel (XGen's optimized variant —
//! better accuracy at the same bit width, and the form the MCU codegen
//! exploits).
//!
//! Every entry point returns `Result`: non-finite input is rejected with a
//! typed [`XgenError::NonFinite`] naming the offending channel (a NaN
//! weight would otherwise quantize to a silently-wrong 0 through the
//! saturating cast), and malformed shapes/payloads are rejected with
//! [`XgenError::ShapeMismatch`] instead of panicking or truncating. The
//! module is inside the xtask panic-hygiene ratchet's scope: zero
//! unwrap / expect / panic sites, tests included.

use crate::error::XgenError;
use crate::tensor::Tensor;
use anyhow::Result;

/// Quantization granularity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantMode {
    PerTensor,
    PerChannel,
}

/// A quantized tensor: int8 payload + scale(s).
#[derive(Debug, Clone)]
pub struct QuantTensor {
    pub shape: Vec<usize>,
    pub data: Vec<i8>,
    /// One scale (per-tensor) or `shape[0]` scales (per-channel).
    pub scales: Vec<f32>,
    pub mode: QuantMode,
}

impl QuantTensor {
    /// Bytes of storage (payload + scales).
    pub fn bytes(&self) -> usize {
        self.data.len() + 4 * self.scales.len()
    }
}

/// Absolute max of a slice, rejecting non-finite values with a typed
/// error naming `at` (the tensor or the channel the value sits in).
fn amax_checked(row: &[f32], at: impl Fn() -> String) -> Result<f32> {
    let mut amax = 0.0f32;
    for &v in row {
        if !v.is_finite() {
            return Err(XgenError::NonFinite { at: at() }.into());
        }
        amax = amax.max(v.abs());
    }
    Ok(amax)
}

/// Quantize symmetric int8. Non-finite input is a typed error, not a
/// silent zero.
pub fn quantize(t: &Tensor, mode: QuantMode) -> Result<QuantTensor> {
    match mode {
        QuantMode::PerTensor => {
            let amax = amax_checked(t.data(), || "quantize(per-tensor) input".into())?;
            let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
            let data = t.data().iter().map(|&v| quant1(v, scale)).collect();
            Ok(QuantTensor { shape: t.shape().to_vec(), data, scales: vec![scale], mode })
        }
        QuantMode::PerChannel => {
            if t.rank() < 2 {
                return Err(XgenError::ShapeMismatch {
                    expected: "rank >= 2 weights for per-channel quantization".into(),
                    got: format!("rank {} {:?}", t.rank(), t.shape()),
                }
                .into());
            }
            let ch = t.shape()[0];
            if ch == 0 || t.len() % ch != 0 {
                return Err(XgenError::ShapeMismatch {
                    expected: format!("len divisible by {ch} channels"),
                    got: format!("len {} {:?}", t.len(), t.shape()),
                }
                .into());
            }
            let per = t.len() / ch;
            let mut scales = Vec::with_capacity(ch);
            let mut data = Vec::with_capacity(t.len());
            for c in 0..ch {
                let row = &t.data()[c * per..(c + 1) * per];
                let amax = amax_checked(row, || format!("quantize(per-channel) channel {c}"))?;
                let scale = if amax > 0.0 { amax / 127.0 } else { 1.0 };
                scales.push(scale);
                data.extend(row.iter().map(|&v| quant1(v, scale)));
            }
            Ok(QuantTensor { shape: t.shape().to_vec(), data, scales, mode })
        }
    }
}

/// Quantize a contraction weight per *output channel*, normalized to the
/// row-major `[out_ch, k]` layout the int8 GEMM packs from.
///
/// - rank-2 Dense weights are stored `[in_f, out_f]` (output channels are
///   *columns*), so the data is transposed to `[out_f, in_f]` rows first —
///   raw `quantize(PerChannel)` on the stored layout would yield
///   per-*input* scales, which the dequant epilogue cannot apply.
/// - rank-4 OIHW conv weights already lead with the output channel; rows
///   are the flattened `[o, i*kh*kw]` filter matrix.
///
/// Both `analyze::quant` (feasibility planning) and `ExecState::prepack`
/// (the kernel's packed weights) call this one helper, so the plan's
/// per-channel scales and the scales the epilogue actually multiplies by
/// agree bitwise by construction.
pub fn quantize_gemm_weight(t: &Tensor) -> Result<QuantTensor> {
    match t.rank() {
        2 => {
            let (in_f, out_f) = (t.shape()[0], t.shape()[1]);
            let mut tr = vec![0.0f32; in_f * out_f];
            for r in 0..in_f {
                for c in 0..out_f {
                    tr[c * in_f + r] = t.data()[r * out_f + c];
                }
            }
            quantize(&Tensor::from_vec(&[out_f, in_f], tr), QuantMode::PerChannel)
        }
        4 => {
            let o = t.shape()[0];
            let cols = t.shape()[1] * t.shape()[2] * t.shape()[3];
            let mut q = quantize(t, QuantMode::PerChannel)?;
            q.shape = vec![o, cols];
            Ok(q)
        }
        _ => Err(XgenError::ShapeMismatch {
            expected: "rank-2 [in,out] or rank-4 OIHW contraction weight".into(),
            got: format!("rank {} {:?}", t.rank(), t.shape()),
        }
        .into()),
    }
}

/// One value, one scale: round-to-nearest, saturate at ±127. Callers have
/// already rejected non-finite input.
fn quant1(v: f32, scale: f32) -> i8 {
    (v / scale).round().clamp(-127.0, 127.0) as i8
}

/// Dequantize back to f32. A `QuantTensor` whose scales/payload/shape
/// disagree is a typed error — the old truncating `n / ch` silently
/// dropped trailing elements.
pub fn dequantize(q: &QuantTensor) -> Result<Tensor> {
    let n = q.data.len();
    let shape_elems: usize = q.shape.iter().product();
    if shape_elems != n {
        return Err(XgenError::ShapeMismatch {
            expected: format!("payload of {shape_elems} elements for shape {:?}", q.shape),
            got: format!("{n} elements"),
        }
        .into());
    }
    let mut out = Vec::with_capacity(n);
    match q.mode {
        QuantMode::PerTensor => {
            let s = match q.scales.as_slice() {
                [s] => *s,
                _ => {
                    return Err(XgenError::ShapeMismatch {
                        expected: "exactly 1 per-tensor scale".into(),
                        got: format!("{} scales", q.scales.len()),
                    }
                    .into())
                }
            };
            out.extend(q.data.iter().map(|&v| v as f32 * s));
        }
        QuantMode::PerChannel => {
            let ch = q.scales.len();
            if ch == 0 || n % ch != 0 {
                return Err(XgenError::ShapeMismatch {
                    expected: format!("payload divisible into {ch} channels"),
                    got: format!("{n} elements"),
                }
                .into());
            }
            let per = n / ch;
            for c in 0..ch {
                let s = q.scales[c];
                out.extend(q.data[c * per..(c + 1) * per].iter().map(|&v| v as f32 * s));
            }
        }
    }
    Ok(Tensor::from_vec(&q.shape, out))
}

/// RMS quantization error of a round trip.
pub fn quant_rms_error(t: &Tensor, mode: QuantMode) -> Result<f64> {
    let back = dequantize(&quantize(t, mode)?)?;
    let n = t.len().max(1);
    let s: f64 = t
        .data()
        .iter()
        .zip(back.data())
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum();
    Ok((s / n as f64).sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::forall;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_error_bounded_by_half_step() -> Result<()> {
        forall("quant roundtrip bounded", 24, |rng| {
            let t = Tensor::randn(&[4, 16], 2.0, rng);
            let q = match quantize(&t, QuantMode::PerTensor) {
                Ok(q) => q,
                Err(e) => unreachable!("finite input rejected: {e}"),
            };
            let back = match dequantize(&q) {
                Ok(b) => b,
                Err(e) => unreachable!("well-formed roundtrip rejected: {e}"),
            };
            let step = q.scales[0];
            for (a, b) in t.data().iter().zip(back.data()) {
                assert!((a - b).abs() <= step * 0.5 + 1e-6);
            }
        });
        Ok(())
    }

    #[test]
    fn per_channel_beats_per_tensor_on_mixed_ranges() -> Result<()> {
        // Channel 0 tiny values, channel 1 huge: per-tensor wastes range.
        let mut rng = Rng::new(21);
        let mut data = Vec::new();
        data.extend(rng.normal_vec(64, 0.0, 0.01));
        data.extend(rng.normal_vec(64, 0.0, 10.0));
        let t = Tensor::from_vec(&[2, 64], data);
        // Overall RMS is dominated by the huge channel; the per-channel win
        // shows on the *small* channel's slice.
        let small_err = |mode| -> Result<f64> {
            let back = dequantize(&quantize(&t, mode)?)?;
            let s: f64 = t.data()[..64]
                .iter()
                .zip(&back.data()[..64])
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum();
            Ok((s / 64.0).sqrt())
        };
        let e_t = small_err(QuantMode::PerTensor)?;
        let e_c = small_err(QuantMode::PerChannel)?;
        assert!(e_c < e_t * 0.1, "per-channel {e_c} vs per-tensor {e_t}");
        Ok(())
    }

    #[test]
    fn storage_is_4x_smaller_than_f32() -> Result<()> {
        let t = Tensor::zeros(&[8, 32]);
        let q = quantize(&t, QuantMode::PerChannel)?;
        assert!(q.bytes() * 3 < 8 * 32 * 4);
        Ok(())
    }

    #[test]
    fn zeros_stay_zero() -> Result<()> {
        let t = Tensor::zeros(&[3, 3]);
        let q = quantize(&t, QuantMode::PerTensor)?;
        assert!(q.data.iter().all(|&v| v == 0));
        assert_eq!(dequantize(&q)?, t);
        Ok(())
    }

    #[test]
    fn nan_and_inf_are_typed_errors_naming_the_channel() {
        // Per-tensor: NaN anywhere is NonFinite, not a silent zero (the
        // old `fold(0.0, max)` ignored NaN and `quant1` cast it to 0).
        let t = Tensor::from_vec(&[2, 2], vec![1.0, f32::NAN, 2.0, 3.0]);
        let err = match quantize(&t, QuantMode::PerTensor) {
            Ok(_) => unreachable!("NaN input must be rejected"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("non-finite"), "got: {err}");

        // Per-channel: the error names the offending channel (row 1).
        let t = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, f32::INFINITY, 3.0]);
        let err = match quantize(&t, QuantMode::PerChannel) {
            Ok(_) => unreachable!("Inf input must be rejected"),
            Err(e) => e,
        };
        let msg = err.to_string();
        assert!(msg.contains("channel 1"), "got: {msg}");
    }

    #[test]
    fn rank1_per_channel_is_a_shape_error_not_a_panic() {
        let t = Tensor::from_vec(&[4], vec![1.0, 2.0, 3.0, 4.0]);
        let err = match quantize(&t, QuantMode::PerChannel) {
            Ok(_) => unreachable!("rank-1 per-channel must be rejected"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("shape mismatch"), "got: {err}");
    }

    #[test]
    fn malformed_quant_tensors_are_rejected() {
        // Payload/shape disagreement.
        let q = QuantTensor {
            shape: vec![2, 3],
            data: vec![1, 2, 3, 4],
            scales: vec![1.0],
            mode: QuantMode::PerTensor,
        };
        assert!(dequantize(&q).is_err());
        // Scales that don't divide the payload (the old truncating
        // `per = n / ch` silently dropped the trailing elements).
        let q = QuantTensor {
            shape: vec![5],
            data: vec![1, 2, 3, 4, 5],
            scales: vec![1.0, 1.0],
            mode: QuantMode::PerChannel,
        };
        assert!(dequantize(&q).is_err());
        // Per-tensor with zero scales.
        let q = QuantTensor {
            shape: vec![1],
            data: vec![1],
            scales: vec![],
            mode: QuantMode::PerTensor,
        };
        assert!(dequantize(&q).is_err());
    }

    #[test]
    fn gemm_weight_scales_are_per_output_channel() -> Result<()> {
        // Dense [in=2, out=3]: column c has amax c+1, so scale (c+1)/127.
        let t = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 0.5, 1.0, 1.5]);
        let q = quantize_gemm_weight(&t)?;
        assert_eq!(q.shape, vec![3, 2]);
        assert_eq!(q.scales.len(), 3);
        for (c, &s) in q.scales.iter().enumerate() {
            assert_eq!(s, (c + 1) as f32 / 127.0);
        }
        // Row-major [out, in]: row c is column c of the stored weight.
        assert_eq!(q.data[0..2], [64, 127]);
        // OIHW conv weights normalize to [o, i*kh*kw].
        let w = Tensor::randn(&[3, 2, 3, 3], 1.0, &mut Rng::new(7));
        let q = quantize_gemm_weight(&w)?;
        assert_eq!(q.shape, vec![3, 18]);
        assert_eq!(q.scales.len(), 3);
        Ok(())
    }
}
