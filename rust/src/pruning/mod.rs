//! CoCo model optimizer (§2.1): pattern-based pruning, block-based pruning,
//! connectivity pruning, the ADMM search framework, and quantization —
//! applied at graph level over a [`WeightStore`].
//!
//! The accuracy impact of a scheme at a rate is provided by
//! [`AccuracyModel`], an interpolation calibrated to the paper's Fig 6
//! curve (see DESIGN.md substitutions — the *real measured* accuracy
//! experiment for the demo CNN lives in `python/compile/train.py`; this
//! model is what CAPS and the figure-level benches consume for the
//! ImageNet-scale networks we cannot train here).

pub mod admm;
pub mod block;
pub mod pattern;
pub mod quant;

use std::collections::BTreeMap;

use crate::graph::{Graph, OpKind, WeightStore};
use crate::tensor::Tensor;

use block::{block_prune, magnitude_prune, BlockPruneConfig};
use pattern::{apply_assignment, assign_patterns, connectivity_prune, PatternAssignment, PatternSet};

/// A pruning scheme, as CAPS selects per layer or uniformly.
#[derive(Debug, Clone, PartialEq)]
pub enum PruneScheme {
    /// No pruning (dense baseline).
    None,
    /// Non-structured magnitude pruning at `rate` (Fig 3a).
    NonStructured { rate: f64 },
    /// Pattern-based pruning (Fig 4): fixed 4-of-9 patterns, `set_size`
    /// pattern vocabulary, plus connectivity pruning at `connectivity_rate`.
    Pattern { set_size: usize, connectivity_rate: f64 },
    /// Block-based pruning (Fig 5) with square blocks of `block` (or whole-
    /// matrix when `usize::MAX`).
    Block { block: usize, rate: f64 },
    /// Coarse structured (filter/channel) pruning = whole-matrix blocks.
    Structured { rate: f64 },
}

impl PruneScheme {
    /// Nominal weight-reduction rate of the scheme.
    pub fn rate(&self) -> f64 {
        match self {
            PruneScheme::None => 0.0,
            PruneScheme::NonStructured { rate } => *rate,
            // 4-of-9 pattern = 5/9, plus connectivity on top.
            PruneScheme::Pattern { connectivity_rate, .. } => {
                let base = 5.0 / 9.0;
                base + (1.0 - base) * connectivity_rate
            }
            PruneScheme::Block { rate, .. } => *rate,
            PruneScheme::Structured { rate } => *rate,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            PruneScheme::None => "dense",
            PruneScheme::NonStructured { .. } => "non-structured",
            PruneScheme::Pattern { .. } => "pattern",
            PruneScheme::Block { .. } => "block",
            PruneScheme::Structured { .. } => "structured",
        }
    }
}

/// Result of pruning a whole graph.
#[derive(Debug, Clone)]
pub struct PruneReport {
    /// Overall fraction of weights zeroed (weighted by tensor size).
    pub sparsity: f64,
    /// Layers (weight tensors) touched.
    pub layers_pruned: usize,
    /// Effective MACs remaining (graph MACs × layer-wise density).
    pub effective_macs: u64,
    /// Per-layer pattern assignments, keyed by weight-node name. Populated
    /// only for 3×3 conv kernels pruned under [`PruneScheme::Pattern`] —
    /// this is what lets the compiler auto-attach FKW kernels to the
    /// corresponding conv nodes instead of forcing every call site to
    /// re-run `assign_patterns` by hand.
    pub pattern_assignments: BTreeMap<String, PatternAssignment>,
}

/// Apply `scheme` to every prunable weight of `g` in `ws` (conv kernels and
/// dense matrices; BN/bias/embedding weights are never pruned). Returns the
/// achieved report.
pub fn prune_graph(g: &Graph, ws: &mut WeightStore, scheme: &PruneScheme) -> PruneReport {
    let mut total = 0usize;
    let mut zeros = 0usize;
    let mut layers = 0usize;
    let mut eff_macs = 0u64;
    let mut assignments = BTreeMap::new();

    // Map weight-node name -> consumer op (to know how to prune it).
    for n in &g.nodes {
        if n.op.is_source() {
            continue;
        }
        let macs = g.node_macs(n.id);
        let mut density = 1.0f64;
        for &i in &n.inputs {
            let w = &g.nodes[i];
            if !matches!(w.op, OpKind::Weight) {
                continue;
            }
            let prunable = matches!(
                n.op,
                OpKind::Conv2d { .. } | OpKind::Conv3d { .. } | OpKind::Dense | OpKind::MatMul
            ) && w.out_elems() >= 64;
            let Some(t) = ws.get(&w.name).cloned() else { continue };
            total += t.len();
            if !prunable || matches!(scheme, PruneScheme::None) {
                continue;
            }
            let (pruned, asg) = prune_tensor_detailed(&t, scheme);
            if let Some(asg) = asg {
                assignments.insert(w.name.clone(), asg);
            }
            let z = pruned.data().iter().filter(|&&v| v == 0.0).count();
            zeros += z;
            density = 1.0 - z as f64 / t.len() as f64;
            layers += 1;
            ws.set(&w.name, pruned);
        }
        eff_macs += (macs as f64 * density) as u64;
    }
    PruneReport {
        sparsity: if total > 0 { zeros as f64 / total as f64 } else { 0.0 },
        layers_pruned: layers,
        effective_macs: eff_macs,
        pattern_assignments: assignments,
    }
}

/// Prune a single weight tensor under a scheme.
pub fn prune_tensor(t: &Tensor, scheme: &PruneScheme) -> Tensor {
    prune_tensor_detailed(t, scheme).0
}

/// Prune a single weight tensor, also returning the [`PatternAssignment`]
/// when the pattern path was taken (3×3 conv kernel under
/// [`PruneScheme::Pattern`]) — the assignment is what FKW encoding needs.
pub fn prune_tensor_detailed(t: &Tensor, scheme: &PruneScheme) -> (Tensor, Option<PatternAssignment>) {
    match scheme {
        PruneScheme::None => (t.clone(), None),
        PruneScheme::NonStructured { rate } => {
            let m = block::conv_weight_as_matrix(t);
            (magnitude_prune(&m, *rate).apply(&m).reshape(t.shape()), None)
        }
        PruneScheme::Pattern { set_size, connectivity_rate } => {
            // Pattern pruning applies to 3x3 conv kernels; other tensors
            // fall back to block pruning at the equivalent rate (this is
            // exactly the paper's motivation for block-based pruning).
            if t.rank() == 4 && t.shape()[2] == 3 && t.shape()[3] == 3 {
                let set = if *set_size <= 4 { PatternSet::elite4() } else { PatternSet::elite8() };
                let mut asg = assign_patterns(t, &set);
                if *connectivity_rate > 0.0 {
                    connectivity_prune(t, &mut asg, *connectivity_rate);
                }
                let pruned = apply_assignment(t, &asg);
                (pruned, Some(asg))
            } else {
                let rate = PruneScheme::Pattern {
                    set_size: *set_size,
                    connectivity_rate: *connectivity_rate,
                }
                .rate();
                let m = block::conv_weight_as_matrix(t);
                let pruned = block_prune(
                    &m,
                    &BlockPruneConfig { block_rows: 8, block_cols: 8, prune_rate: rate },
                )
                .apply(&m)
                .reshape(t.shape());
                (pruned, None)
            }
        }
        PruneScheme::Block { block, rate } => {
            let m = block::conv_weight_as_matrix(t);
            let pruned = block_prune(
                &m,
                &BlockPruneConfig { block_rows: *block, block_cols: *block, prune_rate: *rate },
            )
            .apply(&m)
            .reshape(t.shape());
            (pruned, None)
        }
        PruneScheme::Structured { rate } => {
            let m = block::conv_weight_as_matrix(t);
            let pruned = block_prune(
                &m,
                &BlockPruneConfig {
                    block_rows: usize::MAX,
                    block_cols: usize::MAX,
                    prune_rate: *rate,
                },
            )
            .apply(&m)
            .reshape(t.shape());
            (pruned, None)
        }
    }
}

/// Accuracy impact model, calibrated to the paper's Fig 6 (ResNet-50 @6×:
/// non-structured ≈ −0.2, small blocks ≈ −0.3…−0.6, growing with block
/// size, whole-matrix structured ≈ −4) and the §2.1.1 claim that pattern
/// pruning matches non-structured accuracy.
#[derive(Debug, Clone)]
pub struct AccuracyModel {
    /// Accuracy drop per unit of `rate/(1-rate)` for perfectly fine-grained
    /// pruning.
    pub fine_coeff: f64,
    /// Extra drop per unit of rate-pressure at maximum granularity.
    pub coarse_coeff: f64,
}

impl Default for AccuracyModel {
    fn default() -> Self {
        // Calibration: at 6× (pressure = 5): fine drop = 0.04*5 = 0.2,
        // coarse extra = 0.75*5 = 3.75 → structured total ≈ 3.95.
        AccuracyModel { fine_coeff: 0.04, coarse_coeff: 0.75 }
    }
}

impl AccuracyModel {
    /// Granularity factor in [0,1]: how coarse the scheme's atoms are.
    pub fn granularity(scheme: &PruneScheme) -> f64 {
        match scheme {
            PruneScheme::None => 0.0,
            PruneScheme::NonStructured { .. } => 0.0,
            // Patterns are fine-grained *inside* coarse structures; tiny
            // penalty for the restricted support vocabulary.
            PruneScheme::Pattern { set_size, .. } => {
                if *set_size >= 8 {
                    0.03
                } else {
                    0.05
                }
            }
            PruneScheme::Block { block, .. } => {
                let b = (*block).min(4096) as f64;
                if *block == usize::MAX {
                    1.0
                } else {
                    // log-interpolated: 4→0.08, 16→0.18, 64→0.35, 256→0.60.
                    (0.08 + 0.52 * ((b / 4.0).ln() / (1024.0f64 / 4.0).ln()).max(0.0)).min(1.0)
                }
            }
            PruneScheme::Structured { .. } => 1.0,
        }
    }

    /// Estimated top-1 accuracy after pruning from `base_acc`.
    pub fn estimate(&self, base_acc: f64, scheme: &PruneScheme) -> f64 {
        let rate = scheme.rate();
        if rate <= 0.0 {
            return base_acc;
        }
        let pressure = rate / (1.0 - rate).max(1e-3);
        let g = Self::granularity(scheme);
        let drop = self.fine_coeff * pressure + self.coarse_coeff * pressure * g;
        (base_acc - drop).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo::by_name;
    use crate::util::rng::Rng;

    #[test]
    fn prune_graph_reports_sparsity() {
        let g = by_name("mobilenet-v2", 1);
        let mut rng = Rng::new(31);
        let mut ws = WeightStore::init_random(&g, &mut rng);
        let r = prune_graph(&g, &mut ws, &PruneScheme::Block { block: 8, rate: 0.75 });
        assert!(r.layers_pruned > 20, "layers {}", r.layers_pruned);
        assert!(r.sparsity > 0.4, "sparsity {}", r.sparsity);
        assert!(r.effective_macs < g.total_macs());
        assert!((ws.overall_density() - (1.0 - r.sparsity)).abs() < 0.05);
    }

    #[test]
    fn pattern_scheme_on_resnet_kernels() {
        let g = by_name("resnet-50", 1);
        let mut rng = Rng::new(32);
        let mut ws = WeightStore::init_random(&g, &mut rng);
        let r = prune_graph(
            &g,
            &mut ws,
            &PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.3 },
        );
        assert!(r.sparsity > 0.3, "sparsity {}", r.sparsity);
    }

    #[test]
    fn accuracy_ordering_matches_fig6() {
        // At a uniform 6× rate: non-structured >= pattern >= block4 >=
        // block64 >= structured, and structured loses severely.
        let am = AccuracyModel::default();
        let base = 76.5;
        let rate = 1.0 - 1.0 / 6.0;
        let ns = am.estimate(base, &PruneScheme::NonStructured { rate });
        let pat = am.estimate(base, &PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.5 });
        let b4 = am.estimate(base, &PruneScheme::Block { block: 4, rate });
        let b64 = am.estimate(base, &PruneScheme::Block { block: 64, rate });
        let st = am.estimate(base, &PruneScheme::Structured { rate });
        assert!(ns >= b4 && b4 >= b64 && b64 >= st, "{ns} {b4} {b64} {st}");
        assert!(pat > st);
        assert!(base - ns < 0.5, "non-structured drop too large: {}", base - ns);
        assert!(base - st > 3.0, "structured drop too small: {}", base - st);
        assert!(base - b4 < 1.0, "block-4 drop too large: {}", base - b4);
    }

    #[test]
    fn scheme_rate_arithmetic() {
        assert_eq!(PruneScheme::None.rate(), 0.0);
        let p = PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.0 };
        assert!((p.rate() - 5.0 / 9.0).abs() < 1e-9);
        let pc = PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.5 };
        assert!((pc.rate() - 7.0 / 9.0).abs() < 1e-9);
    }

    #[test]
    fn pattern_assignments_recorded_for_fkw() {
        use crate::graph::zoo::NetBuilder;
        use crate::graph::Act;
        let mut b = NetBuilder::new("pa", &[1, 8, 8, 8]);
        b.conv(16, 3, 1, 1, 1);
        b.act(Act::Relu);
        let g = b.finish();
        let mut rng = Rng::new(35);
        let mut ws = WeightStore::init_random(&g, &mut rng);
        let r = prune_graph(
            &g,
            &mut ws,
            &PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.2 },
        );
        assert_eq!(r.pattern_assignments.len(), 1);
        let (name, asg) = r.pattern_assignments.iter().next().unwrap();
        assert!(ws.get(name).unwrap().zero_fraction() > 0.5);
        assert!(asg.sparsity() > 0.5);
        // Non-pattern schemes record no assignments.
        let mut ws2 = WeightStore::init_random(&g, &mut Rng::new(35));
        let r2 = prune_graph(&g, &mut ws2, &PruneScheme::Block { block: 4, rate: 0.5 });
        assert!(r2.pattern_assignments.is_empty());
    }

    #[test]
    fn dense_scheme_is_noop() {
        let g = by_name("wdsr-b", 1);
        let mut rng = Rng::new(33);
        let mut ws = WeightStore::init_random(&g, &mut rng);
        let before = ws.overall_density();
        let r = prune_graph(&g, &mut ws, &PruneScheme::None);
        assert_eq!(r.layers_pruned, 0);
        assert_eq!(ws.overall_density(), before);
    }
}
