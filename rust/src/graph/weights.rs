//! Weight storage: concrete tensors for the [`OpKind::Weight`] nodes of a
//! graph, keyed by weight-node *name* (names survive dead-code elimination
//! and rewriting, node ids do not). Used by the reference executor, the
//! pruning passes (which rewrite weights in place), and the graph-rewriting
//! pass (which folds weights, e.g. BN-into-conv).

use std::collections::BTreeMap;

use super::ir::Graph;
use super::ops::OpKind;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Name → tensor map for the weights of one graph.
#[derive(Debug, Clone, Default)]
pub struct WeightStore {
    map: BTreeMap<String, Tensor>,
}

impl WeightStore {
    pub fn new() -> WeightStore {
        WeightStore::default()
    }

    /// Initialize every weight node of `g` with Gaussian values scaled by
    /// 1/sqrt(fan_in) (enough to keep activations bounded in tests).
    pub fn init_random(g: &Graph, rng: &mut Rng) -> WeightStore {
        let mut ws = WeightStore::new();
        for n in &g.nodes {
            if matches!(n.op, OpKind::Weight) {
                let fan_in: usize = n.shape.iter().skip(1).product::<usize>().max(1);
                let std = 1.0 / (fan_in as f32).sqrt();
                let t = if let Some(&v) = g.consts.get(&n.name) {
                    // Graph constants (e.g. the attention sqrt(d_k)
                    // divisor) keep their baked value — randomizing a
                    // constant changes semantics.
                    Tensor::full(&n.shape, v)
                } else if n.shape.len() == 2 && n.shape[0] == 2 {
                    // BatchNorm/LayerNorm [2, c] params: scale≈1, shift≈0.
                    let c = n.shape[1];
                    let mut data = Vec::with_capacity(2 * c);
                    for _ in 0..c {
                        data.push(1.0 + 0.1 * rng.normal() as f32);
                    }
                    for _ in 0..c {
                        data.push(0.1 * rng.normal() as f32);
                    }
                    Tensor::from_vec(&n.shape, data)
                } else {
                    Tensor::randn(&n.shape, std, rng)
                };
                ws.map.insert(n.name.clone(), t);
            }
        }
        ws
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.map.get(name)
    }

    pub fn expect(&self, name: &str) -> &Tensor {
        self.map
            .get(name)
            .unwrap_or_else(|| panic!("weight '{name}' missing from store"))
    }

    pub fn set(&mut self, name: &str, t: Tensor) {
        self.map.insert(name.to_string(), t);
    }

    pub fn remove(&mut self, name: &str) -> Option<Tensor> {
        self.map.remove(name)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn names(&self) -> impl Iterator<Item = &String> {
        self.map.keys()
    }

    /// Total nonzero fraction across all stored tensors (sparsity probe).
    pub fn overall_density(&self) -> f64 {
        let total: usize = self.map.values().map(|t| t.len()).sum();
        if total == 0 {
            return 1.0;
        }
        let nz: usize = self
            .map
            .values()
            .map(|t| t.data().iter().filter(|&&x| x != 0.0).count())
            .sum();
        nz as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::zoo::NetBuilder;
    use crate::graph::Act;

    #[test]
    fn init_covers_all_weight_nodes() {
        let mut b = NetBuilder::new("t", &[1, 3, 8, 8]);
        b.conv_bn_act(8, 3, 1, 1, Act::Relu);
        b.conv(4, 1, 1, 0, 1);
        let g = b.finish();
        let mut rng = Rng::new(1);
        let ws = WeightStore::init_random(&g, &mut rng);
        let wnodes = g.nodes.iter().filter(|n| matches!(n.op, OpKind::Weight)).count();
        assert_eq!(ws.len(), wnodes);
        for n in &g.nodes {
            if matches!(n.op, OpKind::Weight) {
                assert_eq!(ws.expect(&n.name).shape(), &n.shape[..]);
            }
        }
    }

    #[test]
    fn bn_weights_initialized_near_identity() {
        let mut b = NetBuilder::new("t", &[1, 4, 4, 4]);
        b.conv(4, 3, 1, 1, 1);
        b.bn();
        let g = b.finish();
        let mut rng = Rng::new(2);
        let ws = WeightStore::init_random(&g, &mut rng);
        let bn_name = g
            .nodes
            .iter()
            .find(|n| matches!(n.op, OpKind::Weight) && n.shape == vec![2, 4])
            .unwrap()
            .name
            .clone();
        let t = ws.expect(&bn_name);
        for c in 0..4 {
            assert!((t.at(&[0, c]) - 1.0).abs() < 0.6, "scale far from 1");
            assert!(t.at(&[1, c]).abs() < 0.6, "shift far from 0");
        }
    }

    #[test]
    fn density_of_fresh_store_is_one() {
        let mut b = NetBuilder::new("t", &[1, 3, 4, 4]);
        b.conv(2, 3, 1, 1, 1);
        let g = b.finish();
        let ws = WeightStore::init_random(&g, &mut Rng::new(3));
        assert!(ws.overall_density() > 0.99);
    }
}
