//! Transformer / NLP / speech models (Table 3 bottom block, Table 4).
//! The paper stresses XGen's support for "extremely deep" transformers that
//! other mobile frameworks lack — these builders produce the deep operator
//! chains (hundreds of nodes) the fusion experiments need.

use super::NetBuilder;
use crate::graph::ir::Graph;
use crate::graph::ops::{Act, OpKind};

/// Generic BERT-style encoder: embedding + L transformer layers + pooler.
fn bert_like(
    name: &str,
    batch: usize,
    seq: usize,
    layers: usize,
    d: usize,
    heads: usize,
    ffn: usize,
    vocab: usize,
) -> Graph {
    let mut b = NetBuilder::new(name, &[batch, seq]);
    // Token embedding: Gather from [vocab, d] table (+ positional embed).
    let table = b.g.weight("tok_embed", &[vocab, d]);
    let emb = b.g.add("embed", OpKind::Embedding, vec![b.cur(), table], vec![batch, seq, d]);
    b.set_cur(emb);
    let pos = b.g.weight("pos_embed", &[seq, d]);
    let posb = b.g.add("pos_broadcast", OpKind::Broadcast, vec![pos], vec![batch, seq, d]);
    let with_pos = b.add_residual(emb, posb);
    b.set_cur(with_pos);
    b.layer_norm();
    for _ in 0..layers {
        b.transformer_layer(heads, ffn, Act::Gelu, false);
    }
    b.layer_norm();
    b.finish()
}

/// BERT-Base: L12 d768 ffn3072 vocab 30522; 108M params (paper row ✓),
/// seq 384 to match the paper's 67.3 GFLOPs scale.
pub fn bert_base(batch: usize) -> Graph {
    bert_like("bert-base", batch, 384, 12, 768, 12, 3072, 30522)
}

/// DistilBERT: 6 layers of BERT-Base; 66M params (paper row ✓).
pub fn distilbert(batch: usize) -> Graph {
    bert_like("distilbert", batch, 384, 6, 768, 12, 3072, 30522)
}

/// TinyBERT(4): L4 d312 ffn1200; ~14.5M params (paper row: 15M ✓).
pub fn tinybert(batch: usize) -> Graph {
    bert_like("tinybert", batch, 384, 4, 312, 12, 1200, 30522)
}

/// MobileBERT: 24 thin bottleneck layers, 128-d block with 512-d FFN stacks;
/// ~25M params (paper row ✓). Approximated with d=128 blocks and 4 stacked
/// FFNs per layer plus input/output bottleneck projections at d=512.
pub fn mobilebert(batch: usize) -> Graph {
    let (seq, d_embed, d_block, layers) = (384usize, 512usize, 128usize, 24usize);
    let mut b = NetBuilder::new("mobilebert", &[batch, seq]);
    let table = b.g.weight("tok_embed", &[30522, d_embed / 4]);
    let emb = b.g.add(
        "embed",
        OpKind::Embedding,
        vec![b.cur(), table],
        vec![batch, seq, d_embed / 4],
    );
    b.set_cur(emb);
    b.dense(d_embed);
    for _ in 0..layers {
        // Bottleneck in.
        let body_in = b.cur();
        b.dense(d_block);
        b.attention(4, false);
        for _ in 0..4 {
            b.ffn(d_block * 4, Act::Relu);
        }
        // Bottleneck out + residual at embed width.
        b.dense(d_embed);
        let out = b.cur();
        b.add_residual(body_in, out);
        b.layer_norm();
    }
    b.finish()
}

/// GPT-2 (124M): L12 d768 ffn3072 vocab 50257, causal decoder. The LM head
/// shares the embedding. Paper row: 125M / 69.1 GFLOPs (seq 384).
pub fn gpt2(batch: usize) -> Graph {
    gpt2_decoder_layers(batch, 12)
}

/// Compact-form GPT-2 decoder with a configurable layer count: embedding +
/// learned positions + L *causal* transformer layers (QK^T → scale →
/// [`OpKind::CausalMask`] → softmax) + final LN + tied LM head. This is
/// what `CompiledModel::decode_session` serves; the registry entry
/// `"gpt-2-decoder"` builds the 2-layer variant the decode tests and
/// benches use.
pub fn gpt2_decoder_layers(batch: usize, layers: usize) -> Graph {
    let (seq, d, heads, ffn) = (384usize, 768usize, 12usize, 3072usize);
    let name = if layers == 12 { "gpt-2" } else { "gpt-2-decoder" };
    let mut b = NetBuilder::new(name, &[batch, seq]);
    let table = b.g.weight("wte", &[50257, d]);
    let emb = b.g.add("embed", OpKind::Embedding, vec![b.cur(), table], vec![batch, seq, d]);
    let pos = b.g.weight("wpe", &[seq, d]);
    let posb = b.g.add("pos_broadcast", OpKind::Broadcast, vec![pos], vec![batch, seq, d]);
    b.set_cur(emb);
    let x = b.add_residual(emb, posb);
    b.set_cur(x);
    for _ in 0..layers {
        b.transformer_layer(heads, ffn, Act::Gelu, true);
    }
    b.layer_norm();
    // LM head: project to vocab via the (shared) embedding — model as
    // MatMul against the *transposed* table ([vocab, d] → [d, vocab]) so
    // the contraction dims line up and no new params are counted.
    let h = b.cur();
    let wte_t = b.g.add(
        "wte_t",
        OpKind::Transpose { perm: vec![1, 0] },
        vec![table],
        vec![d, 50257],
    );
    let logits = b.g.add("lm_head", OpKind::MatMul, vec![h, wte_t], vec![batch, seq, 50257]);
    b.set_cur(logits);
    b.finish()
}

/// GPT-2 as a *frontend dump*: the op-by-op form a PyTorch/ONNX exporter
/// emits before any optimization — explicit per-head Reshape/Transpose
/// pairs, GELU decomposed into its tanh expansion (Pow/Mul/Add/Tanh chain),
/// attention scaling as Div(x, Sqrt(const)), and a separate Bias after
/// every Dense. This is the input for the §2.2.1 experiment ("with graph
/// rewriting, 18% fewer fused layers left after fusion on GPT-2"): the
/// rewrite pass must collapse this redundancy before fusion.
pub fn gpt2_frontend(batch: usize) -> Graph {
    gpt2_frontend_layers(batch, 12)
}

/// Frontend-dump GPT-2 with a configurable layer count (tests use 2).
pub fn gpt2_frontend_layers(batch: usize, layers: usize) -> Graph {
    let (seq, d, _heads, ffn) = (384usize, 768usize, 12usize, 3072usize);
    let mut b = NetBuilder::new("gpt-2-frontend", &[batch, seq]);
    let table = b.g.weight("wte", &[50257, d]);
    let emb = b.g.add("embed", OpKind::Embedding, vec![b.cur(), table], vec![batch, seq, d]);
    let pos = b.g.weight("wpe", &[seq, d]);
    let posb = b.g.add("pos_broadcast", OpKind::Broadcast, vec![pos], vec![batch, seq, d]);
    b.set_cur(emb);
    let x = b.add_residual(emb, posb);
    b.set_cur(x);

    // Decomposed tanh-GELU: 0.5 x (1 + tanh(c1 (x + c2 x^3))).
    fn gelu_decomposed(b: &mut NetBuilder) {
        let x = b.cur();
        let s = b.g.node(x).shape.clone();
        let x3 = b.g.add(&format!("pow_{}", b.g.len()), OpKind::Pow { e: 3.0 }, vec![x], s.clone());
        let sx3 = b.g.add(
            &format!("scale_{}", b.g.len()),
            OpKind::Scale { mul: 0.044715, add: 0.0 },
            vec![x3],
            s.clone(),
        );
        let inner = b.g.add(&format!("add_{}", b.g.len()), OpKind::Add, vec![x, sx3], s.clone());
        let scaled = b.g.add(
            &format!("scale_{}", b.g.len()),
            OpKind::Scale { mul: 0.7978845608028654, add: 0.0 },
            vec![inner],
            s.clone(),
        );
        b.set_cur(scaled);
        b.act(Act::Tanh);
        let t = b.cur();
        let one = b.g.add(
            &format!("scale_{}", b.g.len()),
            OpKind::Scale { mul: 1.0, add: 1.0 },
            vec![t],
            s.clone(),
        );
        let gated = b.g.add(&format!("mul_{}", b.g.len()), OpKind::Mul, vec![x, one], s.clone());
        let half = b.g.add(
            &format!("scale_{}", b.g.len()),
            OpKind::Scale { mul: 0.5, add: 0.0 },
            vec![gated],
            s,
        );
        b.set_cur(half);
    }

    // Dense + explicit bias (exporters never fold the bias).
    fn dense_bias(b: &mut NetBuilder, out: usize) {
        b.dense(out);
        b.bias();
    }

    for _ in 0..layers {
        // ---- attention, exporter-style ----
        let resid = b.cur();
        b.layer_norm();
        let ln = b.cur();
        let mut qkv = Vec::new();
        for _ in 0..3 {
            b.set_cur(ln);
            dense_bias(&mut b, d);
            // Per-head split: Reshape [n,L,d] -> [n,L,h,dh], Transpose -> [n,h,L,dh].
            let s = b.shape();
            let rs = b.g.add(
                &format!("head_split_{}", b.g.len()),
                OpKind::Reshape,
                vec![b.cur()],
                vec![s[0], s[1], 12, d / 12],
            );
            let tp = b.g.add(
                &format!("head_tp_{}", b.g.len()),
                OpKind::Transpose { perm: vec![0, 2, 1, 3] },
                vec![rs],
                vec![s[0], 12, s[1], d / 12],
            );
            qkv.push(tp);
        }
        let (q, k, v) = (qkv[0], qkv[1], qkv[2]);
        // K transposed again for QK^T.
        let ks = b.g.node(k).shape.clone();
        let kt = b.g.add(
            &format!("k_tp_{}", b.g.len()),
            OpKind::Transpose { perm: vec![0, 1, 3, 2] },
            vec![k],
            vec![ks[0], ks[1], ks[3], ks[2]],
        );
        let scores = b.g.add(
            &format!("qk_{}", b.g.len()),
            OpKind::MatMul,
            vec![q, kt],
            vec![batch, 12, seq, seq],
        );
        // Scaling emitted as Sqrt(const) then Div; the divisor is a *graph
        // constant* (d_k = d/heads), not a trainable weight.
        let csqrt = b.g.const_scalar(&format!("dk_{}", b.g.len()), (d / 12) as f32);
        let sq = b.g.add(&format!("sqrt_{}", b.g.len()), OpKind::Sqrt, vec![csqrt], vec![1]);
        let sqb = b.g.add(
            &format!("bcast_{}", b.g.len()),
            OpKind::Broadcast,
            vec![sq],
            vec![batch, 12, seq, seq],
        );
        let scaled = b.g.add(
            &format!("div_{}", b.g.len()),
            OpKind::Div,
            vec![scores, sqb],
            vec![batch, 12, seq, seq],
        );
        // GPT-2 is a decoder: the exporter emits the causal mask between
        // the scaling and the softmax.
        let masked = b.g.add(
            &format!("causal_{}", b.g.len()),
            OpKind::CausalMask,
            vec![scaled],
            vec![batch, 12, seq, seq],
        );
        let probs = b.g.add(
            &format!("softmax_{}", b.g.len()),
            OpKind::Softmax,
            vec![masked],
            vec![batch, 12, seq, seq],
        );
        let ctx = b.g.add(
            &format!("av_{}", b.g.len()),
            OpKind::MatMul,
            vec![probs, v],
            vec![batch, 12, seq, d / 12],
        );
        // Merge heads: Transpose back + Reshape.
        let tp = b.g.add(
            &format!("merge_tp_{}", b.g.len()),
            OpKind::Transpose { perm: vec![0, 2, 1, 3] },
            vec![ctx],
            vec![batch, seq, 12, d / 12],
        );
        let merged = b.g.add(
            &format!("merge_rs_{}", b.g.len()),
            OpKind::Reshape,
            vec![tp],
            vec![batch, seq, d],
        );
        b.set_cur(merged);
        dense_bias(&mut b, d);
        let o = b.cur();
        b.add_residual(resid, o);
        // ---- FFN, exporter-style ----
        let resid = b.cur();
        b.layer_norm();
        dense_bias(&mut b, ffn);
        gelu_decomposed(&mut b);
        dense_bias(&mut b, d);
        let o = b.cur();
        b.add_residual(resid, o);
    }
    b.layer_norm();
    b.finish()
}

/// The small executable transformer: 2 encoder layers, d=64, seq=32,
/// 4 heads, ffn 128, vocab 256, with a [CLS]-slice 8-way classifier head.
/// Small enough to CPU-execute in tests and benches end-to-end through
/// `CompiledModel::infer()` — the transformer counterpart of
/// [`super::misc::demo_cnn`], and the model behind `benches/transformer.rs`.
/// Input is `[batch, 32]` token ids (as f32; `Embedding` does the row
/// lookup against the `[256, 64]` table).
pub fn demo_transformer(batch: usize) -> Graph {
    let (seq, d, heads, ffn, vocab, classes) = (32usize, 64usize, 4usize, 128usize, 256usize, 8usize);
    let mut b = NetBuilder::new("demo-transformer", &[batch, seq]);
    let table = b.g.weight("tok_embed", &[vocab, d]);
    let emb = b.g.add("embed", OpKind::Embedding, vec![b.cur(), table], vec![batch, seq, d]);
    b.set_cur(emb);
    let pos = b.g.weight("pos_embed", &[seq, d]);
    let posb = b.g.add("pos_broadcast", OpKind::Broadcast, vec![pos], vec![batch, seq, d]);
    let with_pos = b.add_residual(emb, posb);
    b.set_cur(with_pos);
    for _ in 0..2 {
        b.transformer_layer(heads, ffn, Act::Gelu, false);
    }
    b.layer_norm();
    // [CLS] head: slice the first sequence position, flatten, classify.
    b.slice(&[0, 0, 0], &[batch, 1, d]);
    b.reshape(&[batch, d]);
    b.dense(classes);
    b.finish()
}

/// The small executable *decoder*: the causal counterpart of
/// [`demo_transformer`] — same scale (2 layers, d=64, seq=32, 4 heads,
/// ffn 128, vocab 256) but with [`OpKind::CausalMask`]ed attention and a
/// per-position LM head (`[batch, 32, 256]` logits) instead of the [CLS]
/// classifier, so it both infers end-to-end *and* decodes autoregressively
/// through `CompiledModel::decode_session`. This is the model behind
/// `tests/decode.rs` and `benches/decode.rs`.
pub fn demo_transformer_causal(batch: usize) -> Graph {
    let (seq, d, heads, ffn, vocab) = (32usize, 64usize, 4usize, 128usize, 256usize);
    let mut b = NetBuilder::new("demo-transformer-causal", &[batch, seq]);
    let table = b.g.weight("tok_embed", &[vocab, d]);
    let emb = b.g.add("embed", OpKind::Embedding, vec![b.cur(), table], vec![batch, seq, d]);
    b.set_cur(emb);
    let pos = b.g.weight("pos_embed", &[seq, d]);
    let posb = b.g.add("pos_broadcast", OpKind::Broadcast, vec![pos], vec![batch, seq, d]);
    let with_pos = b.add_residual(emb, posb);
    b.set_cur(with_pos);
    for _ in 0..2 {
        b.transformer_layer(heads, ffn, Act::Gelu, true);
    }
    b.layer_norm();
    // Per-position LM head (untied — the model is tiny, clarity wins).
    b.dense(vocab);
    b.finish()
}

/// Conformer (speech, Table 4): conv subsampling + N conformer blocks
/// (FFN half, MHSA, conv module, FFN half). Paper row: 1.2M params /
/// 5.6 GMACs / 675 operators → a tiny-width variant (d=144, 8 blocks... we
/// use d=128, 10 blocks to land near 1.2M params over ~500 frames).
pub fn conformer(batch: usize) -> Graph {
    let (frames, d, blocks) = (500usize, 96usize, 6usize);
    let mut b = NetBuilder::new("conformer", &[batch, 1, frames, 80]);
    // Conv subsampling ×4 in time.
    b.conv_bn_act(d / 4, 3, 2, 1, Act::Swish);
    b.conv_bn_act(d / 4, 3, 2, 1, Act::Swish);
    let s = b.shape();
    let t = s[2];
    let feat = s[1] * s[3];
    let rs = b.g.add("to_seq", OpKind::Reshape, vec![b.cur()], vec![batch, t, feat]);
    b.set_cur(rs);
    b.dense(d);
    for _ in 0..blocks {
        // Half-step FFN.
        b.ffn(d * 4, Act::Swish);
        // MHSA.
        b.attention(4, false);
        // Conv module: LN → pointwise dense ×2 (GLU) → depthwise-ish dense →
        // BN → swish → dense, modeled at sequence level.
        let resid = b.cur();
        b.layer_norm();
        b.dense(2 * d);
        b.act(Act::Sigmoid); // GLU gate half
        b.dense(d);
        b.act(Act::Swish);
        b.dense(d);
        let o = b.cur();
        b.add_residual(resid, o);
        // Half-step FFN.
        b.ffn(d * 4, Act::Swish);
        b.layer_norm();
    }
    b.dense(256); // CTC vocabulary head
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mp(g: &Graph) -> f64 {
        g.total_params() as f64 / 1e6
    }

    #[test]
    fn bert_base_params() {
        let p = mp(&bert_base(1));
        assert!((100.0..118.0).contains(&p), "bert params {p}M");
    }

    #[test]
    fn distilbert_params() {
        let p = mp(&distilbert(1));
        assert!((60.0..73.0).contains(&p), "distilbert params {p}M");
    }

    #[test]
    fn tinybert_params() {
        let p = mp(&tinybert(1));
        assert!((11.0..18.0).contains(&p), "tinybert params {p}M");
    }

    #[test]
    fn mobilebert_params() {
        let p = mp(&mobilebert(1));
        assert!((18.0..32.0).contains(&p), "mobilebert params {p}M");
    }

    #[test]
    fn gpt2_params_and_head_shared() {
        let g = gpt2(1);
        let p = mp(&g);
        assert!((115.0..135.0).contains(&p), "gpt2 params {p}M");
        // Deep chain: >= 12 layers x ~15 ops.
        assert!(g.operator_count() > 150, "gpt2 ops {}", g.operator_count());
    }

    #[test]
    fn conformer_params() {
        let p = mp(&conformer(1));
        assert!((0.8..3.5).contains(&p), "conformer params {p}M");
        let g = conformer(1);
        assert!(g.operator_count() > 150, "conformer ops {}", g.operator_count());
    }

    #[test]
    fn demo_transformer_is_small_and_classifies() {
        let g = demo_transformer(2);
        assert!(g.validate().is_ok(), "{:?}", g.validate());
        assert_eq!(g.node(g.outputs[0]).shape, vec![2, 8]);
        // Tiny on purpose: it executes in tests.
        assert!(g.total_params() < 300_000, "params {}", g.total_params());
        // The attention fix: every QK^T matmul consumes a transposed K.
        let qk_with_transposed_rhs = g
            .nodes
            .iter()
            .filter(|n| matches!(n.op, OpKind::MatMul))
            .filter(|n| {
                n.inputs
                    .iter()
                    .any(|&i| matches!(g.node(i).op, OpKind::Transpose { .. }))
            })
            .count();
        assert_eq!(qk_with_transposed_rhs, 2, "one K-transpose per layer");
    }

    #[test]
    fn transformers_have_softmax_and_matmul() {
        use crate::graph::ops::OpKind;
        let g = gpt2(1);
        assert!(g.nodes.iter().any(|n| matches!(n.op, OpKind::Softmax)));
        assert!(g.nodes.iter().any(|n| matches!(n.op, OpKind::MatMul)));
    }

    /// Decoder builders are causal: one `CausalMask` per layer, sitting
    /// directly between the score scaling and the softmax; encoder
    /// builders have none.
    #[test]
    fn gpt2_builders_are_causal_and_encoders_are_not() {
        let masks = |g: &Graph| {
            g.nodes
                .iter()
                .filter(|n| matches!(n.op, OpKind::CausalMask))
                .count()
        };
        assert_eq!(masks(&gpt2(1)), 12);
        assert_eq!(masks(&gpt2_decoder_layers(1, 2)), 2);
        assert_eq!(masks(&gpt2_frontend_layers(1, 2)), 2);
        assert_eq!(masks(&demo_transformer_causal(1)), 2);
        assert_eq!(masks(&demo_transformer(1)), 0);
        assert_eq!(masks(&bert_base(1)), 0);
        // Every mask feeds a softmax (and nothing else).
        let g = gpt2_frontend_layers(1, 2);
        let users = g.users();
        for n in g.nodes.iter().filter(|n| matches!(n.op, OpKind::CausalMask)) {
            assert_eq!(users[n.id].len(), 1, "mask {} escapes", n.id);
            assert!(
                matches!(g.node(users[n.id][0]).op, OpKind::Softmax),
                "mask {} not consumed by softmax",
                n.id
            );
        }
    }

    #[test]
    fn demo_transformer_causal_is_a_small_lm() {
        let g = demo_transformer_causal(2);
        assert!(g.validate().is_ok(), "{:?}", g.validate());
        // Per-position logits over the 256-token vocabulary.
        assert_eq!(g.node(g.outputs[0]).shape, vec![2, 32, 256]);
        assert!(g.total_params() < 300_000, "params {}", g.total_params());
    }
}
