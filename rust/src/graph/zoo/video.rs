//! 3-D convolution video models (Table 3: C3D, R(2+1)D, S3D; §2.1.2's
//! block-pruning generalization to 3-D convolutions targets exactly these).
//! All take 16-frame 112×112 clips like the paper ("C3D (16 frames)").

use super::NetBuilder;
use crate::graph::ir::Graph;
use crate::graph::ops::Act;

/// C3D (Tran et al.): 8 3×3×3 conv layers + 2 fc. Published: ~78M params
/// (fc-heavy), ~38.5 GMACs @16×112×112. Paper row: 78M / 77 GFLOPs ✓.
pub fn c3d(batch: usize) -> Graph {
    let mut b = NetBuilder::new("c3d", &[batch, 3, 16, 112, 112]);
    let pool3d = |b: &mut NetBuilder, kt: usize| {
        // 3-D pooling decomposed into ops the (strictly NCHW) pool
        // vocabulary can express: fold depth into channels for the 2×2
        // spatial max pool, then reduce depth ×kt with a transpose +
        // global-pool mean (a structural stand-in for the temporal max —
        // same reduction pattern and traffic). The old single rank-5
        // `MaxPool` node declared a shape no square-window pool produces,
        // which the general kernel now rejects.
        let s = b.shape();
        let (n, c, d, h, w) = (s[0], s[1], s[2], s[3], s[4]);
        b.reshape(&[n, c * d, h, w]);
        b.maxpool(2, 2, 0);
        let s2 = b.shape();
        let (oh, ow) = (s2[2], s2[3]);
        if kt > 1 && d >= kt {
            let od = d / kt;
            // Group kt consecutive depth slices per output slice and
            // mean-reduce them: [n, c*od, kt, oh*ow] → [.., oh*ow, kt]
            // → one row per output element → global pool.
            b.reshape(&[n, c * od, kt, oh * ow]);
            b.transpose(&[0, 1, 3, 2]);
            b.reshape(&[n, c * od * oh * ow, 1, kt]);
            b.gap();
            b.reshape(&[n, c, od, oh, ow]);
        } else {
            b.reshape(&[n, c, d, oh, ow]);
        }
    };
    b.conv3d(64, 3, 3, 1, 1);
    b.act(Act::Relu);
    pool3d(&mut b, 1);
    b.conv3d(128, 3, 3, 1, 1);
    b.act(Act::Relu);
    pool3d(&mut b, 2);
    for &w in &[256usize, 256] {
        b.conv3d(w, 3, 3, 1, 1);
        b.act(Act::Relu);
    }
    pool3d(&mut b, 2);
    for &w in &[512usize, 512] {
        b.conv3d(w, 3, 3, 1, 1);
        b.act(Act::Relu);
    }
    pool3d(&mut b, 2);
    for &w in &[512usize, 512] {
        b.conv3d(w, 3, 3, 1, 1);
        b.act(Act::Relu);
    }
    pool3d(&mut b, 2);
    b.flatten();
    b.dense(4096);
    b.act(Act::Relu);
    b.dense(4096);
    b.act(Act::Relu);
    b.dense(487);
    b.finish()
}

/// R(2+1)D-34: 3-D convs factorized into 2-D spatial + 1-D temporal.
/// Published: ~63.6M params. Paper row: 64M / 76.3 GFLOPs ✓.
pub fn r2plus1d(batch: usize) -> Graph {
    let mut b = NetBuilder::new("r2plus1d", &[batch, 3, 16, 112, 112]);
    // Factorized conv: spatial 1×3×3 then temporal 3×1×1, with the
    // intermediate width M chosen (as in the paper) to keep params equal to
    // the full 3-D conv.
    fn conv2plus1d(b: &mut NetBuilder, c_out: usize, stride: usize) {
        let c_in = b.shape()[1];
        let m = (3 * 3 * 3 * c_in * c_out) / (3 * 3 * c_in + 3 * c_out);
        b.conv3d(m.max(1), 1, 3, stride, 1);
        b.bn();
        b.act(Act::Relu);
        b.conv3d(c_out, 3, 1, 1, 0);
    }
    b.conv3d(64, 3, 7, 2, 3);
    b.bn();
    b.act(Act::Relu);
    // ResNet-34 style: [3,4,6,3] basic blocks.
    for &(w, blocks, stride1) in &[(64usize, 3usize, 1usize), (128, 4, 2), (256, 6, 2), (512, 3, 2)] {
        for bi in 0..blocks {
            let stride = if bi == 0 { stride1 } else { 1 };
            let identity = b.cur();
            let shortcut = if bi == 0 && (stride != 1 || b.shape()[1] != w) {
                b.set_cur(identity);
                b.conv3d(w, 1, 1, stride, 0);
                b.bn();
                b.cur()
            } else {
                identity
            };
            b.set_cur(identity);
            conv2plus1d(&mut b, w, stride);
            b.bn();
            b.act(Act::Relu);
            conv2plus1d(&mut b, w, 1);
            b.bn();
            let t = b.cur();
            if b.g.node(shortcut).shape == b.g.node(t).shape {
                b.add_residual(shortcut, t);
            }
            b.act(Act::Relu);
        }
    }
    // Global spatiotemporal pool + readout.
    b.gap();
    b.dense(400);
    b.finish()
}

/// S3D: separable 3-D Inception. Published: ~8M params. Paper row:
/// 8.0M / 79.6 GFLOPs. Approximated as an inception-ish stack of separable
/// (spatial+temporal) conv blocks with channel concat branches.
pub fn s3d(batch: usize) -> Graph {
    let mut b = NetBuilder::new("s3d", &[batch, 3, 16, 112, 112]);
    fn sep_conv(b: &mut NetBuilder, c_out: usize, stride: usize) {
        b.conv3d(c_out, 1, 3, stride, 1);
        b.bn();
        b.act(Act::Relu);
        b.conv3d(c_out, 3, 1, 1, 0);
        b.bn();
        b.act(Act::Relu);
    }
    fn inception_sep(b: &mut NetBuilder, c1: usize, c3: usize) {
        let input = b.cur();
        b.conv3d(c1, 1, 1, 1, 0);
        b.bn();
        b.act(Act::Relu);
        let branch1 = b.cur();
        b.set_cur(input);
        b.conv3d(c3 / 2, 1, 1, 1, 0);
        b.bn();
        b.act(Act::Relu);
        sep_conv(b, c3, 1);
        let branch2 = b.cur();
        b.concat(&[branch1, branch2]);
    }
    b.conv3d(64, 1, 7, 2, 3);
    b.bn();
    b.act(Act::Relu);
    b.conv3d(64, 1, 1, 1, 0);
    b.bn();
    b.act(Act::Relu);
    sep_conv(&mut b, 192, 2);
    inception_sep(&mut b, 64, 128);
    inception_sep(&mut b, 96, 160);
    sep_conv(&mut b, 256, 2);
    inception_sep(&mut b, 128, 256);
    inception_sep(&mut b, 128, 256);
    sep_conv(&mut b, 384, 2);
    inception_sep(&mut b, 192, 320);
    b.gap();
    b.dense(400);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn c3d_matches_published() {
        let g = c3d(1);
        let p = g.total_params() as f64 / 1e6;
        assert!((60.0..90.0).contains(&p), "c3d params {p}M");
        let m = g.total_macs() as f64 / 1e9;
        assert!((20.0..60.0).contains(&m), "c3d macs {m}G");
    }

    #[test]
    fn r2plus1d_matches_published() {
        let g = r2plus1d(1);
        let p = g.total_params() as f64 / 1e6;
        assert!((40.0..80.0).contains(&p), "r2+1d params {p}M");
    }

    #[test]
    fn s3d_matches_published() {
        let g = s3d(1);
        let p = g.total_params() as f64 / 1e6;
        assert!((3.0..13.0).contains(&p), "s3d params {p}M");
    }

    #[test]
    fn video_models_use_conv3d() {
        use crate::graph::ops::OpKind;
        for g in [c3d(1), r2plus1d(1), s3d(1)] {
            let any3d = g.nodes.iter().any(|n| matches!(n.op, OpKind::Conv3d { .. }));
            assert!(any3d, "{} has no conv3d", g.name);
        }
    }
}
