//! Remaining Table 3/4 + Fig 21 models: U-Net segmentation, WDSR-b
//! super-resolution (use case III), fast-style-transfer, CycleGAN generator.

use super::NetBuilder;
use crate::graph::ir::Graph;
use crate::graph::ops::Act;

/// The small 8-class demo CNN (mirrors `python/compile/model.py`'s
/// trained artifact: 3×24×24 input, conv stack with a residual block,
/// 8-way classifier head). Small enough to CPU-execute in tests and to
/// serve through `coordinator::Server` without AOT artifacts — the
/// default model of `xgen serve` and the `api` test matrix.
pub fn demo_cnn(batch: usize) -> Graph {
    let mut b = NetBuilder::new("demo-cnn", &[batch, 3, 24, 24]);
    b.conv_bn_act(16, 3, 1, 1, Act::Relu);
    let skip = b.cur();
    b.conv_bn_act(16, 3, 1, 1, Act::Relu);
    let t = b.cur();
    b.add_residual(skip, t);
    b.maxpool(2, 2, 0);
    b.conv_bn_act(32, 3, 1, 1, Act::Relu);
    b.maxpool(2, 2, 0);
    b.gap();
    b.dense(8);
    b.finish()
}

/// Slim U-Net (paper row: 2.1M params / 15 GFLOPs — a mobile variant, so
/// base width 22 rather than the classic 64).
pub fn unet(batch: usize) -> Graph {
    let w0 = 18usize;
    let mut b = NetBuilder::new("u-net", &[batch, 3, 256, 256]);
    let mut skips = Vec::new();
    // Encoder: 4 down stages.
    let mut w = w0;
    for _ in 0..4 {
        b.conv_bn_act(w, 3, 1, 1, Act::Relu);
        b.conv_bn_act(w, 3, 1, 1, Act::Relu);
        skips.push(b.cur());
        b.maxpool(2, 2, 0);
        w *= 2;
    }
    // Bottleneck.
    b.conv_bn_act(w, 3, 1, 1, Act::Relu);
    b.conv_bn_act(w, 3, 1, 1, Act::Relu);
    // Decoder.
    for skip in skips.into_iter().rev() {
        w /= 2;
        b.deconv(w, 2, 2);
        let up = b.cur();
        b.concat(&[up, skip]);
        b.conv_bn_act(w, 3, 1, 1, Act::Relu);
        b.conv_bn_act(w, 3, 1, 1, Act::Relu);
    }
    b.conv(2, 1, 1, 0, 1); // binary segmentation head
    b.finish()
}

/// WDSR-b super-resolution (use case III; Table 4 row: 22.2K params /
/// 11.5 GMACs — tiny params, huge spatial). ×2 upscale from 360p.
pub fn wdsr_b(batch: usize) -> Graph {
    let feats = 16usize;
    let mut b = NetBuilder::new("wdsr-b", &[batch, 3, 360, 640]);
    b.conv(feats, 3, 1, 1, 1);
    let mut trunk = b.cur();
    // 4 wide-activation residual blocks (expand 4x via 1x1, contract, 3x3).
    for _ in 0..4 {
        b.set_cur(trunk);
        b.conv(feats * 4, 1, 1, 0, 1);
        b.act(Act::Relu);
        b.conv(feats, 1, 1, 0, 1);
        b.conv(feats, 3, 1, 1, 1);
        let body = b.cur();
        trunk = b.add_residual(trunk, body);
    }
    b.set_cur(trunk);
    // Upsample head: conv to 3*r^2 then pixel shuffle.
    b.conv(3 * 4, 3, 1, 1, 1);
    b.pixel_shuffle(2);
    let main = b.cur();
    // Global skip: shallow conv path from input.
    b.set_cur(0);
    b.conv(3 * 4, 5, 1, 2, 1);
    b.pixel_shuffle(2);
    let skip = b.cur();
    b.add_residual(main, skip);
    b.finish()
}

/// Fast style transfer (Johnson et al.): down ×2, 5 res blocks, up ×2.
/// Paper row: 1.7M params / 161 GMACs @ high-res input.
pub fn fst(batch: usize) -> Graph {
    let mut b = NetBuilder::new("fst", &[batch, 3, 512, 512]);
    b.conv_bn_act(32, 9, 1, 4, Act::Relu);
    b.conv_bn_act(64, 3, 2, 1, Act::Relu);
    b.conv_bn_act(128, 3, 2, 1, Act::Relu);
    for _ in 0..5 {
        let inp = b.cur();
        b.conv_bn_act(128, 3, 1, 1, Act::Relu);
        b.conv(128, 3, 1, 1, 1);
        b.bn();
        let t = b.cur();
        b.add_residual(inp, t);
    }
    b.deconv(64, 3, 2);
    b.bn();
    b.act(Act::Relu);
    b.deconv(32, 3, 2);
    b.bn();
    b.act(Act::Relu);
    b.conv(3, 9, 1, 4, 1);
    b.act(Act::Tanh);
    b.finish()
}

/// CycleGAN generator (ResNet, 9 blocks). Paper row: 11M params / 186 GMACs.
pub fn cyclegan(batch: usize) -> Graph {
    let mut b = NetBuilder::new("cyclegan", &[batch, 3, 512, 512]);
    b.conv_bn_act(64, 7, 1, 3, Act::Relu);
    b.conv_bn_act(128, 3, 2, 1, Act::Relu);
    b.conv_bn_act(256, 3, 2, 1, Act::Relu);
    for _ in 0..9 {
        let inp = b.cur();
        b.conv_bn_act(256, 3, 1, 1, Act::Relu);
        b.conv(256, 3, 1, 1, 1);
        b.bn();
        let t = b.cur();
        b.add_residual(inp, t);
    }
    b.deconv(128, 3, 2);
    b.bn();
    b.act(Act::Relu);
    b.deconv(64, 3, 2);
    b.bn();
    b.act(Act::Relu);
    b.conv(3, 7, 1, 3, 1);
    b.act(Act::Tanh);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unet_scale() {
        let g = unet(1);
        let p = g.total_params() as f64 / 1e6;
        assert!((1.2..3.2).contains(&p), "unet params {p}M");
        let m = g.total_macs() as f64 / 1e9;
        assert!((3.0..12.0).contains(&m), "unet macs {m}G");
    }

    #[test]
    fn wdsr_tiny_params_big_macs() {
        let g = wdsr_b(1);
        let p = g.total_params() as f64 / 1e3;
        assert!((12.0..40.0).contains(&p), "wdsr params {p}K");
        let m = g.total_macs() as f64 / 1e9;
        assert!((2.0..15.0).contains(&m), "wdsr macs {m}G");
        // Output is 2x the input spatial size.
        let out = &g.node(g.outputs[0]).shape;
        assert_eq!(out, &vec![1, 3, 720, 1280]);
    }

    #[test]
    fn fst_scale() {
        let g = fst(1);
        let p = g.total_params() as f64 / 1e6;
        assert!((1.2..2.4).contains(&p), "fst params {p}M");
        let m = g.total_macs() as f64 / 1e9;
        assert!((25.0..120.0).contains(&m), "fst macs {m}G");
    }

    #[test]
    fn cyclegan_scale() {
        let g = cyclegan(1);
        let p = g.total_params() as f64 / 1e6;
        assert!((9.0..14.0).contains(&p), "cyclegan params {p}M");
    }

    #[test]
    fn generators_preserve_resolution() {
        let g = fst(1);
        let out = &g.node(g.outputs[0]).shape;
        assert_eq!(out, &vec![1, 3, 512, 512]);
        let g = cyclegan(1);
        let out = &g.node(g.outputs[0]).shape;
        assert_eq!(out, &vec![1, 3, 512, 512]);
    }
}
