//! Detection / segmentation models from Table 3 / Table 4. These are
//! structural approximations at the fidelity the cost model needs (operator
//! mix, parameter and MAC scale); where the paper's exact variant is
//! ambiguous (input resolution, head widths) we note the choice.

use super::{cnn, NetBuilder};
use crate::graph::ir::Graph;
use crate::graph::ops::{Act, OpKind};

/// MobileNetV1-SSD @300: MobileNetV1 backbone + SSD extra feature layers +
/// class/box heads. Paper lists 9.5M params / 3.0 GFLOPs.
pub fn mobilenet_v1_ssd(batch: usize) -> Graph {
    let mut b = NetBuilder::new("mobilenet-v1-ssd", &[batch, 3, 300, 300]);
    b.conv_bn_act(32, 3, 2, 1, Act::Relu);
    let cfg: [(usize, usize); 13] = [
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    let mut feature_maps = Vec::new();
    for (i, (c, s)) in cfg.into_iter().enumerate() {
        b.dwconv(3, s, 1);
        b.bn();
        b.act(Act::Relu);
        b.conv_bn_act(c, 1, 1, 0, Act::Relu);
        if i == 10 || i == 12 {
            feature_maps.push(b.cur());
        }
    }
    // SSD extra layers (4 stages of 1x1 reduce + 3x3/2).
    for &w in &[512usize, 256, 256, 128] {
        b.conv_bn_act(w / 2, 1, 1, 0, Act::Relu);
        b.conv_bn_act(w, 3, 2, 1, Act::Relu);
        feature_maps.push(b.cur());
    }
    // Heads: 6 anchors x (21 classes + 4 box) per feature map.
    let mut heads = Vec::new();
    for &fm in &feature_maps {
        b.set_cur(fm);
        b.conv(6 * 25, 3, 1, 1, 1);
        heads.push(b.cur());
    }
    // Post-process (NMS) consumes all heads.
    let shape = vec![batch, 100, 6];
    let pp = b.g.add("nms", OpKind::PostProcess, heads, shape);
    b.set_cur(pp);
    b.finish()
}

/// CSP bottleneck stage used by the YOLO-v4 backbone approximation.
fn csp_stage(b: &mut NetBuilder, c: usize, blocks: usize) {
    b.conv_bn_act(c, 3, 2, 1, Act::Mish);
    let split = b.cur();
    // Main branch.
    b.conv_bn_act(c / 2, 1, 1, 0, Act::Mish);
    for _ in 0..blocks {
        let inp = b.cur();
        b.conv_bn_act(c / 2, 1, 1, 0, Act::Mish);
        b.conv_bn_act(c / 2, 3, 1, 1, Act::Mish);
        let t = b.cur();
        b.add_residual(inp, t);
    }
    let main = b.cur();
    // Shortcut branch.
    b.set_cur(split);
    b.conv_bn_act(c / 2, 1, 1, 0, Act::Mish);
    let short = b.cur();
    b.concat(&[main, short]);
    b.conv_bn_act(c, 1, 1, 0, Act::Mish);
}

/// YOLO-v4 @416: CSPDarknet53 backbone + SPP + PAN neck + 3 YOLO heads.
/// Paper lists 64M params / 34.6 GFLOPs.
pub fn yolo_v4(batch: usize) -> Graph {
    let mut b = NetBuilder::new("yolo-v4", &[batch, 3, 416, 416]);
    b.conv_bn_act(32, 3, 1, 1, Act::Mish);
    csp_stage(&mut b, 64, 1);
    csp_stage(&mut b, 128, 2);
    csp_stage(&mut b, 256, 8);
    let p3 = b.cur();
    csp_stage(&mut b, 512, 8);
    let p4 = b.cur();
    csp_stage(&mut b, 1024, 4);
    // SPP: parallel maxpools + concat.
    b.conv_bn_act(512, 1, 1, 0, Act::LeakyRelu);
    let spp_in = b.cur();
    let mut pools = vec![spp_in];
    for &k in &[5usize, 9, 13] {
        b.set_cur(spp_in);
        let s = b.shape();
        let name = format!("spp_pool{k}");
        // SPP pools are "same"-padded (k odd, stride 1) so the branches
        // concat at equal spatial size.
        let id = b.g.add(&name, OpKind::MaxPool { k, stride: 1, pad: k / 2 }, vec![spp_in], s);
        pools.push(id);
    }
    b.concat(&pools);
    b.conv_bn_act(512, 1, 1, 0, Act::LeakyRelu);
    b.conv_bn_act(1024, 3, 1, 1, Act::LeakyRelu);
    b.conv_bn_act(512, 1, 1, 0, Act::LeakyRelu);
    let p5 = b.cur();

    // PAN top-down: P5 -> P4 -> P3.
    b.conv_bn_act(256, 1, 1, 0, Act::LeakyRelu);
    b.upsample(2);
    let up5 = b.cur();
    b.set_cur(p4);
    b.conv_bn_act(256, 1, 1, 0, Act::LeakyRelu);
    let lat4 = b.cur();
    b.concat(&[lat4, up5]);
    for _ in 0..2 {
        b.conv_bn_act(256, 1, 1, 0, Act::LeakyRelu);
        b.conv_bn_act(512, 3, 1, 1, Act::LeakyRelu);
    }
    b.conv_bn_act(256, 1, 1, 0, Act::LeakyRelu);
    let n4 = b.cur();
    b.conv_bn_act(128, 1, 1, 0, Act::LeakyRelu);
    b.upsample(2);
    let up4 = b.cur();
    b.set_cur(p3);
    b.conv_bn_act(128, 1, 1, 0, Act::LeakyRelu);
    let lat3 = b.cur();
    b.concat(&[lat3, up4]);
    for _ in 0..2 {
        b.conv_bn_act(128, 1, 1, 0, Act::LeakyRelu);
        b.conv_bn_act(256, 3, 1, 1, Act::LeakyRelu);
    }
    let n3 = b.cur();

    // Heads at three scales (80 classes: 3*(80+5)=255 channels).
    let mut heads = Vec::new();
    b.set_cur(n3);
    b.conv_bn_act(256, 3, 1, 1, Act::LeakyRelu);
    b.conv(255, 1, 1, 0, 1);
    heads.push(b.cur());
    b.set_cur(n4);
    b.conv_bn_act(512, 3, 1, 1, Act::LeakyRelu);
    b.conv(255, 1, 1, 0, 1);
    heads.push(b.cur());
    b.set_cur(p5);
    b.conv_bn_act(1024, 3, 1, 1, Act::LeakyRelu);
    b.conv(255, 1, 1, 0, 1);
    heads.push(b.cur());
    let pp = b.g.add("yolo_decode", OpKind::PostProcess, heads, vec![batch, 100, 6]);
    b.set_cur(pp);
    b.finish()
}

/// PointPillars (LiDAR 3-D detection): pillar feature net (dense on points)
/// → scatter to BEV pseudo-image → 2-D CNN backbone → SSD-style head.
/// Paper lists 4.8M params / 97 GFLOPs (large point count dominates MACs).
pub fn pointpillar(batch: usize) -> Graph {
    // Pillar feature net over [batch, 9, 12000 pillars, 32 points] as a
    // 1x1-conv formulation (the standard deployment form).
    let mut b = NetBuilder::new("pointpillar", &[batch, 9, 12000, 32]);
    b.conv_bn_act(64, 1, 1, 0, Act::Relu);
    // Reduce over the 32 points of each pillar → [batch, 64, 12000, 1].
    // The real op is a per-pillar *max* over the points axis only — a 1×32
    // rectangular window the square-pool vocabulary cannot express (the
    // old `MaxPool { k: 32, stride: 32 }` node declared the per-pillar
    // shape while the op semantics said 32×32, an inconsistency the
    // now-general pool kernel would reject at run time). Model it as a
    // reshape to one pillar per row + global pool + reshape back: same
    // reduction structure and traffic, mean instead of max (this is a
    // structural model; the scatter right after is estimate-only anyway).
    let s = b.shape();
    let (pillars, points) = (s[2], s[3]);
    b.reshape(&[s[0], s[1] * pillars, 1, points]);
    b.gap();
    b.reshape(&[s[0], s[1], pillars, 1]);
    let scatter = b.g.add(
        "scatter_bev",
        OpKind::Gather,
        vec![b.cur()],
        vec![batch, 64, 496, 432],
    );
    b.set_cur(scatter);
    // Backbone: 3 blocks (S=2 each, widths 64/128/256, 4/6/6 convs).
    let mut features = Vec::new();
    for &(w, n) in &[(64usize, 4usize), (128, 6), (256, 6)] {
        b.conv_bn_act(w, 3, 2, 1, Act::Relu);
        for _ in 0..n - 1 {
            b.conv_bn_act(w, 3, 1, 1, Act::Relu);
        }
        features.push(b.cur());
    }
    // Upsample each to common scale and concat.
    let mut ups = Vec::new();
    for (i, &f) in features.iter().enumerate() {
        b.set_cur(f);
        b.deconv(128, 3, 1 << i);
        b.bn();
        b.act(Act::Relu);
        ups.push(b.cur());
    }
    b.concat(&ups);
    b.conv(2 * (1 + 7), 1, 1, 0, 1); // cls + box head
    let pp = b.g.add("pp_decode", OpKind::PostProcess, vec![b.cur()], vec![batch, 100, 9]);
    b.set_cur(pp);
    b.finish()
}

/// PIXOR (BEV 3-D detection, Table 4): 2-D CNN over a BEV rasterization.
/// Paper lists 2.1M params / 8.8 GMACs.
pub fn pixor(batch: usize) -> Graph {
    let mut b = NetBuilder::new("pixor", &[batch, 36, 400, 352]);
    b.conv_bn_act(32, 3, 1, 1, Act::Relu);
    b.conv_bn_act(48, 3, 2, 1, Act::Relu);
    for &(w, n, s) in &[(64usize, 3usize, 2usize), (128, 3, 2), (256, 3, 2)] {
        b.conv_bn_act(w, 3, s, 1, Act::Relu);
        for _ in 0..n - 1 {
            b.conv_bn_act(w, 3, 1, 1, Act::Relu);
        }
    }
    // Header: upsample back and predict.
    b.deconv(96, 3, 2);
    b.act(Act::Relu);
    b.conv_bn_act(96, 3, 1, 1, Act::Relu);
    b.conv(1 + 6, 1, 1, 0, 1);
    b.finish()
}

/// EfficientDet-d0: EfficientNet-B0 backbone + 3x BiFPN + shared heads.
/// Paper lists 4.3M params / 2.6 GMACs / 822 operators (ours has fewer
/// operator nodes because resize/pad minutiae are folded).
pub fn efficientdet_d0(batch: usize) -> Graph {
    let mut b = NetBuilder::new("efficientdet-d0", &[batch, 3, 512, 512]);
    // Backbone (EfficientNet-B0 trunk, no classifier).
    b.conv_bn_act(32, 3, 2, 1, Act::Swish);
    let cfg: [(usize, usize, usize, usize, usize); 7] = [
        (16, 1, 3, 1, 1),
        (24, 2, 3, 2, 6),
        (40, 2, 5, 2, 6),
        (80, 3, 3, 2, 6),
        (112, 3, 5, 1, 6),
        (192, 4, 5, 2, 6),
        (320, 1, 3, 1, 6),
    ];
    let mut taps = Vec::new();
    for (c, n, k, s, t) in cfg {
        for i in 0..n {
            cnn::inverted_residual(&mut b, c, k, if i == 0 { s } else { 1 }, t, true, Act::Swish);
        }
        if matches!(c, 40 | 112 | 320) {
            taps.push(b.cur());
        }
    }
    // BiFPN (3 repeats, width 64): per repeat, lateral 1x1s + fused dw convs.
    let w = 64usize;
    let mut levels: Vec<_> = taps
        .iter()
        .map(|&t| {
            b.set_cur(t);
            b.conv_bn_act(w, 1, 1, 0, Act::Swish);
            b.cur()
        })
        .collect();
    for _ in 0..3 {
        let mut next = Vec::new();
        for (i, &l) in levels.iter().enumerate() {
            b.set_cur(l);
            if i > 0 {
                // Fuse with a resized neighbour (structure proxy: upsample+add).
                let nb = levels[i - 1];
                let ls = b.g.node(l).shape.clone();
                let resized = b.g.add(
                    &format!("bifpn_resize_{}_{}", i, b.g.len()),
                    OpKind::Upsample { r: 1 },
                    vec![nb],
                    ls,
                );
                let _sum = b.add_residual(l, resized);
            }
            b.dwconv(3, 1, 1);
            b.bn();
            b.act(Act::Swish);
            b.conv_bn_act(w, 1, 1, 0, Act::Swish);
            next.push(b.cur());
        }
        levels = next;
    }
    // Heads (3 shared convs + predict) per level.
    let mut heads = Vec::new();
    for &l in &levels {
        b.set_cur(l);
        for _ in 0..3 {
            b.dwconv(3, 1, 1);
            b.conv_bn_act(w, 1, 1, 0, Act::Swish);
        }
        b.conv(9 * (90 + 4), 1, 1, 0, 1);
        heads.push(b.cur());
    }
    let pp = b.g.add("ed_decode", OpKind::PostProcess, heads, vec![batch, 100, 6]);
    b.set_cur(pp);
    b.finish()
}

/// Faster R-CNN (ResNet-50 FPN): backbone + FPN + RPN + RoI box head.
/// Paper lists 41M params / 47 GFLOPs.
pub fn faster_rcnn(batch: usize) -> Graph {
    rcnn(batch, false)
}

/// Mask R-CNN: Faster R-CNN + mask head. Paper lists 44M / 184 GFLOPs.
pub fn mask_rcnn(batch: usize) -> Graph {
    rcnn(batch, true)
}

fn rcnn(batch: usize, with_mask: bool) -> Graph {
    let name = if with_mask { "mask-rcnn" } else { "faster-rcnn" };
    let mut b = NetBuilder::new(name, &[batch, 3, 800, 800]);
    // ResNet-50 trunk with taps (reuse stage logic inline).
    b.conv_bn_act(64, 7, 2, 3, Act::Relu);
    b.maxpool(3, 2, 1);
    let mut taps = Vec::new();
    for &(w, blocks, stride1) in &[(64usize, 3usize, 1usize), (128, 4, 2), (256, 6, 2), (512, 3, 2)] {
        for bi in 0..blocks {
            let stride = if bi == 0 { stride1 } else { 1 };
            let identity = b.cur();
            let shortcut = if bi == 0 {
                b.set_cur(identity);
                b.conv(w * 4, 1, stride, 0, 1);
                b.bn();
                b.cur()
            } else {
                identity
            };
            b.set_cur(identity);
            b.conv_bn_act(w, 1, 1, 0, Act::Relu);
            b.conv_bn_act(w, 3, stride, 1, Act::Relu);
            b.conv(w * 4, 1, 1, 0, 1);
            b.bn();
            let trunk = b.cur();
            b.add_residual(shortcut, trunk);
            b.act(Act::Relu);
        }
        taps.push(b.cur());
    }
    // FPN laterals.
    let mut pyramid = Vec::new();
    for &t in taps.iter().rev() {
        b.set_cur(t);
        b.conv(256, 1, 1, 0, 1);
        b.conv(256, 3, 1, 1, 1);
        pyramid.push(b.cur());
    }
    // RPN on each level.
    let mut rois = Vec::new();
    for &p in &pyramid {
        b.set_cur(p);
        b.conv_bn_act(256, 3, 1, 1, Act::Relu);
        b.conv(3 * 5, 1, 1, 0, 1);
        rois.push(b.cur());
    }
    let roi_align = b.g.add("roi_align", OpKind::Gather, rois, vec![batch * 100, 256, 7, 7]);
    b.set_cur(roi_align);
    // Box head: 2 fc over pooled features.
    b.flatten();
    b.dense(1024);
    b.act(Act::Relu);
    b.dense(1024);
    b.act(Act::Relu);
    b.dense(91 * 5);
    let box_out = b.cur();
    let mut outs = vec![box_out];
    if with_mask {
        b.set_cur(roi_align);
        for _ in 0..4 {
            b.conv_bn_act(256, 3, 1, 1, Act::Relu);
        }
        b.deconv(256, 2, 2);
        b.act(Act::Relu);
        b.conv(91, 1, 1, 0, 1);
        outs.push(b.cur());
    }
    b.finish_multi(outs)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ssd_scale() {
        let g = mobilenet_v1_ssd(1);
        let p = g.total_params() as f64 / 1e6;
        assert!((6.0..12.0).contains(&p), "ssd params {p}M");
    }

    #[test]
    fn yolo_scale() {
        let g = yolo_v4(1);
        let p = g.total_params() as f64 / 1e6;
        // Published 64M; our CSP approximation trims the neck slightly.
        assert!((38.0..75.0).contains(&p), "yolo params {p}M");
        let m = g.total_macs() as f64 / 1e9;
        assert!((15.0..45.0).contains(&m), "yolo macs {m}G");
    }

    #[test]
    fn pointpillar_scale() {
        let g = pointpillar(1);
        let p = g.total_params() as f64 / 1e6;
        assert!((2.0..8.0).contains(&p), "pointpillar params {p}M");
    }

    #[test]
    fn pixor_scale() {
        let g = pixor(1);
        let p = g.total_params() as f64 / 1e6;
        assert!((1.0..3.5).contains(&p), "pixor params {p}M");
        let m = g.total_macs() as f64 / 1e9;
        assert!((4.0..15.0).contains(&m), "pixor macs {m}G");
    }

    #[test]
    fn efficientdet_scale() {
        let g = efficientdet_d0(1);
        let p = g.total_params() as f64 / 1e6;
        assert!((3.0..8.0).contains(&p), "efficientdet params {p}M");
        assert!(g.operator_count() > 200, "efficientdet op count {}", g.operator_count());
    }

    #[test]
    fn rcnn_scale_and_mask_extra() {
        let f = faster_rcnn(1);
        let m = mask_rcnn(1);
        let fp = f.total_params() as f64 / 1e6;
        assert!((30.0..50.0).contains(&fp), "faster-rcnn params {fp}M");
        assert!(m.total_params() > f.total_params());
        assert!(m.total_macs() > f.total_macs());
        assert_eq!(m.outputs.len(), 2);
    }
}
