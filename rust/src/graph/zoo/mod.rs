//! Model zoo: graph-level reconstructions of every network the paper's
//! evaluation touches (Tables 3–4, Figs 6/14/19/21). These are *structural*
//! models — correct operator sequences, shapes, parameter and MAC counts —
//! used by the compiler passes and the device cost model. (The numerically
//! executed demo models live in `python/compile/model.py` and
//! [`crate::exec`]; the zoo's job is to make the paper's tables
//! reproducible at the right scale.)
//!
//! Parameter/MAC counts are asserted against published figures in each
//! builder's tests where the architecture is unambiguous, and documented as
//! approximations where the paper's variant is underspecified (e.g.
//! EfficientDet's exact BiFPN repeat count).

pub mod cnn;
pub mod detect;
pub mod video;
pub mod nlp;
pub mod misc;

use super::ir::{conv_out, Graph, NodeId};
use super::ops::{Act, OpKind};

/// Fluent builder over [`Graph`] tracking the "current" tensor, with the
/// composite blocks (conv-bn-act, inverted residual, SE, attention, ...)
/// the zoo architectures are made of.
pub struct NetBuilder {
    pub g: Graph,
    cur: NodeId,
    /// Monotonic counter for unique node names.
    n: usize,
}

impl NetBuilder {
    /// Start a network with one input of the given shape (NCHW / NCDHW / NLC).
    pub fn new(name: &str, input_shape: &[usize]) -> NetBuilder {
        let mut g = Graph::new(name);
        let cur = g.input("input", input_shape);
        NetBuilder { g, cur, n: 0 }
    }

    fn uid(&mut self, base: &str) -> String {
        self.n += 1;
        format!("{}_{}", base, self.n)
    }

    /// Current tensor id.
    pub fn cur(&self) -> NodeId {
        self.cur
    }

    /// Current tensor shape.
    pub fn shape(&self) -> Vec<usize> {
        self.g.node(self.cur).shape.clone()
    }

    /// Reset the current tensor (branching).
    pub fn set_cur(&mut self, id: NodeId) -> &mut Self {
        self.cur = id;
        self
    }

    /// Finish: mark current tensor as the output and return the graph.
    pub fn finish(mut self) -> Graph {
        self.g.outputs = vec![self.cur];
        debug_assert!(self.g.validate().is_ok());
        self.g
    }

    /// Finish with explicit outputs (multi-head models).
    pub fn finish_multi(mut self, outputs: Vec<NodeId>) -> Graph {
        self.g.outputs = outputs;
        debug_assert!(self.g.validate().is_ok());
        self.g
    }

    // ---- primitive layers ------------------------------------------------

    /// conv2d (+ optional groups); updates current tensor. Input NCHW.
    pub fn conv(&mut self, c_out: usize, k: usize, stride: usize, pad: usize, groups: usize) -> NodeId {
        let s = self.shape();
        assert_eq!(s.len(), 4, "conv on non-4d tensor for {}", self.g.name);
        let (n, c, h, w) = (s[0], s[1], s[2], s[3]);
        assert!(c % groups == 0 && c_out % groups == 0);
        let name = self.uid("conv");
        let wname = format!("{name}_w");
        let wgt = self.g.weight(&wname, &[c_out, c / groups, k, k]);
        let oh = conv_out(h, k, stride, pad);
        let ow = conv_out(w, k, stride, pad);
        let id = self.g.add(
            &name,
            OpKind::Conv2d { k, stride, pad, groups },
            vec![self.cur, wgt],
            vec![n, c_out, oh, ow],
        );
        self.cur = id;
        id
    }

    /// Depthwise conv (groups == channels).
    pub fn dwconv(&mut self, k: usize, stride: usize, pad: usize) -> NodeId {
        let c = self.shape()[1];
        self.conv(c, k, stride, pad, c)
    }

    /// conv3d over NCDHW.
    pub fn conv3d(&mut self, c_out: usize, kt: usize, k: usize, stride: usize, pad: usize) -> NodeId {
        let s = self.shape();
        assert_eq!(s.len(), 5);
        let (n, c, d, h, w) = (s[0], s[1], s[2], s[3], s[4]);
        let name = self.uid("conv3d");
        let wname = format!("{name}_w");
        let wgt = self.g.weight(&wname, &[c_out, c, kt, k, k]);
        let od = conv_out(d, kt, stride, kt / 2);
        let oh = conv_out(h, k, stride, pad);
        let ow = conv_out(w, k, stride, pad);
        let id = self.g.add(
            &name,
            OpKind::Conv3d { kt, k, stride, pad },
            vec![self.cur, wgt],
            vec![n, c_out, od, oh, ow],
        );
        self.cur = id;
        id
    }

    /// Transposed conv doubling spatial size.
    pub fn deconv(&mut self, c_out: usize, k: usize, stride: usize) -> NodeId {
        let s = self.shape();
        let (n, c, h, w) = (s[0], s[1], s[2], s[3]);
        let name = self.uid("deconv");
        let wname = format!("{name}_w");
        let wgt = self.g.weight(&wname, &[c, c_out, k, k]);
        let id = self.g.add(
            &name,
            OpKind::ConvTranspose2d { k, stride, pad: k / 2 },
            vec![self.cur, wgt],
            vec![n, c_out, h * stride, w * stride],
        );
        self.cur = id;
        id
    }

    /// Inference batch-norm (scale+shift weights).
    pub fn bn(&mut self) -> NodeId {
        let s = self.shape();
        let c = s[1];
        let name = self.uid("bn");
        let wname = format!("{name}_w");
        let wgt = self.g.weight(&wname, &[2, c]);
        let id = self.g.add(&name, OpKind::BatchNorm, vec![self.cur, wgt], s);
        self.cur = id;
        id
    }

    /// Per-channel bias.
    pub fn bias(&mut self) -> NodeId {
        let s = self.shape();
        let c = if s.len() >= 2 { s[1] } else { s[0] };
        let name = self.uid("bias");
        let wname = format!("{name}_w");
        let wgt = self.g.weight(&wname, &[c]);
        let id = self.g.add(&name, OpKind::Bias, vec![self.cur, wgt], s);
        self.cur = id;
        id
    }

    /// Activation.
    pub fn act(&mut self, a: Act) -> NodeId {
        let s = self.shape();
        let name = self.uid("act");
        let id = self.g.add(&name, OpKind::Activation(a), vec![self.cur], s);
        self.cur = id;
        id
    }

    /// conv + bn + activation, the workhorse CNN block.
    pub fn conv_bn_act(&mut self, c_out: usize, k: usize, stride: usize, pad: usize, a: Act) -> NodeId {
        self.conv(c_out, k, stride, pad, 1);
        self.bn();
        self.act(a)
    }

    /// Max pool k×k stride s with symmetric zero padding. Output spatial
    /// size is `(h + 2*pad − k)/stride + 1` (conv_out semantics) — the old
    /// `h/stride` shape ignored the kernel size and was wrong whenever
    /// k ≠ stride (e.g. k=3, s=1).
    pub fn maxpool(&mut self, k: usize, stride: usize, pad: usize) -> NodeId {
        let s = self.shape();
        let (n, c, h, w) = (s[0], s[1], s[2], s[3]);
        let name = self.uid("maxpool");
        let id = self.g.add(
            &name,
            OpKind::MaxPool { k, stride, pad },
            vec![self.cur],
            vec![n, c, conv_out(h, k, stride, pad), conv_out(w, k, stride, pad)],
        );
        self.cur = id;
        id
    }

    /// Average pool k×k stride s with symmetric padding (same windowed
    /// output-shape semantics as [`NetBuilder::maxpool`]).
    pub fn avgpool(&mut self, k: usize, stride: usize, pad: usize) -> NodeId {
        let s = self.shape();
        let (n, c, h, w) = (s[0], s[1], s[2], s[3]);
        let name = self.uid("avgpool");
        let id = self.g.add(
            &name,
            OpKind::AvgPool { k, stride, pad },
            vec![self.cur],
            vec![n, c, conv_out(h, k, stride, pad), conv_out(w, k, stride, pad)],
        );
        self.cur = id;
        id
    }

    /// Global average pool to [n, c].
    pub fn gap(&mut self) -> NodeId {
        let s = self.shape();
        let name = self.uid("gap");
        let id = self.g.add(&name, OpKind::GlobalAvgPool, vec![self.cur], vec![s[0], s[1]]);
        self.cur = id;
        id
    }

    /// Dense layer on the last dim.
    pub fn dense(&mut self, out_f: usize) -> NodeId {
        let mut s = self.shape();
        let in_f = *s.last().unwrap();
        *s.last_mut().unwrap() = out_f;
        let name = self.uid("dense");
        let wname = format!("{name}_w");
        let wgt = self.g.weight(&wname, &[in_f, out_f]);
        let id = self.g.add(&name, OpKind::Dense, vec![self.cur, wgt], s);
        self.cur = id;
        id
    }

    /// General axis permutation (`out.shape[i] = in.shape[perm[i]]`).
    pub fn transpose(&mut self, perm: &[usize]) -> NodeId {
        let s = self.shape();
        assert_eq!(perm.len(), s.len(), "transpose perm rank mismatch in {}", self.g.name);
        let shape: Vec<usize> = perm.iter().map(|&p| s[p]).collect();
        let name = self.uid("transpose");
        let id = self.g.add(
            &name,
            OpKind::Transpose { perm: perm.to_vec() },
            vec![self.cur],
            shape,
        );
        self.cur = id;
        id
    }

    /// Contiguous crop: keep `len[d]` elements of each dim starting at
    /// `start[d]`.
    pub fn slice(&mut self, start: &[usize], len: &[usize]) -> NodeId {
        let s = self.shape();
        assert_eq!(start.len(), s.len(), "slice rank mismatch in {}", self.g.name);
        assert_eq!(len.len(), s.len(), "slice rank mismatch in {}", self.g.name);
        let name = self.uid("slice");
        let id = self.g.add(
            &name,
            OpKind::Slice { start: start.to_vec() },
            vec![self.cur],
            len.to_vec(),
        );
        self.cur = id;
        id
    }

    /// Zero-pad each dim by (before, after) elements.
    pub fn pad(&mut self, before: &[usize], after: &[usize]) -> NodeId {
        let s = self.shape();
        assert_eq!(before.len(), s.len(), "pad rank mismatch in {}", self.g.name);
        assert_eq!(after.len(), s.len(), "pad rank mismatch in {}", self.g.name);
        let shape: Vec<usize> =
            s.iter().zip(before).zip(after).map(|((&x, &b), &a)| x + b + a).collect();
        let name = self.uid("pad");
        let id = self.g.add(
            &name,
            OpKind::Pad { before: before.to_vec(), after: after.to_vec() },
            vec![self.cur],
            shape,
        );
        self.cur = id;
        id
    }

    /// Reshape to an explicit shape of the same element count.
    pub fn reshape(&mut self, shape: &[usize]) -> NodeId {
        let name = self.uid("reshape");
        let id = self.g.add(&name, OpKind::Reshape, vec![self.cur], shape.to_vec());
        self.cur = id;
        id
    }

    /// Flatten NCHW → [n, c*h*w].
    pub fn flatten(&mut self) -> NodeId {
        let s = self.shape();
        let n = s[0];
        let rest: usize = s[1..].iter().product();
        let name = self.uid("flatten");
        let id = self.g.add(&name, OpKind::Flatten, vec![self.cur], vec![n, rest]);
        self.cur = id;
        id
    }

    /// Residual add of two tensors (shapes must match).
    pub fn add_residual(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let sa = self.g.node(a).shape.clone();
        assert_eq!(sa, self.g.node(b).shape, "residual shape mismatch in {}", self.g.name);
        let name = self.uid("add");
        let id = self.g.add(&name, OpKind::Add, vec![a, b], sa);
        self.cur = id;
        id
    }

    /// Elementwise multiply (SE gates, attention masks).
    pub fn mul(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let sa = self.g.node(a).shape.clone();
        let name = self.uid("mul");
        let id = self.g.add(&name, OpKind::Mul, vec![a, b], sa);
        self.cur = id;
        id
    }

    /// Concat along channel dim.
    pub fn concat(&mut self, parts: &[NodeId]) -> NodeId {
        let mut s = self.g.node(parts[0]).shape.clone();
        s[1] = parts.iter().map(|&p| self.g.node(p).shape[1]).sum();
        let name = self.uid("concat");
        let id = self.g.add(&name, OpKind::Concat, parts.to_vec(), s);
        self.cur = id;
        id
    }

    /// Nearest-neighbour upsample ×r.
    pub fn upsample(&mut self, r: usize) -> NodeId {
        let s = self.shape();
        let (n, c, h, w) = (s[0], s[1], s[2], s[3]);
        let name = self.uid("upsample");
        let id = self.g.add(&name, OpKind::Upsample { r }, vec![self.cur], vec![n, c, h * r, w * r]);
        self.cur = id;
        id
    }

    /// Pixel shuffle (depth-to-space) ×r.
    pub fn pixel_shuffle(&mut self, r: usize) -> NodeId {
        let s = self.shape();
        let (n, c, h, w) = (s[0], s[1], s[2], s[3]);
        assert!(c % (r * r) == 0);
        let name = self.uid("pixel_shuffle");
        let id = self.g.add(
            &name,
            OpKind::PixelShuffle { r },
            vec![self.cur],
            vec![n, c / (r * r), h * r, w * r],
        );
        self.cur = id;
        id
    }

    /// Squeeze-and-excitation block: GAP → dense(reduce) → relu → dense →
    /// sigmoid → broadcast-mul with the trunk.
    pub fn se_block(&mut self, reduction: usize) -> NodeId {
        let trunk = self.cur;
        let s = self.shape();
        let c = s[1];
        self.gap();
        self.dense((c / reduction).max(1));
        self.act(Act::Relu);
        self.dense(c);
        self.act(Act::Sigmoid);
        // Broadcast gate back over spatial dims.
        let gate = self.cur;
        let name = self.uid("se_broadcast");
        let bid = self.g.add(&name, OpKind::Broadcast, vec![gate], s);
        self.mul(trunk, bid)
    }

    // ---- transformer pieces ---------------------------------------------

    /// LayerNorm over last dim.
    pub fn layer_norm(&mut self) -> NodeId {
        let s = self.shape();
        let d = *s.last().unwrap();
        let name = self.uid("ln");
        let wname = format!("{name}_w");
        let wgt = self.g.weight(&wname, &[2, d]);
        let id = self.g.add(&name, OpKind::LayerNorm, vec![self.cur, wgt], s);
        self.cur = id;
        id
    }

    /// Multi-head self-attention over [n, L, d]; returns output id.
    /// Structure: LN → Q,K,V dense → QK^T matmul → scale → (causal mask) →
    /// softmax → V matmul → output dense → residual add. With
    /// `causal = true` a [`OpKind::CausalMask`] sits between the scale and
    /// the softmax, turning the block into decoder (GPT-style)
    /// autoregressive attention.
    pub fn attention(&mut self, heads: usize, causal: bool) -> NodeId {
        let resid = self.cur;
        let s = self.shape();
        assert_eq!(s.len(), 3, "attention wants [n, L, d]");
        let (n, l, d) = (s[0], s[1], s[2]);
        assert!(d % heads == 0);
        self.layer_norm();
        let x = self.cur;
        let q = {
            self.set_cur(x);
            self.dense(d)
        };
        let k = {
            self.set_cur(x);
            self.dense(d)
        };
        let v = {
            self.set_cur(x);
            self.dense(d)
        };
        // scores = q @ k^T : [n, L, L] (head dim folded into the matmul).
        // K must be *transposed* before the batched matmul — the old
        // MatMul(q, k) form was [n,L,d]×[n,L,d], which is not QK^T (and
        // died at runtime with "batched matmul mismatch" the moment the
        // executor grew a transformer path).
        let kt = {
            self.set_cur(k);
            self.transpose(&[0, 2, 1])
        };
        let name = self.uid("qk");
        let scores = self.g.add(&name, OpKind::MatMul, vec![q, kt], vec![n, l, l]);
        let name = self.uid("scale");
        let dh = (d / heads) as f64;
        let mut scaled = self.g.add(
            &name,
            OpKind::Scale { mul: 1.0 / dh.sqrt(), add: 0.0 },
            vec![scores],
            vec![n, l, l],
        );
        if causal {
            let name = self.uid("causal_mask");
            scaled = self.g.add(&name, OpKind::CausalMask, vec![scaled], vec![n, l, l]);
        }
        let name = self.uid("softmax");
        let probs = self.g.add(&name, OpKind::Softmax, vec![scaled], vec![n, l, l]);
        let name = self.uid("av");
        let ctx = self.g.add(&name, OpKind::MatMul, vec![probs, v], vec![n, l, d]);
        self.set_cur(ctx);
        self.dense(d);
        let o = self.cur;
        self.add_residual(resid, o)
    }

    /// Transformer FFN block with residual: LN → dense(hidden) → act → dense(d) → add.
    pub fn ffn(&mut self, hidden: usize, a: Act) -> NodeId {
        let resid = self.cur;
        let d = *self.shape().last().unwrap();
        self.layer_norm();
        self.dense(hidden);
        self.act(a);
        self.dense(d);
        let o = self.cur;
        self.add_residual(resid, o)
    }

    /// One standard transformer layer: encoder (`causal = false`) or
    /// decoder (`causal = true`) self-attention, then the FFN block.
    pub fn transformer_layer(
        &mut self,
        heads: usize,
        ffn_hidden: usize,
        a: Act,
        causal: bool,
    ) -> NodeId {
        self.attention(heads, causal);
        self.ffn(ffn_hidden, a)
    }
}

/// Registry: build any zoo model by its paper name, at a given batch size.
/// Panics on unknown name (callers enumerate via [`all_models`]).
pub fn by_name(name: &str, batch: usize) -> Graph {
    match name {
        "demo-cnn" => misc::demo_cnn(batch),
        "demo-transformer" => nlp::demo_transformer(batch),
        "demo-transformer-causal" => nlp::demo_transformer_causal(batch),
        "gpt-2-decoder" => nlp::gpt2_decoder_layers(batch, 2),
        "efficientnet-b0" => cnn::efficientnet_b0(batch),
        "resnet-50" => cnn::resnet50(batch),
        "vgg-16" => cnn::vgg16(batch),
        "mobilenet-v1" => cnn::mobilenet_v1(batch),
        "mobilenet-v1-ssd" => detect::mobilenet_v1_ssd(batch),
        "mobilenet-v2" => cnn::mobilenet_v2(batch),
        "mobilenet-v3" => cnn::mobilenet_v3(batch),
        "yolo-v4" => detect::yolo_v4(batch),
        "c3d" => video::c3d(batch),
        "r2plus1d" => video::r2plus1d(batch),
        "s3d" => video::s3d(batch),
        "pointpillar" => detect::pointpillar(batch),
        "u-net" => misc::unet(batch),
        "faster-rcnn" => detect::faster_rcnn(batch),
        "mask-rcnn" => detect::mask_rcnn(batch),
        "tinybert" => nlp::tinybert(batch),
        "distilbert" => nlp::distilbert(batch),
        "bert-base" => nlp::bert_base(batch),
        "mobilebert" => nlp::mobilebert(batch),
        "gpt-2" => nlp::gpt2(batch),
        "conformer" => nlp::conformer(batch),
        "fst" => misc::fst(batch),
        "cyclegan" => misc::cyclegan(batch),
        "wdsr-b" => misc::wdsr_b(batch),
        "efficientdet-d0" => detect::efficientdet_d0(batch),
        "pixor" => detect::pixor(batch),
        _ => panic!("unknown zoo model '{name}'"),
    }
}

/// All registry names (stable order).
pub fn all_models() -> Vec<&'static str> {
    vec![
        "demo-cnn",
        "demo-transformer",
        "demo-transformer-causal",
        "gpt-2-decoder",
        "efficientnet-b0",
        "resnet-50",
        "vgg-16",
        "mobilenet-v1",
        "mobilenet-v1-ssd",
        "mobilenet-v2",
        "mobilenet-v3",
        "yolo-v4",
        "c3d",
        "r2plus1d",
        "s3d",
        "pointpillar",
        "u-net",
        "faster-rcnn",
        "mask-rcnn",
        "tinybert",
        "distilbert",
        "bert-base",
        "mobilebert",
        "gpt-2",
        "conformer",
        "fst",
        "cyclegan",
        "wdsr-b",
        "efficientdet-d0",
        "pixor",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_registry_model_builds_and_validates() {
        for name in all_models() {
            let g = by_name(name, 1);
            assert!(g.validate().is_ok(), "{name} invalid: {:?}", g.validate());
            assert!(g.operator_count() > 3, "{name} suspiciously small");
            assert!(g.total_macs() > 0, "{name} has no compute");
        }
    }

    #[test]
    fn batch_scales_macs_linearly() {
        let g1 = by_name("resnet-50", 1);
        let g2 = by_name("resnet-50", 2);
        // Dense classifier head params identical; MACs scale with batch.
        assert_eq!(g1.total_params(), g2.total_params());
        assert!(g2.total_macs() > g1.total_macs() * 19 / 10);
    }

    #[test]
    fn se_block_round_trips_shape() {
        let mut b = NetBuilder::new("se_test", &[1, 32, 8, 8]);
        b.conv_bn_act(32, 3, 1, 1, Act::Relu);
        let before = b.shape();
        b.se_block(4);
        assert_eq!(b.shape(), before);
        let g = b.finish();
        assert!(g.validate().is_ok());
    }

    #[test]
    fn transformer_layer_preserves_shape() {
        let mut b = NetBuilder::new("tl", &[1, 16, 64]);
        b.transformer_layer(4, 256, Act::Gelu, false);
        assert_eq!(b.shape(), vec![1, 16, 64]);
        // One layer = 12 d^2 params (+ LN/embed): 4 attn dense + 2 ffn dense.
        let g = b.finish();
        let expect = (4 * 64 * 64 + 2 * 64 * 256) as u64;
        let params = g.total_params();
        assert!(params >= expect && params < expect + 1000, "params {params}");
    }
}
