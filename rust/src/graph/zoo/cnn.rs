//! Image-classification backbones from Table 3 / Table 4 / Fig 19.
//! Each builder's test pins the parameter count against the published
//! figure (tolerance noted per model).

use super::NetBuilder;
use crate::graph::ir::Graph;
use crate::graph::ops::Act;

/// ResNet-50 (He et al.): stem 7×7/2 + [3,4,6,3] bottleneck stages + fc.
/// Published: 25.5M params, ~4.1 GMACs @224.
pub fn resnet50(batch: usize) -> Graph {
    let mut b = NetBuilder::new("resnet-50", &[batch, 3, 224, 224]);
    b.conv_bn_act(64, 7, 2, 3, Act::Relu);
    b.maxpool(3, 2, 1);
    // (width, blocks, first-stride) per stage.
    let stages: [(usize, usize, usize); 4] = [(64, 3, 1), (128, 4, 2), (256, 6, 2), (512, 3, 2)];
    for &(w, blocks, stride1) in stages.iter() {
        for bi in 0..blocks {
            let stride = if bi == 0 { stride1 } else { 1 };
            let identity = b.cur();
            // Projection shortcut on the first block of each stage.
            let shortcut = if bi == 0 {
                b.set_cur(identity);
                b.conv(w * 4, 1, stride, 0, 1);
                b.bn();
                b.cur()
            } else {
                identity
            };
            b.set_cur(identity);
            b.conv_bn_act(w, 1, 1, 0, Act::Relu);
            b.conv_bn_act(w, 3, stride, 1, Act::Relu);
            b.conv(w * 4, 1, 1, 0, 1);
            b.bn();
            let trunk = b.cur();
            b.add_residual(shortcut, trunk);
            b.act(Act::Relu);
        }
    }
    b.gap();
    b.dense(1000);
    b.finish()
}

/// VGG-16: 13 convs + 3 fc. Published: 138M params (fc-heavy), ~15.5 GMACs.
pub fn vgg16(batch: usize) -> Graph {
    let mut b = NetBuilder::new("vgg-16", &[batch, 3, 224, 224]);
    let cfg: [(usize, usize); 5] = [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)];
    for (w, reps) in cfg {
        for _ in 0..reps {
            b.conv(w, 3, 1, 1, 1);
            b.bias();
            b.act(Act::Relu);
        }
        b.maxpool(2, 2, 0);
    }
    b.flatten();
    b.dense(4096);
    b.act(Act::Relu);
    b.dense(4096);
    b.act(Act::Relu);
    b.dense(1000);
    b.finish()
}

/// MobileNetV1: 13 depthwise-separable blocks. Published: 4.2M params.
pub fn mobilenet_v1(batch: usize) -> Graph {
    let mut b = NetBuilder::new("mobilenet-v1", &[batch, 3, 224, 224]);
    b.conv_bn_act(32, 3, 2, 1, Act::Relu);
    let cfg: [(usize, usize); 13] = [
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    for (c, s) in cfg {
        b.dwconv(3, s, 1);
        b.bn();
        b.act(Act::Relu);
        b.conv_bn_act(c, 1, 1, 0, Act::Relu);
    }
    b.gap();
    b.dense(1000);
    b.finish()
}

/// Inverted-residual (MobileNetV2/V3, EfficientNet) block.
/// expand×, dw k×k/s, (optional SE), project; residual when s=1 and c_in=c_out.
pub(crate) fn inverted_residual(
    b: &mut NetBuilder,
    c_out: usize,
    k: usize,
    stride: usize,
    expand: usize,
    se: bool,
    a: Act,
) {
    let c_in = b.shape()[1];
    let input = b.cur();
    let hidden = c_in * expand;
    if expand != 1 {
        b.conv_bn_act(hidden, 1, 1, 0, a);
    }
    b.dwconv(k, stride, k / 2);
    b.bn();
    b.act(a);
    if se {
        // EfficientNet-style SE squeezes to c_in/4 (not hidden/4), so the
        // reduction relative to the expanded width is 4×expand.
        b.se_block(4 * expand);
    }
    b.conv(c_out, 1, 1, 0, 1);
    b.bn();
    if stride == 1 && c_in == c_out {
        let trunk = b.cur();
        b.add_residual(input, trunk);
    }
}

/// MobileNetV2: t=6 inverted residuals. Published: 3.5M params, ~300 MMACs.
pub fn mobilenet_v2(batch: usize) -> Graph {
    let mut b = NetBuilder::new("mobilenet-v2", &[batch, 3, 224, 224]);
    b.conv_bn_act(32, 3, 2, 1, Act::Relu6);
    inverted_residual(&mut b, 16, 3, 1, 1, false, Act::Relu6);
    let cfg: [(usize, usize, usize, usize); 6] = [
        // (c, n, s, t)
        (24, 2, 2, 6),
        (32, 3, 2, 6),
        (64, 4, 2, 6),
        (96, 3, 1, 6),
        (160, 3, 2, 6),
        (320, 1, 1, 6),
    ];
    for (c, n, s, t) in cfg {
        for i in 0..n {
            inverted_residual(&mut b, c, 3, if i == 0 { s } else { 1 }, t, false, Act::Relu6);
        }
    }
    b.conv_bn_act(1280, 1, 1, 0, Act::Relu6);
    b.gap();
    b.dense(1000);
    b.finish()
}

/// MobileNetV3-Large (approximation: V3 head, SE on the published subset).
/// Published: ~5.4M params, ~219 MMACs (paper Table 3 lists 6M / 0.45 GFLOPs).
pub fn mobilenet_v3(batch: usize) -> Graph {
    let mut b = NetBuilder::new("mobilenet-v3", &[batch, 3, 224, 224]);
    b.conv_bn_act(16, 3, 2, 1, Act::HardSwish);
    // (c_out, k, s, expand_ratio_hundredths, se, act)
    struct L(usize, usize, usize, usize, bool, Act);
    let cfg = [
        L(16, 3, 1, 100, false, Act::Relu),
        L(24, 3, 2, 400, false, Act::Relu),
        L(24, 3, 1, 300, false, Act::Relu),
        L(40, 5, 2, 300, true, Act::Relu),
        L(40, 5, 1, 300, true, Act::Relu),
        L(40, 5, 1, 300, true, Act::Relu),
        L(80, 3, 2, 600, false, Act::HardSwish),
        L(80, 3, 1, 250, false, Act::HardSwish),
        L(80, 3, 1, 230, false, Act::HardSwish),
        L(80, 3, 1, 230, false, Act::HardSwish),
        L(112, 3, 1, 600, true, Act::HardSwish),
        L(112, 3, 1, 600, true, Act::HardSwish),
        L(160, 5, 2, 600, true, Act::HardSwish),
        L(160, 5, 1, 600, true, Act::HardSwish),
        L(160, 5, 1, 600, true, Act::HardSwish),
    ];
    for L(c, k, s, e100, se, a) in cfg {
        let c_in = b.shape()[1];
        let hidden = (c_in * e100 / 100).max(c_in);
        // Emulate fractional expansion with explicit hidden width.
        let input = b.cur();
        if hidden != c_in {
            b.conv_bn_act(hidden, 1, 1, 0, a);
        }
        b.dwconv(k, s, k / 2);
        b.bn();
        b.act(a);
        if se {
            b.se_block(4);
        }
        b.conv(c, 1, 1, 0, 1);
        b.bn();
        if s == 1 && c_in == c {
            let t = b.cur();
            b.add_residual(input, t);
        }
    }
    b.conv_bn_act(960, 1, 1, 0, Act::HardSwish);
    b.gap();
    b.dense(1280);
    b.act(Act::HardSwish);
    b.dense(1000);
    b.finish()
}

/// EfficientNet-B0: MBConv with SE throughout. Published: 5.3M params,
/// ~390 MMACs (paper: 5.3M / 0.8 GFLOPs ✓).
pub fn efficientnet_b0(batch: usize) -> Graph {
    let mut b = NetBuilder::new("efficientnet-b0", &[batch, 3, 224, 224]);
    b.conv_bn_act(32, 3, 2, 1, Act::Swish);
    // (c, n, k, s, expand)
    let cfg: [(usize, usize, usize, usize, usize); 7] = [
        (16, 1, 3, 1, 1),
        (24, 2, 3, 2, 6),
        (40, 2, 5, 2, 6),
        (80, 3, 3, 2, 6),
        (112, 3, 5, 1, 6),
        (192, 4, 5, 2, 6),
        (320, 1, 3, 1, 6),
    ];
    for (c, n, k, s, t) in cfg {
        for i in 0..n {
            inverted_residual(&mut b, c, k, if i == 0 { s } else { 1 }, t, true, Act::Swish);
        }
    }
    b.conv_bn_act(1280, 1, 1, 0, Act::Swish);
    b.gap();
    b.dense(1000);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mparams(g: &Graph) -> f64 {
        g.total_params() as f64 / 1e6
    }

    fn gmacs(g: &Graph) -> f64 {
        g.total_macs() as f64 / 1e9
    }

    #[test]
    fn resnet50_matches_published_size() {
        let g = resnet50(1);
        let p = mparams(&g);
        assert!((23.0..28.0).contains(&p), "resnet50 params {p}M");
        let m = gmacs(&g);
        assert!((3.5..4.8).contains(&m), "resnet50 macs {m}G");
    }

    #[test]
    fn vgg16_matches_published_size() {
        let g = vgg16(1);
        let p = mparams(&g);
        assert!((130.0..142.0).contains(&p), "vgg16 params {p}M");
        let m = gmacs(&g);
        assert!((14.0..17.0).contains(&m), "vgg16 macs {m}G");
    }

    #[test]
    fn mobilenet_v1_matches_published_size() {
        let g = mobilenet_v1(1);
        let p = mparams(&g);
        assert!((3.8..4.8).contains(&p), "mnv1 params {p}M");
        let m = gmacs(&g);
        assert!((0.45..0.70).contains(&m), "mnv1 macs {m}G");
    }

    #[test]
    fn mobilenet_v2_matches_published_size() {
        let g = mobilenet_v2(1);
        let p = mparams(&g);
        assert!((3.0..4.2).contains(&p), "mnv2 params {p}M");
        let m = gmacs(&g);
        assert!((0.25..0.45).contains(&m), "mnv2 macs {m}G");
    }

    #[test]
    fn mobilenet_v3_close_to_published() {
        let g = mobilenet_v3(1);
        let p = mparams(&g);
        assert!((4.0..7.5).contains(&p), "mnv3 params {p}M");
    }

    #[test]
    fn efficientnet_b0_matches_published_size() {
        let g = efficientnet_b0(1);
        let p = mparams(&g);
        assert!((4.4..6.2).contains(&p), "effb0 params {p}M");
        let m = gmacs(&g);
        assert!((0.3..0.55).contains(&m), "effb0 macs {m}G");
    }

    #[test]
    fn stride_chain_shapes_sane() {
        let g = resnet50(1);
        // Final dense output is [1, 1000].
        let out = &g.node(*g.outputs.last().unwrap()).shape;
        assert_eq!(out, &vec![1, 1000]);
    }
}
