//! DNN computational-graph IR, operator vocabulary with DNNFusion mapping
//! types, and the model zoo reproducing every network in the paper's
//! evaluation.

pub mod ir;
pub mod ops;
pub mod weights;
pub mod zoo;

pub use ir::{Graph, Node, NodeId};
pub use ops::{Act, FuseClass, MappingType, OpKind};
pub use weights::WeightStore;
