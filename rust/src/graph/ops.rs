//! Operator vocabulary and the DNNFusion **mapping-type** classification
//! (§2.2.2, Table 1 of the paper): every operator is classified by the
//! relation between its input and output elements — One-to-One,
//! One-to-Many, Many-to-Many, Reorganize, or Shuffle — and fusion legality
//! and the fused operator's mapping type are derived from an algebra over
//! these types rather than from a fixed pattern list.

/// DNNFusion mapping types (paper Table 1 header row/column).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MappingType {
    /// Elementwise: each output element depends on exactly one input element.
    OneToOne,
    /// Each input element feeds many outputs (e.g. upsample, broadcast).
    OneToMany,
    /// Dense dependence (conv, matmul, pooling, softmax reductions).
    ManyToMany,
    /// Pure data movement that changes layout (reshape/transpose/concat/pad).
    Reorganize,
    /// Index-permuting movement (channel/pixel shuffle, gather).
    Shuffle,
}

/// Fusion profitability classes (Table 1 cell colors).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FuseClass {
    /// Green: legal and likely profitable — fuse directly.
    Direct,
    /// Yellow: legal but profitability depends on shapes — needs profiling.
    Profile,
    /// Red (×): illegal/unprofitable — do not fuse.
    Never,
}

/// The mapping-type algebra of Table 1: the mapping type of `second ∘ first`
/// when fusion is legal, or `None` for the × cells.
///
/// Row = mapping type of the *first* operator, column = the *second*.
pub fn fused_mapping(first: MappingType, second: MappingType) -> Option<MappingType> {
    use MappingType::*;
    Some(match (first, second) {
        // Row One-to-One: result takes the second op's type.
        (OneToOne, t) => t,
        // Row One-to-Many.
        (OneToMany, OneToOne) => OneToMany,
        (OneToMany, OneToMany) => OneToMany,
        (OneToMany, ManyToMany) => return None, // ×
        (OneToMany, Reorganize) => OneToMany,
        (OneToMany, Shuffle) => OneToMany,
        // Row Many-to-Many.
        (ManyToMany, OneToOne) => ManyToMany,
        (ManyToMany, OneToMany) => ManyToMany,
        (ManyToMany, ManyToMany) => return None, // ×
        (ManyToMany, Reorganize) => ManyToMany,
        (ManyToMany, Shuffle) => ManyToMany,
        // Row Reorganize.
        (Reorganize, OneToOne) => Reorganize,
        (Reorganize, OneToMany) => OneToMany,
        (Reorganize, ManyToMany) => ManyToMany,
        (Reorganize, Reorganize) => Reorganize,
        (Reorganize, Shuffle) => Reorganize,
        // Row Shuffle.
        (Shuffle, OneToOne) => Shuffle,
        (Shuffle, OneToMany) => OneToMany,
        (Shuffle, ManyToMany) => ManyToMany,
        (Shuffle, Reorganize) => Reorganize,
        (Shuffle, Shuffle) => Shuffle,
    })
}

/// Profitability classification of a fusion candidate (Table 1 colors).
///
/// The paper's figure colors are not recoverable from the text dump; the
/// encoding here follows the DNNFusion (PLDI'21) analysis it cites:
/// * `×` cells are [`FuseClass::Never`];
/// * absorbing a data-movement op (Reorganize/Shuffle) into a compute op, or
///   chaining it after one, is shape-dependent → [`FuseClass::Profile`];
/// * everything else (elementwise chains, compute+elementwise, movement
///   chains) is [`FuseClass::Direct`].
pub fn fuse_class(first: MappingType, second: MappingType) -> FuseClass {
    use MappingType::*;
    if fused_mapping(first, second).is_none() {
        return FuseClass::Never;
    }
    match (first, second) {
        // Data movement feeding heavy compute, or heavy compute feeding data
        // movement: legal, but the layout change may or may not be absorbable
        // for free — profile.
        (Reorganize | Shuffle, ManyToMany) => FuseClass::Profile,
        (ManyToMany, Reorganize | Shuffle) => FuseClass::Profile,
        (OneToMany, Reorganize | Shuffle) => FuseClass::Profile,
        _ => FuseClass::Direct,
    }
}

/// Activation functions (kept separate so graph rewriting can reason about
/// them uniformly).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Act {
    Relu,
    Relu6,
    Sigmoid,
    Tanh,
    Gelu,
    Swish,
    HardSwish,
    LeakyRelu,
    Mish,
}

/// Operator kinds. Shape/arity metadata lives on the graph node; the kind
/// carries only what optimization passes dispatch on.
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// Graph input placeholder.
    Input,
    /// Trainable weight/constant tensor (distinguishing weights from
    /// intermediates is what enables the Fig 9 rewrites).
    Weight,
    /// 2-D convolution: kernel k×k, stride, padding, groups.
    Conv2d { k: usize, stride: usize, pad: usize, groups: usize },
    /// 3-D convolution (C3D/S3D/R2+1D): kt×k×k kernel.
    Conv3d { kt: usize, k: usize, stride: usize, pad: usize },
    /// Transposed conv (CycleGAN / U-Net upsampling path).
    ConvTranspose2d { k: usize, stride: usize, pad: usize },
    /// Fully-connected / linear layer.
    Dense,
    /// Batched matmul (attention).
    MatMul,
    /// Inference-form batch norm (per-channel scale+shift).
    BatchNorm,
    /// Per-channel bias add.
    Bias,
    /// Layer norm (transformers).
    LayerNorm,
    /// Elementwise activation.
    Activation(Act),
    /// Elementwise binary ops between two graph values.
    Add,
    Sub,
    Mul,
    Div,
    /// Elementwise power by a constant exponent.
    Pow { e: f64 },
    Sqrt,
    /// Elementwise affine by constants: `x*mul + add` (strength-reduced
    /// form that constant-folding rewrites produce; a weight input, when
    /// present, overrides with per-channel scale).
    Scale { mul: f64, add: f64 },
    /// Autoregressive (decoder) attention mask over the last two dims of a
    /// score tensor `[..., Lq, Lk]`: positions with key index `j > i`
    /// (strictly above the diagonal) are masked to `-inf` so the following
    /// `Softmax` assigns them exactly zero probability. Kept as its own op
    /// (between QK^T-scale and Softmax) rather than a payload on Softmax so
    /// graph rewriting can reason about the chain; the executors fuse it
    /// into a masked-softmax kernel that *skips* masked columns instead of
    /// materializing `-inf`.
    CausalMask,
    Softmax,
    /// Windowed pooling: `out = (h + 2*pad - k)/stride + 1` per spatial
    /// dim (conv_out semantics — a k≠stride window is *not* `h/stride`).
    MaxPool { k: usize, stride: usize, pad: usize },
    AvgPool { k: usize, stride: usize, pad: usize },
    GlobalAvgPool,
    /// Layout / movement ops (Reorganize).
    Reshape,
    /// General axis permutation: `out.shape[i] = in.shape[perm[i]]`
    /// (NumPy `transpose(x, axes=perm)`). The perm is explicit on the op —
    /// inferring it from shapes is ambiguous whenever two dims are equal,
    /// which is exactly the attention case (seq == seq).
    Transpose { perm: Vec<usize> },
    Concat,
    /// Contiguous crop: per-dim start offsets; the extent of each dim is
    /// the node's output shape.
    Slice { start: Vec<usize> },
    /// Zero padding: per-dim (before, after) element counts.
    Pad { before: Vec<usize>, after: Vec<usize> },
    Flatten,
    /// Shuffle ops.
    ChannelShuffle { groups: usize },
    PixelShuffle { r: usize },
    Gather,
    /// One-to-Many ops.
    Upsample { r: usize },
    Broadcast,
    Embedding,
    /// Detection-head post-processing (NMS etc.) — treated as CPU-side op.
    PostProcess,
}

impl OpKind {
    /// DNNFusion mapping type of this operator.
    pub fn mapping(&self) -> MappingType {
        use MappingType::*;
        use OpKind::*;
        match self {
            Input | Weight => OneToOne, // sources; never fused as "ops"
            Conv2d { .. } | Conv3d { .. } | ConvTranspose2d { .. } | Dense | MatMul
            | Softmax | MaxPool { .. } | AvgPool { .. } | GlobalAvgPool | PostProcess => ManyToMany,
            BatchNorm | Bias | LayerNorm | Activation(_) | Add | Sub | Mul | Div
            | Pow { .. } | Sqrt | Scale { .. } | CausalMask => OneToOne,
            Reshape | Transpose { .. } | Concat | Slice { .. } | Pad { .. } | Flatten => Reorganize,
            ChannelShuffle { .. } | PixelShuffle { .. } | Gather => Shuffle,
            Upsample { .. } | Broadcast | Embedding => OneToMany,
        }
    }

    /// Is this a source (no compute) node?
    pub fn is_source(&self) -> bool {
        matches!(self, OpKind::Input | OpKind::Weight)
    }

    /// Does this op carry trainable weights as its second input?
    pub fn has_weights(&self) -> bool {
        matches!(
            self,
            OpKind::Conv2d { .. }
                | OpKind::Conv3d { .. }
                | OpKind::ConvTranspose2d { .. }
                | OpKind::Dense
                | OpKind::BatchNorm
                | OpKind::Bias
                | OpKind::LayerNorm
                | OpKind::Embedding
                | OpKind::Scale { .. }
        )
    }

    /// Short display name.
    pub fn name(&self) -> &'static str {
        use OpKind::*;
        match self {
            Input => "input",
            Weight => "weight",
            Conv2d { .. } => "conv2d",
            Conv3d { .. } => "conv3d",
            ConvTranspose2d { .. } => "conv_transpose2d",
            Dense => "dense",
            MatMul => "matmul",
            BatchNorm => "batch_norm",
            Bias => "bias",
            LayerNorm => "layer_norm",
            Activation(_) => "activation",
            Add => "add",
            Sub => "sub",
            Mul => "mul",
            Div => "div",
            Pow { .. } => "pow",
            Sqrt => "sqrt",
            Scale { .. } => "scale",
            CausalMask => "causal_mask",
            Softmax => "softmax",
            MaxPool { .. } => "max_pool",
            AvgPool { .. } => "avg_pool",
            GlobalAvgPool => "global_avg_pool",
            Reshape => "reshape",
            Transpose { .. } => "transpose",
            Concat => "concat",
            Slice { .. } => "slice",
            Pad { .. } => "pad",
            Flatten => "flatten",
            ChannelShuffle { .. } => "channel_shuffle",
            PixelShuffle { .. } => "pixel_shuffle",
            Gather => "gather",
            Upsample { .. } => "upsample",
            Broadcast => "broadcast",
            Embedding => "embedding",
            PostProcess => "post_process",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use MappingType::*;

    #[test]
    fn table1_row_one_to_one_copies_second() {
        for t in [OneToOne, OneToMany, ManyToMany, Reorganize, Shuffle] {
            assert_eq!(fused_mapping(OneToOne, t), Some(t));
        }
    }

    #[test]
    fn table1_cross_cells() {
        // The two × cells.
        assert_eq!(fused_mapping(OneToMany, ManyToMany), None);
        assert_eq!(fused_mapping(ManyToMany, ManyToMany), None);
        // Spot-check non-trivial cells against the printed table.
        assert_eq!(fused_mapping(Reorganize, OneToMany), Some(OneToMany));
        assert_eq!(fused_mapping(Shuffle, Reorganize), Some(Reorganize));
        assert_eq!(fused_mapping(Shuffle, Shuffle), Some(Shuffle));
        assert_eq!(fused_mapping(ManyToMany, Shuffle), Some(ManyToMany));
    }

    #[test]
    fn never_matches_cross_cells_only() {
        let all = [OneToOne, OneToMany, ManyToMany, Reorganize, Shuffle];
        let mut nevers = Vec::new();
        for f in all {
            for s in all {
                if fuse_class(f, s) == FuseClass::Never {
                    nevers.push((f, s));
                }
            }
        }
        assert_eq!(nevers, vec![(OneToMany, ManyToMany), (ManyToMany, ManyToMany)]);
    }

    #[test]
    fn conv_relu_is_direct() {
        let conv = OpKind::Conv2d { k: 3, stride: 1, pad: 1, groups: 1 };
        let relu = OpKind::Activation(Act::Relu);
        assert_eq!(fuse_class(conv.mapping(), relu.mapping()), FuseClass::Direct);
    }

    #[test]
    fn conv_conv_never_fuses() {
        let conv = OpKind::Conv2d { k: 3, stride: 1, pad: 1, groups: 1 };
        assert_eq!(fuse_class(conv.mapping(), conv.mapping()), FuseClass::Never);
    }

    #[test]
    fn reshape_into_conv_needs_profile() {
        assert_eq!(fuse_class(Reorganize, ManyToMany), FuseClass::Profile);
    }

    #[test]
    fn mapping_assignments() {
        assert_eq!(OpKind::Softmax.mapping(), ManyToMany);
        assert_eq!(OpKind::ChannelShuffle { groups: 2 }.mapping(), Shuffle);
        assert_eq!(OpKind::Upsample { r: 2 }.mapping(), OneToMany);
        assert_eq!(OpKind::Transpose { perm: vec![1, 0] }.mapping(), Reorganize);
        assert_eq!(OpKind::Activation(Act::Gelu).mapping(), OneToOne);
        // CausalMask is elementwise-classified so the scale → mask →
        // softmax chain stays fusable under the Table 1 algebra.
        assert_eq!(OpKind::CausalMask.mapping(), OneToOne);
        assert!(!OpKind::CausalMask.has_weights());
    }
}
