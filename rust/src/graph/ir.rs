//! The DNN computational-graph IR all XGen passes operate on.
//!
//! A [`Graph`] is an SSA-style DAG: each [`Node`] consumes earlier node ids
//! and produces one tensor whose shape is recorded on the node. Weights are
//! explicit [`OpKind::Weight`] source nodes — rewriting (Fig 9) dispatches
//! on whether an operand is a weight or an intermediate, and pruning
//! rewrites weight nodes in place.

use std::collections::{BTreeMap, BTreeSet};

use super::ops::{MappingType, OpKind};
use crate::error::XgenError;

/// Node identifier (index into `Graph::nodes`).
pub type NodeId = usize;

/// One operator instance.
#[derive(Debug, Clone)]
pub struct Node {
    pub id: NodeId,
    pub name: String,
    pub op: OpKind,
    /// Ids of the value inputs (data first, then weights by convention).
    pub inputs: Vec<NodeId>,
    /// Output tensor shape.
    pub shape: Vec<usize>,
}

impl Node {
    /// Number of elements in the output.
    pub fn out_elems(&self) -> u64 {
        self.shape.iter().map(|&d| d as u64).product()
    }
}

/// A DNN computational graph.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub name: String,
    pub nodes: Vec<Node>,
    pub outputs: Vec<NodeId>,
    /// Weight-node name → fixed scalar value for weights that are really
    /// *constants* the frontend baked into the graph (e.g. the `sqrt(d_k)`
    /// attention divisor an exporter emits as an initializer).
    /// [`crate::graph::WeightStore::init_random`] honors these instead of
    /// drawing random values — a random "constant" would change semantics
    /// (and a negative one would make `Sqrt` produce NaN).
    pub consts: BTreeMap<String, f32>,
}

impl Graph {
    pub fn new(name: &str) -> Graph {
        Graph {
            name: name.to_string(),
            nodes: Vec::new(),
            outputs: Vec::new(),
            consts: BTreeMap::new(),
        }
    }

    /// Append a node; inputs must already exist (ids are topological by
    /// construction).
    pub fn add(&mut self, name: &str, op: OpKind, inputs: Vec<NodeId>, shape: Vec<usize>) -> NodeId {
        let id = self.nodes.len();
        for &i in &inputs {
            assert!(i < id, "graph input {i} does not precede node {id}");
        }
        self.nodes.push(Node { id, name: name.to_string(), op, inputs, shape });
        id
    }

    /// Add a graph input placeholder.
    pub fn input(&mut self, name: &str, shape: &[usize]) -> NodeId {
        self.add(name, OpKind::Input, vec![], shape.to_vec())
    }

    /// Add a weight source.
    pub fn weight(&mut self, name: &str, shape: &[usize]) -> NodeId {
        self.add(name, OpKind::Weight, vec![], shape.to_vec())
    }

    /// Add a 1-element weight holding a graph constant. The value is
    /// recorded in [`Graph::consts`] so weight initialization reproduces
    /// it (names survive rewriting; node ids do not).
    pub fn const_scalar(&mut self, name: &str, value: f32) -> NodeId {
        self.consts.insert(name.to_string(), value);
        self.weight(name, &[1])
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id]
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Ids of compute nodes (non-source).
    pub fn compute_nodes(&self) -> Vec<NodeId> {
        self.nodes.iter().filter(|n| !n.op.is_source()).map(|n| n.id).collect()
    }

    /// users[v] = nodes that consume v.
    pub fn users(&self) -> Vec<Vec<NodeId>> {
        let mut u = vec![Vec::new(); self.nodes.len()];
        for n in &self.nodes {
            for &i in &n.inputs {
                u[i].push(n.id);
            }
        }
        u
    }

    /// The single *data* (non-weight) input of a node, if it has exactly one.
    pub fn data_input(&self, id: NodeId) -> Option<NodeId> {
        let data: Vec<NodeId> = self.nodes[id]
            .inputs
            .iter()
            .copied()
            .filter(|&i| !matches!(self.nodes[i].op, OpKind::Weight))
            .collect();
        if data.len() == 1 {
            Some(data[0])
        } else {
            None
        }
    }

    /// Multiply–accumulate count of one node (inference, batch included).
    pub fn node_macs(&self, id: NodeId) -> u64 {
        let n = &self.nodes[id];
        let out = n.out_elems();
        match &n.op {
            OpKind::Conv2d { k, groups, .. } => {
                let in_c = self.nodes[n.inputs[0]].shape[1] as u64;
                out * in_c / *groups as u64 * (*k as u64) * (*k as u64)
            }
            OpKind::Conv3d { kt, k, .. } => {
                let in_c = self.nodes[n.inputs[0]].shape[1] as u64;
                out * in_c * (*kt as u64) * (*k as u64) * (*k as u64)
            }
            OpKind::ConvTranspose2d { k, .. } => {
                let in_c = self.nodes[n.inputs[0]].shape[1] as u64;
                out * in_c * (*k as u64) * (*k as u64)
            }
            OpKind::Dense => {
                let in_f = *self.nodes[n.inputs[0]].shape.last().unwrap() as u64;
                out * in_f
            }
            OpKind::MatMul => {
                // [..., m, k] x [..., k, n] -> [..., m, n]
                let k = *self.nodes[n.inputs[0]].shape.last().unwrap() as u64;
                out * k
            }
            OpKind::MaxPool { k, .. } | OpKind::AvgPool { k, .. } => out * (*k as u64) * (*k as u64),
            OpKind::GlobalAvgPool => {
                let i = &self.nodes[n.inputs[0]];
                i.out_elems()
            }
            OpKind::Softmax | OpKind::LayerNorm => out * 4,
            OpKind::BatchNorm | OpKind::Bias | OpKind::Scale { .. } | OpKind::Activation(_)
            | OpKind::Add | OpKind::Sub | OpKind::Mul | OpKind::Div | OpKind::Pow { .. }
            | OpKind::Sqrt | OpKind::CausalMask => out,
            OpKind::Embedding => out,
            _ => 0, // movement ops: no MACs
        }
    }

    /// Parameter count of one node's weight inputs.
    pub fn node_params(&self, id: NodeId) -> u64 {
        self.nodes[id]
            .inputs
            .iter()
            .filter(|&&i| matches!(self.nodes[i].op, OpKind::Weight))
            .map(|&i| self.nodes[i].out_elems())
            .sum()
    }

    /// Total MACs over the graph.
    pub fn total_macs(&self) -> u64 {
        (0..self.nodes.len()).map(|i| self.node_macs(i)).sum()
    }

    /// Total parameters (each weight node counted once).
    pub fn total_params(&self) -> u64 {
        self.nodes
            .iter()
            .filter(|n| matches!(n.op, OpKind::Weight))
            .map(|n| n.out_elems())
            .sum()
    }

    /// Number of operator nodes (paper Table 4 "#Operators").
    pub fn operator_count(&self) -> usize {
        self.compute_nodes().len()
    }

    /// Total intermediate-tensor bytes (f32), a memory-pressure proxy the
    /// fusion profitability analysis consumes.
    pub fn intermediate_bytes(&self) -> u64 {
        self.nodes
            .iter()
            .filter(|n| !n.op.is_source())
            .map(|n| n.out_elems() * 4)
            .sum()
    }

    /// Verify structural invariants; returns a typed
    /// [`XgenError::InvalidGraph`] on violation. The pass label is the
    /// generic "graph" — [`XgenError::with_pass`] re-labels it with the
    /// pipeline stage when the verifier runs after a specific pass.
    pub fn validate(&self) -> Result<(), XgenError> {
        fn bad(detail: String) -> XgenError {
            XgenError::InvalidGraph { pass: "graph".to_string(), detail }
        }
        for (i, n) in self.nodes.iter().enumerate() {
            if n.id != i {
                return Err(bad(format!("node {} has id {}", i, n.id)));
            }
            for &inp in &n.inputs {
                if inp >= i {
                    return Err(bad(format!("node {} consumes non-preceding {}", i, inp)));
                }
            }
            if n.op.is_source() && !n.inputs.is_empty() {
                return Err(bad(format!("source node {} has inputs", i)));
            }
            if !n.op.is_source() && n.inputs.is_empty() {
                return Err(bad(format!("compute node {} ({}) has no inputs", i, n.op.name())));
            }
            if n.shape.iter().any(|&d| d == 0) {
                return Err(bad(format!("node {} has zero dim", i)));
            }
            // Movement-op payloads must be consistent with the recorded
            // input/output shapes — a wrong perm dies here, not deep in a
            // kernel.
            match &n.op {
                OpKind::Transpose { perm } => {
                    let xs = &self.nodes[n.inputs[0]].shape;
                    let mut seen = vec![false; xs.len()];
                    for &p in perm {
                        if p >= xs.len() || seen[p] {
                            return Err(bad(format!(
                                "node {} transpose perm {:?} is not a permutation of rank {}",
                                i, perm, xs.len()
                            )));
                        }
                        seen[p] = true;
                    }
                    if perm.len() != xs.len() {
                        return Err(bad(format!(
                            "node {} transpose perm {:?} is not a permutation of rank {}",
                            i, perm, xs.len()
                        )));
                    }
                    let want: Vec<usize> = perm.iter().map(|&p| xs[p]).collect();
                    if want != n.shape {
                        return Err(bad(format!(
                            "node {} transpose shape {:?} != perm {:?} of {:?}",
                            i, n.shape, perm, xs
                        )));
                    }
                }
                OpKind::Slice { start } => {
                    let xs = &self.nodes[n.inputs[0]].shape;
                    if start.len() != xs.len()
                        || n.shape.len() != xs.len()
                        || start.iter().zip(&n.shape).zip(xs).any(|((&s, &o), &x)| s + o > x)
                    {
                        return Err(bad(format!(
                            "node {} slice start {:?} + {:?} exceeds {:?}",
                            i, start, n.shape, xs
                        )));
                    }
                }
                OpKind::MaxPool { k, stride, pad } | OpKind::AvgPool { k, stride, pad } => {
                    // pad < k guarantees every window overlaps the data
                    // (first window reaches index k-1-pad ≥ 0; the last
                    // window starts at most h+pad-k < h), so the max
                    // kernel can never emit -inf for an all-padding
                    // window and the avg kernel never divides by zero.
                    if *k == 0 || *stride == 0 || pad >= k {
                        return Err(bad(format!(
                            "node {} pool k={} stride={} pad={} invalid (need k, stride > 0 and pad < k)",
                            i, k, stride, pad
                        )));
                    }
                    // The pool kernels are strictly NCHW; higher-rank
                    // pools must be decomposed (fold extra dims into
                    // channels — see the video zoo's pool3d).
                    if self.nodes[n.inputs[0]].shape.len() != 4 {
                        return Err(bad(format!(
                            "node {} pools a rank-{} tensor (pools are NCHW-only)",
                            i,
                            self.nodes[n.inputs[0]].shape.len()
                        )));
                    }
                }
                OpKind::CausalMask => {
                    let xs = &self.nodes[n.inputs[0]].shape;
                    if xs != &n.shape {
                        return Err(bad(format!(
                            "node {} causal mask shape {:?} != input {:?}",
                            i, n.shape, xs
                        )));
                    }
                    // The mask is defined over the last two dims (query
                    // rows × key columns) and the full-graph form is the
                    // square attention score matrix.
                    if n.shape.len() < 2 || n.shape[n.shape.len() - 1] != n.shape[n.shape.len() - 2]
                    {
                        return Err(bad(format!(
                            "node {} causal mask needs square trailing dims, got {:?}",
                            i, n.shape
                        )));
                    }
                }
                OpKind::Pad { before, after } => {
                    let xs = &self.nodes[n.inputs[0]].shape;
                    let ok = before.len() == xs.len()
                        && after.len() == xs.len()
                        && n.shape.len() == xs.len()
                        && xs
                            .iter()
                            .zip(before)
                            .zip(after)
                            .zip(&n.shape)
                            .all(|(((&x, &b), &a), &o)| x + b + a == o);
                    if !ok {
                        return Err(bad(format!(
                            "node {} pad ({:?}, {:?}) of {:?} != {:?}",
                            i, before, after, xs, n.shape
                        )));
                    }
                }
                _ => {}
            }
        }
        for &o in &self.outputs {
            if o >= self.nodes.len() {
                return Err(bad(format!("output {o} out of range")));
            }
        }
        Ok(())
    }

    /// Nodes reachable (backwards) from the outputs — used by rewrite passes
    /// to drop dead code after substitution.
    pub fn live_set(&self) -> BTreeSet<NodeId> {
        let mut live = BTreeSet::new();
        let mut stack: Vec<NodeId> = self.outputs.clone();
        while let Some(id) = stack.pop() {
            if live.insert(id) {
                stack.extend(&self.nodes[id].inputs);
            }
        }
        live
    }

    /// Remove dead nodes, renumbering ids. Returns old→new id map.
    pub fn prune_dead(&mut self) -> BTreeMap<NodeId, NodeId> {
        let live = self.live_set();
        let mut remap = BTreeMap::new();
        let mut nodes = Vec::with_capacity(live.len());
        for old in &live {
            let new_id = nodes.len();
            let mut n = self.nodes[*old].clone();
            n.id = new_id;
            n.inputs = n.inputs.iter().map(|i| remap[i]).collect();
            remap.insert(*old, new_id);
            nodes.push(n);
        }
        self.nodes = nodes;
        self.outputs = self.outputs.iter().map(|o| remap[o]).collect();
        remap
    }

    /// Histogram of mapping types over compute nodes.
    pub fn mapping_histogram(&self) -> BTreeMap<&'static str, usize> {
        let mut h = BTreeMap::new();
        for id in self.compute_nodes() {
            let m = self.nodes[id].op.mapping();
            let key = match m {
                MappingType::OneToOne => "one-to-one",
                MappingType::OneToMany => "one-to-many",
                MappingType::ManyToMany => "many-to-many",
                MappingType::Reorganize => "reorganize",
                MappingType::Shuffle => "shuffle",
            };
            *h.entry(key).or_insert(0) += 1;
        }
        h
    }

    /// Pretty one-line summary used by the CLI.
    pub fn summary(&self) -> String {
        format!(
            "{}: {} ops, {:.2}M params, {:.2}G MACs",
            self.name,
            self.operator_count(),
            self.total_params() as f64 / 1e6,
            self.total_macs() as f64 / 1e9,
        )
    }
}

/// Convolution output spatial size helper shared by zoo builders.
pub fn conv_out(h: usize, k: usize, stride: usize, pad: usize) -> usize {
    (h + 2 * pad - k) / stride + 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ops::Act;

    fn tiny() -> Graph {
        let mut g = Graph::new("tiny");
        let x = g.input("x", &[1, 3, 8, 8]);
        let w = g.weight("w", &[16, 3, 3, 3]);
        let c = g.add("conv", OpKind::Conv2d { k: 3, stride: 1, pad: 1, groups: 1 }, vec![x, w], vec![1, 16, 8, 8]);
        let r = g.add("relu", OpKind::Activation(Act::Relu), vec![c], vec![1, 16, 8, 8]);
        g.outputs = vec![r];
        g
    }

    #[test]
    fn validate_ok() {
        assert!(tiny().validate().is_ok());
    }

    #[test]
    fn macs_conv_formula() {
        let g = tiny();
        // out elems = 16*8*8 = 1024; per-out = 3*3*3 = 27.
        assert_eq!(g.node_macs(2), 1024 * 27);
        // relu = 1 per element
        assert_eq!(g.node_macs(3), 1024);
    }

    #[test]
    fn params_counts_weight_nodes() {
        let g = tiny();
        assert_eq!(g.total_params(), 16 * 3 * 3 * 3);
        assert_eq!(g.node_params(2), 16 * 27);
    }

    #[test]
    fn dead_code_elimination() {
        let mut g = tiny();
        // Add a dead branch.
        let x2 = g.weight("dead_w", &[4, 4]);
        let _dead = g.add("dead_sqrt", OpKind::Sqrt, vec![x2], vec![4, 4]);
        assert_eq!(g.len(), 6);
        g.prune_dead();
        assert_eq!(g.len(), 4);
        assert!(g.validate().is_ok());
        assert_eq!(g.outputs, vec![3]);
    }

    #[test]
    fn users_inverts_inputs() {
        let g = tiny();
        let u = g.users();
        assert_eq!(u[0], vec![2]); // x used by conv
        assert_eq!(u[2], vec![3]); // conv used by relu
        assert!(u[3].is_empty());
    }

    #[test]
    fn data_input_skips_weights() {
        let g = tiny();
        assert_eq!(g.data_input(2), Some(0));
        assert_eq!(g.data_input(3), Some(2));
    }

    #[test]
    fn rejects_forward_edges() {
        let g = tiny();
        let mut bad = g.clone();
        bad.nodes[2].inputs = vec![3, 1];
        let err = bad.validate().unwrap_err();
        assert_eq!(err.code(), "InvalidGraph");
        assert!(err.to_string().contains("non-preceding"));
    }

    #[test]
    fn mapping_histogram_counts() {
        let g = tiny();
        let h = g.mapping_histogram();
        assert_eq!(h.get("many-to-many"), Some(&1));
        assert_eq!(h.get("one-to-one"), Some(&1));
    }

    #[test]
    fn conv_out_helper() {
        assert_eq!(conv_out(224, 7, 2, 3), 112);
        assert_eq!(conv_out(8, 3, 1, 1), 8);
    }
}
