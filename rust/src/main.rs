//! `xgen` CLI — the leader entrypoint over the whole stack. `compile` and
//! `serve` construct inference exclusively through the
//! [`xgen::api::Compiler`] session API.
//!
//! ```text
//! xgen models                                   list the model zoo
//! xgen compile --model resnet-50 [--scheme pattern|block|none]
//!              [--opt 0..3] [--reuse] [--no-fkw] [--infer] [--generate N]
//!              [--verify] [--analyze] [--int8 off|force|auto]
//! xgen sched [--variant ADy416] [--horizon 3000]    Table 5 simulation
//! xgen caps [--budget 8.0]                      NPAS co-search
//! xgen emit-kernel [--pattern 0] [--unroll 4]   generated pattern kernel
//! xgen run --artifact cnn_dense_b1              one PJRT inference
//! xgen serve [--model demo-cnn] [--requests 64] [--opt 0..3]
//!            [--scheme none|pattern|...] [--reuse] [--no-fkw] [--pjrt]
//!            [--queue-cap 1024] [--deadline-ms N]
//! xgen decode-serve [--model demo-transformer-causal] [--streams 16]
//!            [--tokens 12] [--prompt 4] [--max-streams 4]
//!            [--kv-budget-kb N] [--queue-cap 1024] [--deadline-ms N]
//! ```
//!
//! Failures exit nonzero and print `error[<code>]: ...` where `<code>` is
//! the stable [`xgen::error::XgenError::code`] of the root cause.

// Same lint policy as lib.rs (CI gates `cargo clippy -- -D warnings`).
#![allow(unknown_lints)]
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil,
    clippy::uninlined_format_args,
    clippy::collapsible_else_if,
    clippy::collapsible_if
)]

use anyhow::Result;

use xgen::api::{CompiledModel, Compiler, OptLevel, QuantPolicy};
use xgen::baselines::{DeviceClass, Framework};
use xgen::caps::{search, CapsConfig};
use xgen::coordinator::{SchedConfig, ServeConfig, Server, StreamScheduler};
use xgen::error::XgenError;
use xgen::cost::devices;
use xgen::graph::zoo::{all_models, by_name};
use xgen::pruning::PruneScheme;
use xgen::runtime::{default_artifact_dir, ModelRuntime};
use xgen::util::cli::Args;
use xgen::util::rng::Rng;
use xgen::xengine::adapp::{modules, variants};
use xgen::xengine::sim::simulate;
use xgen::xengine::Policy;

fn main() {
    if let Err(e) = run() {
        // Typed errors print their stable code so scripts can branch on
        // `error[SeqOverflow]`-style prefixes; everything else is Internal.
        eprintln!("error[{}]: {e:#}", XgenError::classify(&e).code());
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env();
    match args.command.as_str() {
        "models" => cmd_models(),
        "compile" => cmd_compile(&args),
        "sched" => cmd_sched(&args),
        "caps" => cmd_caps(&args),
        "emit-kernel" => cmd_emit(&args),
        "run" => cmd_run(&args),
        "serve" => cmd_serve(&args),
        "decode-serve" => cmd_decode_serve(&args),
        "" | "help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n{HELP}");
            std::process::exit(2);
        }
    }
}

const HELP: &str = "\
xgen — CoCoPIE XGen reproduction (see DESIGN.md)
  models        list the model zoo with params/MACs
  compile       compile a zoo model through the session API
                (--scheme, --opt 0..3, --reuse, --no-fkw, --infer;
                 --generate N greedy-decodes N tokens on causal models;
                 --verify runs the static soundness checkers even in
                 release builds; --analyze forces the semantic dataflow
                 analyses — range/NaN safety, int8 feasibility, trace
                 purity — below O2, where they are on by default;
                 --int8 off|force|auto picks contraction-layer precision —
                 auto follows the compile-time QuantPlan per layer)
  sched         XEngine Table-5 scheduler simulation
  caps          NPAS architecture/pruning co-search
  emit-kernel   print a generated branch-less pattern kernel
  run           execute one AOT artifact via PJRT
  serve         dynamic-batching serving demo (compiled sessions by
                default; --pjrt for the AOT artifact path;
                --queue-cap bounds the queue, --deadline-ms sets a
                per-request deadline)
  decode-serve  multi-stream decode serving demo: --streams concurrent
                greedy generations multiplexed over a session pool
                (--max-streams residents, optionally tightened by
                --kv-budget-kb; --deadline-ms arms the eviction
                watchdog)
";

/// CLI spelling of a pruning scheme; unknown spellings are a loud error,
/// not a silent default.
fn parse_scheme(s: &str) -> Result<PruneScheme> {
    Ok(match s {
        "none" => PruneScheme::None,
        "pattern" => PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.3 },
        "block" => PruneScheme::Block { block: 8, rate: 0.75 },
        "structured" => PruneScheme::Structured { rate: 0.5 },
        "nonstructured" => PruneScheme::NonStructured { rate: 0.8 },
        other => anyhow::bail!(
            "unknown --scheme '{other}' (use none|pattern|block|structured|nonstructured)"
        ),
    })
}

/// Shared `--opt/--reuse/--no-fkw` handling for `compile` and `serve`.
fn session(args: &Args, model: &str, batch: usize) -> Result<Compiler> {
    let opt = OptLevel::parse(args.opt_or("opt", "2"))
        .ok_or_else(|| anyhow::anyhow!("bad --opt level (use 0..3)"))?;
    Ok(Compiler::for_model(model, batch)?
        .random_weights(args.opt_u64("seed", 7))
        .scheme(parse_scheme(args.opt_or("scheme", "pattern"))?)
        .opt_level(opt)
        .fkw(!args.flag("no-fkw"))
        .deep_reuse(args.flag("reuse")))
}

fn cmd_models() -> Result<()> {
    for name in all_models() {
        println!("{}", by_name(name, 1).summary());
    }
    Ok(())
}

fn cmd_compile(args: &Args) -> Result<()> {
    let model = args.opt_or("model", "resnet-50");
    let mut c = session(args, model, args.opt_usize("batch", 1))?;
    if args.flag("verify") {
        // Force the static soundness checkers on even in release builds
        // (debug builds run them by default); the report gains a
        // `verify:` line, and a violation exits with error[InvalidGraph]
        // or error[InvalidPlan] naming the offending pass.
        c = c.verify(true);
    }
    if args.flag("analyze") {
        // Force the semantic analyses on below O2 (O2+ runs them by
        // default). The report gains an `analysis:` line with the int8
        // QuantPlan summary and the purity classification; guaranteed
        // non-finite paths print as typed warnings.
        c = c.analyze(true);
    }
    // Int8 precision policy (ISSUE-10): `force` quantizes every eligible
    // contraction layer, `auto` follows the compile-time QuantPlan per
    // layer (forcing analysis on). The report gains a `quant:` line with
    // the per-layer precision split.
    let int8 = args.opt_or("int8", "off");
    c = c.quantize(
        QuantPolicy::parse(int8)
            .ok_or_else(|| anyhow::anyhow!("bad --int8 '{int8}' (use off|force|auto)"))?,
    );
    let cm = c.compile()?;
    println!("model: {}", cm.graph().summary());
    print!("{}", cm.report().summary());
    for (fw, class, dev) in [
        (Framework::Mnn, DeviceClass::MobileCpu, devices::s10_cpu()),
        (Framework::XGenFull, DeviceClass::MobileCpu, devices::s10_cpu()),
        (Framework::XGenFull, DeviceClass::MobileGpu, devices::s10_gpu()),
    ] {
        if let Some(ms) = cm.estimate(&dev, fw, class) {
            println!("latency[{} on {}]: {:.1} ms", fw.name(), dev.name, ms);
        }
    }
    if args.flag("infer") {
        // Valid sample inputs per input node: token ids for embedding-fed
        // inputs (demo-transformer), Gaussians otherwise.
        let xs = cm.sample_inputs(args.opt_u64("seed", 7));
        let shape = cm.input_shapes()[0].clone();
        let t0 = std::time::Instant::now();
        let y = cm.infer(&xs)?;
        let finite = y[0].data().iter().all(|v| v.is_finite());
        println!(
            "real inference: {:?} -> {} outputs in {:.2} ms ({})",
            shape,
            y[0].len(),
            t0.elapsed().as_secs_f64() * 1e3,
            if finite { "finite" } else { "NON-FINITE" }
        );
        if !finite {
            anyhow::bail!("inference produced non-finite outputs");
        }
    }
    // Autoregressive smoke: greedy-generate N tokens through a
    // DecodeSession (causal decoder models only — demo-transformer-causal,
    // gpt-2-decoder). Exits nonzero on a non-causal model or invalid ids.
    let n = args.opt_usize("generate", 0);
    if n > 0 {
        let xs = cm.sample_inputs(args.opt_u64("seed", 7));
        let prompt: Vec<u32> = xs[0].data().iter().take(4).map(|&v| v as u32).collect();
        // The last generated token needs no extra position (same sizing
        // as CompiledModel::generate).
        let mut session = cm.decode_session((prompt.len() + n.saturating_sub(1)).max(1))?;
        let t0 = std::time::Instant::now();
        session.prefill(&prompt)?;
        let prefill_ms = t0.elapsed().as_secs_f64() * 1e3;
        let t1 = std::time::Instant::now();
        let toks = session.generate_continue(n)?;
        let step_s = t1.elapsed().as_secs_f64();
        println!(
            "generate: prompt {:?} -> {:?} (prefill {:.2} ms, {:.0} tok/s, kv cache {:.1} KB)",
            prompt,
            toks,
            prefill_ms,
            n as f64 / step_s.max(1e-9),
            session.kv_cache_elems() as f64 * 4.0 / 1024.0
        );
    }
    Ok(())
}

fn cmd_sched(args: &Args) -> Result<()> {
    let want = args.opt_or("variant", "all");
    let horizon = args.opt_f64("horizon", 3000.0);
    for v in variants() {
        if want != "all" && v.name != want {
            continue;
        }
        println!("== {} ==", v.name);
        let mods = modules(v);
        for p in Policy::all() {
            let r = simulate(v.name, &mods, p, horizon, 0xCE01);
            let worst = r.worst_miss_rate();
            print!("{:45} miss {:>5.1}% |", p.name(), worst * 100.0);
            for m in &r.modules {
                if m.name == "percept_postproc" {
                    continue;
                }
                if m.timed_out() {
                    print!(" {}=∞", m.name);
                } else {
                    print!(" {}={:.0}±{:.0}", m.name, m.mean(), m.std());
                }
            }
            println!();
        }
    }
    Ok(())
}

fn cmd_caps(args: &Args) -> Result<()> {
    let cfg = CapsConfig {
        latency_budget_ms: args.opt("budget").and_then(|b| b.parse().ok()),
        iterations: args.opt_usize("iters", 12),
        population: args.opt_usize("pop", 8),
        seed: args.opt_u64("seed", 0xCA95),
    };
    let r = search(&cfg, &devices::s10_cpu());
    println!("evaluated {} candidates; frontier:", r.evaluated);
    for e in &r.frontier {
        println!(
            "  {:6.2} ms  acc {:5.2}%  {:.2}G MACs  [{} w={} d={}]",
            e.latency_ms,
            e.accuracy,
            e.macs as f64 / 1e9,
            e.cand.scheme.name(),
            e.cand.width,
            e.cand.depth
        );
    }
    if let Some(best) = &r.best_in_budget {
        println!("best in budget: {:.2} ms @ {:.2}%", best.latency_ms, best.accuracy);
    }
    Ok(())
}

fn cmd_emit(args: &Args) -> Result<()> {
    use xgen::pruning::pattern::PatternSet;
    let set = PatternSet::elite8();
    let idx = args.opt_usize("pattern", 0).min(set.len() - 1);
    let unroll = args.opt_usize("unroll", 4);
    print!("{}", xgen::codegen::emit_kernel_source(set.patterns[idx], unroll));
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let name = args.opt_or("artifact", "cnn_dense_b1");
    let mut rt = ModelRuntime::open(default_artifact_dir())?;
    println!("platform: {}", rt.platform());
    let m = rt.load(name)?;
    let n: usize = m.input_shape.iter().product();
    let mut rng = Rng::new(args.opt_u64("seed", 1));
    let x: Vec<f32> = (0..n).map(|_| rng.f32() * 2.0 - 1.0).collect();
    let t0 = std::time::Instant::now();
    let y = m.run(&x)?;
    println!(
        "{name}: input {:?} -> {} outputs in {:.2} ms",
        m.input_shape,
        y.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );
    println!("head: {:?}", &y[..y.len().min(8)]);
    Ok(())
}

/// Multi-stream decode serving demo: many concurrent greedy generations
/// over one compiled causal decoder, multiplexed by the
/// [`StreamScheduler`] session pool (ISSUE-8).
fn cmd_decode_serve(args: &Args) -> Result<()> {
    let model = args.opt_or("model", "demo-transformer-causal");
    let cm: CompiledModel = session(args, model, 1)?.compile()?;
    let streams = args.opt_usize("streams", 16);
    let tokens = args.opt_usize("tokens", 12);
    let prompt_len = args.opt_usize("prompt", 4).max(1);
    let max_seq = (prompt_len + tokens.saturating_sub(1)).max(1);
    let cfg = SchedConfig {
        max_streams: args.opt_usize("max-streams", 4),
        queue_cap: args.opt_usize("queue-cap", 1024),
        kv_budget_bytes: args
            .opt("kv-budget-kb")
            .and_then(|v| v.parse::<u64>().ok())
            .map(|kb| kb * 1024),
        default_deadline: args
            .opt("deadline-ms")
            .and_then(|v| v.parse().ok())
            .map(std::time::Duration::from_millis),
    };
    // Valid token ids for this decoder, rotated per stream so every
    // stream decodes a different prompt.
    let xs = cm.sample_inputs(args.opt_u64("seed", 9));
    let base: Vec<u32> = xs[0].data().iter().take(prompt_len).map(|&v| v as u32).collect();
    println!(
        "decode-serving {model}: one session's K/V at max_seq {max_seq} = {:.1} KB",
        cm.kv_cache_bytes(max_seq) as f64 / 1024.0
    );
    let sched = StreamScheduler::start_cfg(cm, max_seq, cfg)?;
    let t0 = std::time::Instant::now();
    let handles: Vec<_> = (0..streams)
        .map(|i| {
            let mut p = base.clone();
            p.rotate_left(i % p.len());
            sched.submit(p, tokens)
        })
        .collect();
    let mut toks = 0usize;
    let mut failed = 0usize;
    let mut first_err: Option<XgenError> = None;
    for h in handles {
        let (out, err) = h.collect();
        toks += out.len();
        if let Some(e) = err {
            failed += 1;
            first_err.get_or_insert(e);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let st = sched.shutdown();
    println!("{}", st.report());
    println!(
        "{streams} streams × {tokens} tokens in {:.1} ms: {:.0} tok/s aggregate",
        wall * 1e3,
        toks as f64 / wall.max(1e-9)
    );
    if let Some(e) = first_err {
        return Err(anyhow::Error::new(e).context(format!("{failed}/{streams} streams failed")));
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    let n = args.opt_usize("requests", 64);
    let cfg = ServeConfig {
        max_wait: std::time::Duration::from_millis(args.opt_u64("max-wait-ms", 2)),
        queue_cap: args.opt_usize("queue-cap", 1024),
        default_deadline: args
            .opt("deadline-ms")
            .and_then(|v| v.parse().ok())
            .map(std::time::Duration::from_millis),
    };
    let (server, per) = if args.flag("pjrt") {
        // Legacy path: AOT artifacts over the PJRT runtime.
        let server =
            Server::start_cfg(default_artifact_dir(), "cnn_dense_b1", "cnn_dense_b4", cfg)?;
        (server, 3 * 24 * 24)
    } else {
        // Default path: compiled sessions executing in-process.
        let model = args.opt_or("model", "demo-cnn");
        let single: CompiledModel = session(args, model, 1)?.compile()?;
        let batched = session(args, model, args.opt_usize("batch", 4))?.compile()?;
        let per: usize = single.input_shapes()[0].iter().product();
        println!(
            "serving {} [{}], batch {} + remainder singles",
            model,
            single.report().opt.name(),
            batched.batch_size()
        );
        (Server::start_compiled_cfg(single, batched, cfg)?, per)
    };
    let mut rng = Rng::new(9);
    let t0 = std::time::Instant::now();
    let rxs: Vec<_> = (0..n)
        .map(|_| server.submit((0..per).map(|_| rng.f32()).collect()))
        .collect();
    let mut first_err: Option<XgenError> = None;
    let mut failed = 0usize;
    for rx in rxs {
        match rx.recv() {
            Ok(Ok(_)) => {}
            Ok(Err(e)) => {
                failed += 1;
                first_err.get_or_insert(e);
            }
            Err(_) => anyhow::bail!("server thread died mid-run"),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let st = server.stats();
    println!("{}", st.report());
    if let Some(s) = st.summary() {
        println!(
            "{n} requests in {:.1} ms: {:.0} req/s, p50 {:.2} ms, p95 {:.2} ms",
            wall * 1e3,
            n as f64 / wall,
            s.p50,
            s.p95
        );
    }
    if let Some(e) = first_err {
        return Err(anyhow::Error::new(e).context(format!("{failed}/{n} requests failed")));
    }
    Ok(())
}
