//! CAPS — Compiler-Aware neural architecture & Pruning co-Search (§2.4,
//! Fig 13; the NPAS framework of the Fig 14 results).
//!
//! The search space couples *architecture* knobs (depth/width multipliers,
//! per-stage kernel size) with *compression* knobs (pruning scheme and
//! rate), and — the paper's differentiator — evaluates every candidate
//! through the actual compiler pipeline: the candidate graph is built,
//! fused by DNNFusion, and costed on the target device, so the latency
//! constraint reflects code generation, not a FLOPs proxy.
//!
//! Search: an ε-greedy evolutionary controller (the RL-with-fast-
//! evaluation stand-in; see DESIGN.md) over a Pareto archive, plus the
//! **composability** optimization: candidate layer sequences are mined
//! with [`sequitur`] for shared blocks whose training cost is paid once
//! ([`composability`]).

pub mod composability;
pub mod sequitur;

use crate::baselines::{DeviceClass, Framework};
use crate::cost::{estimate_latency, scheme_density_map, sparse_efficiency, DensityMap, Device};
use crate::fusion::{fuse, FusionConfig};
use crate::graph::zoo::NetBuilder;
use crate::graph::{Act, Graph};
use crate::pruning::{AccuracyModel, PruneScheme};
use crate::util::rng::Rng;

/// One point in the joint architecture × pruning space.
#[derive(Debug, Clone, PartialEq)]
pub struct Candidate {
    /// Width multiplier ∈ {0.5, 0.75, 1.0, 1.25, 1.5} (×32 base channels).
    pub width: f64,
    /// Depth: number of stage repeats ∈ 1..=4.
    pub depth: usize,
    /// Kernel size per stage (3 or 5).
    pub kernels: [usize; 3],
    /// Pruning scheme + rate.
    pub scheme: PruneScheme,
}

impl Candidate {
    /// Layer-symbol sequence for composability mining: each (kind, width,
    /// kernel) combination is one terminal symbol.
    pub fn layer_symbols(&self) -> Vec<u32> {
        let mut syms = Vec::new();
        let w = (self.width * 4.0).round() as u32; // quantized width id
        for (si, &k) in self.kernels.iter().enumerate() {
            let stage_width = w + si as u32 * 16;
            for _ in 0..self.depth {
                syms.push(stage_width * 10 + if k == 5 { 5 } else { 3 });
            }
        }
        syms
    }

    /// Materialize the candidate as a graph (MobileNet-ish 3-stage CNN).
    pub fn build_graph(&self) -> Graph {
        let base = (32.0 * self.width).round() as usize;
        let mut b = NetBuilder::new("caps-cand", &[1, 3, 224, 224]);
        b.conv_bn_act(base.max(8), 3, 2, 1, Act::HardSwish);
        let mut c = base.max(8);
        for (si, &k) in self.kernels.iter().enumerate() {
            let c_out = c * 2;
            for d in 0..self.depth {
                let stride = if d == 0 { 2 } else { 1 };
                // Inverted residual-ish: expand, dw k×k, project.
                b.conv_bn_act(c * 4, 1, 1, 0, Act::HardSwish);
                b.dwconv(k, stride, k / 2);
                b.bn();
                b.act(Act::HardSwish);
                b.conv(if d == self.depth - 1 { c_out } else { c }, 1, 1, 0, 1);
                b.bn();
            }
            c = c_out;
            let _ = si;
        }
        b.conv_bn_act(c * 2, 1, 1, 0, Act::HardSwish);
        b.gap();
        b.dense(1000);
        b.finish()
    }
}

/// Evaluation of one candidate through the full compiler loop.
#[derive(Debug, Clone)]
pub struct Evaluated {
    pub cand: Candidate,
    pub latency_ms: f64,
    pub accuracy: f64,
    pub macs: u64,
}

/// CAPS configuration.
#[derive(Debug, Clone)]
pub struct CapsConfig {
    /// Latency budget on the target device (None = unconstrained frontier).
    pub latency_budget_ms: Option<f64>,
    pub iterations: usize,
    pub population: usize,
    pub seed: u64,
}

impl Default for CapsConfig {
    fn default() -> Self {
        CapsConfig { latency_budget_ms: None, iterations: 24, population: 12, seed: 0xCA95 }
    }
}

/// Synthetic accuracy surface for the search family: grows
/// logarithmically with capacity (diminishing returns), kernel-5 stages
/// add a little; pruning subtracts per [`AccuracyModel`]. Calibrated so
/// 1.0×-width dense ≈ 75–78% — the Fig 14 regime. (The *measured*
/// accuracy experiment on the trainable demo CNN lives in python/.)
pub fn accuracy_surface(cand: &Candidate, macs: u64) -> f64 {
    let gmacs = macs as f64 / 1e9;
    let base = 70.0 + 3.4 * (gmacs / 0.05).max(0.2).ln();
    let k5_bonus: f64 = cand.kernels.iter().filter(|&&k| k == 5).count() as f64 * 0.15;
    let am = AccuracyModel::default();
    am.estimate((base + k5_bonus).min(82.0), &cand.scheme)
}

/// Evaluate one candidate: build graph → fuse → cost → accuracy estimate.
pub fn evaluate(cand: &Candidate, device: &Device) -> Evaluated {
    let g = cand.build_graph();
    let plan = fuse(&g, &FusionConfig::default());
    let prof = Framework::XGenFull.profile(DeviceClass::MobileCpu).unwrap();
    let dm = if matches!(cand.scheme, PruneScheme::None) {
        DensityMap::new()
    } else {
        scheme_density_map(&g, &cand.scheme)
    };
    let lat = estimate_latency(&g, &plan, device, &prof, &dm, sparse_efficiency(&cand.scheme))
        .total_ms();
    let macs = g.total_macs();
    Evaluated {
        cand: cand.clone(),
        latency_ms: lat,
        accuracy: accuracy_surface(cand, macs),
        macs,
    }
}

fn random_candidate(rng: &mut Rng) -> Candidate {
    let widths = [0.5, 0.75, 1.0, 1.25, 1.5];
    let schemes = [
        PruneScheme::None,
        PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.3 },
        PruneScheme::Block { block: 8, rate: 0.75 },
        PruneScheme::Block { block: 32, rate: 0.85 },
        PruneScheme::Structured { rate: 0.5 },
    ];
    Candidate {
        width: *rng.choose(&widths),
        depth: 1 + rng.below(4),
        kernels: [
            *rng.choose(&[3usize, 5]),
            *rng.choose(&[3usize, 5]),
            *rng.choose(&[3usize, 5]),
        ],
        scheme: schemes[rng.below(schemes.len())].clone(),
    }
}

fn mutate(c: &Candidate, rng: &mut Rng) -> Candidate {
    let mut m = c.clone();
    match rng.below(4) {
        0 => m.width = *rng.choose(&[0.5, 0.75, 1.0, 1.25, 1.5]),
        1 => m.depth = 1 + rng.below(4),
        2 => m.kernels[rng.below(3)] = *rng.choose(&[3usize, 5]),
        _ => {
            m.scheme = match rng.below(4) {
                0 => PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.2 + rng.f64() * 0.4 },
                1 => PruneScheme::Block { block: *rng.choose(&[4usize, 8, 16, 32]), rate: 0.6 + rng.f64() * 0.3 },
                2 => PruneScheme::Structured { rate: 0.3 + rng.f64() * 0.4 },
                _ => PruneScheme::None,
            }
        }
    }
    m
}

/// Search result: Pareto archive (accuracy vs latency) + bookkeeping.
#[derive(Debug, Clone)]
pub struct SearchResult {
    /// Pareto-optimal evaluated candidates, sorted by latency.
    pub frontier: Vec<Evaluated>,
    pub evaluated: usize,
    /// Best candidate meeting the budget, if one was set.
    pub best_in_budget: Option<Evaluated>,
}

/// Run the NPAS co-search loop.
pub fn search(cfg: &CapsConfig, device: &Device) -> SearchResult {
    let mut rng = Rng::new(cfg.seed);
    let mut archive: Vec<Evaluated> = Vec::new();
    let mut evaluated = 0usize;
    let mut population: Vec<Candidate> =
        (0..cfg.population).map(|_| random_candidate(&mut rng)).collect();
    for _ in 0..cfg.iterations {
        for cand in &population {
            let e = evaluate(cand, device);
            evaluated += 1;
            insert_pareto(&mut archive, e);
        }
        // ε-greedy: mostly mutate archive elites, sometimes explore fresh.
        population = (0..cfg.population)
            .map(|_| {
                if !archive.is_empty() && rng.chance(0.8) {
                    let parent = &archive[rng.below(archive.len())].cand;
                    mutate(parent, &mut rng)
                } else {
                    random_candidate(&mut rng)
                }
            })
            .collect();
    }
    archive.sort_by(|a, b| a.latency_ms.partial_cmp(&b.latency_ms).unwrap());
    let best_in_budget = cfg.latency_budget_ms.and_then(|budget| {
        archive
            .iter()
            .filter(|e| e.latency_ms <= budget)
            .max_by(|a, b| a.accuracy.partial_cmp(&b.accuracy).unwrap())
            .cloned()
    });
    SearchResult { frontier: archive, evaluated, best_in_budget }
}

fn insert_pareto(archive: &mut Vec<Evaluated>, e: Evaluated) {
    if archive
        .iter()
        .any(|a| a.latency_ms <= e.latency_ms && a.accuracy >= e.accuracy)
    {
        return; // dominated
    }
    archive.retain(|a| !(e.latency_ms <= a.latency_ms && e.accuracy >= a.accuracy));
    archive.push(e);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cost::devices;

    #[test]
    fn candidate_builds_valid_graph() {
        let c = Candidate {
            width: 1.0,
            depth: 2,
            kernels: [3, 5, 3],
            scheme: PruneScheme::None,
        };
        let g = c.build_graph();
        assert!(g.validate().is_ok());
        assert!(g.total_macs() > 10_000_000);
    }

    #[test]
    fn accuracy_surface_monotone_in_capacity() {
        let mk = |width| Candidate {
            width,
            depth: 2,
            kernels: [3, 3, 3],
            scheme: PruneScheme::None,
        };
        let small = evaluate(&mk(0.5), &devices::s10_cpu());
        let big = evaluate(&mk(1.5), &devices::s10_cpu());
        assert!(big.accuracy > small.accuracy);
        assert!(big.latency_ms > small.latency_ms);
    }

    #[test]
    fn search_produces_nonempty_pareto_frontier() {
        let cfg = CapsConfig { iterations: 6, population: 6, ..Default::default() };
        let r = search(&cfg, &devices::s10_cpu());
        assert!(r.frontier.len() >= 3, "frontier size {}", r.frontier.len());
        assert!(r.evaluated >= 36);
        // Frontier is strictly improving in accuracy as latency grows.
        for w in r.frontier.windows(2) {
            assert!(w[0].latency_ms <= w[1].latency_ms);
            assert!(
                w[1].accuracy > w[0].accuracy - 1e-9,
                "dominated point on frontier"
            );
        }
    }

    #[test]
    fn latency_budget_respected() {
        let cfg = CapsConfig {
            latency_budget_ms: Some(8.0),
            iterations: 6,
            population: 6,
            ..Default::default()
        };
        let r = search(&cfg, &devices::s10_cpu());
        if let Some(best) = &r.best_in_budget {
            assert!(best.latency_ms <= 8.0);
        }
        // With a generous budget a model must be found.
        let cfg2 = CapsConfig { latency_budget_ms: Some(1e6), iterations: 3, population: 4, ..Default::default() };
        assert!(search(&cfg2, &devices::s10_cpu()).best_in_budget.is_some());
    }

    #[test]
    fn pruned_candidates_dominate_dense_at_same_accuracy_band() {
        // A pattern-pruned 1.0x net should be faster than the dense 1.0x
        // net with only a small accuracy drop — the co-search's raison
        // d'être.
        let dense = Candidate { width: 1.0, depth: 2, kernels: [3, 3, 3], scheme: PruneScheme::None };
        let pruned = Candidate {
            scheme: PruneScheme::Pattern { set_size: 8, connectivity_rate: 0.3 },
            ..dense.clone()
        };
        let d = evaluate(&dense, &devices::s10_cpu());
        let p = evaluate(&pruned, &devices::s10_cpu());
        assert!(p.latency_ms < d.latency_ms * 0.75, "{} vs {}", p.latency_ms, d.latency_ms);
        assert!(d.accuracy - p.accuracy < 1.0, "accuracy drop {}", d.accuracy - p.accuracy);
    }

    #[test]
    fn layer_symbols_shared_between_similar_candidates() {
        let a = Candidate { width: 1.0, depth: 3, kernels: [3, 3, 3], scheme: PruneScheme::None };
        let b = Candidate { width: 1.0, depth: 2, kernels: [3, 3, 5], scheme: PruneScheme::None };
        let sa = a.layer_symbols();
        let sb = b.layer_symbols();
        let shared = sb.iter().filter(|s| sa.contains(s)).count();
        assert!(shared >= sb.len() / 2, "only {shared}/{} shared", sb.len());
    }
}
