//! Composability-driven pruning-space exploration (§2.4, the Wootz line of
//! work): candidate networks in the search space share layer blocks, so
//! the training results of common blocks can be reused across candidates.
//! All candidates' layer sequences are concatenated, [`sequitur`] mines
//! the most reusable blocks, and the training-cost model charges each
//! shared block once (pre-training) instead of once per candidate.

use super::sequitur::Grammar;

/// Training-cost accounting for a batch of candidates.
#[derive(Debug, Clone)]
pub struct CompoPlan {
    /// Blocks chosen for pre-training: (layer symbols, #uses).
    pub blocks: Vec<(Vec<u32>, usize)>,
    /// Cost (layer-epochs) of training every candidate from scratch.
    pub cost_naive: u64,
    /// Cost with block pre-training + per-candidate assembly fine-tuning.
    pub cost_composed: u64,
}

impl CompoPlan {
    pub fn savings(&self) -> f64 {
        if self.cost_naive == 0 {
            return 0.0;
        }
        1.0 - self.cost_composed as f64 / self.cost_naive as f64
    }
}

/// Cost model constants: training a layer for the full schedule costs 1.0
/// layer-epoch; fine-tuning an assembled network costs `FINETUNE_FRAC` of
/// full training for every layer (shared or not).
const FINETUNE_FRAC: f64 = 0.25;

/// Plan block pre-training for a set of candidate layer sequences.
///
/// A separator symbol is inserted between candidates so Sequitur cannot
/// invent blocks spanning two networks.
pub fn plan(candidates: &[Vec<u32>]) -> CompoPlan {
    let sep_base = candidates
        .iter()
        .flat_map(|c| c.iter())
        .copied()
        .max()
        .unwrap_or(0)
        + 1;
    let mut seq = Vec::new();
    for (i, c) in candidates.iter().enumerate() {
        seq.extend_from_slice(c);
        seq.push(sep_base + i as u32); // unique separator: never repeats
    }
    let g = Grammar::infer(&seq);
    let blocks = g.reusable_blocks();

    let total_layers: u64 = candidates.iter().map(|c| c.len() as u64).sum();
    let cost_naive = total_layers;

    // Composed: each reusable block trained once; remaining layers trained
    // per candidate; everything fine-tuned at FINETUNE_FRAC.
    let mut covered: u64 = 0;
    let mut pretrain: u64 = 0;
    for (body, uses) in &blocks {
        pretrain += body.len() as u64;
        covered += (body.len() * uses) as u64;
    }
    let covered = covered.min(total_layers);
    let uncovered = total_layers - covered;
    let finetune = (total_layers as f64 * FINETUNE_FRAC) as u64;
    let cost_composed = pretrain + uncovered + finetune;

    CompoPlan { blocks, cost_naive, cost_composed: cost_composed.min(cost_naive) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::caps::{Candidate};
    use crate::pruning::PruneScheme;

    #[test]
    fn identical_candidates_save_most() {
        let seq: Vec<u32> = vec![1, 2, 3, 4, 1, 2, 3, 4];
        let cands: Vec<Vec<u32>> = (0..6).map(|_| seq.clone()).collect();
        let p = plan(&cands);
        assert!(p.savings() > 0.4, "savings {}", p.savings());
        assert!(!p.blocks.is_empty());
    }

    #[test]
    fn disjoint_candidates_save_nothing_structural() {
        let cands: Vec<Vec<u32>> = (0..4)
            .map(|i| ((i * 10)..(i * 10 + 5)).map(|x| x as u32).collect())
            .collect();
        let p = plan(&cands);
        // No cross-candidate blocks; cost_composed == naive (clamped).
        assert!(p.savings() <= 1e-9, "savings {}", p.savings());
    }

    #[test]
    fn caps_population_shares_blocks() {
        // Real CAPS candidates around one architecture family share stage
        // blocks, so savings must be substantial.
        let mk = |depth| Candidate {
            width: 1.0,
            depth,
            kernels: [3, 3, 3],
            scheme: PruneScheme::None,
        };
        let cands: Vec<Vec<u32>> =
            [mk(2), mk(3), mk(4), mk(2), mk(3)].iter().map(|c| c.layer_symbols()).collect();
        let p = plan(&cands);
        // Mixed depths limit block sharing; Wootz-style savings on such a
        // population land in the 10–40% range.
        assert!(p.savings() > 0.12, "savings {}", p.savings());
    }

    #[test]
    fn separator_prevents_cross_network_blocks() {
        // Tail of candidate A + head of candidate B repeat, but only across
        // the boundary — must not be mined as a block.
        let cands = vec![vec![1, 2, 9, 9], vec![9, 9, 3, 4], vec![5, 6, 7, 8]];
        let p = plan(&cands);
        for (body, _) in &p.blocks {
            // The only legitimate repeat is [9,9] *within* each candidate...
            // which does appear once per candidate; ensure no block contains
            // a separator (symbols > 9).
            assert!(body.iter().all(|&s| s <= 9), "block crosses boundary: {body:?}");
        }
    }
}
