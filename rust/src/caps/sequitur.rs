//! Sequitur (Nevill-Manning & Witten, 1997) — the hierarchical grammar
//! inference algorithm XGen uses to find the **most reusable building
//! blocks** across the networks CAPS explores (§2.4): all layers of all
//! candidate networks are flattened into a symbol sequence; Sequitur's
//! rules are exactly the repeated layer blocks worth pre-training once.
//!
//! Implementation: iterative digram replacement to a fixpoint. This is the
//! O(n²) formulation (repeatedly find the most frequent digram and replace
//! it), which produces the same grammar class as the online algorithm and
//! is simpler to verify; sequences here are thousands of symbols, far
//! below where the asymptotics matter.

use std::collections::BTreeMap;

/// Terminal symbols are user values; nonterminals index `Grammar::rules`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Sym {
    T(u32),
    /// Rule reference.
    N(u32),
}

/// A context-free grammar with rule 0 as the start rule.
#[derive(Debug, Clone)]
pub struct Grammar {
    /// rules[0] = start; rules[i] for i>0 are introduced digram rules.
    pub rules: Vec<Vec<Sym>>,
}

impl Grammar {
    /// Infer a grammar for `seq` by repeated replacement of repeating
    /// digrams (digram uniqueness), then removing rules used once (rule
    /// utility).
    pub fn infer(seq: &[u32]) -> Grammar {
        let mut rules: Vec<Vec<Sym>> = vec![seq.iter().map(|&t| Sym::T(t)).collect()];
        loop {
            // Count digrams across all rules (non-overlapping, greedy).
            let mut counts: BTreeMap<(Sym, Sym), usize> = BTreeMap::new();
            for r in &rules {
                let mut i = 0;
                while i + 1 < r.len() {
                    let d = (r[i], r[i + 1]);
                    *counts.entry(d).or_insert(0) += 1;
                    // Avoid counting aaa as two aa digrams.
                    if i + 2 < r.len() && r[i] == r[i + 1] && r[i + 1] == r[i + 2] {
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
            }
            let Some((&digram, &count)) = counts.iter().max_by_key(|(_, &c)| c) else {
                break;
            };
            if count < 2 {
                break;
            }
            // Introduce a rule for the digram and rewrite everywhere —
            // everywhere EXCEPT the new rule's own body (rewriting it would
            // produce the cyclic rule N → N).
            let new_rule = rules.len();
            let nt = Sym::N(new_rule as u32);
            rules.push(vec![digram.0, digram.1]);
            for (ri, r) in rules.iter_mut().enumerate() {
                if ri == new_rule {
                    continue;
                }
                let mut out = Vec::with_capacity(r.len());
                let mut i = 0;
                while i < r.len() {
                    if i + 1 < r.len() && (r[i], r[i + 1]) == digram {
                        out.push(nt);
                        i += 2;
                    } else {
                        out.push(r[i]);
                        i += 1;
                    }
                }
                *r = out;
            }
        }
        Grammar { rules }
    }

    /// Expand a symbol to its terminal string.
    pub fn expand(&self, s: Sym) -> Vec<u32> {
        match s {
            Sym::T(t) => vec![t],
            Sym::N(i) => self.rules[i as usize]
                .iter()
                .flat_map(|&x| self.expand(x))
                .collect(),
        }
    }

    /// Reconstruct the original sequence from the start rule.
    pub fn reconstruct(&self) -> Vec<u32> {
        self.rules[0].iter().flat_map(|&s| self.expand(s)).collect()
    }

    /// How many times each non-tombstone rule is referenced.
    pub fn rule_usage(&self) -> Vec<usize> {
        let mut usage = vec![0usize; self.rules.len()];
        for r in &self.rules {
            for s in r {
                if let Sym::N(i) = s {
                    usage[*i as usize] += 1;
                }
            }
        }
        usage
    }

    /// The reusable blocks: (terminal expansion, reference count) of every
    /// rule used ≥2 times, longest first — the pre-training candidates.
    pub fn reusable_blocks(&self) -> Vec<(Vec<u32>, usize)> {
        let usage = self.rule_usage();
        let mut out: Vec<(Vec<u32>, usize)> = (1..self.rules.len())
            .filter(|&i| usage[i] >= 2 && !self.rules[i].is_empty())
            .map(|i| (self.expand(Sym::N(i as u32)), usage[i]))
            .collect();
        out.sort_by(|a, b| (b.0.len() * b.1).cmp(&(a.0.len() * a.1)));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::forall;

    #[test]
    fn reconstruction_is_lossless() {
        forall("sequitur reconstructs input", 32, |rng| {
            let n = 2 + rng.below(60);
            let alphabet = 1 + rng.below(5) as u32;
            let seq: Vec<u32> = (0..n).map(|_| rng.next_u32() % alphabet).collect();
            let g = Grammar::infer(&seq);
            assert_eq!(g.reconstruct(), seq);
        });
    }

    #[test]
    fn finds_repeated_block() {
        // abcabcabc → a rule covering "abc" (possibly nested) used 3 times.
        let seq = [1, 2, 3, 1, 2, 3, 1, 2, 3];
        let g = Grammar::infer(&seq);
        let blocks = g.reusable_blocks();
        assert!(!blocks.is_empty());
        let top = &blocks[0];
        assert_eq!(top.0, vec![1, 2, 3]);
        assert!(top.1 >= 3);
    }

    #[test]
    fn no_rules_for_unique_sequence() {
        let seq = [1, 2, 3, 4, 5, 6];
        let g = Grammar::infer(&seq);
        assert!(g.reusable_blocks().is_empty());
        assert_eq!(g.reconstruct(), seq);
    }

    #[test]
    fn grammar_is_smaller_than_repetitive_input() {
        let mut seq = Vec::new();
        for _ in 0..16 {
            seq.extend_from_slice(&[7, 8, 9, 10]);
        }
        let g = Grammar::infer(&seq);
        let grammar_size: usize = g.rules.iter().map(|r| r.len()).sum();
        assert!(grammar_size < seq.len() / 2, "grammar {grammar_size} vs seq {}", seq.len());
        assert_eq!(g.reconstruct(), seq);
    }

    #[test]
    fn digram_counting_handles_aaa_runs() {
        let seq = [5, 5, 5, 5, 5, 5];
        let g = Grammar::infer(&seq);
        assert_eq!(g.reconstruct(), seq);
    }
}
