//! Small self-contained substrates that replace crates unavailable in the
//! offline vendor set (see DESIGN.md "Offline-vendor substitutions").

pub mod rng;
pub mod stats;
pub mod json;
pub mod cli;
pub mod bench;
pub mod proptest_lite;

/// Format a float with a fixed number of significant-ish decimals for table
/// output, dropping trailing zeros ("6.0" stays "6.0", "6.75" stays "6.75").
pub fn fmt_ms(v: f64) -> String {
    if v >= 100.0 {
        format!("{:.0}", v)
    } else if v >= 10.0 {
        format!("{:.1}", v)
    } else {
        format!("{:.2}", v)
    }
}

/// Geometric mean of a non-empty slice of positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "geomean of empty slice");
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[8.0]) - 8.0).abs() < 1e-12);
    }

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
    }

    #[test]
    fn fmt_ms_ranges() {
        assert_eq!(fmt_ms(117.3), "117");
        assert_eq!(fmt_ms(36.04), "36.0");
        assert_eq!(fmt_ms(6.7), "6.70");
    }
}
